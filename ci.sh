#!/usr/bin/env bash
# CI gate, two tiers:
#   tier-0 — the invariant lint (DESIGN.md §Static-Analysis), via the
#            stdlib-only Python mirror. Runs in EVERY container, toolchain
#            or not, and gates everything else.
#   tier-1 — formatting, clippy, build, the full test suite, the example
#            smokes and the four bench baselines. Skipped (loudly) when
#            no cargo toolchain is present.
# Usage: ./ci.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-0: invariant lint (python mirror, self-test + deny) =="
python3 tools/lint.py --self-test
python3 tools/lint.py --deny

if ! command -v cargo >/dev/null 2>&1; then
  echo "cargo not found: tier-0 lint gate passed, skipping toolchain tiers"
  echo "CI OK (tier-0 only)"
  exit 0
fi

# Same spec, same fixtures, second interpreter: the Rust runner must agree
# with the Python mirror before anything heavier runs.
echo "== tier-0: invariant lint (rust runner, self-test + deny) =="
cargo run -q -p lint -- --self-test
cargo run -q -p lint -- --deny

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (-D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

# Run the suite again with the pool pinned to one thread so the serial
# fallback paths (no lease, direct scatter into the output) stay covered.
# (The pool resolves GNN_SPMM_THREADS once per process, so this needs a
# separate run, not a separate test.)
echo "== tier-1 again with GNN_SPMM_THREADS=1 (serial fallback paths) =="
GNN_SPMM_THREADS=1 cargo test -q

# And once more with a forced NON-DEFAULT kernel schedule: every
# unscheduled spmm_into/spmm_t_into entry point resolves
# GNN_SPMM_SCHEDULE once per process (sparse::schedule::Schedule), so this
# run drives the whole suite through the 8-lane tiles, even splits and a
# serial thread cap — the schedule variants the default run never touches.
echo "== tier-1 again with GNN_SPMM_SCHEDULE=t8/even/1 (non-default schedule) =="
GNN_SPMM_SCHEDULE=t8/even/1 cargo test -q

# Mini-batch smoke: small shard count, fixed seed, shrunk ogbn-arxiv-scale.
# The examples assert the shard stream reuses cached decisions and never
# falls back to COO round-trip extraction; the strict >80% warm-rate gate
# runs in tests/integration_minibatch.rs under tier-1 above.
echo "== minibatch smoke test: GCN (4 shards, fixed seed) =="
cargo run --release --example minibatch_gcn -- \
  --shrink 32 --shards 4 --epochs 2 --fanout 12 --policy static --seed 48879

# RGCN exercises the per-relation extraction path: R slots per layer, one
# decision-cache entry per relation per shard signature (ISSUE-4).
echo "== minibatch smoke test: RGCN (4 shards, per-relation extraction) =="
cargo run --release --example minibatch_rgcn -- \
  --shrink 32 --shards 4 --epochs 2 --fanout 12 --policy static --seed 48879

# Warm-start flow end to end (§Shared-Ownership): train → save the decision
# cache → a FRESH PROCESS loads it and asserts the warm hit rate. Two runs
# of the same example against one cache path = two separate processes.
echo "== warm-start decision cache smoke (train -> save -> fresh-process load) =="
WARMSTART_DIR="$(mktemp -d)"
trap 'rm -rf "$WARMSTART_DIR"' EXIT
WARMSTART_CACHE="$WARMSTART_DIR/warmstart_cache.json"
cargo run --release --example warmstart_cache -- \
  --cache "$WARMSTART_CACHE" --shrink 32 --shards 4 --epochs 2 --fanout 12 --seed 48879
cargo run --release --example warmstart_cache -- \
  --cache "$WARMSTART_CACHE" --shrink 32 --shards 4 --epochs 2 --fanout 12 --seed 48879 \
  --expect-warm 0.8
# Schedule-space PR: persisted cache entries are complete (format, schedule)
# plans — the warm-started file must carry the schedule fields.
for field in tile split threads; do
  grep -q "\"$field\"" "$WARMSTART_CACHE" \
    || { echo "warm-start cache: $WARMSTART_CACHE missing schedule field $field"; exit 1; }
done

# Serving smoke (§Serving): power-law request stream, mid-stream epoch
# swap, warm cache shared read-only across workers. Run once with the
# SpMM pool pinned serial and once with default threading; both runs must
# emit non-empty JSON-lines with every latency field.
echo "== serving smoke: serve_demo (epoch-swap mid-stream, both threading modes) =="
SERVE_OUT="$WARMSTART_DIR/BENCH_serve.json"
SERVE_CACHE="$WARMSTART_DIR/serve_cache.json"
for mode in pinned default; do
  rm -f "$SERVE_OUT"
  if [ "$mode" = pinned ]; then
    GNN_SPMM_THREADS=1 cargo run --release --example serve_demo -- \
      --shrink 32 --requests 120 --workers 1,4 --seed 48879 \
      --out "$SERVE_OUT" --cache "$SERVE_CACHE"
  else
    cargo run --release --example serve_demo -- \
      --shrink 32 --requests 120 --workers 1,4 --seed 48879 \
      --out "$SERVE_OUT" --cache "$SERVE_CACHE"
  fi
  test -s "$SERVE_OUT" || { echo "serve smoke ($mode): $SERVE_OUT empty"; exit 1; }
  for field in p50_ns p95_ns p99_ns ops_per_sec; do
    grep -q "\"$field\"" "$SERVE_OUT" \
      || { echo "serve smoke ($mode): $SERVE_OUT missing $field"; exit 1; }
  done
done

# Fault-injection smoke (§Fault-Tolerance): GNN_FAULT_SEED arms the
# deterministic harness inside serve_demo — the decision-cache file is torn
# in half before reload (must cold-start, not abort), workers draw seeded
# panics/delays (supervisor respawns within the restart budget), and
# expired-deadline probes exercise admission control. The demo itself
# asserts the liveness contract (one response per admitted request); here
# we assert the report carries the fault accounting.
echo "== fault-injection smoke: serve_demo armed via GNN_FAULT_SEED =="
rm -f "$SERVE_OUT"
GNN_FAULT_SEED=48879 cargo run --release --example serve_demo -- \
  --shrink 32 --requests 120 --workers 1,4 --seed 48879 \
  --out "$SERVE_OUT" --cache "$SERVE_CACHE"
test -s "$SERVE_OUT" || { echo "fault smoke: $SERVE_OUT empty"; exit 1; }
for field in shed expired restarts panics degraded; do
  grep -q "\"$field\"" "$SERVE_OUT" \
    || { echo "fault smoke: $SERVE_OUT missing $field"; exit 1; }
done

# Crash-recovery smoke (§Streaming-Durability): stream_ingest arms a
# scripted CrashPoint mid-stream — the injected crash kills the store at a
# durability seam, the example re-opens it (checkpoint + WAL-tail replay),
# retries, and asserts every merged row read is bit-identical to an
# in-memory reference. A second fault-free run covers the clean path. Both
# must emit a record carrying the ingest/recovery fields.
echo "== crash-recovery smoke: stream_ingest (scripted CrashPoint + fault-free) =="
STREAM_OUT="$WARMSTART_DIR/BENCH_stream.json"
for ordinal in 150 0; do
  rm -f "$STREAM_OUT"
  cargo run --release --example stream_ingest -- \
    --ops 400 --crash-ordinal "$ordinal" --seed 48879 --out "$STREAM_OUT"
  test -s "$STREAM_OUT" || { echo "stream smoke (ordinal $ordinal): $STREAM_OUT empty"; exit 1; }
  for field in ingest_ops_per_sec recovery_ms acked replayed verified; do
    grep -q "\"$field\"" "$STREAM_OUT" \
      || { echo "stream smoke (ordinal $ordinal): $STREAM_OUT missing $field"; exit 1; }
  done
done
grep -q '"crashes":0' "$STREAM_OUT" \
  || { echo "stream smoke: fault-free run reported crashes"; exit 1; }

# Bench baselines (EXPERIMENTS.md §Perf): the perf trajectories — kernel
# layer (BENCH_spmm.json), mini-batch training (BENCH_minibatch.json),
# serving (BENCH_serve.json) and streaming ingestion
# (BENCH_stream.json). Each bench self-compares against the
# previous JSON at its output path, so running them in CI keeps the
# trajectory files current.
echo "== bench baselines: perf_hotpath / bench_minibatch / bench_serve / bench_stream =="
cargo bench --bench perf_hotpath
cargo bench --bench bench_minibatch
cargo bench --bench bench_serve
cargo bench --bench bench_stream

echo "CI OK"
