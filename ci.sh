#!/usr/bin/env bash
# CI gate: formatting, lints, build and the tier-1 test suite.
# Usage: ./ci.sh  (from the repo root; cargo required)
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (-D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

echo "CI OK"
