#!/usr/bin/env bash
# CI gate: formatting, lints, build and the tier-1 test suite.
# Usage: ./ci.sh  (from the repo root; cargo required)
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy (-D warnings) =="
cargo clippy --all-targets -- -D warnings

echo "== tier-1: cargo build --release && cargo test -q =="
cargo build --release
cargo test -q

# Run the suite again with the pool pinned to one thread so the serial
# fallback paths (no lease, direct scatter into the output) stay covered.
# (The pool resolves GNN_SPMM_THREADS once per process, so this needs a
# separate run, not a separate test.)
echo "== tier-1 again with GNN_SPMM_THREADS=1 (serial fallback paths) =="
GNN_SPMM_THREADS=1 cargo test -q

echo "CI OK"
