//! Warm-start smoke: persist the decision cache from one training process
//! and reload it in the next, skipping the cold first epoch entirely.
//!
//! ci.sh runs this twice against the same `--cache` path:
//!
//! ```bash
//! # 1st run: no cache file yet → trains cold, saves the cache.
//! cargo run --release --example warmstart_cache -- --cache /tmp/c.json --shrink 32
//! # 2nd run (fresh process): loads the cache, trains warm, and asserts
//! # the overall hit rate clears the warm-rate gate.
//! cargo run --release --example warmstart_cache -- --cache /tmp/c.json --shrink 32 --expect-warm 0.8
//! ```

use gnn_spmm::gnn::engine::StaticPolicy;
use gnn_spmm::gnn::{train_minibatch_warm, MinibatchConfig, ModelKind};
use gnn_spmm::graph::{GraphDataset, LARGE_DATASETS};
use gnn_spmm::predictor::DecisionCache;
use gnn_spmm::sparse::Format;
use gnn_spmm::util::cli::Args;
use gnn_spmm::util::rng::Rng;
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let cache_path = PathBuf::from(args.get_or("cache", "warmstart_cache.json"));
    let shrink: usize = args.get_or("shrink", "32").parse()?;
    let n_shards: usize = args.get_or("shards", "4").parse()?;
    let epochs: usize = args.get_or("epochs", "2").parse()?;
    let fanout: usize = args.get_or("fanout", "12").parse()?;
    let seed: u64 = args.get_or("seed", "48879").parse()?;
    let expect_warm: Option<f64> = args.get("expect-warm").map(|v| v.parse()).transpose()?;

    let spec = if shrink > 1 {
        LARGE_DATASETS[0].scaled_same_degree(shrink, 64)
    } else {
        LARGE_DATASETS[0]
    };
    println!("dataset: {} — {} nodes (shrink {shrink})", spec.name, spec.n);
    let mut rng = Rng::new(seed);
    let ds = GraphDataset::generate(&spec, &mut rng);

    // Hardened warm-start boundary: a missing file cold-starts quietly, a
    // corrupt/truncated one warns and cold-starts — never aborts the run.
    let warm = DecisionCache::load_or_cold(&cache_path);
    match &warm {
        Some(cache) => println!(
            "loaded decision cache: {} entries from {}",
            cache.len(),
            cache_path.display()
        ),
        None => println!("no usable cache at {} — cold start", cache_path.display()),
    }
    let loaded = warm.is_some();

    let cfg = MinibatchConfig {
        epochs,
        hidden: 8,
        lr: 0.02,
        seed,
        n_shards,
        fanout,
    };
    let mut policy = StaticPolicy(Format::Csr);
    let report = train_minibatch_warm(ModelKind::Gcn, &ds, &mut policy, &cfg, warm);

    let total = report.cache_hits + report.cache_misses;
    let rate = if total == 0 { 0.0 } else { report.cache_hits as f64 / total as f64 };
    println!(
        "run done: {} decisions ({} hits / {} misses, overall rate {:.1}%), \
         warm-epoch rate {:.1}%, test acc {:.3}",
        total,
        report.cache_hits,
        report.cache_misses,
        rate * 100.0,
        report.warm_cache_hit_rate * 100.0,
        report.final_test_acc,
    );

    if let Some(gate) = expect_warm {
        anyhow::ensure!(
            loaded,
            "--expect-warm needs an existing cache at {}",
            cache_path.display()
        );
        anyhow::ensure!(
            rate >= gate,
            "warm-started overall hit rate {rate:.3} below the {gate} gate"
        );
        println!("warm-start gate OK: {rate:.3} >= {gate}");
    } else {
        report.final_cache.save(&cache_path)?;
        println!(
            "saved {} cache entries to {}",
            report.final_cache.len(),
            cache_path.display()
        );
    }
    Ok(())
}
