//! End-to-end sharded mini-batch training: RGCN via per-relation induced
//! submatrix extraction — the model where per-matrix format decisions pay
//! off most, because every layer multiplies R independent relation
//! adjacencies (R × shards decision surface).
//!
//! Pipeline: relation split (deterministic undirected-edge hash) →
//! degree-aware partitioning → seeded neighbor sampling → **one direct CSR
//! submatrix extraction per relation per batch** → per-relation format
//! decisions answered by the signature cache → shard-weighted gradient
//! accumulation → full-graph eval.
//!
//! ```bash
//! # Full ogbn-arxiv-scale (169k nodes), learned-predictor policy:
//! cargo run --release --example minibatch_rgcn
//!
//! # CI smoke scale (fast, fixed seed, static policy):
//! cargo run --release --example minibatch_rgcn -- --shrink 32 --shards 4 --epochs 2 --policy static
//! ```

use gnn_spmm::gnn::engine::StaticPolicy;
use gnn_spmm::gnn::rgcn::N_RELATIONS;
use gnn_spmm::gnn::{train_minibatch, FormatPolicy, MinibatchConfig, ModelKind};
use gnn_spmm::graph::{GraphDataset, LARGE_DATASETS};
use gnn_spmm::predictor::training::{train_predictor, TrainingCorpus};
use gnn_spmm::predictor::PredictedPolicy;
use gnn_spmm::sparse::Format;
use gnn_spmm::util::cli::Args;
use gnn_spmm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let shrink: usize = args.get_or("shrink", "1").parse()?;
    let n_shards: usize = args.get_or("shards", "16").parse()?;
    let epochs: usize = args.get_or("epochs", "5").parse()?;
    let fanout: usize = args.get_or("fanout", "8").parse()?;
    let seed: u64 = args.get_or("seed", "48879").parse()?;
    let policy_name = args.get_or("policy", "predicted").to_string();

    let spec = if shrink > 1 {
        LARGE_DATASETS[0].scaled_same_degree(shrink, 128)
    } else {
        LARGE_DATASETS[0]
    };
    println!(
        "dataset: {} — {} nodes, avg degree {:.1} (shrink {shrink}), {N_RELATIONS} relations",
        spec.name,
        spec.n,
        spec.n as f64 * spec.adj_density
    );
    let mut rng = Rng::new(seed);
    let ds = GraphDataset::generate(&spec, &mut rng);
    println!(
        "generated: adjacency nnz {}, feature nnz {}, {} classes",
        ds.adj.nnz(),
        ds.features.nnz(),
        ds.n_classes
    );

    let mut static_policy;
    let mut predicted_policy;
    let policy: &mut dyn FormatPolicy = if policy_name == "static" {
        static_policy = StaticPolicy(Format::Csr);
        &mut static_policy
    } else {
        println!("training format predictor (offline, one-off)…");
        let corpus = TrainingCorpus::build(60, 64, 256, 16, 2, 7);
        predicted_policy = PredictedPolicy::new(train_predictor(&corpus, 1.0, 7));
        &mut predicted_policy
    };

    let cfg = MinibatchConfig {
        epochs,
        hidden: 16,
        lr: 0.02,
        seed,
        n_shards,
        fanout,
    };
    println!(
        "training RGCN: {} shards × {} epochs, fanout {} — policy {}",
        n_shards,
        epochs,
        fanout,
        policy.policy_name()
    );
    let report = train_minibatch(ModelKind::Rgcn, &ds, policy, &cfg);

    println!("\nepoch  loss     time      train-acc  test-acc");
    for e in 0..report.epoch_losses.len() {
        println!(
            "{e:>5}  {:>7.4}  {:>7.1}ms  {:>8.3}  {:>8.3}",
            report.epoch_losses[e],
            report.epoch_times[e] * 1e3,
            report.train_accs[e],
            report.test_accs[e]
        );
    }
    println!("\nengine phases:");
    for (phase, secs, count) in &report.phases {
        println!("  {phase:<16} {secs:>9.4}s  ({count} calls)");
    }
    // Per-relation decision accounting: the R × shards surface the
    // predictor optimizes over.
    println!("\nper-relation decisions:");
    for r in 0..N_RELATIONS {
        let n = report
            .decisions
            .iter()
            .filter(|d| d.slot.starts_with(&format!("rgcn.A{r}.")))
            .count();
        println!("  relation {r}: {n} decisions");
    }
    println!(
        "decision cache: {} hits / {} misses ({:.1}% warm hit rate)",
        report.cache_hits,
        report.cache_misses,
        report.warm_cache_hit_rate * 100.0
    );
    println!(
        "decision overhead: {:.1} ms over {} decisions; COO-fallback extractions: {}",
        report.decision_overhead_s * 1e3,
        report.decisions.len(),
        report.coo_fallback_extractions
    );
    println!("total: {:.2}s end-to-end", report.total_time);

    // The smoke-test contract ci.sh relies on: every relation produced
    // decisions on both layers, the shard stream reuses cached decisions,
    // and per-relation extraction never falls back to the COO round-trip.
    for r in 0..N_RELATIONS {
        for layer in 1..=2 {
            let slot = format!("rgcn.A{r}.l{layer}");
            assert!(
                report.decisions.iter().any(|d| d.slot == slot),
                "no decisions for relation slot {slot}"
            );
        }
    }
    if epochs > 1 {
        assert!(
            report.warm_cache_hit_rate > 0.5,
            "warm cache hit rate {:.3} <= 0.5",
            report.warm_cache_hit_rate
        );
    }
    assert_eq!(report.coo_fallback_extractions, 0, "COO fallback on the shard stream");
    println!("OK");
    Ok(())
}
