//! Format advisor: inspect a matrix the way the paper's runtime does.
//!
//! Generates (or loads the shape of) a matrix, prints its Table-2 feature
//! vector, the per-format measured profile (time + memory), the Eq-1
//! optimum across `w` settings, and what the trained predictor would pick.
//!
//! ```bash
//! cargo run --release --example format_advisor -- --n 1024 --density 0.02 --pattern powerlaw
//! ```

use gnn_spmm::features::{extract_features, FEATURE_NAMES};
use gnn_spmm::graph::{gen_matrix, MatrixPattern};
use gnn_spmm::predictor::labeler::{label_for, profile_formats};
use gnn_spmm::predictor::training::{train_predictor, TrainingCorpus};
use gnn_spmm::util::cli::Args;
use gnn_spmm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let n = args.get_usize("n", 1024);
    let density = args.get_f64("density", 0.02);
    let pattern = match args.get_or("pattern", "uniform") {
        "powerlaw" => MatrixPattern::PowerLaw,
        "banded" => MatrixPattern::Banded,
        "block" => MatrixPattern::Block,
        "diagonal" => MatrixPattern::Diagonal,
        _ => MatrixPattern::Uniform,
    };
    let d = args.get_usize("d", 32);

    let mut rng = Rng::new(args.get_u64("seed", 1));
    let m = gen_matrix(&mut rng, n, density, pattern);
    println!(
        "matrix: {n}×{n}, pattern {pattern:?}, nnz {} ({:.3}% dense)\n",
        m.nnz(),
        m.density() * 100.0
    );

    // Table-2 features.
    println!("Table-2 features:");
    let feats = extract_features(&m);
    for (name, v) in FEATURE_NAMES.iter().zip(feats.iter()) {
        println!("  {name:<11} {v:>14.4}");
    }

    // Per-format profile.
    println!("\nper-format profile (SpMM ·{d} dense columns):");
    let profiles = profile_formats(&m, d, 5);
    for p in &profiles {
        match (p.spmm_secs, p.nbytes) {
            (Some(t), Some(b)) => println!(
                "  {:<4} {:>10.3} ms   {:>10} bytes",
                p.format.name(),
                t * 1e3,
                b
            ),
            _ => println!("  {:<4} infeasible (storage budget)", p.format.name()),
        }
    }

    // Eq-1 optimum across w.
    println!("\nEq-1 optimum by objective weight:");
    for &w in &[0.0, 0.3, 0.5, 0.7, 1.0] {
        println!("  w = {w:.1}  ->  {}", label_for(&profiles, w));
    }

    // What the trained predictor says (without profiling!).
    println!("\ntraining predictor…");
    let corpus = TrainingCorpus::build(60, 64, 256, 16, 2, 11);
    let pred = train_predictor(&corpus, 1.0, 11);
    println!(
        "predictor (cv acc {:.0}%) picks: {}",
        pred.cv_accuracy * 100.0,
        pred.predict(&m)
    );
    Ok(())
}
