//! End-to-end validation driver (DESIGN.md §5): trains a 2-layer GCN on the
//! laptop-scale Cora dataset with ALL THREE LAYERS composed —
//!
//! * **L3 (rust)** owns the training loop and every *sparse* product through
//!   the format-switching [`AdjEngine`] under the **learned predictor**;
//! * **L2 (JAX, AOT)** runs the dense layer math and the loss/gradient head
//!   through PJRT-loaded HLO artifacts (`gcn_layer_fwd`, `gcn_loss_grad`,
//!   `gcn_layer_bwd`);
//! * **L1 (Pallas)** is exercised by executing the `bsr_spmm_demo` artifact
//!   against the rust BSR kernel on the same adjacency.
//!
//! Python never runs: only the pre-compiled `artifacts/*.hlo.txt`.
//!
//! Requires the `pjrt` feature (and a vendored `xla` crate — see DESIGN.md
//! §Hardware-Adaptation); the example is skipped in default builds.
//!
//! ```bash
//! make artifacts && cargo run --release --features pjrt --example train_gcn_e2e -- --epochs 30
//! ```

use gnn_spmm::gnn::adam::Adam;
use gnn_spmm::gnn::engine::AdjEngine;
use gnn_spmm::gnn::TrainConfig;
use gnn_spmm::graph::{GraphDataset, PAPER_DATASETS};
use gnn_spmm::predictor::policy::PredictedPolicy;
use gnn_spmm::predictor::training::{train_predictor, TrainingCorpus};
use gnn_spmm::runtime::{default_artifacts_dir, PjrtEngine};
use gnn_spmm::sparse::Bsr;
use gnn_spmm::tensor::{ops, Matrix};
use gnn_spmm::util::cli::Args;
use gnn_spmm::util::rng::Rng;

// Must match python/compile/aot.py.
const N: usize = 677;
const H: usize = 16;
const C: usize = 7;
const BS: usize = 16;
const NRB: usize = 43;
const NPAD: usize = NRB * BS;
const NNZB_CAP: usize = 4096;
const DSP: usize = 32;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let epochs = args.get_usize("epochs", 30);
    let seed = args.get_u64("seed", 7);

    // ---- PJRT: load the AOT artifacts (startup cost, off the hot loop) ----
    let dir = default_artifacts_dir();
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let mut pjrt = PjrtEngine::cpu()?;
    let loaded = pjrt.load_manifest(&dir)?;
    println!("PJRT {} — loaded artifacts: {loaded:?}", pjrt.platform());

    // ---- dataset: Cora at laptop scale (matches the artifact shapes) ----
    let mut rng = Rng::new(seed);
    let spec = PAPER_DATASETS[1].laptop(); // Cora: n=677, feat 256, 7 classes
    assert_eq!(spec.n, N);
    assert_eq!(spec.n_classes, C);
    let ds = GraphDataset::generate(&spec, &mut rng);
    println!(
        "dataset {}: {} nodes, adjacency density {:.2}%, features {}×{}",
        ds.name,
        ds.adj.rows,
        ds.adj.density() * 100.0,
        ds.features.rows,
        ds.features.cols
    );

    // ---- L1 composition check: Pallas BSR artifact vs rust BSR kernel ----
    l1_check(&pjrt, &ds, &mut rng)?;

    // ---- predictor (the paper's contribution) drives the sparse side ----
    println!("\ntraining format predictor…");
    let corpus = TrainingCorpus::build(80, 64, 512, 32, 2, seed ^ 0xC0FFEE);
    let predictor = train_predictor(&corpus, 1.0, seed);
    println!("predictor cv accuracy: {:.0}%", predictor.cv_accuracy * 100.0);
    let mut policy = PredictedPolicy::new(predictor);
    let mut eng = AdjEngine::new(&mut policy);

    // Engine slots for the sparse operands.
    let s_x = eng.add_slot("e2e.X", ds.features.clone());
    let s_a1 = eng.add_slot("e2e.A.l1", ds.adj_norm.clone());
    let s_a2 = eng.add_slot("e2e.A.l2", ds.adj_norm.clone());

    // Parameters (rust-owned) + Adam.
    let cfg = TrainConfig { epochs, hidden: H, lr: 0.02, seed };
    let mut w0 = Matrix::glorot(ds.features.cols, H, &mut rng);
    let mut b0 = Matrix::zeros(1, H);
    let mut w1 = Matrix::glorot(H, C, &mut rng);
    let mut b1 = vec![0.0f32; C];
    let mut adam = Adam::new(&[w0.data.len(), H, w1.data.len(), C], cfg.lr);

    // Static loss inputs.
    let mut y_onehot = Matrix::zeros(N, C);
    let mut mask = Matrix::zeros(N, 1);
    for i in 0..N {
        *y_onehot.at_mut(i, ds.labels[i]) = 1.0;
        mask.data[i] = f32::from(ds.train_mask[i]);
    }

    println!("\nepoch  loss      train_acc  test_acc   (sparse via {}-slot engine, dense via PJRT)", eng.slots.len());
    let start = std::time::Instant::now();
    let mut final_logits = Matrix::zeros(N, C);
    for epoch in 0..epochs {
        // ---------- forward ----------
        let z0 = eng.spmm(s_x, &w0); // L3 sparse: X·W0
        let s0 = eng.spmm(s_a1, &z0); // L3 sparse: Â·Z0
        let fwd = pjrt.run("gcn_layer_fwd", &[&s0, &b0, &w1])?; // L2 dense
        let (h1, z1) = (&fwd[0], &fwd[1]);
        let logits = ops::add_row(&eng.spmm(s_a2, z1), &b1); // L3 sparse: Â·Z1
        // ---------- loss + gradient (L2) ----------
        let lg = pjrt.run("gcn_loss_grad", &[&logits, &y_onehot, &mask])?;
        let (loss, dlogits) = (lg[0].data[0], &lg[1]);
        // ---------- backward ----------
        let db1 = ops::col_sums(dlogits);
        let dz1 = eng.spmm(s_a2, dlogits); // L3 sparse: Âᵀ·dlogits
        let bwd = pjrt.run("gcn_layer_bwd", &[&s0, &b0, &w1, &dz1])?; // L2 dense
        let (dw1, ds0) = (&bwd[0], &bwd[1]);
        let db0 = ops::col_sums(ds0);
        let dz0 = eng.spmm(s_a1, ds0); // L3 sparse
        let dw0 = eng.spmm_t(s_x, &dz0); // L3 sparse: Xᵀ·dZ0, transpose-free
        // ---------- update ----------
        adam.tick();
        adam.update_matrix(0, &mut w0, &dw0);
        adam.update(1, &mut b0.data, &db0);
        adam.update_matrix(2, &mut w1, dw1);
        adam.update(3, &mut b1, &db1);

        let train_acc = ops::masked_accuracy(&logits, &ds.labels, &ds.train_mask);
        let test_acc = ops::masked_accuracy(&logits, &ds.labels, &ds.test_mask);
        println!("{epoch:>5}  {loss:<9.4} {train_acc:<10.3} {test_acc:<10.3}");
        final_logits = logits;
        let _ = h1; // H1 produced by PJRT; kept for parity with the native model
    }
    let total = start.elapsed().as_secs_f64();

    println!("\ntotal {total:.2}s for {epochs} epochs ({:.1} ms/epoch)", total / epochs as f64 * 1e3);
    println!("final test accuracy: {:.1}%", ops::masked_accuracy(&final_logits, &ds.labels, &ds.test_mask) * 100.0);
    println!("\nengine phase breakdown (sparse side):");
    for (phase, secs, count) in eng.sw.report() {
        println!("  {phase:<18} {secs:>9.4}s  ({count} calls)");
    }
    println!("format decisions:");
    for d in &eng.decisions {
        println!("  {:<10} -> {:<4} (density {:.4})", d.slot, d.format, d.density);
    }
    Ok(())
}

/// Execute the L1 Pallas BSR artifact on the dataset adjacency and check it
/// against the rust BSR kernel.
fn l1_check(pjrt: &PjrtEngine, ds: &GraphDataset, rng: &mut Rng) -> anyhow::Result<()> {
    let bsr = Bsr::from_coo(&ds.adj_norm, BS);
    anyhow::ensure!(bsr.n_blocks() <= NNZB_CAP, "adjacency exceeds demo capacity");
    let mut indptr = Matrix::zeros(1, NRB + 1);
    for (i, &p) in bsr.indptr.iter().enumerate() {
        indptr.data[i] = p as f32;
    }
    let mut indices = Matrix::zeros(1, NNZB_CAP);
    for (i, &c) in bsr.indices.iter().enumerate() {
        indices.data[i] = c as f32;
    }
    let mut blocks = Matrix::zeros(NNZB_CAP * BS, BS);
    blocks.data[..bsr.blocks.len()].copy_from_slice(&bsr.blocks);
    let mut x = Matrix::zeros(NPAD, DSP);
    for r in 0..N {
        for c in 0..DSP {
            *x.at_mut(r, c) = rng.next_f32();
        }
    }
    let out = pjrt.run("bsr_spmm_demo", &[&indptr, &indices, &blocks, &x])?;
    let x_unpadded = Matrix::from_vec(N, DSP, (0..N).flat_map(|r| x.row(r).to_vec()).collect());
    let want = bsr.spmm(&x_unpadded);
    let mut max_diff = 0f32;
    for r in 0..N {
        for c in 0..DSP {
            max_diff = max_diff.max((out[0].at(r, c) - want.at(r, c)).abs());
        }
    }
    anyhow::ensure!(max_diff < 1e-3, "L1 mismatch: {max_diff}");
    println!(
        "L1 check: Pallas BSR artifact ({} blocks, fill {:.1}%) matches rust BSR kernel (max diff {max_diff:.2e})",
        bsr.n_blocks(),
        bsr.block_fill() * 100.0
    );
    Ok(())
}
