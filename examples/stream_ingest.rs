//! Crash-safe streaming ingestion demo (DESIGN.md §Streaming-Durability).
//!
//! Single-process crash-and-recover exercise of `graph::stream`:
//!
//! 1. generate a deterministic edge-op stream (inserts, deletes,
//!    reweights with absolute semantics) and mirror it into an in-memory
//!    reference map,
//! 2. ingest through a `StreamStore` with a **scripted `CrashPoint`**
//!    armed (`--crash-ordinal`): when the injected crash fires at a
//!    durability seam the store is treated as dead — dropped and
//!    re-opened, which replays checkpoint + WAL tail,
//! 3. assert the acknowledged watermark never moves backwards across the
//!    crash and that every merged row read is **bit-identical** to the
//!    reference after the full stream lands,
//! 4. run compactions every `--compact-each` acknowledged ops (crashes at
//!    the checkpoint-rename / publish seams recover the same way),
//! 5. re-open once more cleanly and re-verify (the replay path), then
//!    append one JSON-lines record to `BENCH_stream.json`.
//!
//! ci.sh smoke-runs this with a scripted mid-stream crash and asserts the
//! emitted record carries the ingest/recovery fields.
//!
//! ```bash
//! cargo run --release --example stream_ingest -- --ops 400 --crash-ordinal 150
//! cargo run --release --example stream_ingest -- --crash-ordinal 0   # fault-free
//! ```

use gnn_spmm::graph::stream::{EdgeOp, StreamConfig, StreamError, StreamStore};
use gnn_spmm::testing::{FaultKind, FaultPlan};
use gnn_spmm::util::cli::Args;
use gnn_spmm::util::json::Json;
use gnn_spmm::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// Deterministic op stream: ~20% deletes, ~20% reweights of edges the
/// reference currently holds, the rest inserts (weights in (0.1, 4.0) —
/// strictly positive and finite, as `EdgeOp::check` demands).
fn scripted_ops(n: usize, count: usize, seed: u64) -> Vec<EdgeOp> {
    let mut rng = Rng::new(seed);
    let mut present: Vec<(u32, u32)> = Vec::new();
    let mut ops = Vec::with_capacity(count);
    for _ in 0..count {
        let roll = rng.next_f64();
        let op = if roll < 0.2 && !present.is_empty() {
            let i = rng.gen_range(present.len());
            let (src, dst) = present.swap_remove(i);
            EdgeOp::Delete { src, dst }
        } else if roll < 0.4 && !present.is_empty() {
            let i = rng.gen_range(present.len());
            let (src, dst) = present[i];
            EdgeOp::Reweight { src, dst, w: rng.uniform(0.1, 4.0) as f32 }
        } else {
            let src = rng.gen_range(n) as u32;
            let dst = rng.gen_range(n) as u32;
            if !present.contains(&(src, dst)) {
                present.push((src, dst));
            }
            EdgeOp::Insert { src, dst, w: rng.uniform(0.1, 4.0) as f32 }
        };
        ops.push(op);
    }
    ops
}

fn apply_reference(map: &mut BTreeMap<(u32, u32), f32>, op: &EdgeOp) {
    match *op {
        EdgeOp::Insert { src, dst, w } | EdgeOp::Reweight { src, dst, w } => {
            map.insert((src, dst), w);
        }
        EdgeOp::Delete { src, dst } => {
            map.remove(&(src, dst));
        }
    }
}

/// Merged read of every row, flattened back to a (src, dst) → w map.
fn store_edges(store: &StreamStore) -> BTreeMap<(u32, u32), f32> {
    let mut out = BTreeMap::new();
    for r in 0..store.n_nodes() as u32 {
        for (c, w) in store.read_row(r) {
            out.insert((r, c), w);
        }
    }
    out
}

fn assert_matches_reference(store: &StreamStore, reference: &BTreeMap<(u32, u32), f32>, when: &str) {
    let got = store_edges(store);
    assert_eq!(
        got.len(),
        reference.len(),
        "{when}: store holds {} edges, reference {}",
        got.len(),
        reference.len()
    );
    for ((&(s, d), &w), (&(rs, rd), &rw)) in got.iter().zip(reference.iter()) {
        assert_eq!((s, d), (rs, rd), "{when}: edge key mismatch");
        assert_eq!(w.to_bits(), rw.to_bits(), "{when}: weight for ({s},{d}) not bit-identical");
    }
}

fn main() {
    let args = Args::parse();
    let n_nodes = args.get_usize("nodes", 96);
    let n_ops = args.get_usize("ops", 400);
    let sync_every = args.get_usize("sync-every", 8);
    let compact_each = args.get_usize("compact-each", 64).max(1);
    let crash_ordinal = args.get_u64("crash-ordinal", 150);
    let seed = args.get_u64("seed", 48879);
    let out_path = std::env::var("GNN_SPMM_BENCH_STREAM_OUT")
        .unwrap_or_else(|_| args.get_or("out", "BENCH_stream.json").to_string());
    let dir = args
        .get("dir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::env::temp_dir().join(format!("stream_ingest_{}", std::process::id())));
    let _ = std::fs::remove_dir_all(&dir);

    let plan = if crash_ordinal > 0 {
        Arc::new(FaultPlan::inert().script(FaultKind::CrashPoint, &[crash_ordinal]))
    } else {
        Arc::new(FaultPlan::inert())
    };
    let mut cfg = StreamConfig::new(&dir, n_nodes);
    cfg.sync_every = sync_every;
    cfg.faults = Arc::clone(&plan);
    // The scripted lane counts every CrashPoint seam the store reaches
    // (wal-append on ingest, checkpoint-rename and publish in compaction);
    // the shared `Arc<FaultPlan>` keeps that counter advancing across
    // re-opens, so the retry after recovery does not re-fire.

    let ops = scripted_ops(n_nodes, n_ops, seed);
    let mut reference = BTreeMap::new();

    let mut store = StreamStore::open(cfg.clone()).expect("initial open");
    let mut crashes = 0u64;
    let mut recovery_ms_total = 0.0f64;
    let mut last_recovery_ms = 0.0f64;
    let mut ingest_secs = 0.0f64;

    // Crash handling: the injected CrashPoint means "this process died
    // here" — the handle is dead, so drop it and re-open (checkpoint load
    // + WAL-tail replay). The acknowledged watermark must never regress.
    fn recover(store: StreamStore, cfg: &StreamConfig, what: &str) -> (StreamStore, f64) {
        let acked_before = store.acked();
        drop(store);
        let t0 = Instant::now();
        let store = StreamStore::open(cfg.clone()).expect("recovery open");
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        assert!(
            store.acked() >= acked_before,
            "{what}: acked regressed across recovery ({} -> {})",
            acked_before,
            store.acked()
        );
        (store, ms)
    }

    let mut done = 0usize;
    while done < ops.len() {
        let t0 = Instant::now();
        let res = store.ingest(ops[done]);
        ingest_secs += t0.elapsed().as_secs_f64();
        match res {
            Ok(_) => {
                apply_reference(&mut reference, &ops[done]);
                done += 1;
                if done % compact_each == 0 {
                    match store.compact_once() {
                        Ok(_) => {}
                        Err(StreamError::Crashed { seam }) => {
                            println!("injected crash at compaction seam {seam}; recovering");
                            crashes += 1;
                            let (s, ms) = recover(store, &cfg, "compaction crash");
                            store = s;
                            last_recovery_ms = ms;
                            recovery_ms_total += ms;
                        }
                        Err(e) => panic!("compaction failed: {e}"),
                    }
                }
            }
            Err(StreamError::Crashed { seam }) => {
                println!("injected crash at ingest seam {seam}; recovering");
                crashes += 1;
                let (s, ms) = recover(store, &cfg, "ingest crash");
                store = s;
                last_recovery_ms = ms;
                recovery_ms_total += ms;
                // The crashed op was never acknowledged — retry it as-is
                // (absolute semantics make the retry safe).
            }
            Err(e) => panic!("ingest failed: {e}"),
        }
    }
    store.flush().expect("final flush");
    let acked = store.acked();
    // Counters are per-process: capture before the re-open resets them.
    let compactions = store.stats().compactions;
    assert_matches_reference(&store, &reference, "after full stream");

    // Clean re-open: the replay path must rebuild the identical state.
    drop(store);
    let t0 = Instant::now();
    let store = StreamStore::open(cfg.clone()).expect("clean re-open");
    let replay_ms = t0.elapsed().as_secs_f64() * 1e3;
    let st = store.stats();
    let replayed = st.applied - st.published_seq;
    assert_eq!(st.acked, acked, "clean re-open lost acknowledged writes");
    assert_matches_reference(&store, &reference, "after clean re-open replay");

    if crash_ordinal > 0 {
        assert!(crashes > 0, "crash ordinal {crash_ordinal} never fired — raise --ops");
    }
    let ingest_ops_per_sec = done as f64 / ingest_secs.max(1e-9);
    println!(
        "stream_ingest: {done} ops acked={acked} crashes={crashes} \
         compactions={compactions} replay of {replayed} ops in {replay_ms:.2}ms verified bit-identical"
    );

    let record = Json::obj(vec![
        ("name", Json::Str("stream_ingest".into())),
        ("nodes", Json::Num(n_nodes as f64)),
        ("ops", Json::Num(done as f64)),
        ("sync_every", Json::Num(sync_every as f64)),
        ("acked", Json::Num(acked as f64)),
        ("crashes", Json::Num(crashes as f64)),
        ("crash_ordinal", Json::Num(crash_ordinal as f64)),
        ("recovery_ms", Json::Num(last_recovery_ms)),
        ("recovery_ms_total", Json::Num(recovery_ms_total)),
        ("replayed", Json::Num(replayed as f64)),
        ("replay_ms", Json::Num(replay_ms)),
        ("ingest_ops_per_sec", Json::Num(ingest_ops_per_sec)),
        ("compactions", Json::Num(compactions as f64)),
        ("verified", Json::Bool(true)),
    ]);
    let line = format!("{}\n", record.to_string());
    match std::fs::write(&out_path, line) {
        Ok(()) => println!("wrote {out_path} (1 record)"),
        Err(e) => println!("could not write {out_path}: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
