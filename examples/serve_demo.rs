//! Concurrent inference serving demo (DESIGN.md §Serving).
//!
//! End-to-end flow of the serving layer:
//!
//! 1. full-batch train a template model (the short offline phase),
//! 2. warm a decision cache on representative request shapes, save it,
//!    then reload it via `DecisionCache::load_or_cold` — the same
//!    persisted-cache handoff `warmstart_cache` demonstrates for training,
//!    hardened to cold-start on a torn file,
//! 3. serve a power-law request stream at each requested worker count,
//! 4. epoch-swap a rebuilt graph snapshot mid-stream (in-flight requests
//!    keep their old snapshot; later ones observe the new version),
//! 5. append one JSON-lines record per worker count to `BENCH_serve.json`.
//!
//! Setting `GNN_FAULT_SEED=<u64>` arms the deterministic fault harness
//! (`testing::fault`): the cache file is torn in half before reload (the
//! cold-start path must absorb it), workers draw seeded panics/delays, and
//! the run asserts the liveness contract instead of all-success — every
//! admitted request still gets exactly one (possibly typed-error) response,
//! and the report carries the shed/expired/panics/restarts accounting.
//!
//! ci.sh smoke-runs this under both `GNN_SPMM_THREADS=1` and default
//! threading and asserts the emitted records carry every latency field;
//! a third armed run asserts the fault-accounting fields.
//!
//! ```bash
//! cargo run --release --example serve_demo -- --shrink 32 --requests 120
//! GNN_FAULT_SEED=48879 cargo run --release --example serve_demo
//! ```

use gnn_spmm::gnn::engine::StaticPolicy;
use gnn_spmm::gnn::{AdjEngine, ModelKind};
use gnn_spmm::graph::{GraphDataset, LARGE_DATASETS};
use gnn_spmm::predictor::DecisionCache;
use gnn_spmm::serve::{train_template, EngineSnapshot, InferenceServer, ServeConfig, ServedModel};
use gnn_spmm::sparse::Format;
use gnn_spmm::testing::{FaultKind, FaultPlan};
use gnn_spmm::util::cli::Args;
use gnn_spmm::util::json::Json;
use gnn_spmm::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;

const HIDDEN: usize = 16;

/// Power-law request stream: heavy-tailed batch sizes, node popularity
/// skewed toward low ids (u² inverse-CDF) — the serving-traffic shape the
/// decision cache amortizes over.
fn power_law_requests(n_nodes: usize, count: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let u = rng.next_f64().max(1e-9);
            let size = (6.0 / u.powf(0.6)).min(96.0) as usize;
            (0..size.max(6))
                .map(|_| {
                    let v = rng.next_f64();
                    ((n_nodes - 1) as f64 * v * v) as u32
                })
                .collect()
        })
        .collect()
}

/// Run representative request shapes through an owned-cache engine so the
/// server can share the resulting decisions read-only across its workers.
fn warm_cache(ds: &GraphDataset, template: &ServedModel, requests: &[Vec<u32>]) -> DecisionCache {
    let mut policy = StaticPolicy(Format::Csr);
    let mut eng = AdjEngine::new(&mut policy);
    eng.enable_decision_cache();
    let mut rng = Rng::new(0xCA0E);
    let mut replica = template.replicate(ds, HIDDEN, 0.02, &mut rng, &mut eng);
    let snap = EngineSnapshot::from_dataset(ds, 0);
    let all_cols: Vec<u32> = (0..ds.features.cols as u32).collect();
    for req in requests.iter().take(16) {
        let mut nodes = req.clone();
        nodes.sort_unstable();
        nodes.dedup();
        let x = snap.feats.extract_rows_cols(&nodes, &all_cols);
        let a = snap.adjn.extract_rows_cols(&nodes, &nodes);
        replica.set_graph(&mut eng, x, a);
        let _ = replica.forward(&mut eng);
    }
    eng.take_decision_cache().unwrap()
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let shrink: usize = args.get_or("shrink", "32").parse()?;
    let n_requests: usize = args.get_or("requests", "120").parse()?;
    let seed: u64 = args.get_or("seed", "48879").parse()?;
    let out_path = PathBuf::from(args.get_or("out", "BENCH_serve.json"));
    let cache_path = PathBuf::from(args.get_or("cache", "serve_cache.json"));
    let kind = match args.get_or("model", "gcn") {
        "gcn" => ModelKind::Gcn,
        "film" => ModelKind::Film,
        "egc" => ModelKind::Egc,
        other => anyhow::bail!("--model {other}: serving supports gcn | film | egc"),
    };
    let worker_counts: Vec<usize> = args
        .get_or("workers", "1,4")
        .split(',')
        .map(|w| w.trim().parse())
        .collect::<Result<_, _>>()?;

    let spec = if shrink > 1 {
        LARGE_DATASETS[0].scaled_same_degree(shrink, 64)
    } else {
        LARGE_DATASETS[0]
    };
    println!("dataset: {} — {} nodes (shrink {shrink})", spec.name, spec.n);
    let ds = Arc::new(GraphDataset::generate(&spec, &mut Rng::new(seed)));
    let requests = power_law_requests(spec.n, n_requests, seed ^ 0x90B0);

    println!("training {} template (full-batch, offline)…", kind.name());
    let template = Arc::new(train_template(kind, &ds, HIDDEN, 0.02, 5, seed));

    let faults = Arc::new(FaultPlan::from_env().unwrap_or_default());
    if faults.armed() {
        println!("fault harness ARMED (GNN_FAULT_SEED)");
    }

    // Warm → save → load: the server's cache arrives the way a deployment
    // would ship it — persisted by a warmup process, reloaded here. Armed
    // runs tear the file in half first: the load boundary must degrade to
    // a cold start, never refuse to boot.
    let warmed = warm_cache(&ds, &template, &requests);
    warmed.save(&cache_path)?;
    if faults.maybe_truncate_file(&cache_path)? {
        println!("fault harness tore {} in half", cache_path.display());
    }
    let warm = DecisionCache::load_or_cold(&cache_path).unwrap_or_else(|| {
        println!("torn cache absorbed: serving cold-starts with the in-process warm copy");
        warmed.clone()
    });
    println!(
        "warm decision cache: {} entries via {}",
        warm.len(),
        cache_path.display()
    );

    // Mid-stream snapshot: same spec, regenerated graph — a "graph update"
    // arriving while requests are in flight.
    let updated = Arc::new(EngineSnapshot::from_dataset(
        &GraphDataset::generate(&spec, &mut Rng::new(seed ^ 0xDEAD)),
        1,
    ));

    let mut lines = Vec::new();
    for &workers in &worker_counts {
        // Fresh plan per server: each worker-count run replays the same
        // deterministic fault schedule from ordinal 0.
        let plan = Arc::new(FaultPlan::from_env().unwrap_or_default());
        let armed = plan.armed();
        let cfg = ServeConfig {
            workers,
            queue_capacity: 32,
            hidden: HIDDEN,
            faults: Arc::clone(&plan),
            ..Default::default()
        };
        let srv = InferenceServer::start(
            cfg,
            Arc::clone(&ds),
            Arc::clone(&template),
            EngineSnapshot::from_dataset(&ds, 0),
            Some(warm.clone()),
        );
        let mut admitted = 0usize;
        let mut submit_all = |reqs: &[Vec<u32>]| {
            for req in reqs {
                match srv.submit(req.clone()) {
                    Ok(_) => admitted += 1,
                    // An armed crash loop may exhaust the restart budget
                    // mid-stream; typed rejection is the contract then.
                    Err(e) if armed => {
                        println!("admission rejected under faults: {e}");
                        break;
                    }
                    Err(e) => panic!("unexpected admission failure: {e}"),
                }
            }
        };
        let half = requests.len() / 2;
        submit_all(&requests[..half]);
        // Epoch-swap while the first half is still draining: readers are
        // never blocked, the displaced snapshot frees with its last reader.
        srv.publish_arc(Arc::clone(&updated))?;
        submit_all(&requests[half..]);
        let mut probes = 0u64;
        if armed {
            // Deadline probes: already expired at submission, so workers
            // must drop them at dequeue (counted in `expired`).
            for _ in 0..3 {
                match srv.submit_with_deadline(vec![0, 1, 2, 3], Some(std::time::Instant::now())) {
                    Ok(_) => {
                        admitted += 1;
                        probes += 1;
                    }
                    Err(_) => break,
                }
            }
        }
        let responses = srv.drain();
        anyhow::ensure!(
            responses.len() == admitted,
            "liveness violated: {admitted} admitted, {} responses",
            responses.len()
        );
        let v1 = responses
            .iter()
            .filter_map(|r| r.ok())
            .filter(|inf| inf.snapshot_version == 1)
            .count();
        anyhow::ensure!(
            responses
                .iter()
                .filter_map(|r| r.ok())
                .all(|inf| inf.logits.data.iter().all(|x| x.is_finite())),
            "non-finite logits"
        );
        if armed {
            for r in responses.iter().filter(|r| !r.is_ok()) {
                println!("request {} failed typed: {}", r.id, r.err().unwrap());
            }
        } else {
            anyhow::ensure!(responses.iter().all(|r| r.is_ok()), "unarmed run must not fail");
            anyhow::ensure!(v1 > 0, "no request observed the swapped snapshot");
        }

        let rep = srv.report(spec.name);
        println!(
            "{} w{workers}: {} requests | p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms \
             | {:.0} req/s | cache hit rate {:.1}% | {}/{} on snapshot v1 \
             | shed {} expired {} panics {} restarts {}{}",
            kind.name(),
            rep.requests,
            rep.p50_ns as f64 / 1e6,
            rep.p95_ns as f64 / 1e6,
            rep.p99_ns as f64 / 1e6,
            rep.ops_per_sec,
            rep.cache.hit_rate() * 100.0,
            v1,
            responses.len(),
            rep.shed,
            rep.expired,
            rep.panics,
            rep.restarts,
            if rep.degraded { " | DEGRADED" } else { "" },
        );
        anyhow::ensure!(rep.expired >= probes, "every admitted deadline probe must expire");

        let line = rep.to_json_line();
        let parsed = Json::parse(&line)?;
        for key in [
            "p50_ns", "p95_ns", "p99_ns", "mean_ns", "max_ns", "ops_per_sec",
            "shed", "expired", "panics", "restarts", "degraded",
        ] {
            anyhow::ensure!(
                parsed.get(key).is_some(),
                "BENCH record missing {key}: {line}"
            );
        }
        lines.push(line);
        srv.shutdown();
    }

    std::fs::write(&out_path, lines.join("\n") + "\n")?;
    println!("wrote {} ({} records)", out_path.display(), lines.len());
    Ok(())
}
