//! Quickstart: the 30-second tour of the public API.
//!
//! 1. Build a sparse matrix.
//! 2. Train the format predictor (or load a saved one).
//! 3. `spmm_predict` — re-store the matrix in the predicted format.
//! 4. Run SpMM with the automatically chosen kernel.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gnn_spmm::graph::{gen_matrix, MatrixPattern};
use gnn_spmm::predictor::spmm_predict::spmm_predict;
use gnn_spmm::predictor::training::{train_predictor, TrainingCorpus};
use gnn_spmm::sparse::SparseMatrix;
use gnn_spmm::tensor::Matrix;
use gnn_spmm::util::rng::Rng;
use gnn_spmm::util::timer::time_it;

fn main() -> anyhow::Result<()> {
    // 1. A sparse matrix (here: synthetic power-law, like a citation graph).
    let mut rng = Rng::new(42);
    let coo = gen_matrix(&mut rng, 2048, 0.01, MatrixPattern::PowerLaw);
    let matrix = SparseMatrix::Coo(coo);
    println!(
        "input: {}×{} sparse matrix, {} non-zeros ({:.2}% dense), stored as {}",
        matrix.rows(),
        matrix.cols(),
        matrix.nnz(),
        matrix.density() * 100.0,
        matrix.format()
    );

    // 2. Train the predictor offline (one-off; normally `make artifacts` /
    //    `gnn-spmm train-predictor` and load the JSON).
    println!("\ntraining format predictor on a synthetic corpus…");
    let corpus = TrainingCorpus::build(60, 64, 256, 16, 2, 7);
    let predictor = train_predictor(&corpus, /* w = speed */ 1.0, 7);
    println!("cross-validated accuracy: {:.0}%", predictor.cv_accuracy * 100.0);

    // 3. SpMMPredict (paper §4.6): one call re-stores the matrix.
    let stored = spmm_predict(&predictor, &matrix);
    println!("predicted storage format: {}", stored.format());

    // 4. SpMM dispatches the kernel matching the storage format.
    let x = Matrix::rand(matrix.cols(), 64, &mut rng);
    let (y_baseline, t_coo) = time_it(|| matrix.spmm(&x));
    let (y_predicted, t_pred) = time_it(|| stored.spmm(&x));
    assert!(y_baseline.max_abs_diff(&y_predicted) < 1e-4);
    println!(
        "\nSpMM ({}×{} · {}×64):\n  COO (PyG default) : {:.3} ms\n  {} (predicted)  : {:.3} ms  ({:.2}x)",
        matrix.rows(),
        matrix.cols(),
        matrix.cols(),
        t_coo * 1e3,
        stored.format(),
        t_pred * 1e3,
        t_coo / t_pred
    );
    Ok(())
}
