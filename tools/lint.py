#!/usr/bin/env python3
"""In-tree invariant linter — Python mirror runner (DESIGN.md §Static-Analysis).

Interprets the declarative rule spec in lint/rules.json against the repo
tree. The same spec is interpreted by the Rust workspace bin
(`cargo run -p lint`); this mirror is stdlib-only so the gate runs even in
containers without a cargo/rustc toolchain. The two interpreters share the
fixture corpus under lint/fixtures/ (`--self-test`) so they cannot diverge
silently.

Shared semantics (both runners):
  * Lines of .rs files are split into a code part and a comment part by a
    comment/string-aware lexer (line + nested block comments, string/char
    literals, raw strings, lifetimes). Rule patterns run against the code
    part only; annotations (`SAFETY:`, `ord:`) and lint directives are read
    from the comment part. Non-.rs files are matched raw, with no comment
    part and no directives.
  * Directives (in .rs comments):
      // lint: begin(<marker>) ... // lint: end(<marker>)   span markers
      // lint: allow(<rule>[, <rule>]) -- <reason>          suppression
    A trailing allow covers its own line; an allow on a comment-only line
    covers the next line. Suppressions are counted; an allow that matches
    nothing, names an unknown rule, or lacks a `-- reason` is itself a
    violation, so stale or silent suppressions cannot accumulate.
  * Regex patterns in the spec stay inside the subset the dependency-free
    Rust engine implements: literals, escapes, \\b \\s \\S \\w \\W \\d \\D,
    [...] classes, (?:...) and (...) groups, alternation, * + ?, ^ $.

Exit status: 0 clean (or report-only mode), 2 on violations with --deny or
on a --self-test mismatch.
"""

import argparse
import json
import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

# Built-in rule ids for directive hygiene (reported like spec rules).
RULE_MARKER_SYNTAX = "lint-marker-syntax"
RULE_ALLOW_SYNTAX = "lint-allow-syntax"
RULE_UNKNOWN_RULE = "lint-unknown-rule"
RULE_UNUSED_ALLOW = "lint-unused-allow"

ALLOW_RE = re.compile(r"lint:\s*allow\(([A-Za-z0-9_,\s-]+)\)\s*--\s*(\S.*)")
ALLOW_ANY_RE = re.compile(r"lint:\s*allow")
BEGIN_RE = re.compile(r"lint:\s*begin\(([A-Za-z0-9_-]+)\)")
END_RE = re.compile(r"lint:\s*end\(([A-Za-z0-9_-]+)\)")

SKIP_DIRS = {".git", "target", "__pycache__", ".claude"}


# ---------------------------------------------------------------------------
# Lexer
# ---------------------------------------------------------------------------

CHAR_LIT_RE = re.compile(r"'(\\[^\n']*|[^\\'\n])'")
RAW_STR_RE = re.compile(r'b?r(#*)"')


def lex_rust(text):
    """Split Rust source into per-line (code, full, comment) strings.

    All outputs preserve column positions: `code` is code with string/char
    literal *contents* blanked (what pattern rules match against, so a
    forbidden token inside an error-message string cannot fire), `full` is
    code with literal contents intact (what exhaustive rules search, so
    serialized field names like "tile" stay visible), `comment` is comment
    text only (where annotations and lint directives live).
    """
    lines_code, lines_full, lines_comment = [], [], []
    code, full, com = [], [], []
    state = "code"  # code | line | block | str | rawstr
    depth = 0
    raw_hashes = 0
    i, n = 0, len(text)

    def flush():
        lines_code.append("".join(code))
        lines_full.append("".join(full))
        lines_comment.append("".join(com))
        code.clear()
        full.clear()
        com.clear()

    def emit_code(s):
        code.append(s)
        full.append(s)
        com.append(" " * len(s))

    def emit_com(s):
        com.append(s)
        code.append(" " * len(s))
        full.append(" " * len(s))

    def emit_str(s):
        # String-literal contents: visible to `full`, blank in `code`.
        full.append(s)
        code.append(" " * len(s))
        com.append(" " * len(s))

    while i < n:
        c = text[i]
        if c == "\n":
            flush()
            if state == "line":
                state = "code"
            i += 1
            continue
        if state == "code":
            nxt = text[i + 1] if i + 1 < n else ""
            if c == "/" and nxt == "/":
                emit_com("//")
                state = "line"
                i += 2
                continue
            if c == "/" and nxt == "*":
                emit_com("/*")
                state = "block"
                depth = 1
                i += 2
                continue
            if c == '"':
                emit_code('"')
                state = "str"
                i += 1
                continue
            if c in "br":
                m = RAW_STR_RE.match(text, i)
                if m:
                    emit_code(text[i : m.end()])
                    raw_hashes = len(m.group(1))
                    state = "rawstr"
                    i = m.end()
                    continue
                emit_code(c)
                i += 1
                continue
            if c == "'":
                m = CHAR_LIT_RE.match(text, i)
                if m:
                    emit_code("'")
                    emit_str(text[i + 1 : m.end() - 1])
                    emit_code("'")
                    i = m.end()
                else:  # lifetime
                    emit_code("'")
                    i += 1
                continue
            emit_code(c)
            i += 1
        elif state == "line":
            emit_com(c)
            i += 1
        elif state == "block":
            nxt = text[i + 1] if i + 1 < n else ""
            if c == "*" and nxt == "/":
                emit_com("*/")
                depth -= 1
                if depth == 0:
                    state = "code"
                i += 2
            elif c == "/" and nxt == "*":
                emit_com("/*")
                depth += 1
                i += 2
            else:
                emit_com(c)
                i += 1
        elif state == "str":
            if c == "\\":
                nxt = text[i + 1] if i + 1 < n else ""
                if nxt == "\n" or nxt == "":
                    emit_str("\\")
                    i += 1
                else:
                    emit_str("\\" + nxt)
                    i += 2
            elif c == '"':
                emit_code('"')
                state = "code"
                i += 1
            else:
                emit_str(c)
                i += 1
        elif state == "rawstr":
            closer = '"' + "#" * raw_hashes
            if text.startswith(closer, i):
                emit_code(closer)
                state = "code"
                i += len(closer)
            else:
                emit_str(c)
                i += 1
    flush()
    if text.endswith("\n"):
        lines_code.pop()
        lines_full.pop()
        lines_comment.pop()
    return lines_code, lines_full, lines_comment


def lex_plain(text):
    lines = text.split("\n")
    if text.endswith("\n"):
        lines.pop()
    return lines, list(lines), ["" for _ in lines]


# ---------------------------------------------------------------------------
# Globs
# ---------------------------------------------------------------------------


def glob_to_regex(glob):
    """Translate a path glob to a regex over '/'-separated relative paths.

    `**/` crosses directories (including zero), `*` and `?` stay within one
    path segment. Identical translation in the Rust runner.
    """
    out, i = [], 0
    while i < len(glob):
        c = glob[i]
        if c == "*":
            if glob.startswith("**/", i):
                out.append("(?:.*/)?")
                i += 3
                continue
            if glob.startswith("**", i):
                out.append(".*")
                i += 2
                continue
            out.append("[^/]*")
            i += 1
        elif c == "?":
            out.append("[^/]")
            i += 1
        elif c in ".^$+(){}[]|\\":
            out.append("\\" + c)
            i += 1
        else:
            out.append(c)
            i += 1
    return "".join(out)


# ---------------------------------------------------------------------------
# Per-file analysis state
# ---------------------------------------------------------------------------


class Allow:
    def __init__(self, src_line, applies_line, rules, reason):
        self.src_line = src_line
        self.applies_line = applies_line
        self.rules = rules
        self.reason = reason
        self.used = False


class SourceFile:
    def __init__(self, rel, code, full, comment, is_rust):
        self.rel = rel
        self.code = code
        self.full = full
        self.comment = comment
        self.is_rust = is_rust
        self.spans = {}  # marker name -> set of 1-based line numbers
        self.allows = []
        self.directive_violations = []
        if is_rust:
            self._scan_directives()

    def _scan_directives(self):
        open_spans = {}  # name -> start line
        for ln, com in enumerate(self.comment, start=1):
            if not com.strip():
                continue
            m = BEGIN_RE.search(com)
            if m:
                name = m.group(1)
                if name in open_spans:
                    self.directive_violations.append(
                        (ln, RULE_MARKER_SYNTAX, f"begin({name}) while span already open")
                    )
                else:
                    open_spans[name] = ln
            m = END_RE.search(com)
            if m:
                name = m.group(1)
                if name not in open_spans:
                    self.directive_violations.append(
                        (ln, RULE_MARKER_SYNTAX, f"end({name}) without begin")
                    )
                else:
                    start = open_spans.pop(name)
                    self.spans.setdefault(name, set()).update(range(start, ln + 1))
            if ALLOW_ANY_RE.search(com):
                m = ALLOW_RE.search(com)
                if not m:
                    self.directive_violations.append(
                        (
                            ln,
                            RULE_ALLOW_SYNTAX,
                            "malformed allow: expected `lint: allow(<rule>) -- <reason>`",
                        )
                    )
                else:
                    rules = [r.strip() for r in m.group(1).split(",") if r.strip()]
                    comment_only = not self.code[ln - 1].strip()
                    applies = ln + 1 if comment_only else ln
                    self.allows.append(Allow(ln, applies, rules, m.group(2).strip()))
        for name, start in sorted(open_spans.items()):
            self.directive_violations.append(
                (start, RULE_MARKER_SYNTAX, f"begin({name}) never closed")
            )

    def in_span(self, marker, line):
        return line in self.spans.get(marker, ())

    def try_allow(self, rule_id, line):
        for a in self.allows:
            if a.applies_line == line and rule_id in a.rules:
                a.used = True
                return a
        return None


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class Violation:
    def __init__(self, rel, line, rule, msg):
        self.rel = rel
        self.line = line
        self.rule = rule
        self.msg = msg

    def key(self):
        return (self.rel, self.line, self.rule)

    def __str__(self):
        return f"{self.rel}:{self.line}: [{self.rule}] {self.msg}"


class Engine:
    def __init__(self, root, spec):
        self.root = Path(root)
        self.spec = spec
        self.rules = spec["rules"]
        self.known_ids = {r["id"] for r in self.rules} | {
            RULE_MARKER_SYNTAX,
            RULE_ALLOW_SYNTAX,
            RULE_UNKNOWN_RULE,
            RULE_UNUSED_ALLOW,
        }
        self.files = {}  # rel path -> SourceFile
        self.violations = []
        self.suppressed = {}  # rule id -> list of (rel, line, reason)
        self.allowlisted = {}  # rule id -> site count

    # -- file loading -------------------------------------------------------

    def _walk(self):
        all_files = []
        stack = [self.root]
        while stack:
            d = stack.pop()
            for p in sorted(d.iterdir()):
                if p.is_dir():
                    if p.name not in SKIP_DIRS:
                        stack.append(p)
                elif p.is_file():
                    all_files.append(p.relative_to(self.root).as_posix())
        return sorted(all_files)

    def _load(self, rel):
        if rel not in self.files:
            text = (self.root / rel).read_text(encoding="utf-8", errors="replace")
            is_rust = rel.endswith(".rs")
            code, full, comment = lex_rust(text) if is_rust else lex_plain(text)
            self.files[rel] = SourceFile(rel, code, full, comment, is_rust)
        return self.files[rel]

    def _select(self, globs, all_files):
        regexes = [re.compile(glob_to_regex(g) + r"\Z") for g in globs]
        return [f for f in all_files if any(rx.match(f) for rx in regexes)]

    # -- main entry ---------------------------------------------------------

    def run(self):
        all_files = self._walk()
        for rule in self.rules:
            kind = rule["kind"]
            if kind == "forbid-pattern":
                self._run_forbid(rule, all_files)
            elif kind == "require-annotation":
                self._run_annotation(rule, all_files)
            elif kind == "exhaustive":
                self._run_exhaustive(rule)
            else:
                raise SystemExit(f"lint: unknown rule kind {kind!r} in spec")
        self._finish_directives()
        self.violations.sort(key=Violation.key)
        return self

    def _emit(self, sf, line, rule_id, msg):
        a = sf.try_allow(rule_id, line)
        if a:
            self.suppressed.setdefault(rule_id, []).append((sf.rel, line, a.reason))
        else:
            self.violations.append(Violation(sf.rel, line, rule_id, msg))

    def _run_forbid(self, rule, all_files):
        pat = re.compile(rule["pattern"])
        exc = re.compile(rule["except_pattern"]) if rule.get("except_pattern") else None
        marker = rule.get("within_marker")
        for rel in self._select(rule["paths"], all_files):
            sf = self._load(rel)
            for ln, codeline in enumerate(sf.code, start=1):
                if marker and not sf.in_span(marker, ln):
                    continue
                exc_spans = (
                    [m.span() for m in exc.finditer(codeline)] if exc else []
                )
                for m in pat.finditer(codeline):
                    s, e = m.span()
                    if any(s2 <= s and e <= e2 for s2, e2 in exc_spans):
                        continue
                    self._emit(
                        sf, ln, rule["id"], f"forbidden pattern `{m.group(0).strip()}`"
                    )
                    break  # one violation per line

    def _run_annotation(self, rule, all_files):
        pat = re.compile(rule["pattern"])
        ann = re.compile(rule["annotation"])
        allow_paths = set(rule.get("allow_paths", []))
        for rel in self._select(rule["paths"], all_files):
            sf = self._load(rel)
            if rel in allow_paths:
                sites = sum(len(pat.findall(c)) for c in sf.code)
                if sites:
                    self.allowlisted[rule["id"]] = (
                        self.allowlisted.get(rule["id"], 0) + sites
                    )
                continue
            for ln, codeline in enumerate(sf.code, start=1):
                m = pat.search(codeline)
                if not m:
                    continue
                if ann.search(sf.comment[ln - 1]):
                    continue
                j = ln - 1  # walk the contiguous comment block above
                justified = False
                while j >= 1 and not sf.code[j - 1].strip() and sf.comment[j - 1].strip():
                    if ann.search(sf.comment[j - 1]):
                        justified = True
                        break
                    j -= 1
                if not justified:
                    self._emit(
                        sf,
                        ln,
                        rule["id"],
                        f"`{m.group(0)}` without `{rule['annotation']}` justification",
                    )

    # -- exhaustive ---------------------------------------------------------

    def _region(self, sf, target):
        """(start, end) 1-based inclusive line range for a target, or None.

        Regions and exhaustive needles match against the `full` view (code
        with string-literal contents intact) so serialized field names stay
        visible; comments stay invisible either way.
        """
        start_re = target.get("region_start")
        if not start_re:
            return 1, len(sf.full)
        rx = re.compile(start_re)
        start = None
        for ln, line in enumerate(sf.full, start=1):
            if rx.search(line):
                start = ln
                break
        if start is None:
            return None
        end = len(sf.full)
        end_pat = target.get("region_end")
        if end_pat:
            rx_end = re.compile(end_pat)
            for ln in range(start, len(sf.full) + 1):
                if rx_end.search(sf.full[ln - 1]):
                    end = ln
                    break
        return start, end

    def _run_exhaustive(self, rule):
        src = rule["source"]
        if "tokens" in src:
            tokens = list(src["tokens"])
        else:
            sf = self._load(src["path"])
            region = self._region(sf, src)
            if region is None:
                self.violations.append(
                    Violation(
                        sf.rel, 1, rule["id"], f"source region `{src['region_start']}` not found"
                    )
                )
                return
            tok_re = re.compile(src["token_pattern"])
            tokens = []
            for ln in range(region[0], region[1] + 1):
                m = tok_re.search(sf.full[ln - 1])
                if m and m.group(1) not in tokens:
                    tokens.append(m.group(1))
            if not tokens:
                self.violations.append(
                    Violation(sf.rel, region[0], rule["id"], "no source tokens extracted")
                )
                return
        for target in rule["targets"]:
            sf = self._load(target["path"])
            region = self._region(sf, target)
            if region is None:
                self.violations.append(
                    Violation(
                        sf.rel,
                        1,
                        rule["id"],
                        f"target region `{target['region_start']}` not found",
                    )
                )
                continue
            start, end = region
            for tok in tokens:
                needle = target["template"].replace("{token}", tok).replace(
                    "{TOKEN}", tok.upper()
                )
                if not any(
                    needle in sf.full[ln - 1] for ln in range(start, end + 1)
                ):
                    self._emit(
                        sf,
                        start,
                        rule["id"],
                        f"`{needle}` missing from target region (drifted from source list)",
                    )

    # -- directive hygiene --------------------------------------------------

    def _finish_directives(self):
        for sf in self.files.values():
            for ln, rule_id, msg in sf.directive_violations:
                self.violations.append(Violation(sf.rel, ln, rule_id, msg))
            for a in sf.allows:
                unknown = [r for r in a.rules if r not in self.known_ids]
                for r in unknown:
                    self.violations.append(
                        Violation(
                            sf.rel, a.src_line, RULE_UNKNOWN_RULE, f"allow names unknown rule `{r}`"
                        )
                    )
                if not a.used and not unknown:
                    self.violations.append(
                        Violation(
                            sf.rel,
                            a.src_line,
                            RULE_UNUSED_ALLOW,
                            f"allow({', '.join(a.rules)}) suppressed nothing — stale?",
                        )
                    )

    # -- reporting ----------------------------------------------------------

    def report(self, out=sys.stdout):
        for v in self.violations:
            print(v, file=out)
        n_supp = sum(len(v) for v in self.suppressed.values())
        n_allow = sum(self.allowlisted.values())
        print(
            f"lint: {len(self.files)} files, {len(self.rules)} rules, "
            f"{len(self.violations)} violations, {n_supp} suppressed, "
            f"{n_allow} allowlisted sites",
            file=out,
        )
        for rule_id in sorted(self.suppressed):
            for rel, line, reason in self.suppressed[rule_id]:
                print(f"  suppressed {rule_id} at {rel}:{line}: {reason}", file=out)


# ---------------------------------------------------------------------------
# Self-test against the fixture corpus
# ---------------------------------------------------------------------------


def self_test(fixtures_dir):
    fixtures_dir = Path(fixtures_dir)
    spec = json.loads((fixtures_dir / "rules.json").read_text())
    expected = json.loads((fixtures_dir / "expected.json").read_text())
    eng = Engine(fixtures_dir, spec).run()
    got = sorted(v.key() for v in eng.violations)
    want = sorted(
        (e["file"], e["line"], e["rule"]) for e in expected["violations"]
    )
    ok = True
    for miss in [w for w in want if w not in got]:
        print(f"self-test: expected violation did not fire: {miss}")
        ok = False
    for extra in [g for g in got if g not in want]:
        print(f"self-test: unexpected violation: {extra}")
        ok = False
    got_supp = {k: len(v) for k, v in eng.suppressed.items()}
    if got_supp != expected.get("suppressed", {}):
        print(
            f"self-test: suppression counts {got_supp} != expected "
            f"{expected.get('suppressed', {})}"
        )
        ok = False
    got_allow = dict(eng.allowlisted)
    if got_allow != expected.get("allowlisted", {}):
        print(
            f"self-test: allowlisted counts {got_allow} != expected "
            f"{expected.get('allowlisted', {})}"
        )
        ok = False
    print(
        f"self-test: {len(want)} expected violations, "
        f"{sum(got_supp.values())} suppressions — {'OK' if ok else 'FAIL'}"
    )
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=str(REPO_ROOT), help="repo root to lint")
    ap.add_argument("--rules", default=None, help="rule spec (default <root>/lint/rules.json)")
    ap.add_argument(
        "--deny", action="store_true", help="exit non-zero on any violation"
    )
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="run the lint/fixtures corpus instead of linting the repo",
    )
    args = ap.parse_args(argv)
    root = Path(args.root)
    if args.self_test:
        return 0 if self_test(root / "lint" / "fixtures") else 2
    rules_path = Path(args.rules) if args.rules else root / "lint" / "rules.json"
    spec = json.loads(rules_path.read_text())
    eng = Engine(root, spec).run()
    eng.report()
    if eng.violations and args.deny:
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
