//! Regenerates paper Table 3: XGBoost (ours) vs CNN [45,24] vs decision
//! tree [27] — inference time, prediction accuracy, realized speedup.
use gnn_spmm::coordinator::{experiments, Workbench};
use gnn_spmm::gnn::TrainConfig;

fn main() -> anyhow::Result<()> {
    let wb = Workbench::bench(0xE8);
    let cfg = TrainConfig { epochs: 5, ..Default::default() };
    let t = experiments::table3(&wb, &cfg, 2);
    experiments::print_table("Table 3 — comparison with prior predictors", &t);
    t.write_file("results/table3.csv")?;
    Ok(())
}
