//! Regenerates paper Fig. 6: how often each storage format is Eq-1-optimal
//! on the synthetic training corpus as the weight w varies.
use gnn_spmm::coordinator::{experiments, Workbench};

fn main() -> anyhow::Result<()> {
    let wb = Workbench::bench(0xE8);
    let t = experiments::fig6(&wb, &[0.0, 0.3, 0.5, 0.7, 1.0]);
    experiments::print_table("Fig 6 — optimal-format frequency vs w", &t);
    t.write_file("results/fig6.csv")?;
    Ok(())
}
