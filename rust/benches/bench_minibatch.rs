//! Sharded mini-batch training bench: epoch time and decision overhead vs
//! shard count on the full `ogbn-arxiv-scale` synthetic graph (169k nodes —
//! the workload class that cannot train full-batch at paper scale).
//!
//! What it measures, per shard count:
//!
//! * epoch wall-clock (shard loop + optimizer step; eval excluded),
//! * decision overhead (COO views + feature extraction + model inference)
//!   and extraction time, both charged to the engine stopwatch,
//! * decision-cache hit rate, warm (post-first-epoch) hit rate,
//! * the COO-fallback extraction counter delta — **asserted zero**: shard
//!   extraction must take the direct CSR path (ISSUE-3 acceptance gate;
//!   the counter is pool-aggregated, so extractions on worker threads
//!   cannot escape it),
//! * an RGCN pass (ISSUE-4): R relations × shards of per-relation direct
//!   submatrix extraction, one decision-cache entry per relation per shard
//!   signature — the workload where per-matrix decisions multiply,
//! * an **eval-rebind probe** (§Shared-Ownership): the per-epoch
//!   full-graph eval flip onto the dedicated double-buffered eval slots,
//!   alloc-counter instrumented under the same accounting rules as
//!   `perf_hotpath` (DESIGN.md §Perf) — **asserted to perform zero
//!   allocations** in steady state — next to the legacy deep-clone rebind
//!   it replaced (`rebind_ns` / `rebind_allocs` / `deep_rebind_ns`
//!   records).
//!
//! Results land in `BENCH_minibatch.json` (override with
//! `GNN_SPMM_BENCH_MINIBATCH_OUT`) — the start of the minibatch perf
//! trajectory, alongside `BENCH_spmm.json` for the kernel layer.

use gnn_spmm::bench::{bench, count_allocs, section, CountingAlloc};
use gnn_spmm::gnn::engine::{AdjEngine, StaticPolicy};
use gnn_spmm::gnn::gcn::Gcn;
use gnn_spmm::gnn::rgcn::Rgcn;
use gnn_spmm::gnn::{train_minibatch, MinibatchConfig, ModelKind};
use gnn_spmm::graph::{GraphDataset, LARGE_DATASETS};
use gnn_spmm::predictor::training::{train_predictor, TrainingCorpus};
use gnn_spmm::predictor::PredictedPolicy;
use gnn_spmm::sparse::{Csr, Format, SharedMatrix};
use gnn_spmm::util::json::Json;
use gnn_spmm::util::rng::Rng;
use gnn_spmm::util::stats;

// Shared counting allocator (rules live in `bench::alloc_counter`; the
// counters are gated, so timing sections run uninstrumented). The rebind
// probe's zero-allocation gate reads it around the eval-slot flip.
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn main() {
    let out_path = std::env::var("GNN_SPMM_BENCH_MINIBATCH_OUT")
        .unwrap_or_else(|_| "BENCH_minibatch.json".to_string());

    // Full-scale synthetic ogbn-arxiv (shrink with GNN_SPMM_MB_SHRINK for
    // quick local iterations).
    let shrink: usize = std::env::var("GNN_SPMM_MB_SHRINK")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1);
    let spec = if shrink > 1 {
        LARGE_DATASETS[0].scaled_same_degree(shrink, 128)
    } else {
        LARGE_DATASETS[0]
    };
    println!(
        "generating {} (n={}, avg degree {:.1})…",
        spec.name,
        spec.n,
        spec.n as f64 * spec.adj_density
    );
    let mut rng = Rng::new(0xA12C);
    let ds = GraphDataset::generate(&spec, &mut rng);
    println!("adjacency nnz {}, feature nnz {}", ds.adj.nnz(), ds.features.nnz());

    // The paper's deployed policy: the learned GBDT predictor — decision
    // overhead is the quantity of interest, so use the policy that has one.
    println!("training format predictor…");
    let corpus = TrainingCorpus::build(60, 64, 256, 16, 2, 7);
    let mut policy = PredictedPolicy::new(train_predictor(&corpus, 1.0, 7));

    let epochs = 3;
    let mut records: Vec<Json> = Vec::new();
    for &n_shards in &[4usize, 8, 16, 32] {
        let cfg = MinibatchConfig {
            epochs,
            hidden: 16,
            n_shards,
            fanout: 8,
            seed: 0xBEEF,
            ..Default::default()
        };
        let report = train_minibatch(ModelKind::Gcn, &ds, &mut policy, &cfg);

        // ISSUE-3 acceptance gate: extraction never round-trips CSR/CSC
        // through COO (the pool-aggregated counter also catches
        // extractions executed on worker threads).
        assert_eq!(
            report.coo_fallback_extractions, 0,
            "shard extraction fell back to the COO round-trip"
        );

        let epoch_ns: Vec<f64> =
            report.epoch_times.iter().map(|s| s * 1e9).collect();
        let extract_s = report
            .phases
            .iter()
            .find(|p| p.0 == "extract")
            .map(|p| p.1)
            .unwrap_or(0.0);
        println!(
            "shards {n_shards:>3}: epoch median {:>8.1} ms | decisions {} (warm hit rate {:.1}%) | decision overhead {:.1} ms | extract {:.1} ms | test acc {:.3}",
            stats::median(&epoch_ns) / 1e6,
            report.decisions.len(),
            report.warm_cache_hit_rate * 100.0,
            report.decision_overhead_s * 1e3,
            extract_s * 1e3,
            report.final_test_acc,
        );
        records.push(Json::obj(vec![
            ("model", Json::Str(report.model.to_string())),
            // Report fields move into the record — no per-report clones.
            ("dataset", Json::Str(report.dataset)),
            ("policy", Json::Str(report.policy)),
            ("n", Json::Num(ds.adj.rows as f64)),
            ("adj_nnz", Json::Num(ds.adj.nnz() as f64)),
            ("shards", Json::Num(n_shards as f64)),
            ("fanout", Json::Num(cfg.fanout as f64)),
            ("epochs", Json::Num(epochs as f64)),
            ("epoch_median_ns", Json::Num(stats::median(&epoch_ns))),
            ("epoch_min_ns", Json::Num(stats::min(&epoch_ns))),
            ("decision_overhead_ns", Json::Num(report.decision_overhead_s * 1e9)),
            ("extract_ns", Json::Num(extract_s * 1e9)),
            ("decisions", Json::Num(report.decisions.len() as f64)),
            ("cache_hits", Json::Num(report.cache_hits as f64)),
            ("cache_misses", Json::Num(report.cache_misses as f64)),
            ("warm_cache_hit_rate", Json::Num(report.warm_cache_hit_rate)),
            ("coo_fallback_extractions", Json::Num(report.coo_fallback_extractions as f64)),
            ("final_test_acc", Json::Num(report.final_test_acc)),
        ]));
    }

    // RGCN (ISSUE-4): per-relation induced-submatrix extraction — R
    // relation slots per layer, each with its own shard-signature cache
    // entry, so the decision surface is R × shards instead of one
    // adjacency. Fewer shard counts than GCN: each epoch multiplies R
    // relation matrices.
    for &n_shards in &[8usize, 16] {
        let cfg = MinibatchConfig {
            epochs,
            hidden: 16,
            n_shards,
            fanout: 8,
            seed: 0xBEEF,
            ..Default::default()
        };
        let report = train_minibatch(ModelKind::Rgcn, &ds, &mut policy, &cfg);
        assert_eq!(
            report.coo_fallback_extractions, 0,
            "per-relation shard extraction fell back to the COO round-trip"
        );
        let rel_decisions = report
            .decisions
            .iter()
            .filter(|d| d.slot.starts_with("rgcn.A"))
            .count();
        let epoch_ns: Vec<f64> = report.epoch_times.iter().map(|s| s * 1e9).collect();
        let extract_s = report
            .phases
            .iter()
            .find(|p| p.0 == "extract")
            .map(|p| p.1)
            .unwrap_or(0.0);
        println!(
            "RGCN shards {n_shards:>3}: epoch median {:>8.1} ms | decisions {} ({} on relation slots, warm hit rate {:.1}%) | extract {:.1} ms | test acc {:.3}",
            stats::median(&epoch_ns) / 1e6,
            report.decisions.len(),
            rel_decisions,
            report.warm_cache_hit_rate * 100.0,
            extract_s * 1e3,
            report.final_test_acc,
        );
        records.push(Json::obj(vec![
            ("model", Json::Str(report.model.to_string())),
            ("dataset", Json::Str(report.dataset)),
            ("policy", Json::Str(report.policy)),
            ("n", Json::Num(ds.adj.rows as f64)),
            ("adj_nnz", Json::Num(ds.adj.nnz() as f64)),
            ("shards", Json::Num(n_shards as f64)),
            ("fanout", Json::Num(cfg.fanout as f64)),
            ("epochs", Json::Num(epochs as f64)),
            ("epoch_median_ns", Json::Num(stats::median(&epoch_ns))),
            ("epoch_min_ns", Json::Num(stats::min(&epoch_ns))),
            ("decision_overhead_ns", Json::Num(report.decision_overhead_s * 1e9)),
            ("extract_ns", Json::Num(extract_s * 1e9)),
            ("decisions", Json::Num(report.decisions.len() as f64)),
            ("relation_slot_decisions", Json::Num(rel_decisions as f64)),
            ("cache_hits", Json::Num(report.cache_hits as f64)),
            ("cache_misses", Json::Num(report.cache_misses as f64)),
            ("warm_cache_hit_rate", Json::Num(report.warm_cache_hit_rate)),
            ("coo_fallback_extractions", Json::Num(report.coo_fallback_extractions as f64)),
            ("final_test_acc", Json::Num(report.final_test_acc)),
        ]));
    }

    // Reference point: the same machinery under a static-CSR policy (no
    // prediction overhead at all) at one shard count.
    let mut static_policy = StaticPolicy(Format::Csr);
    let cfg = MinibatchConfig {
        epochs,
        hidden: 16,
        n_shards: 8,
        fanout: 8,
        seed: 0xBEEF,
        ..Default::default()
    };
    let report = train_minibatch(ModelKind::Gcn, &ds, &mut static_policy, &cfg);
    assert_eq!(report.coo_fallback_extractions, 0);
    let epoch_ns: Vec<f64> = report.epoch_times.iter().map(|s| s * 1e9).collect();
    println!(
        "static-CSR reference (8 shards): epoch median {:.1} ms",
        stats::median(&epoch_ns) / 1e6
    );
    records.push(Json::obj(vec![
        ("model", Json::Str(report.model.to_string())),
        ("dataset", Json::Str(report.dataset)),
        ("policy", Json::Str(report.policy)),
        ("n", Json::Num(ds.adj.rows as f64)),
        ("adj_nnz", Json::Num(ds.adj.nnz() as f64)),
        ("shards", Json::Num(8.0)),
        ("fanout", Json::Num(8.0)),
        ("epochs", Json::Num(epochs as f64)),
        ("epoch_median_ns", Json::Num(stats::median(&epoch_ns))),
        ("epoch_min_ns", Json::Num(stats::min(&epoch_ns))),
        ("decision_overhead_ns", Json::Num(report.decision_overhead_s * 1e9)),
        ("warm_cache_hit_rate", Json::Num(report.warm_cache_hit_rate)),
        ("coo_fallback_extractions", Json::Num(report.coo_fallback_extractions as f64)),
        ("final_test_acc", Json::Num(report.final_test_acc)),
    ]));

    // ── §Shared-Ownership eval-rebind probe ─────────────────────────────
    // The per-epoch full-graph eval is an O(1) flip onto dedicated eval
    // slots bound once at startup. Measure the flip (rebind_ns), gate it
    // at ZERO allocations (rebind_allocs — the hard acceptance criterion),
    // and record the legacy deep-clone rebind it replaced for the
    // before/after story.
    section("eval rebind (§Shared-Ownership): slot flip vs legacy deep-clone");
    {
        let feats = SharedMatrix::from(Csr::from_coo(&ds.features));
        let adjn = SharedMatrix::from(Csr::from_coo(&ds.adj_norm));
        let shard: Vec<u32> = (0..ds.adj.rows as u32).step_by(8).collect();
        let all_cols: Vec<u32> = (0..ds.features.cols as u32).collect();
        let mut probe_policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut probe_policy);
        eng.enable_decision_cache();
        let mut prng = Rng::new(0xE7A1);
        let mut model = Gcn::new(&ds, 16, 0.02, &mut prng, &mut eng);
        model.bind_eval_graph(&mut eng, feats.clone(), adjn.clone());
        // Settle: one shard bind + forward, one eval flip + forward — all
        // decisions, conversions and workspace pools now exist.
        model.set_graph(
            &mut eng,
            feats.extract_rows_cols(&shard, &all_cols),
            adjn.extract_rows_cols(&shard, &shard),
        );
        let _ = model.forward(&mut eng);
        model.use_eval_graph();
        let _ = model.forward(&mut eng);
        model.use_train_graph();
        // Hard gate: the steady-state eval rebind performs ZERO allocations.
        let (rebind_allocs, rebind_bytes) = count_allocs(|| model.use_eval_graph());
        assert_eq!(
            (rebind_allocs, rebind_bytes),
            (0, 0),
            "eval-slot flip must be allocation-free (got {rebind_allocs} allocs / {rebind_bytes} B)"
        );
        model.use_train_graph();
        let r_flip = bench("rebind/eval_flip/GCN", 4, 32, || {
            model.use_eval_graph();
            model.use_train_graph();
        });
        // …and the steady-state eval forward makes no new decisions and no
        // conversions (the slots are literally the same matrices).
        let decisions_before = eng.decisions.len();
        let converts_before =
            eng.sw.report().iter().find(|p| p.0 == "convert").map(|p| p.2).unwrap_or(0);
        model.use_eval_graph();
        let _ = model.forward(&mut eng);
        assert_eq!(
            eng.decisions.len(),
            decisions_before,
            "steady-state eval flip must not re-decide"
        );
        let converts_after =
            eng.sw.report().iter().find(|p| p.0 == "convert").map(|p| p.2).unwrap_or(0);
        assert_eq!(converts_after, converts_before, "steady-state eval flip must not convert");
        // Legacy path for comparison: deep-clone the masters into the
        // train slots (what every epoch used to pay).
        let r_deep = bench("rebind/deep_clone/GCN", 1, 5, || {
            model.set_graph(&mut eng, (*feats).clone(), (*adjn).clone());
        });
        records.push(Json::obj(vec![
            ("probe", Json::Str("eval_rebind".to_string())),
            ("model", Json::Str("GCN".to_string())),
            ("n", Json::Num(ds.adj.rows as f64)),
            ("rebind_ns", Json::Num(r_flip.median_s * 1e9)),
            ("rebind_allocs", Json::Num(rebind_allocs as f64)),
            ("rebind_alloc_bytes", Json::Num(rebind_bytes as f64)),
            ("deep_rebind_ns", Json::Num(r_deep.median_s * 1e9)),
            (
                "rebind_speedup",
                Json::Num(r_deep.median_s / r_flip.median_s.max(1e-12)),
            ),
        ]));
    }
    // RGCN: the worst legacy offender (~2R CSR master copies per epoch).
    {
        let rels = gnn_spmm::gnn::rgcn::relation_operands(&ds.adj);
        let rel_masters: Vec<SharedMatrix> =
            rels.iter().map(|r| SharedMatrix::from(Csr::from_coo(r))).collect();
        let feats = SharedMatrix::from(Csr::from_coo(&ds.features));
        let mut probe_policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut probe_policy);
        eng.enable_decision_cache();
        let mut prng = Rng::new(0xE7A2);
        let mut model = Rgcn::with_relations(&ds, &rels, 16, 0.02, &mut prng, &mut eng);
        model.bind_eval_graph(&mut eng, feats.clone(), rel_masters.clone());
        model.use_eval_graph();
        let _ = model.forward(&mut eng);
        model.use_train_graph();
        let (rebind_allocs, rebind_bytes) = count_allocs(|| model.use_eval_graph());
        assert_eq!(
            (rebind_allocs, rebind_bytes),
            (0, 0),
            "RGCN eval-slot flip must be allocation-free"
        );
        model.use_train_graph();
        let r_flip = bench("rebind/eval_flip/RGCN", 4, 32, || {
            model.use_eval_graph();
            model.use_train_graph();
        });
        let r_deep = bench("rebind/deep_clone/RGCN", 1, 3, || {
            let deep: Vec<SharedMatrix> = rel_masters
                .iter()
                .map(|r| SharedMatrix::from((**r).clone()))
                .collect();
            model.set_graph(&mut eng, (*feats).clone(), deep);
        });
        records.push(Json::obj(vec![
            ("probe", Json::Str("eval_rebind".to_string())),
            ("model", Json::Str("RGCN".to_string())),
            ("n", Json::Num(ds.adj.rows as f64)),
            ("relations", Json::Num(rels.len() as f64)),
            ("rebind_ns", Json::Num(r_flip.median_s * 1e9)),
            ("rebind_allocs", Json::Num(rebind_allocs as f64)),
            ("rebind_alloc_bytes", Json::Num(rebind_bytes as f64)),
            ("deep_rebind_ns", Json::Num(r_deep.median_s * 1e9)),
            (
                "rebind_speedup",
                Json::Num(r_deep.median_s / r_flip.median_s.max(1e-12)),
            ),
        ]));
    }

    let threads = gnn_spmm::util::parallel::num_threads();
    let doc = Json::obj(vec![
        ("bench", Json::Str("bench_minibatch".to_string())),
        ("threads", Json::Num(threads as f64)),
        (
            "unit",
            Json::Str("ns (medians over epochs); rates in [0,1]".to_string()),
        ),
        ("minibatch", Json::Arr(records)),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
