//! Regenerates paper Fig. 11: XGBoost vs MLP / KNN / SVM — accuracy and
//! per-sample inference time.
use gnn_spmm::coordinator::{experiments, Workbench};

fn main() -> anyhow::Result<()> {
    let wb = Workbench::bench(0xE8);
    let t = experiments::fig11(&wb);
    experiments::print_table("Fig 11 — modeling-technique comparison", &t);
    t.write_file("results/fig11.csv")?;
    Ok(())
}
