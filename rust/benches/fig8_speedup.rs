//! Regenerates paper Fig. 8: end-to-end speedup of the learned predictor
//! over always-COO, per model (8a) and per dataset (8b).
use gnn_spmm::coordinator::{experiments, Workbench};
use gnn_spmm::gnn::TrainConfig;
use gnn_spmm::util::stats;

fn main() -> anyhow::Result<()> {
    let wb = Workbench::bench(0xE8);
    let cfg = TrainConfig { epochs: 5, ..Default::default() };
    let t = experiments::fig8(&wb, &cfg, 2);
    experiments::print_table("Fig 8 — predicted-policy speedup over COO", &t);
    t.write_file("results/fig8.csv")?;

    // 8(a): geomean per model; 8(b): geomean per dataset.
    let speedups: Vec<(String, String, f64)> = t
        .rows
        .iter()
        .map(|r| (r[0].clone(), r[1].clone(), r[4].parse().unwrap()))
        .collect();
    println!("\nFig 8(a) — geomean speedup per model:");
    for model in ["GCN", "GAT", "RGCN", "FiLM", "EGC"] {
        let xs: Vec<f64> = speedups.iter().filter(|(m, _, _)| m == model).map(|(_, _, s)| *s).collect();
        println!("  {model:<6} {:.3}x", stats::geomean(&xs));
    }
    println!("Fig 8(b) — geomean speedup per dataset:");
    for ds in ["CoraFull", "Cora", "DblpFull", "PubmedFull", "KarateClub"] {
        let xs: Vec<f64> = speedups.iter().filter(|(_, d, _)| d == ds).map(|(_, _, s)| *s).collect();
        println!("  {ds:<12} {:.3}x", stats::geomean(&xs));
    }
    let all: Vec<f64> = speedups.iter().map(|(_, _, s)| *s).collect();
    println!("overall geomean: {:.3}x (paper: 1.17x, up to 3x)", stats::geomean(&all));
    Ok(())
}
