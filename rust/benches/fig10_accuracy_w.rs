//! Regenerates paper Fig. 10: prediction accuracy as the optimization
//! weight w varies.
use gnn_spmm::coordinator::{experiments, Workbench};

fn main() -> anyhow::Result<()> {
    let wb = Workbench::bench(0xE8);
    let t = experiments::fig10(&wb, &[0.0, 0.3, 0.5, 0.7, 1.0]);
    experiments::print_table("Fig 10 — prediction accuracy vs w", &t);
    t.write_file("results/fig10.csv")?;
    Ok(())
}
