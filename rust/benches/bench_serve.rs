//! Serving-layer bench: request latency and throughput vs worker count on
//! a power-law workload (DESIGN.md §Serving).
//!
//! Per (model, worker-count) it serves a fixed stream of node-batch
//! requests — batch sizes and node popularity both power-law distributed,
//! the "heavy traffic from millions of users" shape — and emits one
//! JSON-lines record with `p50_ns`/`p95_ns`/`p99_ns`/`ops_per_sec`
//! (DecentDB-style: one JSON object per line, `BENCH_serve.json`).
//!
//! Two hard gates ride along:
//!
//! * **throughput scales**: ops/sec at the max worker count must beat the
//!   single-worker run on the same stream (the queue + snapshot design
//!   has no serialization point to eat the speedup),
//! * **the swap path is allocation-free**: an alloc-counter probe around
//!   `EpochCell::publish_arc` (same rules as `bench_minibatch`'s
//!   `rebind_allocs` gate) — snapshot building is the writer's cost,
//!   publication is a pointer store.

use gnn_spmm::bench::{count_allocs, section, CountingAlloc};
use gnn_spmm::gnn::engine::StaticPolicy;
use gnn_spmm::gnn::{AdjEngine, ModelKind};
use gnn_spmm::graph::{GraphDataset, LARGE_DATASETS};
use gnn_spmm::predictor::DecisionCache;
use gnn_spmm::serve::{
    train_template, EngineSnapshot, InferenceServer, ServeConfig, ServedModel,
};
use gnn_spmm::sparse::shared::EpochCell;
use gnn_spmm::sparse::Format;
use gnn_spmm::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

const HIDDEN: usize = 16;

/// Power-law request stream: batch size ~ heavy-tailed in [8, 128], node
/// popularity skewed toward low ids (u² inverse-CDF — a Zipf-ish head).
fn power_law_requests(n_nodes: usize, count: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            let u = rng.next_f64().max(1e-9);
            let size = (8.0 / u.powf(0.7)).min(128.0) as usize;
            (0..size.max(8))
                .map(|_| {
                    let v = rng.next_f64();
                    ((n_nodes - 1) as f64 * v * v) as u32
                })
                .collect()
        })
        .collect()
}

/// Warm a decision cache the way a service would (an owned-cache engine
/// runs representative request shapes; the server then shares the result
/// read-only across workers — the `DecisionCache::load` flow without the
/// disk hop, which `serve_demo` exercises end to end).
fn warm_cache(ds: &GraphDataset, template: &ServedModel, requests: &[Vec<u32>]) -> DecisionCache {
    let mut policy = StaticPolicy(Format::Csr);
    let mut eng = AdjEngine::new(&mut policy);
    eng.enable_decision_cache();
    let mut rng = Rng::new(0xCA0E);
    let mut replica = template.replicate(ds, HIDDEN, 0.02, &mut rng, &mut eng);
    let snap = EngineSnapshot::from_dataset(ds, 0);
    let all_cols: Vec<u32> = (0..ds.features.cols as u32).collect();
    for req in requests.iter().take(12) {
        let mut nodes = req.clone();
        nodes.sort_unstable();
        nodes.dedup();
        let x = snap.feats.extract_rows_cols(&nodes, &all_cols);
        let a = snap.adjn.extract_rows_cols(&nodes, &nodes);
        replica.set_graph(&mut eng, x, a);
        let _ = replica.forward(&mut eng);
    }
    eng.take_decision_cache().unwrap()
}

fn main() {
    let out_path = std::env::var("GNN_SPMM_BENCH_SERVE_OUT")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let shrink: usize = std::env::var("GNN_SPMM_SERVE_SHRINK")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let spec = if shrink > 1 {
        LARGE_DATASETS[0].scaled_same_degree(shrink, 128)
    } else {
        LARGE_DATASETS[0]
    };
    println!("generating {} (n={})…", spec.name, spec.n);
    let ds = Arc::new(GraphDataset::generate(&spec, &mut Rng::new(0xA12C)));
    let n_requests: usize = std::env::var("GNN_SPMM_SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    let requests = power_law_requests(spec.n, n_requests, 0x90B0);
    let max_workers = gnn_spmm::util::parallel::num_threads().clamp(2, 8);

    let mut lines: Vec<String> = Vec::new();
    let grid: &[(ModelKind, &[usize])] = &[
        (ModelKind::Gcn, &[1, 2, max_workers]),
        (ModelKind::Film, &[1, max_workers]),
        (ModelKind::Egc, &[1, max_workers]),
    ];
    for &(kind, worker_counts) in grid {
        println!("training {} template…", kind.name());
        let template = Arc::new(train_template(kind, &ds, HIDDEN, 0.02, 5, 0x7E4));
        let warm = warm_cache(&ds, &template, &requests);
        let mut ops_by_workers: Vec<(usize, f64)> = Vec::new();
        for &workers in worker_counts {
            let cfg = ServeConfig {
                workers,
                queue_capacity: 64,
                hidden: HIDDEN,
                ..Default::default()
            };
            let srv = InferenceServer::start(
                cfg,
                Arc::clone(&ds),
                Arc::clone(&template),
                EngineSnapshot::from_dataset(&ds, 0),
                Some(warm.clone()),
            );
            let t0 = Instant::now();
            for req in &requests {
                srv.submit(req.clone()).unwrap();
            }
            let responses = srv.drain();
            let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
            assert_eq!(responses.len(), requests.len());
            let mut rep = srv.report(spec.name);
            rep.ops_per_sec = requests.len() as f64 / elapsed;
            println!(
                "{} w{workers}: p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms | {:.0} req/s | cache hit rate {:.1}%",
                kind.name(),
                rep.p50_ns as f64 / 1e6,
                rep.p95_ns as f64 / 1e6,
                rep.p99_ns as f64 / 1e6,
                rep.ops_per_sec,
                rep.cache.hit_rate() * 100.0,
            );
            ops_by_workers.push((workers, rep.ops_per_sec));
            lines.push(rep.to_json_line());
            srv.shutdown();
        }
        // Acceptance gate: the worker pool actually parallelizes the
        // stream — max-worker throughput beats single-worker.
        let single = ops_by_workers.iter().find(|(w, _)| *w == 1).unwrap().1;
        let best = ops_by_workers
            .iter()
            .map(|&(_, ops)| ops)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(
            best > single,
            "{}: throughput failed to scale (1 worker {single:.0} req/s, best {best:.0} req/s)",
            kind.name()
        );
        println!("  scale 1→{}: ×{:.2}", max_workers, best / single);
    }

    // ── §Serving swap-path alloc gate ───────────────────────────────────
    // Snapshot construction (CSR builds, Arc) happens before publication;
    // the publish itself must allocate NOTHING — pointer store + epoch
    // bump under a momentary write lock.
    section("epoch-swap publish: zero-allocation gate");
    {
        let cell = EpochCell::new(EngineSnapshot::from_dataset(&ds, 0));
        let reader = cell.load(); // an in-flight request keeps v0 alive
        let mut staged = Some(Arc::new(EngineSnapshot::from_dataset(&ds, 1)));
        let (allocs, bytes) = count_allocs(|| {
            cell.publish_arc(staged.take().unwrap());
        });
        assert_eq!(
            (allocs, bytes),
            (0, 0),
            "epoch-swap publish must be allocation-free (got {allocs} allocs / {bytes} B)"
        );
        assert_eq!(reader.version, 0, "in-flight reader keeps its snapshot");
        assert_eq!(cell.load().version, 1);
        lines.push(
            gnn_spmm::util::json::Json::obj(vec![
                ("name", gnn_spmm::util::json::Json::Str("serve/publish_arc_probe".to_string())),
                ("publish_allocs", gnn_spmm::util::json::Json::Num(allocs as f64)),
                ("publish_alloc_bytes", gnn_spmm::util::json::Json::Num(bytes as f64)),
            ])
            .to_string(),
        );
    }

    let body = lines.join("\n") + "\n";
    match std::fs::write(&out_path, &body) {
        Ok(()) => println!("\nwrote {out_path} ({} records)", lines.len()),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
