//! §Perf micro-benchmarks: the hot paths the whole system sits on —
//! per-format SpMM kernels (allocating and `_into` workspace variants, both
//! directions), format conversions, feature extraction and the dense GEMM.
//! Used by the optimization pass in EXPERIMENTS.md §Perf.
//!
//! Workloads cover **uniform and skewed (power-law)** non-zero placements:
//! the power-law inputs are where nnz-balanced scheduling (see
//! `util::parallel::indptr_span`) earns its keep — a count-based row split
//! hands one worker all the hub rows.
//!
//! Besides the human-readable table, emits a machine-readable
//! `BENCH_spmm.json` (ns/op and allocation counts per format × pattern ×
//! size) so subsequent PRs have a perf trajectory to compare against. If a
//! previous `BENCH_spmm.json` exists at the output path it is loaded first
//! and every record gains `prev_*_ns` + `speedup_*` fields (old/new) — the
//! before/after comparison is recorded in the file itself. Output path
//! overridable via `GNN_SPMM_BENCH_OUT`.
//!
//! Allocation counts come from a counting global allocator. With the
//! persistent worker pool, the `_into` kernels are allocation-free in
//! steady state for every format — the pool dispatches on parked workers,
//! scatter kernels reuse grow-only scratch, and LIL binary-searches a
//! cached per-matrix nnz prefix-sum instead of materializing a range list —
//! so `allocs_per_op_into` should read 0 after warmup.

use gnn_spmm::bench::{bench, count_allocs, section, CountingAlloc};
use gnn_spmm::features::extract_features;
use gnn_spmm::graph::{gen_matrix, MatrixPattern};
use gnn_spmm::sparse::{Format, SparseMatrix, ALL_FORMATS};
use gnn_spmm::tensor::Matrix;
use gnn_spmm::util::json::Json;
use gnn_spmm::util::rng::Rng;
use std::collections::HashMap;

// Shared counting allocator (rules live in `bench::alloc_counter`): the
// JSON reports the per-op allocation cost of each kernel variant. The
// counters are gated inside `count_allocs`, so the timing sections run
// under uninstrumented conditions.
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// (format, pattern, n, d) → (spmm_into_ns, spmm_t_into_ns) from a previous
/// run's JSON, if one exists at `path`. Records predating the `pattern`
/// field (PR-1 baseline) are treated as power-law — that is what the old
/// bench generated.
fn load_baseline(path: &str) -> HashMap<(String, String, u64, u64), (f64, f64)> {
    let mut map = HashMap::new();
    let Ok(text) = std::fs::read_to_string(path) else {
        return map;
    };
    let Ok(doc) = Json::parse(&text) else {
        return map;
    };
    let Some(arr) = doc.get("spmm").and_then(|v| v.as_arr()) else {
        return map;
    };
    for rec in arr {
        let fmt = rec.get("format").and_then(|v| v.as_str()).unwrap_or("");
        let pattern = rec.get("pattern").and_then(|v| v.as_str()).unwrap_or("powerlaw");
        let n = rec.get("n").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        let d = rec.get("d").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        let into_ns = rec.get("spmm_into_ns").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let t_ns = rec.get("spmm_t_into_ns").and_then(|v| v.as_f64()).unwrap_or(0.0);
        map.insert((fmt.to_string(), pattern.to_string(), n, d), (into_ns, t_ns));
    }
    map
}

fn main() {
    let mut rng = Rng::new(0x9E7F);
    let mut records: Vec<Json> = Vec::new();

    let out_path = std::env::var("GNN_SPMM_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_spmm.json".to_string());
    let baseline = load_baseline(&out_path);
    if !baseline.is_empty() {
        println!("loaded {} baseline records from {out_path}", baseline.len());
    }

    let patterns = [
        (MatrixPattern::Uniform, "uniform"),
        (MatrixPattern::PowerLaw, "powerlaw"),
    ];
    for &(n, d, density) in &[(1024usize, 16usize, 0.02f64), (2048, 32, 0.01), (4096, 64, 0.01)] {
        for (pi, &(pattern, pat_name)) in patterns.iter().enumerate() {
            // Fresh per-workload RNG so each (n, d, pattern) matrix is
            // reproducible regardless of which workloads a bench version
            // runs or in what order — prev_*/speedup_* comparisons across
            // runs are then apples-to-apples.
            let mut wrng = Rng::new(0x9E7F ^ ((n as u64) << 24) ^ ((d as u64) << 8) ^ pi as u64);
            let coo = gen_matrix(&mut wrng, n, density, pattern);
            let nnz = coo.nnz();
            let x = Matrix::rand(n, d, &mut wrng);
            println!(
                "\nworkload: {n}×{n} {pat_name} matrix, nnz={nnz} ({:.2}%), dense width {d}",
                coo.density() * 100.0
            );

            section("SpMM per format: alloc vs workspace (`_into`) vs transpose");
            let base = SparseMatrix::Coo(coo.clone());
            for &fmtc in &ALL_FORMATS {
                let Ok(m) = base.convert(fmtc) else {
                    println!(
                        "{:<44} infeasible (storage budget)",
                        format!("spmm/{}/{pat_name}/{n}x{d}", fmtc.name())
                    );
                    continue;
                };
                let name = fmtc.name();
                let r = bench(&format!("spmm/{name}/{pat_name}/{n}x{d}"), 2, 7, || m.spmm(&x));
                let mut out = Matrix::zeros(n, d);
                let r_into = bench(&format!("spmm_into/{name}/{pat_name}/{n}x{d}"), 2, 7, || {
                    m.spmm_into(&x, &mut out)
                });
                let mut out_t = Matrix::zeros(n, d);
                let r_t = bench(&format!("spmm_t_into/{name}/{pat_name}/{n}x{d}"), 2, 7, || {
                    m.spmm_t_into(&x, &mut out_t)
                });
                let (ac, ab) = count_allocs(|| m.spmm(&x));
                let (ac_into, ab_into) = count_allocs(|| m.spmm_into(&x, &mut out));
                let gflops = 2.0 * nnz as f64 * d as f64 / r.median_s / 1e9;
                println!(
                    "{:<44} {gflops:.2} GFLOP/s | allocs/op {ac} ({ab} B) -> into {ac_into} ({ab_into} B)",
                    format!("  throughput/{name}")
                );
                let mut fields = vec![
                    ("format", Json::Str(name.to_string())),
                    ("pattern", Json::Str(pat_name.to_string())),
                    ("n", Json::Num(n as f64)),
                    ("d", Json::Num(d as f64)),
                    ("nnz", Json::Num(nnz as f64)),
                    ("spmm_ns", Json::Num(r.median_s * 1e9)),
                    ("spmm_into_ns", Json::Num(r_into.median_s * 1e9)),
                    ("spmm_t_into_ns", Json::Num(r_t.median_s * 1e9)),
                    ("gflops", Json::Num(gflops)),
                    ("allocs_per_op", Json::Num(ac as f64)),
                    ("alloc_bytes_per_op", Json::Num(ab as f64)),
                    ("allocs_per_op_into", Json::Num(ac_into as f64)),
                    ("alloc_bytes_per_op_into", Json::Num(ab_into as f64)),
                ];
                // Record before/after against the previous run of this
                // bench, keyed by (format, pattern, n, d).
                let key = (name.to_string(), pat_name.to_string(), n as u64, d as u64);
                if let Some(&(prev_into, prev_t)) = baseline.get(&key) {
                    if prev_into > 0.0 {
                        let speedup = prev_into / (r_into.median_s * 1e9);
                        println!(
                            "{:<44} {prev_into:.0} ns -> {:.0} ns ({speedup:.2}x)",
                            format!("  vs-baseline/{name}/into"),
                            r_into.median_s * 1e9
                        );
                        fields.push(("prev_spmm_into_ns", Json::Num(prev_into)));
                        fields.push(("speedup_into", Json::Num(speedup)));
                    }
                    if prev_t > 0.0 {
                        let speedup_t = prev_t / (r_t.median_s * 1e9);
                        fields.push(("prev_spmm_t_into_ns", Json::Num(prev_t)));
                        fields.push(("speedup_t_into", Json::Num(speedup_t)));
                    }
                }
                records.push(Json::obj(fields));
            }
        }
    }

    // Secondary hot paths (printed only; stable enough not to track in JSON).
    let n = 4096;
    let coo = gen_matrix(&mut rng, n, 0.01, MatrixPattern::PowerLaw);
    let base = SparseMatrix::Coo(coo.clone());

    section("format conversions (per-layer switch cost)");
    for &fmtc in &[Format::Csr, Format::Csc, Format::Bsr, Format::Lil, Format::Dok] {
        bench(&format!("convert/COO->{}", fmtc.name()), 1, 5, || {
            base.convert(fmtc).unwrap()
        });
    }
    let csr = base.convert(Format::Csr).unwrap();
    bench("convert/CSR->CSC (direct path)", 1, 5, || csr.convert(Format::Csc).unwrap());
    bench("transpose/CSR (direct structural path)", 1, 5, || csr.transpose().unwrap());
    bench("convert/to_coo_view (engine decide path)", 1, 5, || csr.to_coo());

    section("feature extraction (Table-2, parallel)");
    bench("features/extract_19", 2, 7, || extract_features(&coo));

    section("dense GEMM (tensor substrate)");
    for &(gn, gk, gm) in &[(512usize, 512usize, 512usize), (2048, 64, 64)] {
        let a = Matrix::rand(gn, gk, &mut rng);
        let b = Matrix::rand(gk, gm, &mut rng);
        let r = bench(&format!("gemm/{gn}x{gk}x{gm}"), 1, 5, || a.matmul(&b));
        let gflops = 2.0 * (gn * gk * gm) as f64 / r.median_s / 1e9;
        println!("{:<44} {gflops:.2} GFLOP/s", "  throughput");
    }

    section("sparsify dense activation (GCN H1 path)");
    let h1 = {
        let mut m = Matrix::rand(n, 16, &mut rng);
        for v in m.data.iter_mut() {
            if *v < 0.5 {
                *v = 0.0;
            }
        }
        m
    };
    bench("coo/from_dense (n x 16, ~50% dense)", 1, 5, || {
        gnn_spmm::sparse::Coo::from_dense(&h1)
    });

    // Machine-readable dump for the perf trajectory.
    let threads = gnn_spmm::util::parallel::num_threads();
    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_hotpath".to_string())),
        ("threads", Json::Num(threads as f64)),
        ("unit", Json::Str("ns per op (median); allocation calls/bytes per op".to_string())),
        ("spmm", Json::Arr(records)),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
