//! §Perf micro-benchmarks: the hot paths the whole system sits on —
//! per-format SpMM kernels (allocating and `_into` workspace variants, both
//! directions), format conversions, feature extraction and the dense GEMM.
//! Used by the optimization pass in EXPERIMENTS.md §Perf.
//!
//! Workloads cover **uniform and skewed (power-law)** non-zero placements:
//! the power-law inputs are where nnz-balanced scheduling (see
//! `util::parallel::indptr_span`) earns its keep — a count-based row split
//! hands one worker all the hub rows.
//!
//! Besides the human-readable table, emits a machine-readable
//! `BENCH_spmm.json` (ns/op and allocation counts per format × pattern ×
//! size) so subsequent PRs have a perf trajectory to compare against. If a
//! previous `BENCH_spmm.json` exists at the output path it is loaded first
//! and every record gains `prev_*_ns` + `speedup_*` fields (old/new) — the
//! before/after comparison is recorded in the file itself. Output path
//! overridable via `GNN_SPMM_BENCH_OUT`.
//!
//! Allocation counts come from a counting global allocator. With the
//! persistent worker pool, the `_into` kernels are allocation-free in
//! steady state for every format — the pool dispatches on parked workers,
//! scatter kernels reuse grow-only scratch, and LIL binary-searches a
//! cached per-matrix nnz prefix-sum instead of materializing a range list —
//! so `allocs_per_op_into` should read 0 after warmup.

use gnn_spmm::bench::{bench, count_allocs, section, CountingAlloc};
use gnn_spmm::features::extract_features;
use gnn_spmm::graph::{gen_matrix, MatrixPattern};
use gnn_spmm::predictor::{train_predictor, train_schedule_heads, TrainingCorpus};
use gnn_spmm::sparse::{Format, Schedule, SparseMatrix, ALL_FORMATS};
use gnn_spmm::tensor::Matrix;
use gnn_spmm::util::json::Json;
use gnn_spmm::util::rng::Rng;
use std::collections::HashMap;

// Shared counting allocator (rules live in `bench::alloc_counter`): the
// JSON reports the per-op allocation cost of each kernel variant. The
// counters are gated inside `count_allocs`, so the timing sections run
// under uninstrumented conditions.
#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// (format, pattern, n, d) → (spmm_into_ns, spmm_t_into_ns) from a previous
/// run's JSON, if one exists at `path`. Records predating the `pattern`
/// field (PR-1 baseline) are treated as power-law — that is what the old
/// bench generated.
fn load_baseline(path: &str) -> HashMap<(String, String, u64, u64), (f64, f64)> {
    let mut map = HashMap::new();
    let Ok(text) = std::fs::read_to_string(path) else {
        return map;
    };
    let Ok(doc) = Json::parse(&text) else {
        return map;
    };
    let Some(arr) = doc.get("spmm").and_then(|v| v.as_arr()) else {
        return map;
    };
    for rec in arr {
        let fmt = rec.get("format").and_then(|v| v.as_str()).unwrap_or("");
        let pattern = rec.get("pattern").and_then(|v| v.as_str()).unwrap_or("powerlaw");
        let n = rec.get("n").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        let d = rec.get("d").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        let into_ns = rec.get("spmm_into_ns").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let t_ns = rec.get("spmm_t_into_ns").and_then(|v| v.as_f64()).unwrap_or(0.0);
        map.insert((fmt.to_string(), pattern.to_string(), n, d), (into_ns, t_ns));
    }
    map
}

fn main() {
    let mut rng = Rng::new(0x9E7F);
    let mut records: Vec<Json> = Vec::new();

    let out_path = std::env::var("GNN_SPMM_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_spmm.json".to_string());
    let baseline = load_baseline(&out_path);
    if !baseline.is_empty() {
        println!("loaded {} baseline records from {out_path}", baseline.len());
    }

    // Multi-output schedule predictor for the predicted-rank trajectory:
    // small corpus, trained once — the bench then scores its schedule pick
    // against the measured candidate sweep on every workload.
    section("training schedule heads (predicted-rank tracking)");
    let corpus = TrainingCorpus::build(20, 48, 128, 8, 1, 0x5EED);
    let mut predictor = train_predictor(&corpus, 1.0, 7);
    train_schedule_heads(&corpus, &mut predictor);
    let mut rank_top2 = 0usize;
    let mut rank_total = 0usize;

    let patterns = [
        (MatrixPattern::Uniform, "uniform"),
        (MatrixPattern::PowerLaw, "powerlaw"),
    ];
    // Feature widths span the tile spectrum (4–64): d=4 is where narrow
    // tiles and serial schedules win, d=64 is deep-tile territory.
    for &(n, d, density) in
        &[(512usize, 4usize, 0.05f64), (1024, 16, 0.02), (2048, 32, 0.01), (4096, 64, 0.01)]
    {
        for (pi, &(pattern, pat_name)) in patterns.iter().enumerate() {
            // Fresh per-workload RNG so each (n, d, pattern) matrix is
            // reproducible regardless of which workloads a bench version
            // runs or in what order — prev_*/speedup_* comparisons across
            // runs are then apples-to-apples.
            let mut wrng = Rng::new(0x9E7F ^ ((n as u64) << 24) ^ ((d as u64) << 8) ^ pi as u64);
            let coo = gen_matrix(&mut wrng, n, density, pattern);
            let nnz = coo.nnz();
            let x = Matrix::rand(n, d, &mut wrng);
            println!(
                "\nworkload: {n}×{n} {pat_name} matrix, nnz={nnz} ({:.2}%), dense width {d}",
                coo.density() * 100.0
            );
            let (_, predicted_sched, _) = predictor.predict_plan_with_margin(&coo);
            println!("  predicted schedule: {}", predicted_sched.label());

            section("SpMM per format: alloc vs workspace (`_into`) vs transpose");
            let base = SparseMatrix::Coo(coo.clone());
            for &fmtc in &ALL_FORMATS {
                let Ok(m) = base.convert(fmtc) else {
                    println!(
                        "{:<44} infeasible (storage budget)",
                        format!("spmm/{}/{pat_name}/{n}x{d}", fmtc.name())
                    );
                    continue;
                };
                let name = fmtc.name();
                let r = bench(&format!("spmm/{name}/{pat_name}/{n}x{d}"), 2, 7, || m.spmm(&x));
                let mut out = Matrix::zeros(n, d);
                let r_into = bench(&format!("spmm_into/{name}/{pat_name}/{n}x{d}"), 2, 7, || {
                    m.spmm_into(&x, &mut out)
                });
                let mut out_t = Matrix::zeros(n, d);
                let r_t = bench(&format!("spmm_t_into/{name}/{pat_name}/{n}x{d}"), 2, 7, || {
                    m.spmm_t_into(&x, &mut out_t)
                });
                let (ac, ab) = count_allocs(|| m.spmm(&x));
                let (ac_into, ab_into) = count_allocs(|| m.spmm_into(&x, &mut out));

                // Schedule sweep: every candidate timed on the `_into` hot
                // path, plus the predictor's pick (scored by rank among the
                // measured candidates — rank 1 = it chose the fastest).
                let mut sched_records: Vec<Json> = Vec::new();
                let mut sched_times: Vec<(Schedule, f64)> = Vec::new();
                for &sched in &Schedule::CANDIDATES {
                    let rs = bench(
                        &format!("spmm_into/{name}/{pat_name}/{n}x{d}/{}", sched.label()),
                        1,
                        5,
                        || m.spmm_into_with(&x, &mut out, sched),
                    );
                    sched_times.push((sched, rs.median_s));
                    sched_records.push(Json::obj(vec![
                        ("schedule", Json::Str(sched.label())),
                        ("spmm_into_ns", Json::Num(rs.median_s * 1e9)),
                    ]));
                    if fmtc == Format::Lil {
                        // PR-2 regression probe: LIL's forward kernel must
                        // stay allocation-free in steady state (cached nnz
                        // prefix-sum, no per-multiply range list) under
                        // every schedule variant.
                        let (lc, lb) = count_allocs(|| m.spmm_into_with(&x, &mut out, sched));
                        assert_eq!(
                            (lc, lb),
                            (0, 0),
                            "LIL spmm_into allocated under schedule {}",
                            sched.label()
                        );
                    }
                }
                let predicted_s = sched_times
                    .iter()
                    .find(|(s, _)| *s == predicted_sched)
                    .map(|&(_, t)| t)
                    .unwrap_or_else(|| {
                        // The heads can compose a plan outside the candidate
                        // set (16 combinations vs 4 candidates): time it so
                        // the rank is against real measurements.
                        let rs = bench(
                            &format!(
                                "spmm_into/{name}/{pat_name}/{n}x{d}/{} (predicted)",
                                predicted_sched.label()
                            ),
                            1,
                            5,
                            || m.spmm_into_with(&x, &mut out, predicted_sched),
                        );
                        rs.median_s
                    });
                let predicted_rank = 1 + sched_times
                    .iter()
                    .filter(|&&(s, t)| s != predicted_sched && t < predicted_s)
                    .count();
                rank_total += 1;
                if predicted_rank <= 2 {
                    rank_top2 += 1;
                }

                let gflops = 2.0 * nnz as f64 * d as f64 / r.median_s / 1e9;
                println!(
                    "{:<44} {gflops:.2} GFLOP/s | allocs/op {ac} ({ab} B) -> into {ac_into} ({ab_into} B)",
                    format!("  throughput/{name}")
                );
                let mut fields = vec![
                    ("format", Json::Str(name.to_string())),
                    ("pattern", Json::Str(pat_name.to_string())),
                    ("n", Json::Num(n as f64)),
                    ("d", Json::Num(d as f64)),
                    ("nnz", Json::Num(nnz as f64)),
                    ("spmm_ns", Json::Num(r.median_s * 1e9)),
                    ("spmm_into_ns", Json::Num(r_into.median_s * 1e9)),
                    ("spmm_t_into_ns", Json::Num(r_t.median_s * 1e9)),
                    ("gflops", Json::Num(gflops)),
                    ("allocs_per_op", Json::Num(ac as f64)),
                    ("alloc_bytes_per_op", Json::Num(ab as f64)),
                    ("allocs_per_op_into", Json::Num(ac_into as f64)),
                    ("alloc_bytes_per_op_into", Json::Num(ab_into as f64)),
                    ("schedules", Json::Arr(sched_records)),
                    ("predicted_schedule", Json::Str(predicted_sched.label())),
                    ("predicted_rank", Json::Num(predicted_rank as f64)),
                ];
                // Record before/after against the previous run of this
                // bench, keyed by (format, pattern, n, d).
                let key = (name.to_string(), pat_name.to_string(), n as u64, d as u64);
                if let Some(&(prev_into, prev_t)) = baseline.get(&key) {
                    if prev_into > 0.0 {
                        let speedup = prev_into / (r_into.median_s * 1e9);
                        println!(
                            "{:<44} {prev_into:.0} ns -> {:.0} ns ({speedup:.2}x)",
                            format!("  vs-baseline/{name}/into"),
                            r_into.median_s * 1e9
                        );
                        fields.push(("prev_spmm_into_ns", Json::Num(prev_into)));
                        fields.push(("speedup_into", Json::Num(speedup)));
                    }
                    if prev_t > 0.0 {
                        let speedup_t = prev_t / (r_t.median_s * 1e9);
                        fields.push(("prev_spmm_t_into_ns", Json::Num(prev_t)));
                        fields.push(("speedup_t_into", Json::Num(speedup_t)));
                    }
                }
                records.push(Json::obj(fields));
            }
        }
    }

    // Secondary hot paths (printed only; stable enough not to track in JSON).
    let n = 4096;
    let coo = gen_matrix(&mut rng, n, 0.01, MatrixPattern::PowerLaw);
    let base = SparseMatrix::Coo(coo.clone());

    section("format conversions (per-layer switch cost)");
    for &fmtc in &[Format::Csr, Format::Csc, Format::Bsr, Format::Lil, Format::Dok] {
        bench(&format!("convert/COO->{}", fmtc.name()), 1, 5, || {
            base.convert(fmtc).unwrap()
        });
    }
    let csr = base.convert(Format::Csr).unwrap();
    bench("convert/CSR->CSC (direct path)", 1, 5, || csr.convert(Format::Csc).unwrap());
    bench("transpose/CSR (direct structural path)", 1, 5, || csr.transpose().unwrap());
    bench("convert/to_coo_view (engine decide path)", 1, 5, || csr.to_coo());

    section("feature extraction (Table-2, parallel)");
    bench("features/extract_19", 2, 7, || extract_features(&coo));

    section("dense GEMM (tensor substrate)");
    for &(gn, gk, gm) in &[(512usize, 512usize, 512usize), (2048, 64, 64)] {
        let a = Matrix::rand(gn, gk, &mut rng);
        let b = Matrix::rand(gk, gm, &mut rng);
        let r = bench(&format!("gemm/{gn}x{gk}x{gm}"), 1, 5, || a.matmul(&b));
        let gflops = 2.0 * (gn * gk * gm) as f64 / r.median_s / 1e9;
        println!("{:<44} {gflops:.2} GFLOP/s", "  throughput");
    }

    section("sparsify dense activation (GCN H1 path)");
    let h1 = {
        let mut m = Matrix::rand(n, 16, &mut rng);
        for v in m.data.iter_mut() {
            if *v < 0.5 {
                *v = 0.0;
            }
        }
        m
    };
    bench("coo/from_dense (n x 16, ~50% dense)", 1, 5, || {
        gnn_spmm::sparse::Coo::from_dense(&h1)
    });

    // Machine-readable dump for the perf trajectory.
    let threads = gnn_spmm::util::parallel::num_threads();
    let top2_rate = if rank_total > 0 { rank_top2 as f64 / rank_total as f64 } else { 0.0 };
    println!(
        "\npredicted schedule in measured top-2: {rank_top2}/{rank_total} ({:.0}%)",
        top2_rate * 100.0
    );
    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_hotpath".to_string())),
        ("threads", Json::Num(threads as f64)),
        ("unit", Json::Str("ns per op (median); allocation calls/bytes per op".to_string())),
        ("predicted_top2_rate", Json::Num(top2_rate)),
        ("spmm", Json::Arr(records)),
    ]);
    match std::fs::write(&out_path, doc.to_string()) {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
