//! §Perf micro-benchmarks: the hot paths the whole system sits on —
//! per-format SpMM kernels, format conversions, feature extraction and the
//! dense GEMM. Used by the optimization pass in EXPERIMENTS.md §Perf.
//!
//! A throughput summary (GFLOP/s for SpMM ≈ 2·nnz·d / t) is printed so the
//! numbers can be compared against the machine's practical roofline.

use gnn_spmm::bench::{bench, section};
use gnn_spmm::features::extract_features;
use gnn_spmm::graph::{gen_matrix, MatrixPattern};
use gnn_spmm::sparse::{Format, SparseMatrix, ALL_FORMATS};
use gnn_spmm::tensor::Matrix;
use gnn_spmm::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0x9E7F);
    let n = 4096;
    let d = 64;
    let density = 0.01;
    let coo = gen_matrix(&mut rng, n, density, MatrixPattern::PowerLaw);
    let nnz = coo.nnz();
    let x = Matrix::rand(n, d, &mut rng);
    println!(
        "workload: {n}×{n} power-law matrix, nnz={nnz} ({:.2}%), dense width {d}",
        coo.density() * 100.0
    );

    section("SpMM per format (the paper's kernel set)");
    let base = SparseMatrix::Coo(coo.clone());
    for &fmtc in &ALL_FORMATS {
        let Ok(m) = base.convert(fmtc) else {
            println!("{:<44} infeasible (storage budget)", format!("spmm/{}", fmtc.name()));
            continue;
        };
        let r = bench(&format!("spmm/{}", fmtc.name()), 2, 7, || m.spmm(&x));
        let gflops = 2.0 * nnz as f64 * d as f64 / r.median_s / 1e9;
        println!("{:<44} {gflops:.2} GFLOP/s", format!("  throughput/{}", fmtc.name()));
    }

    section("format conversions (per-layer switch cost)");
    for &fmtc in &[Format::Csr, Format::Csc, Format::Bsr, Format::Lil, Format::Dok] {
        bench(&format!("convert/COO->{}", fmtc.name()), 1, 5, || {
            base.convert(fmtc).unwrap()
        });
    }
    let csr = base.convert(Format::Csr).unwrap();
    bench("convert/CSR->CSC (direct path)", 1, 5, || csr.convert(Format::Csc).unwrap());
    bench("convert/to_coo_view (engine decide path)", 1, 5, || csr.to_coo());

    section("feature extraction (Table-2, parallel)");
    bench("features/extract_19", 2, 7, || extract_features(&coo));

    section("dense GEMM (tensor substrate)");
    for &(gn, gk, gm) in &[(512usize, 512usize, 512usize), (2048, 64, 64)] {
        let a = Matrix::rand(gn, gk, &mut rng);
        let b = Matrix::rand(gk, gm, &mut rng);
        let r = bench(&format!("gemm/{gn}x{gk}x{gm}"), 1, 5, || a.matmul(&b));
        let gflops = 2.0 * (gn * gk * gm) as f64 / r.median_s / 1e9;
        println!("{:<44} {gflops:.2} GFLOP/s", "  throughput");
    }

    section("sparsify dense activation (GCN H1 path)");
    let h1 = {
        let mut m = Matrix::rand(n, 16, &mut rng);
        for v in m.data.iter_mut() {
            if *v < 0.5 {
                *v = 0.0;
            }
        }
        m
    };
    bench("coo/from_dense (n x 16, ~50% dense)", 1, 5, || {
        gnn_spmm::sparse::Coo::from_dense(&h1)
    });
}
