//! Regenerates paper Fig. 7: leave-one-out feature importance (top features
//! by accuracy drop) plus the GBDT's gain importance.
use gnn_spmm::coordinator::{experiments, Workbench};

fn main() -> anyhow::Result<()> {
    let wb = Workbench::bench(0xE8);
    let t = experiments::fig7(&wb);
    experiments::print_table("Fig 7 — feature importance (top-8 = first 8 rows)", &t);
    t.write_file("results/fig7.csv")?;
    Ok(())
}
