//! Regenerates paper Table 1: dataset statistics.
use gnn_spmm::coordinator::{experiments, Workbench};

fn main() -> anyhow::Result<()> {
    let wb = Workbench::bench(0xE8);
    let t = experiments::table1(&wb);
    experiments::print_table("Table 1 — dataset statistics (laptop scale)", &t);
    t.write_file("results/table1.csv")?;
    Ok(())
}
