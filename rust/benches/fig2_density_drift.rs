//! Regenerates paper Fig. 2: matrix density growth as the GNN iterates —
//! k-hop effective adjacency density + GCN H1 activation density per epoch.
use gnn_spmm::coordinator::{experiments, Workbench};

fn main() -> anyhow::Result<()> {
    let wb = Workbench::bench(0xE8);
    let t = experiments::fig2(&wb, "CoraFull", 10);
    experiments::print_table("Fig 2 — density drift over GNN iteration (CoraFull)", &t);
    t.write_file("results/fig2.csv")?;
    Ok(())
}
