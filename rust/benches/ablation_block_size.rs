//! Ablation (DESIGN.md §Hardware-Adaptation): BSR block-size sweep.
//!
//! On TPU the paper's format-selection decision collapses to *block-size
//! selection* for the MXU-oriented BSR layout. This bench sweeps block
//! sizes on graph-like and block-structured matrices, reporting:
//!   * CPU SpMM time (rust kernel),
//!   * block fill (the MXU utilization proxy: fraction of streamed block
//!     slots that hold real non-zeros),
//!   * the VMEM footprint of one grid step of the Pallas kernel
//!     (blocks panel + X panel + accumulator).

use gnn_spmm::bench::{bench, section};
use gnn_spmm::graph::{gen_matrix, MatrixPattern};
use gnn_spmm::sparse::{Bsr, Coo};
use gnn_spmm::tensor::Matrix;
use gnn_spmm::util::csv::CsvTable;
use gnn_spmm::util::rng::Rng;

fn sweep(name: &str, coo: &Coo, d: usize, rng: &mut Rng, out: &mut CsvTable) {
    section(&format!("{name} (nnz={}, density {:.2}%)", coo.nnz(), coo.density() * 100.0));
    let x = Matrix::rand(coo.cols, d, rng);
    for &bs in &[4usize, 8, 16, 32, 64, 128] {
        if bs > coo.rows {
            continue;
        }
        let bsr = Bsr::from_coo(coo, bs);
        let r = bench(&format!("{name}/bs={bs}"), 1, 5, || bsr.spmm(&x));
        let fill = bsr.block_fill();
        // VMEM model per grid step: max row-block span × (block + X panel)
        // + accumulator, in f32.
        let nrb = coo.rows.div_ceil(bs);
        let max_span = (0..nrb)
            .map(|i| bsr.indptr[i + 1] - bsr.indptr[i])
            .max()
            .unwrap_or(0);
        let vmem_bytes = max_span * bs * bs * 4 + max_span * bs * d * 4 + bs * d * 4;
        println!(
            "  bs={bs:<4} blocks={:<6} fill={:.1}%  est. VMEM/step={:.1} KiB  (MXU-util proxy = fill)",
            bsr.n_blocks(),
            fill * 100.0,
            vmem_bytes as f64 / 1024.0
        );
        out.push([
            name.to_string(),
            bs.to_string(),
            format!("{:.6}", r.median_s),
            format!("{:.4}", fill),
            vmem_bytes.to_string(),
        ]);
    }
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0xAB1A);
    let mut out = CsvTable::new([
        "workload",
        "block_size",
        "spmm_median_s",
        "block_fill",
        "vmem_bytes_per_step",
    ]);

    // Graph-like scattered pattern: small blocks win (fill collapses fast).
    let graph = gen_matrix(&mut rng, 2048, 0.005, MatrixPattern::PowerLaw);
    sweep("powerlaw_graph", &graph, 32, &mut rng, &mut out);

    // Block-structured pattern: larger blocks win up to the native size.
    let blocky = gen_matrix(&mut rng, 2048, 0.02, MatrixPattern::Block);
    sweep("block_structured", &blocky, 32, &mut rng, &mut out);

    // Banded pattern.
    let banded = gen_matrix(&mut rng, 2048, 0.01, MatrixPattern::Banded);
    sweep("banded", &banded, 32, &mut rng, &mut out);

    out.write_file("results/ablation_block_size.csv")?;
    println!("\nwrote results/ablation_block_size.csv");
    Ok(())
}
