//! Regenerates paper Fig. 3: speedup over COO when only the *layer-1
//! output* (H1) uses a given format, on CoraFull (a) and PubmedFull (b).
use gnn_spmm::coordinator::{experiments, Workbench};
use gnn_spmm::gnn::TrainConfig;

fn main() -> anyhow::Result<()> {
    let wb = Workbench::bench(0xE8);
    let cfg = TrainConfig { epochs: 5, ..Default::default() };
    let t = experiments::fig3(&wb, &cfg, 2);
    experiments::print_table("Fig 3 — layer-1 output format vs COO", &t);
    t.write_file("results/fig3.csv")?;
    Ok(())
}
