//! Regenerates paper Fig. 9: predicted-policy performance relative to the
//! exhaustive-profiling oracle (paper: 89% of oracle on average).
use gnn_spmm::coordinator::{experiments, Workbench};
use gnn_spmm::gnn::TrainConfig;
use gnn_spmm::util::stats;

fn main() -> anyhow::Result<()> {
    let wb = Workbench::bench(0xE8);
    let cfg = TrainConfig { epochs: 5, ..Default::default() };
    let t = experiments::fig9(&wb, &cfg, 2);
    experiments::print_table("Fig 9 — % of oracle performance", &t);
    t.write_file("results/fig9.csv")?;
    let pcts: Vec<f64> = t.rows.iter().map(|r| r[4].parse().unwrap()).collect();
    println!("\naverage: {:.1}% of oracle (paper: 89%)", stats::mean(&pcts));
    Ok(())
}
