//! Streaming-ingestion bench: WAL append throughput vs fsync batching,
//! compaction latency, and recovery replay rate (DESIGN.md
//! §Streaming-Durability).
//!
//! Three measurements, one JSON-lines record each (`BENCH_stream.json`):
//!
//! * `stream/wal_append` — ingest a fixed op stream at each `sync_every`
//!   in {1, 8, 64}: per-op fsync is the durability floor, batching is the
//!   throughput knob (unsynced ops are unacknowledged by construction, so
//!   batching trades ack latency, never safety).
//! * `stream/compact` — time one full compaction cycle (freeze → merge →
//!   validate → renormalize touched rows → checkpoint → publish) over the
//!   accumulated delta.
//! * `stream/recovery` — drop the store with a full WAL tail and time the
//!   re-open (checkpoint load + tail replay into a fresh overlay).
//!
//! Gates: recovery must replay every op it acknowledged, compaction must
//! drain the overlay to zero pending edits, and batched fsync must not
//! fall below half the per-op-fsync throughput (batching can only help;
//! the margin absorbs tmpfs noise where fsync is nearly free).

use gnn_spmm::graph::stream::{EdgeOp, StreamConfig, StreamStore};
use gnn_spmm::util::json::Json;
use gnn_spmm::util::rng::Rng;
use std::time::Instant;

const N_NODES: usize = 256;
const N_OPS: usize = 2000;

/// Deterministic mixed op stream (same shape as `examples/stream_ingest`):
/// ~20% deletes, ~20% reweights, the rest inserts.
fn scripted_ops(n: usize, count: usize, seed: u64) -> Vec<EdgeOp> {
    let mut rng = Rng::new(seed);
    let mut present: Vec<(u32, u32)> = Vec::new();
    let mut ops = Vec::with_capacity(count);
    for _ in 0..count {
        let roll = rng.next_f64();
        let op = if roll < 0.2 && !present.is_empty() {
            let i = rng.gen_range(present.len());
            let (src, dst) = present.swap_remove(i);
            EdgeOp::Delete { src, dst }
        } else if roll < 0.4 && !present.is_empty() {
            let i = rng.gen_range(present.len());
            let (src, dst) = present[i];
            EdgeOp::Reweight { src, dst, w: rng.uniform(0.1, 4.0) as f32 }
        } else {
            let src = rng.gen_range(n) as u32;
            let dst = rng.gen_range(n) as u32;
            if !present.contains(&(src, dst)) {
                present.push((src, dst));
            }
            EdgeOp::Insert { src, dst, w: rng.uniform(0.1, 4.0) as f32 }
        };
        ops.push(op);
    }
    ops
}

fn main() {
    let out_path = std::env::var("GNN_SPMM_BENCH_STREAM_OUT")
        .unwrap_or_else(|_| "BENCH_stream.json".to_string());
    let base = std::env::temp_dir().join(format!("bench_stream_{}", std::process::id()));
    let ops = scripted_ops(N_NODES, N_OPS, 0xBEEF);
    let mut lines: Vec<String> = Vec::new();

    // ── WAL append throughput vs fsync batching ─────────────────────────
    let mut ops_per_sec_by_sync: Vec<(usize, f64)> = Vec::new();
    for &sync_every in &[1usize, 8, 64] {
        let dir = base.join(format!("wal_{sync_every}"));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = StreamConfig::new(&dir, N_NODES);
        cfg.sync_every = sync_every;
        cfg.compact_every = usize::MAX; // isolate the WAL path
        let store = StreamStore::open(cfg).expect("open");
        let t0 = Instant::now();
        for op in &ops {
            store.ingest(*op).expect("ingest");
        }
        store.flush().expect("flush");
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let ops_per_sec = N_OPS as f64 / secs;
        assert_eq!(store.acked(), N_OPS as u64, "every op must be acknowledged after flush");
        println!(
            "wal append sync_every={sync_every}: {ops_per_sec:.0} ops/s ({:.2} ms total)",
            secs * 1e3
        );
        ops_per_sec_by_sync.push((sync_every, ops_per_sec));
        lines.push(
            Json::obj(vec![
                ("name", Json::Str("stream/wal_append".to_string())),
                ("nodes", Json::Num(N_NODES as f64)),
                ("ops", Json::Num(N_OPS as f64)),
                ("sync_every", Json::Num(sync_every as f64)),
                ("ops_per_sec", Json::Num(ops_per_sec)),
            ])
            .to_string(),
        );
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }
    let per_op = ops_per_sec_by_sync[0].1;
    let batched = ops_per_sec_by_sync.last().unwrap().1;
    assert!(
        batched >= 0.5 * per_op,
        "fsync batching regressed throughput (sync_every=1: {per_op:.0} ops/s, \
         sync_every=64: {batched:.0} ops/s)"
    );
    println!("  fsync batching 1→64: ×{:.2}", batched / per_op);

    // ── Compaction latency + recovery replay rate ───────────────────────
    let dir = base.join("compact");
    let _ = std::fs::remove_dir_all(&dir);
    let mut cfg = StreamConfig::new(&dir, N_NODES);
    cfg.sync_every = 64;
    cfg.compact_every = usize::MAX; // compaction driven explicitly below
    let store = StreamStore::open(cfg.clone()).expect("open");
    for op in &ops {
        store.ingest(*op).expect("ingest");
    }
    store.flush().expect("flush");

    // Recovery first, while the WAL tail still holds the full stream.
    drop(store);
    let t0 = Instant::now();
    let store = StreamStore::open(cfg.clone()).expect("recovery open");
    let recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
    let st = store.stats();
    let replayed = st.applied - st.published_seq;
    assert_eq!(replayed, N_OPS as u64, "recovery must replay the full WAL tail");
    assert_eq!(st.acked, N_OPS as u64, "recovery must keep every acknowledged op");
    let replay_per_sec = replayed as f64 / (recovery_ms / 1e3).max(1e-9);
    println!("recovery: {replayed} ops replayed in {recovery_ms:.2} ms ({replay_per_sec:.0} ops/s)");
    lines.push(
        Json::obj(vec![
            ("name", Json::Str("stream/recovery".to_string())),
            ("nodes", Json::Num(N_NODES as f64)),
            ("replayed", Json::Num(replayed as f64)),
            ("recovery_ms", Json::Num(recovery_ms)),
            ("replay_ops_per_sec", Json::Num(replay_per_sec)),
        ])
        .to_string(),
    );

    let t0 = Instant::now();
    let stats = store.compact_once().expect("compact");
    let compact_ms = t0.elapsed().as_secs_f64() * 1e3;
    let after = store.stats();
    assert_eq!(after.pending_edits, 0, "compaction must drain the overlay");
    assert_eq!(after.published_seq, N_OPS as u64, "published snapshot must cover the stream");
    println!(
        "compact: {} edits over {} rows in {compact_ms:.2} ms (epoch v{})",
        stats.merged_edits, stats.touched_rows, stats.version
    );
    lines.push(
        Json::obj(vec![
            ("name", Json::Str("stream/compact".to_string())),
            ("nodes", Json::Num(N_NODES as f64)),
            ("merged_edits", Json::Num(stats.merged_edits as f64)),
            ("touched_rows", Json::Num(stats.touched_rows as f64)),
            ("compact_ms", Json::Num(compact_ms)),
        ])
        .to_string(),
    );
    drop(store);
    let _ = std::fs::remove_dir_all(&base);

    let body = lines.join("\n") + "\n";
    match std::fs::write(&out_path, &body) {
        Ok(()) => println!("\nwrote {out_path} ({} records)", lines.len()),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
