//! Regenerates paper Fig. 1: best-performing static storage format per
//! dataset (GCN, whole-run, normalized vs COO).
use gnn_spmm::coordinator::{experiments, Workbench};
use gnn_spmm::gnn::TrainConfig;

fn main() -> anyhow::Result<()> {
    let wb = Workbench::bench(0xE8);
    let cfg = TrainConfig { epochs: 5, ..Default::default() };
    let t = experiments::fig1(&wb, &cfg, 2);
    experiments::print_table("Fig 1 — best static format per dataset (GCN)", &t);
    t.write_file("results/fig1.csv")?;
    // Paper-style summary: the winner per dataset.
    println!("\nbest format per dataset:");
    for row in t.rows.iter().filter(|r| r[4] == "true") {
        println!("  {:<12} {}  ({}x vs COO)", row[0], row[1], row[3]);
    }
    Ok(())
}
