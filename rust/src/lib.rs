//! # gnn-spmm
//!
//! Reproduction of *"Optimizing Sparse Matrix Multiplications for Graph
//! Neural Networks"* (Qiu, You, Wang — 2021) as a three-layer
//! rust + JAX + Pallas stack.
//!
//! The paper's contribution — choosing the sparse-matrix **storage format**
//! (and therefore the SpMM kernel) per GNN layer at runtime with a learned
//! predictor — lives in [`predictor`], built on top of:
//!
//! * [`sparse`] — seven storage formats (COO/CSR/CSC/DIA/BSR/DOK/LIL) with
//!   conversions and per-format parallel SpMM kernels,
//! * [`features`] — the paper's Table-2 matrix features (F1–F19),
//! * [`ml`] — a from-scratch ML stack: gradient-boosted trees (the paper's
//!   XGBoost), plus the CART / KNN / SVM / MLP / CNN baselines it compares to,
//! * [`gnn`] + [`tensor`] — five GNN architectures (GCN/GAT/RGCN/FiLM/EGC)
//!   with a full training loop,
//! * [`graph`] — dataset generators matching the paper's Table-1 workloads,
//! * [`runtime`] — the PJRT bridge that loads JAX/Pallas-AOT-compiled HLO
//!   artifacts so the dense compute runs through XLA,
//! * [`coordinator`] — the experiment/training orchestrator that performs
//!   per-layer format switching and collects the paper's metrics,
//! * [`serve`] — concurrent inference serving over trained models with
//!   epoch-swap snapshot isolation and a shared read-only decision cache.
//!
//! Support plumbing (offline build: no external crates beyond `xla`/`anyhow`)
//! is under [`util`], [`testing`] and [`bench`].

pub mod util;
pub mod testing;
pub mod sparse;
pub mod features;
pub mod ml;
pub mod tensor;
pub mod graph;
pub mod gnn;
pub mod predictor;
pub mod serve;
pub mod coordinator;
/// PJRT bridge — compiled only with `--features pjrt` (needs the image's
/// `xla` crate; the default offline build stays dependency-free).
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod bench;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
