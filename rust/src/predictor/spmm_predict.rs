//! The paper's §4.6 user-facing API: `SpMMPredict(matrix) → matrix` in the
//! predicted storage format. "The function takes as input a matrix object
//! and outputs a matrix object stored using the predicted storage format.
//! Depending on the matrix object type, the corresponding SpMM kernel will
//! be automatically chosen."

use super::training::TrainedPredictor;
use crate::sparse::SparseMatrix;

/// Re-store `matrix` in the format the predictor chooses for it. The
/// returned object dispatches the matching SpMM kernel via
/// [`SparseMatrix::spmm`]. Falls back to CSR if the predicted format cannot
/// represent the matrix (DIA budget).
pub fn spmm_predict(
    predictor: &TrainedPredictor,
    matrix: &SparseMatrix,
) -> SparseMatrix {
    let coo = matrix.to_coo();
    let fmt = predictor.predict(&coo);
    matrix
        .convert(fmt)
        .or_else(|_| matrix.convert(crate::sparse::Format::Csr))
        .expect("CSR conversion cannot fail")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_matrix, MatrixPattern};
    use crate::predictor::training::{train_predictor, TrainingCorpus};
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    #[test]
    fn returns_equivalent_matrix_in_predicted_format() {
        let corpus = TrainingCorpus::build(15, 48, 96, 8, 1, 0xCD);
        let pred = train_predictor(&corpus, 1.0, 7);
        let mut rng = Rng::new(3);
        let coo = gen_matrix(&mut rng, 80, 0.08, MatrixPattern::Uniform);
        let m = SparseMatrix::Coo(coo.clone());
        let out = spmm_predict(&pred, &m);
        // Same matrix, possibly different storage.
        assert_eq!(out.to_coo(), coo);
        // SpMM result is identical.
        let x = Matrix::rand(80, 4, &mut rng);
        assert!(out.spmm(&x).max_abs_diff(&m.spmm(&x)) < 1e-4);
    }
}
