//! Runtime [`FormatPolicy`] implementations: the learned GBDT predictor,
//! the exhaustive oracle, and the prior-work baselines (decision tree [27],
//! CNN [45, 24]) used by Table 3 / Fig. 11.

use super::labeler::{label_for, profile_formats};
use super::training::TrainedPredictor;
use crate::features::{extract_features, Normalizer};
use crate::gnn::engine::FormatPolicy;
use crate::ml::cnn::{thumbnail, Cnn};
use crate::ml::Classifier;
use crate::sparse::{Coo, Format, Schedule};
use crate::util::timer::Stopwatch;

/// Below this nnz the decision can never pay for its own feature
/// extraction (sub-millisecond SpMMs); keep the incumbent default. The
/// paper makes the same amortization argument for its <3% overhead claim.
pub const MIN_NNZ_TO_PREDICT: usize = 2048;

/// The paper's deployed predictor: feature extraction → normalize → GBDT.
/// Overheads are charged to the stopwatch (`feature_extract`, `predict`) so
/// end-to-end measurements include them, as in the paper.
pub struct PredictedPolicy {
    pub predictor: TrainedPredictor,
}

impl PredictedPolicy {
    pub fn new(predictor: TrainedPredictor) -> PredictedPolicy {
        PredictedPolicy { predictor }
    }
}

impl PredictedPolicy {
    /// Shared decide path: (format, calibrated margin), overheads charged.
    fn decide_inner(&mut self, coo: &Coo, sw: &mut Stopwatch) -> (Format, f64) {
        if coo.nnz() < MIN_NNZ_TO_PREDICT {
            // Tiny matrix: decision cost > any gain. The default is a
            // deliberate, fully-confident choice — cache it freely.
            return (Format::Coo, 1.0);
        }
        let raw = sw.phase("feature_extract", || extract_features(coo));
        sw.phase("predict", || {
            let x = self.predictor.norm.transform(&raw);
            let (label, margin) = self.predictor.model.predict_with_margin(&x);
            (Format::from_label(label), margin)
        })
    }
}

impl FormatPolicy for PredictedPolicy {
    fn decide(&mut self, coo: &Coo, _d: usize, sw: &mut Stopwatch) -> Format {
        self.decide_inner(coo, sw).0
    }

    /// The GBDT's softmax top-1 − top-2 gap rides along so the decision
    /// cache can bypass low-margin answers (predictor::cache).
    fn decide_for_slot_with_confidence(
        &mut self,
        _slot: &str,
        coo: &Coo,
        _d: usize,
        sw: &mut Stopwatch,
    ) -> (Format, f64) {
        self.decide_inner(coo, sw)
    }

    /// Full-plan prediction: one feature pass feeds both the format model
    /// and the multi-output schedule heads ([`TrainedPredictor::
    /// predict_plan_with_margin`]). A predictor without trained heads — or
    /// a matrix under the amortization floor — runs under the process-
    /// default schedule, exactly the format-only behavior.
    fn decide_plan_for_slot(
        &mut self,
        _slot: &str,
        coo: &Coo,
        _d: usize,
        sw: &mut Stopwatch,
    ) -> (Format, Schedule, f64) {
        if coo.nnz() < MIN_NNZ_TO_PREDICT {
            return (Format::Coo, Schedule::effective(), 1.0);
        }
        let raw = sw.phase("feature_extract", || crate::features::extract_features(coo));
        sw.phase("predict", || {
            let x = self.predictor.norm.transform(&raw);
            let (label, fmt_margin) = self.predictor.model.predict_with_margin(&x);
            let (sched, sched_margin) = match &self.predictor.schedule_heads {
                Some(heads) => heads.predict_with_margin(&x),
                None => (Schedule::effective(), 1.0),
            };
            (Format::from_label(label), sched, fmt_margin.min(sched_margin))
        })
    }

    fn policy_name(&self) -> String {
        "predicted-xgboost".to_string()
    }
}

/// Theoretically perfect selector (paper §6.3): exhaustively profiles all
/// formats at decision time. The profiling cost is *not* charged — the
/// oracle models a zero-overhead perfect predictor; only the chosen
/// format's conversions/SpMMs count.
pub struct OraclePolicy {
    /// Profiling repetitions per format.
    pub reps: usize,
    /// Eq-1 weight used to rank profiles.
    pub w: f64,
}

impl Default for OraclePolicy {
    fn default() -> Self {
        OraclePolicy { reps: 3, w: 1.0 }
    }
}

impl FormatPolicy for OraclePolicy {
    fn decide(&mut self, coo: &Coo, d: usize, sw: &mut Stopwatch) -> Format {
        // Charged to the dedicated `oracle_search` phase, which the trainer
        // SUBTRACTS from end-to-end time: the oracle models a perfect
        // zero-overhead predictor (paper §6.3).
        sw.phase("oracle_search", || {
            let profiles = profile_formats(coo, d, self.reps);
            label_for(&profiles, self.w)
        })
    }

    fn policy_name(&self) -> String {
        "oracle".to_string()
    }
}

/// Prior-work baseline: any tabular classifier over the Table-2 features
/// (decision tree [27], KNN, SVM, MLP — Fig. 11 / Table 3).
pub struct TabularModelPolicy<C: Classifier> {
    pub model: C,
    pub norm: Normalizer,
    pub label: &'static str,
}

impl<C: Classifier> FormatPolicy for TabularModelPolicy<C> {
    fn decide(&mut self, coo: &Coo, _d: usize, sw: &mut Stopwatch) -> Format {
        let raw = sw.phase("feature_extract", || extract_features(coo));
        sw.phase("predict", || {
            let x = self.norm.transform(&raw);
            Format::from_label(self.model.predict(&x).min(6))
        })
    }

    fn policy_name(&self) -> String {
        format!("predicted-{}", self.label)
    }
}

/// Prior-work baseline: CNN over the matrix density thumbnail ([45, 24]).
pub struct CnnPolicy {
    pub cnn: Cnn,
}

impl FormatPolicy for CnnPolicy {
    fn decide(&mut self, coo: &Coo, _d: usize, sw: &mut Stopwatch) -> Format {
        let img = sw.phase("feature_extract", || thumbnail(coo));
        sw.phase("predict", || Format::from_label(self.cnn.predict_image(&img).min(6)))
    }

    fn policy_name(&self) -> String {
        "predicted-cnn".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_matrix, MatrixPattern};
    use crate::predictor::training::TrainingCorpus;
    use crate::util::rng::Rng;

    #[test]
    fn oracle_picks_a_feasible_format() {
        let mut rng = Rng::new(1);
        let m = gen_matrix(&mut rng, 96, 0.05, MatrixPattern::Uniform);
        let mut oracle = OraclePolicy { reps: 1, w: 1.0 };
        let mut sw = Stopwatch::new();
        let fmt = oracle.decide(&m, 8, &mut sw);
        // The oracle's search cost lands in its dedicated phase (which the
        // trainer subtracts), never in the real-overhead phases.
        assert!(sw.total("oracle_search") > 0.0);
        assert_eq!(sw.total("feature_extract"), 0.0);
        assert_eq!(sw.total("predict"), 0.0);
        let _ = fmt;
    }

    #[test]
    fn predicted_policy_charges_overhead() {
        let corpus = TrainingCorpus::build(15, 48, 96, 8, 1, 0xAB);
        let pred = crate::predictor::training::train_predictor(&corpus, 1.0, 1);
        let mut policy = PredictedPolicy::new(pred);
        let mut rng = Rng::new(2);
        // Large enough to clear MIN_NNZ_TO_PREDICT.
        let m = gen_matrix(&mut rng, 512, 0.05, MatrixPattern::PowerLaw);
        assert!(m.nnz() >= MIN_NNZ_TO_PREDICT);
        let mut sw = Stopwatch::new();
        let _ = policy.decide(&m, 8, &mut sw);
        assert!(sw.total("feature_extract") > 0.0);
        assert!(sw.total("predict") > 0.0);
    }

    #[test]
    fn plan_prediction_uses_heads_and_charges_one_feature_pass() {
        use crate::gnn::engine::FormatPolicy;
        use crate::sparse::{Split, ThreadCap, Tile};
        let corpus = TrainingCorpus::build(15, 48, 96, 8, 1, 0xAD);
        let mut pred = crate::predictor::training::train_predictor(&corpus, 1.0, 1);
        crate::predictor::training::train_schedule_heads(&corpus, &mut pred);
        let mut policy = PredictedPolicy::new(pred);
        let mut rng = Rng::new(4);
        let m = gen_matrix(&mut rng, 512, 0.05, MatrixPattern::PowerLaw);
        assert!(m.nnz() >= MIN_NNZ_TO_PREDICT);
        let mut sw = Stopwatch::new();
        let (fmt, sched, margin) = policy.decide_plan_for_slot("A", &m, 8, &mut sw);
        assert!(crate::sparse::ALL_FORMATS.contains(&fmt));
        assert!(Tile::ALL.contains(&sched.tile));
        assert!(Split::ALL.contains(&sched.split));
        assert!(matches!(sched.threads, ThreadCap::Auto | ThreadCap::Cap(1)));
        assert!((0.0..=1.0).contains(&margin));
        let extracts = sw.report().iter().find(|r| r.0 == "feature_extract").map(|r| r.2);
        assert_eq!(extracts, Some(1), "format + schedule share one feature pass");
        // Tiny matrices skip the heads too and stay fully confident.
        let tiny = gen_matrix(&mut rng, 48, 0.05, MatrixPattern::Uniform);
        let (fmt, sched, margin) = policy.decide_plan_for_slot("A", &tiny, 8, &mut sw);
        assert_eq!(fmt, Format::Coo);
        assert_eq!(sched, crate::sparse::Schedule::effective());
        assert_eq!(margin, 1.0);
    }

    #[test]
    fn tiny_matrices_skip_prediction() {
        let corpus = TrainingCorpus::build(10, 48, 96, 8, 1, 0xAC);
        let pred = crate::predictor::training::train_predictor(&corpus, 1.0, 1);
        let mut policy = PredictedPolicy::new(pred);
        let mut rng = Rng::new(3);
        let m = gen_matrix(&mut rng, 48, 0.05, MatrixPattern::Uniform);
        assert!(m.nnz() < MIN_NNZ_TO_PREDICT);
        let mut sw = Stopwatch::new();
        let fmt = policy.decide(&m, 8, &mut sw);
        assert_eq!(fmt, Format::Coo);
        assert_eq!(sw.grand_total(), 0.0, "no overhead for tiny matrices");
    }
}
