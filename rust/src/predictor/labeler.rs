//! Exhaustive per-format profiling and the paper's Eq-1 labeling objective.
//!
//! For each training matrix the labeler measures every candidate format's
//! SpMM time and storage footprint, then labels the matrix with the format
//! minimizing `O = w·R + (1−w)·M` where `R`/`M` are the min–max-normalized
//! runtime/memory across the candidates (§4.3).

use crate::sparse::{Coo, Format, SparseMatrix, ALL_FORMATS};
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::timer::time_n;

/// One format's measured profile on one matrix.
#[derive(Clone, Debug)]
pub struct FormatProfile {
    pub format: Format,
    /// Median SpMM seconds (None when the format can't hold the matrix).
    pub spmm_secs: Option<f64>,
    /// One-off conversion cost into this format (amortized into the Eq-1
    /// runtime term — the paper charges conversion to end-to-end time).
    pub convert_secs: Option<f64>,
    /// Storage footprint in bytes (None when infeasible).
    pub nbytes: Option<usize>,
}

/// SpMM invocations a format decision is amortized over: the paper decides
/// once per GNN layer and trains ≥10 epochs with ~2 SpMMs per layer-epoch.
pub const AMORTIZE_USES: f64 = 20.0;

impl FormatProfile {
    /// Effective per-use runtime: SpMM + amortized conversion.
    pub fn effective_secs(&self) -> Option<f64> {
        Some(self.spmm_secs? + self.convert_secs.unwrap_or(0.0) / AMORTIZE_USES)
    }
}

/// Profile every candidate format's SpMM against a dense operand of width
/// `d`. `reps` measured repetitions (median reported).
pub fn profile_formats(coo: &Coo, d: usize, reps: usize) -> Vec<FormatProfile> {
    let mut rng = Rng::new(0xBEEF ^ coo.nnz() as u64);
    let x = Matrix::rand(coo.cols, d, &mut rng);
    let base = SparseMatrix::Coo(coo.clone());
    ALL_FORMATS
        .iter()
        .map(|&fmt| {
            let (converted, convert_secs) =
                crate::util::timer::time_it(|| base.convert(fmt));
            let m = match converted {
                Ok(m) => m,
                Err(_) => {
                    return FormatProfile {
                        format: fmt,
                        spmm_secs: None,
                        convert_secs: None,
                        nbytes: None,
                    };
                }
            };
            let samples = time_n(1, reps.max(1), || m.spmm(&x));
            FormatProfile {
                format: fmt,
                spmm_secs: Some(stats::median(&samples)),
                convert_secs: Some(convert_secs),
                nbytes: Some(m.nbytes()),
            }
        })
        .collect()
}

/// Apply Eq. 1 to a profile set: the label is the feasible format with the
/// smallest `w·R + (1−w)·M`. Infeasible formats are never chosen.
pub fn label_for(profiles: &[FormatProfile], w: f64) -> Format {
    let times: Vec<f64> = profiles.iter().filter_map(|p| p.effective_secs()).collect();
    let mems: Vec<f64> = profiles.iter().filter_map(|p| p.nbytes.map(|b| b as f64)).collect();
    let (t_lo, t_hi) = (stats::min(&times), stats::max(&times));
    let (m_lo, m_hi) = (stats::min(&mems), stats::max(&mems));
    let mut best: Option<(f64, Format)> = None;
    for p in profiles {
        let (Some(t), Some(b)) = (p.effective_secs(), p.nbytes) else {
            continue;
        };
        let r = stats::minmax_scale(t, t_lo, t_hi);
        let m = stats::minmax_scale(b as f64, m_lo, m_hi);
        let o = w * r + (1.0 - w) * m;
        if best.map(|(bo, _)| o < bo).unwrap_or(true) {
            best = Some((o, p.format));
        }
    }
    best.map(|(_, f)| f).unwrap_or(Format::Csr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_matrix, MatrixPattern};

    #[test]
    fn profiles_cover_all_feasible_formats() {
        let mut rng = Rng::new(1);
        let m = gen_matrix(&mut rng, 128, 0.05, MatrixPattern::Uniform);
        let profiles = profile_formats(&m, 8, 2);
        assert_eq!(profiles.len(), ALL_FORMATS.len());
        let feasible = profiles.iter().filter(|p| p.spmm_secs.is_some()).count();
        assert!(feasible >= 6, "most formats feasible on a small matrix");
    }

    #[test]
    fn label_prefers_speed_at_w1_and_memory_at_w0() {
        // Construct synthetic profiles with a clear speed/memory trade-off.
        let p = |format, spmm, bytes| FormatProfile {
            format,
            spmm_secs: Some(spmm),
            convert_secs: Some(0.0),
            nbytes: Some(bytes),
        };
        let profiles = vec![
            p(Format::Coo, 1.0, 100),
            p(Format::Csr, 0.1, 1000),
            p(Format::Dok, 2.0, 2000),
        ];
        assert_eq!(label_for(&profiles, 1.0), Format::Csr); // fastest
        assert_eq!(label_for(&profiles, 0.0), Format::Coo); // smallest
    }

    #[test]
    fn infeasible_formats_never_win() {
        let profiles = vec![
            FormatProfile { format: Format::Dia, spmm_secs: None, convert_secs: None, nbytes: None },
            FormatProfile {
                format: Format::Csr,
                spmm_secs: Some(0.5),
                convert_secs: Some(0.1),
                nbytes: Some(500),
            },
        ];
        assert_eq!(label_for(&profiles, 1.0), Format::Csr);
        assert_eq!(label_for(&profiles, 0.0), Format::Csr);
    }

    #[test]
    fn expensive_conversion_penalized() {
        let p = |format, spmm, conv| FormatProfile {
            format,
            spmm_secs: Some(spmm),
            convert_secs: Some(conv),
            nbytes: Some(100),
        };
        // CSR is 10% faster per SpMM but costs 10s to convert: at 20-use
        // amortization (0.5s/use) COO wins.
        let profiles = vec![p(Format::Coo, 1.0, 0.0), p(Format::Csr, 0.9, 10.0)];
        assert_eq!(label_for(&profiles, 1.0), Format::Coo);
        // Cheap conversion: CSR wins.
        let profiles = vec![p(Format::Coo, 1.0, 0.0), p(Format::Csr, 0.9, 0.01)];
        assert_eq!(label_for(&profiles, 1.0), Format::Csr);
    }

    #[test]
    fn diagonal_matrix_labels_fast_format_sanely() {
        let mut rng = Rng::new(2);
        let m = gen_matrix(&mut rng, 256, 0.02, MatrixPattern::Diagonal);
        let profiles = profile_formats(&m, 16, 3);
        let label = label_for(&profiles, 1.0);
        // DIA must at least be feasible and competitive here.
        let dia = profiles.iter().find(|p| p.format == Format::Dia).unwrap();
        assert!(dia.spmm_secs.is_some());
        // The label must be one of the measured formats.
        assert!(ALL_FORMATS.contains(&label));
    }
}
