//! Signature-keyed format-decision cache for streamed inputs.
//!
//! Full-batch training asks the predictor for a format a handful of times
//! per run; sharded mini-batch training asks **per slot per shard per
//! epoch** — hundreds of decisions over matrices that are structurally
//! near-identical (same partitioner, same sampler fan-out). Re-running
//! feature extraction (the paper's Table-2 features are O(nnz)) for every
//! shard would let decision overhead eat exactly the SpMM savings the
//! predictor buys — ParamSpMM makes the same amortization argument for
//! adaptive per-input kernel selection.
//!
//! The cache keys decisions by the **slot identity** plus a **cheap
//! structural signature** — log₂ buckets of rows, nnz and dense-operand
//! width plus a half-decade density bucket — all O(1) reads off the matrix
//! header, no COO view, no feature pass. Keying by slot keeps
//! slot-sensitive policies (`decide_for_slot`) correct: one slot's cached
//! answer is never served to another. Within a bucket, a **hysteresis dead-band** extends the engine's
//! `redecide_rel_drift` rule: a cached decision keeps answering until the
//! observed density drifts more than `rel_drift` from the density anchored
//! at decision time; then the entry is re-decided and re-anchored. Shards
//! that straddle a bucket boundary simply occupy two entries.

use crate::sparse::Format;
use std::collections::HashMap;

/// Pack the structural signature into one key. Buckets are deliberately
/// coarse: the predictor's own decision boundaries are much coarser than a
/// factor of 2 in size or √10 in density (paper Fig. 1: winners flip
/// between density *regimes*, not between adjacent sizes).
///
/// **Both** dimensions are keyed (log₂ rows *and* cols): density alone
/// cannot distinguish two shapes — equal rows/nnz with 2× the cols gives
/// 2× the density, which can still land in the same half-decade bucket —
/// so a rebind to a differently-shaped operand must change the signature,
/// not ride the dead-band (ISSUE-4 hardening; the engine additionally
/// re-decides on any shape change).
///
/// The **slot identity** is part of the key (22 bits of FNV-1a over the
/// slot name): `FormatPolicy::decide_for_slot` may answer differently per
/// slot (e.g. [`crate::gnn::engine::SlotTargetedPolicy`]), so a decision
/// cached for one slot must never be served to another.
fn signature(slot: &str, rows: usize, cols: usize, nnz: usize, density: f64, d: usize) -> u64 {
    let log2 = |v: usize| u64::from(usize::BITS - v.max(1).leading_zeros());
    // Half-decade buckets, offset to stay positive in the packing and
    // clamped so even denormal densities can't bleed into other fields.
    let density_bucket: u64 = if density > 0.0 {
        ((density.log10() * 2.0).floor() as i64 + 512).clamp(1, 1023) as u64
    } else {
        0
    };
    let mut name_hash: u64 = 0xcbf2_9ce4_8422_2325;
    for b in slot.bytes() {
        name_hash = (name_hash ^ u64::from(b)).wrapping_mul(0x0100_0000_01b3);
    }
    (log2(cols) << 56)
        | (log2(rows) << 48)
        | (log2(nnz) << 40)
        | (log2(d) << 32)
        | ((name_hash & 0x3f_ffff) << 10)
        | density_bucket
}

#[derive(Clone, Copy, Debug)]
struct CacheEntry {
    format: Format,
    /// Density anchor for the hysteresis dead-band.
    density: f64,
}

/// Format-decision cache with drift hysteresis (see module docs).
#[derive(Clone, Debug)]
pub struct DecisionCache {
    entries: HashMap<u64, CacheEntry>,
    /// Relative density drift tolerated within a signature bucket before
    /// the cached decision is re-made (inherited from the engine's
    /// `redecide_rel_drift`).
    pub rel_drift: f64,
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that fell through to the policy.
    pub misses: u64,
}

impl DecisionCache {
    pub fn new(rel_drift: f64) -> DecisionCache {
        DecisionCache { entries: HashMap::new(), rel_drift, hits: 0, misses: 0 }
    }

    /// Answer a decision from the cache, or record a miss. `slot` is the
    /// engine slot name (part of the key — policies may be slot-sensitive);
    /// `d` is the dense operand width of the upcoming multiply (part of
    /// the signature: the policy sees it too).
    pub fn lookup(
        &mut self,
        slot: &str,
        rows: usize,
        cols: usize,
        nnz: usize,
        density: f64,
        d: usize,
    ) -> Option<Format> {
        let sig = signature(slot, rows, cols, nnz, density, d);
        match self.entries.get(&sig) {
            Some(e) if rel_dev(density, e.density) <= self.rel_drift => {
                self.hits += 1;
                Some(e.format)
            }
            _ => {
                self.misses += 1;
                None
            }
        }
    }

    /// Record a freshly made decision, (re-)anchoring the drift dead-band
    /// at the observed density.
    #[allow(clippy::too_many_arguments)]
    pub fn store(
        &mut self,
        slot: &str,
        rows: usize,
        cols: usize,
        nnz: usize,
        density: f64,
        d: usize,
        format: Format,
    ) {
        let sig = signature(slot, rows, cols, nnz, density, d);
        self.entries.insert(sig, CacheEntry { format, density });
    }

    /// Distinct signatures currently cached.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fraction of lookups answered from the cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Relative deviation of `x` from anchor `a` (symmetric in magnitude).
fn rel_dev(x: f64, a: f64) -> f64 {
    (x - a).abs() / a.abs().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit_for_similar_matrices() {
        let mut c = DecisionCache::new(0.5);
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.005, 16), None);
        c.store("A", 1000, 1000, 5000, 0.005, 16, Format::Csr);
        // Same bucket, slightly different shard.
        assert_eq!(c.lookup("A", 990, 990, 5100, 0.0052, 16), Some(Format::Csr));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn different_buckets_are_distinct_entries() {
        let mut c = DecisionCache::new(0.5);
        c.store("A", 1000, 1000, 5000, 0.005, 16, Format::Csr);
        // 4× the rows: different rows bucket.
        assert_eq!(c.lookup("A", 4000, 1000, 5000, 0.005, 16), None);
        // 4× nnz: different nnz bucket.
        assert_eq!(c.lookup("A", 1000, 1000, 20000, 0.005, 16), None);
        // 10× density: different density bucket.
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.05, 16), None);
        // 4× dense width: different d bucket.
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.005, 64), None);
        c.store("A", 4000, 1000, 5000, 0.005, 16, Format::Coo);
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.005, 16), Some(Format::Csr));
        assert_eq!(c.lookup("A", 4000, 1000, 5000, 0.005, 16), Some(Format::Coo));
        assert_eq!(c.len(), 2);
    }

    /// Regression (ISSUE-4): cols is part of the signature. A matrix with
    /// half the cols but comparable nnz can land in the same rows/nnz/
    /// density buckets *and* inside the density dead-band — without a cols
    /// bucket it would be served the full-width entry's decision.
    #[test]
    fn different_cols_are_distinct_entries_even_in_same_density_bucket() {
        let mut c = DecisionCache::new(0.5);
        // 1000×1000, nnz 11000 → density 0.011 (bucket −4; nnz bucket 14).
        c.store("A", 1000, 1000, 11000, 0.011, 16, Format::Csr);
        // 1000×500, nnz 8200 → density 0.0164: same nnz bucket (≥ 8192),
        // same density bucket (−4), rel-drift 0.49 ≤ 0.5 — only the cols
        // bucket separates the two.
        assert_eq!(
            c.lookup("A", 1000, 500, 8200, 0.0164, 16),
            None,
            "halved cols must not reuse the full-width entry"
        );
        c.store("A", 1000, 500, 8200, 0.0164, 16, Format::Csc);
        assert_eq!(c.lookup("A", 1000, 1000, 11000, 0.011, 16), Some(Format::Csr));
        assert_eq!(c.lookup("A", 1000, 500, 8200, 0.0164, 16), Some(Format::Csc));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn drift_beyond_band_invalidates_and_restore_reanchors() {
        let mut c = DecisionCache::new(0.5);
        c.store("A", 1000, 1000, 5000, 0.0040, 16, Format::Csr);
        // Within the same half-decade bucket but > 50% above the anchor:
        // hysteresis trips, the entry must be re-decided.
        assert_eq!(c.lookup("A", 1000, 1000, 7000, 0.0070, 16), None);
        c.store("A", 1000, 1000, 7000, 0.0070, 16, Format::Csc);
        // New anchor holds for nearby densities…
        assert_eq!(c.lookup("A", 1000, 1000, 6900, 0.0069, 16), Some(Format::Csc));
        // …and a density far below the *new* anchor re-decides even though
        // it sits in the same bucket (dead-band moved with the anchor —
        // that is the hysteresis).
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.0034, 16), None);
    }

    /// Slot-sensitive policies (`SlotTargetedPolicy`) may answer
    /// differently for structurally identical matrices: the slot name must
    /// isolate cache entries.
    #[test]
    fn same_structure_different_slots_are_distinct_entries() {
        let mut c = DecisionCache::new(0.5);
        c.store("gcn.H1", 1000, 1000, 5000, 0.005, 16, Format::Dia);
        assert_eq!(c.lookup("gcn.A.l1", 1000, 1000, 5000, 0.005, 16), None);
        c.store("gcn.A.l1", 1000, 1000, 5000, 0.005, 16, Format::Csr);
        assert_eq!(c.lookup("gcn.H1", 1000, 1000, 5000, 0.005, 16), Some(Format::Dia));
        assert_eq!(c.lookup("gcn.A.l1", 1000, 1000, 5000, 0.005, 16), Some(Format::Csr));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn zero_density_degenerates_safely() {
        let mut c = DecisionCache::new(0.5);
        c.store("A", 10, 10, 0, 0.0, 4, Format::Coo);
        assert_eq!(c.lookup("A", 10, 10, 0, 0.0, 4), Some(Format::Coo));
    }
}
