//! Signature-keyed format-decision cache for streamed inputs.
//!
//! Full-batch training asks the predictor for a format a handful of times
//! per run; sharded mini-batch training asks **per slot per shard per
//! epoch** — hundreds of decisions over matrices that are structurally
//! near-identical (same partitioner, same sampler fan-out). Re-running
//! feature extraction (the paper's Table-2 features are O(nnz)) for every
//! shard would let decision overhead eat exactly the SpMM savings the
//! predictor buys — ParamSpMM makes the same amortization argument for
//! adaptive per-input kernel selection.
//!
//! The cache keys decisions by the **slot identity** plus a **cheap
//! structural signature** — log₂ buckets of rows, nnz and dense-operand
//! width plus a half-decade density bucket — all O(1) reads off the matrix
//! header, no COO view, no feature pass. Keying by slot keeps
//! slot-sensitive policies (`decide_for_slot`) correct: one slot's cached
//! answer is never served to another. Within a bucket, a **hysteresis dead-band** extends the engine's
//! `redecide_rel_drift` rule: a cached decision keeps answering until the
//! observed density drifts more than `rel_drift` from the density anchored
//! at decision time; then the entry is re-decided and re-anchored. Shards
//! that straddle a bucket boundary simply occupy two entries.

//! Two service-oriented extensions (§Shared-Ownership PR):
//!
//! * **Persistence** — [`DecisionCache::save`]/[`DecisionCache::load`]
//!   round-trip the entry table through `util::json`, so a service
//!   warm-starts with a hot cache instead of paying a cold first epoch.
//! * **Confidence margins** — [`DecisionCache::store_with_margin`] declines
//!   to cache decisions whose calibrated confidence margin (top-1 − top-2
//!   class probability from the predictor) falls below
//!   [`DecisionCache::min_margin`]. A low-margin prediction is a coin flip
//!   near a decision boundary; pinning it would let the hysteresis
//!   dead-band keep serving the flip for the rest of the run. Bypassed
//!   decisions are still *used* once — they are just re-asked next time.

use crate::sparse::{Format, Schedule, Split, ThreadCap, Tile};
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Pack the structural signature into one key. Buckets are deliberately
/// coarse: the predictor's own decision boundaries are much coarser than a
/// factor of 2 in size or √10 in density (paper Fig. 1: winners flip
/// between density *regimes*, not between adjacent sizes).
///
/// **Both** dimensions are keyed (log₂ rows *and* cols): density alone
/// cannot distinguish two shapes — equal rows/nnz with 2× the cols gives
/// 2× the density, which can still land in the same half-decade bucket —
/// so a rebind to a differently-shaped operand must change the signature,
/// not ride the dead-band (ISSUE-4 hardening; the engine additionally
/// re-decides on any shape change).
///
/// The **slot identity** is part of the key (22 bits of FNV-1a over the
/// slot name): `FormatPolicy::decide_for_slot` may answer differently per
/// slot (e.g. [`crate::gnn::engine::SlotTargetedPolicy`]), so a decision
/// cached for one slot must never be served to another.
pub(crate) fn signature(
    slot: &str,
    rows: usize,
    cols: usize,
    nnz: usize,
    density: f64,
    d: usize,
) -> u64 {
    let log2 = |v: usize| u64::from(usize::BITS - v.max(1).leading_zeros());
    // Half-decade buckets, offset to stay positive in the packing and
    // clamped so even denormal densities can't bleed into other fields.
    let density_bucket: u64 = if density > 0.0 {
        ((density.log10() * 2.0).floor() as i64 + 512).clamp(1, 1023) as u64
    } else {
        0
    };
    let mut name_hash: u64 = 0xcbf2_9ce4_8422_2325;
    for b in slot.bytes() {
        name_hash = (name_hash ^ u64::from(b)).wrapping_mul(0x0100_0000_01b3);
    }
    (log2(cols) << 56)
        | (log2(rows) << 48)
        | (log2(nnz) << 40)
        | (log2(d) << 32)
        | ((name_hash & 0x3f_ffff) << 10)
        | density_bucket
}

#[derive(Clone, Copy, Debug)]
struct CacheEntry {
    format: Format,
    /// Kernel schedule pinned alongside the format (tile/split/threads) —
    /// a cache hit hands workers a complete execution plan, not just a
    /// storage decision. Pre-schedule cache files load with
    /// [`Schedule::default`] (the historical fixed behavior).
    schedule: Schedule,
    /// Density anchor for the hysteresis dead-band.
    density: f64,
}

/// Decisions whose confidence margin falls below this are not cached
/// (see module docs). Margins are top-1 − top-2 class probabilities in
/// [0, 1]; deterministic policies report 1.0 and always cache.
pub const DEFAULT_MIN_MARGIN: f64 = 0.1;

/// Point-in-time counter readout from [`DecisionCache::snapshot`] — a
/// plain-data struct concurrent reporting paths can hold without touching
/// the live cache again.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub low_margin_bypasses: u64,
    /// Distinct signatures stored at snapshot time.
    pub entries: usize,
}

impl CacheStats {
    /// Fraction of lookups answered from the cache; 0 with no lookups.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Format-decision cache with drift hysteresis (see module docs).
///
/// Concurrency: [`DecisionCache::lookup`] takes `&self` — the entry table
/// is only read, and the hit/miss accounting lives in relaxed atomics — so
/// a warm cache behind an `Arc` serves any number of inference workers
/// with **no mutex on the hot path** (the serving layer's cache-sharing
/// rule, DESIGN.md §Serving). Mutation (`store*`, `load`) still requires
/// `&mut self`/ownership: writes happen in single-writer phases (training,
/// warm-up), never concurrently with shared readers.
#[derive(Debug)]
pub struct DecisionCache {
    entries: HashMap<u64, CacheEntry>,
    /// Relative density drift tolerated within a signature bucket before
    /// the cached decision is re-made (inherited from the engine's
    /// `redecide_rel_drift`).
    pub rel_drift: f64,
    /// Minimum confidence margin a decision needs to be pinned
    /// ([`DEFAULT_MIN_MARGIN`]; tune per deployment).
    pub min_margin: f64,
    /// Lookups answered from the cache (relaxed atomic: exactness under
    /// contention matters less than never serializing readers).
    hits: AtomicU64,
    /// Lookups that fell through to the policy.
    misses: AtomicU64,
    /// Decisions declined by the margin gate (used once, not pinned).
    low_margin_bypasses: AtomicU64,
}

impl Clone for DecisionCache {
    /// Clones entries and configuration; the run-local counters restart at
    /// zero (same rule as the JSON round trip — accounting belongs to one
    /// run, the entry table to the workload).
    fn clone(&self) -> DecisionCache {
        DecisionCache {
            entries: self.entries.clone(),
            rel_drift: self.rel_drift,
            min_margin: self.min_margin,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            low_margin_bypasses: AtomicU64::new(0),
        }
    }
}

impl DecisionCache {
    pub fn new(rel_drift: f64) -> DecisionCache {
        DecisionCache {
            entries: HashMap::new(),
            rel_drift,
            min_margin: DEFAULT_MIN_MARGIN,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            low_margin_bypasses: AtomicU64::new(0),
        }
    }

    /// Answer a decision from the cache, or record a miss. `slot` is the
    /// engine slot name (part of the key — policies may be slot-sensitive);
    /// `d` is the dense operand width of the upcoming multiply (part of
    /// the signature: the policy sees it too). Takes `&self`: concurrent
    /// readers share one cache lock-free (see the type docs).
    ///
    /// Format-only view of [`DecisionCache::lookup_plan`] (the schedule is
    /// dropped); hit/miss accounting happens once, in the plan lookup.
    pub fn lookup(
        &self,
        slot: &str,
        rows: usize,
        cols: usize,
        nnz: usize,
        density: f64,
        d: usize,
    ) -> Option<Format> {
        self.lookup_plan(slot, rows, cols, nnz, density, d).map(|(fmt, _)| fmt)
    }

    /// Answer the complete execution plan — storage format **and** kernel
    /// schedule — from the cache, or record a miss. Entries loaded from
    /// pre-schedule cache files carry [`Schedule::default`].
    pub fn lookup_plan(
        &self,
        slot: &str,
        rows: usize,
        cols: usize,
        nnz: usize,
        density: f64,
        d: usize,
    ) -> Option<(Format, Schedule)> {
        let sig = signature(slot, rows, cols, nnz, density, d);
        match self.entries.get(&sig) {
            Some(e) if rel_dev(density, e.density) <= self.rel_drift => {
                // ord: standalone stat counter; no reader infers other
                // state from its value, so Relaxed suffices.
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some((e.format, e.schedule))
            }
            _ => {
                // ord: same stat-counter argument as `hits` above.
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Lookups answered from the cache so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed) // ord: monotonic stat read, no ordering dependency
    }

    /// Lookups that fell through to the policy so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed) // ord: monotonic stat read, no ordering dependency
    }

    /// Decisions declined by the margin gate so far.
    pub fn low_margin_bypasses(&self) -> u64 {
        self.low_margin_bypasses.load(Ordering::Relaxed) // ord: monotonic stat read, no ordering dependency
    }

    /// Read-only stats snapshot — one consistent-enough readout (each
    /// counter is read once, relaxed) for reports from concurrently
    /// serving readers.
    pub fn snapshot(&self) -> CacheStats {
        CacheStats {
            hits: self.hits(),
            misses: self.misses(),
            low_margin_bypasses: self.low_margin_bypasses(),
            entries: self.entries.len(),
        }
    }

    /// Record a freshly made decision, (re-)anchoring the drift dead-band
    /// at the observed density. Fully-confident shorthand for
    /// [`DecisionCache::store_with_margin`].
    #[allow(clippy::too_many_arguments)]
    pub fn store(
        &mut self,
        slot: &str,
        rows: usize,
        cols: usize,
        nnz: usize,
        density: f64,
        d: usize,
        format: Format,
    ) {
        self.store_with_margin(slot, rows, cols, nnz, density, d, format, 1.0);
    }

    /// Record a decision together with the predictor's calibrated
    /// confidence margin. Margins below [`DecisionCache::min_margin`] are
    /// **not** stored — a near-boundary prediction must not be pinned by
    /// the hysteresis dead-band; the next structurally similar lookup
    /// re-consults the policy instead. Format-only shorthand: the entry is
    /// pinned with the default schedule.
    #[allow(clippy::too_many_arguments)]
    pub fn store_with_margin(
        &mut self,
        slot: &str,
        rows: usize,
        cols: usize,
        nnz: usize,
        density: f64,
        d: usize,
        format: Format,
        margin: f64,
    ) {
        self.store_plan(slot, rows, cols, nnz, density, d, format, Schedule::default(), margin);
    }

    /// Record a complete (format, schedule) plan with its confidence
    /// margin. The margin gate covers the whole plan: a near-boundary
    /// prediction of either output must not be pinned by the hysteresis
    /// dead-band.
    #[allow(clippy::too_many_arguments)]
    pub fn store_plan(
        &mut self,
        slot: &str,
        rows: usize,
        cols: usize,
        nnz: usize,
        density: f64,
        d: usize,
        format: Format,
        schedule: Schedule,
        margin: f64,
    ) {
        if margin < self.min_margin {
            // ord: stat counter only; the early-return is decided by
            // `margin`, not by the counter value.
            self.low_margin_bypasses.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let sig = signature(slot, rows, cols, nnz, density, d);
        self.entries.insert(sig, CacheEntry { format, schedule, density });
    }

    /// Distinct signatures currently cached.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Fraction of lookups answered from the cache.
    pub fn hit_rate(&self) -> f64 {
        self.snapshot().hit_rate()
    }

    /// Serialize the entry table + configuration. Signatures are hex
    /// strings (u64 does not survive a JSON f64), entries are emitted in
    /// signature order for reproducible dumps. Hit/miss counters are
    /// run-local accounting and are **not** persisted.
    pub fn to_json(&self) -> Json {
        let mut entries: Vec<(&u64, &CacheEntry)> = self.entries.iter().collect();
        entries.sort_by_key(|(sig, _)| **sig);
        Json::obj(vec![
            ("rel_drift", Json::Num(self.rel_drift)),
            ("min_margin", Json::Num(self.min_margin)),
            (
                "entries",
                Json::Arr(
                    entries
                        .into_iter()
                        .map(|(sig, e)| {
                            Json::obj(vec![
                                ("sig", Json::Str(format!("{sig:016x}"))),
                                ("format", Json::Str(e.format.name().to_string())),
                                ("tile", Json::Num(e.schedule.tile.lanes() as f64)),
                                ("split", Json::Str(e.schedule.split.name().to_string())),
                                ("threads", Json::Num(e.schedule.threads.encode() as f64)),
                                ("density", Json::Num(e.density)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Rebuild a cache from [`DecisionCache::to_json`] output. Counters
    /// start at zero: a warm-started run reports its own hit rate.
    pub fn from_json(j: &Json) -> anyhow::Result<DecisionCache> {
        let rel_drift = j.req_f64("rel_drift")?;
        if !rel_drift.is_finite() || rel_drift < 0.0 {
            anyhow::bail!("bad rel_drift {rel_drift}");
        }
        let mut cache = DecisionCache::new(rel_drift);
        cache.min_margin = j.req_f64("min_margin").unwrap_or(DEFAULT_MIN_MARGIN);
        if !cache.min_margin.is_finite() || !(0.0..=1.0).contains(&cache.min_margin) {
            anyhow::bail!("bad min_margin {}", cache.min_margin);
        }
        for e in j.req_arr("entries")? {
            let sig = u64::from_str_radix(e.req_str("sig")?, 16)
                .map_err(|_| anyhow::anyhow!("bad cache signature"))?;
            let format = Format::from_name(e.req_str("format")?)
                .ok_or_else(|| anyhow::anyhow!("unknown cached format"))?;
            let density = e.req_f64("density")?;
            if !density.is_finite() || !(0.0..=1.0).contains(&density) {
                anyhow::bail!("bad cached density {density}");
            }
            // Schedule fields are optional: pre-schedule cache files carry
            // format-only entries, which load with the default schedule (the
            // behavior those runs actually had). Present-but-invalid fields
            // are corruption and reject like any other bad value.
            let schedule = Schedule {
                tile: match e.get("tile") {
                    None => Schedule::default().tile,
                    Some(v) => v
                        .as_f64()
                        .filter(|l| l.fract() == 0.0 && *l >= 0.0)
                        .and_then(|l| Tile::from_lanes(l as usize))
                        .ok_or_else(|| anyhow::anyhow!("bad cached tile width"))?,
                },
                split: match e.get("split") {
                    None => Schedule::default().split,
                    Some(v) => v
                        .as_str()
                        .and_then(Split::from_name)
                        .ok_or_else(|| anyhow::anyhow!("bad cached split rule"))?,
                },
                threads: match e.get("threads") {
                    None => Schedule::default().threads,
                    Some(v) => v
                        .as_f64()
                        .filter(|t| t.fract() == 0.0 && *t >= 0.0 && *t < 4096.0)
                        .map(|t| ThreadCap::decode(t as usize))
                        .ok_or_else(|| anyhow::anyhow!("bad cached thread cap"))?,
                },
            };
            cache.entries.insert(sig, CacheEntry { format, schedule, density });
        }
        Ok(cache)
    }

    /// Persist to a JSON file (warm-start input for the next process).
    /// Written temp-file + atomic rename (`util::fsio::atomic_write`): a
    /// crash mid-save leaves the previous cache intact instead of a
    /// truncated file that [`DecisionCache::load_or_cold`] must discard.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        crate::util::fsio::atomic_write(path, self.to_json().to_string().as_bytes())?;
        Ok(())
    }

    /// Load a cache persisted by [`DecisionCache::save`].
    pub fn load(path: &Path) -> anyhow::Result<DecisionCache> {
        let text = std::fs::read_to_string(path)?;
        DecisionCache::from_json(&Json::parse(&text)?)
    }

    /// Warm-start load that **cannot fail** (DESIGN.md §Fault-Tolerance):
    /// a missing file is a quiet cold start (first run, nothing persisted
    /// yet), while a corrupt one — truncated mid-write, garbage bytes,
    /// missing fields, non-finite values — logs one warning and also cold
    /// starts. The cache is a performance hint; its on-disk state must
    /// never be able to stop a training run or a server boot.
    pub fn load_or_cold(path: &Path) -> Option<DecisionCache> {
        if !path.exists() {
            return None;
        }
        match DecisionCache::load(path) {
            Ok(cache) => Some(cache),
            Err(e) => {
                eprintln!(
                    "warning: decision cache {} is unreadable ({e}); cold-starting",
                    path.display()
                );
                None
            }
        }
    }
}

/// Relative deviation of `x` from anchor `a` (symmetric in magnitude).
fn rel_dev(x: f64, a: f64) -> f64 {
    (x - a).abs() / a.abs().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit_for_similar_matrices() {
        let mut c = DecisionCache::new(0.5);
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.005, 16), None);
        c.store("A", 1000, 1000, 5000, 0.005, 16, Format::Csr);
        // Same bucket, slightly different shard.
        assert_eq!(c.lookup("A", 990, 990, 5100, 0.0052, 16), Some(Format::Csr));
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
        let stats = c.snapshot();
        assert_eq!(
            stats,
            CacheStats { hits: 1, misses: 1, low_margin_bypasses: 0, entries: 1 }
        );
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn different_buckets_are_distinct_entries() {
        let mut c = DecisionCache::new(0.5);
        c.store("A", 1000, 1000, 5000, 0.005, 16, Format::Csr);
        // 4× the rows: different rows bucket.
        assert_eq!(c.lookup("A", 4000, 1000, 5000, 0.005, 16), None);
        // 4× nnz: different nnz bucket.
        assert_eq!(c.lookup("A", 1000, 1000, 20000, 0.005, 16), None);
        // 10× density: different density bucket.
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.05, 16), None);
        // 4× dense width: different d bucket.
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.005, 64), None);
        c.store("A", 4000, 1000, 5000, 0.005, 16, Format::Coo);
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.005, 16), Some(Format::Csr));
        assert_eq!(c.lookup("A", 4000, 1000, 5000, 0.005, 16), Some(Format::Coo));
        assert_eq!(c.len(), 2);
    }

    /// Regression (ISSUE-4): cols is part of the signature. A matrix with
    /// half the cols but comparable nnz can land in the same rows/nnz/
    /// density buckets *and* inside the density dead-band — without a cols
    /// bucket it would be served the full-width entry's decision.
    #[test]
    fn different_cols_are_distinct_entries_even_in_same_density_bucket() {
        let mut c = DecisionCache::new(0.5);
        // 1000×1000, nnz 11000 → density 0.011 (bucket −4; nnz bucket 14).
        c.store("A", 1000, 1000, 11000, 0.011, 16, Format::Csr);
        // 1000×500, nnz 8200 → density 0.0164: same nnz bucket (≥ 8192),
        // same density bucket (−4), rel-drift 0.49 ≤ 0.5 — only the cols
        // bucket separates the two.
        assert_eq!(
            c.lookup("A", 1000, 500, 8200, 0.0164, 16),
            None,
            "halved cols must not reuse the full-width entry"
        );
        c.store("A", 1000, 500, 8200, 0.0164, 16, Format::Csc);
        assert_eq!(c.lookup("A", 1000, 1000, 11000, 0.011, 16), Some(Format::Csr));
        assert_eq!(c.lookup("A", 1000, 500, 8200, 0.0164, 16), Some(Format::Csc));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn drift_beyond_band_invalidates_and_restore_reanchors() {
        let mut c = DecisionCache::new(0.5);
        c.store("A", 1000, 1000, 5000, 0.0040, 16, Format::Csr);
        // Within the same half-decade bucket but > 50% above the anchor:
        // hysteresis trips, the entry must be re-decided.
        assert_eq!(c.lookup("A", 1000, 1000, 7000, 0.0070, 16), None);
        c.store("A", 1000, 1000, 7000, 0.0070, 16, Format::Csc);
        // New anchor holds for nearby densities…
        assert_eq!(c.lookup("A", 1000, 1000, 6900, 0.0069, 16), Some(Format::Csc));
        // …and a density far below the *new* anchor re-decides even though
        // it sits in the same bucket (dead-band moved with the anchor —
        // that is the hysteresis).
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.0034, 16), None);
    }

    /// Slot-sensitive policies (`SlotTargetedPolicy`) may answer
    /// differently for structurally identical matrices: the slot name must
    /// isolate cache entries.
    #[test]
    fn same_structure_different_slots_are_distinct_entries() {
        let mut c = DecisionCache::new(0.5);
        c.store("gcn.H1", 1000, 1000, 5000, 0.005, 16, Format::Dia);
        assert_eq!(c.lookup("gcn.A.l1", 1000, 1000, 5000, 0.005, 16), None);
        c.store("gcn.A.l1", 1000, 1000, 5000, 0.005, 16, Format::Csr);
        assert_eq!(c.lookup("gcn.H1", 1000, 1000, 5000, 0.005, 16), Some(Format::Dia));
        assert_eq!(c.lookup("gcn.A.l1", 1000, 1000, 5000, 0.005, 16), Some(Format::Csr));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn zero_density_degenerates_safely() {
        let mut c = DecisionCache::new(0.5);
        c.store("A", 10, 10, 0, 0.0, 4, Format::Coo);
        assert_eq!(c.lookup("A", 10, 10, 0, 0.0, 4), Some(Format::Coo));
    }

    /// Margin gate: low-confidence decisions are counted but never stored;
    /// at-threshold and confident decisions are pinned as before.
    #[test]
    fn low_margin_store_is_bypassed() {
        let mut c = DecisionCache::new(0.5);
        c.store_with_margin("A", 1000, 1000, 5000, 0.005, 16, Format::Csr, 0.02);
        assert_eq!(c.len(), 0);
        assert_eq!(c.low_margin_bypasses(), 1);
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.005, 16), None);
        // Exactly at the threshold counts as confident enough.
        c.store_with_margin("A", 1000, 1000, 5000, 0.005, 16, Format::Csr, c.min_margin);
        assert_eq!(c.len(), 1);
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.005, 16), Some(Format::Csr));
        // `store` is the fully-confident shorthand.
        c.store("B", 10, 10, 5, 0.05, 4, Format::Coo);
        assert_eq!(c.len(), 2);
        assert_eq!(c.low_margin_bypasses(), 1);
    }

    /// JSON round trip: entries, dead-band and margin gate survive; the
    /// run-local hit/miss counters reset.
    #[test]
    fn json_round_trip_preserves_entries_and_config() {
        let mut c = DecisionCache::new(0.4);
        c.min_margin = 0.2;
        c.store("gcn.A.l1", 1000, 1000, 5000, 0.005, 16, Format::Csr);
        c.store("gcn.A.l1", 4000, 1000, 5000, 0.005, 16, Format::Coo);
        c.store("rgcn.A2.l2", 500, 500, 9000, 0.036, 8, Format::Csc);
        // Generate some counter state that must NOT round-trip.
        assert!(c.lookup("gcn.A.l1", 1000, 1000, 5000, 0.005, 16).is_some());
        assert!(c.lookup("nope", 1, 1, 1, 1.0, 1).is_none());

        let j = crate::util::json::Json::parse(&c.to_json().to_string()).unwrap();
        let r = DecisionCache::from_json(&j).unwrap();
        assert_eq!(r.len(), 3);
        assert_eq!(r.rel_drift, 0.4);
        assert_eq!(r.min_margin, 0.2);
        assert_eq!(r.hits(), 0);
        assert_eq!(r.misses(), 0);
        assert_eq!(r.lookup("gcn.A.l1", 1000, 1000, 5000, 0.005, 16), Some(Format::Csr));
        assert_eq!(r.lookup("gcn.A.l1", 4000, 1000, 5000, 0.005, 16), Some(Format::Coo));
        assert_eq!(r.lookup("rgcn.A2.l2", 500, 500, 9000, 0.036, 8), Some(Format::Csc));
        // Hysteresis anchors survived: same signature bucket (nnz 7200 and
        // 5000 share the log₂ bucket, densities share the half-decade) but
        // 44% density drift > the 40% band → still re-decides after load.
        assert_eq!(r.lookup("gcn.A.l1", 1000, 1000, 7200, 0.0072, 16), None);
    }

    /// The full (format, schedule) plan survives the JSON round trip:
    /// non-default tiles, splits and caps come back exactly, and the
    /// format-only `lookup` view stays consistent with `lookup_plan`.
    #[test]
    fn schedule_plan_round_trips_through_json() {
        let mut c = DecisionCache::new(0.5);
        let fast = Schedule {
            tile: Tile::T4,
            split: Split::EvenUnits,
            threads: ThreadCap::Cap(1),
        };
        let wide = Schedule {
            tile: Tile::T32,
            split: Split::NnzBalanced,
            threads: ThreadCap::Auto,
        };
        c.store_plan("A", 100, 100, 500, 0.05, 16, Format::Csr, fast, 1.0);
        c.store_plan("B", 4000, 4000, 80000, 0.005, 64, Format::Csc, wide, 1.0);
        c.store("C", 1000, 1000, 5000, 0.005, 16, Format::Coo); // default plan

        let j = crate::util::json::Json::parse(&c.to_json().to_string()).unwrap();
        let r = DecisionCache::from_json(&j).unwrap();
        assert_eq!(r.lookup_plan("A", 100, 100, 500, 0.05, 16), Some((Format::Csr, fast)));
        assert_eq!(r.lookup_plan("B", 4000, 4000, 80000, 0.005, 64), Some((Format::Csc, wide)));
        assert_eq!(
            r.lookup_plan("C", 1000, 1000, 5000, 0.005, 16),
            Some((Format::Coo, Schedule::default()))
        );
        assert_eq!(r.lookup("A", 100, 100, 500, 0.05, 16), Some(Format::Csr));
        // The emitted JSON names the schedule fields — what serving's smoke
        // test greps for after a warm start.
        let text = c.to_json().to_string();
        for field in ["\"tile\"", "\"split\"", "\"threads\""] {
            assert!(text.contains(field), "cache JSON must carry {field}");
        }
    }

    /// Cache-compat: a **pre-schedule** cache file (entries carry only
    /// `sig`/`format`/`density`) must load cleanly — never error — with
    /// every entry getting the default schedule, which is exactly the fixed
    /// kernel behavior those runs had.
    #[test]
    fn pre_schedule_cache_files_load_with_default_schedule() {
        // Verbatim layout of a v7-era save (before schedule fields existed).
        let fixture = "{\"rel_drift\": 0.5, \"min_margin\": 0.1, \"entries\": \
             [{\"sig\": \"121e0e000623f5fa\", \"format\": \"csr\", \"density\": 0.005}]}";
        let r = DecisionCache::from_json(&Json::parse(fixture).unwrap())
            .expect("pre-schedule cache must load");
        assert_eq!(r.len(), 1);
        // And through the never-fails warm-start boundary too.
        let dir = std::env::temp_dir().join("gnn_spmm_cache_prescem_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("old_format.json");
        // lint: allow(durability-io) -- test writes a verbatim v7-era fixture file
        std::fs::write(&path, fixture).unwrap();
        let warm = DecisionCache::load_or_cold(&path).expect("old format warm-starts, not cold");
        let plan = warm.entries.values().next().unwrap();
        assert_eq!(plan.schedule, Schedule::default());
        let _ = std::fs::remove_file(&path);

        // Present-but-corrupt schedule fields are rejected (→ cold start at
        // the load_or_cold boundary), not silently defaulted.
        for bad in [
            "{\"rel_drift\": 0.5, \"min_margin\": 0.1, \"entries\": \
             [{\"sig\": \"aa\", \"format\": \"csr\", \"tile\": 5, \"density\": 0.005}]}",
            "{\"rel_drift\": 0.5, \"min_margin\": 0.1, \"entries\": \
             [{\"sig\": \"aa\", \"format\": \"csr\", \"split\": \"fancy\", \"density\": 0.005}]}",
            "{\"rel_drift\": 0.5, \"min_margin\": 0.1, \"entries\": \
             [{\"sig\": \"aa\", \"format\": \"csr\", \"threads\": -1, \"density\": 0.005}]}",
        ] {
            assert!(DecisionCache::from_json(&Json::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn save_load_file_round_trip_and_garbage_rejection() {
        let dir = std::env::temp_dir().join("gnn_spmm_cache_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.json");
        let mut c = DecisionCache::new(0.5);
        c.store("A", 1000, 1000, 5000, 0.005, 16, Format::Bsr);
        c.save(&path).unwrap();
        let r = DecisionCache::load(&path).unwrap();
        assert_eq!(r.lookup("A", 1000, 1000, 5000, 0.005, 16), Some(Format::Bsr));
        // lint: allow(durability-io) -- test plants a deliberately corrupt cache file
        std::fs::write(&path, "{not json").unwrap();
        assert!(DecisionCache::load(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    /// The warm-start boundary must be total: every way the on-disk cache
    /// can be wrong — absent, truncated mid-write, garbage, structurally
    /// valid JSON missing fields, non-finite values — degrades to a cold
    /// start instead of an error (DESIGN.md §Fault-Tolerance).
    #[test]
    fn load_or_cold_survives_every_corruption_mode() {
        let dir = std::env::temp_dir().join("gnn_spmm_cache_cold_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("warm.json");
        let _ = std::fs::remove_file(&path);

        assert!(DecisionCache::load_or_cold(&path).is_none(), "missing file: quiet cold start");

        let mut c = DecisionCache::new(0.5);
        c.store("A", 1000, 1000, 5000, 0.005, 16, Format::Csr);
        c.save(&path).unwrap();
        let warm = DecisionCache::load_or_cold(&path).expect("intact file loads");
        assert_eq!(warm.lookup("A", 1000, 1000, 5000, 0.005, 16), Some(Format::Csr));

        // Torn write: the fault harness's file truncation (half the bytes).
        let plan = crate::testing::FaultPlan::inert()
            .with_rate(crate::testing::FaultKind::TruncateFile, 1.0);
        assert!(plan.maybe_truncate_file(&path).unwrap());
        assert!(DecisionCache::load_or_cold(&path).is_none(), "truncated file: cold start");

        // lint: allow(durability-io) -- test plants garbage bytes to prove cold start
        std::fs::write(&path, "\u{0}\u{1}garbage\u{2}").unwrap();
        assert!(DecisionCache::load_or_cold(&path).is_none(), "garbage bytes: cold start");

        // lint: allow(durability-io) -- test plants a field-poor cache to prove cold start
        std::fs::write(&path, "{\"rel_drift\": 0.5}").unwrap();
        assert!(DecisionCache::load_or_cold(&path).is_none(), "missing entries field: cold start");

        // lint: allow(durability-io) -- test plants a non-finite density to prove cold start
        std::fs::write(
            &path,
            "{\"rel_drift\": 0.5, \"min_margin\": 0.05, \"entries\": \
             [{\"sig\": \"00000000000000aa\", \"format\": \"csr\", \"density\": 1e999}]}",
        )
        .unwrap();
        assert!(DecisionCache::load_or_cold(&path).is_none(), "non-finite density: cold start");

        let _ = std::fs::remove_file(&path);
    }

    /// Serving's cache-sharing rule: a warm cache behind an `Arc` answers
    /// concurrent readers through `&self` — no mutex, and the relaxed
    /// counters still account every lookup exactly (each thread's bumps
    /// are atomic; only cross-thread ordering is relaxed).
    #[test]
    fn shared_cache_serves_concurrent_readers() {
        let mut c = DecisionCache::new(0.5);
        c.store("A", 1000, 1000, 5000, 0.005, 16, Format::Csr);
        let shared = std::sync::Arc::new(c);
        let per_thread = 500;
        std::thread::scope(|s| {
            for _ in 0..4 {
                let cache = std::sync::Arc::clone(&shared);
                s.spawn(move || {
                    for _ in 0..per_thread {
                        assert_eq!(
                            cache.lookup("A", 1000, 1000, 5000, 0.005, 16),
                            Some(Format::Csr)
                        );
                        assert_eq!(cache.lookup("B", 1000, 1000, 5000, 0.005, 16), None);
                    }
                });
            }
        });
        let stats = shared.snapshot();
        assert_eq!(stats.hits, 4 * per_thread);
        assert_eq!(stats.misses, 4 * per_thread);
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
    }

    /// Cloning shares nothing mutable: entries/config copy over, counters
    /// restart (the clone begins its own run's accounting).
    #[test]
    fn clone_copies_entries_and_resets_counters() {
        let mut c = DecisionCache::new(0.5);
        c.store("A", 1000, 1000, 5000, 0.005, 16, Format::Dia);
        assert_eq!(c.lookup("A", 1000, 1000, 5000, 0.005, 16), Some(Format::Dia));
        let d = c.clone();
        assert_eq!(d.len(), 1);
        assert_eq!(d.hits(), 0);
        assert_eq!(d.misses(), 0);
        assert_eq!(d.lookup("A", 1000, 1000, 5000, 0.005, 16), Some(Format::Dia));
        assert_eq!(c.hits(), 1, "original accounting unaffected by the clone");
    }
}
