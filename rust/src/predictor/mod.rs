//! The paper's contribution: a learned, runtime sparse-format selector.
//!
//! * [`labeler`] — exhaustive per-format profiling of a matrix and the Eq-1
//!   objective `O = w·R + (1−w)·M` that turns profiles into class labels
//!   (§4.3, Fig. 6).
//! * [`training`] — offline pipeline: synthetic corpus → profiles → labeled
//!   feature vectors → fitted GBDT + normalizer (§4.3–4.5).
//! * [`policy`] — the runtime [`crate::gnn::FormatPolicy`] implementations:
//!   the learned predictor, the oracle, and prior-work baselines (CNN,
//!   decision tree) used by Table 3.
//! * [`spmm_predict`] — the user-facing `SpMMPredict` call of §4.6.
//! * [`cache`] — the signature-keyed decision cache that amortizes feature
//!   extraction over streams of structurally similar inputs (the sharded
//!   mini-batch path; see DESIGN.md §Minibatch).
//! * [`autotune`] — the measured schedule fallback: time the
//!   [`crate::sparse::Schedule::CANDIDATES`] once per slot signature and
//!   pin the winner (DESIGN.md §Schedule-Prediction).

pub mod labeler;
pub mod training;
pub mod policy;
pub mod spmm_predict;
pub mod cache;
pub mod autotune;

pub use autotune::{best_schedule, profile_schedules, AutotunePolicy, ScheduleProfile};
pub use cache::{CacheStats, DecisionCache};
pub use labeler::{label_for, profile_formats, FormatProfile};
pub use policy::{OraclePolicy, PredictedPolicy};
pub use spmm_predict::spmm_predict;
pub use training::{train_predictor, train_schedule_heads, ScheduleHeads, TrainedPredictor, TrainingCorpus};
