//! Measured schedule autotuning: the decide-by-timing fallback for the
//! schedule dimension of the execution plan (DESIGN.md §Schedule-Prediction).
//!
//! Mirrors the format oracle's shape (`labeler::profile_formats` →
//! `label_for`): convert the operand once into its decided format, then time
//! every [`Schedule::CANDIDATES`] entry on a representative dense operand
//! and keep the fastest. The search runs **once per slot signature** — the
//! same coarse structural key the decision cache uses — so a mini-batch
//! shard stream pays the 4-candidate sweep once, not per shard, exactly the
//! amortization argument ParamSpMM makes for adaptive kernel selection.

use super::cache::signature;
use crate::gnn::engine::FormatPolicy;
use crate::sparse::{Coo, Format, Schedule, SparseMatrix};
use crate::tensor::Matrix;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::timer::{time_n, Stopwatch};
use std::collections::HashMap;

/// One schedule candidate's measured profile on one (matrix, format, d).
#[derive(Clone, Copy, Debug)]
pub struct ScheduleProfile {
    pub schedule: Schedule,
    /// Median seconds per SpMM under this schedule.
    pub secs: f64,
}

/// Time every schedule candidate's SpMM for `coo` held in `fmt` against a
/// dense operand of width `d` (`reps` measured repetitions, median
/// reported). Falls back to CSR when `fmt` cannot hold the matrix (the
/// DIA-budget rule the engine itself applies).
pub fn profile_schedules(coo: &Coo, fmt: Format, d: usize, reps: usize) -> Vec<ScheduleProfile> {
    let mut rng = Rng::new(0x5CED ^ coo.nnz() as u64);
    let x = Matrix::rand(coo.cols, d.max(1), &mut rng);
    let base = SparseMatrix::Coo(coo.clone());
    let m = base
        .convert(fmt)
        .unwrap_or_else(|_| base.convert(Format::Csr).expect("CSR conversion cannot fail"));
    let mut out = Matrix::zeros(coo.rows, d.max(1));
    Schedule::CANDIDATES
        .iter()
        .map(|&schedule| {
            let samples = time_n(1, reps.max(1), || m.spmm_into_with(&x, &mut out, schedule));
            ScheduleProfile { schedule, secs: stats::median(&samples) }
        })
        .collect()
}

/// The fastest measured candidate ([`Schedule::default`] on an empty
/// profile set).
pub fn best_schedule(profiles: &[ScheduleProfile]) -> Schedule {
    profiles
        .iter()
        .min_by(|a, b| a.secs.total_cmp(&b.secs))
        .map(|p| p.schedule)
        .unwrap_or_default()
}

/// [`FormatPolicy`] adapter that adds a measured schedule to any inner
/// format policy's decision. The candidate sweep is charged to the
/// `schedule_autotune` phase and memoized per slot signature; repeat
/// decisions for structurally similar operands reuse the stored winner
/// without re-timing.
pub struct AutotunePolicy<P: FormatPolicy> {
    pub inner: P,
    /// Timed repetitions per candidate.
    pub reps: usize,
    /// Slot-signature → measured winner.
    memo: HashMap<u64, Schedule>,
}

impl<P: FormatPolicy> AutotunePolicy<P> {
    pub fn new(inner: P) -> AutotunePolicy<P> {
        AutotunePolicy { inner, reps: 3, memo: HashMap::new() }
    }

    /// Distinct slot signatures autotuned so far.
    pub fn tuned_signatures(&self) -> usize {
        self.memo.len()
    }
}

impl<P: FormatPolicy> FormatPolicy for AutotunePolicy<P> {
    fn decide(&mut self, coo: &Coo, d: usize, sw: &mut Stopwatch) -> Format {
        self.inner.decide(coo, d, sw)
    }

    fn decide_for_slot(
        &mut self,
        slot: &str,
        coo: &Coo,
        d: usize,
        sw: &mut Stopwatch,
    ) -> Format {
        self.inner.decide_for_slot(slot, coo, d, sw)
    }

    fn decide_for_slot_with_confidence(
        &mut self,
        slot: &str,
        coo: &Coo,
        d: usize,
        sw: &mut Stopwatch,
    ) -> (Format, f64) {
        self.inner.decide_for_slot_with_confidence(slot, coo, d, sw)
    }

    fn decide_plan_for_slot(
        &mut self,
        slot: &str,
        coo: &Coo,
        d: usize,
        sw: &mut Stopwatch,
    ) -> (Format, Schedule, f64) {
        let (fmt, margin) = self.inner.decide_for_slot_with_confidence(slot, coo, d, sw);
        let sig = signature(slot, coo.rows, coo.cols, coo.nnz(), coo.density(), d);
        let reps = self.reps;
        let sched = *self.memo.entry(sig).or_insert_with(|| {
            sw.phase("schedule_autotune", || best_schedule(&profile_schedules(coo, fmt, d, reps)))
        });
        (fmt, sched, margin)
    }

    fn policy_name(&self) -> String {
        format!("autotune({})", self.inner.policy_name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::engine::StaticPolicy;
    use crate::graph::{gen_matrix, MatrixPattern};

    #[test]
    fn profiles_cover_every_candidate_and_pick_a_member() {
        let mut rng = Rng::new(11);
        let m = gen_matrix(&mut rng, 128, 0.05, MatrixPattern::PowerLaw);
        let profiles = profile_schedules(&m, Format::Csr, 8, 1);
        assert_eq!(profiles.len(), Schedule::CANDIDATES.len());
        assert!(profiles.iter().all(|p| p.secs.is_finite() && p.secs >= 0.0));
        let best = best_schedule(&profiles);
        assert!(Schedule::CANDIDATES.contains(&best));
    }

    #[test]
    fn empty_profiles_fall_back_to_default() {
        assert_eq!(best_schedule(&[]), Schedule::default());
    }

    #[test]
    fn infeasible_format_profiles_via_csr_fallback() {
        // Anti-diagonal blows the DIA budget; the profiler must fall back
        // instead of panicking (same rule as the engine's convert path).
        let n = 9000;
        let triples: Vec<_> = (0..n).map(|i| (i as u32, (n - 1 - i) as u32, 1.0f32)).collect();
        let coo = Coo::from_triples(n, n, triples);
        let profiles = profile_schedules(&coo, Format::Dia, 4, 1);
        assert_eq!(profiles.len(), Schedule::CANDIDATES.len());
    }

    #[test]
    fn autotune_memoizes_per_slot_signature() {
        let mut rng = Rng::new(12);
        let mut policy = AutotunePolicy::new(StaticPolicy(Format::Csr));
        policy.reps = 1;
        let mut sw = Stopwatch::new();
        let a = gen_matrix(&mut rng, 96, 0.05, MatrixPattern::Uniform);
        let (fmt, sched, margin) = policy.decide_plan_for_slot("A", &a, 8, &mut sw);
        assert_eq!(fmt, Format::Csr);
        assert!(Schedule::CANDIDATES.contains(&sched));
        assert_eq!(margin, 1.0);
        assert_eq!(policy.tuned_signatures(), 1);
        let sweeps = sw.report().iter().find(|r| r.0 == "schedule_autotune").map(|r| r.2);
        assert_eq!(sweeps, Some(1));
        // Structurally similar operand, same slot: memo answers, no re-time.
        let b = gen_matrix(&mut rng, 96, 0.05, MatrixPattern::Uniform);
        let (_, sched2, _) = policy.decide_plan_for_slot("A", &b, 8, &mut sw);
        assert_eq!(sched2, sched);
        assert_eq!(policy.tuned_signatures(), 1);
        let sweeps = sw.report().iter().find(|r| r.0 == "schedule_autotune").map(|r| r.2);
        assert_eq!(sweeps, Some(1), "memoized decision must not re-profile");
        // A different slot name is a different signature: tuned again.
        let _ = policy.decide_plan_for_slot("B", &a, 8, &mut sw);
        assert_eq!(policy.tuned_signatures(), 2);
    }
}
