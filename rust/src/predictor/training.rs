//! Offline training pipeline (paper §4.3–4.5, Fig. 5):
//! synthetic corpus → exhaustive profiles → Eq-1 labels → feature vectors →
//! min–max normalizer → fitted GBDT. The corpus profiles are computed once
//! and can be re-labeled for any `w` (Figs. 6/10) without re-profiling.

use super::autotune::{best_schedule, profile_schedules, ScheduleProfile};
use super::labeler::{label_for, profile_formats, FormatProfile};
use crate::features::{extract_features, Normalizer, N_FEATURES};
use crate::graph::generators::training_corpus;
use crate::ml::gbdt::{Gbdt, GbdtParams};
use crate::ml::metrics::{accuracy, kfold};
use crate::ml::{Classifier, TabularData};
use crate::sparse::{Coo, Format, Schedule, Split, ThreadCap, Tile, ALL_FORMATS};
use crate::util::json::Json;
use crate::util::parallel::parallel_map;
use crate::util::rng::Rng;

/// A profiled training corpus: everything needed to build a labeled dataset
/// for any optimization weight `w`.
pub struct TrainingCorpus {
    pub matrices: Vec<Coo>,
    pub raw_features: Vec<[f64; N_FEATURES]>,
    pub profiles: Vec<Vec<FormatProfile>>,
    /// Per-matrix timings of every [`Schedule::CANDIDATES`] entry, measured
    /// under the matrix's Eq-1 speed-label format — the label source for the
    /// multi-output schedule heads (DESIGN.md §Schedule-Prediction).
    pub schedule_profiles: Vec<Vec<ScheduleProfile>>,
    /// Density thumbnails for the CNN baseline.
    pub thumbnails: Vec<Vec<f32>>,
}

impl TrainingCorpus {
    /// Generate and profile `count` synthetic matrices (paper: 300,
    /// sizes 1k–15k; ours: laptop-scaled sizes, same sparsity band —
    /// DESIGN.md §Substitutions).
    pub fn build(count: usize, min_n: usize, max_n: usize, d: usize, reps: usize, seed: u64) -> TrainingCorpus {
        let mut rng = Rng::new(seed);
        let corpus = training_corpus(&mut rng, count, min_n, max_n);
        let matrices: Vec<Coo> = corpus.into_iter().map(|(m, _)| m).collect();
        // Profile + featurize in parallel across matrices (each profile is
        // itself serial to keep timings clean).
        let profiles: Vec<Vec<FormatProfile>> = matrices
            .iter()
            .map(|m| profile_formats(m, d, reps))
            .collect();
        // Schedule candidates are timed under each matrix's speed-optimal
        // format (w = 1.0): that is the format the runtime will actually be
        // executing when the schedule decision matters.
        let schedule_profiles: Vec<Vec<ScheduleProfile>> = matrices
            .iter()
            .zip(&profiles)
            .map(|(m, p)| profile_schedules(m, label_for(p, 1.0), d, reps))
            .collect();
        let raw_features = parallel_map(matrices.len(), |i| extract_features(&matrices[i]));
        let thumbnails = parallel_map(matrices.len(), |i| crate::ml::cnn::thumbnail(&matrices[i]));
        TrainingCorpus { matrices, raw_features, profiles, schedule_profiles, thumbnails }
    }

    /// Eq-1 labels for a given `w`.
    pub fn labels(&self, w: f64) -> Vec<usize> {
        self.profiles.iter().map(|p| label_for(p, w).label()).collect()
    }

    /// Label frequency per format (Fig. 6 rows).
    pub fn label_frequency(&self, w: f64) -> Vec<(Format, usize)> {
        let labels = self.labels(w);
        ALL_FORMATS
            .iter()
            .map(|&f| (f, labels.iter().filter(|&&l| l == f.label()).count()))
            .collect()
    }

    /// Build the normalized tabular dataset for a given `w`.
    pub fn dataset(&self, w: f64) -> (TabularData, Normalizer) {
        let norm = Normalizer::fit(&self.raw_features);
        let x: Vec<Vec<f64>> = self
            .raw_features
            .iter()
            .map(|r| norm.transform(r).to_vec())
            .collect();
        (TabularData::new(x, self.labels(w), ALL_FORMATS.len()), norm)
    }

    /// Measured-fastest schedule per matrix (the multi-output label source).
    pub fn schedule_labels(&self) -> Vec<Schedule> {
        self.schedule_profiles.iter().map(|p| best_schedule(p)).collect()
    }
}

/// Multi-output schedule prediction: one small GBDT ensemble per schedule
/// knob, all reading the same Table-2 feature vector the format model uses
/// (no extra extraction pass at decision time). Output class spaces are
/// [`Tile::ALL`] (4), [`Split::ALL`] (2) and the binary thread-cap class
/// (auto vs capped-serial).
pub struct ScheduleHeads {
    pub tile: Gbdt,
    pub split: Gbdt,
    pub threads: Gbdt,
}

/// Small per-head ensemble: three heads ride along with the format model,
/// so each stays a fraction of its size (the outputs are 2–4-way splits on
/// coarse structure, not a 7-way format call).
fn head_params() -> GbdtParams {
    GbdtParams { n_rounds: 30, max_depth: 3, ..GbdtParams::default() }
}

impl ScheduleHeads {
    /// Predict a schedule from a **normalized** feature vector, with the
    /// weakest head's confidence margin (the plan is only as trustworthy as
    /// its least certain output).
    pub fn predict_with_margin(&self, x: &[f64]) -> (Schedule, f64) {
        let (tile_c, tile_m) = self.tile.predict_with_margin(x);
        let (split_c, split_m) = self.split.predict_with_margin(x);
        let (cap_c, cap_m) = self.threads.predict_with_margin(x);
        let sched = Schedule {
            tile: Tile::from_class(tile_c).unwrap_or(Schedule::default().tile),
            split: Split::from_class(split_c).unwrap_or(Schedule::default().split),
            threads: ThreadCap::from_class(cap_c).unwrap_or(Schedule::default().threads),
        };
        (sched, tile_m.min(split_m).min(cap_m))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tile", self.tile.to_json()),
            ("split", self.split.to_json()),
            ("threads", self.threads.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ScheduleHeads> {
        Ok(ScheduleHeads {
            tile: Gbdt::from_json(j.req("tile")?)?,
            split: Gbdt::from_json(j.req("split")?)?,
            threads: Gbdt::from_json(j.req("threads")?)?,
        })
    }
}

/// A deployable predictor: fitted model + feature normalizer, plus the
/// optional multi-output schedule heads (absent in format-only predictors
/// and in models persisted before the schedule-space PR).
pub struct TrainedPredictor {
    pub model: Gbdt,
    pub norm: Normalizer,
    /// Cross-validated accuracy on the training corpus.
    pub cv_accuracy: f64,
    pub w: f64,
    /// Schedule heads, when trained (see [`train_schedule_heads`]).
    pub schedule_heads: Option<ScheduleHeads>,
}

impl TrainedPredictor {
    /// Predict the storage format for a matrix.
    pub fn predict(&self, coo: &Coo) -> Format {
        self.predict_with_margin(coo).0
    }

    /// Predict plus the calibrated confidence margin (top-1 − top-2 class
    /// probability; see [`crate::ml::gbdt::Gbdt::predict_with_margin`]) —
    /// what the runtime decision cache uses to decline pinning
    /// near-boundary answers.
    pub fn predict_with_margin(&self, coo: &Coo) -> (Format, f64) {
        let raw = extract_features(coo);
        let x = self.norm.transform(&raw);
        let (label, margin) = self.model.predict_with_margin(&x);
        (Format::from_label(label), margin)
    }

    /// Predict the complete execution plan from **one** feature pass:
    /// format from the main model, schedule from the multi-output heads
    /// (process-default schedule at full confidence when no heads are
    /// trained), margin of the weakest output.
    pub fn predict_plan_with_margin(&self, coo: &Coo) -> (Format, Schedule, f64) {
        let raw = extract_features(coo);
        let x = self.norm.transform(&raw);
        let (label, fmt_margin) = self.model.predict_with_margin(&x);
        let (sched, sched_margin) = match &self.schedule_heads {
            Some(heads) => heads.predict_with_margin(&x),
            None => (Schedule::effective(), 1.0),
        };
        (Format::from_label(label), sched, fmt_margin.min(sched_margin))
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model", self.model.to_json()),
            ("norm", self.norm.to_json()),
            ("cv_accuracy", Json::Num(self.cv_accuracy)),
            ("w", Json::Num(self.w)),
        ];
        if let Some(heads) = &self.schedule_heads {
            fields.push(("schedule_heads", heads.to_json()));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TrainedPredictor> {
        Ok(TrainedPredictor {
            model: Gbdt::from_json(j.req("model")?)?,
            norm: Normalizer::from_json(j.req("norm")?)?,
            cv_accuracy: j.req_f64("cv_accuracy").unwrap_or(0.0),
            w: j.req_f64("w").unwrap_or(1.0),
            // Optional: format-only models (and pre-schedule saves) load
            // without heads and predict the default schedule.
            schedule_heads: match j.get("schedule_heads") {
                Some(h) => Some(ScheduleHeads::from_json(h)?),
                None => None,
            },
        })
    }

    /// Crash-safe save: temp-file + atomic rename, so a kill mid-write
    /// can never leave a truncated model behind.
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        crate::util::fsio::atomic_write(path, self.to_json().to_string().as_bytes())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<TrainedPredictor> {
        let text = std::fs::read_to_string(path)?;
        TrainedPredictor::from_json(&Json::parse(&text)?)
    }
}

/// Fit the GBDT on a corpus for weight `w`, reporting k-fold CV accuracy.
/// Format-only (no schedule heads); see [`train_schedule_heads`].
pub fn train_predictor(corpus: &TrainingCorpus, w: f64, seed: u64) -> TrainedPredictor {
    let (data, norm) = corpus.dataset(w);
    let cv_accuracy = cross_validate_gbdt(&data, 5, seed);
    let model = Gbdt::fit(&data, GbdtParams::default());
    TrainedPredictor { model, norm, cv_accuracy, w, schedule_heads: None }
}

/// Fit the multi-output schedule heads on the corpus's measured schedule
/// labels and attach them to `pred` (which supplies the shared normalizer —
/// the heads must see the exact feature distribution the format model
/// sees).
pub fn train_schedule_heads(corpus: &TrainingCorpus, pred: &mut TrainedPredictor) {
    let x: Vec<Vec<f64>> = corpus
        .raw_features
        .iter()
        .map(|r| pred.norm.transform(r).to_vec())
        .collect();
    let labels = corpus.schedule_labels();
    let fit = |y: Vec<usize>, n_classes: usize| {
        Gbdt::fit(&TabularData::new(x.clone(), y, n_classes), head_params())
    };
    pred.schedule_heads = Some(ScheduleHeads {
        tile: fit(labels.iter().map(|s| s.tile.class()).collect(), Tile::ALL.len()),
        split: fit(labels.iter().map(|s| s.split.class()).collect(), Split::ALL.len()),
        threads: fit(labels.iter().map(|s| s.threads.class()).collect(), 2),
    });
}

/// k-fold CV accuracy for the GBDT on a labeled dataset.
pub fn cross_validate_gbdt(data: &TabularData, k: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let folds = kfold(data.len(), k.min(data.len().max(2)), &mut rng);
    let accs: Vec<f64> = folds
        .iter()
        .map(|(train_idx, test_idx)| {
            let train = data.subset(train_idx);
            let test = data.subset(test_idx);
            let model = Gbdt::fit(&train, GbdtParams::default());
            accuracy(&model.predict_batch(&test.x), &test.y)
        })
        .collect();
    crate::util::stats::mean(&accs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_corpus() -> TrainingCorpus {
        TrainingCorpus::build(30, 48, 128, 8, 1, 0x7E57)
    }

    #[test]
    fn corpus_builds_consistently() {
        let c = small_corpus();
        assert_eq!(c.matrices.len(), 30);
        assert_eq!(c.raw_features.len(), 30);
        assert_eq!(c.profiles.len(), 30);
        assert_eq!(c.schedule_profiles.len(), 30);
        assert!(c
            .schedule_profiles
            .iter()
            .all(|p| p.len() == Schedule::CANDIDATES.len()));
        assert_eq!(c.thumbnails.len(), 30);
    }

    #[test]
    fn labels_vary_with_w() {
        let c = small_corpus();
        let speed_labels = c.labels(1.0);
        let mem_labels = c.labels(0.0);
        // Memory optimum is usually CSR/CSC (most compact); speed optimum
        // varies. The two labelings should not be identical.
        assert_ne!(speed_labels, mem_labels, "w should change the labeling");
        let freq = c.label_frequency(1.0);
        let total: usize = freq.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 30);
    }

    #[test]
    fn trained_predictor_beats_chance_and_roundtrips() {
        let c = small_corpus();
        let pred = train_predictor(&c, 1.0, 42);
        // 7 classes → chance ≈ 14%; require clearly better.
        assert!(pred.cv_accuracy > 0.3, "cv accuracy {}", pred.cv_accuracy);
        // Persistence round-trip preserves predictions.
        let j = Json::parse(&pred.to_json().to_string()).unwrap();
        let loaded = TrainedPredictor::from_json(&j).unwrap();
        for m in c.matrices.iter().take(5) {
            assert_eq!(pred.predict(m), loaded.predict(m));
        }
    }

    /// Multi-output heads: trained plans stay inside the knob spaces, the
    /// JSON round trip preserves every head's predictions, and a head-less
    /// save (the pre-schedule model layout) still loads and predicts the
    /// process-default schedule at full confidence.
    #[test]
    fn schedule_heads_predict_and_round_trip() {
        let c = small_corpus();
        let mut pred = train_predictor(&c, 1.0, 42);
        // Format-only predictor: default schedule, fully confident.
        let (_, sched, margin) = pred.predict_plan_with_margin(&c.matrices[0]);
        assert_eq!(sched, Schedule::effective());
        assert_eq!(margin, 1.0);

        train_schedule_heads(&c, &mut pred);
        assert!(pred.schedule_heads.is_some());
        let j = Json::parse(&pred.to_json().to_string()).unwrap();
        let loaded = TrainedPredictor::from_json(&j).unwrap();
        assert!(loaded.schedule_heads.is_some(), "heads must survive the round trip");
        for m in c.matrices.iter().take(8) {
            let (fmt, sched, margin) = pred.predict_plan_with_margin(m);
            assert!(ALL_FORMATS.contains(&fmt));
            assert!(Tile::ALL.contains(&sched.tile));
            assert!(Split::ALL.contains(&sched.split));
            assert!(matches!(sched.threads, ThreadCap::Auto | ThreadCap::Cap(1)));
            assert!((0.0..=1.0).contains(&margin));
            let (lf, ls, lm) = loaded.predict_plan_with_margin(m);
            assert_eq!((lf, ls), (fmt, sched));
            assert!((lm - margin).abs() < 1e-12);
        }

        // Head-less legacy layout: strip the field and reload.
        let mut no_heads = pred;
        no_heads.schedule_heads = None;
        let j = Json::parse(&no_heads.to_json().to_string()).unwrap();
        assert!(!j.to_string().contains("schedule_heads"));
        let legacy = TrainedPredictor::from_json(&j).unwrap();
        assert!(legacy.schedule_heads.is_none());
    }
}
