//! Offline training pipeline (paper §4.3–4.5, Fig. 5):
//! synthetic corpus → exhaustive profiles → Eq-1 labels → feature vectors →
//! min–max normalizer → fitted GBDT. The corpus profiles are computed once
//! and can be re-labeled for any `w` (Figs. 6/10) without re-profiling.

use super::labeler::{label_for, profile_formats, FormatProfile};
use crate::features::{extract_features, Normalizer, N_FEATURES};
use crate::graph::generators::training_corpus;
use crate::ml::gbdt::{Gbdt, GbdtParams};
use crate::ml::metrics::{accuracy, kfold};
use crate::ml::{Classifier, TabularData};
use crate::sparse::{Coo, Format, ALL_FORMATS};
use crate::util::json::Json;
use crate::util::parallel::parallel_map;
use crate::util::rng::Rng;

/// A profiled training corpus: everything needed to build a labeled dataset
/// for any optimization weight `w`.
pub struct TrainingCorpus {
    pub matrices: Vec<Coo>,
    pub raw_features: Vec<[f64; N_FEATURES]>,
    pub profiles: Vec<Vec<FormatProfile>>,
    /// Density thumbnails for the CNN baseline.
    pub thumbnails: Vec<Vec<f32>>,
}

impl TrainingCorpus {
    /// Generate and profile `count` synthetic matrices (paper: 300,
    /// sizes 1k–15k; ours: laptop-scaled sizes, same sparsity band —
    /// DESIGN.md §Substitutions).
    pub fn build(count: usize, min_n: usize, max_n: usize, d: usize, reps: usize, seed: u64) -> TrainingCorpus {
        let mut rng = Rng::new(seed);
        let corpus = training_corpus(&mut rng, count, min_n, max_n);
        let matrices: Vec<Coo> = corpus.into_iter().map(|(m, _)| m).collect();
        // Profile + featurize in parallel across matrices (each profile is
        // itself serial to keep timings clean).
        let profiles: Vec<Vec<FormatProfile>> = matrices
            .iter()
            .map(|m| profile_formats(m, d, reps))
            .collect();
        let raw_features = parallel_map(matrices.len(), |i| extract_features(&matrices[i]));
        let thumbnails = parallel_map(matrices.len(), |i| crate::ml::cnn::thumbnail(&matrices[i]));
        TrainingCorpus { matrices, raw_features, profiles, thumbnails }
    }

    /// Eq-1 labels for a given `w`.
    pub fn labels(&self, w: f64) -> Vec<usize> {
        self.profiles.iter().map(|p| label_for(p, w).label()).collect()
    }

    /// Label frequency per format (Fig. 6 rows).
    pub fn label_frequency(&self, w: f64) -> Vec<(Format, usize)> {
        let labels = self.labels(w);
        ALL_FORMATS
            .iter()
            .map(|&f| (f, labels.iter().filter(|&&l| l == f.label()).count()))
            .collect()
    }

    /// Build the normalized tabular dataset for a given `w`.
    pub fn dataset(&self, w: f64) -> (TabularData, Normalizer) {
        let norm = Normalizer::fit(&self.raw_features);
        let x: Vec<Vec<f64>> = self
            .raw_features
            .iter()
            .map(|r| norm.transform(r).to_vec())
            .collect();
        (TabularData::new(x, self.labels(w), ALL_FORMATS.len()), norm)
    }
}

/// A deployable predictor: fitted model + feature normalizer.
pub struct TrainedPredictor {
    pub model: Gbdt,
    pub norm: Normalizer,
    /// Cross-validated accuracy on the training corpus.
    pub cv_accuracy: f64,
    pub w: f64,
}

impl TrainedPredictor {
    /// Predict the storage format for a matrix.
    pub fn predict(&self, coo: &Coo) -> Format {
        self.predict_with_margin(coo).0
    }

    /// Predict plus the calibrated confidence margin (top-1 − top-2 class
    /// probability; see [`crate::ml::gbdt::Gbdt::predict_with_margin`]) —
    /// what the runtime decision cache uses to decline pinning
    /// near-boundary answers.
    pub fn predict_with_margin(&self, coo: &Coo) -> (Format, f64) {
        let raw = extract_features(coo);
        let x = self.norm.transform(&raw);
        let (label, margin) = self.model.predict_with_margin(&x);
        (Format::from_label(label), margin)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", self.model.to_json()),
            ("norm", self.norm.to_json()),
            ("cv_accuracy", Json::Num(self.cv_accuracy)),
            ("w", Json::Num(self.w)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TrainedPredictor> {
        Ok(TrainedPredictor {
            model: Gbdt::from_json(j.req("model")?)?,
            norm: Normalizer::from_json(j.req("norm")?)?,
            cv_accuracy: j.req_f64("cv_accuracy").unwrap_or(0.0),
            w: j.req_f64("w").unwrap_or(1.0),
        })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<TrainedPredictor> {
        let text = std::fs::read_to_string(path)?;
        TrainedPredictor::from_json(&Json::parse(&text)?)
    }
}

/// Fit the GBDT on a corpus for weight `w`, reporting k-fold CV accuracy.
pub fn train_predictor(corpus: &TrainingCorpus, w: f64, seed: u64) -> TrainedPredictor {
    let (data, norm) = corpus.dataset(w);
    let cv_accuracy = cross_validate_gbdt(&data, 5, seed);
    let model = Gbdt::fit(&data, GbdtParams::default());
    TrainedPredictor { model, norm, cv_accuracy, w }
}

/// k-fold CV accuracy for the GBDT on a labeled dataset.
pub fn cross_validate_gbdt(data: &TabularData, k: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let folds = kfold(data.len(), k.min(data.len().max(2)), &mut rng);
    let accs: Vec<f64> = folds
        .iter()
        .map(|(train_idx, test_idx)| {
            let train = data.subset(train_idx);
            let test = data.subset(test_idx);
            let model = Gbdt::fit(&train, GbdtParams::default());
            accuracy(&model.predict_batch(&test.x), &test.y)
        })
        .collect();
    crate::util::stats::mean(&accs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_corpus() -> TrainingCorpus {
        TrainingCorpus::build(30, 48, 128, 8, 1, 0x7E57)
    }

    #[test]
    fn corpus_builds_consistently() {
        let c = small_corpus();
        assert_eq!(c.matrices.len(), 30);
        assert_eq!(c.raw_features.len(), 30);
        assert_eq!(c.profiles.len(), 30);
        assert_eq!(c.thumbnails.len(), 30);
    }

    #[test]
    fn labels_vary_with_w() {
        let c = small_corpus();
        let speed_labels = c.labels(1.0);
        let mem_labels = c.labels(0.0);
        // Memory optimum is usually CSR/CSC (most compact); speed optimum
        // varies. The two labelings should not be identical.
        assert_ne!(speed_labels, mem_labels, "w should change the labeling");
        let freq = c.label_frequency(1.0);
        let total: usize = freq.iter().map(|(_, c)| c).sum();
        assert_eq!(total, 30);
    }

    #[test]
    fn trained_predictor_beats_chance_and_roundtrips() {
        let c = small_corpus();
        let pred = train_predictor(&c, 1.0, 42);
        // 7 classes → chance ≈ 14%; require clearly better.
        assert!(pred.cv_accuracy > 0.3, "cv accuracy {}", pred.cv_accuracy);
        // Persistence round-trip preserves predictions.
        let j = Json::parse(&pred.to_json().to_string()).unwrap();
        let loaded = TrainedPredictor::from_json(&j).unwrap();
        for m in c.matrices.iter().take(5) {
            assert_eq!(pred.predict(m), loaded.predict(m));
        }
    }
}
