//! Min–max feature normalization (paper §4.4): fit on the training set,
//! scale each feature to [0,1], clip unseen values into range at deployment.

use super::N_FEATURES;
use crate::util::json::Json;
use crate::util::stats::minmax_scale;

/// Per-feature min/max recorded from the training matrices.
#[derive(Clone, Debug, PartialEq)]
pub struct Normalizer {
    pub lo: [f64; N_FEATURES],
    pub hi: [f64; N_FEATURES],
}

impl Normalizer {
    /// Fit bounds on a training set of raw feature vectors.
    pub fn fit(samples: &[[f64; N_FEATURES]]) -> Normalizer {
        let mut lo = [f64::INFINITY; N_FEATURES];
        let mut hi = [f64::NEG_INFINITY; N_FEATURES];
        for s in samples {
            for j in 0..N_FEATURES {
                lo[j] = lo[j].min(s[j]);
                hi[j] = hi[j].max(s[j]);
            }
        }
        if samples.is_empty() {
            lo = [0.0; N_FEATURES];
            hi = [1.0; N_FEATURES];
        }
        Normalizer { lo, hi }
    }

    /// Scale (and clip) a raw feature vector into [0,1]^19.
    pub fn transform(&self, raw: &[f64; N_FEATURES]) -> [f64; N_FEATURES] {
        let mut out = [0.0; N_FEATURES];
        for j in 0..N_FEATURES {
            out[j] = minmax_scale(raw[j], self.lo[j], self.hi[j]);
        }
        out
    }

    pub fn transform_all(&self, raws: &[[f64; N_FEATURES]]) -> Vec<[f64; N_FEATURES]> {
        raws.iter().map(|r| self.transform(r)).collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lo", Json::num_arr(self.lo.iter())),
            ("hi", Json::num_arr(self.hi.iter())),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Normalizer> {
        let mut out = Normalizer { lo: [0.0; N_FEATURES], hi: [1.0; N_FEATURES] };
        for (arr, dst) in [("lo", &mut out.lo), ("hi", &mut out.hi)] {
            let vals = j.req_arr(arr)?;
            anyhow::ensure!(vals.len() == N_FEATURES, "normalizer arity");
            for (d, v) in dst.iter_mut().zip(vals) {
                *d = v.as_f64().ok_or_else(|| anyhow::anyhow!("non-numeric bound"))?;
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecn(v: f64) -> [f64; N_FEATURES] {
        [v; N_FEATURES]
    }

    #[test]
    fn fit_transform_in_unit_range() {
        let samples = vec![vecn(0.0), vecn(10.0), vecn(5.0)];
        let norm = Normalizer::fit(&samples);
        let t = norm.transform(&vecn(5.0));
        assert!(t.iter().all(|&v| (v - 0.5).abs() < 1e-12));
    }

    #[test]
    fn clips_out_of_range() {
        let norm = Normalizer::fit(&[vecn(0.0), vecn(1.0)]);
        assert!(norm.transform(&vecn(9.0)).iter().all(|&v| v == 1.0));
        assert!(norm.transform(&vecn(-9.0)).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn degenerate_feature_maps_to_zero() {
        let norm = Normalizer::fit(&[vecn(3.0), vecn(3.0)]);
        assert!(norm.transform(&vecn(3.0)).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn json_roundtrip() {
        let norm = Normalizer::fit(&[vecn(-2.0), vecn(7.0)]);
        let j = norm.to_json();
        let back = Normalizer::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(norm, back);
    }
}
