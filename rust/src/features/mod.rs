//! Matrix feature extraction — the paper's Table 2 (F1–F19).
//!
//! Features capture the non-zero distribution cheaply enough to run before
//! every GNN layer; extraction is parallelized across rows/nnz exactly as
//! the paper does ("our feature extraction process runs in parallel using
//! all CPU cores"), and its cost is charged to end-to-end time.

pub mod normalize;

pub use normalize::Normalizer;

use crate::sparse::Coo;
use crate::util::parallel::{num_threads, split_ranges};

/// Number of features (paper Table 2).
pub const N_FEATURES: usize = 19;

/// Feature names, index-aligned with the extracted vector.
pub const FEATURE_NAMES: [&str; N_FEATURES] = [
    "numRow",     // F1
    "numCol",     // F2
    "NNZ",        // F3
    "N_diags",    // F4
    "aver_RD",    // F5
    "max_RD",     // F6
    "min_RD",     // F7
    "dev_RD",     // F8
    "aver_CD",    // F9
    "max_CD",     // F10
    "min_CD",     // F11
    "dev_CD",     // F12
    "ER_DIA",     // F13
    "ER_CD",      // F14
    "row_bounce", // F15
    "col_bounce", // F16
    "density",    // F17
    "cv",         // F18
    "max_mu",     // F19
];

/// Extract the 19 Table-2 features from a COO view.
///
/// Row/column count statistics and the occupied-diagonal bitmap are built
/// with per-thread partials over nnz chunks, then reduced.
pub fn extract_features(m: &Coo) -> [f64; N_FEATURES] {
    let rows = m.rows.max(1);
    let cols = m.cols.max(1);
    let nnz = m.nnz();

    // Parallel partial histograms over the triple list, one chunk per pool
    // executor (no thread is spawned — the pool's parked workers run the
    // chunks; see `util::pool`).
    let nt = num_threads();
    let chunks = split_ranges(nnz, nt);
    struct Partial {
        row_counts: Vec<u32>,
        col_counts: Vec<u32>,
        diag_bits: Vec<u64>,
    }
    let n_diag_slots = rows + cols - 1;
    let partials: Vec<Partial> =
        crate::util::parallel::parallel_map(chunks.len(), |ci| {
            let mut p = Partial {
                row_counts: vec![0u32; rows],
                col_counts: vec![0u32; cols],
                diag_bits: vec![0u64; n_diag_slots.div_ceil(64)],
            };
            for i in chunks[ci].clone() {
                let r = m.row[i] as usize;
                let c = m.col[i] as usize;
                p.row_counts[r] += 1;
                p.col_counts[c] += 1;
                // diagonal id: col - row + (rows-1) ∈ [0, rows+cols-2]
                let d = c + rows - 1 - r;
                p.diag_bits[d / 64] |= 1u64 << (d % 64);
            }
            p
        });

    let mut row_counts = vec![0u32; rows];
    let mut col_counts = vec![0u32; cols];
    let mut diag_bits = vec![0u64; n_diag_slots.div_ceil(64)];
    for p in &partials {
        for (a, &b) in row_counts.iter_mut().zip(p.row_counts.iter()) {
            *a += b;
        }
        for (a, &b) in col_counts.iter_mut().zip(p.col_counts.iter()) {
            *a += b;
        }
        for (a, &b) in diag_bits.iter_mut().zip(p.diag_bits.iter()) {
            *a |= b;
        }
    }

    let n_diags = diag_bits.iter().map(|w| w.count_ones() as usize).sum::<usize>();

    let rd_stats = count_stats(&row_counts);
    let cd_stats = count_stats(&col_counts);

    // F13 ER_DIA: efficiency if stored as DIA — fraction of the DIA
    // storage (n_diags × rows) that holds real non-zeros.
    let er_dia = if n_diags == 0 {
        0.0
    } else {
        nnz as f64 / (n_diags as f64 * rows as f64)
    };
    // F14 ER_CD: efficiency if rows are packed to max_RD width (ELL-style
    // column-packed structure).
    let er_cd = if rd_stats.max == 0.0 {
        0.0
    } else {
        nnz as f64 / (rd_stats.max * rows as f64)
    };

    let row_bounce = bounce(&row_counts);
    let col_bounce = bounce(&col_counts);

    let density = nnz as f64 / (rows as f64 * cols as f64);
    let cv = if rd_stats.mean > 0.0 { rd_stats.dev / rd_stats.mean } else { 0.0 };
    let max_mu = rd_stats.max - rd_stats.mean;

    [
        rows as f64,
        cols as f64,
        nnz as f64,
        n_diags as f64,
        rd_stats.mean,
        rd_stats.max,
        rd_stats.min,
        rd_stats.dev,
        cd_stats.mean,
        cd_stats.max,
        cd_stats.min,
        cd_stats.dev,
        er_dia,
        er_cd,
        row_bounce,
        col_bounce,
        density,
        cv,
        max_mu,
    ]
}

struct CountStats {
    mean: f64,
    max: f64,
    min: f64,
    dev: f64,
}

fn count_stats(counts: &[u32]) -> CountStats {
    if counts.is_empty() {
        return CountStats { mean: 0.0, max: 0.0, min: 0.0, dev: 0.0 };
    }
    let n = counts.len() as f64;
    let sum: u64 = counts.iter().map(|&c| c as u64).sum();
    let mean = sum as f64 / n;
    let max = counts.iter().max().copied().unwrap_or(0) as f64;
    let min = counts.iter().min().copied().unwrap_or(0) as f64;
    let var = counts
        .iter()
        .map(|&c| {
            let d = c as f64 - mean;
            d * d
        })
        .sum::<f64>()
        / n;
    CountStats { mean, max, min, dev: var.sqrt() }
}

/// Mean |count[i+1] - count[i]| between adjacent rows/columns (F15/F16).
fn bounce(counts: &[u32]) -> f64 {
    if counts.len() < 2 {
        return 0.0;
    }
    counts
        .windows(2)
        .map(|w| (w[0] as f64 - w[1] as f64).abs())
        .sum::<f64>()
        / (counts.len() - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert};
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, max_dim: usize) -> Coo {
        let rows = 2 + rng.gen_range(max_dim);
        let cols = 2 + rng.gen_range(max_dim);
        let density = rng.uniform(0.02, 0.5);
        let mut triples = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, 1.0f32));
                }
            }
        }
        Coo::from_triples(rows, cols, triples)
    }

    #[test]
    fn identity_matrix_features() {
        let n = 16;
        let triples: Vec<_> = (0..n).map(|i| (i as u32, i as u32, 1.0f32)).collect();
        let coo = Coo::from_triples(n, n, triples);
        let f = extract_features(&coo);
        assert_eq!(f[0], n as f64); // numRow
        assert_eq!(f[1], n as f64); // numCol
        assert_eq!(f[2], n as f64); // NNZ
        assert_eq!(f[3], 1.0); // single diagonal
        assert_eq!(f[4], 1.0); // aver_RD
        assert_eq!(f[5], 1.0); // max_RD
        assert_eq!(f[6], 1.0); // min_RD
        assert_eq!(f[7], 0.0); // dev_RD
        assert!((f[12] - 1.0).abs() < 1e-12); // ER_DIA perfect
        assert!((f[13] - 1.0).abs() < 1e-12); // ER_CD perfect
        assert_eq!(f[14], 0.0); // row_bounce
        assert!((f[16] - 1.0 / n as f64).abs() < 1e-12); // density
        assert_eq!(f[17], 0.0); // cv
        assert_eq!(f[18], 0.0); // max_mu
    }

    #[test]
    fn prop_feature_invariants() {
        check(
            30,
            |rng| random_coo(rng, 48),
            |coo| {
                let f = extract_features(coo);
                prop_assert(f.iter().all(|v| v.is_finite()), "all finite")?;
                prop_assert(f[2] as usize == coo.nnz(), "NNZ matches")?;
                prop_assert(f[6] <= f[4] && f[4] <= f[5], "min_RD ≤ aver_RD ≤ max_RD")?;
                prop_assert(f[10] <= f[8] && f[8] <= f[9], "min_CD ≤ aver_CD ≤ max_CD")?;
                prop_assert((0.0..=1.0).contains(&f[12]), "ER_DIA in [0,1]")?;
                prop_assert((0.0..=1.0).contains(&f[13]), "ER_CD in [0,1]")?;
                prop_assert((0.0..=1.0).contains(&f[16]), "density in [0,1]")?;
                prop_assert(f[18] >= 0.0, "max_mu ≥ 0")?;
                let max_diags = coo.rows + coo.cols - 1;
                prop_assert(f[3] as usize <= max_diags, "diags bounded")?;
                Ok(())
            },
        );
    }

    #[test]
    fn transpose_swaps_row_col_features() {
        let mut rng = Rng::new(3);
        let coo = random_coo(&mut rng, 32);
        let f = extract_features(&coo);
        let ft = extract_features(&coo.transpose());
        assert_eq!(f[0], ft[1]);
        assert_eq!(f[1], ft[0]);
        assert_eq!(f[2], ft[2]);
        // RD stats of A = CD stats of Aᵀ
        assert!((f[4] - ft[8]).abs() < 1e-12);
        assert!((f[5] - ft[9]).abs() < 1e-12);
        assert!((f[7] - ft[11]).abs() < 1e-12);
    }

    #[test]
    fn empty_matrix_is_safe() {
        let coo = Coo::from_triples(4, 4, vec![]);
        let f = extract_features(&coo);
        assert!(f.iter().all(|v| v.is_finite()));
        assert_eq!(f[2], 0.0);
        assert_eq!(f[3], 0.0);
    }
}
