//! Sparse-matrix substrate: the seven storage formats the paper studies
//! (§2.2 — COO, CSR, CSC, DIA, BSR, DOK, LIL), conversions between them, and
//! parallel SpMM kernels (`sparse · dense → dense`, both `A·X` and the
//! transpose-free `Aᵀ·X`) per format, unified behind the [`ops::SparseOps`]
//! trait with output-buffer-taking `*_into` variants (DESIGN.md §SparseOps).
//!
//! Design notes:
//! * [`coo::Coo`] is the canonical interchange carrier: sorted row-major
//!   triples, no duplicates, no explicit zeros. Every format converts
//!   to/from COO; hot direct paths (CSR↔CSC) bypass the hub.
//! * Each format reports a memory-footprint model ([`format::SparseMatrix::nbytes`])
//!   mirroring scipy's relative ordering — the `M` term of the paper's Eq. 1.
//! * Formats whose storage blows up on a given matrix (DIA on scattered
//!   patterns) return an error from conversion instead of OOMing; the
//!   labeler treats that as "worst case", which matches how the paper's
//!   exhaustive profiling would score them.

pub mod ops;
pub mod coo;
pub mod csr;
pub mod csc;
pub mod dia;
pub mod bsr;
pub mod dok;
pub mod lil;
pub mod format;
pub mod schedule;
pub mod shared;
pub mod validate;

pub use coo::Coo;
pub use csr::Csr;
pub use csc::Csc;
pub use dia::Dia;
pub use bsr::Bsr;
pub use dok::Dok;
pub use lil::Lil;
pub use format::{Format, SparseMatrix, ALL_FORMATS};
pub use ops::{coo_fallback_extractions, SparseOps};
pub use schedule::{Schedule, Split, ThreadCap, Tile};
pub use shared::{EpochCell, SharedMatrix, WeakMatrix};
pub use validate::FormatError;
