//! Compressed sparse column (CSC). Column-major traversal makes its SpMM
//! scatter into output rows — each worker accumulates a private output
//! buffer over its column span, then buffers are reduced. This mirrors why
//! CSC trails CSR on row-major outputs yet wins when column locality
//! dominates (paper Fig. 3a).

use super::coo::Coo;
use crate::tensor::Matrix;
use crate::util::parallel::{num_threads, split_ranges};

/// CSC sparse matrix: `indptr[c]..indptr[c+1]` spans column `c`'s entries in
/// `indices` (row ids, ascending within a column) and `vals`.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csc {
    pub fn from_coo(coo: &Coo) -> Csc {
        // Counting sort by column (COO is row-major, so within a column the
        // row ids come out ascending — scipy's canonical CSC ordering).
        let mut indptr = vec![0usize; coo.cols + 1];
        for &c in &coo.col {
            indptr[c as usize + 1] += 1;
        }
        for i in 0..coo.cols {
            indptr[i + 1] += indptr[i];
        }
        let mut indices = vec![0u32; coo.nnz()];
        let mut vals = vec![0f32; coo.nnz()];
        let mut next = indptr.clone();
        for i in 0..coo.nnz() {
            let c = coo.col[i] as usize;
            let slot = next[c];
            indices[slot] = coo.row[i];
            vals[slot] = coo.val[i];
            next[c] += 1;
        }
        Csc { rows: coo.rows, cols: coo.cols, indptr, indices, vals }
    }

    pub fn to_coo(&self) -> Coo {
        let mut triples = Vec::with_capacity(self.nnz());
        for c in 0..self.cols {
            for i in self.indptr[c]..self.indptr[c + 1] {
                triples.push((self.indices[i], c as u32, self.vals[i]));
            }
        }
        Coo::from_triples(self.rows, self.cols, triples)
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Footprint model: symmetric to CSR with a column pointer array.
    pub fn nbytes(&self) -> usize {
        self.nnz() * 8 + (self.cols + 1) * 8
    }

    /// SpMM `self (n×m) · x (m×d) → (n×d)`.
    ///
    /// Threads own disjoint **column** spans; each accumulates a private
    /// `n×d` buffer (`y[i] += v * x[c]` for entries `(i, v)` of column `c`),
    /// then the buffers are summed. The extra reduction is CSC's intrinsic
    /// cost for row-major output.
    pub fn spmm(&self, x: &Matrix) -> Matrix {
        assert_eq!(self.cols, x.rows, "spmm shape mismatch");
        let d = x.cols;
        let n = self.rows;
        let nt = num_threads().min(self.cols.max(1));
        let ranges = split_ranges(self.cols, nt);
        let partials: Vec<Vec<f32>> = std::thread::scope(|s| {
            let handles: Vec<_> = ranges
                .into_iter()
                .map(|range| {
                    s.spawn(move || {
                        let mut buf = vec![0f32; n * d];
                        for c in range {
                            let x_row = x.row(c);
                            for i in self.indptr[c]..self.indptr[c + 1] {
                                let r = self.indices[i] as usize;
                                let v = self.vals[i];
                                let out_row = &mut buf[r * d..(r + 1) * d];
                                for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                                    *o += v * xv;
                                }
                            }
                        }
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut out = Matrix::zeros(n, d);
        // Parallel reduction over output rows.
        let parts = &partials;
        let out_data = &mut out.data;
        crate::util::parallel::parallel_fill_rows(out_data, n, d, |range, chunk| {
            let lo = range.start * d;
            let len = chunk.len();
            for buf in parts {
                for (o, &v) in chunk.iter_mut().zip(buf[lo..lo + len].iter()) {
                    *o += v;
                }
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Coo {
        let mut triples = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, rng.uniform(-1.0, 1.0) as f32));
                }
            }
        }
        Coo::from_triples(rows, cols, triples)
    }

    #[test]
    fn coo_roundtrip() {
        let mut rng = Rng::new(1);
        let coo = random_coo(&mut rng, 19, 13, 0.15);
        let csc = Csc::from_coo(&coo);
        assert_eq!(csc.to_coo(), coo);
    }

    #[test]
    fn rows_ascending_within_column() {
        let mut rng = Rng::new(2);
        let csc = Csc::from_coo(&random_coo(&mut rng, 25, 25, 0.2));
        for c in 0..25 {
            let span = &csc.indices[csc.indptr[c]..csc.indptr[c + 1]];
            for w in span.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Rng::new(3);
        for &(n, m, d) in &[(5usize, 7usize, 3usize), (33, 47, 8), (64, 64, 16)] {
            let coo = random_coo(&mut rng, n, m, 0.15);
            let csc = Csc::from_coo(&coo);
            let x = Matrix::rand(m, d, &mut rng);
            let want = coo.to_dense().matmul(&x);
            assert!(csc.spmm(&x).max_abs_diff(&want) < 1e-4, "({n},{m},{d})");
        }
    }

    #[test]
    fn tall_skinny_and_wide() {
        let mut rng = Rng::new(4);
        for &(n, m) in &[(100usize, 3usize), (3, 100)] {
            let coo = random_coo(&mut rng, n, m, 0.3);
            let csc = Csc::from_coo(&coo);
            let x = Matrix::rand(m, 4, &mut rng);
            let want = coo.to_dense().matmul(&x);
            assert!(csc.spmm(&x).max_abs_diff(&want) < 1e-4);
        }
    }
}
