//! Compressed sparse column (CSC). Column-major traversal makes its SpMM
//! scatter into output rows — each worker accumulates a private output
//! buffer over its column span, then buffers are reduced. This mirrors why
//! CSC trails CSR on row-major outputs yet wins when column locality
//! dominates (paper Fig. 3a).

use super::coo::Coo;
use super::ops::{check_into_shapes, gather_row_lanes, scatter_reduce_into, SparseOps};
use super::schedule::{Schedule, Split, Tile};
use crate::tensor::Matrix;
use crate::util::parallel::{even_range, indptr_span, parallel_fill_rows_spans};

/// CSC sparse matrix: `indptr[c]..indptr[c+1]` spans column `c`'s entries in
/// `indices` (row ids, ascending within a column) and `vals`.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csc {
    pub fn from_coo(coo: &Coo) -> Csc {
        // Counting sort by column (COO is row-major, so within a column the
        // row ids come out ascending — scipy's canonical CSC ordering).
        let mut indptr = vec![0usize; coo.cols + 1];
        for &c in &coo.col {
            indptr[c as usize + 1] += 1;
        }
        for i in 0..coo.cols {
            indptr[i + 1] += indptr[i];
        }
        let mut indices = vec![0u32; coo.nnz()];
        let mut vals = vec![0f32; coo.nnz()];
        let mut next = indptr.clone();
        for i in 0..coo.nnz() {
            let c = coo.col[i] as usize;
            let slot = next[c];
            indices[slot] = coo.row[i];
            vals[slot] = coo.val[i];
            next[c] += 1;
        }
        Csc { rows: coo.rows, cols: coo.cols, indptr, indices, vals }
    }

    pub fn to_coo(&self) -> Coo {
        let mut triples = Vec::with_capacity(self.nnz());
        for c in 0..self.cols {
            for i in self.indptr[c]..self.indptr[c + 1] {
                triples.push((self.indices[i], c as u32, self.vals[i]));
            }
        }
        Coo::from_triples(self.rows, self.cols, triples)
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Footprint model: symmetric to CSR with a column pointer array.
    pub fn nbytes(&self) -> usize {
        self.nnz() * 8 + (self.cols + 1) * 8
    }

    /// SpMM `self (n×m) · x (m×d) → out (n×d)` into a caller-provided
    /// buffer.
    ///
    /// Tasks own disjoint **column** spans (nnz-balanced or even per the
    /// [`Schedule`]); each accumulates a pool-owned `n×d` scratch buffer
    /// (`y[i] += v * x[c]` for entries `(i, v)` of column `c`), then the
    /// buffers are summed. The extra reduction is CSC's intrinsic cost for
    /// row-major output. Runs under the process-wide default schedule.
    // lint: begin(hot-path)
    pub fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        self.spmm_into_sched(x, out, Schedule::effective());
    }

    /// Schedule-parameterized [`Csc::spmm_into`]. The scatter kernel has no
    /// gather tile, so only the split rule and thread cap apply.
    pub fn spmm_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        check_into_shapes(self.rows, self.cols, x, out);
        let d = x.cols;
        let k = sched.tasks_for(self.cols);
        let span_of = |i| match sched.split {
            Split::NnzBalanced => indptr_span(&self.indptr, k, i),
            Split::EvenUnits => even_range(self.cols, k, i),
        };
        scatter_reduce_into(out, k, span_of, |cols, buf| {
            for c in cols {
                let x_row = x.row(c);
                for i in self.indptr[c]..self.indptr[c + 1] {
                    let r = self.indices[i] as usize;
                    let v = self.vals[i];
                    let out_row = &mut buf[r * d..(r + 1) * d];
                    for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                        *o += v * xv;
                    }
                }
            }
        });
    }
    // lint: end(hot-path)

    /// Allocating SpMM wrapper.
    pub fn spmm(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, x.cols);
        self.spmm_into(x, &mut out);
        out
    }

    /// Transpose-SpMM `selfᵀ (m×n) · x (n×d) → out (m×d)` — transpose-free.
    ///
    /// CSR↔CSC duality in the other direction: the CSC arrays of `A` are the
    /// CSR arrays of `Aᵀ`, so `Aᵀ·X` runs as a CSR-style **gather** — each
    /// output row `c` sums `vals[i] · x[indices[i]]` over column `c`'s span.
    /// This is the cheap direction: parallel over column spans, no
    /// reduction needed, and feature-tiled like the CSR forward kernel.
    /// Runs under the process-wide default [`Schedule`].
    // lint: begin(hot-path)
    pub fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        self.spmm_t_into_sched(x, out, Schedule::effective());
    }

    /// Schedule-parameterized [`Csc::spmm_t_into`]: tile width picks a
    /// monomorphized gather instantiation (dispatched once per call), split
    /// rule picks nnz-balanced vs even column spans, thread cap folds into
    /// the task count.
    pub fn spmm_t_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        match sched.tile {
            Tile::T4 => self.spmm_t_into_lanes::<4>(x, out, sched),
            Tile::T8 => self.spmm_t_into_lanes::<8>(x, out, sched),
            Tile::T16 => self.spmm_t_into_lanes::<16>(x, out, sched),
            Tile::T32 => self.spmm_t_into_lanes::<32>(x, out, sched),
        }
    }

    fn spmm_t_into_lanes<const L: usize>(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        check_into_shapes(self.cols, self.rows, x, out);
        let d = x.cols;
        let k = sched.tasks_for(self.cols);
        parallel_fill_rows_spans(
            &mut out.data,
            self.cols,
            d,
            k,
            |i| match sched.split {
                Split::NnzBalanced => indptr_span(&self.indptr, k, i),
                Split::EvenUnits => even_range(self.cols, k, i),
            },
            |range, chunk| {
                for (cc, c) in range.clone().enumerate() {
                    let out_row = &mut chunk[cc * d..(cc + 1) * d];
                    let span = self.indptr[c]..self.indptr[c + 1];
                    gather_row_lanes::<L>(
                        out_row,
                        x,
                        &self.indices[span.clone()],
                        &self.vals[span],
                    );
                }
            },
        );
    }
    // lint: end(hot-path)

    /// Induced submatrix `self[rows, cols]` for sorted, duplicate-free id
    /// selections, extracted **directly on the CSC arrays** (mirror of
    /// [`super::Csr::extract_rows_cols`]): one pass over the selected
    /// columns' spans, row ids re-indexed by binary search into `rows`
    /// (skipped when `rows` selects every row). No COO round-trip.
    pub fn extract_rows_cols(&self, rows: &[u32], cols: &[u32]) -> Csc {
        super::ops::debug_assert_selection(rows, self.rows, "row");
        super::ops::debug_assert_selection(cols, self.cols, "col");
        let all_rows = rows.len() == self.rows;
        let mut indptr = Vec::with_capacity(cols.len() + 1);
        indptr.push(0usize);
        let mut indices = Vec::new();
        let mut vals = Vec::new();
        for &old_c in cols {
            let span = self.indptr[old_c as usize]..self.indptr[old_c as usize + 1];
            if all_rows {
                indices.extend_from_slice(&self.indices[span.clone()]);
                vals.extend_from_slice(&self.vals[span]);
            } else {
                for i in span {
                    if let Ok(nr) = rows.binary_search(&self.indices[i]) {
                        indices.push(nr as u32);
                        vals.push(self.vals[i]);
                    }
                }
            }
            indptr.push(indices.len());
        }
        Csc { rows: rows.len(), cols: cols.len(), indptr, indices, vals }
    }

    /// Direct CSC→CSR conversion by counting sort over rows (mirror of
    /// [`super::Csr::to_csc`]; skips the COO hub).
    pub fn to_csr(&self) -> super::csr::Csr {
        let mut rowptr = vec![0usize; self.rows + 1];
        for &r in &self.indices {
            rowptr[r as usize + 1] += 1;
        }
        for i in 0..self.rows {
            rowptr[i + 1] += rowptr[i];
        }
        let mut indices = vec![0u32; self.nnz()];
        let mut vals = vec![0f32; self.nnz()];
        let mut next = rowptr.clone();
        for c in 0..self.cols {
            for i in self.indptr[c]..self.indptr[c + 1] {
                let r = self.indices[i] as usize;
                let slot = next[r];
                indices[slot] = c as u32;
                vals[slot] = self.vals[i];
                next[r] += 1;
            }
        }
        super::csr::Csr {
            rows: self.rows,
            cols: self.cols,
            indptr: rowptr,
            indices,
            vals,
        }
    }

    /// Direct structural transpose: the CSR arrays of `self` (via
    /// [`Csc::to_csr`]) reinterpreted as the CSC arrays of `selfᵀ`.
    pub fn transpose(&self) -> Csc {
        let csr = self.to_csr();
        Csc {
            rows: csr.cols,
            cols: csr.rows,
            indptr: csr.indptr,
            indices: csr.indices,
            vals: csr.vals,
        }
    }
}

impl SparseOps for Csc {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        Csc::nnz(self)
    }
    fn nbytes(&self) -> usize {
        Csc::nbytes(self)
    }
    fn to_coo(&self) -> Coo {
        Csc::to_coo(self)
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        Csc::spmm_into(self, x, out)
    }
    fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        Csc::spmm_t_into(self, x, out)
    }
    fn spmm_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        Csc::spmm_into_sched(self, x, out, sched)
    }
    fn spmm_t_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        Csc::spmm_t_into_sched(self, x, out, sched)
    }
    fn extract_rows_cols(&self, rows: &[u32], cols: &[u32]) -> super::SparseMatrix {
        super::SparseMatrix::Csc(Csc::extract_rows_cols(self, rows, cols))
    }
    fn row_sums(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.rows];
        for (i, &r) in self.indices.iter().enumerate() {
            out[r as usize] += self.vals[i];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Coo {
        let mut triples = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, rng.uniform(-1.0, 1.0) as f32));
                }
            }
        }
        Coo::from_triples(rows, cols, triples)
    }

    #[test]
    fn coo_roundtrip() {
        let mut rng = Rng::new(1);
        let coo = random_coo(&mut rng, 19, 13, 0.15);
        let csc = Csc::from_coo(&coo);
        assert_eq!(csc.to_coo(), coo);
    }

    #[test]
    fn rows_ascending_within_column() {
        let mut rng = Rng::new(2);
        let csc = Csc::from_coo(&random_coo(&mut rng, 25, 25, 0.2));
        for c in 0..25 {
            let span = &csc.indices[csc.indptr[c]..csc.indptr[c + 1]];
            for w in span.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Rng::new(3);
        for &(n, m, d) in &[(5usize, 7usize, 3usize), (33, 47, 8), (64, 64, 16)] {
            let coo = random_coo(&mut rng, n, m, 0.15);
            let csc = Csc::from_coo(&coo);
            let x = Matrix::rand(m, d, &mut rng);
            let want = coo.to_dense().matmul(&x);
            assert!(csc.spmm(&x).max_abs_diff(&want) < 1e-4, "({n},{m},{d})");
        }
    }

    #[test]
    fn spmm_t_matches_transposed_dense() {
        let mut rng = Rng::new(5);
        for &(n, m, d) in &[(5usize, 7usize, 3usize), (33, 47, 8), (64, 64, 16)] {
            let coo = random_coo(&mut rng, n, m, 0.15);
            let csc = Csc::from_coo(&coo);
            let x = Matrix::rand(n, d, &mut rng);
            let want = coo.to_dense().transpose().matmul(&x);
            let mut out = Matrix::full(m, d, 123.0); // stale garbage
            csc.spmm_t_into(&x, &mut out);
            assert!(out.max_abs_diff(&want) < 1e-4, "({n},{m},{d})");
        }
    }

    #[test]
    fn to_csr_and_transpose_match_hub() {
        let mut rng = Rng::new(6);
        let coo = random_coo(&mut rng, 23, 31, 0.12);
        let csc = Csc::from_coo(&coo);
        assert_eq!(csc.to_csr(), super::super::csr::Csr::from_coo(&coo));
        assert_eq!(csc.transpose().to_coo(), coo.transpose());
    }

    #[test]
    fn tall_skinny_and_wide() {
        let mut rng = Rng::new(4);
        for &(n, m) in &[(100usize, 3usize), (3, 100)] {
            let coo = random_coo(&mut rng, n, m, 0.3);
            let csc = Csc::from_coo(&coo);
            let x = Matrix::rand(m, 4, &mut rng);
            let want = coo.to_dense().matmul(&x);
            assert!(csc.spmm(&x).max_abs_diff(&want) < 1e-4);
        }
    }
}
