//! Kernel **schedule** parameterization for the SpMM hot path.
//!
//! The paper predicts the *format*; ParamSpMM (arXiv:2605.15695) and
//! GE-SpMM (arXiv:2007.03179) show the *kernel schedule* — feature-tile
//! width, work-partitioning rule and thread count — matters just as much on
//! skewed real-world graphs. A [`Schedule`] bundles the three knobs our
//! kernels used to hard-code:
//!
//! * [`Tile`] — feature-dimension tile width of the gather kernels
//!   (CSR `A·X`, CSC `Aᵀ·X`, LIL `A·X`). Const-generic lane counts
//!   (4/8/16/32) are monomorphized per kernel call, so the inner non-zero
//!   loop carries **no per-row branching**: the one `match` per call sits
//!   outside the row loop and selects a fully specialized instantiation.
//! * [`Split`] — how source units (rows / columns / block rows) are
//!   partitioned across pool tasks: nnz-balanced quantiles
//!   (`indptr_span` / the COO row-quantile rule) or plain even unit counts.
//!   Even splitting skips the quantile binary searches and wins on uniform
//!   graphs; nnz balancing wins under power-law skew.
//! * [`ThreadCap`] — an optional per-call cap on pool parallelism. The cap
//!   folds into the task count `k` each kernel hands `util::pool`
//!   ([`Schedule::tasks_for`]); a capped count of 1 takes the pool's inline
//!   serial path (no lease, no scratch), which beats dispatch overhead on
//!   tiny matrices.
//!
//! [`Schedule::default`] reproduces the pre-schedule kernels exactly
//! (16 lanes, nnz-balanced, uncapped). `GNN_SPMM_SCHEDULE` overrides the
//! default process-wide (resolved once, like `GNN_SPMM_THREADS`) so CI can
//! force every kernel through a non-default variant.

use std::sync::OnceLock;

/// Feature-dimension tile width (f32 lanes) for the gather kernels. Each
/// width is a distinct monomorphization of the gather loop — see
/// `ops::gather_row_lanes`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Tile {
    T4,
    T8,
    T16,
    T32,
}

impl Tile {
    /// Every tile width, in class-index order (the multi-output predictor's
    /// label space for this output).
    pub const ALL: [Tile; 4] = [Tile::T4, Tile::T8, Tile::T16, Tile::T32];

    /// Lane count of this tile.
    #[inline]
    pub fn lanes(self) -> usize {
        match self {
            Tile::T4 => 4,
            Tile::T8 => 8,
            Tile::T16 => 16,
            Tile::T32 => 32,
        }
    }

    /// Inverse of [`Tile::lanes`].
    pub fn from_lanes(lanes: usize) -> Option<Tile> {
        Tile::ALL.into_iter().find(|t| t.lanes() == lanes)
    }

    /// Class index in [`Tile::ALL`] (predictor label).
    pub fn class(self) -> usize {
        match self {
            Tile::T4 => 0,
            Tile::T8 => 1,
            Tile::T16 => 2,
            Tile::T32 => 3,
        }
    }

    /// Inverse of [`Tile::class`].
    pub fn from_class(c: usize) -> Option<Tile> {
        Tile::ALL.get(c).copied()
    }
}

/// Work-partitioning rule: how a kernel splits its source units across pool
/// tasks.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Split {
    /// Quantiles of cumulative non-zero count (`indptr_span` /
    /// `split_ranges_by_weight`): every task carries an equal share of
    /// multiply-adds even when hub units dominate.
    NnzBalanced,
    /// Near-equal unit counts (`even_range`): no quantile search, optimal
    /// when per-unit work is uniform.
    EvenUnits,
}

impl Split {
    /// Both rules, in class-index order.
    pub const ALL: [Split; 2] = [Split::NnzBalanced, Split::EvenUnits];

    /// Stable short name (cache JSON / bench keys / env override).
    pub fn name(self) -> &'static str {
        match self {
            Split::NnzBalanced => "nnz",
            Split::EvenUnits => "even",
        }
    }

    /// Inverse of [`Split::name`].
    pub fn from_name(s: &str) -> Option<Split> {
        Split::ALL.into_iter().find(|sp| sp.name() == s)
    }

    /// Class index in [`Split::ALL`] (predictor label).
    pub fn class(self) -> usize {
        match self {
            Split::NnzBalanced => 0,
            Split::EvenUnits => 1,
        }
    }

    /// Inverse of [`Split::class`].
    pub fn from_class(c: usize) -> Option<Split> {
        Split::ALL.get(c).copied()
    }
}

/// Optional per-call cap on pool parallelism. Encoded as `0` (= no cap) or
/// the cap value in cache JSON and the env override.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ThreadCap {
    /// Use the pool's full thread budget.
    Auto,
    /// Use at most this many executors (≥ 1; a cap of 1 runs the kernel on
    /// the pool's inline serial path).
    Cap(usize),
}

impl ThreadCap {
    /// Executors to use given the pool's `avail` threads (always ≥ 1).
    #[inline]
    pub fn apply(self, avail: usize) -> usize {
        match self {
            ThreadCap::Auto => avail.max(1),
            ThreadCap::Cap(c) => avail.max(1).min(c.max(1)),
        }
    }

    /// JSON/env encoding: 0 = auto, otherwise the cap.
    pub fn encode(self) -> usize {
        match self {
            ThreadCap::Auto => 0,
            ThreadCap::Cap(c) => c.max(1),
        }
    }

    /// Inverse of [`ThreadCap::encode`].
    pub fn decode(v: usize) -> ThreadCap {
        if v == 0 {
            ThreadCap::Auto
        } else {
            ThreadCap::Cap(v)
        }
    }

    /// Binary class index for the predictor: 0 = auto, 1 = capped-serial.
    pub fn class(self) -> usize {
        match self {
            ThreadCap::Auto => 0,
            ThreadCap::Cap(_) => 1,
        }
    }

    /// Inverse of [`ThreadCap::class`] (the capped class decodes to 1, the
    /// only cap the candidate set uses).
    pub fn from_class(c: usize) -> Option<ThreadCap> {
        match c {
            0 => Some(ThreadCap::Auto),
            1 => Some(ThreadCap::Cap(1)),
            _ => None,
        }
    }
}

/// A complete kernel schedule: (tile width, split rule, thread cap).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Schedule {
    pub tile: Tile,
    pub split: Split,
    pub threads: ThreadCap,
}

impl Default for Schedule {
    /// The pre-schedule kernel behavior, bit-for-bit: 16-lane gather tiles,
    /// nnz-balanced splits, full pool parallelism.
    fn default() -> Schedule {
        Schedule {
            tile: Tile::T16,
            split: Split::NnzBalanced,
            threads: ThreadCap::Auto,
        }
    }
}

impl Schedule {
    /// The measured-autotune / bench candidate set (DESIGN.md
    /// §Schedule-Prediction): the tuned default, a narrow and a wide tile
    /// for the feature-width extremes, and a serial even-split candidate
    /// that wins on tiny matrices where pool dispatch overhead dominates.
    pub const CANDIDATES: [Schedule; 4] = [
        Schedule { tile: Tile::T16, split: Split::NnzBalanced, threads: ThreadCap::Auto },
        Schedule { tile: Tile::T4, split: Split::NnzBalanced, threads: ThreadCap::Auto },
        Schedule { tile: Tile::T32, split: Split::NnzBalanced, threads: ThreadCap::Auto },
        Schedule { tile: Tile::T16, split: Split::EvenUnits, threads: ThreadCap::Cap(1) },
    ];

    /// Task count a kernel should hand the pool for `units` source units:
    /// the capped thread budget, never more tasks than units (or fewer than
    /// one).
    #[inline]
    pub fn tasks_for(self, units: usize) -> usize {
        self.threads.apply(crate::util::parallel::num_threads()).min(units.max(1))
    }

    /// Canonical textual form, e.g. `t16/nnz/auto` or `t8/even/1` — used in
    /// bench keys, logs and the `GNN_SPMM_SCHEDULE` override.
    pub fn label(self) -> String {
        let threads = match self.threads {
            ThreadCap::Auto => "auto".to_string(),
            ThreadCap::Cap(c) => c.to_string(),
        };
        format!("t{}/{}/{}", self.tile.lanes(), self.split.name(), threads)
    }

    /// Parse the [`Schedule::label`] form. `None` on any malformed field.
    pub fn parse(s: &str) -> Option<Schedule> {
        let mut parts = s.trim().split('/');
        let tile = parts.next()?.strip_prefix('t')?.parse::<usize>().ok()?;
        let tile = Tile::from_lanes(tile)?;
        let split = Split::from_name(parts.next()?)?;
        let threads = match parts.next()? {
            "auto" => ThreadCap::Auto,
            n => ThreadCap::Cap(n.parse::<usize>().ok().filter(|&c| c >= 1)?),
        };
        if parts.next().is_some() {
            return None;
        }
        Some(Schedule { tile, split, threads })
    }

    /// The process-wide default schedule: the `GNN_SPMM_SCHEDULE` override
    /// if set and well-formed, else [`Schedule::default`]. Resolved exactly
    /// once (like the pool's thread count); every unscheduled
    /// `spmm_into`/`spmm_t_into` entry point routes through this, so the CI
    /// override exercises each kernel variant under the full test suite.
    pub fn effective() -> Schedule {
        static OVERRIDE: OnceLock<Option<Schedule>> = OnceLock::new();
        OVERRIDE
            .get_or_init(|| {
                let raw = std::env::var("GNN_SPMM_SCHEDULE").ok()?;
                match Schedule::parse(&raw) {
                    Some(s) => Some(s),
                    None => {
                        eprintln!(
                            "warning: ignoring malformed GNN_SPMM_SCHEDULE={raw:?} \
                             (expected e.g. t16/nnz/auto)"
                        );
                        None
                    }
                }
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_the_pre_schedule_behavior() {
        let s = Schedule::default();
        assert_eq!(s.tile, Tile::T16);
        assert_eq!(s.split, Split::NnzBalanced);
        assert_eq!(s.threads, ThreadCap::Auto);
        assert_eq!(s, Schedule::CANDIDATES[0]);
    }

    #[test]
    fn label_parse_round_trips_every_candidate() {
        for s in Schedule::CANDIDATES {
            assert_eq!(Schedule::parse(&s.label()), Some(s), "{}", s.label());
        }
        // Explicit thread caps survive too.
        let capped = Schedule {
            tile: Tile::T8,
            split: Split::EvenUnits,
            threads: ThreadCap::Cap(3),
        };
        assert_eq!(capped.label(), "t8/even/3");
        assert_eq!(Schedule::parse("t8/even/3"), Some(capped));
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "t16", "t16/nnz", "t5/nnz/auto", "16/nnz/auto", "t16/fancy/auto",
            "t16/nnz/0", "t16/nnz/-1", "t16/nnz/auto/extra", "t16/nnz/fast",
        ] {
            assert!(Schedule::parse(bad).is_none(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn class_round_trips() {
        for t in Tile::ALL {
            assert_eq!(Tile::from_class(t.class()), Some(t));
            assert_eq!(Tile::from_lanes(t.lanes()), Some(t));
        }
        for sp in Split::ALL {
            assert_eq!(Split::from_class(sp.class()), Some(sp));
            assert_eq!(Split::from_name(sp.name()), Some(sp));
        }
        assert_eq!(ThreadCap::from_class(ThreadCap::Auto.class()), Some(ThreadCap::Auto));
        assert_eq!(ThreadCap::decode(ThreadCap::Cap(2).encode()), ThreadCap::Cap(2));
        assert_eq!(ThreadCap::decode(0), ThreadCap::Auto);
    }

    #[test]
    fn thread_cap_applies() {
        assert_eq!(ThreadCap::Auto.apply(8), 8);
        assert_eq!(ThreadCap::Cap(2).apply(8), 2);
        assert_eq!(ThreadCap::Cap(16).apply(8), 8);
        assert_eq!(ThreadCap::Cap(1).apply(0), 1);
        assert_eq!(ThreadCap::Auto.apply(0), 1);
    }

    #[test]
    fn candidates_cover_every_output() {
        // The autotuner can only ever pick what's in the candidate set; make
        // sure each predicted output dimension has at least two candidate
        // values so the multi-output heads have something to learn.
        let tiles: std::collections::HashSet<_> =
            Schedule::CANDIDATES.iter().map(|s| s.tile).collect();
        let splits: std::collections::HashSet<_> =
            Schedule::CANDIDATES.iter().map(|s| s.split).collect();
        let caps: std::collections::HashSet<_> =
            Schedule::CANDIDATES.iter().map(|s| s.threads.class()).collect();
        assert!(tiles.len() >= 3);
        assert_eq!(splits.len(), 2);
        assert_eq!(caps.len(), 2);
    }
}
