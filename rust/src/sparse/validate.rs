//! Structural validation for every sparse format (DESIGN.md
//! §Fault-Tolerance: validation trust boundaries).
//!
//! The kernels in this crate assume well-formed storage — monotone
//! `indptr`, in-bounds sorted indices, coherent array lengths, finite
//! values — and index unchecked off those invariants in their hot loops.
//! That is the right trade *inside* the engine, where every operand is
//! produced by our own constructors; it is the wrong trade at **trust
//! boundaries**, where operands arrive from outside the invariant bubble
//! (a published serving snapshot, a cache file from disk, a corrupt
//! extraction under fault injection). [`SparseMatrix::validate`] is the
//! gate those boundaries call: a full O(nnz) sweep of every per-format
//! invariant, returning a typed [`FormatError`] naming the violated
//! invariant instead of letting a kernel read out of bounds or launder a
//! NaN into logits.
//!
//! [`SparseMatrix::validate_quick`] is the O(rows)-at-worst subset (array
//! length/shape coherence only) cheap enough for always-on enforcement at
//! per-shard engine binds; the full sweep backs it up in debug builds and
//! at the explicitly fault-tolerant boundaries.

use super::format::SparseMatrix;
use super::Format;

/// A violated structural invariant, naming the offending format and what
/// broke. Typed (rather than a bare panic) so serving can turn a corrupt
/// operand into a per-request error instead of a dead worker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FormatError {
    pub format: Format,
    pub what: String,
}

impl FormatError {
    fn new(format: Format, what: impl Into<String>) -> FormatError {
        FormatError { format, what: what.into() }
    }
}

impl std::fmt::Display for FormatError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "malformed {} matrix: {}", self.format.name(), self.what)
    }
}

impl std::error::Error for FormatError {}

/// Shorthand: build the error and return early.
macro_rules! invalid {
    ($fmt:expr, $($arg:tt)*) => {
        return Err(FormatError::new($fmt, format!($($arg)*)))
    };
}

fn check_finite(fmt: Format, vals: &[f32]) -> Result<(), FormatError> {
    if let Some(i) = vals.iter().position(|v| !v.is_finite()) {
        invalid!(fmt, "non-finite value {} at position {i}", vals[i]);
    }
    Ok(())
}

/// `indptr` must be a monotone prefix-sum: len `outer+1`, starts at 0,
/// never decreases, ends at `nnz`.
fn check_indptr(fmt: Format, indptr: &[usize], outer: usize, nnz: usize, axis: &str) -> Result<(), FormatError> {
    if indptr.len() != outer + 1 {
        invalid!(fmt, "indptr length {} but {axis} count is {outer}", indptr.len());
    }
    if indptr[0] != 0 {
        invalid!(fmt, "indptr must start at 0, starts at {}", indptr[0]);
    }
    if let Some(i) = indptr.windows(2).position(|w| w[1] < w[0]) {
        invalid!(fmt, "indptr decreases at {axis} {i}: {} → {}", indptr[i], indptr[i + 1]);
    }
    if indptr[outer] != nnz {
        invalid!(fmt, "indptr ends at {} but {nnz} entries are stored", indptr[outer]);
    }
    Ok(())
}

/// Compressed index segments: in-bounds and strictly ascending per segment.
fn check_segments(
    fmt: Format,
    indptr: &[usize],
    indices: &[u32],
    bound: usize,
    axis: &str,
) -> Result<(), FormatError> {
    for (seg, w) in indptr.windows(2).enumerate() {
        let ids = &indices[w[0]..w[1]];
        for (j, &id) in ids.iter().enumerate() {
            if id as usize >= bound {
                invalid!(fmt, "{axis} {seg}: index {id} out of bounds (< {bound})");
            }
            if j > 0 && ids[j - 1] >= id {
                invalid!(fmt, "{axis} {seg}: indices not strictly ascending ({} then {id})", ids[j - 1]);
            }
        }
    }
    Ok(())
}

impl SparseMatrix {
    /// Cheap shape/length-coherence check — O(1) for most formats, O(rows)
    /// never exceeded. Catches torn storage (mismatched array lengths, an
    /// `indptr` that disagrees with the stored entry count) without paying
    /// a per-element sweep; always-on at engine slot binds.
    pub fn validate_quick(&self) -> Result<(), FormatError> {
        match self {
            SparseMatrix::Coo(c) => {
                if c.row.len() != c.val.len() || c.col.len() != c.val.len() {
                    invalid!(
                        Format::Coo,
                        "triple arrays disagree: {} rows / {} cols / {} vals",
                        c.row.len(),
                        c.col.len(),
                        c.val.len()
                    );
                }
            }
            SparseMatrix::Csr(c) => {
                if c.indices.len() != c.vals.len() {
                    invalid!(Format::Csr, "{} indices vs {} vals", c.indices.len(), c.vals.len());
                }
                if c.indptr.len() != c.rows + 1 || c.indptr.first() != Some(&0) {
                    invalid!(Format::Csr, "indptr length {} for {} rows", c.indptr.len(), c.rows);
                }
                if c.indptr.last() != Some(&c.vals.len()) {
                    invalid!(Format::Csr, "indptr end {:?} vs {} stored", c.indptr.last(), c.vals.len());
                }
            }
            SparseMatrix::Csc(c) => {
                if c.indices.len() != c.vals.len() {
                    invalid!(Format::Csc, "{} indices vs {} vals", c.indices.len(), c.vals.len());
                }
                if c.indptr.len() != c.cols + 1 || c.indptr.first() != Some(&0) {
                    invalid!(Format::Csc, "indptr length {} for {} cols", c.indptr.len(), c.cols);
                }
                if c.indptr.last() != Some(&c.vals.len()) {
                    invalid!(Format::Csc, "indptr end {:?} vs {} stored", c.indptr.last(), c.vals.len());
                }
            }
            SparseMatrix::Dia(d) => {
                if d.data.len() != d.offsets.len() * d.rows {
                    invalid!(
                        Format::Dia,
                        "data length {} but {} diagonals × {} rows",
                        d.data.len(),
                        d.offsets.len(),
                        d.rows
                    );
                }
            }
            SparseMatrix::Bsr(b) => {
                if b.block == 0 {
                    invalid!(Format::Bsr, "zero block size");
                }
                let block_rows = b.rows.div_ceil(b.block);
                if b.indptr.len() != block_rows + 1 || b.indptr.first() != Some(&0) {
                    invalid!(Format::Bsr, "indptr length {} for {} block rows", b.indptr.len(), block_rows);
                }
                if b.indptr.last() != Some(&b.indices.len()) {
                    invalid!(Format::Bsr, "indptr end {:?} vs {} blocks", b.indptr.last(), b.indices.len());
                }
                if b.blocks.len() != b.indices.len() * b.block * b.block {
                    invalid!(
                        Format::Bsr,
                        "block storage {} vs {} blocks of {}²",
                        b.blocks.len(),
                        b.indices.len(),
                        b.block
                    );
                }
            }
            SparseMatrix::Dok(_) => {}
            SparseMatrix::Lil(l) => {
                if l.rows_data.len() != l.rows {
                    invalid!(Format::Lil, "{} row lists for {} rows", l.rows_data.len(), l.rows);
                }
            }
        }
        Ok(())
    }

    /// Full structural validation: everything [`SparseMatrix::validate_quick`]
    /// checks, plus the per-element invariants each format's kernels index
    /// off — monotone `indptr` (checked whole, not just the endpoints),
    /// in-bounds strictly-sorted indices, finite values, zeroed
    /// out-of-matrix padding (DIA lanes, BSR edge blocks). O(nnz); called
    /// at trust boundaries, not in kernel hot loops.
    pub fn validate(&self) -> Result<(), FormatError> {
        self.validate_quick()?;
        match self {
            SparseMatrix::Coo(c) => {
                for i in 0..c.val.len() {
                    if c.row[i] as usize >= c.rows || c.col[i] as usize >= c.cols {
                        invalid!(
                            Format::Coo,
                            "entry {i} at ({}, {}) outside {}×{}",
                            c.row[i],
                            c.col[i],
                            c.rows,
                            c.cols
                        );
                    }
                    if i > 0 && (c.row[i - 1], c.col[i - 1]) >= (c.row[i], c.col[i]) {
                        invalid!(
                            Format::Coo,
                            "triples not strictly sorted row-major at {i}: ({}, {}) then ({}, {})",
                            c.row[i - 1],
                            c.col[i - 1],
                            c.row[i],
                            c.col[i]
                        );
                    }
                }
                check_finite(Format::Coo, &c.val)?;
            }
            SparseMatrix::Csr(c) => {
                check_indptr(Format::Csr, &c.indptr, c.rows, c.vals.len(), "row")?;
                check_segments(Format::Csr, &c.indptr, &c.indices, c.cols, "row")?;
                check_finite(Format::Csr, &c.vals)?;
            }
            SparseMatrix::Csc(c) => {
                check_indptr(Format::Csc, &c.indptr, c.cols, c.vals.len(), "col")?;
                check_segments(Format::Csc, &c.indptr, &c.indices, c.rows, "col")?;
                check_finite(Format::Csc, &c.vals)?;
            }
            SparseMatrix::Dia(d) => {
                if let Some(i) = d.offsets.windows(2).position(|w| w[0] >= w[1]) {
                    invalid!(Format::Dia, "offsets not strictly ascending at {i}");
                }
                for (k, &off) in d.offsets.iter().enumerate() {
                    for r in 0..d.rows {
                        let v = d.data[k * d.rows + r];
                        if !v.is_finite() {
                            invalid!(Format::Dia, "non-finite value {v} on diagonal {off}, row {r}");
                        }
                        let c = r as i64 + off;
                        if (c < 0 || c >= d.cols as i64) && v != 0.0 {
                            invalid!(Format::Dia, "non-zero {v} outside the matrix on diagonal {off}, row {r}");
                        }
                    }
                }
            }
            SparseMatrix::Bsr(b) => {
                let block_cols = b.cols.div_ceil(b.block);
                check_segments(Format::Bsr, &b.indptr, &b.indices, block_cols, "block row")?;
                check_finite(Format::Bsr, &b.blocks)?;
                // Edge blocks: cells past the logical matrix edge are
                // padding and must be zero, or SpMM would leak them in.
                for (br, w) in b.indptr.windows(2).enumerate() {
                    for slot in w[0]..w[1] {
                        let bc = b.indices[slot] as usize;
                        for i in 0..b.block {
                            for j in 0..b.block {
                                let (r, c) = (br * b.block + i, bc * b.block + j);
                                let v = b.blocks[slot * b.block * b.block + i * b.block + j];
                                if (r >= b.rows || c >= b.cols) && v != 0.0 {
                                    invalid!(Format::Bsr, "non-zero {v} in padding at ({r}, {c})");
                                }
                            }
                        }
                    }
                }
            }
            SparseMatrix::Dok(d) => {
                for (&(r, c), &v) in &d.map {
                    if r as usize >= d.rows || c as usize >= d.cols {
                        invalid!(Format::Dok, "key ({r}, {c}) outside {}×{}", d.rows, d.cols);
                    }
                    if !v.is_finite() {
                        invalid!(Format::Dok, "non-finite value {v} at ({r}, {c})");
                    }
                }
            }
            SparseMatrix::Lil(l) => {
                for (r, list) in l.rows_data.iter().enumerate() {
                    for (j, &(c, v)) in list.iter().enumerate() {
                        if c as usize >= l.cols {
                            invalid!(Format::Lil, "row {r}: column {c} out of bounds (< {})", l.cols);
                        }
                        if j > 0 && list[j - 1].0 >= c {
                            invalid!(Format::Lil, "row {r}: columns not strictly ascending at {j}");
                        }
                        if !v.is_finite() {
                            invalid!(Format::Lil, "row {r}: non-finite value {v} in column {c}");
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Bsr, Coo, Csc, Csr, Dia, Dok, Lil, ALL_FORMATS};
    use super::*;

    fn sample_coo() -> Coo {
        Coo::from_triples(
            6,
            5,
            vec![(0, 1, 1.0), (1, 4, -2.0), (2, 0, 0.5), (3, 3, 3.0), (5, 2, 4.0)],
        )
    }

    #[test]
    fn well_formed_matrices_pass_in_every_format() {
        let coo = sample_coo();
        for &fmt in ALL_FORMATS {
            let m = SparseMatrix::from_coo(coo.clone())
                .convert(fmt)
                .expect("tiny matrix converts everywhere");
            m.validate_quick().unwrap_or_else(|e| panic!("{fmt:?} quick: {e}"));
            m.validate().unwrap_or_else(|e| panic!("{fmt:?} full: {e}"));
        }
    }

    #[test]
    fn empty_matrices_pass() {
        let coo = Coo::from_triples(4, 3, vec![]);
        for &fmt in ALL_FORMATS {
            let m = SparseMatrix::from_coo(coo.clone()).convert(fmt).unwrap();
            m.validate().unwrap_or_else(|e| panic!("{fmt:?}: {e}"));
        }
    }

    // One crafted malformed instance per format (the acceptance-criteria
    // set; integration_faults pushes these through the publish boundary).

    #[test]
    fn coo_rejects_unsorted_and_out_of_bounds() {
        let mut c = sample_coo();
        c.row.swap(0, 1);
        c.col.swap(0, 1);
        c.val.swap(0, 1);
        let err = SparseMatrix::Coo(c).validate().unwrap_err();
        assert_eq!(err.format, Format::Coo);
        assert!(err.what.contains("sorted"), "{err}");

        let mut oob = sample_coo();
        oob.col[0] = 99;
        assert!(SparseMatrix::Coo(oob).validate().is_err());

        let mut torn = sample_coo();
        torn.row.push(0);
        assert!(SparseMatrix::Coo(torn).validate_quick().is_err(), "quick catches torn triples");
    }

    #[test]
    fn csr_rejects_decreasing_indptr_and_oob_indices() {
        let mut c = Csr::from_coo(&sample_coo());
        let last = c.indptr.len() - 1;
        c.indptr.swap(1, last - 1);
        let err = SparseMatrix::Csr(c).validate().unwrap_err();
        assert_eq!(err.format, Format::Csr);

        let mut oob = Csr::from_coo(&sample_coo());
        oob.indices[0] = oob.cols as u32 + 3;
        let err = SparseMatrix::Csr(oob).validate().unwrap_err();
        assert!(err.what.contains("out of bounds"), "{err}");

        let mut nan = Csr::from_coo(&sample_coo());
        nan.vals[2] = f32::NAN;
        assert!(SparseMatrix::Csr(nan).validate().is_err());
    }

    #[test]
    fn csc_rejects_torn_indptr() {
        let mut c = Csc::from_coo(&sample_coo());
        c.indptr.pop();
        let err = SparseMatrix::Csc(c).validate_quick().unwrap_err();
        assert_eq!(err.format, Format::Csc);
    }

    #[test]
    fn dia_rejects_data_length_mismatch_and_stray_lane_values() {
        let mut d = Dia::from_coo(&sample_coo()).unwrap();
        d.data.pop();
        assert!(SparseMatrix::Dia(d).validate_quick().is_err());

        // A value on a lane position that falls outside the matrix.
        let mut stray = Dia::from_coo(&Coo::from_triples(3, 3, vec![(0, 2, 1.0)])).unwrap();
        // offset +2: rows 1, 2 map to cols 3, 4 — out of a 3-col matrix.
        stray.data[2] = 7.0;
        let err = SparseMatrix::Dia(stray).validate().unwrap_err();
        assert!(err.what.contains("outside the matrix"), "{err}");
    }

    #[test]
    fn bsr_rejects_block_storage_mismatch() {
        let mut b = Bsr::from_coo(&sample_coo(), 2);
        b.blocks.truncate(b.blocks.len() - 1);
        let err = SparseMatrix::Bsr(b).validate_quick().unwrap_err();
        assert_eq!(err.format, Format::Bsr);

        let mut oob = Bsr::from_coo(&sample_coo(), 2);
        oob.indices[0] = 1000;
        assert!(SparseMatrix::Bsr(oob).validate().is_err());
    }

    #[test]
    fn dok_rejects_out_of_bounds_keys_and_nan() {
        let mut d = Dok::from_coo(&sample_coo());
        d.map.insert((50, 50), 1.0);
        assert!(SparseMatrix::Dok(d).validate().is_err());

        let mut nan = Dok::from_coo(&sample_coo());
        nan.map.insert((0, 0), f32::NAN);
        assert!(SparseMatrix::Dok(nan).validate().is_err());
    }

    #[test]
    fn lil_rejects_unsorted_rows_and_oob_columns() {
        let mut l = Lil::from_coo(&sample_coo());
        l.rows_data[0].push((0, 9.0)); // after column 1 → out of order
        assert!(SparseMatrix::Lil(l).validate().is_err());

        let mut oob = Lil::from_coo(&sample_coo());
        oob.rows_data[1].push((77, 1.0));
        assert!(SparseMatrix::Lil(oob).validate().is_err());

        let mut torn = Lil::from_coo(&sample_coo());
        torn.rows_data.pop();
        assert!(SparseMatrix::Lil(torn).validate_quick().is_err());
    }
}
