//! Dictionary-of-keys (DOK): a hash map from `(row, col)` to value. Cheap
//! incremental updates, poor SpMM locality — its honest weakness in the
//! paper's profiling, reproduced here by iterating the hash table directly.

use super::coo::Coo;
use super::ops::{check_into_shapes, SparseOps};
use crate::tensor::Matrix;
use std::collections::HashMap;

/// DOK sparse matrix.
#[derive(Clone, Debug)]
pub struct Dok {
    pub rows: usize,
    pub cols: usize,
    pub map: HashMap<(u32, u32), f32>,
}

impl Dok {
    pub fn from_coo(coo: &Coo) -> Dok {
        let mut map = HashMap::with_capacity(coo.nnz());
        for i in 0..coo.nnz() {
            map.insert((coo.row[i], coo.col[i]), coo.val[i]);
        }
        Dok { rows: coo.rows, cols: coo.cols, map }
    }

    pub fn to_coo(&self) -> Coo {
        let triples = self.map.iter().map(|(&(r, c), &v)| (r, c, v)).collect();
        Coo::from_triples(self.rows, self.cols, triples)
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.map.len()
    }

    /// Point read (the operation DOK is actually good at).
    pub fn get(&self, r: u32, c: u32) -> f32 {
        self.map.get(&(r, c)).copied().unwrap_or(0.0)
    }

    /// Point write.
    pub fn set(&mut self, r: u32, c: u32, v: f32) {
        if v == 0.0 {
            self.map.remove(&(r, c));
        } else {
            self.map.insert((r, c), v);
        }
    }

    /// Footprint model: 8B key + 4B value + ~36B hash-table overhead per
    /// entry (mirrors the dictionary overhead that makes scipy DOK the most
    /// memory-hungry format in the paper's Eq-1 memory term).
    pub fn nbytes(&self) -> usize {
        self.map.len() * 48
    }

    /// SpMM `self (n×m) · x (m×d) → out (n×d)` into a caller-provided
    /// buffer.
    ///
    /// Iterates the hash table in storage order — scattered output access is
    /// DOK's intrinsic SpMM penalty, kept deliberately (matching scipy,
    /// which converts or iterates the dict).
    // lint: begin(hot-path)
    pub fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        check_into_shapes(self.rows, self.cols, x, out);
        out.data.fill(0.0);
        for (&(r, c), &v) in &self.map {
            let x_row = x.row(c as usize);
            let out_row = out.row_mut(r as usize);
            for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                *o += v * xv;
            }
        }
    }
    // lint: end(hot-path)

    /// Allocating SpMM wrapper.
    pub fn spmm(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, x.cols);
        self.spmm_into(x, &mut out);
        out
    }

    /// Transpose-SpMM `selfᵀ (m×n) · x (n×d) → out (m×d)`: the same
    /// storage-order iteration with the roles of key row/col swapped — DOK
    /// pays the identical scatter penalty in both directions.
    // lint: begin(hot-path)
    pub fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        check_into_shapes(self.cols, self.rows, x, out);
        out.data.fill(0.0);
        for (&(r, c), &v) in &self.map {
            let x_row = x.row(r as usize);
            let out_row = out.row_mut(c as usize);
            for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                *o += v * xv;
            }
        }
    }
    // lint: end(hot-path)
}

impl SparseOps for Dok {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        Dok::nnz(self)
    }
    fn nbytes(&self) -> usize {
        Dok::nbytes(self)
    }
    fn to_coo(&self) -> Coo {
        Dok::to_coo(self)
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        Dok::spmm_into(self, x, out)
    }
    fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        Dok::spmm_t_into(self, x, out)
    }
}

impl PartialEq for Dok {
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows && self.cols == other.cols && self.map == other.map
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Coo {
        let mut triples = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, rng.uniform(-1.0, 1.0) as f32));
                }
            }
        }
        Coo::from_triples(rows, cols, triples)
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let coo = random_coo(&mut rng, 15, 21, 0.15);
        let dok = Dok::from_coo(&coo);
        assert_eq!(dok.to_coo(), coo);
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Rng::new(2);
        let coo = random_coo(&mut rng, 31, 27, 0.12);
        let dok = Dok::from_coo(&coo);
        let x = Matrix::rand(27, 6, &mut rng);
        let want = coo.to_dense().matmul(&x);
        assert!(dok.spmm(&x).max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn point_ops() {
        let mut dok = Dok::from_coo(&Coo::from_triples(4, 4, vec![(1, 2, 5.0)]));
        assert_eq!(dok.get(1, 2), 5.0);
        assert_eq!(dok.get(0, 0), 0.0);
        dok.set(0, 0, 7.0);
        assert_eq!(dok.get(0, 0), 7.0);
        dok.set(1, 2, 0.0); // zero removes
        assert_eq!(dok.nnz(), 1);
    }
}
