//! [`SharedMatrix`] — Arc-backed shared ownership for slot-bound operands
//! (DESIGN.md §Shared-Ownership).
//!
//! The mini-batch driver keeps full-graph **masters** (features, normalized
//! adjacency, RGCN's per-relation CSRs) alive for a whole run and rebinds
//! them into model slots every epoch for the full-graph eval. Before this
//! type, each rebind deep-cloned the master into the slot — for RGCN that
//! is ~2R CSR copies per epoch, pure memcpy traffic the hardware never
//! needed (GE-SpMM/ParamSpMM's data-movement argument, applied to our own
//! runtime). A `SharedMatrix` is a cheap handle: cloning bumps a refcount,
//! and rebinding a slot is an O(1) pointer bind.
//!
//! Mutation is copy-on-write: the few paths that really write through a
//! handle (the GAT attention value refresh) go through
//! [`SharedMatrix::to_mut`], which clones the payload only while the handle
//! is shared — masters are never written through a slot. Paths that
//! *replace* a representation (format conversion, dense sparsification)
//! simply install a fresh handle; the previous one is dropped, and the
//! master it may have pointed at stays untouched.

use super::{Coo, Csr, SparseMatrix};
use crate::util::sync::{read_recover, write_recover};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock, Weak};

/// Shared, copy-on-write handle to a [`SparseMatrix`].
///
/// Dereferences to `SparseMatrix`, so every read-only operation (`spmm`,
/// `nnz`, `extract_rows_cols`, …) works directly on the handle.
#[derive(Clone, Debug)]
pub struct SharedMatrix(Arc<SparseMatrix>);

impl SharedMatrix {
    pub fn new(m: SparseMatrix) -> SharedMatrix {
        SharedMatrix(Arc::new(m))
    }

    /// Mutable access with copy-on-write semantics: clones the payload iff
    /// the handle is currently shared, then (and on every later call while
    /// unique) mutates in place.
    pub fn to_mut(&mut self) -> &mut SparseMatrix {
        Arc::make_mut(&mut self.0)
    }

    /// Do `self` and `other` point at the same allocation? This is the
    /// *handle identity* the engine keys rebind short-circuits and decision
    /// provenance off — content equality is irrelevant (and far costlier).
    pub fn ptr_eq(&self, other: &SharedMatrix) -> bool {
        Arc::ptr_eq(&self.0, &other.0)
    }

    /// Number of live handles to this payload (test instrumentation: the
    /// rebind-equivalence suite asserts masters are not duplicated).
    pub fn strong_count(&self) -> usize {
        Arc::strong_count(&self.0)
    }

    /// Non-owning identity token for this handle (the engine's rebind
    /// short-circuit keys on it without pinning the payload).
    pub fn downgrade(&self) -> WeakMatrix {
        WeakMatrix(Arc::downgrade(&self.0))
    }
}

/// Epoch-swap snapshot cell (DESIGN.md §Serving): a single writer publishes
/// new `Arc`-backed snapshots while any number of readers keep serving the
/// one they loaded.
///
/// The lock discipline is the whole point: the `RwLock` guards **only the
/// pointer clone**, never the payload. `load` takes the read lock for an
/// `Arc::clone` (a refcount bump, ~nanoseconds) and releases it before the
/// caller touches the snapshot — so a request's entire SpMM pipeline runs
/// with *zero* locks held, and a writer's `publish` can never block an
/// in-flight request, only the instant of pointer acquisition. Old
/// snapshots free themselves when the last in-flight reader drops its
/// `Arc` — no reclamation protocol, the refcount *is* the grace period.
///
/// The epoch counter is bumped after the swap; readers use
/// [`EpochCell::epoch`] to cheaply detect "a newer snapshot exists"
/// without loading it (metrics, staleness probes).
#[derive(Debug)]
pub struct EpochCell<T> {
    inner: RwLock<Arc<T>>,
    epoch: AtomicU64,
}

impl<T> EpochCell<T> {
    pub fn new(value: T) -> EpochCell<T> {
        EpochCell { inner: RwLock::new(Arc::new(value)), epoch: AtomicU64::new(0) }
    }

    /// Snapshot handle for a reader. Lock held only for the `Arc` clone.
    /// Poison-recovering: the critical sections here are single pointer
    /// ops that cannot tear, so a panicked holder never invalidates the
    /// cell (DESIGN.md §Fault-Tolerance).
    pub fn load(&self) -> Arc<T> {
        Arc::clone(&read_recover(&self.inner))
    }

    /// Publish a new snapshot, returning the epoch it became current at.
    /// Allocates the `Arc` *outside* the write lock; prefer
    /// [`EpochCell::publish_arc`] where the swap path itself must be
    /// allocation-free (the caller pre-builds the `Arc`).
    pub fn publish(&self, value: T) -> u64 {
        self.publish_arc(Arc::new(value))
    }

    /// Publish a pre-built snapshot. The swap path here performs no
    /// allocation at all: a pointer store under the write lock plus an
    /// atomic increment. The displaced snapshot's `Arc` is dropped after
    /// the lock is released, so even its (uncounted) deallocation happens
    /// off the critical section.
    pub fn publish_arc(&self, value: Arc<T>) -> u64 {
        let old = {
            let mut guard = write_recover(&self.inner);
            std::mem::replace(&mut *guard, value)
        };
        drop(old);
        // ord: Release pairs with the Acquire in epoch(): a reader that
        // observes the new epoch also observes the snapshot published
        // before the bump (the lock orders the store itself).
        self.epoch.fetch_add(1, Ordering::Release) + 1
    }

    /// Number of publishes so far (0 for a freshly constructed cell).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire) // ord: pairs with publish_arc's Release
    }
}

/// Non-owning identity token for a [`SharedMatrix`]. Lets a slot remember
/// *which* operand it was bound to (so rebinding the same master is a
/// no-op) without keeping a replaced operand's memory alive — after the
/// engine converts a shard submatrix, the original extraction is freed,
/// not pinned by provenance.
#[derive(Clone, Debug)]
pub struct WeakMatrix(Weak<SparseMatrix>);

impl WeakMatrix {
    /// Does this token denote exactly `m`'s allocation? A dropped payload
    /// never matches (the upgrade fails first), so a stale token cannot
    /// alias a new allocation that reused the same address.
    pub fn is_handle_of(&self, m: &SharedMatrix) -> bool {
        self.0.upgrade().is_some_and(|live| Arc::ptr_eq(&live, &m.0))
    }
}

impl std::ops::Deref for SharedMatrix {
    type Target = SparseMatrix;

    fn deref(&self) -> &SparseMatrix {
        &self.0
    }
}

impl From<SparseMatrix> for SharedMatrix {
    fn from(m: SparseMatrix) -> SharedMatrix {
        SharedMatrix::new(m)
    }
}

impl From<Coo> for SharedMatrix {
    fn from(c: Coo) -> SharedMatrix {
        SharedMatrix::new(SparseMatrix::Coo(c))
    }
}

impl From<Csr> for SharedMatrix {
    fn from(c: Csr) -> SharedMatrix {
        SharedMatrix::new(SparseMatrix::Csr(c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SparseMatrix {
        SparseMatrix::Coo(Coo::from_triples(
            3,
            3,
            vec![(0, 1, 1.0), (1, 2, 2.0), (2, 0, 3.0)],
        ))
    }

    #[test]
    fn clone_is_a_handle_not_a_copy() {
        let a = SharedMatrix::new(sample());
        assert_eq!(a.strong_count(), 1);
        let b = a.clone();
        assert!(a.ptr_eq(&b));
        assert_eq!(a.strong_count(), 2);
        assert_eq!(b.nnz(), 3);
        drop(b);
        assert_eq!(a.strong_count(), 1);
    }

    #[test]
    fn to_mut_copies_only_while_shared() {
        let mut a = SharedMatrix::new(sample());
        let master = a.clone();
        // Shared: the write must not reach the master.
        if let SparseMatrix::Coo(c) = a.to_mut() {
            c.val[0] = 99.0;
        }
        assert!(!a.ptr_eq(&master), "CoW must detach the written handle");
        assert_eq!(master.to_coo().val[0], 1.0, "master untouched");
        assert_eq!(a.to_coo().val[0], 99.0);
        // Unique: further writes stay in place (no fresh allocation).
        let before = &*a as *const SparseMatrix;
        if let SparseMatrix::Coo(c) = a.to_mut() {
            c.val[1] = 55.0;
        }
        assert_eq!(before, &*a as *const SparseMatrix, "unique handle mutates in place");
    }

    #[test]
    fn weak_token_matches_identity_without_owning() {
        let a = SharedMatrix::new(sample());
        let token = a.downgrade();
        assert_eq!(a.strong_count(), 1, "token must not own the payload");
        assert!(token.is_handle_of(&a));
        // Content-equal but distinct allocation: no match.
        let other = SharedMatrix::new(sample());
        assert!(!token.is_handle_of(&other));
        // Dropped payload: the token goes permanently stale.
        drop(a);
        assert!(!token.is_handle_of(&other));
    }

    #[test]
    fn epoch_cell_swap_preserves_in_flight_snapshots() {
        let cell = EpochCell::new(SharedMatrix::new(sample()));
        assert_eq!(cell.epoch(), 0);
        let held = cell.load(); // in-flight reader
        let epoch = cell.publish(SharedMatrix::new(sample()));
        assert_eq!(epoch, 1);
        assert_eq!(cell.epoch(), 1);
        // The reader still sees (and owns) the old snapshot.
        assert!(!held.ptr_eq(&cell.load()));
        assert_eq!(held.nnz(), 3);
        // Dropping the last in-flight handle frees the old snapshot; the
        // cell's current snapshot is unaffected.
        drop(held);
        assert_eq!(cell.load().strong_count(), 2, "cell + our load");
    }

    #[test]
    fn epoch_cell_publish_arc_takes_prebuilt_snapshot() {
        let cell = EpochCell::new(7_u32);
        let next = Arc::new(8_u32);
        assert_eq!(cell.publish_arc(Arc::clone(&next)), 1);
        assert!(Arc::ptr_eq(&cell.load(), &next));
        assert_eq!(cell.publish(9), 2);
        assert_eq!(*cell.load(), 9);
    }

    #[test]
    fn deref_reaches_sparse_matrix_api() {
        let a = SharedMatrix::from(sample());
        assert_eq!((a.rows(), a.cols()), (3, 3));
        assert_eq!(a.format(), super::super::Format::Coo);
        let sub = a.extract_rows_cols(&[0, 1], &[0, 1, 2]);
        assert_eq!(sub.rows(), 2);
    }
}
