//! Diagonal (DIA) format. Excellent for banded matrices; catastrophic for
//! scattered patterns (storage is `n_diags × rows`), so construction is
//! fallible with a budget guard — the labeler scores over-budget DIA as
//! worst-case, exactly how exhaustive profiling would.

use super::coo::Coo;
use super::ops::{check_into_shapes, SparseOps};
use crate::tensor::Matrix;
use crate::util::parallel::parallel_fill_rows;

/// Max stored elements (n_diags × rows) before we refuse to build DIA.
/// 1<<26 f32 = 256 MiB — far beyond any point where DIA could win.
pub const DIA_BUDGET: usize = 1 << 26;

/// DIA sparse matrix. Diagonal `k` holds offset `offsets[k]`; element
/// `(r, r + offsets[k])` lives at `data[k * rows + r]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Dia {
    pub rows: usize,
    pub cols: usize,
    pub offsets: Vec<i64>,
    /// `offsets.len() * rows` values, row-indexed within each diagonal.
    pub data: Vec<f32>,
}

impl Dia {
    /// Build from COO; fails if the diagonal footprint exceeds [`DIA_BUDGET`].
    pub fn from_coo(coo: &Coo) -> anyhow::Result<Dia> {
        let mut offsets: Vec<i64> = (0..coo.nnz())
            .map(|i| coo.col[i] as i64 - coo.row[i] as i64)
            .collect();
        offsets.sort_unstable();
        offsets.dedup();
        let footprint = offsets.len().saturating_mul(coo.rows);
        if footprint > DIA_BUDGET {
            anyhow::bail!(
                "DIA footprint {} (diags={} × rows={}) exceeds budget {}",
                footprint,
                offsets.len(),
                coo.rows,
                DIA_BUDGET
            );
        }
        let mut data = vec![0f32; footprint];
        for i in 0..coo.nnz() {
            let off = coo.col[i] as i64 - coo.row[i] as i64;
            let k = offsets.binary_search(&off).unwrap();
            data[k * coo.rows + coo.row[i] as usize] = coo.val[i];
        }
        Ok(Dia { rows: coo.rows, cols: coo.cols, offsets, data })
    }

    pub fn to_coo(&self) -> Coo {
        let mut triples = Vec::new();
        for (k, &off) in self.offsets.iter().enumerate() {
            for r in 0..self.rows {
                let c = r as i64 + off;
                if c < 0 || c >= self.cols as i64 {
                    continue;
                }
                let v = self.data[k * self.rows + r];
                if v != 0.0 {
                    triples.push((r as u32, c as u32, v));
                }
            }
        }
        Coo::from_triples(self.rows, self.cols, triples)
    }

    pub fn nnz(&self) -> usize {
        // Count stored non-zeros (DIA may store explicit zeros as padding).
        let mut n = 0;
        for (k, &off) in self.offsets.iter().enumerate() {
            for r in 0..self.rows {
                let c = r as i64 + off;
                if c >= 0 && c < self.cols as i64 && self.data[k * self.rows + r] != 0.0 {
                    n += 1;
                }
            }
        }
        n
    }

    pub fn n_diags(&self) -> usize {
        self.offsets.len()
    }

    /// Footprint model: full diagonal storage + 8B per offset.
    pub fn nbytes(&self) -> usize {
        self.data.len() * 4 + self.offsets.len() * 8
    }

    /// SpMM `self (n×m) · x (m×d) → out (n×d)`, parallel over row ranges,
    /// into a caller-provided buffer.
    ///
    /// Per output row `r`, walks the diagonals: `y[r] += data[k][r] * x[r+off]`.
    /// Contiguous in `data` along rows and in `x` along features.
    ///
    /// Scheduling note: every row touches every diagonal (±boundary
    /// clipping), so per-row work is uniform and the pool's even row split
    /// *is* the nnz-balanced split — DIA needs no weighted spans.
    // lint: begin(hot-path)
    pub fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        check_into_shapes(self.rows, self.cols, x, out);
        let d = x.cols;
        parallel_fill_rows(&mut out.data, self.rows, d, |range, chunk| {
            chunk.fill(0.0);
            for (k, &off) in self.offsets.iter().enumerate() {
                let base = k * self.rows;
                for (rr, r) in range.clone().enumerate() {
                    let c = r as i64 + off;
                    if c < 0 || c >= self.cols as i64 {
                        continue;
                    }
                    let v = self.data[base + r];
                    if v == 0.0 {
                        continue;
                    }
                    let x_row = x.row(c as usize);
                    let out_row = &mut chunk[rr * d..(rr + 1) * d];
                    for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                        *o += v * xv;
                    }
                }
            }
        });
    }
    // lint: end(hot-path)

    /// Allocating SpMM wrapper.
    pub fn spmm(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, x.cols);
        self.spmm_into(x, &mut out);
        out
    }

    /// Transpose-SpMM `selfᵀ (m×n) · x (n×d) → out (m×d)` — transpose-free
    /// gather: output row `c` (a column of `self`) reads element `(r, c)`
    /// from diagonal `off = c - r`, i.e. `r = c - off`, so each diagonal
    /// contributes `data[k][c - off] · x[c - off]` to row `c`. Row-parallel
    /// like the forward kernel; no transposed storage is built.
    // lint: begin(hot-path)
    pub fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        check_into_shapes(self.cols, self.rows, x, out);
        let d = x.cols;
        parallel_fill_rows(&mut out.data, self.cols, d, |range, chunk| {
            chunk.fill(0.0);
            for (k, &off) in self.offsets.iter().enumerate() {
                let base = k * self.rows;
                for (cc, c) in range.clone().enumerate() {
                    let r = c as i64 - off;
                    if r < 0 || r >= self.rows as i64 {
                        continue;
                    }
                    let v = self.data[base + r as usize];
                    if v == 0.0 {
                        continue;
                    }
                    let x_row = x.row(r as usize);
                    let out_row = &mut chunk[cc * d..(cc + 1) * d];
                    for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                        *o += v * xv;
                    }
                }
            }
        });
    }
    // lint: end(hot-path)

    /// Direct structural transpose: diagonal `off` of `self` is diagonal
    /// `-off` of `selfᵀ`, so the offsets negate (and reverse, staying
    /// sorted) and each stored value re-indexes from row `r` to row `c`.
    /// Fails only if the (cols-indexed) transposed footprint exceeds
    /// [`DIA_BUDGET`] — possible for very wide matrices.
    pub fn transpose(&self) -> anyhow::Result<Dia> {
        let footprint = self.offsets.len().saturating_mul(self.cols);
        if footprint > DIA_BUDGET {
            anyhow::bail!(
                "transposed DIA footprint {} (diags={} × rows={}) exceeds budget {}",
                footprint,
                self.offsets.len(),
                self.cols,
                DIA_BUDGET
            );
        }
        let n_diags = self.offsets.len();
        let offsets: Vec<i64> = self.offsets.iter().rev().map(|&o| -o).collect();
        let mut data = vec![0f32; footprint];
        for (k, &off) in self.offsets.iter().enumerate() {
            let k_t = n_diags - 1 - k; // position of `-off` in `offsets`
            for r in 0..self.rows {
                let c = r as i64 + off;
                if c < 0 || c >= self.cols as i64 {
                    continue;
                }
                data[k_t * self.cols + c as usize] = self.data[k * self.rows + r];
            }
        }
        Ok(Dia { rows: self.cols, cols: self.rows, offsets, data })
    }
}

impl SparseOps for Dia {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        Dia::nnz(self)
    }
    fn nbytes(&self) -> usize {
        Dia::nbytes(self)
    }
    fn to_coo(&self) -> Coo {
        Dia::to_coo(self)
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        Dia::spmm_into(self, x, out)
    }
    fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        Dia::spmm_t_into(self, x, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_banded(rng: &mut Rng, n: usize, band: i64, density: f64) -> Coo {
        let mut triples = Vec::new();
        for r in 0..n {
            for off in -band..=band {
                let c = r as i64 + off;
                if c >= 0 && c < n as i64 && rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, rng.uniform(-1.0, 1.0) as f32));
                }
            }
        }
        Coo::from_triples(n, n, triples)
    }

    #[test]
    fn roundtrip_banded() {
        let mut rng = Rng::new(1);
        let coo = random_banded(&mut rng, 30, 3, 0.7);
        let dia = Dia::from_coo(&coo).unwrap();
        assert_eq!(dia.to_coo(), coo);
        assert_eq!(dia.nnz(), coo.nnz());
        assert!(dia.n_diags() <= 7);
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Rng::new(2);
        let coo = random_banded(&mut rng, 40, 5, 0.5);
        let dia = Dia::from_coo(&coo).unwrap();
        let x = Matrix::rand(40, 8, &mut rng);
        let want = coo.to_dense().matmul(&x);
        assert!(dia.spmm(&x).max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn rectangular_matrices() {
        let coo = Coo::from_triples(
            4,
            6,
            vec![(0, 0, 1.0), (1, 2, 2.0), (3, 5, 3.0), (2, 0, 4.0)],
        );
        let dia = Dia::from_coo(&coo).unwrap();
        assert_eq!(dia.to_coo(), coo);
        let mut rng = Rng::new(3);
        let x = Matrix::rand(6, 3, &mut rng);
        let want = coo.to_dense().matmul(&x);
        assert!(dia.spmm(&x).max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn spmm_t_and_transpose_match_dense() {
        let mut rng = Rng::new(7);
        let coo = random_banded(&mut rng, 40, 5, 0.5);
        let dia = Dia::from_coo(&coo).unwrap();
        let x = Matrix::rand(40, 8, &mut rng);
        let want = coo.to_dense().transpose().matmul(&x);
        let mut out = Matrix::full(40, 8, 123.0); // stale garbage
        dia.spmm_t_into(&x, &mut out);
        assert!(out.max_abs_diff(&want) < 1e-4);
        // Direct transpose agrees with the COO hub.
        let t = dia.transpose().unwrap();
        assert_eq!(t.to_coo(), coo.transpose());
        // Rectangular case.
        let rect = Coo::from_triples(3, 6, vec![(0, 4, 1.5), (2, 0, -2.0), (1, 1, 3.0)]);
        let rd = Dia::from_coo(&rect).unwrap();
        assert_eq!(rd.transpose().unwrap().to_coo(), rect.transpose());
    }

    #[test]
    fn budget_guard_trips() {
        // A maximally scattered pattern on a big-enough matrix: anti-diagonal
        // touches a distinct diagonal per element → n_diags = n.
        let n = 10_000;
        let triples: Vec<_> = (0..n)
            .map(|i| (i as u32, (n - 1 - i) as u32, 1.0f32))
            .collect();
        let coo = Coo::from_triples(n, n, triples);
        assert!(Dia::from_coo(&coo).is_err());
    }

    #[test]
    fn empty_matrix() {
        let coo = Coo::from_triples(5, 5, vec![]);
        let dia = Dia::from_coo(&coo).unwrap();
        assert_eq!(dia.n_diags(), 0);
        assert_eq!(dia.to_coo().nnz(), 0);
    }
}
