//! Coordinate-list (COO) format — the PyTorch/PyG default the paper
//! baselines against, and our canonical interchange representation.

use super::ops::{check_into_shapes, scatter_reduce_into, SparseOps};
use super::schedule::{Schedule, Split};
use crate::tensor::Matrix;
use crate::util::parallel::{even_range, parallel_fill_rows_spans};

/// COO sparse matrix. Invariants: triples sorted by (row, col), unique
/// coordinates, no explicit zeros.
#[derive(Clone, Debug, PartialEq)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub row: Vec<u32>,
    pub col: Vec<u32>,
    pub val: Vec<f32>,
}

impl Coo {
    /// Build from arbitrary triples: sorts, merges duplicates (summing),
    /// drops explicit zeros.
    pub fn from_triples(
        rows: usize,
        cols: usize,
        triples: Vec<(u32, u32, f32)>,
    ) -> Coo {
        let mut triples = triples;
        triples.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut row = Vec::with_capacity(triples.len());
        let mut col = Vec::with_capacity(triples.len());
        let mut val: Vec<f32> = Vec::with_capacity(triples.len());
        for (r, c, v) in triples {
            debug_assert!((r as usize) < rows && (c as usize) < cols);
            if let (Some(&lr), Some(&lc)) = (row.last(), col.last()) {
                if lr == r && lc == c {
                    *val.last_mut().unwrap() += v;
                    continue;
                }
            }
            row.push(r);
            col.push(c);
            val.push(v);
        }
        // Drop entries that became (or were) zero.
        let mut out = Coo { rows, cols, row: vec![], col: vec![], val: vec![] };
        out.row.reserve(val.len());
        out.col.reserve(val.len());
        out.val.reserve(val.len());
        for i in 0..val.len() {
            if val[i] != 0.0 {
                out.row.push(row[i]);
                out.col.push(col[i]);
                out.val.push(val[i]);
            }
        }
        out
    }

    /// Extract the non-zeros of a dense matrix.
    pub fn from_dense(m: &Matrix) -> Coo {
        let mut triples = Vec::new();
        for r in 0..m.rows {
            for c in 0..m.cols {
                let v = m.at(r, c);
                if v != 0.0 {
                    triples.push((r as u32, c as u32, v));
                }
            }
        }
        Coo::from_triples(m.rows, m.cols, triples)
    }

    /// Materialize as dense.
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.nnz() {
            *out.at_mut(self.row[i] as usize, self.col[i] as usize) = self.val[i];
        }
        out
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.val.len()
    }

    /// True when triples are strictly row-major sorted with unique
    /// coordinates — the struct invariant `from_triples` establishes, and
    /// the precondition of the direct `Csr::from_coo` copy.
    pub fn is_sorted_row_major(&self) -> bool {
        (1..self.nnz())
            .all(|i| (self.row[i - 1], self.col[i - 1]) < (self.row[i], self.col[i]))
    }

    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Transpose (swap row/col, re-sort).
    pub fn transpose(&self) -> Coo {
        let triples = (0..self.nnz())
            .map(|i| (self.col[i], self.row[i], self.val[i]))
            .collect();
        Coo::from_triples(self.cols, self.rows, triples)
    }

    /// Storage footprint model: 4B row idx + 4B col idx + 4B value per nnz.
    pub fn nbytes(&self) -> usize {
        self.nnz() * 12
    }

    /// SpMM `self (n×m) · x (m×d) → out (n×d)` into a caller-provided
    /// buffer.
    ///
    /// Because triples are row-sorted, the output can be partitioned by row
    /// ranges: each task binary-searches its triple span and streams it.
    /// Under the default nnz-balanced [`Schedule`], span boundaries are the
    /// rows holding the triple-count quantiles (`row[nnz·i/k]`), so a hub
    /// row never shares its worker with half the matrix.
    // lint: begin(hot-path)
    pub fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        self.spmm_into_sched(x, out, Schedule::effective());
    }

    /// Schedule-parameterized [`Coo::spmm_into`]. The triple stream has no
    /// gather tile, so the split rule (nnz-quantile vs even row ranges) and
    /// thread cap are the knobs that apply.
    pub fn spmm_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        check_into_shapes(self.rows, self.cols, x, out);
        let d = x.cols;
        let (row, col, val) = (&self.row, &self.col, &self.val);
        let n = self.rows;
        let nnz = self.nnz();
        let k = sched.tasks_for(n);
        let span_of = |i: usize| -> std::ops::Range<usize> {
            if n == 0 {
                return 0..0;
            }
            if nnz == 0 || sched.split == Split::EvenUnits {
                return even_range(n, k, i);
            }
            let start = if i == 0 { 0 } else { row[nnz * i / k] as usize };
            let end = if i + 1 == k { n } else { row[nnz * (i + 1) / k] as usize };
            start..end.max(start)
        };
        parallel_fill_rows_spans(&mut out.data, self.rows, d, k, span_of, |range, chunk| {
            chunk.fill(0.0);
            // Triple span covering rows in `range`.
            let lo = row.partition_point(|&r| (r as usize) < range.start);
            let hi = row.partition_point(|&r| (r as usize) < range.end);
            for i in lo..hi {
                let r = row[i] as usize - range.start;
                let c = col[i] as usize;
                let v = val[i];
                let x_row = x.row(c);
                let out_row = &mut chunk[r * d..(r + 1) * d];
                for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                    *o += v * xv;
                }
            }
        });
    }
    // lint: end(hot-path)

    /// Allocating SpMM wrapper.
    pub fn spmm(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, x.cols);
        self.spmm_into(x, &mut out);
        out
    }

    /// Transpose-SpMM `selfᵀ (m×n) · x (n×d) → out (m×d)` — transpose-free:
    /// workers own contiguous triple spans (each triple is one work unit, so
    /// an even split is already nnz-balanced — both split rules coincide
    /// here) and scatter `val·x[row]` into output row `col` of pool-owned
    /// scratch buffers, which are then reduced.
    // lint: begin(hot-path)
    pub fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        self.spmm_t_into_sched(x, out, Schedule::effective());
    }

    /// Schedule-parameterized [`Coo::spmm_t_into`]: only the thread cap
    /// applies (triple spans are already nnz-balanced under either split
    /// rule, and the scatter stream has no gather tile).
    pub fn spmm_t_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        check_into_shapes(self.cols, self.rows, x, out);
        let d = x.cols;
        let (row, col, val) = (&self.row, &self.col, &self.val);
        let nnz = self.nnz();
        let k = sched.tasks_for(nnz);
        scatter_reduce_into(out, k, |i| even_range(nnz, k, i), |span, buf| {
            for i in span {
                let c = col[i] as usize;
                let x_row = x.row(row[i] as usize);
                let v = val[i];
                let out_row = &mut buf[c * d..(c + 1) * d];
                for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                    *o += v * xv;
                }
            }
        });
    }
    // lint: end(hot-path)

    /// Induced submatrix `self[rows, cols]` for sorted, duplicate-free id
    /// selections — native COO filter (this *is* the canonical form, so no
    /// round-trip is involved; see `ops::extract_coo`).
    pub fn extract_rows_cols(&self, rows: &[u32], cols: &[u32]) -> Coo {
        super::ops::extract_coo(self, rows, cols)
    }

    /// Per-row non-zero counts (used by conversions and feature extraction).
    pub fn row_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.rows];
        for &r in &self.row {
            counts[r as usize] += 1;
        }
        counts
    }

    /// Per-column non-zero counts.
    pub fn col_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.cols];
        for &c in &self.col {
            counts[c as usize] += 1;
        }
        counts
    }
}

impl SparseOps for Coo {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        Coo::nnz(self)
    }
    fn nbytes(&self) -> usize {
        Coo::nbytes(self)
    }
    fn to_coo(&self) -> Coo {
        self.clone()
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        Coo::spmm_into(self, x, out)
    }
    fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        Coo::spmm_t_into(self, x, out)
    }
    fn spmm_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        Coo::spmm_into_sched(self, x, out, sched)
    }
    fn spmm_t_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        Coo::spmm_t_into_sched(self, x, out, sched)
    }
    fn extract_rows_cols(&self, rows: &[u32], cols: &[u32]) -> super::SparseMatrix {
        super::SparseMatrix::Coo(Coo::extract_rows_cols(self, rows, cols))
    }
    fn row_sums(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.rows];
        for i in 0..self.nnz() {
            out[self.row[i] as usize] += self.val[i];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    pub fn random_coo(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Coo {
        let mut triples = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, rng.uniform(-1.0, 1.0) as f32));
                }
            }
        }
        Coo::from_triples(rows, cols, triples)
    }

    #[test]
    fn from_triples_sorts_and_dedups() {
        let coo = Coo::from_triples(
            3,
            3,
            vec![(2, 1, 1.0), (0, 0, 2.0), (2, 1, 3.0), (1, 2, 0.0)],
        );
        assert_eq!(coo.nnz(), 2);
        assert_eq!(coo.row, vec![0, 2]);
        assert_eq!(coo.col, vec![0, 1]);
        assert_eq!(coo.val, vec![2.0, 4.0]);
    }

    #[test]
    fn duplicate_cancellation_drops_entry() {
        let coo = Coo::from_triples(2, 2, vec![(0, 0, 1.0), (0, 0, -1.0)]);
        assert_eq!(coo.nnz(), 0);
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Rng::new(1);
        let coo = random_coo(&mut rng, 13, 9, 0.2);
        let dense = coo.to_dense();
        let back = Coo::from_dense(&dense);
        assert_eq!(coo, back);
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Rng::new(2);
        for &(n, m, d) in &[(1usize, 1usize, 1usize), (7, 5, 3), (33, 50, 8), (64, 64, 16)] {
            let a = random_coo(&mut rng, n, m, 0.15);
            let x = Matrix::rand(m, d, &mut rng);
            let got = a.spmm(&x);
            let want = a.to_dense().matmul(&x);
            assert!(got.max_abs_diff(&want) < 1e-4, "({n},{m},{d})");
        }
    }

    #[test]
    fn transpose_twice_is_identity() {
        let mut rng = Rng::new(3);
        let a = random_coo(&mut rng, 11, 17, 0.2);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn counts_sum_to_nnz() {
        let mut rng = Rng::new(4);
        let a = random_coo(&mut rng, 20, 30, 0.1);
        assert_eq!(a.row_counts().iter().sum::<u32>() as usize, a.nnz());
        assert_eq!(a.col_counts().iter().sum::<u32>() as usize, a.nnz());
    }

    #[test]
    fn empty_matrix_spmm() {
        let a = Coo::from_triples(4, 5, vec![]);
        let x = Matrix::full(5, 2, 1.0);
        let y = a.spmm(&x);
        assert_eq!(y.data, vec![0.0; 8]);
    }
}
