//! The [`SparseOps`] trait: the uniform kernel surface every storage format
//! implements, built around **output-buffer-taking** SpMM kernels so the
//! steady-state training path allocates nothing per multiply.
//!
//! Two kernels per format:
//!
//! * [`SparseOps::spmm_into`] — `out = A · X`, overwriting `out` completely.
//! * [`SparseOps::spmm_t_into`] — `out = Aᵀ · X`, **transpose-free**: no
//!   transposed copy of `A` is ever materialized. CSR↔CSC duality makes this
//!   cheap — CSRᵀ·X runs as a CSC-style scatter over the same three arrays,
//!   and CSCᵀ·X runs as a CSR-style gather. The remaining formats scatter
//!   through thread-private buffers ([`scatter_reduce_into`]) or gather
//!   directly (DIA).
//!
//! The allocating [`SparseOps::spmm`]/[`SparseOps::spmm_t`] wrappers are
//! provided for callers that don't hold a workspace (benches, one-shot
//! predictions); the GNN engine routes everything through the `_into`
//! entry points with per-slot recycled buffers (see `gnn::engine`).

use super::coo::Coo;
use crate::tensor::Matrix;
use crate::util::parallel::{num_threads, parallel_fill_rows, split_ranges};

/// Format-agnostic sparse-matrix operations (object-safe; `SparseMatrix`
/// dispatches through `&dyn SparseOps`).
pub trait SparseOps {
    /// `(rows, cols)` of the logical matrix.
    fn shape(&self) -> (usize, usize);

    /// Number of stored non-zeros.
    fn nnz(&self) -> usize;

    /// Storage footprint under the format's memory model (paper Eq. 1).
    fn nbytes(&self) -> usize;

    /// Convert to the canonical COO interchange form.
    fn to_coo(&self) -> Coo;

    /// `out = self · x`; `out` must be `rows × x.cols` and is overwritten
    /// completely (no zeroing required from the caller).
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix);

    /// `out = selfᵀ · x`; `out` must be `cols × x.cols` and is overwritten
    /// completely. Executed transpose-free on the format's own arrays.
    fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix);

    /// Allocating convenience wrapper over [`SparseOps::spmm_into`].
    fn spmm(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.shape().0, x.cols);
        self.spmm_into(x, &mut out);
        out
    }

    /// Allocating convenience wrapper over [`SparseOps::spmm_t_into`].
    fn spmm_t(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.shape().1, x.cols);
        self.spmm_t_into(x, &mut out);
        out
    }
}

/// Shape guard shared by every `_into` kernel.
#[inline]
pub(crate) fn check_into_shapes(
    a_rows: usize,
    a_cols: usize,
    x: &Matrix,
    out: &Matrix,
) {
    assert_eq!(a_cols, x.rows, "spmm shape mismatch");
    assert_eq!(
        (out.rows, out.cols),
        (a_rows, x.cols),
        "spmm output buffer shape mismatch"
    );
}

/// Shared scatter-style kernel: overwrites `out` with the sum of per-worker
/// contributions. Each worker owns a contiguous span of `n_src` source units
/// (columns, rows, row-blocks or raw triples — whatever the format scatters
/// from), accumulates into a thread-private `out.rows × out.cols` buffer via
/// `scatter(span, buf)`, and the buffers are reduced in parallel over output
/// rows. Single-threaded (or single-unit) cases scatter straight into `out`.
pub(crate) fn scatter_reduce_into<F>(out: &mut Matrix, n_src: usize, scatter: F)
where
    F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    let n = out.rows;
    let d = out.cols;
    let nt = num_threads().min(n_src.max(1));
    if nt <= 1 {
        out.data.fill(0.0);
        if n_src > 0 {
            scatter(0..n_src, &mut out.data);
        }
        return;
    }
    let ranges = split_ranges(n_src, nt);
    let partials: Vec<Vec<f32>> = std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|range| {
                let scatter = &scatter;
                s.spawn(move || {
                    let mut buf = vec![0f32; n * d];
                    scatter(range, &mut buf);
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let parts = &partials;
    parallel_fill_rows(&mut out.data, n, d, |range, chunk| {
        chunk.fill(0.0);
        let lo = range.start * d;
        let len = chunk.len();
        for buf in parts {
            for (o, &v) in chunk.iter_mut().zip(buf[lo..lo + len].iter()) {
                *o += v;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_reduce_overwrites_stale_output() {
        // Pre-fill with garbage; the reduction must fully overwrite it.
        let mut out = Matrix::full(8, 3, 99.0);
        scatter_reduce_into(&mut out, 16, |span, buf| {
            for i in span {
                buf[(i % 8) * 3] += 1.0;
            }
        });
        for r in 0..8 {
            assert_eq!(out.at(r, 0), 2.0);
            assert_eq!(out.at(r, 1), 0.0);
            assert_eq!(out.at(r, 2), 0.0);
        }
    }

    #[test]
    fn scatter_reduce_handles_empty_source() {
        let mut out = Matrix::full(4, 2, 7.0);
        scatter_reduce_into(&mut out, 0, |_span, _buf| unreachable!());
        assert_eq!(out.data, vec![0.0; 8]);
    }
}
