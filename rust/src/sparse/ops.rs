//! The [`SparseOps`] trait: the uniform kernel surface every storage format
//! implements, built around **output-buffer-taking** SpMM kernels so the
//! steady-state training path allocates nothing per multiply.
//!
//! Two kernels per format:
//!
//! * [`SparseOps::spmm_into`] — `out = A · X`, overwriting `out` completely.
//! * [`SparseOps::spmm_t_into`] — `out = Aᵀ · X`, **transpose-free**: no
//!   transposed copy of `A` is ever materialized. CSR↔CSC duality makes this
//!   cheap — CSRᵀ·X runs as a CSC-style scatter over the same three arrays,
//!   and CSCᵀ·X runs as a CSR-style gather. The remaining formats scatter
//!   through pool-owned scratch buffers ([`scatter_reduce_into`]) or gather
//!   directly (DIA).
//!
//! Execution model (DESIGN.md §Execution-Pool): every kernel dispatches on
//! the persistent worker pool — no thread is ever spawned per call — and
//! partitions its source units by **non-zero count** (`indptr_span` /
//! `split_ranges_by_weight`), so hub rows of power-law graphs don't pile
//! onto one worker. The CSR/CSC gather loops additionally tile the feature
//! dimension ([`gather_row_lanes`]) with a register-resident accumulator
//! block the compiler can vectorize. Rationale: GE-SpMM (arXiv:2007.03179)
//! shows load-balanced partitioning plus feature-dimension tiling is what
//! makes SpMM competitive for GNN workloads, and the paper's
//! adaptive-format selection only pays off once each kernel runs near its
//! memory roofline — per-call spawn/allocation overhead would otherwise
//! drown the format signal being measured.
//!
//! The allocating [`SparseOps::spmm`]/[`SparseOps::spmm_t`] wrappers are
//! provided for callers that don't hold a workspace (benches, one-shot
//! predictions); the GNN engine routes everything through the `_into` entry
//! points with per-slot recycled buffers (see `gnn::engine`).

use super::coo::Coo;
use super::format::SparseMatrix;
use super::schedule::Schedule;
use crate::tensor::Matrix;
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

std::thread_local! {
    /// [`SparseOps::extract_rows_cols`] calls **on this thread** that fell
    /// back to the COO round-trip (the default trait path). CSR/CSC/COO
    /// extract directly on their own arrays and never bump this — the
    /// mini-batch pipeline asserts the counter stays flat across a sharded
    /// training run (`bench_minibatch` and the minibatch integration test).
    /// Thread-local so concurrently running tests don't observe each
    /// other's fallbacks.
    static COO_FALLBACK_EXTRACTIONS: Cell<u64> = const { Cell::new(0) };
}

/// Fallbacks executed **on pool worker threads**. A thread-local alone
/// would silently miss extractions dispatched onto `util::pool` workers
/// (e.g. a caller fanning per-relation extraction out via `parallel_map`):
/// the worker's thread-local is invisible to the measuring thread, and the
/// zero-fallback acceptance gates would pass vacuously. Worker-side bumps
/// therefore land in this shared atomic, which
/// [`coo_fallback_extractions`] folds into its total. Pool jobs are
/// serialized by the pool's lease, so no concurrent workload can inflate a
/// caller's delta through this term in practice.
static POOL_COO_FALLBACK_EXTRACTIONS: AtomicU64 = AtomicU64::new(0);

/// Record one COO-fallback extraction on the executing thread (worker
/// threads aggregate into the shared pool counter; see above).
fn count_coo_fallback() {
    if crate::util::pool::in_pool_worker() {
        // ord: monotone diagnostic counter; readers compare deltas around a
        // region after the pool lease serializes the jobs, so Relaxed is enough.
        POOL_COO_FALLBACK_EXTRACTIONS.fetch_add(1, Ordering::Relaxed);
    } else {
        COO_FALLBACK_EXTRACTIONS.with(|c| c.set(c.get() + 1));
    }
}

/// COO-fallback extractions visible to this thread: its own thread-local
/// count **plus** everything executed on `util::pool` workers (monotone;
/// compare deltas around the region of interest). Pool-safe: an extraction
/// cannot escape the count by running on a pool worker.
pub fn coo_fallback_extractions() -> u64 {
    COO_FALLBACK_EXTRACTIONS.with(|c| c.get())
        // ord: delta-compared diagnostic read; see count_coo_fallback().
        + POOL_COO_FALLBACK_EXTRACTIONS.load(Ordering::Relaxed)
}

/// Debug-build validation of a row/col id selection: strictly ascending
/// (sorted, duplicate-free) and within the source dimension. The direct
/// extraction kernels rely on this ordering to emit sorted output without a
/// re-sort.
#[inline]
pub(crate) fn debug_assert_selection(sel: &[u32], bound: usize, what: &str) {
    debug_assert!(
        sel.windows(2).all(|w| w[0] < w[1]),
        "{what} selection must be strictly ascending (sorted, duplicate-free)"
    );
    debug_assert!(
        sel.last().map_or(true, |&v| (v as usize) < bound),
        "{what} selection index out of bounds"
    );
}

/// Induced-submatrix filter on a row-major-sorted COO: keeps entries whose
/// row id is in `rows` and col id is in `cols`, re-indexing both to the
/// selection positions. Because the selections are sorted, the output keeps
/// the COO struct invariant (row-major sorted, unique) without a re-sort.
pub(crate) fn extract_coo(coo: &Coo, rows: &[u32], cols: &[u32]) -> Coo {
    debug_assert_selection(rows, coo.rows, "row");
    debug_assert_selection(cols, coo.cols, "col");
    // Sorted + in-bounds + len == dim ⇒ the selection is the identity.
    let all_cols = cols.len() == coo.cols;
    let mut row = Vec::new();
    let mut col = Vec::new();
    let mut val = Vec::new();
    for (new_r, &old_r) in rows.iter().enumerate() {
        let lo = coo.row.partition_point(|&r| r < old_r);
        let hi = coo.row.partition_point(|&r| r <= old_r);
        for i in lo..hi {
            let nc = if all_cols {
                Some(coo.col[i] as usize)
            } else {
                cols.binary_search(&coo.col[i]).ok()
            };
            if let Some(nc) = nc {
                row.push(new_r as u32);
                col.push(nc as u32);
                val.push(coo.val[i]);
            }
        }
    }
    Coo { rows: rows.len(), cols: cols.len(), row, col, val }
}

/// Format-agnostic sparse-matrix operations (object-safe; `SparseMatrix`
/// dispatches through `&dyn SparseOps`).
pub trait SparseOps {
    /// `(rows, cols)` of the logical matrix.
    fn shape(&self) -> (usize, usize);

    /// Number of stored non-zeros.
    fn nnz(&self) -> usize;

    /// Storage footprint under the format's memory model (paper Eq. 1).
    fn nbytes(&self) -> usize;

    /// Convert to the canonical COO interchange form.
    fn to_coo(&self) -> Coo;

    /// Induced submatrix `self[rows, cols]` for **sorted, duplicate-free**
    /// id selections — the mini-batch shard-extraction primitive.
    ///
    /// CSR/CSC/COO override this with direct kernels on their own arrays
    /// (no interchange hop) and preserve their format; the remaining
    /// formats take this default COO round-trip and return a COO result
    /// (the caller's next format decision re-homes it — converting back
    /// eagerly would be wasted work on the shard stream). Fallback calls
    /// are counted in [`coo_fallback_extractions`].
    fn extract_rows_cols(&self, rows: &[u32], cols: &[u32]) -> SparseMatrix {
        count_coo_fallback();
        SparseMatrix::Coo(extract_coo(&self.to_coo(), rows, cols))
    }

    /// Per-row sums of stored values (ρ in GNN-FiLM; degree vector for unit
    /// weights). Default walks a COO view; CSR/CSC/COO override with
    /// array-direct loops.
    fn row_sums(&self) -> Vec<f32> {
        let coo = self.to_coo();
        let mut out = vec![0f32; self.shape().0];
        for i in 0..coo.nnz() {
            out[coo.row[i] as usize] += coo.val[i];
        }
        out
    }

    /// `out = self · x`; `out` must be `rows × x.cols` and is overwritten
    /// completely (no zeroing required from the caller).
    ///
    /// Runs under the process-wide default schedule
    /// ([`Schedule::effective`]); formats with schedule-parameterized
    /// kernels implement this as `spmm_into_sched(x, out,
    /// Schedule::effective())`.
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix);

    /// `out = selfᵀ · x`; `out` must be `cols × x.cols` and is overwritten
    /// completely. Executed transpose-free on the format's own arrays.
    fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix);

    /// Schedule-parameterized `out = self · x` (DESIGN.md
    /// §Schedule-Prediction): the caller picks tile width, split rule and
    /// thread cap per invocation. CSR/CSC/COO/BSR/LIL override this with
    /// kernels that honor every knob that applies to them; formats whose
    /// kernel has no schedule dimension (DIA's diagonal sweep, DOK's
    /// hash-map stream) take this default and ignore the schedule.
    fn spmm_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        let _ = sched;
        self.spmm_into(x, out);
    }

    /// Schedule-parameterized `out = selfᵀ · x`; see
    /// [`SparseOps::spmm_into_sched`].
    fn spmm_t_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        let _ = sched;
        self.spmm_t_into(x, out);
    }

    /// Allocating convenience wrapper over [`SparseOps::spmm_into`].
    fn spmm(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.shape().0, x.cols);
        self.spmm_into(x, &mut out);
        out
    }

    /// Allocating convenience wrapper over [`SparseOps::spmm_t_into`].
    fn spmm_t(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.shape().1, x.cols);
        self.spmm_t_into(x, &mut out);
        out
    }
}

/// Shape guard shared by every `_into` kernel.
#[inline]
pub(crate) fn check_into_shapes(
    a_rows: usize,
    a_cols: usize,
    x: &Matrix,
    out: &Matrix,
) {
    assert_eq!(a_cols, x.rows, "spmm shape mismatch");
    assert_eq!(
        (out.rows, out.cols),
        (a_rows, x.cols),
        "spmm output buffer shape mismatch"
    );
}

/// Gather one output row from sparse entries with `L`-lane
/// feature-dimension tiling: `out_row = Σ_k vals[k] · x[idx[k]]`,
/// overwriting `out_row` completely.
///
/// For `d ≥ L`, columns are processed in fixed-width blocks with a
/// register-resident accumulator array: the inner nnz loop then has no
/// load/store traffic on the output, and the unrolled lane loop
/// auto-vectorizes. Narrow rows fall back to the streaming loop (the tile
/// bookkeeping wouldn't amortize). `L` is const-generic so each tile width
/// is a separate monomorphization — callers dispatch on
/// [`crate::sparse::schedule::Tile`] **once per kernel call**, outside the
/// row loop, and the row loop itself carries no width branching.
// lint: begin(hot-path)
#[inline]
pub(crate) fn gather_row_lanes<const L: usize>(
    out_row: &mut [f32],
    x: &Matrix,
    idx: &[u32],
    vals: &[f32],
) {
    let d = out_row.len();
    debug_assert_eq!(idx.len(), vals.len());
    debug_assert_eq!(d, x.cols);
    if d < L {
        out_row.fill(0.0);
        for (k, &c) in idx.iter().enumerate() {
            let v = vals[k];
            for (o, &xv) in out_row.iter_mut().zip(x.row(c as usize).iter()) {
                *o += v * xv;
            }
        }
        return;
    }
    let mut j = 0;
    while j + L <= d {
        let mut acc = [0.0f32; L];
        for (k, &c) in idx.iter().enumerate() {
            let v = vals[k];
            let xt = &x.row(c as usize)[j..j + L];
            for (a, &xv) in acc.iter_mut().zip(xt.iter()) {
                *a += v * xv;
            }
        }
        out_row[j..j + L].copy_from_slice(&acc);
        j += L;
    }
    if j < d {
        let (_, rem) = out_row.split_at_mut(j);
        rem.fill(0.0);
        for (k, &c) in idx.iter().enumerate() {
            let v = vals[k];
            for (o, &xv) in rem.iter_mut().zip(x.row(c as usize)[j..].iter()) {
                *o += v * xv;
            }
        }
    }
}

/// [`gather_row_lanes`] over `(col, val)` pair lists — the LIL row layout.
/// Same tiling contract: overwrites `out_row` completely, streams when
/// `d < L`.
#[inline]
pub(crate) fn gather_row_pairs_lanes<const L: usize>(
    out_row: &mut [f32],
    x: &Matrix,
    entries: &[(u32, f32)],
) {
    let d = out_row.len();
    debug_assert_eq!(d, x.cols);
    if d < L {
        out_row.fill(0.0);
        for &(c, v) in entries {
            for (o, &xv) in out_row.iter_mut().zip(x.row(c as usize).iter()) {
                *o += v * xv;
            }
        }
        return;
    }
    let mut j = 0;
    while j + L <= d {
        let mut acc = [0.0f32; L];
        for &(c, v) in entries {
            let xt = &x.row(c as usize)[j..j + L];
            for (a, &xv) in acc.iter_mut().zip(xt.iter()) {
                *a += v * xv;
            }
        }
        out_row[j..j + L].copy_from_slice(&acc);
        j += L;
    }
    if j < d {
        let (_, rem) = out_row.split_at_mut(j);
        rem.fill(0.0);
        for &(c, v) in entries {
            for (o, &xv) in rem.iter_mut().zip(x.row(c as usize)[j..].iter()) {
                *o += v * xv;
            }
        }
    }
}

/// Shared scatter-style kernel: overwrites `out` with the sum of per-task
/// contributions. The caller decides the task count (usually
/// `num_threads().min(n_units)`) and supplies `span_of(i)` — the contiguous
/// source-unit span task `i` scatters from, typically weighted by non-zero
/// count so every task carries equal work. Each task accumulates into a
/// pool-owned scratch buffer (grow-only: zero allocations in steady state)
/// via `scatter(span, buf)`; the buffers are then reduced in parallel over
/// output rows. Single-threaded / nested cases scatter straight into `out`.
pub(crate) fn scatter_reduce_into<S, F>(out: &mut Matrix, n_tasks: usize, span_of: S, scatter: F)
where
    S: Fn(usize) -> std::ops::Range<usize> + Sync,
    F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    let (n, d) = (out.rows, out.cols);
    crate::util::pool::global().scatter_reduce(&mut out.data, n, d, n_tasks, span_of, scatter);
}
// lint: end(hot-path)

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parallel::even_range;

    #[test]
    fn scatter_reduce_overwrites_stale_output() {
        // Pre-fill with garbage; the reduction must fully overwrite it.
        let mut out = Matrix::full(8, 3, 99.0);
        let k = crate::util::parallel::num_threads().min(16).max(2);
        scatter_reduce_into(&mut out, k, |i| even_range(16, k, i), |span, buf| {
            for i in span {
                buf[(i % 8) * 3] += 1.0;
            }
        });
        for r in 0..8 {
            assert_eq!(out.at(r, 0), 2.0);
            assert_eq!(out.at(r, 1), 0.0);
            assert_eq!(out.at(r, 2), 0.0);
        }
    }

    #[test]
    fn scatter_reduce_handles_empty_source() {
        let mut out = Matrix::full(4, 2, 7.0);
        scatter_reduce_into(&mut out, 1, |_| 0..0, |_span, _buf| unreachable!());
        assert_eq!(out.data, vec![0.0; 8]);
    }

    #[test]
    fn gather_row_default_tile_matches_naive() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        for &d in &[1usize, 3, 15, 16, 17, 32, 40, 64] {
            let x = Matrix::rand(30, d, &mut rng);
            let idx: Vec<u32> = (0..12).map(|_| rng.gen_range(30) as u32).collect();
            let vals: Vec<f32> = (0..12).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            let mut naive = vec![0f32; d];
            for (k, &c) in idx.iter().enumerate() {
                for (o, &xv) in naive.iter_mut().zip(x.row(c as usize).iter()) {
                    *o += vals[k] * xv;
                }
            }
            let mut got = vec![123.0f32; d]; // stale garbage: must be overwritten
            gather_row_lanes::<16>(&mut got, &x, &idx, &vals);
            for (g, w) in got.iter().zip(naive.iter()) {
                assert!((g - w).abs() < 1e-4, "d={d}");
            }
        }
    }

    #[test]
    fn gather_lanes_agree_across_tile_widths() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(23);
        // Widths straddling every tile boundary: below the narrowest tile,
        // exact multiples, and off-by-one remainders of each lane count.
        for &d in &[0usize, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 40, 64] {
            let x = Matrix::rand(30, d, &mut rng);
            let idx: Vec<u32> = (0..12).map(|_| rng.gen_range(30) as u32).collect();
            let vals: Vec<f32> = (0..12).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            let pairs: Vec<(u32, f32)> =
                idx.iter().copied().zip(vals.iter().copied()).collect();
            let mut want = vec![-9.0f32; d];
            gather_row_lanes::<16>(&mut want, &x, &idx, &vals);
            let run = |got: &[f32], label: &str| {
                assert_eq!(got.len(), d);
                for (g, w) in got.iter().zip(want.iter()) {
                    assert!((g - w).abs() < 1e-4, "{label} d={d}");
                }
            };
            let mut got = vec![123.0f32; d];
            gather_row_lanes::<4>(&mut got, &x, &idx, &vals);
            run(&got, "L=4");
            gather_row_lanes::<8>(&mut got, &x, &idx, &vals);
            run(&got, "L=8");
            gather_row_lanes::<32>(&mut got, &x, &idx, &vals);
            run(&got, "L=32");
            for (lanes, label) in [(4usize, "pairs L=4"), (16, "pairs L=16"), (32, "pairs L=32")]
            {
                got.fill(123.0);
                match lanes {
                    4 => gather_row_pairs_lanes::<4>(&mut got, &x, &pairs),
                    16 => gather_row_pairs_lanes::<16>(&mut got, &x, &pairs),
                    _ => gather_row_pairs_lanes::<32>(&mut got, &x, &pairs),
                }
                run(&got, label);
            }
        }
    }
}
