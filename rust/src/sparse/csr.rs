//! Compressed sparse row (CSR) — the workhorse format; typically the
//! paper's Fig-1 winner for GNN inputs.

use super::coo::Coo;
use super::ops::{check_into_shapes, gather_row_lanes, scatter_reduce_into, SparseOps};
use super::schedule::{Schedule, Split, Tile};
use crate::tensor::Matrix;
use crate::util::parallel::{even_range, indptr_span, parallel_fill_rows_spans};

/// CSR sparse matrix: `indptr[r]..indptr[r+1]` spans row `r`'s entries in
/// `indices` (column ids, ascending within a row) and `vals`.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr {
    pub fn from_coo(coo: &Coo) -> Csr {
        // Precondition: the direct indices/vals copy below is only correct
        // for row-major-sorted COO (the `Coo` struct invariant). An unsorted
        // input would silently scramble entries across rows.
        debug_assert!(
            coo.is_sorted_row_major(),
            "Csr::from_coo requires strictly row-major-sorted COO triples"
        );
        let mut indptr = vec![0usize; coo.rows + 1];
        for &r in &coo.row {
            indptr[r as usize + 1] += 1;
        }
        for i in 0..coo.rows {
            indptr[i + 1] += indptr[i];
        }
        // COO is row-major sorted, so indices/vals copy straight through.
        Csr {
            rows: coo.rows,
            cols: coo.cols,
            indptr,
            indices: coo.col.clone(),
            vals: coo.val.clone(),
        }
    }

    /// Direct dense→CSR sparsification (single pass; used by the engine's
    /// per-epoch activation refresh to skip the COO intermediate).
    pub fn from_dense(m: &crate::tensor::Matrix) -> Csr {
        let mut indptr = Vec::with_capacity(m.rows + 1);
        indptr.push(0usize);
        let mut indices = Vec::new();
        let mut vals = Vec::new();
        for r in 0..m.rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    indices.push(c as u32);
                    vals.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Csr { rows: m.rows, cols: m.cols, indptr, indices, vals }
    }

    pub fn to_coo(&self) -> Coo {
        let mut row = Vec::with_capacity(self.nnz());
        for r in 0..self.rows {
            for _ in self.indptr[r]..self.indptr[r + 1] {
                row.push(r as u32);
            }
        }
        Coo {
            rows: self.rows,
            cols: self.cols,
            row,
            col: self.indices.clone(),
            val: self.vals.clone(),
        }
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Row `r`'s (column, value) entries.
    #[inline]
    pub fn row_entries(&self, r: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let span = self.indptr[r]..self.indptr[r + 1];
        self.indices[span.clone()]
            .iter()
            .zip(self.vals[span].iter())
            .map(|(&c, &v)| (c as usize, v))
    }

    /// Footprint model: 4B col idx + 4B value per nnz, 8B per indptr slot.
    pub fn nbytes(&self) -> usize {
        self.nnz() * 8 + (self.rows + 1) * 8
    }

    /// A new CSR with the listed rows replaced wholesale (each replacement
    /// a column-sorted `(col, val)` list; an empty list empties the row).
    /// Untouched rows are copied verbatim — `graph::stream` compaction
    /// leans on this to rebuild only the rows its delta overlay touched.
    pub fn replace_rows(
        &self,
        rows: &std::collections::BTreeMap<u32, Vec<(u32, f32)>>,
    ) -> Csr {
        let replaced: usize = rows.values().map(Vec::len).sum();
        let kept: usize = rows.keys().map(|&r| {
            let r = r as usize;
            self.indptr[r + 1] - self.indptr[r]
        }).sum();
        let new_nnz = self.nnz() - kept + replaced;
        let mut indptr = Vec::with_capacity(self.rows + 1);
        indptr.push(0usize);
        let mut indices = Vec::with_capacity(new_nnz);
        let mut vals = Vec::with_capacity(new_nnz);
        for r in 0..self.rows {
            if let Some(entries) = rows.get(&(r as u32)) {
                debug_assert!(
                    entries.windows(2).all(|w| w[0].0 < w[1].0),
                    "replacement row {r} must be strictly column-sorted"
                );
                for &(c, v) in entries {
                    indices.push(c);
                    vals.push(v);
                }
            } else {
                let span = self.indptr[r]..self.indptr[r + 1];
                indices.extend_from_slice(&self.indices[span.clone()]);
                vals.extend_from_slice(&self.vals[span]);
            }
            indptr.push(indices.len());
        }
        Csr { rows: self.rows, cols: self.cols, indptr, indices, vals }
    }

    /// SpMM `self (n×m) · x (m×d) → out (n×d)`, parallel over row spans,
    /// into a caller-provided buffer (the zero-allocation hot path: pool
    /// dispatch + per-task span boundaries allocate nothing). Runs under the
    /// process-wide default [`Schedule`].
    ///
    /// The inner loop is feature-tiled ([`gather_row_lanes`]): a
    /// register-resident accumulator block per column tile, streaming `x`
    /// rows — the canonical row-major-friendly kernel (and why CSR usually
    /// wins).
    // lint: begin(hot-path)
    pub fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        self.spmm_into_sched(x, out, Schedule::effective());
    }

    /// Schedule-parameterized [`Csr::spmm_into`]: the tile width picks a
    /// monomorphized gather instantiation (one `match` per call, outside the
    /// row loop), the split rule picks nnz-balanced vs even row spans, and
    /// the thread cap folds into the task count.
    pub fn spmm_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        match sched.tile {
            Tile::T4 => self.spmm_into_lanes::<4>(x, out, sched),
            Tile::T8 => self.spmm_into_lanes::<8>(x, out, sched),
            Tile::T16 => self.spmm_into_lanes::<16>(x, out, sched),
            Tile::T32 => self.spmm_into_lanes::<32>(x, out, sched),
        }
    }

    fn spmm_into_lanes<const L: usize>(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        check_into_shapes(self.rows, self.cols, x, out);
        let d = x.cols;
        let k = sched.tasks_for(self.rows);
        parallel_fill_rows_spans(
            &mut out.data,
            self.rows,
            d,
            k,
            |i| match sched.split {
                Split::NnzBalanced => indptr_span(&self.indptr, k, i),
                Split::EvenUnits => even_range(self.rows, k, i),
            },
            |range, chunk| {
                for (rr, r) in range.clone().enumerate() {
                    let out_row = &mut chunk[rr * d..(rr + 1) * d];
                    let span = self.indptr[r]..self.indptr[r + 1];
                    gather_row_lanes::<L>(
                        out_row,
                        x,
                        &self.indices[span.clone()],
                        &self.vals[span],
                    );
                }
            },
        );
    }
    // lint: end(hot-path)

    /// Allocating SpMM wrapper.
    pub fn spmm(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, x.cols);
        self.spmm_into(x, &mut out);
        out
    }

    /// Transpose-SpMM `selfᵀ (m×n) · x (n×d) → out (m×d)` — transpose-free.
    ///
    /// CSR↔CSC duality: the CSR arrays of `A` *are* the CSC arrays of `Aᵀ`
    /// (`indptr` spans become column spans), so `Aᵀ·X` executes as a
    /// CSC-style scatter over the same three arrays with zero conversion.
    /// Runs under the process-wide default [`Schedule`].
    // lint: begin(hot-path)
    pub fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        self.spmm_t_into_sched(x, out, Schedule::effective());
    }

    /// Schedule-parameterized [`Csr::spmm_t_into`]. The scatter kernel has
    /// no gather tile, so only the split rule and thread cap apply.
    pub fn spmm_t_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        check_into_shapes(self.cols, self.rows, x, out);
        let d = x.cols;
        let k = sched.tasks_for(self.rows);
        let span_of = |i| match sched.split {
            Split::NnzBalanced => indptr_span(&self.indptr, k, i),
            Split::EvenUnits => even_range(self.rows, k, i),
        };
        scatter_reduce_into(out, k, span_of, |rows, buf| {
            for r in rows {
                let x_row = x.row(r);
                let span = self.indptr[r]..self.indptr[r + 1];
                for (idx, &c) in self.indices[span.clone()].iter().enumerate() {
                    let v = self.vals[span.start + idx];
                    let out_row = &mut buf[c as usize * d..(c as usize + 1) * d];
                    for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                        *o += v * xv;
                    }
                }
            }
        });
    }
    // lint: end(hot-path)

    /// Direct structural transpose: counting-sort the entries by column
    /// (exactly [`Csr::to_csc`]) and reinterpret the CSC arrays of `self` as
    /// the CSR arrays of `selfᵀ` — no COO hop.
    pub fn transpose(&self) -> Csr {
        let csc = self.to_csc();
        Csr {
            rows: csc.cols,
            cols: csc.rows,
            indptr: csc.indptr,
            indices: csc.indices,
            vals: csc.vals,
        }
    }

    /// Induced submatrix `self[rows, cols]` for sorted, duplicate-free id
    /// selections, extracted **directly on the CSR arrays** — one pass over
    /// the selected rows' spans, columns re-indexed by binary search into
    /// `cols` (skipped entirely when `cols` selects every column, the
    /// feature-matrix row-slice case). No COO round-trip: this is the
    /// mini-batch shard-extraction hot path.
    pub fn extract_rows_cols(&self, rows: &[u32], cols: &[u32]) -> Csr {
        super::ops::debug_assert_selection(rows, self.rows, "row");
        super::ops::debug_assert_selection(cols, self.cols, "col");
        let all_cols = cols.len() == self.cols;
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        indptr.push(0usize);
        let mut indices = Vec::new();
        let mut vals = Vec::new();
        for &old_r in rows {
            let span = self.indptr[old_r as usize]..self.indptr[old_r as usize + 1];
            if all_cols {
                indices.extend_from_slice(&self.indices[span.clone()]);
                vals.extend_from_slice(&self.vals[span]);
            } else {
                for i in span {
                    if let Ok(nc) = cols.binary_search(&self.indices[i]) {
                        indices.push(nc as u32);
                        vals.push(self.vals[i]);
                    }
                }
            }
            indptr.push(indices.len());
        }
        Csr { rows: rows.len(), cols: cols.len(), indptr, indices, vals }
    }

    /// Direct CSR→CSC conversion by counting sort over columns (faster than
    /// the COO hub; used on the per-layer format-switch hot path).
    pub fn to_csc(&self) -> super::csc::Csc {
        let mut colptr = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            colptr[c as usize + 1] += 1;
        }
        for i in 0..self.cols {
            colptr[i + 1] += colptr[i];
        }
        let mut indices = vec![0u32; self.nnz()];
        let mut vals = vec![0f32; self.nnz()];
        let mut next = colptr.clone();
        for r in 0..self.rows {
            for i in self.indptr[r]..self.indptr[r + 1] {
                let c = self.indices[i] as usize;
                let slot = next[c];
                indices[slot] = r as u32;
                vals[slot] = self.vals[i];
                next[c] += 1;
            }
        }
        super::csc::Csc {
            rows: self.rows,
            cols: self.cols,
            indptr: colptr,
            indices,
            vals,
        }
    }
}

impl SparseOps for Csr {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        Csr::nnz(self)
    }
    fn nbytes(&self) -> usize {
        Csr::nbytes(self)
    }
    fn to_coo(&self) -> Coo {
        Csr::to_coo(self)
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        Csr::spmm_into(self, x, out)
    }
    fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        Csr::spmm_t_into(self, x, out)
    }
    fn spmm_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        Csr::spmm_into_sched(self, x, out, sched)
    }
    fn spmm_t_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        Csr::spmm_t_into_sched(self, x, out, sched)
    }
    fn extract_rows_cols(&self, rows: &[u32], cols: &[u32]) -> super::SparseMatrix {
        super::SparseMatrix::Csr(Csr::extract_rows_cols(self, rows, cols))
    }
    fn row_sums(&self) -> Vec<f32> {
        (0..self.rows)
            .map(|r| self.vals[self.indptr[r]..self.indptr[r + 1]].iter().sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Coo {
        let mut triples = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, rng.uniform(-1.0, 1.0) as f32));
                }
            }
        }
        Coo::from_triples(rows, cols, triples)
    }

    #[test]
    fn replace_rows_splices_and_copies_verbatim() {
        let base = Csr::from_coo(&Coo::from_triples(
            4,
            6,
            vec![(0, 0, 1.0), (0, 5, 5.0), (1, 2, 2.0), (3, 3, 3.0)],
        ));
        let mut patch = std::collections::BTreeMap::new();
        patch.insert(0u32, vec![(1u32, 10.0f32), (4, 40.0)]); // rewritten
        patch.insert(1, Vec::new()); // emptied
        patch.insert(2, vec![(0, 7.0)]); // was empty, now populated
        let out = base.replace_rows(&patch);
        assert_eq!(out.rows, 4);
        assert_eq!(out.cols, 6);
        assert_eq!(out.nnz(), 4);
        assert_eq!(out.row_entries(0).collect::<Vec<_>>(), vec![(1, 10.0), (4, 40.0)]);
        assert_eq!(out.row_entries(1).count(), 0);
        assert_eq!(out.row_entries(2).collect::<Vec<_>>(), vec![(0, 7.0)]);
        // Untouched row 3 is bit-identical.
        assert_eq!(out.row_entries(3).collect::<Vec<_>>(), vec![(3, 3.0)]);
        // No-patch call clones the structure outright.
        assert_eq!(base.replace_rows(&std::collections::BTreeMap::new()), base);
    }

    #[test]
    fn coo_roundtrip() {
        let mut rng = Rng::new(1);
        let coo = random_coo(&mut rng, 17, 11, 0.2);
        let csr = Csr::from_coo(&coo);
        assert_eq!(csr.to_coo(), coo);
        assert_eq!(csr.nnz(), coo.nnz());
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Rng::new(2);
        for &(n, m, d) in &[(5usize, 7usize, 3usize), (40, 33, 9), (64, 64, 16)] {
            let coo = random_coo(&mut rng, n, m, 0.15);
            let csr = Csr::from_coo(&coo);
            let x = Matrix::rand(m, d, &mut rng);
            let want = coo.to_dense().matmul(&x);
            assert!(csr.spmm(&x).max_abs_diff(&want) < 1e-4);
        }
    }

    #[test]
    fn row_entries_sorted() {
        let mut rng = Rng::new(3);
        let csr = Csr::from_coo(&random_coo(&mut rng, 30, 30, 0.2));
        for r in 0..30 {
            let cols: Vec<usize> = csr.row_entries(r).map(|(c, _)| c).collect();
            let mut sorted = cols.clone();
            sorted.sort_unstable();
            assert_eq!(cols, sorted);
        }
    }

    #[test]
    fn direct_csc_matches_hub() {
        let mut rng = Rng::new(4);
        let coo = random_coo(&mut rng, 23, 31, 0.12);
        let csr = Csr::from_coo(&coo);
        let direct = csr.to_csc();
        let via_hub = super::super::csc::Csc::from_coo(&coo);
        assert_eq!(direct, via_hub);
    }

    #[test]
    fn spmm_t_matches_transposed_dense() {
        let mut rng = Rng::new(5);
        for &(n, m, d) in &[(5usize, 7usize, 3usize), (40, 33, 9), (64, 64, 16)] {
            let coo = random_coo(&mut rng, n, m, 0.15);
            let csr = Csr::from_coo(&coo);
            let x = Matrix::rand(n, d, &mut rng);
            let want = coo.to_dense().transpose().matmul(&x);
            let mut out = Matrix::full(m, d, 123.0); // stale garbage: must be overwritten
            csr.spmm_t_into(&x, &mut out);
            assert!(out.max_abs_diff(&want) < 1e-4, "({n},{m},{d})");
        }
    }

    #[test]
    fn direct_transpose_matches_coo_hub() {
        let mut rng = Rng::new(6);
        let coo = random_coo(&mut rng, 21, 34, 0.18);
        let direct = Csr::from_coo(&coo).transpose();
        assert_eq!(direct.to_coo(), coo.transpose());
        assert_eq!(direct.rows, 34);
        assert_eq!(direct.cols, 21);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "row-major-sorted")]
    fn from_coo_rejects_unsorted_triples() {
        // Bypass Coo::from_triples (which sorts) to violate the invariant
        // the direct indices/vals copy depends on.
        let bad = Coo {
            rows: 2,
            cols: 2,
            row: vec![1, 0],
            col: vec![0, 1],
            val: vec![1.0, 2.0],
        };
        let _ = Csr::from_coo(&bad);
    }

    #[test]
    fn empty_rows_handled() {
        let coo = Coo::from_triples(5, 5, vec![(0, 0, 1.0), (4, 4, 2.0)]);
        let csr = Csr::from_coo(&coo);
        assert_eq!(csr.indptr, vec![0, 1, 1, 1, 1, 2]);
        let x = Matrix::eye(5);
        let y = csr.spmm(&x);
        assert_eq!(y.at(0, 0), 1.0);
        assert_eq!(y.at(4, 4), 2.0);
    }
}
