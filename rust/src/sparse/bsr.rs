//! Block sparse row (BSR): CSR over fixed-shape dense sub-blocks.
//!
//! This is also the format our Pallas/TPU kernel (L1) implements — dense
//! blocks feed the MXU systolic array; see DESIGN.md §Hardware-Adaptation.
//! The rust kernel mirrors that schedule on CPU: per row-block, accumulate
//! `A_blk · X_blk` panels.

use super::coo::Coo;
use super::ops::{check_into_shapes, scatter_reduce_into, SparseOps};
use super::schedule::{Schedule, Split};
use crate::tensor::Matrix;
use crate::util::parallel::{even_range, indptr_span, parallel_fill_rows_spans};
use std::collections::HashMap;

/// Default block edge; benches ablate 8..128 (see `ablation_block_size`).
pub const DEFAULT_BLOCK: usize = 16;

/// BSR sparse matrix with square `block × block` blocks. Virtual matrix
/// dimensions are padded up to block multiples; out-of-range padding is
/// zero-filled inside blocks.
#[derive(Clone, Debug, PartialEq)]
pub struct Bsr {
    pub rows: usize,
    pub cols: usize,
    pub block: usize,
    /// Row-block pointer (length `rows.div_ceil(block) + 1`).
    pub indptr: Vec<usize>,
    /// Column-block index per stored block.
    pub indices: Vec<u32>,
    /// Dense block storage, `indices.len() * block * block`, row-major
    /// within each block.
    pub blocks: Vec<f32>,
}

impl Bsr {
    pub fn from_coo(coo: &Coo, block: usize) -> Bsr {
        assert!(block > 0);
        let rb = coo.rows.div_ceil(block);
        // Map (row-block, col-block) -> slot, in sorted order.
        let mut keys: Vec<(u32, u32)> = (0..coo.nnz())
            .map(|i| (coo.row[i] / block as u32, coo.col[i] / block as u32))
            .collect();
        keys.sort_unstable();
        keys.dedup();
        let slot_of: HashMap<(u32, u32), usize> =
            keys.iter().enumerate().map(|(s, &k)| (k, s)).collect();

        let mut indptr = vec![0usize; rb + 1];
        for &(br, _) in &keys {
            indptr[br as usize + 1] += 1;
        }
        for i in 0..rb {
            indptr[i + 1] += indptr[i];
        }
        let indices: Vec<u32> = keys.iter().map(|&(_, bc)| bc).collect();
        let mut blocks = vec![0f32; keys.len() * block * block];
        for i in 0..coo.nnz() {
            let (r, c) = (coo.row[i] as usize, coo.col[i] as usize);
            let key = ((r / block) as u32, (c / block) as u32);
            let slot = slot_of[&key];
            let br_off = r % block;
            let bc_off = c % block;
            blocks[slot * block * block + br_off * block + bc_off] = coo.val[i];
        }
        Bsr { rows: coo.rows, cols: coo.cols, block, indptr, indices, blocks }
    }

    pub fn to_coo(&self) -> Coo {
        let b = self.block;
        let mut triples = Vec::new();
        let rb = self.rows.div_ceil(b);
        for brow in 0..rb {
            for s in self.indptr[brow]..self.indptr[brow + 1] {
                let bcol = self.indices[s] as usize;
                for i in 0..b {
                    let r = brow * b + i;
                    if r >= self.rows {
                        break;
                    }
                    for j in 0..b {
                        let c = bcol * b + j;
                        if c >= self.cols {
                            break;
                        }
                        let v = self.blocks[s * b * b + i * b + j];
                        if v != 0.0 {
                            triples.push((r as u32, c as u32, v));
                        }
                    }
                }
            }
        }
        Coo::from_triples(self.rows, self.cols, triples)
    }

    pub fn n_blocks(&self) -> usize {
        self.indices.len()
    }

    pub fn nnz(&self) -> usize {
        self.blocks.iter().filter(|&&v| v != 0.0).count()
    }

    /// Fraction of stored block slots that hold actual non-zeros (MXU
    /// utilization proxy for the TPU variant).
    pub fn block_fill(&self) -> f64 {
        if self.blocks.is_empty() {
            return 0.0;
        }
        self.nnz() as f64 / self.blocks.len() as f64
    }

    /// Footprint model: dense block storage + 4B block col idx + 8B indptr.
    pub fn nbytes(&self) -> usize {
        self.blocks.len() * 4 + self.indices.len() * 4 + self.indptr.len() * 8
    }

    /// SpMM `self (n×m) · x (m×d) → out (n×d)`, parallel over row-blocks,
    /// into a caller-provided buffer.
    ///
    /// For each stored block, accumulates a dense `block × d` panel:
    /// `Y[brow·b .. brow·b+b] += A_blk · X[bcol·b .. bcol·b+b]`. Runs under
    /// the process-wide default [`Schedule`].
    // lint: begin(hot-path)
    pub fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        self.spmm_into_sched(x, out, Schedule::effective());
    }

    /// Schedule-parameterized [`Bsr::spmm_into`]: the split rule picks
    /// stored-block-balanced vs even row-block spans and the thread cap
    /// folds into the task count. The block edge is fixed at construction,
    /// so the gather-tile knob does not apply.
    pub fn spmm_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        check_into_shapes(self.rows, self.cols, x, out);
        let b = self.block;
        let d = x.cols;
        let n = self.rows;
        let rb = n.div_ceil(b);
        // Tasks own contiguous row-block spans, balanced by stored-block
        // count (`indptr` weight ≈ nnz) or split evenly; spans are converted
        // to row spans so each task zeroes and fills a disjoint output chunk.
        let k = sched.tasks_for(rb);
        parallel_fill_rows_spans(
            &mut out.data,
            n,
            d,
            k,
            |i| {
                let bs = match sched.split {
                    Split::NnzBalanced => indptr_span(&self.indptr, k, i),
                    Split::EvenUnits => even_range(rb, k, i),
                };
                (bs.start * b).min(n)..(bs.end * b).min(n)
            },
            |range, chunk| {
                chunk.fill(0.0);
                for brow in range.start / b..range.end.div_ceil(b) {
                    let row0 = brow * b;
                    let row1 = (row0 + b).min(n);
                    for s in self.indptr[brow]..self.indptr[brow + 1] {
                        let bcol = self.indices[s] as usize;
                        let col0 = bcol * b;
                        let col1 = (col0 + b).min(self.cols);
                        let blk = &self.blocks[s * b * b..(s + 1) * b * b];
                        for (i, r) in (row0..row1).enumerate() {
                            let off = (r - range.start) * d;
                            let out_row = &mut chunk[off..off + d];
                            for (j, c) in (col0..col1).enumerate() {
                                let v = blk[i * b + j];
                                if v == 0.0 {
                                    continue;
                                }
                                let x_row = x.row(c);
                                for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                                    *o += v * xv;
                                }
                            }
                        }
                    }
                }
            },
        );
    }
    // lint: end(hot-path)

    /// Allocating SpMM wrapper.
    pub fn spmm(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, x.cols);
        self.spmm_into(x, &mut out);
        out
    }

    /// Transpose-SpMM `selfᵀ (m×n) · x (n×d) → out (m×d)` — transpose-free:
    /// workers own nnz-balanced row-block spans and scatter each stored
    /// block's transposed panel (`Y[c] += A[r][c] · X[r]`) into pool-owned
    /// scratch buffers, reduced at the end. No transposed block index is
    /// built. Runs under the process-wide default [`Schedule`].
    // lint: begin(hot-path)
    pub fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        self.spmm_t_into_sched(x, out, Schedule::effective());
    }

    /// Schedule-parameterized [`Bsr::spmm_t_into`]. Only the split rule and
    /// thread cap apply (see [`Bsr::spmm_into_sched`]).
    pub fn spmm_t_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        check_into_shapes(self.cols, self.rows, x, out);
        let b = self.block;
        let d = x.cols;
        let rb = self.rows.div_ceil(b);
        let k = sched.tasks_for(rb);
        let span_of = |i| match sched.split {
            Split::NnzBalanced => indptr_span(&self.indptr, k, i),
            Split::EvenUnits => even_range(rb, k, i),
        };
        scatter_reduce_into(out, k, span_of, |brange, buf| {
            for brow in brange {
                let row0 = brow * b;
                let row1 = (row0 + b).min(self.rows);
                for s in self.indptr[brow]..self.indptr[brow + 1] {
                    let bcol = self.indices[s] as usize;
                    let col0 = bcol * b;
                    let col1 = (col0 + b).min(self.cols);
                    let blk = &self.blocks[s * b * b..(s + 1) * b * b];
                    for (i, r) in (row0..row1).enumerate() {
                        let x_row = x.row(r);
                        for (j, c) in (col0..col1).enumerate() {
                            let v = blk[i * b + j];
                            if v == 0.0 {
                                continue;
                            }
                            let out_row = &mut buf[c * d..(c + 1) * d];
                            for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                                *o += v * xv;
                            }
                        }
                    }
                }
            }
        });
    }
    // lint: end(hot-path)
}

impl SparseOps for Bsr {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        Bsr::nnz(self)
    }
    fn nbytes(&self) -> usize {
        Bsr::nbytes(self)
    }
    fn to_coo(&self) -> Coo {
        Bsr::to_coo(self)
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        Bsr::spmm_into(self, x, out)
    }
    fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        Bsr::spmm_t_into(self, x, out)
    }
    fn spmm_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        Bsr::spmm_into_sched(self, x, out, sched)
    }
    fn spmm_t_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        Bsr::spmm_t_into_sched(self, x, out, sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Coo {
        let mut triples = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, rng.uniform(-1.0, 1.0) as f32));
                }
            }
        }
        Coo::from_triples(rows, cols, triples)
    }

    #[test]
    fn roundtrip_various_blocks() {
        let mut rng = Rng::new(1);
        let coo = random_coo(&mut rng, 37, 29, 0.1); // non-multiple dims
        for &b in &[1usize, 2, 4, 8, 16] {
            let bsr = Bsr::from_coo(&coo, b);
            assert_eq!(bsr.to_coo(), coo, "block={b}");
            assert_eq!(bsr.nnz(), coo.nnz());
        }
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Rng::new(2);
        for &(n, m, b) in &[(20usize, 30usize, 4usize), (37, 29, 8), (64, 64, 16), (10, 10, 16)] {
            let coo = random_coo(&mut rng, n, m, 0.15);
            let bsr = Bsr::from_coo(&coo, b);
            let x = Matrix::rand(m, 7, &mut rng);
            let want = coo.to_dense().matmul(&x);
            assert!(bsr.spmm(&x).max_abs_diff(&want) < 1e-4, "({n},{m},b={b})");
        }
    }

    #[test]
    fn block_fill_bounds() {
        let mut rng = Rng::new(3);
        let coo = random_coo(&mut rng, 64, 64, 0.05);
        let bsr = Bsr::from_coo(&coo, 8);
        let fill = bsr.block_fill();
        assert!(fill > 0.0 && fill <= 1.0);
        // Block-diagonal dense pattern has fill 1.0:
        let mut triples = Vec::new();
        for blk in 0..4u32 {
            for i in 0..8u32 {
                for j in 0..8u32 {
                    triples.push((blk * 8 + i, blk * 8 + j, 1.0));
                }
            }
        }
        let bd = Bsr::from_coo(&Coo::from_triples(32, 32, triples), 8);
        assert_eq!(bd.n_blocks(), 4);
        assert!((bd.block_fill() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_matrix() {
        let coo = Coo::from_triples(16, 16, vec![]);
        let bsr = Bsr::from_coo(&coo, 4);
        assert_eq!(bsr.n_blocks(), 0);
        let x = Matrix::full(16, 2, 1.0);
        assert_eq!(bsr.spmm(&x).data, vec![0.0; 32]);
    }
}
