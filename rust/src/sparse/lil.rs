//! List-of-lists (LIL): per-row lists of `(col, value)` pairs, the
//! row-mutable format. SpMM walks each row list; the per-node indirection
//! cost is modeled in the memory footprint.

use super::coo::Coo;
use super::ops::{check_into_shapes, gather_row_pairs_lanes, scatter_reduce_into, SparseOps};
use super::schedule::{Schedule, Split, Tile};
use crate::tensor::Matrix;
use crate::util::parallel::{even_range, indptr_span, parallel_fill_rows_spans};
use std::sync::OnceLock;

/// LIL sparse matrix: `rows_data[r]` is row `r`'s sorted `(col, val)` list.
///
/// Carries a lazily-built nnz **prefix-sum cache** (`indptr`-style) so the
/// SpMM kernels can binary-search nnz-balanced row spans like the
/// compressed formats instead of materializing a range list per multiply
/// (the last per-op allocation the execution-pool rework left behind —
/// ROADMAP). Structural mutation ([`Lil::insert`]) invalidates the cache;
/// value-only updates keep it.
#[derive(Clone, Debug)]
pub struct Lil {
    pub rows: usize,
    pub cols: usize,
    pub rows_data: Vec<Vec<(u32, f32)>>,
    /// Cached per-row nnz prefix sums (`len == rows + 1`), built on first
    /// kernel use. `OnceLock` keeps `Lil: Sync` for the worker pool.
    indptr: OnceLock<Vec<usize>>,
}

/// Equality is structural only — the prefix-sum cache is derived state.
impl PartialEq for Lil {
    fn eq(&self, other: &Lil) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.rows_data == other.rows_data
    }
}

impl Lil {
    pub fn from_coo(coo: &Coo) -> Lil {
        let mut rows_data = vec![Vec::new(); coo.rows];
        for i in 0..coo.nnz() {
            rows_data[coo.row[i] as usize].push((coo.col[i], coo.val[i]));
        }
        Lil { rows: coo.rows, cols: coo.cols, rows_data, indptr: OnceLock::new() }
    }

    /// Direct dense→LIL sparsification (single pass).
    pub fn from_dense(m: &crate::tensor::Matrix) -> Lil {
        let rows_data = (0..m.rows)
            .map(|r| {
                m.row(r)
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(c, &v)| (c as u32, v))
                    .collect()
            })
            .collect();
        Lil { rows: m.rows, cols: m.cols, rows_data, indptr: OnceLock::new() }
    }

    /// The cached nnz prefix-sum (built once per structure): `indptr[r]` is
    /// the total nnz of rows `0..r`. Lets [`indptr_span`] compute
    /// nnz-balanced spans with an `O(log n)` binary search and **zero
    /// allocation per multiply**.
    fn nnz_prefix(&self) -> &[usize] {
        self.indptr.get_or_init(|| {
            let mut p = Vec::with_capacity(self.rows + 1);
            let mut acc = 0usize;
            p.push(0);
            for list in &self.rows_data {
                acc += list.len();
                p.push(acc);
            }
            p
        })
    }

    pub fn to_coo(&self) -> Coo {
        let mut triples = Vec::new();
        for (r, list) in self.rows_data.iter().enumerate() {
            for &(c, v) in list {
                triples.push((r as u32, c, v));
            }
        }
        Coo::from_triples(self.rows, self.cols, triples)
    }

    pub fn nnz(&self) -> usize {
        self.rows_data.iter().map(|l| l.len()).sum()
    }

    /// Insert (or overwrite) a single entry, keeping the row sorted — the
    /// incremental-build operation LIL exists for. Invalidates the nnz
    /// prefix-sum cache (row lengths may change).
    pub fn insert(&mut self, r: usize, c: u32, v: f32) {
        self.indptr.take();
        let list = &mut self.rows_data[r];
        match list.binary_search_by_key(&c, |&(col, _)| col) {
            Ok(pos) => {
                if v == 0.0 {
                    list.remove(pos);
                } else {
                    list[pos].1 = v;
                }
            }
            Err(pos) => {
                if v != 0.0 {
                    list.insert(pos, (c, v));
                }
            }
        }
    }

    /// Footprint model: 8B per (col,val) node + 8B link overhead per node
    /// (linked-list pointer) + 24B list header per row.
    pub fn nbytes(&self) -> usize {
        self.nnz() * 16 + self.rows * 24
    }

    /// SpMM `self (n×m) · x (m×d) → out (n×d)`, parallel over nnz-balanced
    /// row spans (binary-searched on the cached nnz prefix-sum — no range
    /// list is allocated per multiply), into a caller-provided buffer. Runs
    /// under the process-wide default [`Schedule`].
    // lint: begin(hot-path)
    pub fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        self.spmm_into_sched(x, out, Schedule::effective());
    }

    /// Schedule-parameterized [`Lil::spmm_into`]: the tile width picks a
    /// monomorphized pair-gather instantiation
    /// ([`gather_row_pairs_lanes`], dispatched once per call), the split
    /// rule picks nnz-balanced vs even row spans, and the thread cap folds
    /// into the task count.
    pub fn spmm_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        match sched.tile {
            Tile::T4 => self.spmm_into_lanes::<4>(x, out, sched),
            Tile::T8 => self.spmm_into_lanes::<8>(x, out, sched),
            Tile::T16 => self.spmm_into_lanes::<16>(x, out, sched),
            Tile::T32 => self.spmm_into_lanes::<32>(x, out, sched),
        }
    }

    fn spmm_into_lanes<const L: usize>(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        check_into_shapes(self.rows, self.cols, x, out);
        let d = x.cols;
        let k = sched.tasks_for(self.rows);
        let prefix = self.nnz_prefix();
        parallel_fill_rows_spans(
            &mut out.data,
            self.rows,
            d,
            k,
            |i| match sched.split {
                Split::NnzBalanced => indptr_span(prefix, k, i),
                Split::EvenUnits => even_range(self.rows, k, i),
            },
            |range, chunk| {
                for (rr, r) in range.clone().enumerate() {
                    let out_row = &mut chunk[rr * d..(rr + 1) * d];
                    gather_row_pairs_lanes::<L>(out_row, x, &self.rows_data[r]);
                }
            },
        );
    }
    // lint: end(hot-path)

    /// Allocating SpMM wrapper.
    pub fn spmm(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, x.cols);
        self.spmm_into(x, &mut out);
        out
    }

    /// Transpose-SpMM `selfᵀ (m×n) · x (n×d) → out (m×d)` — transpose-free:
    /// workers own nnz-balanced row spans and scatter each row list's
    /// `v·x[r]` into output row `c` of pool-owned scratch buffers, reduced
    /// at the end. Runs under the process-wide default [`Schedule`].
    // lint: begin(hot-path)
    pub fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        self.spmm_t_into_sched(x, out, Schedule::effective());
    }

    /// Schedule-parameterized [`Lil::spmm_t_into`]. The scatter kernel has
    /// no gather tile, so only the split rule and thread cap apply.
    pub fn spmm_t_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        check_into_shapes(self.cols, self.rows, x, out);
        let d = x.cols;
        let k = sched.tasks_for(self.rows);
        let prefix = self.nnz_prefix();
        let span_of = |i| match sched.split {
            Split::NnzBalanced => indptr_span(prefix, k, i),
            Split::EvenUnits => even_range(self.rows, k, i),
        };
        scatter_reduce_into(out, k, span_of, |rows, buf| {
            for r in rows {
                let x_row = x.row(r);
                for &(c, v) in &self.rows_data[r] {
                    let out_row = &mut buf[c as usize * d..(c as usize + 1) * d];
                    for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                        *o += v * xv;
                    }
                }
            }
        });
    }
    // lint: end(hot-path)
}

impl SparseOps for Lil {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        Lil::nnz(self)
    }
    fn nbytes(&self) -> usize {
        Lil::nbytes(self)
    }
    fn to_coo(&self) -> Coo {
        Lil::to_coo(self)
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        Lil::spmm_into(self, x, out)
    }
    fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        Lil::spmm_t_into(self, x, out)
    }
    fn spmm_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        Lil::spmm_into_sched(self, x, out, sched)
    }
    fn spmm_t_into_sched(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        Lil::spmm_t_into_sched(self, x, out, sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Coo {
        let mut triples = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, rng.uniform(-1.0, 1.0) as f32));
                }
            }
        }
        Coo::from_triples(rows, cols, triples)
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let coo = random_coo(&mut rng, 18, 14, 0.2);
        let lil = Lil::from_coo(&coo);
        assert_eq!(lil.to_coo(), coo);
        assert_eq!(lil.nnz(), coo.nnz());
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Rng::new(2);
        let coo = random_coo(&mut rng, 29, 35, 0.1);
        let lil = Lil::from_coo(&coo);
        let x = Matrix::rand(35, 5, &mut rng);
        let want = coo.to_dense().matmul(&x);
        assert!(lil.spmm(&x).max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn nnz_prefix_cache_builds_once_and_invalidates_on_insert() {
        let mut rng = Rng::new(3);
        let coo = random_coo(&mut rng, 20, 15, 0.2);
        let mut lil = Lil::from_coo(&coo);
        let p1 = lil.nnz_prefix().to_vec();
        assert_eq!(p1.len(), lil.rows + 1);
        assert_eq!(*p1.last().unwrap(), lil.nnz());
        for r in 0..lil.rows {
            assert_eq!(p1[r + 1] - p1[r], lil.rows_data[r].len());
        }
        // Second call returns the same cached slice (no rebuild observable
        // via pointer identity).
        let ptr1 = lil.nnz_prefix().as_ptr();
        let ptr2 = lil.nnz_prefix().as_ptr();
        assert_eq!(ptr1, ptr2);
        // Structural mutation invalidates; the rebuilt prefix reflects it.
        lil.insert(0, 14, 9.0);
        let p2 = lil.nnz_prefix();
        assert_eq!(*p2.last().unwrap(), lil.nnz());
    }

    #[test]
    fn spmm_correct_after_insert_invalidation() {
        // The kernels read the cached prefix for span scheduling; a stale
        // cache after insert would mis-partition rows. Verify numerics
        // against dense before and after mutation.
        let mut rng = Rng::new(4);
        let coo = random_coo(&mut rng, 31, 23, 0.15);
        let mut lil = Lil::from_coo(&coo);
        let x = Matrix::rand(23, 17, &mut rng);
        let want = coo.to_dense().matmul(&x);
        assert!(lil.spmm(&x).max_abs_diff(&want) < 1e-4);
        lil.insert(5, 7, 2.5);
        lil.insert(5, 8, -1.5);
        lil.insert(30, 0, 4.0);
        let want2 = lil.to_coo().to_dense().matmul(&x);
        assert!(lil.spmm(&x).max_abs_diff(&want2) < 1e-4);
        // Transpose kernel shares the same cache.
        let xt = Matrix::rand(31, 5, &mut rng);
        let want_t = lil.to_coo().to_dense().transpose().matmul(&xt);
        let mut out_t = Matrix::full(23, 5, 77.0);
        lil.spmm_t_into(&xt, &mut out_t);
        assert!(out_t.max_abs_diff(&want_t) < 1e-4);
    }

    #[test]
    fn equality_ignores_prefix_cache_state() {
        let mut rng = Rng::new(5);
        let coo = random_coo(&mut rng, 12, 12, 0.2);
        let a = Lil::from_coo(&coo);
        let b = Lil::from_coo(&coo);
        let _ = a.nnz_prefix(); // build cache on one side only
        assert_eq!(a, b);
    }

    #[test]
    fn insert_keeps_sorted_and_handles_zero() {
        let mut lil = Lil::from_coo(&Coo::from_triples(3, 10, vec![(0, 5, 1.0)]));
        lil.insert(0, 2, 2.0);
        lil.insert(0, 8, 3.0);
        lil.insert(0, 5, 9.0); // overwrite
        assert_eq!(lil.rows_data[0], vec![(2, 2.0), (5, 9.0), (8, 3.0)]);
        lil.insert(0, 5, 0.0); // delete
        assert_eq!(lil.rows_data[0], vec![(2, 2.0), (8, 3.0)]);
    }
}
