//! List-of-lists (LIL): per-row lists of `(col, value)` pairs, the
//! row-mutable format. SpMM walks each row list; the per-node indirection
//! cost is modeled in the memory footprint.

use super::coo::Coo;
use super::ops::{check_into_shapes, scatter_reduce_into, SparseOps};
use crate::tensor::Matrix;
use crate::util::parallel::{num_threads, parallel_fill_rows_spans, split_ranges_by_weight};

/// LIL sparse matrix: `rows_data[r]` is row `r`'s sorted `(col, val)` list.
#[derive(Clone, Debug, PartialEq)]
pub struct Lil {
    pub rows: usize,
    pub cols: usize,
    pub rows_data: Vec<Vec<(u32, f32)>>,
}

impl Lil {
    pub fn from_coo(coo: &Coo) -> Lil {
        let mut rows_data = vec![Vec::new(); coo.rows];
        for i in 0..coo.nnz() {
            rows_data[coo.row[i] as usize].push((coo.col[i], coo.val[i]));
        }
        Lil { rows: coo.rows, cols: coo.cols, rows_data }
    }

    /// Direct dense→LIL sparsification (single pass).
    pub fn from_dense(m: &crate::tensor::Matrix) -> Lil {
        let rows_data = (0..m.rows)
            .map(|r| {
                m.row(r)
                    .iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(c, &v)| (c as u32, v))
                    .collect()
            })
            .collect();
        Lil { rows: m.rows, cols: m.cols, rows_data }
    }

    pub fn to_coo(&self) -> Coo {
        let mut triples = Vec::new();
        for (r, list) in self.rows_data.iter().enumerate() {
            for &(c, v) in list {
                triples.push((r as u32, c, v));
            }
        }
        Coo::from_triples(self.rows, self.cols, triples)
    }

    pub fn nnz(&self) -> usize {
        self.rows_data.iter().map(|l| l.len()).sum()
    }

    /// Insert (or overwrite) a single entry, keeping the row sorted — the
    /// incremental-build operation LIL exists for.
    pub fn insert(&mut self, r: usize, c: u32, v: f32) {
        let list = &mut self.rows_data[r];
        match list.binary_search_by_key(&c, |&(col, _)| col) {
            Ok(pos) => {
                if v == 0.0 {
                    list.remove(pos);
                } else {
                    list[pos].1 = v;
                }
            }
            Err(pos) => {
                if v != 0.0 {
                    list.insert(pos, (c, v));
                }
            }
        }
    }

    /// Footprint model: 8B per (col,val) node + 8B link overhead per node
    /// (linked-list pointer) + 24B list header per row.
    pub fn nbytes(&self) -> usize {
        self.nnz() * 16 + self.rows * 24
    }

    /// SpMM `self (n×m) · x (m×d) → out (n×d)`, parallel over nnz-balanced
    /// row spans (weighted by per-row list length — LIL has no `indptr` to
    /// binary-search, so the spans are materialized by a weight sweep), into
    /// a caller-provided buffer.
    pub fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        check_into_shapes(self.rows, self.cols, x, out);
        let d = x.cols;
        let k = num_threads().min(self.rows.max(1));
        let spans = split_ranges_by_weight(self.rows, k, |r| self.rows_data[r].len());
        parallel_fill_rows_spans(&mut out.data, self.rows, d, k, |i| spans[i].clone(), |range, chunk| {
            chunk.fill(0.0);
            for (rr, r) in range.clone().enumerate() {
                let out_row = &mut chunk[rr * d..(rr + 1) * d];
                for &(c, v) in &self.rows_data[r] {
                    let x_row = x.row(c as usize);
                    for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                        *o += v * xv;
                    }
                }
            }
        });
    }

    /// Allocating SpMM wrapper.
    pub fn spmm(&self, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, x.cols);
        self.spmm_into(x, &mut out);
        out
    }

    /// Transpose-SpMM `selfᵀ (m×n) · x (n×d) → out (m×d)` — transpose-free:
    /// workers own nnz-balanced row spans and scatter each row list's
    /// `v·x[r]` into output row `c` of pool-owned scratch buffers, reduced
    /// at the end.
    pub fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        check_into_shapes(self.cols, self.rows, x, out);
        let d = x.cols;
        let k = num_threads().min(self.rows.max(1));
        let spans = split_ranges_by_weight(self.rows, k, |r| self.rows_data[r].len());
        scatter_reduce_into(out, k, |i| spans[i].clone(), |rows, buf| {
            for r in rows {
                let x_row = x.row(r);
                for &(c, v) in &self.rows_data[r] {
                    let out_row = &mut buf[c as usize * d..(c as usize + 1) * d];
                    for (o, &xv) in out_row.iter_mut().zip(x_row.iter()) {
                        *o += v * xv;
                    }
                }
            }
        });
    }
}

impl SparseOps for Lil {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }
    fn nnz(&self) -> usize {
        Lil::nnz(self)
    }
    fn nbytes(&self) -> usize {
        Lil::nbytes(self)
    }
    fn to_coo(&self) -> Coo {
        Lil::to_coo(self)
    }
    fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        Lil::spmm_into(self, x, out)
    }
    fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        Lil::spmm_t_into(self, x, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Coo {
        let mut triples = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, rng.uniform(-1.0, 1.0) as f32));
                }
            }
        }
        Coo::from_triples(rows, cols, triples)
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let coo = random_coo(&mut rng, 18, 14, 0.2);
        let lil = Lil::from_coo(&coo);
        assert_eq!(lil.to_coo(), coo);
        assert_eq!(lil.nnz(), coo.nnz());
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Rng::new(2);
        let coo = random_coo(&mut rng, 29, 35, 0.1);
        let lil = Lil::from_coo(&coo);
        let x = Matrix::rand(35, 5, &mut rng);
        let want = coo.to_dense().matmul(&x);
        assert!(lil.spmm(&x).max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn insert_keeps_sorted_and_handles_zero() {
        let mut lil = Lil::from_coo(&Coo::from_triples(3, 10, vec![(0, 5, 1.0)]));
        lil.insert(0, 2, 2.0);
        lil.insert(0, 8, 3.0);
        lil.insert(0, 5, 9.0); // overwrite
        assert_eq!(lil.rows_data[0], vec![(2, 2.0), (5, 9.0), (8, 3.0)]);
        lil.insert(0, 5, 0.0); // delete
        assert_eq!(lil.rows_data[0], vec![(2, 2.0), (8, 3.0)]);
    }
}
