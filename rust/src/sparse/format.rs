//! The [`Format`] label set and the [`SparseMatrix`] dynamic wrapper that
//! the per-layer format switcher operates on.
//!
//! `SparseMatrix::convert` is the operation the paper's runtime performs
//! before a GNN layer when the predictor picks a different format than the
//! incumbent; its cost is charged to the end-to-end time in every
//! experiment, exactly as the paper does (§4, "Note that we include the
//! overhead of format conversion and feature extraction in all our
//! experimental results").

use super::{Bsr, Coo, Csc, Csr, Dia, Dok, Lil};
use crate::tensor::Matrix;

/// The seven storage formats of paper §2.2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Format {
    Coo,
    Csr,
    Csc,
    Dia,
    Bsr,
    Dok,
    Lil,
}

/// All candidate formats in a stable order (class-label order for the ML
/// models: the label of `ALL_FORMATS[i]` is `i`).
pub const ALL_FORMATS: [Format; 7] = [
    Format::Coo,
    Format::Csr,
    Format::Csc,
    Format::Dia,
    Format::Bsr,
    Format::Dok,
    Format::Lil,
];

impl Format {
    pub fn name(self) -> &'static str {
        match self {
            Format::Coo => "COO",
            Format::Csr => "CSR",
            Format::Csc => "CSC",
            Format::Dia => "DIA",
            Format::Bsr => "BSR",
            Format::Dok => "DOK",
            Format::Lil => "LIL",
        }
    }

    pub fn from_name(name: &str) -> Option<Format> {
        match name.to_ascii_uppercase().as_str() {
            "COO" => Some(Format::Coo),
            "CSR" => Some(Format::Csr),
            "CSC" => Some(Format::Csc),
            "DIA" => Some(Format::Dia),
            "BSR" => Some(Format::Bsr),
            "DOK" => Some(Format::Dok),
            "LIL" => Some(Format::Lil),
            _ => None,
        }
    }

    /// Class label used by the predictive models.
    pub fn label(self) -> usize {
        ALL_FORMATS.iter().position(|&f| f == self).unwrap()
    }

    pub fn from_label(label: usize) -> Format {
        ALL_FORMATS[label]
    }
}

impl std::fmt::Display for Format {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A sparse matrix in one of the seven formats.
#[derive(Clone, Debug, PartialEq)]
pub enum SparseMatrix {
    Coo(Coo),
    Csr(Csr),
    Csc(Csc),
    Dia(Dia),
    Bsr(Bsr),
    Dok(Dok),
    Lil(Lil),
}

impl SparseMatrix {
    /// Wrap a COO matrix (the PyG-default entry point).
    pub fn from_coo(coo: Coo) -> SparseMatrix {
        SparseMatrix::Coo(coo)
    }

    /// Build from dense in a given format.
    ///
    /// Row-major single-pass fast paths for COO/CSR/LIL (the formats the
    /// per-epoch activation refresh hits); the rest go through the COO hub.
    pub fn from_dense(m: &Matrix, fmt: Format) -> anyhow::Result<SparseMatrix> {
        match fmt {
            Format::Coo => Ok(SparseMatrix::Coo(Coo::from_dense(m))),
            Format::Csr => Ok(SparseMatrix::Csr(Csr::from_dense(m))),
            Format::Lil => Ok(SparseMatrix::Lil(Lil::from_dense(m))),
            _ => SparseMatrix::Coo(Coo::from_dense(m)).convert(fmt),
        }
    }

    pub fn format(&self) -> Format {
        match self {
            SparseMatrix::Coo(_) => Format::Coo,
            SparseMatrix::Csr(_) => Format::Csr,
            SparseMatrix::Csc(_) => Format::Csc,
            SparseMatrix::Dia(_) => Format::Dia,
            SparseMatrix::Bsr(_) => Format::Bsr,
            SparseMatrix::Dok(_) => Format::Dok,
            SparseMatrix::Lil(_) => Format::Lil,
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            SparseMatrix::Coo(m) => m.rows,
            SparseMatrix::Csr(m) => m.rows,
            SparseMatrix::Csc(m) => m.rows,
            SparseMatrix::Dia(m) => m.rows,
            SparseMatrix::Bsr(m) => m.rows,
            SparseMatrix::Dok(m) => m.rows,
            SparseMatrix::Lil(m) => m.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            SparseMatrix::Coo(m) => m.cols,
            SparseMatrix::Csr(m) => m.cols,
            SparseMatrix::Csc(m) => m.cols,
            SparseMatrix::Dia(m) => m.cols,
            SparseMatrix::Bsr(m) => m.cols,
            SparseMatrix::Dok(m) => m.cols,
            SparseMatrix::Lil(m) => m.cols,
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            SparseMatrix::Coo(m) => m.nnz(),
            SparseMatrix::Csr(m) => m.nnz(),
            SparseMatrix::Csc(m) => m.nnz(),
            SparseMatrix::Dia(m) => m.nnz(),
            SparseMatrix::Bsr(m) => m.nnz(),
            SparseMatrix::Dok(m) => m.nnz(),
            SparseMatrix::Lil(m) => m.nnz(),
        }
    }

    pub fn density(&self) -> f64 {
        let cells = self.rows() as f64 * self.cols() as f64;
        if cells == 0.0 {
            0.0
        } else {
            self.nnz() as f64 / cells
        }
    }

    /// Storage footprint under each format's memory model — the `M` term of
    /// the paper's Eq. 1.
    pub fn nbytes(&self) -> usize {
        match self {
            SparseMatrix::Coo(m) => m.nbytes(),
            SparseMatrix::Csr(m) => m.nbytes(),
            SparseMatrix::Csc(m) => m.nbytes(),
            SparseMatrix::Dia(m) => m.nbytes(),
            SparseMatrix::Bsr(m) => m.nbytes(),
            SparseMatrix::Dok(m) => m.nbytes(),
            SparseMatrix::Lil(m) => m.nbytes(),
        }
    }

    /// Convert to COO (identity-clone when already COO).
    pub fn to_coo(&self) -> Coo {
        match self {
            SparseMatrix::Coo(m) => m.clone(),
            SparseMatrix::Csr(m) => m.to_coo(),
            SparseMatrix::Csc(m) => m.to_coo(),
            SparseMatrix::Dia(m) => m.to_coo(),
            SparseMatrix::Bsr(m) => m.to_coo(),
            SparseMatrix::Dok(m) => m.to_coo(),
            SparseMatrix::Lil(m) => m.to_coo(),
        }
    }

    /// Convert to `fmt`. Errors if the target cannot represent the matrix
    /// within budget (DIA on scattered patterns).
    ///
    /// Fast paths: no-op when already in `fmt`; direct CSR→CSC counting sort.
    pub fn convert(&self, fmt: Format) -> anyhow::Result<SparseMatrix> {
        if self.format() == fmt {
            return Ok(self.clone());
        }
        if let (SparseMatrix::Csr(csr), Format::Csc) = (self, fmt) {
            return Ok(SparseMatrix::Csc(csr.to_csc()));
        }
        let coo = self.to_coo();
        Ok(match fmt {
            Format::Coo => SparseMatrix::Coo(coo),
            Format::Csr => SparseMatrix::Csr(Csr::from_coo(&coo)),
            Format::Csc => SparseMatrix::Csc(Csc::from_coo(&coo)),
            Format::Dia => SparseMatrix::Dia(Dia::from_coo(&coo)?),
            Format::Bsr => SparseMatrix::Bsr(Bsr::from_coo(&coo, super::bsr::DEFAULT_BLOCK)),
            Format::Dok => SparseMatrix::Dok(Dok::from_coo(&coo)),
            Format::Lil => SparseMatrix::Lil(Lil::from_coo(&coo)),
        })
    }

    /// The format-dispatched SpMM kernel — the operation whose cost the
    /// whole paper is about.
    pub fn spmm(&self, x: &Matrix) -> Matrix {
        match self {
            SparseMatrix::Coo(m) => m.spmm(x),
            SparseMatrix::Csr(m) => m.spmm(x),
            SparseMatrix::Csc(m) => m.spmm(x),
            SparseMatrix::Dia(m) => m.spmm(x),
            SparseMatrix::Bsr(m) => m.spmm(x),
            SparseMatrix::Dok(m) => m.spmm(x),
            SparseMatrix::Lil(m) => m.spmm(x),
        }
    }

    /// Transpose (via COO), preserving the current format.
    pub fn transpose(&self) -> anyhow::Result<SparseMatrix> {
        SparseMatrix::Coo(self.to_coo().transpose()).convert(self.format())
    }

    pub fn to_dense(&self) -> Matrix {
        self.to_coo().to_dense()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert, prop_close, PropResult};
    use crate::util::rng::Rng;

    pub fn random_coo(rng: &mut Rng, max_dim: usize) -> Coo {
        let rows = 1 + rng.gen_range(max_dim);
        let cols = 1 + rng.gen_range(max_dim);
        let density = rng.uniform(0.01, 0.4);
        let mut triples = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, rng.uniform(-2.0, 2.0) as f32));
                }
            }
        }
        Coo::from_triples(rows, cols, triples)
    }

    #[test]
    fn label_roundtrip() {
        for (i, &f) in ALL_FORMATS.iter().enumerate() {
            assert_eq!(f.label(), i);
            assert_eq!(Format::from_label(i), f);
            assert_eq!(Format::from_name(f.name()), Some(f));
        }
        assert_eq!(Format::from_name("csr"), Some(Format::Csr));
        assert_eq!(Format::from_name("nope"), None);
    }

    #[test]
    fn prop_conversion_roundtrip_preserves_matrix() {
        check(
            40,
            |rng| random_coo(rng, 40),
            |coo| {
                let base = SparseMatrix::Coo(coo.clone());
                for &fmt in &ALL_FORMATS {
                    let converted = match base.convert(fmt) {
                        Ok(c) => c,
                        Err(_) => continue, // DIA budget trip is legal
                    };
                    prop_assert(converted.format() == fmt, "target format")?;
                    prop_assert(converted.to_coo() == *coo, "round-trip equality")?;
                    prop_assert(converted.nnz() == coo.nnz(), "nnz preserved")?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_spmm_agrees_across_all_formats() {
        check(
            25,
            |rng| {
                let coo = random_coo(rng, 32);
                let d = 1 + rng.gen_range(12);
                let x = Matrix::rand(coo.cols, d, rng);
                (coo, x)
            },
            |(coo, x)| -> PropResult {
                let want = coo.to_dense().matmul(x);
                let base = SparseMatrix::Coo(coo.clone());
                for &fmt in &ALL_FORMATS {
                    let m = match base.convert(fmt) {
                        Ok(m) => m,
                        Err(_) => continue,
                    };
                    let got = m.spmm(x);
                    prop_close(&got.data, &want.data, 1e-4, fmt.name())?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_transpose_involution() {
        check(
            30,
            |rng| random_coo(rng, 30),
            |coo| {
                let m = SparseMatrix::Coo(coo.clone());
                let tt = m.transpose().unwrap().transpose().unwrap();
                prop_assert(tt.to_coo() == *coo, "transpose twice = identity")
            },
        );
    }

    #[test]
    fn nbytes_ordering_sane() {
        // On a moderately sparse matrix, DOK should be the heaviest and CSR
        // lighter than COO (paper's memory-footprint motivation).
        let mut rng = Rng::new(42);
        let coo = {
            let mut triples = Vec::new();
            for r in 0..200u32 {
                for c in 0..200u32 {
                    if rng.bernoulli(0.05) {
                        triples.push((r, c, 1.0f32));
                    }
                }
            }
            Coo::from_triples(200, 200, triples)
        };
        let base = SparseMatrix::Coo(coo);
        let coo_b = base.nbytes();
        let csr_b = base.convert(Format::Csr).unwrap().nbytes();
        let dok_b = base.convert(Format::Dok).unwrap().nbytes();
        assert!(csr_b < coo_b, "CSR ({csr_b}) should compress vs COO ({coo_b})");
        assert!(dok_b > coo_b, "DOK ({dok_b}) should exceed COO ({coo_b})");
    }

    #[test]
    fn convert_is_noop_for_same_format() {
        let mut rng = Rng::new(7);
        let coo = random_coo(&mut rng, 20);
        let m = SparseMatrix::Coo(coo);
        let same = m.convert(Format::Coo).unwrap();
        assert_eq!(m, same);
    }
}
