//! The [`Format`] label set and the [`SparseMatrix`] dynamic wrapper that
//! the per-layer format switcher operates on.
//!
//! `SparseMatrix::convert` is the operation the paper's runtime performs
//! before a GNN layer when the predictor picks a different format than the
//! incumbent; its cost is charged to the end-to-end time in every
//! experiment, exactly as the paper does (§4, "Note that we include the
//! overhead of format conversion and feature extraction in all our
//! experimental results").
//!
//! All per-format method dispatch is **macro-generated** through the
//! [`SparseOps`] trait object ([`SparseMatrix::ops`]): adding a format means
//! adding one line to the `sparse_formats!` invocation, not editing eight
//! hand-written seven-arm `match` blocks.

use super::schedule::Schedule;
use super::{Bsr, Coo, Csc, Csr, Dia, Dok, Lil, SparseOps};
use crate::tensor::Matrix;

/// Generates the [`Format`] enum, the [`SparseMatrix`] wrapper and the
/// variant↔label↔name plumbing from a single format list.
macro_rules! sparse_formats {
    ($($variant:ident($ty:ty) = $name:literal),+ $(,)?) => {
        /// The seven storage formats of paper §2.2.
        #[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
        pub enum Format {
            $($variant,)+
        }

        /// Number of candidate formats (derived from the macro list).
        pub const N_FORMATS: usize = [$(Format::$variant,)+].len();

        /// All candidate formats in a stable order (class-label order for
        /// the ML models: the label of `ALL_FORMATS[i]` is `i`).
        pub const ALL_FORMATS: [Format; N_FORMATS] = [$(Format::$variant,)+];

        impl Format {
            pub fn name(self) -> &'static str {
                match self {
                    $(Format::$variant => $name,)+
                }
            }

            pub fn from_name(name: &str) -> Option<Format> {
                match name.to_ascii_uppercase().as_str() {
                    $($name => Some(Format::$variant),)+
                    _ => None,
                }
            }
        }

        /// A sparse matrix in one of the seven formats.
        #[derive(Clone, Debug, PartialEq)]
        pub enum SparseMatrix {
            $($variant($ty),)+
        }

        impl SparseMatrix {
            /// The storage format of the current variant.
            pub fn format(&self) -> Format {
                match self {
                    $(SparseMatrix::$variant(_) => Format::$variant,)+
                }
            }

            /// Uniform kernel surface: every per-format operation reaches
            /// its implementation through this trait object.
            pub fn ops(&self) -> &dyn SparseOps {
                match self {
                    $(SparseMatrix::$variant(m) => m,)+
                }
            }
        }
    };
}

sparse_formats! {
    Coo(Coo) = "COO",
    Csr(Csr) = "CSR",
    Csc(Csc) = "CSC",
    Dia(Dia) = "DIA",
    Bsr(Bsr) = "BSR",
    Dok(Dok) = "DOK",
    Lil(Lil) = "LIL",
}

impl Format {
    /// Class label used by the predictive models.
    pub fn label(self) -> usize {
        ALL_FORMATS.iter().position(|&f| f == self).unwrap()
    }

    pub fn from_label(label: usize) -> Format {
        ALL_FORMATS[label]
    }
}

impl std::fmt::Display for Format {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl SparseMatrix {
    /// Wrap a COO matrix (the PyG-default entry point).
    pub fn from_coo(coo: Coo) -> SparseMatrix {
        SparseMatrix::Coo(coo)
    }

    /// Build from dense in a given format.
    ///
    /// Row-major single-pass fast paths for COO/CSR/LIL (the formats the
    /// per-epoch activation refresh hits); the rest go through the COO hub.
    pub fn from_dense(m: &Matrix, fmt: Format) -> anyhow::Result<SparseMatrix> {
        match fmt {
            Format::Coo => Ok(SparseMatrix::Coo(Coo::from_dense(m))),
            Format::Csr => Ok(SparseMatrix::Csr(Csr::from_dense(m))),
            Format::Lil => Ok(SparseMatrix::Lil(Lil::from_dense(m))),
            _ => SparseMatrix::Coo(Coo::from_dense(m)).convert(fmt),
        }
    }

    pub fn rows(&self) -> usize {
        self.ops().shape().0
    }

    pub fn cols(&self) -> usize {
        self.ops().shape().1
    }

    pub fn nnz(&self) -> usize {
        self.ops().nnz()
    }

    pub fn density(&self) -> f64 {
        let cells = self.rows() as f64 * self.cols() as f64;
        if cells == 0.0 {
            0.0
        } else {
            self.nnz() as f64 / cells
        }
    }

    /// Storage footprint under each format's memory model — the `M` term of
    /// the paper's Eq. 1.
    pub fn nbytes(&self) -> usize {
        self.ops().nbytes()
    }

    /// Convert to COO (identity-clone when already COO).
    pub fn to_coo(&self) -> Coo {
        self.ops().to_coo()
    }

    /// Convert to `fmt`. Errors if the target cannot represent the matrix
    /// within budget (DIA on scattered patterns).
    ///
    /// Fast paths: no-op when already in `fmt`; direct CSR↔CSC counting
    /// sorts in both directions.
    pub fn convert(&self, fmt: Format) -> anyhow::Result<SparseMatrix> {
        if self.format() == fmt {
            return Ok(self.clone());
        }
        if let (SparseMatrix::Csr(csr), Format::Csc) = (self, fmt) {
            return Ok(SparseMatrix::Csc(csr.to_csc()));
        }
        if let (SparseMatrix::Csc(csc), Format::Csr) = (self, fmt) {
            return Ok(SparseMatrix::Csr(csc.to_csr()));
        }
        let coo = self.to_coo();
        Ok(match fmt {
            Format::Coo => SparseMatrix::Coo(coo),
            Format::Csr => SparseMatrix::Csr(Csr::from_coo(&coo)),
            Format::Csc => SparseMatrix::Csc(Csc::from_coo(&coo)),
            Format::Dia => SparseMatrix::Dia(Dia::from_coo(&coo)?),
            Format::Bsr => SparseMatrix::Bsr(Bsr::from_coo(&coo, super::bsr::DEFAULT_BLOCK)),
            Format::Dok => SparseMatrix::Dok(Dok::from_coo(&coo)),
            Format::Lil => SparseMatrix::Lil(Lil::from_coo(&coo)),
        })
    }

    /// The format-dispatched SpMM kernel — the operation whose cost the
    /// whole paper is about.
    pub fn spmm(&self, x: &Matrix) -> Matrix {
        self.ops().spmm(x)
    }

    /// SpMM into a caller-provided output buffer (`rows × x.cols`,
    /// overwritten completely) — the zero-allocation hot path.
    pub fn spmm_into(&self, x: &Matrix, out: &mut Matrix) {
        self.ops().spmm_into(x, out)
    }

    /// Transpose-SpMM `selfᵀ · x` — executed transpose-free on the current
    /// format's own arrays (CSR↔CSC duality and friends; see `sparse::ops`).
    pub fn spmm_t(&self, x: &Matrix) -> Matrix {
        self.ops().spmm_t(x)
    }

    /// Transpose-SpMM into a caller-provided buffer (`cols × x.cols`).
    pub fn spmm_t_into(&self, x: &Matrix, out: &mut Matrix) {
        self.ops().spmm_t_into(x, out)
    }

    /// SpMM into a caller-provided buffer under an explicit kernel
    /// [`Schedule`] — the engine's decided (format, schedule) plan enters
    /// here. Formats without a schedule-sensitive kernel ignore it.
    pub fn spmm_into_with(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        self.ops().spmm_into_sched(x, out, sched)
    }

    /// Transpose-SpMM into a caller-provided buffer under an explicit
    /// kernel [`Schedule`].
    pub fn spmm_t_into_with(&self, x: &Matrix, out: &mut Matrix, sched: Schedule) {
        self.ops().spmm_t_into_sched(x, out, sched)
    }

    /// Induced submatrix `self[rows, cols]` for **sorted, duplicate-free**
    /// id selections — the mini-batch shard-extraction entry point.
    ///
    /// CSR/CSC/COO extract directly on their own arrays and preserve their
    /// format; the remaining formats fall back through a COO view and
    /// return a COO result (the caller's next format decision re-homes it).
    /// See [`super::ops::coo_fallback_extractions`] for the fallback
    /// accounting the minibatch bench asserts on.
    pub fn extract_rows_cols(&self, rows: &[u32], cols: &[u32]) -> SparseMatrix {
        self.ops().extract_rows_cols(rows, cols)
    }

    /// Per-row sums of stored values (ρ in GNN-FiLM).
    pub fn row_sums(&self) -> Vec<f32> {
        self.ops().row_sums()
    }

    /// Transpose, preserving the current format.
    ///
    /// Direct structural paths for COO/CSR/CSC/DIA (no interchange hop);
    /// the remaining formats fall back to the COO hub + `convert`.
    pub fn transpose(&self) -> anyhow::Result<SparseMatrix> {
        Ok(match self {
            SparseMatrix::Coo(m) => SparseMatrix::Coo(m.transpose()),
            SparseMatrix::Csr(m) => SparseMatrix::Csr(m.transpose()),
            SparseMatrix::Csc(m) => SparseMatrix::Csc(m.transpose()),
            SparseMatrix::Dia(m) => SparseMatrix::Dia(m.transpose()?),
            other => {
                SparseMatrix::Coo(other.to_coo().transpose()).convert(other.format())?
            }
        })
    }

    pub fn to_dense(&self) -> Matrix {
        self.to_coo().to_dense()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert, prop_close, PropResult};
    use crate::util::rng::Rng;

    pub fn random_coo(rng: &mut Rng, max_dim: usize) -> Coo {
        let rows = 1 + rng.gen_range(max_dim);
        let cols = 1 + rng.gen_range(max_dim);
        let density = rng.uniform(0.01, 0.4);
        let mut triples = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, rng.uniform(-2.0, 2.0) as f32));
                }
            }
        }
        Coo::from_triples(rows, cols, triples)
    }

    #[test]
    fn label_roundtrip() {
        for (i, &f) in ALL_FORMATS.iter().enumerate() {
            assert_eq!(f.label(), i);
            assert_eq!(Format::from_label(i), f);
            assert_eq!(Format::from_name(f.name()), Some(f));
        }
        assert_eq!(Format::from_name("csr"), Some(Format::Csr));
        assert_eq!(Format::from_name("nope"), None);
    }

    #[test]
    fn prop_conversion_roundtrip_preserves_matrix() {
        check(
            40,
            |rng| random_coo(rng, 40),
            |coo| {
                let base = SparseMatrix::Coo(coo.clone());
                for &fmt in &ALL_FORMATS {
                    let converted = match base.convert(fmt) {
                        Ok(c) => c,
                        Err(_) => continue, // DIA budget trip is legal
                    };
                    prop_assert(converted.format() == fmt, "target format")?;
                    prop_assert(converted.to_coo() == *coo, "round-trip equality")?;
                    prop_assert(converted.nnz() == coo.nnz(), "nnz preserved")?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_spmm_agrees_across_all_formats() {
        check(
            25,
            |rng| {
                let coo = random_coo(rng, 32);
                let d = 1 + rng.gen_range(12);
                let x = Matrix::rand(coo.cols, d, rng);
                (coo, x)
            },
            |(coo, x)| -> PropResult {
                let want = coo.to_dense().matmul(x);
                let base = SparseMatrix::Coo(coo.clone());
                for &fmt in &ALL_FORMATS {
                    let m = match base.convert(fmt) {
                        Ok(m) => m,
                        Err(_) => continue,
                    };
                    let got = m.spmm(x);
                    prop_close(&got.data, &want.data, 1e-4, fmt.name())?;
                }
                Ok(())
            },
        );
    }

    /// `spmm` / `spmm_into` / `spmm_t` / `spmm_t_into` all agree with the
    /// dense reference for every format, and the `_into` kernels fully
    /// overwrite stale output buffers (the workspace-reuse contract).
    #[test]
    fn prop_spmm_into_and_spmm_t_into_agree_with_dense() {
        check(
            25,
            |rng| {
                let coo = random_coo(rng, 28);
                let d = 1 + rng.gen_range(10);
                let x = Matrix::rand(coo.cols, d, rng);
                let xt = Matrix::rand(coo.rows, d, rng);
                (coo, x, xt)
            },
            |(coo, x, xt)| -> PropResult {
                let dense = coo.to_dense();
                let want = dense.matmul(x);
                let want_t = dense.transpose().matmul(xt);
                let base = SparseMatrix::Coo(coo.clone());
                for &fmt in &ALL_FORMATS {
                    let m = match base.convert(fmt) {
                        Ok(m) => m,
                        Err(_) => continue,
                    };
                    // Stale garbage in the buffers: kernels must overwrite.
                    let mut out = Matrix::full(coo.rows, x.cols, 123.0);
                    m.spmm_into(x, &mut out);
                    prop_close(&out.data, &want.data, 1e-4, fmt.name())?;
                    prop_close(&m.spmm(x).data, &want.data, 1e-4, fmt.name())?;
                    let mut out_t = Matrix::full(coo.cols, xt.cols, -321.0);
                    m.spmm_t_into(xt, &mut out_t);
                    prop_close(&out_t.data, &want_t.data, 1e-4, fmt.name())?;
                    prop_close(&m.spmm_t(xt).data, &want_t.data, 1e-4, fmt.name())?;
                }
                Ok(())
            },
        );
    }

    /// Degenerate shapes (0-row, 0-col, 0×0, empty-nnz) flow through every
    /// conversion, both SpMM kernel directions and transpose without panics.
    #[test]
    fn degenerate_shapes_through_every_kernel_and_conversion() {
        for &(rows, cols) in &[(0usize, 5usize), (5, 0), (0, 0), (4, 7)] {
            let coo = Coo::from_triples(rows, cols, vec![]);
            let base = SparseMatrix::Coo(coo);
            let d = 3;
            for &fmt in &ALL_FORMATS {
                let m = base.convert(fmt).unwrap_or_else(|e| {
                    panic!("{fmt} conversion failed on {rows}x{cols}: {e}")
                });
                assert_eq!(m.nnz(), 0, "{fmt}");
                assert_eq!((m.rows(), m.cols()), (rows, cols), "{fmt}");
                assert_eq!(m.to_coo().nnz(), 0, "{fmt}");

                let x = Matrix::full(cols, d, 1.0);
                let mut out = Matrix::full(rows, d, 9.0);
                m.spmm_into(&x, &mut out);
                assert_eq!(out.data, vec![0.0; rows * d], "{fmt} spmm_into");
                assert_eq!(m.spmm(&x).data, vec![0.0; rows * d], "{fmt} spmm");

                let xt = Matrix::full(rows, d, 1.0);
                let mut out_t = Matrix::full(cols, d, 9.0);
                m.spmm_t_into(&xt, &mut out_t);
                assert_eq!(out_t.data, vec![0.0; cols * d], "{fmt} spmm_t_into");

                let t = m.transpose().unwrap();
                assert_eq!((t.rows(), t.cols()), (cols, rows), "{fmt} transpose");
                assert_eq!(t.format(), fmt, "{fmt} transpose preserves format");
            }
        }
    }

    /// Every (format × tile × split × cap) kernel variant agrees with the
    /// dense reference on degenerate and tile-hostile shapes: 0-row, 0-col,
    /// empty, `d` below the narrowest tile, and `d` not a multiple of any
    /// tile. Both kernel directions, with stale output buffers the variants
    /// must fully overwrite.
    #[test]
    fn schedule_variants_agree_with_dense_on_degenerate_shapes() {
        use super::super::schedule::{Schedule, Split, ThreadCap, Tile};
        let mut rng = Rng::new(0x5C4ED);
        let shapes = [(0usize, 5usize), (5, 0), (0, 0), (1, 1), (7, 5), (33, 47)];
        // d < 4 (every tile streams), between tile widths, and off-multiple
        // remainders of 4/8/16/32.
        let widths = [1usize, 3, 5, 15, 17, 33];
        for &(rows, cols) in &shapes {
            let mut triples = Vec::new();
            for r in 0..rows {
                for c in 0..cols {
                    if rng.bernoulli(0.3) {
                        triples.push((r as u32, c as u32, rng.uniform(-2.0, 2.0) as f32));
                    }
                }
            }
            let coo = Coo::from_triples(rows, cols, triples);
            let dense = coo.to_dense();
            let base = SparseMatrix::Coo(coo);
            for &d in &widths {
                let x = Matrix::rand(cols, d, &mut rng);
                let xt = Matrix::rand(rows, d, &mut rng);
                let want = dense.matmul(&x);
                let want_t = dense.transpose().matmul(&xt);
                for &fmt in &ALL_FORMATS {
                    let m = match base.convert(fmt) {
                        Ok(m) => m,
                        Err(_) => continue, // DIA budget trip is legal
                    };
                    for tile in Tile::ALL {
                        for split in Split::ALL {
                            for threads in [ThreadCap::Auto, ThreadCap::Cap(1)] {
                                let sched = Schedule { tile, split, threads };
                                let label = format!(
                                    "{} {} ({rows},{cols},{d})",
                                    fmt.name(),
                                    sched.label()
                                );
                                let mut out = Matrix::full(rows, d, 123.0);
                                m.spmm_into_with(&x, &mut out, sched);
                                assert!(
                                    out.max_abs_diff(&want) < 1e-3,
                                    "spmm {label}"
                                );
                                let mut out_t = Matrix::full(cols, d, -321.0);
                                m.spmm_t_into_with(&xt, &mut out_t, sched);
                                assert!(
                                    out_t.max_abs_diff(&want_t) < 1e-3,
                                    "spmm_t {label}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn prop_transpose_involution() {
        check(
            30,
            |rng| random_coo(rng, 30),
            |coo| {
                let m = SparseMatrix::Coo(coo.clone());
                let tt = m.transpose().unwrap().transpose().unwrap();
                prop_assert(tt.to_coo() == *coo, "transpose twice = identity")
            },
        );
    }

    /// The direct structural transpose paths (CSR/CSC/DIA) match the COO
    /// hub, preserve the format, and `Aᵀ·x == spmm_t(A, x)`.
    #[test]
    fn prop_direct_transpose_paths_match_hub() {
        check(
            25,
            |rng| {
                let coo = random_coo(rng, 30);
                let x = Matrix::rand(coo.rows, 4, rng);
                (coo, x)
            },
            |(coo, x)| -> PropResult {
                let base = SparseMatrix::Coo(coo.clone());
                let want_t = coo.transpose();
                for &fmt in &ALL_FORMATS {
                    let m = match base.convert(fmt) {
                        Ok(m) => m,
                        Err(_) => continue,
                    };
                    let t = m.transpose().map_err(|e| e.to_string())?;
                    prop_assert(t.format() == fmt, "transpose keeps format")?;
                    prop_assert(t.to_coo() == want_t, "transpose content")?;
                    prop_close(
                        &t.spmm(x).data,
                        &m.spmm_t(x).data,
                        1e-4,
                        "Aᵀ·x == spmm_t(A, x)",
                    )?;
                }
                Ok(())
            },
        );
    }

    /// Random sorted duplicate-free selection of `[0, n)`.
    fn random_selection(rng: &mut Rng, n: usize) -> Vec<u32> {
        let k = rng.gen_range(n + 1);
        let mut sel: Vec<u32> = rng.sample_indices(n, k).into_iter().map(|i| i as u32).collect();
        sel.sort_unstable();
        sel
    }

    /// Induced-submatrix extraction matches the dense reference for every
    /// format, preserves the format for the direct paths (CSR/CSC/COO), and
    /// handles the empty and full-graph selections.
    #[test]
    fn prop_extract_rows_cols_matches_dense_reference() {
        check(
            30,
            |rng| {
                let coo = random_coo(rng, 30);
                let rows = random_selection(rng, coo.rows);
                let cols = random_selection(rng, coo.cols);
                (coo, rows, cols)
            },
            |(coo, rows, cols)| -> PropResult {
                let dense = coo.to_dense();
                let mut want = crate::tensor::Matrix::zeros(rows.len(), cols.len());
                for (nr, &r) in rows.iter().enumerate() {
                    for (nc, &c) in cols.iter().enumerate() {
                        *want.at_mut(nr, nc) = dense.at(r as usize, c as usize);
                    }
                }
                let base = SparseMatrix::Coo(coo.clone());
                for &fmt in &ALL_FORMATS {
                    let m = match base.convert(fmt) {
                        Ok(m) => m,
                        Err(_) => continue,
                    };
                    let sub = m.extract_rows_cols(rows, cols);
                    prop_assert(
                        sub.rows() == rows.len() && sub.cols() == cols.len(),
                        "extracted shape",
                    )?;
                    prop_close(&sub.to_dense().data, &want.data, 0.0, fmt.name())?;
                    // Direct paths keep their format; fallbacks land in COO.
                    match fmt {
                        Format::Coo | Format::Csr | Format::Csc => {
                            prop_assert(sub.format() == fmt, "direct path keeps format")?
                        }
                        _ => prop_assert(sub.format() == Format::Coo, "fallback is COO")?,
                    }
                    // Output selections are positional: re-extracting
                    // everything from the submatrix is the identity.
                    let all_r: Vec<u32> = (0..sub.rows() as u32).collect();
                    let all_c: Vec<u32> = (0..sub.cols() as u32).collect();
                    let again = sub.extract_rows_cols(&all_r, &all_c);
                    prop_assert(again.to_coo() == sub.to_coo(), "full selection is identity")?;
                }
                // Empty batch: 0×0 extraction flows through without panics.
                let empty = base.extract_rows_cols(&[], &[]);
                prop_assert(empty.nnz() == 0, "empty selection has no entries")?;
                prop_assert((empty.rows(), empty.cols()) == (0, 0), "empty selection shape")
            },
        );
    }

    #[test]
    fn extract_output_is_sorted_and_duplicate_free() {
        // The direct CSR/CSC/COO kernels must emit canonically ordered
        // output without a re-sort (the `Coo` struct invariant).
        let mut rng = Rng::new(12);
        let coo = random_coo(&mut rng, 40);
        let rows = random_selection(&mut rng, coo.rows);
        let cols = random_selection(&mut rng, coo.cols);
        for fmt in [Format::Coo, Format::Csr, Format::Csc] {
            let m = SparseMatrix::Coo(coo.clone()).convert(fmt).unwrap();
            let sub = m.extract_rows_cols(&rows, &cols);
            assert!(sub.to_coo().is_sorted_row_major(), "{fmt}");
        }
    }

    /// The fallback counter moves only for default-path formats — never
    /// for CSR/CSC/COO. (Inline extractions land in this thread's local
    /// counter, so concurrently running tests can't perturb the deltas;
    /// pool-worker visibility is covered by `tests/fallback_counter.rs`.)
    #[test]
    fn coo_fallback_counter_tracks_only_default_paths() {
        use super::super::ops::coo_fallback_extractions;
        let mut rng = Rng::new(13);
        let coo = random_coo(&mut rng, 40);
        let rows = random_selection(&mut rng, coo.rows);
        let cols = random_selection(&mut rng, coo.cols);
        let before = coo_fallback_extractions();
        for fmt in [Format::Coo, Format::Csr, Format::Csc] {
            let m = SparseMatrix::Coo(coo.clone()).convert(fmt).unwrap();
            let _ = m.extract_rows_cols(&rows, &cols);
        }
        assert_eq!(coo_fallback_extractions(), before, "direct paths must not count");
        let dok = SparseMatrix::Coo(coo).convert(Format::Dok).unwrap();
        let _ = dok.extract_rows_cols(&rows, &cols);
        assert_eq!(coo_fallback_extractions(), before + 1);
    }

    #[test]
    fn prop_row_sums_match_dense() {
        check(
            20,
            |rng| random_coo(rng, 30),
            |coo| -> PropResult {
                let dense = coo.to_dense();
                let want: Vec<f32> =
                    (0..coo.rows).map(|r| dense.row(r).iter().sum()).collect();
                let base = SparseMatrix::Coo(coo.clone());
                for &fmt in &ALL_FORMATS {
                    let m = match base.convert(fmt) {
                        Ok(m) => m,
                        Err(_) => continue,
                    };
                    prop_close(&m.row_sums(), &want, 1e-4, fmt.name())?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn nbytes_ordering_sane() {
        // On a moderately sparse matrix, DOK should be the heaviest and CSR
        // lighter than COO (paper's memory-footprint motivation).
        let mut rng = Rng::new(42);
        let coo = {
            let mut triples = Vec::new();
            for r in 0..200u32 {
                for c in 0..200u32 {
                    if rng.bernoulli(0.05) {
                        triples.push((r, c, 1.0f32));
                    }
                }
            }
            Coo::from_triples(200, 200, triples)
        };
        let base = SparseMatrix::Coo(coo);
        let coo_b = base.nbytes();
        let csr_b = base.convert(Format::Csr).unwrap().nbytes();
        let dok_b = base.convert(Format::Dok).unwrap().nbytes();
        assert!(csr_b < coo_b, "CSR ({csr_b}) should compress vs COO ({coo_b})");
        assert!(dok_b > coo_b, "DOK ({dok_b}) should exceed COO ({coo_b})");
    }

    #[test]
    fn convert_is_noop_for_same_format() {
        let mut rng = Rng::new(7);
        let coo = random_coo(&mut rng, 20);
        let m = SparseMatrix::Coo(coo);
        let same = m.convert(Format::Coo).unwrap();
        assert_eq!(m, same);
    }

    #[test]
    fn direct_csc_csr_conversions_match_hub() {
        let mut rng = Rng::new(8);
        let coo = random_coo(&mut rng, 35);
        let csr = SparseMatrix::Coo(coo.clone()).convert(Format::Csr).unwrap();
        let csc = csr.convert(Format::Csc).unwrap(); // direct path
        assert_eq!(csc.to_coo(), coo);
        let back = csc.convert(Format::Csr).unwrap(); // direct path
        assert_eq!(back, csr);
    }
}
