//! PJRT runtime — loads the JAX/Pallas-AOT-compiled HLO-text artifacts
//! produced by `python/compile/aot.py` and executes them from the rust hot
//! path (python is never on the request path; see DESIGN.md).
//!
//! Interchange is **HLO text** (not serialized `HloModuleProto`): jax ≥ 0.5
//! emits 64-bit instruction ids that the image's xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

use crate::tensor::Matrix;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded artifact: compiled executable + declared shapes.
pub struct Artifact {
    pub name: String,
    pub exe: xla::PjRtLoadedExecutable,
    /// Input shapes (rows, cols) as declared in the manifest.
    pub in_shapes: Vec<(usize, usize)>,
    /// Output shapes (rows, cols).
    pub out_shapes: Vec<(usize, usize)>,
}

/// PJRT CPU engine holding all compiled artifacts.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    artifacts: HashMap<String, Artifact>,
}

impl PjrtEngine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> anyhow::Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtEngine { client, artifacts: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load every artifact listed in `<dir>/manifest.json`, compiling each
    /// HLO text module once (startup cost; the request path only executes).
    pub fn load_manifest(&mut self, dir: &Path) -> anyhow::Result<Vec<String>> {
        let manifest_path = dir.join("manifest.json");
        let manifest = Json::parse(&std::fs::read_to_string(&manifest_path)?)?;
        let mut loaded = Vec::new();
        for entry in manifest.req_arr("artifacts")? {
            let name = entry.req_str("name")?.to_string();
            let file: PathBuf = dir.join(entry.req_str("file")?);
            let parse_shapes = |key: &str| -> anyhow::Result<Vec<(usize, usize)>> {
                entry
                    .req_arr(key)?
                    .iter()
                    .map(|s| {
                        let dims = s.as_arr().ok_or_else(|| anyhow::anyhow!("bad shape"))?;
                        anyhow::ensure!(dims.len() == 2, "expect 2-D shapes");
                        Ok((
                            dims[0].as_usize().unwrap_or(0),
                            dims[1].as_usize().unwrap_or(0),
                        ))
                    })
                    .collect()
            };
            let in_shapes = parse_shapes("inputs")?;
            let out_shapes = parse_shapes("outputs")?;
            self.load_hlo(&name, &file, in_shapes, out_shapes)?;
            loaded.push(name);
        }
        Ok(loaded)
    }

    /// Compile one HLO-text module.
    pub fn load_hlo(
        &mut self,
        name: &str,
        path: &Path,
        in_shapes: Vec<(usize, usize)>,
        out_shapes: Vec<(usize, usize)>,
    ) -> anyhow::Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.artifacts.insert(
            name.to_string(),
            Artifact { name: name.to_string(), exe, in_shapes, out_shapes },
        );
        Ok(())
    }

    pub fn has(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    /// Execute an artifact on f32 matrices. Inputs must match the declared
    /// shapes; outputs are reshaped per the manifest.
    pub fn run(&self, name: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
        let art = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{name}'"))?;
        anyhow::ensure!(
            inputs.len() == art.in_shapes.len(),
            "artifact '{name}' expects {} inputs, got {}",
            art.in_shapes.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (m, &(r, c)) in inputs.iter().zip(art.in_shapes.iter()) {
            anyhow::ensure!(
                m.rows == r && m.cols == c,
                "artifact '{name}': input shape ({}, {}) != declared ({r}, {c})",
                m.rows,
                m.cols
            );
            let lit = xla::Literal::vec1(&m.data).reshape(&[r as i64, c as i64])?;
            literals.push(lit);
        }
        let mut result = art.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let tuple = result.decompose_tuple()?;
        anyhow::ensure!(
            tuple.len() == art.out_shapes.len(),
            "artifact '{name}': {} outputs declared, {} returned",
            art.out_shapes.len(),
            tuple.len()
        );
        tuple
            .into_iter()
            .zip(art.out_shapes.iter())
            .map(|(lit, &(r, c))| {
                let data = lit.to_vec::<f32>()?;
                anyhow::ensure!(data.len() == r * c, "output size mismatch");
                Ok(Matrix::from_vec(r, c, data))
            })
            .collect()
    }
}

/// Default artifacts directory (relative to the repo root).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("GNN_SPMM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
