//! Degree-aware node partitioning for sharded mini-batch training.
//!
//! Large graphs (ROADMAP north star: beyond full-batch scale) are trained
//! over node shards. A naive contiguous split of a power-law graph hands
//! one shard most of the edges — the same skew problem the SpMM scheduler
//! solves with nnz-balanced spans (`util::parallel::indptr_span`), one
//! level up. The partitioner here applies the LPT greedy rule to node
//! degrees: heaviest node first, each to the currently lightest shard, so
//! shard *edge* loads (and therefore per-shard SpMM cost) stay within one
//! hub degree of each other.
//!
//! Invariants (property-tested): shards are disjoint, cover every node
//! exactly once, each shard's node list is sorted ascending (the
//! precondition of `SparseOps::extract_rows_cols`), and the partitioning is
//! deterministic for a given graph.

use crate::sparse::Coo;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A disjoint cover of `[0, n)` by node shards.
#[derive(Clone, Debug)]
pub struct Partitioning {
    /// Shard node id lists, each sorted ascending and duplicate-free.
    pub shards: Vec<Vec<u32>>,
    /// Total node count (shard lists partition `[0, n)`).
    pub n: usize,
}

impl Partitioning {
    /// Degree-aware partition of `adj`'s nodes into `n_shards` shards
    /// (LPT greedy on row degree; see module docs).
    pub fn by_degree(adj: &Coo, n_shards: usize) -> Partitioning {
        let degrees: Vec<usize> =
            adj.row_counts().into_iter().map(|c| c as usize).collect();
        Partitioning::from_weights(&degrees, n_shards)
    }

    /// LPT greedy partition of `[0, weights.len())` balancing total node
    /// weight per shard. Deterministic: nodes are processed heaviest-first
    /// with id ascending as tie-break, shards tie-break by index.
    pub fn from_weights(weights: &[usize], n_shards: usize) -> Partitioning {
        let n = weights.len();
        let n_shards = n_shards.max(1);
        let mut order: Vec<u32> = (0..n as u32).collect();
        // Heaviest first; stable ascending-id tie-break for determinism.
        order.sort_by_key(|&i| (Reverse(weights[i as usize]), i));
        let mut shards: Vec<Vec<u32>> = vec![Vec::new(); n_shards];
        // Min-heap of (load, shard index): pop lightest, assign, push back.
        let mut heap: BinaryHeap<Reverse<(usize, usize)>> =
            (0..n_shards).map(|s| Reverse((0usize, s))).collect();
        for &node in &order {
            let Reverse((load, s)) = heap.pop().expect("n_shards >= 1");
            shards[s].push(node);
            heap.push(Reverse((load + weights[node as usize], s)));
        }
        for shard in &mut shards {
            shard.sort_unstable();
        }
        Partitioning { shards, n }
    }

    /// Per-shard total weight under `weights` (diagnostics / tests).
    pub fn loads(&self, weights: &[usize]) -> Vec<usize> {
        self.shards
            .iter()
            .map(|s| s.iter().map(|&i| weights[i as usize]).sum())
            .collect()
    }

    /// Inverse map: `shard_of[node] = shard index`.
    pub fn shard_of(&self) -> Vec<usize> {
        let mut out = vec![usize::MAX; self.n];
        for (s, shard) in self.shards.iter().enumerate() {
            for &i in shard {
                out[i as usize] = s;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DatasetSpec, GraphDataset};
    use crate::testing::{check, prop_assert, PropResult};
    use crate::util::rng::Rng;

    fn check_invariants(p: &Partitioning) -> PropResult {
        let mut seen = vec![false; p.n];
        for shard in &p.shards {
            prop_assert(
                shard.windows(2).all(|w| w[0] < w[1]),
                "shard sorted ascending, duplicate-free",
            )?;
            for &i in shard {
                prop_assert((i as usize) < p.n, "node id in range")?;
                prop_assert(!seen[i as usize], "shards disjoint")?;
                seen[i as usize] = true;
            }
        }
        prop_assert(seen.iter().all(|&s| s), "shards cover every node")
    }

    #[test]
    fn prop_cover_disjoint_and_balanced() {
        check(
            30,
            |rng| {
                let n = 1 + rng.gen_range(300);
                let weights: Vec<usize> =
                    (0..n).map(|_| rng.powerlaw(100, 2.0)).collect();
                let shards = 1 + rng.gen_range(12);
                (weights, shards)
            },
            |(weights, shards)| -> PropResult {
                let p = Partitioning::from_weights(weights, *shards);
                check_invariants(&p)?;
                // LPT guarantee: max load exceeds min load by at most the
                // heaviest single weight (when every shard got something).
                let loads = p.loads(weights);
                let (lo, hi) = (
                    *loads.iter().min().unwrap(),
                    *loads.iter().max().unwrap(),
                );
                let wmax = weights.iter().copied().max().unwrap_or(0);
                prop_assert(hi <= lo + wmax.max(1), "LPT balance bound")
            },
        );
    }

    #[test]
    fn degree_partition_balances_powerlaw_graph() {
        let mut rng = Rng::new(1);
        let spec = DatasetSpec {
            name: "Part",
            n: 500,
            feat_dim: 16,
            adj_density: 0.03,
            feat_density: 0.1,
            n_classes: 4,
        };
        let ds = GraphDataset::generate(&spec, &mut rng);
        let p = Partitioning::by_degree(&ds.adj, 8);
        check_invariants(&p).unwrap();
        let degrees: Vec<usize> =
            ds.adj.row_counts().into_iter().map(|c| c as usize).collect();
        let loads = p.loads(&degrees);
        let wmax = degrees.iter().copied().max().unwrap();
        let (lo, hi) = (*loads.iter().min().unwrap(), *loads.iter().max().unwrap());
        assert!(hi <= lo + wmax, "degree loads {loads:?} (wmax {wmax})");
        // Inverse map is total.
        assert!(p.shard_of().iter().all(|&s| s < 8));
    }

    #[test]
    fn deterministic_for_same_input() {
        let weights: Vec<usize> = (0..200).map(|i| (i * 7919) % 97).collect();
        let a = Partitioning::from_weights(&weights, 6);
        let b = Partitioning::from_weights(&weights, 6);
        assert_eq!(a.shards, b.shards);
    }

    #[test]
    fn degenerate_shapes() {
        // More shards than nodes: empty shards allowed, cover still exact.
        let p = Partitioning::from_weights(&[5, 1], 4);
        check_invariants(&p).unwrap();
        assert_eq!(p.shards.len(), 4);
        // Zero nodes.
        let p0 = Partitioning::from_weights(&[], 3);
        check_invariants(&p0).unwrap();
        // One shard takes everything.
        let p1 = Partitioning::from_weights(&[3, 2, 8], 1);
        assert_eq!(p1.shards[0], vec![0, 1, 2]);
    }
}
