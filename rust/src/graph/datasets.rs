//! The paper's evaluation datasets (Table 1), synthesized to matching
//! shape, density, and degree skew. KarateClub uses the real Zachary graph
//! (public domain). The Entities suite for RGCN is generated as multi-
//! relational graphs.
//!
//! Substitution note (DESIGN.md): the format predictor consumes only matrix
//! *structure*; matching N, density and degree distribution reproduces the
//! format-performance trade-offs the paper measured.

use super::generators;
use super::normalize_adj;
use crate::sparse::Coo;
use crate::util::rng::Rng;

/// Shape/density spec for a Table-1 dataset (paper scale).
#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// Number of graph nodes (adjacency is n × n).
    pub n: usize,
    /// Node feature dimension.
    pub feat_dim: usize,
    /// Adjacency density (Table 1).
    pub adj_density: f64,
    /// Node feature density (bag-of-words style sparsity).
    pub feat_density: f64,
    pub n_classes: usize,
}

/// Paper Table 1 (adjacency is n×n; the table's second dimension is the
/// feature arity).
pub const PAPER_DATASETS: [DatasetSpec; 5] = [
    DatasetSpec { name: "CoraFull", n: 19_793, feat_dim: 8_710, adj_density: 0.006, feat_density: 0.007, n_classes: 70 },
    DatasetSpec { name: "Cora", n: 2_708, feat_dim: 1_433, adj_density: 0.0127, feat_density: 0.0127, n_classes: 7 },
    DatasetSpec { name: "DblpFull", n: 17_716, feat_dim: 1_639, adj_density: 0.0031, feat_density: 0.006, n_classes: 4 },
    DatasetSpec { name: "PubmedFull", n: 19_717, feat_dim: 500, adj_density: 0.1002, feat_density: 0.02, n_classes: 3 },
    DatasetSpec { name: "KarateClub", n: 34, feat_dim: 34, adj_density: 0.0294, feat_density: 0.0294, n_classes: 2 },
];

/// Production-scale synthetic specs beyond Table 1 — graphs that cannot be
/// trained full-batch at reasonable memory/latency, the workloads the
/// sharded mini-batch subsystem (`gnn::minibatch`) exists for. Shapes and
/// densities mirror public large-graph benchmarks (ogbn-arxiv: 169,343
/// nodes / ~1.17M undirected edges; a 50×-Cora citation shape), generated
/// with the same SBM + power-law machinery as the Table-1 substitutes.
pub const LARGE_DATASETS: [DatasetSpec; 2] = [
    DatasetSpec { name: "ogbn-arxiv-scale", n: 169_343, feat_dim: 128, adj_density: 8.1e-5, feat_density: 0.05, n_classes: 40 },
    DatasetSpec { name: "cora-x50-scale", n: 135_400, feat_dim: 256, adj_density: 2.6e-4, feat_density: 0.01, n_classes: 7 },
];

impl DatasetSpec {
    /// Laptop-scale variant: nodes divided by `shrink`, feature dim capped —
    /// same density band, same degree skew (see DESIGN.md §Substitutions).
    pub fn scaled(&self, shrink: usize, max_feat: usize) -> DatasetSpec {
        let mut s = *self;
        if s.n > 64 {
            s.n = (s.n / shrink).max(64);
        }
        s.feat_dim = s.feat_dim.min(max_feat);
        s
    }

    /// Default evaluation scale used across benches (shrink 4, feat ≤ 256).
    pub fn laptop(&self) -> DatasetSpec {
        self.scaled(4, 256)
    }

    /// Shrink node count while **preserving average degree** (density
    /// scales up by `shrink`, capped at 0.5). The right scaling for
    /// mini-batch CI runs: per-shard edge load and neighbor-sampling
    /// behavior depend on degree, which plain [`DatasetSpec::scaled`]
    /// dilutes along with the node count.
    pub fn scaled_same_degree(&self, shrink: usize, max_feat: usize) -> DatasetSpec {
        let mut s = self.scaled(shrink, max_feat);
        if s.n < self.n {
            let factor = self.n as f64 / s.n as f64;
            s.adj_density = (self.adj_density * factor).min(0.5);
        }
        s
    }
}

/// A node-classification graph dataset.
#[derive(Clone, Debug)]
pub struct GraphDataset {
    pub name: String,
    /// Raw symmetric adjacency (no self loops).
    pub adj: Coo,
    /// Â = D^{-1/2}(A+I)D^{-1/2}.
    pub adj_norm: Coo,
    /// Sparse node features (n × feat_dim) — bag-of-words style.
    pub features: Coo,
    pub labels: Vec<usize>,
    pub n_classes: usize,
    pub train_mask: Vec<bool>,
    pub val_mask: Vec<bool>,
    pub test_mask: Vec<bool>,
}

impl GraphDataset {
    /// Generate a dataset matching `spec`: SBM-style homophilous graph with
    /// power-law degree activity, plus class-signature sparse features.
    pub fn generate(spec: &DatasetSpec, rng: &mut Rng) -> GraphDataset {
        if spec.name == "KarateClub" {
            return karate_club();
        }
        let n = spec.n;
        let k = spec.n_classes;
        let labels: Vec<usize> = (0..n).map(|_| rng.gen_range(k)).collect();

        // Node activity (power-law) controls degree skew like citation data.
        let activity: Vec<f64> = (0..n)
            .map(|_| 1.0 / (1.0 + rng.powerlaw(1000, 2.0) as f64))
            .collect();
        let act_sum: f64 = activity.iter().sum();

        // Target undirected edge count from density (nnz = 2·edges).
        let target_edges = ((n as f64 * n as f64 * spec.adj_density) / 2.0).round() as usize;
        let homophily = 0.8;
        let mut triples = Vec::with_capacity(target_edges * 2);
        // Pre-bucket nodes per class for homophilous target sampling.
        let mut per_class: Vec<Vec<u32>> = vec![Vec::new(); k];
        for (i, &l) in labels.iter().enumerate() {
            per_class[l].push(i as u32);
        }
        // Activity-weighted source sampling via cumulative table.
        let mut cum = Vec::with_capacity(n);
        let mut acc = 0.0;
        for &a in &activity {
            acc += a / act_sum;
            cum.push(acc);
        }
        let sample_node = |rng: &mut Rng| -> usize {
            let u = rng.next_f64();
            cum.partition_point(|&c| c < u).min(n - 1)
        };
        let mut placed = 0usize;
        let mut attempts = 0usize;
        while placed < target_edges && attempts < target_edges * 20 {
            attempts += 1;
            let src = sample_node(rng);
            let dst = if rng.bernoulli(homophily) {
                let bucket = &per_class[labels[src]];
                if bucket.is_empty() {
                    continue;
                }
                *rng.choose(bucket) as usize
            } else {
                sample_node(rng)
            };
            if src == dst {
                continue;
            }
            triples.push((src as u32, dst as u32, 1.0f32));
            triples.push((dst as u32, src as u32, 1.0f32));
            placed += 1;
        }
        let adj = Coo::from_triples(n, n, triples);

        // Sparse class-signature features: each class owns a word bucket;
        // each node samples most words from its class bucket + noise.
        let d = spec.feat_dim;
        let words_per_node = ((d as f64 * spec.feat_density).round() as usize).clamp(1, d);
        let bucket = (d / k).max(1);
        let mut ftriples = Vec::with_capacity(n * words_per_node);
        for (i, &l) in labels.iter().enumerate() {
            for _ in 0..words_per_node {
                let w = if rng.bernoulli(0.8) {
                    (l * bucket + rng.gen_range(bucket)).min(d - 1)
                } else {
                    rng.gen_range(d)
                };
                ftriples.push((i as u32, w as u32, 1.0f32));
            }
        }
        let features = Coo::from_triples(n, d, ftriples);

        let (train_mask, val_mask, test_mask) = split_masks(n, rng);
        GraphDataset {
            name: spec.name.to_string(),
            adj_norm: normalize_adj(&adj),
            adj,
            features,
            labels,
            n_classes: k,
            train_mask,
            val_mask,
            test_mask,
        }
    }
}

/// 60/20/20 node split.
fn split_masks(n: usize, rng: &mut Rng) -> (Vec<bool>, Vec<bool>, Vec<bool>) {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut train = vec![false; n];
    let mut val = vec![false; n];
    let mut test = vec![false; n];
    for (pos, &i) in idx.iter().enumerate() {
        match pos * 10 / n {
            0..=5 => train[i] = true,
            6..=7 => val[i] = true,
            _ => test[i] = true,
        }
    }
    (train, val, test)
}

/// Zachary's karate club (real, public-domain): 34 nodes, 78 edges,
/// 2 factions, identity features — Table 1's smallest dataset.
pub fn karate_club() -> GraphDataset {
    #[rustfmt::skip]
    const EDGES: [(u32, u32); 78] = [
        (1,2),(1,3),(2,3),(1,4),(2,4),(3,4),(1,5),(1,6),(1,7),(5,7),(6,7),
        (1,8),(2,8),(3,8),(4,8),(1,9),(3,9),(3,10),(1,11),(5,11),(6,11),
        (1,12),(1,13),(4,13),(1,14),(2,14),(3,14),(4,14),(6,17),(7,17),
        (1,18),(2,18),(1,20),(2,20),(1,22),(2,22),(24,26),(25,26),(3,28),
        (24,28),(25,28),(3,29),(24,30),(27,30),(2,31),(9,31),(1,32),(25,32),
        (26,32),(29,32),(3,33),(9,33),(15,33),(16,33),(19,33),(21,33),
        (23,33),(24,33),(30,33),(31,33),(32,33),(9,34),(10,34),(14,34),
        (15,34),(16,34),(19,34),(20,34),(21,34),(23,34),(24,34),(27,34),
        (28,34),(29,34),(30,34),(31,34),(32,34),(33,34),
    ];
    const FACTION_HI: [u32; 17] = [1, 2, 3, 4, 5, 6, 7, 8, 9, 11, 12, 13, 14, 17, 18, 20, 22];
    let n = 34;
    let mut triples = Vec::with_capacity(EDGES.len() * 2);
    for &(a, b) in &EDGES {
        triples.push((a - 1, b - 1, 1.0f32));
        triples.push((b - 1, a - 1, 1.0f32));
    }
    let adj = Coo::from_triples(n, n, triples);
    let labels: Vec<usize> = (0..n as u32)
        .map(|i| usize::from(!FACTION_HI.contains(&(i + 1))))
        .collect();
    // Identity features (the standard featureless-graph convention).
    let features = Coo::from_triples(n, n, (0..n as u32).map(|i| (i, i, 1.0f32)).collect());
    // Semi-supervised: label 4 seeds per faction, evaluate on the rest.
    let mut train_mask = vec![false; n];
    for &i in &[0usize, 1, 2, 3, 33, 32, 31, 30] {
        train_mask[i] = true;
    }
    let test_mask: Vec<bool> = train_mask.iter().map(|&t| !t).collect();
    GraphDataset {
        name: "KarateClub".to_string(),
        adj_norm: normalize_adj(&adj),
        adj,
        features,
        labels,
        n_classes: 2,
        val_mask: vec![false; n],
        train_mask,
        test_mask,
    }
}

/// Multi-relational dataset for RGCN (the paper's Entities suite [26]):
/// one adjacency per relation type, identity features, entity-class labels.
#[derive(Clone, Debug)]
pub struct RelationalDataset {
    pub name: String,
    pub adjs: Vec<Coo>,
    pub adjs_norm: Vec<Coo>,
    pub n: usize,
    pub labels: Vec<usize>,
    pub n_classes: usize,
    pub train_mask: Vec<bool>,
    pub test_mask: Vec<bool>,
}

impl RelationalDataset {
    /// Generate an Entities-like relational graph. Relation densities are
    /// skewed (one dominant relation + sparse auxiliaries) as in AIFB/MUTAG.
    pub fn generate(name: &str, n: usize, n_rels: usize, n_classes: usize, rng: &mut Rng) -> RelationalDataset {
        let labels: Vec<usize> = (0..n).map(|_| rng.gen_range(n_classes)).collect();
        let mut adjs = Vec::with_capacity(n_rels);
        for r in 0..n_rels {
            let density = 0.004 / (1.0 + r as f64 * 2.0);
            let pattern = if r % 2 == 0 {
                generators::MatrixPattern::PowerLaw
            } else {
                generators::MatrixPattern::Uniform
            };
            let m = generators::gen_matrix(rng, n, density, pattern);
            // Symmetrize (RGCN uses inverse relations; we fold them in).
            let mut triples: Vec<(u32, u32, f32)> = Vec::with_capacity(m.nnz() * 2);
            for i in 0..m.nnz() {
                triples.push((m.row[i], m.col[i], 1.0));
                triples.push((m.col[i], m.row[i], 1.0));
            }
            adjs.push(Coo::from_triples(n, n, triples));
        }
        let adjs_norm = adjs.iter().map(normalize_adj).collect();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let mut train_mask = vec![false; n];
        for &i in idx.iter().take(n * 8 / 10) {
            train_mask[i] = true;
        }
        let test_mask: Vec<bool> = train_mask.iter().map(|&t| !t).collect();
        RelationalDataset {
            name: name.to_string(),
            adjs,
            adjs_norm,
            n,
            labels,
            n_classes,
            train_mask,
            test_mask,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn karate_club_is_the_real_graph() {
        let kc = karate_club();
        assert_eq!(kc.adj.rows, 34);
        assert_eq!(kc.adj.nnz(), 156); // 78 undirected edges
        assert_eq!(kc.labels.iter().filter(|&&l| l == 0).count(), 17);
        // Symmetric.
        assert_eq!(kc.adj.transpose(), kc.adj);
        // Node 0 (Mr. Hi) and node 33 (Officer) are in different factions.
        assert_ne!(kc.labels[0], kc.labels[33]);
    }

    #[test]
    fn generated_dataset_matches_spec_roughly() {
        let mut rng = Rng::new(1);
        let spec = DatasetSpec {
            name: "Test",
            n: 400,
            feat_dim: 64,
            adj_density: 0.02,
            feat_density: 0.05,
            n_classes: 4,
        };
        let ds = GraphDataset::generate(&spec, &mut rng);
        assert_eq!(ds.adj.rows, 400);
        let density = ds.adj.density();
        assert!(density > 0.008 && density < 0.04, "density {density}");
        // Symmetric adjacency.
        assert_eq!(ds.adj.transpose(), ds.adj);
        // Features shaped and sparse.
        assert_eq!(ds.features.rows, 400);
        assert_eq!(ds.features.cols, 64);
        assert!(ds.features.density() < 0.2);
        // Masks partition.
        for i in 0..400 {
            let cnt = usize::from(ds.train_mask[i]) + usize::from(ds.val_mask[i]) + usize::from(ds.test_mask[i]);
            assert_eq!(cnt, 1);
        }
    }

    #[test]
    fn homophily_present() {
        let mut rng = Rng::new(2);
        let spec = DatasetSpec {
            name: "Homo",
            n: 300,
            feat_dim: 32,
            adj_density: 0.03,
            feat_density: 0.1,
            n_classes: 3,
        };
        let ds = GraphDataset::generate(&spec, &mut rng);
        let mut intra = 0usize;
        let mut total = 0usize;
        for i in 0..ds.adj.nnz() {
            total += 1;
            if ds.labels[ds.adj.row[i] as usize] == ds.labels[ds.adj.col[i] as usize] {
                intra += 1;
            }
        }
        let frac = intra as f64 / total as f64;
        assert!(frac > 0.6, "homophily fraction {frac}");
    }

    #[test]
    fn laptop_scaling() {
        let full = PAPER_DATASETS[0];
        let small = full.laptop();
        assert_eq!(small.n, full.n / 4);
        assert_eq!(small.feat_dim, 256);
        assert_eq!(small.adj_density, full.adj_density);
        // Karate club (n=34 ≤ 64) never shrinks.
        let kc = PAPER_DATASETS[4].laptop();
        assert_eq!(kc.n, 34);
    }

    #[test]
    fn degree_preserving_scaling() {
        let full = LARGE_DATASETS[0];
        let small = full.scaled_same_degree(8, 64);
        let deg_full = full.n as f64 * full.adj_density;
        let deg_small = small.n as f64 * small.adj_density;
        assert!((deg_full - deg_small).abs() / deg_full < 0.05, "{deg_full} vs {deg_small}");
        assert_eq!(small.feat_dim, 64);
    }

    #[test]
    fn large_specs_are_minibatch_scale() {
        for spec in &LARGE_DATASETS {
            // An order of magnitude past the Table-1 full-batch graphs.
            assert!(spec.n >= 100_000, "{}", spec.name);
            // Still sparse: average degree stays citation-graph-like.
            let avg_deg = spec.n as f64 * spec.adj_density;
            assert!(avg_deg > 1.0 && avg_deg < 100.0, "{}: {avg_deg}", spec.name);
        }
        // A shrunk variant generates quickly with matching shape (the CI
        // scale the minibatch integration tests use).
        let mut rng = Rng::new(9);
        let spec = LARGE_DATASETS[0].scaled(32, 32);
        let ds = GraphDataset::generate(&spec, &mut rng);
        assert_eq!(ds.adj.rows, LARGE_DATASETS[0].n / 32);
        assert!(ds.adj.nnz() > 0);
        assert_eq!(ds.features.cols, 32);
    }

    #[test]
    fn relational_dataset_shapes() {
        let mut rng = Rng::new(3);
        let ds = RelationalDataset::generate("EntitiesTest", 200, 3, 4, &mut rng);
        assert_eq!(ds.adjs.len(), 3);
        assert_eq!(ds.adjs_norm.len(), 3);
        for a in &ds.adjs {
            assert_eq!(a.rows, 200);
            assert_eq!(a.transpose(), *a);
        }
        // Dominant relation is denser than auxiliaries.
        assert!(ds.adjs[0].nnz() >= ds.adjs[2].nnz());
    }
}
