//! Graph workloads: the paper's Table-1 datasets (synthesized to matching
//! shape/density/degree-skew — see DESIGN.md §Substitutions), the Entities
//! relational suite for RGCN, and the synthetic matrix generators used to
//! train the format predictor (§4.3).

pub mod generators;
pub mod datasets;
pub mod partition;
pub mod sampler;
pub mod stream;

pub use datasets::{DatasetSpec, GraphDataset, RelationalDataset, LARGE_DATASETS, PAPER_DATASETS};
pub use generators::{gen_matrix, MatrixPattern};
pub use partition::Partitioning;
pub use sampler::{NeighborSampler, SubgraphBatch};

use crate::sparse::Coo;

/// Symmetrically normalized adjacency with self-loops:
/// `Â = D^{-1/2} (A + I) D^{-1/2}` — the GCN propagation operator.
///
/// Assumes **nonnegative edge weights**: the degrees are weight sums, so a
/// negative weight would make `D^{-1/2}` meaningless. Callers own the
/// invariant (every dataset generator emits unit/positive weights); it is
/// asserted in debug builds rather than silently patched with `abs()`.
pub fn normalize_adj(adj: &Coo) -> Coo {
    assert_eq!(adj.rows, adj.cols, "adjacency must be square");
    debug_assert!(
        adj.val.iter().all(|&v| v >= 0.0),
        "normalize_adj requires nonnegative edge weights"
    );
    let n = adj.rows;
    // A + I, pre-sized: exactly nnz + n triples, no per-push growth.
    let mut triples: Vec<(u32, u32, f32)> = Vec::with_capacity(adj.nnz() + n);
    for i in 0..adj.nnz() {
        triples.push((adj.row[i], adj.col[i], adj.val[i]));
    }
    for i in 0..n {
        triples.push((i as u32, i as u32, 1.0));
    }
    let a_hat = Coo::from_triples(n, n, triples);
    // degree = row sums
    let mut deg = vec![0f64; n];
    for i in 0..a_hat.nnz() {
        deg[a_hat.row[i] as usize] += a_hat.val[i] as f64;
    }
    let d_inv_sqrt: Vec<f64> = deg.iter().map(|&d| if d > 0.0 { d.powf(-0.5) } else { 0.0 }).collect();
    let triples = (0..a_hat.nnz())
        .map(|i| {
            let r = a_hat.row[i] as usize;
            let c = a_hat.col[i] as usize;
            (
                a_hat.row[i],
                a_hat.col[i],
                (a_hat.val[i] as f64 * d_inv_sqrt[r] * d_inv_sqrt[c]) as f32,
            )
        })
        .collect();
    Coo::from_triples(n, n, triples)
}

/// Density of the k-hop reachability pattern of `adj` (with self loops) —
/// the effective propagation field after `k` GNN iterations. Used by the
/// Fig-2 density-drift experiment.
pub fn khop_density(adj: &Coo, k: usize) -> f64 {
    let n = adj.rows;
    // Boolean sparse power via repeated pattern expansion on row adjacency
    // lists (values irrelevant).
    let mut neigh: Vec<Vec<u32>> = vec![Vec::new(); n];
    for i in 0..adj.nnz() {
        neigh[adj.row[i] as usize].push(adj.col[i]);
    }
    for (i, list) in neigh.iter_mut().enumerate() {
        list.push(i as u32); // self loop
        list.sort_unstable();
        list.dedup();
    }
    let mut reach: Vec<Vec<u32>> = neigh.clone();
    for _ in 1..k {
        reach = crate::util::parallel::parallel_map(n, |i| {
            let mut acc: Vec<u32> = Vec::new();
            for &j in &reach[i] {
                acc.extend_from_slice(&neigh[j as usize]);
            }
            acc.sort_unstable();
            acc.dedup();
            acc
        });
    }
    let nnz: usize = reach.iter().map(|l| l.len()).sum();
    nnz as f64 / (n as f64 * n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn normalize_adds_self_loops_and_scales() {
        // Path graph 0-1-2.
        let adj = Coo::from_triples(
            3,
            3,
            vec![(0, 1, 1.0), (1, 0, 1.0), (1, 2, 1.0), (2, 1, 1.0)],
        );
        let norm = normalize_adj(&adj);
        assert_eq!(norm.nnz(), 7); // 4 edges + 3 self loops
        // Entries are positive and ≤ 1 (D^{-1/2}(A+I)D^{-1/2} with unit weights).
        let dense = norm.to_dense();
        assert!(norm.val.iter().all(|&v| v > 0.0 && v <= 1.0));
        // Middle node (degree 3 incl. self-loop) has Â_11 = 1/3.
        assert!((dense.at(1, 1) - 1.0 / 3.0).abs() < 1e-6);
        // Symmetry preserved.
        for r in 0..3 {
            for c in 0..3 {
                assert!((dense.at(r, c) - dense.at(c, r)).abs() < 1e-6);
            }
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "nonnegative")]
    fn normalize_rejects_negative_weights() {
        let adj = Coo::from_triples(2, 2, vec![(0, 1, -1.0), (1, 0, -1.0)]);
        let _ = normalize_adj(&adj);
    }

    #[test]
    fn khop_density_monotone() {
        let mut rng = Rng::new(1);
        let mut triples = Vec::new();
        for r in 0..60u32 {
            for c in 0..60u32 {
                if r != c && rng.bernoulli(0.03) {
                    triples.push((r, c, 1.0f32));
                    triples.push((c, r, 1.0f32));
                }
            }
        }
        let adj = Coo::from_triples(60, 60, triples);
        let d1 = khop_density(&adj, 1);
        let d2 = khop_density(&adj, 2);
        let d3 = khop_density(&adj, 3);
        assert!(d1 <= d2 && d2 <= d3, "{d1} {d2} {d3}");
        assert!(d3 <= 1.0);
    }
}
