//! Seeded neighbor sampling: shard seeds → induced subgraph batch.
//!
//! GraphSAGE-style fan-out control: every seed node contributes itself plus
//! at most `fanout` of its neighbors (a uniform draw without replacement
//! when the degree exceeds the fanout), bounding the batch at
//! `|seeds| · (fanout + 1)` nodes regardless of hub degrees. The induced
//! node set feeds `SparseOps::extract_rows_cols`, so it is returned sorted
//! ascending and duplicate-free.
//!
//! Sampling is **deterministic** per `(sampler seed, epoch, shard)`: the
//! same run configuration reproduces the same batches (the experiment
//! reproducibility rule every harness in this repo follows), while
//! different epochs resample different neighborhoods — the variance that
//! makes neighbor sampling work.

use crate::sparse::Csr;
use crate::util::rng::Rng;

/// One induced subgraph batch produced by [`NeighborSampler::sample`].
#[derive(Clone, Debug)]
pub struct SubgraphBatch {
    /// Induced node ids (sorted ascending, duplicate-free): the shard's
    /// seeds plus their sampled neighbors.
    pub nodes: Vec<u32>,
    /// `is_seed[i]` — `nodes[i]` is a seed (loss) node, not a sampled-in
    /// neighbor (neighbors provide message-passing context only).
    pub is_seed: Vec<bool>,
}

impl SubgraphBatch {
    /// Number of seed (loss) nodes in the batch.
    pub fn seed_count(&self) -> usize {
        self.is_seed.iter().filter(|&&s| s).count()
    }
}

/// Uniform per-seed neighbor sampler over a CSR adjacency.
pub struct NeighborSampler<'g> {
    adj: &'g Csr,
    /// Max sampled neighbors per seed (0 = seeds only).
    pub fanout: usize,
    seed: u64,
}

impl<'g> NeighborSampler<'g> {
    /// `adj` must be the (square) graph adjacency in CSR — row `v`'s
    /// indices are `v`'s neighbor list.
    pub fn new(adj: &'g Csr, fanout: usize, seed: u64) -> NeighborSampler<'g> {
        assert_eq!(adj.rows, adj.cols, "adjacency must be square");
        NeighborSampler { adj, fanout, seed }
    }

    /// Sample the induced batch for `seeds` (sorted ascending,
    /// duplicate-free) at a given `(epoch, shard)` coordinate. Same
    /// coordinates ⇒ same batch.
    pub fn sample(&self, seeds: &[u32], epoch: usize, shard: usize) -> SubgraphBatch {
        debug_assert!(
            seeds.windows(2).all(|w| w[0] < w[1]),
            "seeds must be sorted ascending, duplicate-free"
        );
        let mut rng = Rng::new(
            self.seed
                ^ (epoch as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (shard as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        );
        let mut nodes: Vec<u32> = seeds.to_vec();
        for &s in seeds {
            let span =
                &self.adj.indices[self.adj.indptr[s as usize]..self.adj.indptr[s as usize + 1]];
            if span.len() <= self.fanout {
                nodes.extend_from_slice(span);
            } else if self.fanout > 0 {
                for idx in rng.sample_indices(span.len(), self.fanout) {
                    nodes.push(span[idx]);
                }
            }
        }
        nodes.sort_unstable();
        nodes.dedup();
        let is_seed = nodes.iter().map(|v| seeds.binary_search(v).is_ok()).collect();
        SubgraphBatch { nodes, is_seed }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{DatasetSpec, GraphDataset};
    use crate::graph::partition::Partitioning;
    use crate::testing::{check, prop_assert, PropResult};
    use crate::util::rng::Rng;

    fn graph(n: usize, seed: u64) -> (GraphDataset, Csr) {
        let mut rng = Rng::new(seed);
        let spec = DatasetSpec {
            name: "Samp",
            n,
            feat_dim: 8,
            adj_density: 0.04,
            feat_density: 0.2,
            n_classes: 3,
        };
        let ds = GraphDataset::generate(&spec, &mut rng);
        let csr = Csr::from_coo(&ds.adj);
        (ds, csr)
    }

    #[test]
    fn prop_batch_invariants() {
        let (_, csr) = graph(250, 1);
        check(
            25,
            |rng| {
                let fanout = rng.gen_range(6);
                let k = 1 + rng.gen_range(20);
                let mut seeds: Vec<u32> =
                    rng.sample_indices(250, k).into_iter().map(|i| i as u32).collect();
                seeds.sort_unstable();
                let epoch = rng.gen_range(5);
                (fanout, seeds, epoch)
            },
            |(fanout, seeds, epoch)| -> PropResult {
                let sampler = NeighborSampler::new(&csr, *fanout, 0xFEED);
                let b = sampler.sample(seeds, *epoch, 3);
                prop_assert(
                    b.nodes.windows(2).all(|w| w[0] < w[1]),
                    "nodes sorted, duplicate-free",
                )?;
                prop_assert(b.nodes.len() == b.is_seed.len(), "mask aligned")?;
                prop_assert(
                    b.nodes.len() <= seeds.len() * (fanout + 1),
                    "fanout bound",
                )?;
                prop_assert(b.seed_count() == seeds.len(), "every seed present")?;
                // Seed flags mark exactly the seed ids.
                for (i, &v) in b.nodes.iter().enumerate() {
                    prop_assert(
                        b.is_seed[i] == seeds.binary_search(&v).is_ok(),
                        "is_seed correctness",
                    )?;
                }
                // Sampled-in nodes are genuine neighbors of some seed.
                for (i, &v) in b.nodes.iter().enumerate() {
                    if b.is_seed[i] {
                        continue;
                    }
                    let reachable = seeds.iter().any(|&s| {
                        csr.indices[csr.indptr[s as usize]..csr.indptr[s as usize + 1]]
                            .contains(&v)
                    });
                    prop_assert(reachable, "non-seed node is a sampled neighbor")?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn deterministic_per_coordinate_and_varies_across_epochs() {
        let (ds, csr) = graph(300, 2);
        let part = Partitioning::by_degree(&ds.adj, 6);
        let sampler = NeighborSampler::new(&csr, 3, 42);
        let shard = &part.shards[2];
        let a = sampler.sample(shard, 1, 2);
        let b = sampler.sample(shard, 1, 2);
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(a.is_seed, b.is_seed);
        // Different epochs usually resample differently (not guaranteed for
        // every shard, so check across all shards).
        let differs = part.shards.iter().enumerate().any(|(sid, s)| {
            !s.is_empty()
                && sampler.sample(s, 0, sid).nodes != sampler.sample(s, 1, sid).nodes
        });
        assert!(differs, "epoch coordinate should change sampling somewhere");
    }

    #[test]
    fn fanout_zero_returns_seeds_only() {
        let (_, csr) = graph(100, 3);
        let sampler = NeighborSampler::new(&csr, 0, 7);
        let seeds = vec![1u32, 5, 50, 99];
        let b = sampler.sample(&seeds, 0, 0);
        assert_eq!(b.nodes, seeds);
        assert!(b.is_seed.iter().all(|&s| s));
    }

    #[test]
    fn huge_fanout_takes_full_neighborhood() {
        let (ds, csr) = graph(120, 4);
        let sampler = NeighborSampler::new(&csr, usize::MAX, 9);
        let seeds: Vec<u32> = (0..120).collect();
        let b = sampler.sample(&seeds, 0, 0);
        // Every node with every neighbor = all nodes.
        assert_eq!(b.nodes.len(), ds.adj.rows);
    }
}
