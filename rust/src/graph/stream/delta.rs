//! Write-optimized delta overlay (DESIGN.md §Streaming-Durability).
//!
//! The LSM memtable analogue: edge ops land here (after the WAL append
//! that makes them durable) as per-row patch maps over the immutable CSR
//! master. A patch entry is `col → Some(w)` (upsert) or `col → None`
//! (delete); applying an op is an `O(log)` map insert, and the read path
//! merges a master row with one or two overlays (frozen + live) in one
//! ordered sweep. DOK/LIL live in `sparse/` as full matrix formats; this
//! structure is deliberately *sparser than that* — it only materializes
//! touched rows, so a stream touching 1% of a million-node graph costs
//! memory proportional to the touch set, not the graph.

use super::wal::EdgeOp;
use crate::sparse::Csr;
use std::collections::BTreeMap;

/// Per-row patches over a CSR master. `Clone` is deliberate: compaction
/// clones the frozen overlay to merge outside the state lock, keeping the
/// original in place until the merge succeeds (panic-safety).
#[derive(Clone, Debug, Default)]
pub struct DeltaOverlay {
    rows: BTreeMap<u32, BTreeMap<u32, Option<f32>>>,
    edits: usize,
}

impl DeltaOverlay {
    pub fn new() -> DeltaOverlay {
        DeltaOverlay::default()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Total column-level patch entries (distinct `(row, col)` pairs).
    pub fn edits(&self) -> usize {
        self.edits
    }

    /// Rows with at least one patch entry, ascending.
    pub fn touched_rows(&self) -> impl Iterator<Item = u32> + '_ {
        self.rows.keys().copied()
    }

    /// Fold one absolute op in. Insert/Reweight upsert the weight;
    /// Delete records a tombstone (the master may still hold the edge —
    /// only compaction erases it for real).
    pub fn apply(&mut self, op: &EdgeOp) {
        let (r, c, patch) = match *op {
            EdgeOp::Insert { src, dst, w } | EdgeOp::Reweight { src, dst, w } => {
                (src, dst, Some(w))
            }
            EdgeOp::Delete { src, dst } => (src, dst, None),
        };
        if self.rows.entry(r).or_default().insert(c, patch).is_none() {
            self.edits += 1;
        }
    }

    /// The patch recorded for `(r, c)`, if any: `Some(Some(w))` upsert,
    /// `Some(None)` tombstone, `None` untouched.
    pub fn get(&self, r: u32, c: u32) -> Option<Option<f32>> {
        self.rows.get(&r).and_then(|row| row.get(&c).copied())
    }

    /// Patch a sorted `(col, weight)` row in place: upserts overwrite or
    /// splice in, tombstones remove. One ordered merge — `entries` stays
    /// sorted by column.
    pub fn patch_row(&self, r: u32, entries: &mut Vec<(u32, f32)>) {
        let Some(patches) = self.rows.get(&r) else {
            return;
        };
        let base = std::mem::take(entries);
        entries.reserve(base.len() + patches.len());
        let mut patch_it = patches.iter().peekable();
        for (c, w) in base {
            // Emit patches for columns strictly before the base entry.
            while let Some(&(&pc, &pw)) = patch_it.peek() {
                if pc >= c {
                    break;
                }
                patch_it.next();
                if let Some(pw) = pw {
                    entries.push((pc, pw));
                }
            }
            // A patch on exactly this column replaces (or deletes) it.
            if let Some(&(&pc, &pw)) = patch_it.peek() {
                if pc == c {
                    patch_it.next();
                    if let Some(pw) = pw {
                        entries.push((pc, pw));
                    }
                    continue;
                }
            }
            entries.push((c, w));
        }
        for (&pc, &pw) in patch_it {
            if let Some(pw) = pw {
                entries.push((pc, pw));
            }
        }
    }

    /// Backfill from an overlay that is **older** than `self`: entries
    /// from `older` land only where `self` has no patch (newer wins).
    /// Used when a crashed compaction hands its frozen overlay back to
    /// the live one.
    pub fn absorb_older(&mut self, older: DeltaOverlay) {
        for (r, row) in older.rows {
            let dst = self.rows.entry(r).or_default();
            for (c, patch) in row {
                if let std::collections::btree_map::Entry::Vacant(slot) = dst.entry(c) {
                    slot.insert(patch);
                    self.edits += 1;
                }
            }
        }
    }
}

/// A CSR row as an owned sorted `(col, weight)` vec — the merge substrate
/// `patch_row` edits.
pub(crate) fn csr_row(m: &Csr, r: u32) -> Vec<(u32, f32)> {
    m.row_entries(r as usize).map(|(c, w)| (c as u32, w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    fn master() -> Csr {
        // Row 1: cols {1: 1.0, 3: 3.0, 5: 5.0}
        Csr::from_coo(&Coo::from_triples(
            4,
            8,
            vec![(1, 1, 1.0), (1, 3, 3.0), (1, 5, 5.0), (2, 0, 2.0)],
        ))
    }

    #[test]
    fn apply_tracks_distinct_edits() {
        let mut d = DeltaOverlay::new();
        d.apply(&EdgeOp::Insert { src: 1, dst: 2, w: 2.0 });
        d.apply(&EdgeOp::Reweight { src: 1, dst: 2, w: 4.0 });
        d.apply(&EdgeOp::Delete { src: 1, dst: 3 });
        assert_eq!(d.edits(), 2, "re-patching the same cell is not a new edit");
        assert_eq!(d.get(1, 2), Some(Some(4.0)));
        assert_eq!(d.get(1, 3), Some(None));
        assert_eq!(d.get(0, 0), None);
        assert_eq!(d.touched_rows().collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn patch_row_merges_in_order() {
        let m = master();
        let mut d = DeltaOverlay::new();
        d.apply(&EdgeOp::Insert { src: 1, dst: 0, w: 0.5 }); // prepend
        d.apply(&EdgeOp::Reweight { src: 1, dst: 3, w: 30.0 }); // overwrite
        d.apply(&EdgeOp::Delete { src: 1, dst: 5 }); // tombstone
        d.apply(&EdgeOp::Insert { src: 1, dst: 7, w: 7.0 }); // append
        let mut row = csr_row(&m, 1);
        d.patch_row(1, &mut row);
        assert_eq!(row, vec![(0, 0.5), (1, 1.0), (3, 30.0), (7, 7.0)]);
        // Untouched row is left alone.
        let mut row2 = csr_row(&m, 2);
        d.patch_row(2, &mut row2);
        assert_eq!(row2, vec![(0, 2.0)]);
    }

    #[test]
    fn tombstone_on_absent_edge_is_a_noop_read() {
        let m = master();
        let mut d = DeltaOverlay::new();
        d.apply(&EdgeOp::Delete { src: 1, dst: 6 });
        let mut row = csr_row(&m, 1);
        d.patch_row(1, &mut row);
        assert_eq!(row, vec![(1, 1.0), (3, 3.0), (5, 5.0)]);
    }

    #[test]
    fn absorb_older_lets_the_newer_overlay_win() {
        let mut newer = DeltaOverlay::new();
        newer.apply(&EdgeOp::Insert { src: 0, dst: 0, w: 9.0 });
        let mut older = DeltaOverlay::new();
        older.apply(&EdgeOp::Insert { src: 0, dst: 0, w: 1.0 }); // loses
        older.apply(&EdgeOp::Insert { src: 0, dst: 1, w: 2.0 }); // fills
        older.apply(&EdgeOp::Delete { src: 3, dst: 3 }); // fills
        newer.absorb_older(older);
        assert_eq!(newer.get(0, 0), Some(Some(9.0)));
        assert_eq!(newer.get(0, 1), Some(Some(2.0)));
        assert_eq!(newer.get(3, 3), Some(None));
        assert_eq!(newer.edits(), 3);
    }
}
