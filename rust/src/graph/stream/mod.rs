//! Crash-safe streaming graph ingestion (DESIGN.md §Streaming-Durability).
//!
//! ROADMAP Direction 2: real services don't retrain on frozen graphs —
//! edges arrive continuously, and an ingestion path is only real if it
//! survives being killed mid-write and mid-compaction. This module is an
//! LSM-style mutable adjacency with a durability spine:
//!
//! * [`wal`] — a checksummed append-only write-ahead log. Every edge
//!   insert/delete/reweight is a length-prefixed, CRC-guarded record;
//!   fsync is batched (`sync_every`), and an operation counts as
//!   **acknowledged** only once its record is fsynced. Torn tails are
//!   truncated on open.
//! * [`delta`] — the in-memory write-optimized overlay (per-row patch
//!   maps over the immutable CSR master); the read path merges
//!   master + frozen delta + live delta per row.
//! * [`compact`] — the background compaction: freeze the live delta,
//!   merge into a fresh validated CSR master, renormalize only touched
//!   rows, checkpoint (temp-file + atomic rename via `util::fsio`),
//!   publish through [`EpochCell::publish_arc`], and drop compacted WAL
//!   records — supervised like serve's workers (panic → respawn under a
//!   restart budget → degraded mode where **ingest backpressures but
//!   reads stay live** on the last published snapshot).
//! * [`recovery`] — startup replay: load the checkpoint, scan the WAL
//!   tail, rebuild the overlay. Invariant: **every acknowledged write
//!   survives any single crash point** (the `testing::fault` CrashPoint
//!   seams script exactly those crashes; `tests/integration_stream.rs`
//!   sweeps every ordinal).
//!
//! All three edge operations are *absolute* (upserts/removals, never
//! increments), so replaying any suffix of the op stream after recovery
//! converges to a state bit-identical to the fault-free run — the
//! property the recovery-equivalence test pins.
//!
//! Normalization here is **row-stochastic** (`D⁻¹A`), not GCN's
//! symmetric `D^{-1/2}(A+I)D^{-1/2}`: row normalization is local to a
//! row, so compaction renormalizes exactly the touched rows (DESIGN.md
//! §Substitutions records the deviation).

pub mod compact;
pub mod delta;
pub mod recovery;
pub mod wal;

pub use delta::DeltaOverlay;
pub use wal::{EdgeOp, Wal};

use crate::sparse::shared::EpochCell;
use crate::sparse::{Csr, SharedMatrix, SparseMatrix};
use crate::testing::FaultPlan;
use crate::util::sync::lock_recover;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Why a streaming operation failed. Mirrors `serve::ServeError`'s
/// taxonomy: one typed variant per failure site, stable `kind()` tags.
#[derive(Debug, Clone, PartialEq)]
pub enum StreamError {
    /// A durable-write seam failed (real I/O error or an injected
    /// `FaultKind::IoError`). The op is not acknowledged; the caller may
    /// retry — ops are absolute, so a retry can never double-apply.
    Io { what: String },
    /// On-disk or in-flight state failed validation (bad checkpoint
    /// magic/CRC, out-of-bounds endpoint, non-finite weight, compacted
    /// master rejected by `SparseMatrix::validate`).
    Corrupt { what: String },
    /// An injected `FaultKind::CrashPoint` fired at this seam: the store
    /// must be treated as dead — drop it and re-open (recovery).
    Crashed { seam: &'static str },
    /// Ingest backpressure: the compactor exhausted its restart budget
    /// and the store is degraded — writes are refused so the un-compacted
    /// delta cannot grow without bound, while reads stay live.
    Backpressure { pending: usize },
}

impl StreamError {
    /// Stable short tag for logs/metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            StreamError::Io { .. } => "io",
            StreamError::Corrupt { .. } => "corrupt",
            StreamError::Crashed { .. } => "crash_point",
            StreamError::Backpressure { .. } => "backpressure",
        }
    }

    pub(crate) fn io(what: &str, e: std::io::Error) -> StreamError {
        StreamError::Io { what: format!("{what}: {e}") }
    }
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::Io { what } => write!(f, "stream I/O failure: {what}"),
            StreamError::Corrupt { what } => write!(f, "stream state corrupt: {what}"),
            StreamError::Crashed { seam } => write!(f, "injected crash at seam {seam}"),
            StreamError::Backpressure { pending } => {
                write!(f, "ingest backpressure: store degraded with {pending} pending edits")
            }
        }
    }
}

impl std::error::Error for StreamError {}

/// Configuration for [`StreamStore::open`].
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Durability directory: holds `wal.bin` and `checkpoint.bin`.
    pub dir: PathBuf,
    /// Fixed node count (adjacency is `n_nodes × n_nodes`).
    pub n_nodes: usize,
    /// Fsync batching: acknowledge (sync) after this many appends. `1`
    /// means sync-per-op; larger values trade ack latency for throughput
    /// (unsynced ops are the only writes a crash may lose — and they
    /// were never acknowledged).
    pub sync_every: usize,
    /// Background compaction threshold (live-delta edits).
    pub compact_every: usize,
    /// Compactor supervision: panics tolerated before the store degrades.
    pub restart_budget: u32,
    /// Fault-injection schedule (inert by default).
    pub faults: Arc<FaultPlan>,
}

impl StreamConfig {
    pub fn new(dir: impl Into<PathBuf>, n_nodes: usize) -> StreamConfig {
        StreamConfig {
            dir: dir.into(),
            n_nodes,
            sync_every: 64,
            compact_every: 1024,
            restart_budget: 3,
            faults: Arc::new(FaultPlan::inert()),
        }
    }
}

/// The published unit: one compacted adjacency epoch. Raw and normalized
/// masters are `SharedMatrix` handles co-owned with the store's in-memory
/// state — publication is an `Arc` swap, never a matrix copy.
#[derive(Clone, Debug)]
pub struct StreamSnapshot {
    /// Raw-weight adjacency (CSR).
    pub raw: SharedMatrix,
    /// Row-normalized adjacency `D⁻¹A` (CSR) — what serving binds.
    pub norm: SharedMatrix,
    /// WAL sequence this snapshot covers: every op with `seq <= seq` is
    /// folded in; later ops live in the overlay until the next epoch.
    pub seq: u64,
    /// Monotone epoch counter (0 = the recovery-time snapshot).
    pub version: u64,
}

/// Mutable in-memory state: masters + overlays (one mutex; every section
/// is short and allocation-light).
pub(crate) struct MemState {
    /// Immutable raw-weight CSR master (covered through `master_seq`).
    pub(crate) master: SharedMatrix,
    /// Row-normalized master, kept in lockstep with `master`.
    pub(crate) norm: SharedMatrix,
    pub(crate) master_seq: u64,
    /// Write-optimized overlay receiving live ingest.
    pub(crate) live: DeltaOverlay,
    /// Overlay frozen by an in-flight (or crashed-and-retried) compaction,
    /// with the WAL seq it covers. Readers still merge it.
    pub(crate) frozen: Option<(DeltaOverlay, u64)>,
    /// Seq of the last op applied to `live`.
    pub(crate) applied_seq: u64,
    /// Published epoch counter.
    pub(crate) version: u64,
}

/// Compactor mailbox: `work` is notified on threshold crossings and
/// shutdown; `closed` ends the thread.
pub(crate) struct CompactSignal {
    pub(crate) state: Mutex<bool>, // closed?
    pub(crate) cv: Condvar,
}

pub(crate) struct StoreInner {
    pub(crate) cfg: StreamConfig,
    pub(crate) wal: Mutex<Wal>,
    pub(crate) state: Mutex<MemState>,
    pub(crate) published: EpochCell<StreamSnapshot>,
    /// Set (and never cleared) once the compactor exhausts its restart
    /// budget: ingest refuses with `Backpressure`, reads stay live.
    pub(crate) degraded: AtomicBool,
    pub(crate) compactions: AtomicU64,
    pub(crate) compactor_restarts: AtomicU64,
    pub(crate) signal: CompactSignal,
}

/// Point-in-time counters for reports/benches.
#[derive(Clone, Debug)]
pub struct StreamStats {
    /// Highest acknowledged (fsynced) WAL seq.
    pub acked: u64,
    /// Highest seq applied to the in-memory overlay.
    pub applied: u64,
    /// Live + frozen overlay edits not yet compacted.
    pub pending_edits: usize,
    pub compactions: u64,
    pub compactor_restarts: u64,
    pub degraded: bool,
    /// Version of the currently published snapshot.
    pub published_version: u64,
    /// Seq covered by the currently published snapshot.
    pub published_seq: u64,
}

/// The durable streaming-graph store (see the module docs for the full
/// protocol). Reads are wait-free against ingest on the published
/// snapshot, or one short lock on the merged row path; writes are
/// WAL-first and acknowledged only after fsync.
pub struct StreamStore {
    inner: Arc<StoreInner>,
    compactor: Option<std::thread::JoinHandle<()>>,
}

impl StreamStore {
    /// Open (or recover) the store at `cfg.dir`: load the checkpoint,
    /// truncate any torn WAL tail, replay the surviving records into a
    /// fresh overlay, and publish the recovered master as epoch 0. No
    /// background thread is started — call [`StreamStore::spawn_compactor`]
    /// for threshold-driven compaction, or drive [`StreamStore::compact_once`]
    /// explicitly (what the deterministic tests do).
    pub fn open(cfg: StreamConfig) -> Result<StreamStore, StreamError> {
        let rec = recovery::recover(&cfg)?;
        let master = SharedMatrix::from(rec.master);
        // The checkpoint decoder only checks framing (magic, CRC, indptr
        // endpoints); the full structural sweep — the same trust boundary
        // compaction applies to a freshly merged master — runs here, so a
        // decodable-but-inconsistent checkpoint is a typed error instead
        // of a panic later inside SpMM.
        master.validate().map_err(|e| StreamError::Corrupt {
            what: format!("recovered master failed validation: {e}"),
        })?;
        let norm = SharedMatrix::new(SparseMatrix::Csr(compact::row_normalize_full(
            master_csr(&master),
        )));
        let snapshot = StreamSnapshot {
            raw: master.clone(),
            norm: norm.clone(),
            seq: rec.master_seq,
            version: 0,
        };
        let inner = Arc::new(StoreInner {
            wal: Mutex::new(rec.wal),
            state: Mutex::new(MemState {
                master,
                norm,
                master_seq: rec.master_seq,
                live: rec.live,
                frozen: None,
                applied_seq: rec.applied_seq,
                version: 0,
            }),
            published: EpochCell::new(snapshot),
            degraded: AtomicBool::new(false),
            compactions: AtomicU64::new(0),
            compactor_restarts: AtomicU64::new(0),
            signal: CompactSignal { state: Mutex::new(false), cv: Condvar::new() },
            cfg,
        });
        Ok(StreamStore { inner, compactor: None })
    }

    /// Start the supervised background compactor (idempotent).
    pub fn spawn_compactor(&mut self) {
        if self.compactor.is_none() {
            self.compactor = Some(compact::spawn(Arc::clone(&self.inner)));
        }
    }

    /// Ingest one edge operation: WAL append (the durability point) and
    /// live-overlay apply run atomically under the state lock, then the
    /// batched fsync (per `sync_every`) runs outside it. Returns the op's
    /// WAL seq; it is **acknowledged** once [`StreamStore::acked`]
    /// reaches that seq (immediately so when `sync_every == 1`). If the
    /// append fails nothing was applied; if only the fsync fails the op
    /// is applied but unacknowledged — either way the caller may retry
    /// the same op safely (absolute semantics, so a retry can never
    /// double-apply).
    pub fn ingest(&self, op: EdgeOp) -> Result<u64, StreamError> {
        // ord: single flag, no ordering dependency with other writes — a
        // stale read only delays the backpressure rejection by one op.
        if self.inner.degraded.load(Ordering::Relaxed) {
            let st = lock_recover(&self.inner.state);
            let pending = st.live.edits() + st.frozen.as_ref().map_or(0, |(d, _)| d.edits());
            return Err(StreamError::Backpressure { pending });
        }
        op.check(self.inner.cfg.n_nodes)?;
        // Seq assignment and overlay apply must be one atomic step with
        // respect to compaction's freeze (which reads `applied_seq` under
        // this same lock): if op k could be appended but not yet applied
        // while op k+1 advanced `applied_seq`, a freeze at k+1 would
        // checkpoint a master missing op k and then drop its WAL record —
        // losing an acknowledged write across the next crash. Lock order
        // here is state → wal, the module's only nesting; no other path
        // acquires them nested, so no cycle.
        let (seq, edits) = {
            let mut st = lock_recover(&self.inner.state);
            let seq = {
                let mut wal = lock_recover(&self.inner.wal);
                wal.append_record(&op)?
            };
            st.live.apply(&op);
            st.applied_seq = st.applied_seq.max(seq);
            (seq, st.live.edits())
        };
        // The batched fsync stays off the state lock so merged-row reads
        // never wait on the disk.
        {
            let mut wal = lock_recover(&self.inner.wal);
            wal.sync_batch()?;
        }
        if edits >= self.inner.cfg.compact_every {
            self.inner.signal.cv.notify_all();
        }
        Ok(seq)
    }

    /// Force an fsync and return the acknowledged watermark.
    pub fn flush(&self) -> Result<u64, StreamError> {
        let mut wal = lock_recover(&self.inner.wal);
        wal.sync()
    }

    /// Highest acknowledged (durable) WAL seq.
    pub fn acked(&self) -> u64 {
        lock_recover(&self.inner.wal).acked()
    }

    /// Merged read of row `r`: master row patched by the frozen overlay,
    /// then the live overlay — the freshest consistent view, including
    /// ops not yet compacted (raw weights, sorted by column). Rows at or
    /// past `n_nodes` read as empty — the adjacency has no such row
    /// (ingest rejects out-of-bounds endpoints, so nothing can live there).
    pub fn read_row(&self, r: u32) -> Vec<(u32, f32)> {
        if r as usize >= self.inner.cfg.n_nodes {
            return Vec::new();
        }
        let st = lock_recover(&self.inner.state);
        let mut entries = delta::csr_row(master_csr(&st.master), r);
        if let Some((frozen, _)) = &st.frozen {
            frozen.patch_row(r, &mut entries);
        }
        st.live.patch_row(r, &mut entries);
        entries
    }

    /// The last published compacted snapshot (a co-owning handle; never
    /// blocks on ingest or compaction).
    pub fn published(&self) -> Arc<StreamSnapshot> {
        self.inner.published.load()
    }

    /// Run one full compaction cycle synchronously (freeze → merge →
    /// validate → checkpoint → publish → WAL drop). No-op when there is
    /// nothing to compact. The background compactor calls exactly this.
    pub fn compact_once(&self) -> Result<compact::CompactStats, StreamError> {
        compact::compact_once(&self.inner)
    }

    /// Has the compactor exhausted its restart budget? (Ingest refuses
    /// with [`StreamError::Backpressure`]; reads stay live.)
    pub fn degraded(&self) -> bool {
        // ord: monotone flag read for reporting; staleness is benign.
        self.inner.degraded.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> StreamStats {
        let published = self.inner.published.load();
        let (applied, pending) = {
            let st = lock_recover(&self.inner.state);
            (
                st.applied_seq,
                st.live.edits() + st.frozen.as_ref().map_or(0, |(d, _)| d.edits()),
            )
        };
        StreamStats {
            acked: self.acked(),
            applied,
            pending_edits: pending,
            // ord: monotone counters read for reporting only.
            compactions: self.inner.compactions.load(Ordering::Relaxed),
            // ord: monotone counters read for reporting only.
            compactor_restarts: self.inner.compactor_restarts.load(Ordering::Relaxed),
            degraded: self.degraded(),
            published_version: published.version,
            published_seq: published.seq,
        }
    }

    /// Node count this store serves.
    pub fn n_nodes(&self) -> usize {
        self.inner.cfg.n_nodes
    }
}

impl Drop for StreamStore {
    fn drop(&mut self) {
        if let Some(h) = self.compactor.take() {
            *lock_recover(&self.inner.signal.state) = true;
            self.inner.signal.cv.notify_all();
            let _ = h.join();
        }
    }
}

/// The store's masters are CSR by construction (recovery and compaction
/// only ever build `Csr`); this is the one place that assumption is spelled.
pub(crate) fn master_csr(m: &SharedMatrix) -> &Csr {
    match &**m {
        SparseMatrix::Csr(c) => c,
        other => unreachable!("stream master must be CSR, found {:?}", other.format()),
    }
}
