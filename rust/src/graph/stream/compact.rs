//! Background compaction: delta → fresh CSR master, under supervision
//! (DESIGN.md §Streaming-Durability).
//!
//! One cycle ([`compact_once`], also driven synchronously by tests):
//!
//! 1. **freeze** — swap the live overlay into the frozen slot under the
//!    state lock (or adopt a frozen overlay a crashed attempt left
//!    behind), then fsync the WAL so every frozen op is acknowledged;
//! 2. **merge** — outside any lock, patch each touched master row and
//!    build the new raw CSR via `Csr::replace_rows`; run the full
//!    `SparseMatrix::validate()` sweep (the compaction trust boundary);
//! 3. **renormalize** — recompute `D⁻¹A` rows for exactly the touched
//!    rows (row normalization is row-local, so untouched rows keep their
//!    bit-identical values);
//! 4. **checkpoint** — `util::fsio::PreparedWrite`: temp file + fsync +
//!    atomic rename (`CrashPoint` seam `checkpoint-rename` fires between
//!    the two halves);
//! 5. **publish** — swap masters under the state lock and
//!    `EpochCell::publish_arc` the new [`StreamSnapshot`] (seam
//!    `compact-publish` fires just before);
//! 6. **drop** — atomically rewrite the WAL keeping only records past
//!    the checkpointed seq.
//!
//! A crash or panic anywhere leaves the frozen overlay in place (step 1
//! clones it out rather than taking it), so reads keep merging it and
//! the next attempt resumes at step 2 — and every on-disk transition is
//! atomic, so recovery always sees a consistent checkpoint ∪ WAL.
//!
//! Supervision mirrors serve's workers: the background thread wraps each
//! cycle in `catch_unwind`; panics (and injected crash/I-O errors, which
//! a background thread cannot "die" from) are charged against
//! `restart_budget`, and past it the store **degrades** — ingest refuses
//! with [`StreamError::Backpressure`], reads keep serving the last
//! published snapshot. Every published epoch carries a fresh
//! `SharedMatrix` identity, which is exactly what forces `AdjEngine`'s
//! `ensure` to re-decide the format/schedule plan on the next bind (the
//! shape/drift anchors in `predictor::cache`).

use super::delta::csr_row;
use super::recovery::{checkpoint_path, encode_checkpoint};
use super::{master_csr, StoreInner, StreamError, StreamSnapshot};
use crate::sparse::{Csr, SharedMatrix, SparseMatrix};
use crate::util::fsio::PreparedWrite;
use crate::util::sync::{lock_recover, wait_timeout_recover};
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// What one compaction cycle did.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompactStats {
    /// Column-level overlay edits folded in (0 for a no-op cycle).
    pub merged_edits: usize,
    /// Master rows rebuilt (and renormalized).
    pub touched_rows: usize,
    /// WAL seq the new checkpoint covers.
    pub seq: u64,
    /// Published epoch version (unchanged for a no-op cycle).
    pub version: u64,
}

/// Row-normalize every row of `raw` (`D⁻¹A`): recovery's full rebuild.
/// Incremental compaction must agree bit-for-bit, so both paths share
/// [`normalize_row`] on identical raw inputs.
pub(crate) fn row_normalize_full(raw: &Csr) -> Csr {
    let mut out = raw.clone();
    for r in 0..out.rows {
        let span = out.indptr[r]..out.indptr[r + 1];
        let sum: f64 = out.vals[span.clone()].iter().map(|&w| w as f64).sum();
        if sum > 0.0 {
            for v in &mut out.vals[span] {
                *v = (*v as f64 / sum) as f32;
            }
        }
    }
    out
}

/// Normalize one raw row to sum 1. Rows with a non-positive sum pass
/// through unchanged — exactly what [`row_normalize_full`] does — so the
/// bit-identity argument between the incremental and full paths holds on
/// every input, not just the ingest-validated (strictly positive) domain.
fn normalize_row(entries: &[(u32, f32)]) -> Vec<(u32, f32)> {
    let sum: f64 = entries.iter().map(|&(_, w)| w as f64).sum();
    if sum <= 0.0 {
        return entries.to_vec();
    }
    entries.iter().map(|&(c, w)| (c, (w as f64 / sum) as f32)).collect()
}

/// One full compaction cycle (see module docs). Returns the stats of the
/// published epoch, or a no-op stats record when there was nothing to do.
pub(crate) fn compact_once(inner: &StoreInner) -> Result<CompactStats, StreamError> {
    // Panic seam for supervision tests (inert plans never fire).
    inner.cfg.faults.maybe_panic();

    // ── 1. freeze ────────────────────────────────────────────────────
    let (master, norm, frozen, frozen_seq) = {
        let mut st = lock_recover(&inner.state);
        if st.frozen.is_none() {
            if st.live.is_empty() {
                return Ok(CompactStats {
                    merged_edits: 0,
                    touched_rows: 0,
                    seq: st.master_seq,
                    version: st.version,
                });
            }
            let live = std::mem::take(&mut st.live);
            st.frozen = Some((live, st.applied_seq));
        }
        let (f, seq) = st.frozen.as_ref().expect("frozen set above");
        // Clone the overlay out (bounded by compact_every edits): the
        // original stays visible to readers — and survives — until the
        // cycle commits.
        (st.master.clone(), st.norm.clone(), f.clone(), *seq)
    };
    // Acknowledge everything we are about to fold in (checkpointing an
    // un-fsynced op would let ack regress across a crash).
    {
        let mut wal = lock_recover(&inner.wal);
        wal.sync()?;
    }

    // ── 2. merge + validate ──────────────────────────────────────────
    let raw = master_csr(&master);
    let mut new_rows: BTreeMap<u32, Vec<(u32, f32)>> = BTreeMap::new();
    for r in frozen.touched_rows() {
        let mut row = csr_row(raw, r);
        frozen.patch_row(r, &mut row);
        new_rows.insert(r, row);
    }
    let touched = new_rows.len();
    let new_raw = SharedMatrix::new(SparseMatrix::Csr(raw.replace_rows(&new_rows)));
    new_raw.validate().map_err(|e| StreamError::Corrupt {
        what: format!("compacted master failed validation: {e}"),
    })?;

    // ── 3. incremental renormalization ───────────────────────────────
    let norm_rows: BTreeMap<u32, Vec<(u32, f32)>> =
        new_rows.iter().map(|(&r, row)| (r, normalize_row(row))).collect();
    let new_norm = SharedMatrix::new(SparseMatrix::Csr(master_csr(&norm).replace_rows(&norm_rows)));

    // ── 4. checkpoint (temp file + atomic rename) ────────────────────
    inner
        .cfg
        .faults
        .maybe_io_error("checkpoint-write")
        .map_err(|e| StreamError::io("checkpoint write", e))?;
    let bytes = encode_checkpoint(master_csr(&new_raw), frozen_seq);
    let staged = PreparedWrite::prepare(&checkpoint_path(&inner.cfg.dir), &bytes)
        .map_err(|e| StreamError::io("checkpoint write", e))?;
    if inner.cfg.faults.maybe_crash("checkpoint-rename") {
        // Dropping `staged` discards the temp file; the old checkpoint
        // (or none) stays current and the WAL still holds everything.
        return Err(StreamError::Crashed { seam: "checkpoint-rename" });
    }
    staged.commit().map_err(|e| StreamError::io("checkpoint rename", e))?;

    // ── 5. publish ───────────────────────────────────────────────────
    if inner.cfg.faults.maybe_crash("compact-publish") {
        // The checkpoint is durable but unpublished: recovery rebuilds
        // from it and replays the (still intact) WAL tail past its seq.
        return Err(StreamError::Crashed { seam: "compact-publish" });
    }
    let snapshot = {
        let mut st = lock_recover(&inner.state);
        st.master = new_raw.clone();
        st.norm = new_norm.clone();
        st.master_seq = frozen_seq;
        st.frozen = None;
        st.version += 1;
        StreamSnapshot {
            raw: new_raw,
            norm: new_norm,
            seq: frozen_seq,
            version: st.version,
        }
    };
    let version = snapshot.version;
    inner.published.publish_arc(Arc::new(snapshot));

    // ── 6. drop compacted WAL records ────────────────────────────────
    {
        let mut wal = lock_recover(&inner.wal);
        wal.drop_through(frozen_seq)?;
    }
    // ord: monotone stats counter; readers only report it.
    inner.compactions.fetch_add(1, Ordering::Relaxed);
    Ok(CompactStats { merged_edits: frozen.edits(), touched_rows: touched, seq: frozen_seq, version })
}

/// Spawn the supervised compactor thread (threshold-driven; ends on
/// store drop or after degrading).
pub(crate) fn spawn(inner: Arc<StoreInner>) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("stream-compactor".into())
        .spawn(move || supervise(&inner))
        .expect("spawning the compactor thread")
}

fn should_compact(inner: &StoreInner) -> bool {
    let st = lock_recover(&inner.state);
    st.frozen.is_some() || st.live.edits() >= inner.cfg.compact_every
}

fn supervise(inner: &StoreInner) {
    let mut failures: u32 = 0;
    loop {
        // Park until signalled (threshold crossing / shutdown), with a
        // periodic poll so a quiet trickle still compacts eventually.
        {
            let mut closed = lock_recover(&inner.signal.state);
            loop {
                if *closed {
                    return;
                }
                if should_compact(inner) {
                    break;
                }
                let (g, _) =
                    wait_timeout_recover(&inner.signal.cv, closed, Duration::from_millis(25));
                closed = g;
            }
        }
        let attempt =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| compact_once(inner)));
        match attempt {
            Ok(Ok(_)) => {
                failures = 0; // a clean cycle refills the budget
                continue;
            }
            Ok(Err(e)) => {
                // In background mode an injected crash/I-O error cannot
                // actually kill the process; it is charged like a panic.
                eprintln!("stream-compactor: cycle failed ({e}); respawning");
            }
            Err(_) => {
                eprintln!("stream-compactor: cycle panicked; respawning");
            }
        }
        failures += 1;
        // ord: monotone stats counter; readers only report it.
        inner.compactor_restarts.fetch_add(1, Ordering::Relaxed);
        if failures > inner.cfg.restart_budget {
            // ord: SeqCst pairs with ingest's read — after this store,
            // no new ingest is admitted, while reads (EpochCell loads)
            // never consult the flag and stay live.
            inner.degraded.store(true, Ordering::SeqCst);
            eprintln!(
                "stream-compactor: restart budget ({}) exhausted; store degraded — \
                 ingest backpressures, reads stay live",
                inner.cfg.restart_budget
            );
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{StreamConfig, StreamStore};
    use super::*;
    use crate::graph::stream::wal::EdgeOp;
    use std::path::PathBuf;

    fn dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("gnn_spmm_compact").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn incremental_and_full_normalization_agree_bitwise() {
        // The same raw row through normalize_row (compaction's path) and
        // row_normalize_full (recovery's path) must match bit-for-bit —
        // that is the whole recovery-equivalence argument for norms.
        let entries = vec![(0u32, 0.3f32), (4, 1.7), (9, 0.125)];
        let raw = Csr {
            rows: 1,
            cols: 10,
            indptr: vec![0, 3],
            indices: entries.iter().map(|&(c, _)| c).collect(),
            vals: entries.iter().map(|&(_, w)| w).collect(),
        };
        let full = row_normalize_full(&raw);
        let inc = normalize_row(&entries);
        for (i, &(c, w)) in inc.iter().enumerate() {
            assert_eq!(full.indices[i], c);
            assert_eq!(full.vals[i].to_bits(), w.to_bits(), "col {c} diverged");
        }
    }

    #[test]
    fn normalize_row_handles_degenerate_rows() {
        assert!(normalize_row(&[]).is_empty());
        let one = normalize_row(&[(3, 2.5)]);
        assert_eq!(one, vec![(3, 1.0)]);
    }

    #[test]
    fn non_positive_sum_rows_pass_through_on_both_paths() {
        // Unreachable via ingest (weights are validated strictly positive)
        // but reachable from a recovered checkpoint; the incremental and
        // full paths must still agree bit-for-bit.
        let entries = vec![(1u32, 1.0f32), (2, -1.0)];
        let raw = Csr {
            rows: 1,
            cols: 4,
            indptr: vec![0, 2],
            indices: vec![1, 2],
            vals: vec![1.0, -1.0],
        };
        let full = row_normalize_full(&raw);
        let inc = normalize_row(&entries);
        assert_eq!(inc, entries, "non-positive sum leaves the row unchanged");
        assert_eq!(full.vals.len(), inc.len());
        for (i, &(_, w)) in inc.iter().enumerate() {
            assert_eq!(full.vals[i].to_bits(), w.to_bits());
        }
    }

    #[test]
    fn a_full_cycle_checkpoints_publishes_and_drops_the_wal() {
        let mut cfg = StreamConfig::new(dir("cycle"), 6);
        cfg.sync_every = 1;
        let store = StreamStore::open(cfg.clone()).unwrap();
        store.ingest(EdgeOp::Insert { src: 0, dst: 1, w: 2.0 }).unwrap();
        store.ingest(EdgeOp::Insert { src: 0, dst: 2, w: 2.0 }).unwrap();
        store.ingest(EdgeOp::Insert { src: 5, dst: 0, w: 1.0 }).unwrap();
        store.ingest(EdgeOp::Delete { src: 0, dst: 2 }).unwrap();

        let stats = store.compact_once().unwrap();
        assert_eq!(stats.touched_rows, 2, "rows 0 and 5");
        assert_eq!(stats.merged_edits, 3, "(0,1), (0,2), (5,0)");
        assert_eq!(stats.seq, 4);
        assert_eq!(stats.version, 1);

        let snap = store.published();
        assert_eq!(snap.version, 1);
        assert_eq!(snap.seq, 4);
        let raw = master_csr(&snap.raw);
        assert_eq!(raw.row_entries(0).collect::<Vec<_>>(), vec![(1, 2.0)]);
        assert_eq!(raw.row_entries(5).collect::<Vec<_>>(), vec![(0, 1.0)]);
        let norm = master_csr(&snap.norm);
        assert_eq!(norm.row_entries(0).collect::<Vec<_>>(), vec![(1, 1.0)]);

        // A second cycle with nothing pending is a published no-op.
        let stats2 = store.compact_once().unwrap();
        assert_eq!(stats2.merged_edits, 0);
        assert_eq!(stats2.version, 1, "no-op cycles do not publish");

        // The WAL is fully compacted: reopening replays nothing but the
        // checkpoint still carries every acknowledged op.
        drop(store);
        let store = StreamStore::open(cfg).unwrap();
        assert_eq!(store.acked(), 4);
        assert_eq!(store.read_row(0), vec![(1, 2.0)]);
        assert_eq!(store.read_row(5), vec![(0, 1.0)]);
    }

    #[test]
    fn a_crashed_checkpoint_rename_keeps_the_frozen_overlay_for_retry() {
        let mut cfg = StreamConfig::new(dir("retry"), 4);
        cfg.sync_every = 1;
        // The CrashPoint lane counts every seam reached: the ingest below
        // passes `wal-append` (ordinal 1), so ordinal 2 is the compaction's
        // `checkpoint-rename` seam.
        cfg.faults = Arc::new(
            crate::testing::FaultPlan::inert().script(crate::testing::FaultKind::CrashPoint, &[2]),
        );
        let store = StreamStore::open(cfg).unwrap();
        store.ingest(EdgeOp::Insert { src: 1, dst: 2, w: 3.0 }).unwrap();
        let err = store.compact_once().unwrap_err();
        assert_eq!(err.kind(), "crash_point");
        // Reads still see the op (frozen overlay stayed in place) …
        assert_eq!(store.read_row(1), vec![(2, 3.0)]);
        // … and the retry folds it in (the crash ordinal is consumed).
        let stats = store.compact_once().unwrap();
        assert_eq!(stats.merged_edits, 1);
        assert_eq!(store.published().version, 1);
    }
}
