//! Checksummed append-only write-ahead log (DESIGN.md
//! §Streaming-Durability).
//!
//! Record framing, all little-endian:
//!
//! ```text
//! [ len: u32 ][ crc: u32 ][ seq: u64 ][ payload: len-8 bytes ]
//! ```
//!
//! `len` counts the seq + payload bytes; `crc` is CRC-32 (IEEE) over
//! exactly those bytes. The payload is one [`EdgeOp`]:
//! `[tag: u8][src: u32][dst: u32][w: f32-bits]` — 13 bytes, `w = 0` for
//! deletes. Sequence numbers are assigned densely at append time and are
//! authoritative on disk: after a checkpoint drops the compacted prefix,
//! the surviving records still carry their original seqs.
//!
//! Durability contract: an op is **acknowledged** only once [`Wal::sync`]
//! has covered its record (appends batch `sync_every` records per fsync).
//! A crash can therefore lose only unacknowledged tail records — and can
//! tear the last record mid-write. [`Wal::open`] scans the file and
//! truncates at the first frame whose length or CRC fails; the
//! single-crash model means a bad frame is always the torn tail, never a
//! mid-file flip (which would indicate real media corruption — also
//! caught, also truncated, and the checkpoint still bounds the loss to
//! unacknowledged ops).

use super::StreamError;
use crate::testing::FaultPlan;
use crate::util::fsio::{crc32, AppendFile, PreparedWrite};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// One streamed edge operation. All three are **absolute**: `Insert` and
/// `Reweight` both upsert the edge's weight (inserting an existing edge
/// reweights it; reweighting an absent edge inserts it — the two tags
/// exist so intent survives in the log), `Delete` removes it outright.
/// Absolute semantics are what make recovery replay idempotent: applying
/// any suffix of the stream twice converges to the same adjacency.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EdgeOp {
    Insert { src: u32, dst: u32, w: f32 },
    Delete { src: u32, dst: u32 },
    Reweight { src: u32, dst: u32, w: f32 },
}

impl EdgeOp {
    pub fn src(&self) -> u32 {
        match *self {
            EdgeOp::Insert { src, .. } | EdgeOp::Delete { src, .. } | EdgeOp::Reweight { src, .. } => src,
        }
    }

    pub fn dst(&self) -> u32 {
        match *self {
            EdgeOp::Insert { dst, .. } | EdgeOp::Delete { dst, .. } | EdgeOp::Reweight { dst, .. } => dst,
        }
    }

    /// Validate against the store's node bounds and weight domain
    /// (finite, strictly positive — `D⁻¹A` normalization needs
    /// nonnegative row sums, and a zero weight is a delete in disguise).
    pub fn check(&self, n: usize) -> Result<(), StreamError> {
        let (s, d) = (self.src() as usize, self.dst() as usize);
        if s >= n || d >= n {
            return Err(StreamError::Corrupt {
                what: format!("edge ({s}, {d}) out of bounds for {n} nodes"),
            });
        }
        if let EdgeOp::Insert { w, .. } | EdgeOp::Reweight { w, .. } = *self {
            if !w.is_finite() || w <= 0.0 {
                return Err(StreamError::Corrupt {
                    what: format!("edge weight {w} is not finite-positive"),
                });
            }
        }
        Ok(())
    }

    fn tag(&self) -> u8 {
        match self {
            EdgeOp::Insert { .. } => 0,
            EdgeOp::Delete { .. } => 1,
            EdgeOp::Reweight { .. } => 2,
        }
    }

    fn weight_bits(&self) -> u32 {
        match *self {
            EdgeOp::Insert { w, .. } | EdgeOp::Reweight { w, .. } => w.to_bits(),
            EdgeOp::Delete { .. } => 0,
        }
    }

    fn encode_payload(&self, buf: &mut Vec<u8>) {
        buf.push(self.tag());
        buf.extend_from_slice(&self.src().to_le_bytes());
        buf.extend_from_slice(&self.dst().to_le_bytes());
        buf.extend_from_slice(&self.weight_bits().to_le_bytes());
    }

    fn decode_payload(bytes: &[u8]) -> Option<EdgeOp> {
        if bytes.len() != PAYLOAD_LEN {
            return None;
        }
        let src = u32::from_le_bytes(bytes[1..5].try_into().ok()?);
        let dst = u32::from_le_bytes(bytes[5..9].try_into().ok()?);
        let w = f32::from_bits(u32::from_le_bytes(bytes[9..13].try_into().ok()?));
        match bytes[0] {
            0 => Some(EdgeOp::Insert { src, dst, w }),
            1 => Some(EdgeOp::Delete { src, dst }),
            2 => Some(EdgeOp::Reweight { src, dst, w }),
            _ => None,
        }
    }
}

const PAYLOAD_LEN: usize = 13;
const HEADER_LEN: usize = 8; // len + crc
#[cfg(test)]
const RECORD_LEN: usize = HEADER_LEN + 8 + PAYLOAD_LEN; // + seq

fn encode_record(seq: u64, op: &EdgeOp) -> Vec<u8> {
    let mut body = Vec::with_capacity(8 + PAYLOAD_LEN);
    body.extend_from_slice(&seq.to_le_bytes());
    op.encode_payload(&mut body);
    let mut rec = Vec::with_capacity(HEADER_LEN + body.len());
    rec.extend_from_slice(&(body.len() as u32).to_le_bytes());
    rec.extend_from_slice(&crc32(&body).to_le_bytes());
    rec.extend_from_slice(&body);
    rec
}

/// Scan `bytes` into `(seq, op, frame_end_offset)` triples, stopping at
/// the first torn/corrupt frame. Returns the records plus the byte
/// offset of the last good frame's end (the truncation point).
fn scan(bytes: &[u8]) -> (Vec<(u64, EdgeOp)>, u64) {
    let mut records = Vec::new();
    let mut off = 0usize;
    while off + HEADER_LEN <= bytes.len() {
        let len = u32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]])
            as usize;
        let crc = u32::from_le_bytes([
            bytes[off + 4],
            bytes[off + 5],
            bytes[off + 6],
            bytes[off + 7],
        ]);
        let body_start = off + HEADER_LEN;
        if len < 8 || len > 1 << 20 || body_start + len > bytes.len() {
            break; // torn tail (or nonsense length)
        }
        let body = &bytes[body_start..body_start + len];
        if crc32(body) != crc {
            break; // torn or corrupt frame
        }
        let seq = u64::from_le_bytes(body[..8].try_into().expect("8-byte slice"));
        let Some(op) = EdgeOp::decode_payload(&body[8..]) else {
            break; // valid CRC but unknown encoding: stop conservatively
        };
        records.push((seq, op));
        off = body_start + len;
    }
    (records, off as u64)
}

/// The write-ahead log handle (one per store; callers serialize through
/// the store's mutex).
#[derive(Debug)]
pub struct Wal {
    file: AppendFile,
    path: PathBuf,
    /// Seq the next append will carry.
    next_seq: u64,
    /// Highest seq appended (not necessarily durable).
    appended_seq: u64,
    /// Highest seq covered by an fsync — the acknowledged watermark.
    synced_seq: u64,
    /// Appends since the last fsync.
    unsynced: usize,
    sync_every: usize,
    /// Byte length of the last known-good frame end; a failed append is
    /// healed back to this before the next write.
    good_len: u64,
    /// Torn bytes past `good_len` awaiting heal (after an injected short
    /// write whose truncation must wait so a crash-now leaves the tear
    /// for recovery to find).
    torn: bool,
    faults: Arc<FaultPlan>,
}

impl Wal {
    /// Open the log, truncating any torn tail, and return the surviving
    /// records for replay. `base_seq` seeds numbering when the log is
    /// empty (the checkpoint's covered seq).
    pub fn open(
        path: &Path,
        sync_every: usize,
        base_seq: u64,
        faults: Arc<FaultPlan>,
    ) -> Result<(Wal, Vec<(u64, EdgeOp)>), StreamError> {
        let mut file =
            AppendFile::open_append(path).map_err(|e| StreamError::io("wal open", e))?;
        let bytes = file.read_all().map_err(|e| StreamError::io("wal scan", e))?;
        let (records, good_len) = scan(&bytes);
        if good_len < file.len() {
            file.truncate_to(good_len).map_err(|e| StreamError::io("wal tail truncation", e))?;
            file.sync().map_err(|e| StreamError::io("wal tail truncation sync", e))?;
        }
        let last_seq = records.last().map(|&(s, _)| s).unwrap_or(0).max(base_seq);
        let wal = Wal {
            file,
            path: path.to_path_buf(),
            next_seq: last_seq + 1,
            appended_seq: last_seq,
            // Everything that survived the scan is on disk by definition.
            synced_seq: last_seq,
            unsynced: 0,
            sync_every: sync_every.max(1),
            good_len,
            torn: false,
            faults,
        };
        Ok((wal, records))
    }

    /// Append one op, batching fsyncs per `sync_every`. Returns the
    /// record's seq. Exactly [`Wal::append_record`] followed by
    /// [`Wal::sync_batch`] — callers that must not sit on other locks
    /// across a disk sync (ingest holds the store's state lock around
    /// the append) drive the two halves separately.
    pub fn append(&mut self, op: &EdgeOp) -> Result<u64, StreamError> {
        let seq = self.append_record(op)?;
        self.sync_batch()?;
        Ok(seq)
    }

    /// Append one op **without** the batched-fsync step (buffered in the
    /// OS page cache until a sync). Returns the record's seq. Fault seams
    /// (DESIGN.md §Streaming-Durability): `IoError` fails before any byte
    /// lands; `ShortWrite` lands a torn prefix and reports failure
    /// (healed lazily, found by recovery if the process dies first);
    /// `CrashPoint` tears the record and declares the store dead.
    pub fn append_record(&mut self, op: &EdgeOp) -> Result<u64, StreamError> {
        if self.torn {
            // Heal the previous failed append before writing anything new.
            self.file
                .truncate_to(self.good_len)
                .map_err(|e| StreamError::io("wal heal", e))?;
            self.torn = false;
        }
        self.faults.maybe_io_error("wal-append").map_err(|e| StreamError::io("wal append", e))?;
        let seq = self.next_seq;
        let rec = encode_record(seq, op);
        if self.faults.maybe_crash("wal-append") {
            // Simulated death mid-write: half the record reaches the file
            // and nobody heals it — recovery's torn-tail scan must.
            let _ = self.file.append(&rec[..rec.len() / 2]);
            return Err(StreamError::Crashed { seam: "wal-append" });
        }
        if let Some(k) = self.faults.maybe_short_write(rec.len()) {
            let _ = self.file.append(&rec[..k]);
            self.torn = true;
            return Err(StreamError::Io {
                what: format!("wal append: short write ({k}/{} bytes)", rec.len()),
            });
        }
        if let Err(e) = self.file.append(&rec) {
            // Real partial write: heal eagerly; if that fails too, the
            // torn flag defers it to the next append / recovery.
            self.torn = self.file.truncate_to(self.good_len).is_err();
            return Err(StreamError::io("wal append", e));
        }
        self.good_len = self.file.len();
        self.next_seq += 1;
        self.appended_seq = seq;
        self.unsynced += 1;
        Ok(seq)
    }

    /// Fsync iff the `sync_every` batching threshold has been reached;
    /// returns the acknowledged watermark either way.
    pub fn sync_batch(&mut self) -> Result<u64, StreamError> {
        if self.unsynced >= self.sync_every {
            self.sync()
        } else {
            Ok(self.synced_seq)
        }
    }

    /// Fsync everything appended so far; advances and returns the
    /// acknowledged watermark.
    pub fn sync(&mut self) -> Result<u64, StreamError> {
        if self.unsynced > 0 {
            self.file.sync().map_err(|e| StreamError::io("wal sync", e))?;
            self.synced_seq = self.appended_seq;
            self.unsynced = 0;
        }
        Ok(self.synced_seq)
    }

    /// Highest acknowledged seq.
    pub fn acked(&self) -> u64 {
        self.synced_seq
    }

    /// Seqs are dense; number of live records is derivable for tests.
    pub fn appended(&self) -> u64 {
        self.appended_seq
    }

    /// Drop records covered by a checkpoint (`seq <= through`), keeping
    /// the tail. Crash-safe rewrite: surviving frames are written to a
    /// temp file and atomically renamed over the log (`util::fsio`), so a
    /// crash leaves either the old complete log or the new complete log.
    /// Callers must have synced through `through` first (the compaction
    /// protocol does: freeze syncs before checkpointing).
    pub fn drop_through(&mut self, through: u64) -> Result<(), StreamError> {
        debug_assert!(self.synced_seq >= through, "checkpointed ops must be acknowledged");
        let bytes = self.file.read_all().map_err(|e| StreamError::io("wal rewrite scan", e))?;
        let (records, _) = scan(&bytes);
        let mut kept = Vec::new();
        for &(seq, ref op) in &records {
            if seq > through {
                kept.extend_from_slice(&encode_record(seq, op));
            }
        }
        let staged = PreparedWrite::prepare(&self.path, &kept)
            .map_err(|e| StreamError::io("wal rewrite", e))?;
        staged.commit().map_err(|e| StreamError::io("wal rewrite rename", e))?;
        // The old handle points at the unlinked inode; reopen the new log.
        self.file = AppendFile::open_append(&self.path)
            .map_err(|e| StreamError::io("wal reopen", e))?;
        self.good_len = self.file.len();
        self.torn = false;
        // Everything appended so far is durable now: ops <= `through`
        // live in the just-committed checkpoint, and the kept tail was
        // fsynced by PreparedWrite — advance the ack watermark to match.
        self.synced_seq = self.appended_seq;
        self.unsynced = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::FaultKind;

    fn dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("gnn_spmm_wal").join(name);
        // A fresh directory per test: stale logs would change replay.
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn inert() -> Arc<FaultPlan> {
        Arc::new(FaultPlan::inert())
    }

    #[test]
    fn append_sync_reopen_replays_everything() {
        let path = dir("roundtrip").join("wal.bin");
        let ops = vec![
            EdgeOp::Insert { src: 0, dst: 1, w: 1.5 },
            EdgeOp::Delete { src: 0, dst: 1 },
            EdgeOp::Reweight { src: 3, dst: 2, w: 0.25 },
        ];
        {
            let (mut wal, replay) = Wal::open(&path, 1, 0, inert()).unwrap();
            assert!(replay.is_empty());
            for (i, op) in ops.iter().enumerate() {
                assert_eq!(wal.append(op).unwrap(), i as u64 + 1);
            }
            assert_eq!(wal.acked(), 3, "sync_every=1 acknowledges per-op");
        }
        let (wal, replay) = Wal::open(&path, 1, 0, inert()).unwrap();
        assert_eq!(replay.iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(replay.iter().map(|&(_, op)| op).collect::<Vec<_>>(), ops);
        assert_eq!(wal.acked(), 3);
    }

    #[test]
    fn sync_batching_delays_the_ack_watermark() {
        let path = dir("batch").join("wal.bin");
        let (mut wal, _) = Wal::open(&path, 3, 0, inert()).unwrap();
        wal.append(&EdgeOp::Insert { src: 0, dst: 1, w: 1.0 }).unwrap();
        wal.append(&EdgeOp::Insert { src: 1, dst: 2, w: 1.0 }).unwrap();
        assert_eq!(wal.acked(), 0, "below the batch: nothing acknowledged");
        wal.append(&EdgeOp::Insert { src: 2, dst: 3, w: 1.0 }).unwrap();
        assert_eq!(wal.acked(), 3, "batch boundary fsyncs");
        wal.append(&EdgeOp::Insert { src: 3, dst: 4, w: 1.0 }).unwrap();
        assert_eq!(wal.sync().unwrap(), 4, "explicit flush advances the watermark");
    }

    #[test]
    fn torn_tail_is_truncated_on_open() {
        let path = dir("torn").join("wal.bin");
        {
            let (mut wal, _) = Wal::open(&path, 1, 0, inert()).unwrap();
            for i in 0..5u32 {
                wal.append(&EdgeOp::Insert { src: i, dst: i + 1, w: 1.0 }).unwrap();
            }
        }
        // Tear the last record in half (as a mid-append crash would).
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(bytes.len(), 5 * RECORD_LEN);
        // lint: allow(durability-io) -- test simulates the torn tail a crash leaves
        std::fs::write(&path, &bytes[..bytes.len() - RECORD_LEN / 2]).unwrap();
        let (wal, replay) = Wal::open(&path, 1, 0, inert()).unwrap();
        assert_eq!(replay.len(), 4, "the four intact records survive");
        assert_eq!(wal.acked(), 4);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 4 * RECORD_LEN as u64);
    }

    #[test]
    fn corrupt_mid_file_stops_the_scan_conservatively() {
        let path = dir("flip").join("wal.bin");
        {
            let (mut wal, _) = Wal::open(&path, 1, 0, inert()).unwrap();
            for i in 0..4u32 {
                wal.append(&EdgeOp::Insert { src: i, dst: i + 1, w: 1.0 }).unwrap();
            }
        }
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[RECORD_LEN + HEADER_LEN + 9] ^= 0xFF; // flip a payload byte of record 2
        // lint: allow(durability-io) -- test plants mid-file corruption for the scan
        std::fs::write(&path, &bytes).unwrap();
        let (_, replay) = Wal::open(&path, 1, 0, inert()).unwrap();
        assert_eq!(replay.len(), 1, "scan stops at the first bad CRC");
    }

    #[test]
    fn short_write_fails_the_op_and_heals_on_the_next_append() {
        let path = dir("short").join("wal.bin");
        let plan = Arc::new(FaultPlan::inert().script(FaultKind::ShortWrite, &[1]));
        let (mut wal, _) = Wal::open(&path, 1, 0, plan).unwrap();
        wal.append(&EdgeOp::Insert { src: 0, dst: 1, w: 1.0 }).unwrap();
        let err = wal.append(&EdgeOp::Insert { src: 1, dst: 2, w: 1.0 }).unwrap_err();
        assert_eq!(err.kind(), "io");
        // The torn bytes are really on disk until the next append heals.
        assert!(std::fs::metadata(&path).unwrap().len() > RECORD_LEN as u64);
        let seq = wal.append(&EdgeOp::Insert { src: 1, dst: 2, w: 1.0 }).unwrap();
        assert_eq!(seq, 2, "a failed append never consumed its seq — numbering stays dense");
        drop(wal);
        let (_, replay) = Wal::open(&path, 1, 0, inert()).unwrap();
        assert_eq!(replay.iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn crash_point_tears_the_record_for_recovery_to_truncate() {
        let path = dir("crash").join("wal.bin");
        let plan = Arc::new(FaultPlan::inert().script(FaultKind::CrashPoint, &[1]));
        let (mut wal, _) = Wal::open(&path, 1, 0, plan).unwrap();
        wal.append(&EdgeOp::Insert { src: 0, dst: 1, w: 1.0 }).unwrap();
        let err = wal.append(&EdgeOp::Insert { src: 1, dst: 2, w: 1.0 }).unwrap_err();
        assert_eq!(err.kind(), "crash_point");
        drop(wal); // the simulated process death
        let (wal, replay) = Wal::open(&path, 1, 0, inert()).unwrap();
        assert_eq!(replay.len(), 1, "acknowledged record survives, torn one is gone");
        assert_eq!(wal.acked(), 1);
    }

    #[test]
    fn drop_through_keeps_only_the_tail_and_preserves_seqs() {
        let path = dir("dropthru").join("wal.bin");
        let (mut wal, _) = Wal::open(&path, 1, 0, inert()).unwrap();
        for i in 0..6u32 {
            wal.append(&EdgeOp::Insert { src: i, dst: i + 1, w: 1.0 }).unwrap();
        }
        wal.drop_through(4).unwrap();
        // Appends continue with the global numbering.
        assert_eq!(wal.append(&EdgeOp::Delete { src: 0, dst: 1 }).unwrap(), 7);
        drop(wal);
        let (_, replay) = Wal::open(&path, 1, 4, inert()).unwrap();
        assert_eq!(replay.iter().map(|&(s, _)| s).collect::<Vec<_>>(), vec![5, 6, 7]);
    }

    #[test]
    fn op_check_rejects_bad_endpoints_and_weights() {
        assert!(EdgeOp::Insert { src: 0, dst: 9, w: 1.0 }.check(10).is_ok());
        assert_eq!(EdgeOp::Insert { src: 0, dst: 10, w: 1.0 }.check(10).unwrap_err().kind(), "corrupt");
        assert_eq!(EdgeOp::Insert { src: 0, dst: 1, w: 0.0 }.check(10).unwrap_err().kind(), "corrupt");
        assert_eq!(
            EdgeOp::Reweight { src: 0, dst: 1, w: f32::NAN }.check(10).unwrap_err().kind(),
            "corrupt"
        );
        assert!(EdgeOp::Delete { src: 9, dst: 9 }.check(10).is_ok());
    }
}
