//! Startup recovery: checkpoint load + WAL-tail replay (DESIGN.md
//! §Streaming-Durability).
//!
//! The recovery invariant this module owns: **every acknowledged write
//! survives any single crash point**. It holds by construction —
//!
//! * an op is acknowledged only after its WAL record is fsynced;
//! * the checkpoint is written temp-file + atomic rename, so it is
//!   always a complete file covering some seq `S`;
//! * WAL records with `seq <= S` are dropped only *after* the rename
//!   lands (and the drop itself is an atomic rewrite);
//!
//! so at every crash point, `checkpoint ∪ WAL` contains every
//! acknowledged op exactly once-or-more, and replay (absolute ops,
//! idempotent) reconstructs the acknowledged state bit-identically.
//!
//! Checkpoint file layout (little-endian):
//!
//! ```text
//! [ magic: b"GNNSTRM1" ][ seq: u64 ][ rows: u64 ][ cols: u64 ][ nnz: u64 ]
//! [ indptr: (rows+1) × u64 ][ indices: nnz × u32 ][ vals: nnz × f32-bits ]
//! [ crc: u32 over everything above ]
//! ```
//!
//! Binary, not JSON: values round-trip by bit pattern (the equivalence
//! tests compare reads bit-identically) and the CRC makes a flipped byte
//! a typed `Corrupt` error instead of a silently wrong graph. A corrupt
//! checkpoint is a **hard error**, not a cold start: unlike the decision
//! cache (a performance hint), the checkpoint holds acknowledged data —
//! quietly discarding it would break the invariant above.

use super::delta::DeltaOverlay;
use super::wal::Wal;
use super::{StreamConfig, StreamError};
use crate::sparse::Csr;
use crate::util::fsio::crc32;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const MAGIC: &[u8; 8] = b"GNNSTRM1";

pub(crate) fn wal_path(dir: &Path) -> PathBuf {
    dir.join("wal.bin")
}

pub(crate) fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join("checkpoint.bin")
}

/// Serialize a raw CSR master covered through `seq`.
pub(crate) fn encode_checkpoint(master: &Csr, seq: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        MAGIC.len() + 32 + (master.rows + 1) * 8 + master.nnz() * 8 + 4,
    );
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&seq.to_le_bytes());
    out.extend_from_slice(&(master.rows as u64).to_le_bytes());
    out.extend_from_slice(&(master.cols as u64).to_le_bytes());
    out.extend_from_slice(&(master.nnz() as u64).to_le_bytes());
    for &p in &master.indptr {
        out.extend_from_slice(&(p as u64).to_le_bytes());
    }
    for &i in &master.indices {
        out.extend_from_slice(&i.to_le_bytes());
    }
    for &v in &master.vals {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

fn read_u64(bytes: &[u8], off: &mut usize) -> Option<u64> {
    let v = bytes.get(*off..*off + 8)?;
    *off += 8;
    Some(u64::from_le_bytes(v.try_into().ok()?))
}

fn read_u32(bytes: &[u8], off: &mut usize) -> Option<u32> {
    let v = bytes.get(*off..*off + 4)?;
    *off += 4;
    Some(u32::from_le_bytes(v.try_into().ok()?))
}

/// Parse and verify a checkpoint. Structural errors (bad magic, bad CRC,
/// truncated, inconsistent counts) are `Corrupt`.
pub(crate) fn decode_checkpoint(bytes: &[u8]) -> Result<(Csr, u64), StreamError> {
    let corrupt = |what: &str| StreamError::Corrupt { what: format!("checkpoint: {what}") };
    if bytes.len() < MAGIC.len() + 32 + 8 + 4 || &bytes[..MAGIC.len()] != MAGIC {
        return Err(corrupt("missing or short magic header"));
    }
    let (body, tail) = bytes.split_at(bytes.len() - 4);
    let stored_crc = u32::from_le_bytes(tail.try_into().expect("4-byte tail"));
    if crc32(body) != stored_crc {
        return Err(corrupt("CRC mismatch"));
    }
    let mut off = MAGIC.len();
    let seq = read_u64(body, &mut off).ok_or_else(|| corrupt("truncated header"))?;
    let rows = read_u64(body, &mut off).ok_or_else(|| corrupt("truncated header"))? as usize;
    let cols = read_u64(body, &mut off).ok_or_else(|| corrupt("truncated header"))? as usize;
    let nnz = read_u64(body, &mut off).ok_or_else(|| corrupt("truncated header"))? as usize;
    let expected = MAGIC.len() + 32 + (rows + 1) * 8 + nnz * 8;
    if body.len() != expected {
        return Err(corrupt("body length disagrees with header counts"));
    }
    let mut indptr = Vec::with_capacity(rows + 1);
    for _ in 0..rows + 1 {
        indptr.push(read_u64(body, &mut off).ok_or_else(|| corrupt("truncated indptr"))? as usize);
    }
    let mut indices = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        indices.push(read_u32(body, &mut off).ok_or_else(|| corrupt("truncated indices"))?);
    }
    let mut vals = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        vals.push(f32::from_bits(
            read_u32(body, &mut off).ok_or_else(|| corrupt("truncated values"))?,
        ));
    }
    if indptr.first() != Some(&0) || indptr.last() != Some(&nnz) {
        return Err(corrupt("indptr endpoints disagree with nnz"));
    }
    Ok((Csr { rows, cols, indptr, indices, vals }, seq))
}

/// Everything [`super::StreamStore::open`] needs to resume.
pub(crate) struct Recovered {
    pub(crate) master: Csr,
    /// Seq the checkpoint covers (0 when starting fresh).
    pub(crate) master_seq: u64,
    pub(crate) wal: Wal,
    /// Replayed overlay of every surviving op past the checkpoint.
    pub(crate) live: DeltaOverlay,
    /// Highest recovered seq (`>= master_seq`).
    pub(crate) applied_seq: u64,
}

/// Load checkpoint + WAL tail. Torn WAL tails are truncated (expected
/// crash artifact); a corrupt checkpoint is a hard `Corrupt` error (see
/// module docs). The full structural `validate()` sweep over the
/// recovered master runs in `StreamStore::open`, at the same trust
/// boundary compaction uses.
pub(crate) fn recover(cfg: &StreamConfig) -> Result<Recovered, StreamError> {
    let ck_path = checkpoint_path(&cfg.dir);
    let (master, master_seq) = match std::fs::read(&ck_path) {
        Ok(bytes) => decode_checkpoint(&bytes)?,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => (
            Csr {
                rows: cfg.n_nodes,
                cols: cfg.n_nodes,
                indptr: vec![0; cfg.n_nodes + 1],
                indices: Vec::new(),
                vals: Vec::new(),
            },
            0,
        ),
        Err(e) => return Err(StreamError::io("checkpoint read", e)),
    };
    if master.rows != cfg.n_nodes || master.cols != cfg.n_nodes {
        return Err(StreamError::Corrupt {
            what: format!(
                "checkpoint is {}×{} but the store serves {} nodes",
                master.rows, master.cols, cfg.n_nodes
            ),
        });
    }
    let (wal, records) =
        Wal::open(&wal_path(&cfg.dir), cfg.sync_every, master_seq, Arc::clone(&cfg.faults))?;
    let mut live = DeltaOverlay::new();
    let mut applied_seq = master_seq;
    for (seq, op) in records {
        if seq <= master_seq {
            // Already folded into the checkpoint (a crash between the
            // checkpoint rename and the WAL drop leaves such records);
            // skipping is exact because the checkpoint covers them.
            continue;
        }
        op.check(cfg.n_nodes)?;
        live.apply(&op);
        applied_seq = applied_seq.max(seq);
    }
    Ok(Recovered { master, master_seq, wal, live, applied_seq })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    fn sample() -> Csr {
        Csr::from_coo(&Coo::from_triples(
            5,
            5,
            vec![(0, 1, 1.5), (2, 0, -0.25), (2, 4, 3.0), (4, 4, 1.0)],
        ))
    }

    #[test]
    fn checkpoint_round_trips_bit_identically() {
        let m = sample();
        let bytes = encode_checkpoint(&m, 42);
        let (back, seq) = decode_checkpoint(&bytes).unwrap();
        assert_eq!(seq, 42);
        assert_eq!(back, m);
        // f32 payloads survive by bit pattern, not by decimal text.
        assert_eq!(back.vals[1].to_bits(), (-0.25f32).to_bits());
    }

    #[test]
    fn corrupt_checkpoints_are_typed_errors() {
        let m = sample();
        let good = encode_checkpoint(&m, 7);
        // Truncated.
        assert_eq!(decode_checkpoint(&good[..good.len() - 9]).unwrap_err().kind(), "corrupt");
        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert_eq!(decode_checkpoint(&bad).unwrap_err().kind(), "corrupt");
        // Flipped value byte defeats the CRC.
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 10] ^= 0x01;
        assert_eq!(decode_checkpoint(&bad).unwrap_err().kind(), "corrupt");
        // Empty file.
        assert_eq!(decode_checkpoint(&[]).unwrap_err().kind(), "corrupt");
    }

    #[test]
    fn structurally_bad_checkpoints_fail_open_not_spmm() {
        use super::super::{StreamConfig, StreamStore};
        let dir = std::env::temp_dir().join("gnn_spmm_recovery").join("badck");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Decodes fine (magic, CRC, indptr endpoints all pass) but the
        // column index is out of bounds — only the full validate() sweep
        // in StreamStore::open catches it, as a typed Corrupt error.
        let bad =
            Csr { rows: 3, cols: 3, indptr: vec![0, 1, 1, 1], indices: vec![7], vals: vec![1.0] };
        crate::util::fsio::atomic_write(&checkpoint_path(&dir), &encode_checkpoint(&bad, 5))
            .unwrap();
        // (match, not unwrap_err: StreamStore has no Debug impl)
        let err = match StreamStore::open(StreamConfig::new(dir, 3)) {
            Ok(_) => panic!("structurally bad checkpoint must not open"),
            Err(e) => e,
        };
        assert_eq!(err.kind(), "corrupt");
    }

    #[test]
    fn empty_matrix_checkpoints_round_trip() {
        let m = Csr { rows: 3, cols: 3, indptr: vec![0, 0, 0, 0], indices: vec![], vals: vec![] };
        let (back, seq) = decode_checkpoint(&encode_checkpoint(&m, 0)).unwrap();
        assert_eq!(back, m);
        assert_eq!(seq, 0);
    }
}
