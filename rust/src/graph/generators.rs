//! Synthetic sparse-matrix generators for predictor training (paper §4.3)
//! and for the Fig-6 label-frequency study.
//!
//! The paper trains on 300 random square matrices spanning sparsity
//! 0.1%–70%. We additionally mix structural patterns (uniform, power-law,
//! banded, block, diagonal) so each storage format has inputs it can win —
//! the same variety real graphs + GNN intermediates exhibit.

use crate::sparse::Coo;
use crate::util::rng::Rng;

/// Non-zero placement pattern.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatrixPattern {
    /// i.i.d. uniform placement.
    Uniform,
    /// Skewed row/column degrees (citation-graph-like).
    PowerLaw,
    /// Non-zeros concentrated within a diagonal band.
    Banded,
    /// Non-zeros clustered in aligned square blocks.
    Block,
    /// A few dense diagonals.
    Diagonal,
}

pub const ALL_PATTERNS: [MatrixPattern; 5] = [
    MatrixPattern::Uniform,
    MatrixPattern::PowerLaw,
    MatrixPattern::Banded,
    MatrixPattern::Block,
    MatrixPattern::Diagonal,
];

/// Generate an `n × n` matrix with ~`density` non-zeros in the given pattern.
pub fn gen_matrix(rng: &mut Rng, n: usize, density: f64, pattern: MatrixPattern) -> Coo {
    let target = ((n as f64 * n as f64 * density).round() as usize).max(1);
    let mut triples: Vec<(u32, u32, f32)> = Vec::with_capacity(target + target / 4);
    match pattern {
        MatrixPattern::Uniform => {
            for _ in 0..target {
                triples.push((
                    rng.gen_range(n) as u32,
                    rng.gen_range(n) as u32,
                    rng.uniform(0.1, 1.0) as f32,
                ));
            }
        }
        MatrixPattern::PowerLaw => {
            // Skewed draws collide often at high density; sample distinct
            // coordinates until the target count is reached (bounded).
            let mut seen = std::collections::HashSet::with_capacity(target * 2);
            let mut attempts = 0usize;
            while seen.len() < target && attempts < target * 30 {
                attempts += 1;
                let r = rng.powerlaw(n, 2.1);
                let c = if rng.bernoulli(0.5) { rng.powerlaw(n, 2.1) } else { rng.gen_range(n) };
                if seen.insert((r as u32, c as u32)) {
                    triples.push((r as u32, c as u32, rng.uniform(0.1, 1.0) as f32));
                }
            }
        }
        MatrixPattern::Banded => {
            // Bandwidth chosen so the band can hold the target nnz.
            let band = ((target as f64 / (2.0 * n as f64)).ceil() as i64 + 1)
                .min(n as i64 / 2)
                .max(1);
            let mut placed = 0;
            while placed < target {
                let r = rng.gen_range(n) as i64;
                let off = rng.gen_range((2 * band + 1) as usize) as i64 - band;
                let c = r + off;
                if c >= 0 && c < n as i64 {
                    triples.push((r as u32, c as u32, rng.uniform(0.1, 1.0) as f32));
                    placed += 1;
                }
            }
        }
        MatrixPattern::Block => {
            let bs = *rng.choose(&[8usize, 16, 32]).min(&n.max(1));
            let nb = n.div_ceil(bs);
            // Pick enough random blocks, fill each ~70%.
            let per_block = (bs * bs) * 7 / 10;
            let n_blocks = (target / per_block.max(1)).max(1);
            for _ in 0..n_blocks {
                let br = rng.gen_range(nb);
                let bc = rng.gen_range(nb);
                for _ in 0..per_block {
                    let r = br * bs + rng.gen_range(bs);
                    let c = bc * bs + rng.gen_range(bs);
                    if r < n && c < n {
                        triples.push((r as u32, c as u32, rng.uniform(0.1, 1.0) as f32));
                    }
                }
            }
        }
        MatrixPattern::Diagonal => {
            // Fill k full diagonals to reach the target.
            let k = (target / n).max(1).min(2 * n - 1);
            let mut offsets: Vec<i64> = vec![0];
            while offsets.len() < k {
                let o = rng.gen_range(2 * n - 1) as i64 - (n as i64 - 1);
                if !offsets.contains(&o) {
                    offsets.push(o);
                }
            }
            for &off in &offsets {
                for r in 0..n as i64 {
                    let c = r + off;
                    if c >= 0 && c < n as i64 {
                        triples.push((r as u32, c as u32, rng.uniform(0.1, 1.0) as f32));
                    }
                }
            }
        }
    }
    Coo::from_triples(n, n, triples)
}

/// The paper's §4.3 training corpus: `count` square matrices with sizes in
/// `[min_n, max_n]` and sparsity 0.1%–70%, cycling through patterns.
/// Returns `(matrix, pattern)` pairs.
pub fn training_corpus(
    rng: &mut Rng,
    count: usize,
    min_n: usize,
    max_n: usize,
) -> Vec<(Coo, MatrixPattern)> {
    let mut out = Vec::with_capacity(count);
    for i in 0..count {
        let n = min_n + rng.gen_range(max_n - min_n + 1);
        // Log-uniform density in [0.001, 0.7] (the paper's 0.1%..70%).
        let log_lo = (0.001f64).ln();
        let log_hi = (0.7f64).ln();
        let density = (log_lo + (log_hi - log_lo) * rng.next_f64()).exp();
        let pattern = ALL_PATTERNS[i % ALL_PATTERNS.len()];
        out.push((gen_matrix(rng, n, density, pattern), pattern));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, prop_assert};

    #[test]
    fn prop_generator_hits_shape_and_rough_density() {
        check(
            20,
            |rng| {
                let n = 64 + rng.gen_range(128);
                let density = rng.uniform(0.01, 0.3);
                let pattern = *rng.choose(&ALL_PATTERNS);
                (gen_matrix(rng, n, density, pattern), n, density, pattern)
            },
            |(m, n, density, pattern)| {
                prop_assert(m.rows == *n && m.cols == *n, "square shape")?;
                prop_assert(m.nnz() > 0, "non-empty")?;
                let got = m.density();
                // Duplicates / block rounding make density approximate.
                prop_assert(
                    got > density * 0.2 && got < (density * 3.0 + 0.05).min(1.0),
                    &format!("density {got} vs target {density} ({pattern:?})"),
                )?;
                Ok(())
            },
        );
    }

    #[test]
    fn banded_stays_in_band() {
        let mut rng = crate::util::rng::Rng::new(5);
        let m = gen_matrix(&mut rng, 100, 0.02, MatrixPattern::Banded);
        let max_off = (0..m.nnz())
            .map(|i| (m.col[i] as i64 - m.row[i] as i64).abs())
            .max()
            .unwrap();
        assert!(max_off <= 50, "band too wide: {max_off}");
    }

    #[test]
    fn diagonal_pattern_has_few_diags() {
        let mut rng = crate::util::rng::Rng::new(6);
        let m = gen_matrix(&mut rng, 128, 0.05, MatrixPattern::Diagonal);
        let mut offs: Vec<i64> = (0..m.nnz())
            .map(|i| m.col[i] as i64 - m.row[i] as i64)
            .collect();
        offs.sort_unstable();
        offs.dedup();
        assert!(offs.len() <= 10, "expected few diagonals, got {}", offs.len());
    }

    #[test]
    fn corpus_covers_patterns_and_sizes() {
        let mut rng = crate::util::rng::Rng::new(7);
        let corpus = training_corpus(&mut rng, 20, 64, 128);
        assert_eq!(corpus.len(), 20);
        let patterns: std::collections::HashSet<_> =
            corpus.iter().map(|(_, p)| format!("{p:?}")).collect();
        assert_eq!(patterns.len(), 5);
        for (m, _) in &corpus {
            assert!(m.rows >= 64 && m.rows <= 128);
        }
    }
}
