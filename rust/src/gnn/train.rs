//! Unified training loop over the five GNN architectures — the measurement
//! harness behind every speedup figure in the paper (end-to-end epoch time,
//! including format decisions, conversions and feature extraction).
//!
//! Since the zero-allocation SpMM rework (DESIGN.md §SparseOps), every
//! model's backward pass runs through [`AdjEngine::spmm_t`]: no model
//! registers duplicate transposed slots, so the engine phase report shows
//! `spmm`/`spmm_t` against a workspace-reusing, transpose-free baseline.

use super::egc::Egc;
use super::engine::{AdjEngine, Decision, FormatPolicy};
use super::film::Film;
use super::gat::Gat;
use super::gcn::Gcn;
use super::rgcn::Rgcn;
use crate::graph::GraphDataset;
use crate::tensor::{ops, Matrix};
use crate::util::rng::Rng;

/// The paper's five evaluated architectures (§5.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Gcn,
    Gat,
    Rgcn,
    Film,
    Egc,
}

pub const ALL_MODELS: [ModelKind; 5] =
    [ModelKind::Gcn, ModelKind::Gat, ModelKind::Rgcn, ModelKind::Film, ModelKind::Egc];

impl ModelKind {
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Gcn => "GCN",
            ModelKind::Gat => "GAT",
            ModelKind::Rgcn => "RGCN",
            ModelKind::Film => "FiLM",
            ModelKind::Egc => "EGC",
        }
    }

    pub fn from_name(name: &str) -> Option<ModelKind> {
        ALL_MODELS.iter().copied().find(|m| m.name().eq_ignore_ascii_case(name))
    }

    /// Whether the model has a sharded mini-batch training path
    /// (`gnn::minibatch::train_minibatch`). All five models rebind their
    /// engine slots per shard (`set_graph`) and split gradient computation
    /// (`backward_grads`) from the optimizer step (`apply_grads`): GCN/EGC/
    /// FiLM slice the shared normalized adjacency, GAT its attention
    /// pattern, and RGCN one induced submatrix **per relation** (each
    /// relation keeps its own slot and decision-cache entry).
    pub fn supports_minibatch(self) -> bool {
        true
    }
}

enum AnyModel {
    Gcn(Gcn),
    Gat(Gat),
    Rgcn(Rgcn),
    Film(Film),
    Egc(Egc),
}

impl AnyModel {
    fn forward(&mut self, eng: &mut AdjEngine) -> Matrix {
        match self {
            AnyModel::Gcn(m) => m.forward(eng),
            AnyModel::Gat(m) => m.forward(eng),
            AnyModel::Rgcn(m) => m.forward(eng),
            AnyModel::Film(m) => m.forward(eng),
            AnyModel::Egc(m) => m.forward(eng),
        }
    }

    fn backward(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) {
        match self {
            AnyModel::Gcn(m) => m.backward(eng, dlogits),
            AnyModel::Gat(m) => m.backward(eng, dlogits),
            AnyModel::Rgcn(m) => m.backward(eng, dlogits),
            AnyModel::Film(m) => m.backward(eng, dlogits),
            AnyModel::Egc(m) => m.backward(eng, dlogits),
        }
    }

    fn h1_density(&self) -> Option<f64> {
        match self {
            AnyModel::Gcn(m) => m.h1_density(),
            _ => None,
        }
    }
}

/// Training hyperparameters (paper §5.2: 10 epochs per measurement).
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub hidden: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { epochs: 10, hidden: 16, lr: 0.02, seed: 0x6E11 }
    }
}

/// Everything a figure needs from one training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub model: &'static str,
    pub dataset: String,
    pub policy: String,
    pub losses: Vec<f32>,
    pub final_train_acc: f64,
    pub final_test_acc: f64,
    /// End-to-end wall-clock time (includes all engine overheads).
    pub total_time: f64,
    /// Engine phase breakdown: (phase, seconds, invocations).
    pub phases: Vec<(&'static str, f64, u64)>,
    pub decisions: Vec<Decision>,
    /// H1 density per epoch (GCN — the Fig-2 drift signal).
    pub h1_densities: Vec<f64>,
}

/// Train `kind` on `ds` under `policy`, measuring end-to-end time.
pub fn train(
    kind: ModelKind,
    ds: &GraphDataset,
    policy: &mut dyn FormatPolicy,
    cfg: &TrainConfig,
) -> TrainReport {
    let policy_name = policy.policy_name();
    let start = std::time::Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let mut eng = AdjEngine::new(policy);
    let mut model = match kind {
        ModelKind::Gcn => AnyModel::Gcn(Gcn::new(ds, cfg.hidden, cfg.lr, &mut rng, &mut eng)),
        ModelKind::Gat => AnyModel::Gat(Gat::new(ds, cfg.hidden, cfg.lr, &mut rng, &mut eng)),
        ModelKind::Rgcn => AnyModel::Rgcn(Rgcn::new(ds, cfg.hidden, cfg.lr, &mut rng, &mut eng)),
        ModelKind::Film => AnyModel::Film(Film::new(ds, cfg.hidden, cfg.lr, &mut rng, &mut eng)),
        ModelKind::Egc => AnyModel::Egc(Egc::new(ds, cfg.hidden, cfg.lr, &mut rng, &mut eng)),
    };

    let mut losses = Vec::with_capacity(cfg.epochs);
    let mut h1_densities = Vec::new();
    for _epoch in 0..cfg.epochs {
        let logits = model.forward(&mut eng);
        let (loss, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
        if let Some(d) = model.h1_density() {
            h1_densities.push(d);
        }
        model.backward(&mut eng, &dlogits);
        losses.push(loss);
    }
    let logits = model.forward(&mut eng);
    let final_train_acc = ops::masked_accuracy(&logits, &ds.labels, &ds.train_mask);
    let final_test_acc = ops::masked_accuracy(&logits, &ds.labels, &ds.test_mask);
    // The oracle's exhaustive profiling models a perfect zero-overhead
    // predictor (paper §6.3): its search time is excluded from the
    // reported end-to-end time. All real policies charge their overhead
    // to other phases, which stay included.
    let total_time = start.elapsed().as_secs_f64() - eng.sw.total("oracle_search");

    TrainReport {
        model: kind.name(),
        dataset: ds.name.clone(),
        policy: policy_name,
        losses,
        final_train_acc,
        final_test_acc,
        total_time,
        phases: eng.sw.report(),
        decisions: eng.decisions.clone(),
        h1_densities,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::engine::StaticPolicy;
    use crate::graph::DatasetSpec;
    use crate::sparse::Format;

    fn tiny() -> GraphDataset {
        let mut rng = Rng::new(11);
        GraphDataset::generate(
            &DatasetSpec {
                name: "Tiny",
                n: 80,
                feat_dim: 16,
                adj_density: 0.08,
                feat_density: 0.2,
                n_classes: 3,
            },
            &mut rng,
        )
    }

    #[test]
    fn all_five_models_train() {
        let ds = tiny();
        for kind in ALL_MODELS {
            let mut policy = StaticPolicy(Format::Csr);
            let report = train(
                kind,
                &ds,
                &mut policy,
                &TrainConfig { epochs: 8, hidden: 8, ..Default::default() },
            );
            assert_eq!(report.losses.len(), 8);
            let first = report.losses[0];
            let last = *report.losses.last().unwrap();
            assert!(
                last < first,
                "{}: loss should decrease ({first} -> {last})",
                kind.name()
            );
            assert!(report.total_time > 0.0);
            assert!(!report.phases.is_empty());
            assert!(!report.decisions.is_empty());
        }
    }

    #[test]
    fn no_model_registers_transposed_slots() {
        // The transpose-free backward invariant: every decision the engine
        // records is for a forward operand — the legacy `…t` slots
        // (`gcn.Xt`, `gat.Att.l1t`, `rgcn.H1t`, …) must never reappear.
        let ds = tiny();
        for kind in ALL_MODELS {
            let mut policy = StaticPolicy(Format::Csr);
            let report = train(
                kind,
                &ds,
                &mut policy,
                &TrainConfig { epochs: 2, hidden: 8, ..Default::default() },
            );
            for d in &report.decisions {
                assert!(
                    !d.slot.ends_with('t'),
                    "{}: transposed slot '{}' registered",
                    kind.name(),
                    d.slot
                );
            }
            // Backward passes ran through the transpose-free kernel.
            let spmm_t = report.phases.iter().find(|p| p.0 == "spmm_t");
            assert!(spmm_t.is_some(), "{}: no spmm_t phase recorded", kind.name());
        }
    }

    #[test]
    fn model_kind_roundtrip() {
        for m in ALL_MODELS {
            assert_eq!(ModelKind::from_name(m.name()), Some(m));
        }
        assert_eq!(ModelKind::from_name("gcn"), Some(ModelKind::Gcn));
        assert_eq!(ModelKind::from_name("nope"), None);
    }

    #[test]
    fn minibatch_support_matrix() {
        // ISSUE-4 closed the last coverage gap: every model trains sharded.
        for kind in ALL_MODELS {
            assert!(kind.supports_minibatch(), "{}", kind.name());
        }
    }

    /// The grads-split refactor must leave full-batch training identical:
    /// `backward` ≡ `backward_grads` + `apply_grads` (same Adam sequence).
    #[test]
    fn split_backward_matches_fused_backward() {
        let ds = tiny();
        let run = |split: bool| -> Matrix {
            let mut rng = Rng::new(77);
            let mut policy = StaticPolicy(Format::Csr);
            let mut eng = AdjEngine::new(&mut policy);
            let mut model =
                crate::gnn::gcn::Gcn::new(&ds, 8, 0.02, &mut rng, &mut eng);
            for _ in 0..4 {
                let logits = model.forward(&mut eng);
                let (_, dlogits) =
                    ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
                if split {
                    let g = model.backward_grads(&mut eng, &dlogits);
                    model.apply_grads(&g);
                } else {
                    model.backward(&mut eng, &dlogits);
                }
            }
            model.forward(&mut eng)
        };
        let a = run(false);
        let b = run(true);
        assert!(a.max_abs_diff(&b) < 1e-6, "split/fused backward diverged");
    }

    #[test]
    fn gcn_reports_h1_density_per_epoch() {
        let ds = tiny();
        let mut policy = StaticPolicy(Format::Csr);
        let report = train(
            ModelKind::Gcn,
            &ds,
            &mut policy,
            &TrainConfig { epochs: 5, hidden: 8, ..Default::default() },
        );
        assert_eq!(report.h1_densities.len(), 5);
        assert!(report.h1_densities.iter().all(|&d| d > 0.0 && d <= 1.0));
    }
}
