//! Graph Attention Network (Veličković et al. [30]) — two single-head
//! layers with exact backward through the attention softmax.
//!
//! The attention matrix has the adjacency(+self-loop) pattern but fresh
//! values every forward pass, so its engine slot is refreshed per epoch —
//! exercising the runtime's re-conversion path exactly where PyG pays it.
//! The backward pass reads `A_αᵀ` and `Xᵀ`/`H1ᵀ` through
//! [`AdjEngine::spmm_t`] on the forward slots — the transposed attention
//! pattern, its per-epoch value permutation and all duplicate transposed
//! slots are gone (§Perf).

use super::adam::Adam;
use super::engine::AdjEngine;
use crate::graph::GraphDataset;
use crate::sparse::{Coo, SharedMatrix, SparseMatrix};
use crate::tensor::{ops, Matrix};
use crate::util::rng::Rng;
use std::sync::Arc;

const LEAKY: f32 = 0.2;

/// Edge-pattern helpers -----------------------------------------------------

/// Per-edge attention logits `u_e = al·z_i + ar·z_j` for edges `(i=row, j=col)`.
fn edge_logits(pat: &Coo, z: &Matrix, al: &[f32], ar: &[f32]) -> Vec<f32> {
    let score = |row: &[f32], a: &[f32]| -> f32 {
        row.iter().zip(a.iter()).map(|(&x, &w)| x * w).sum()
    };
    // Precompute per-node al·z_i and ar·z_j (O(n·h) instead of O(E·h)).
    let n = z.rows;
    let mut sl = vec![0f32; n];
    let mut sr = vec![0f32; n];
    for i in 0..n {
        sl[i] = score(z.row(i), al);
        sr[i] = score(z.row(i), ar);
    }
    (0..pat.nnz())
        .map(|e| sl[pat.row[e] as usize] + sr[pat.col[e] as usize])
        .collect()
}

fn leaky(u: f32) -> f32 {
    if u > 0.0 {
        u
    } else {
        LEAKY * u
    }
}

fn leaky_grad(u: f32) -> f32 {
    if u > 0.0 {
        1.0
    } else {
        LEAKY
    }
}

/// Row segments of a row-sorted COO pattern: (start, end) per row with nnz.
fn row_segments(pat: &Coo) -> Vec<(usize, usize)> {
    let mut segs = Vec::new();
    let mut e = 0;
    while e < pat.nnz() {
        let r = pat.row[e];
        let start = e;
        while e < pat.nnz() && pat.row[e] == r {
            e += 1;
        }
        segs.push((start, e));
    }
    segs
}

/// Per-row softmax over edge scores (after LeakyReLU).
fn edge_softmax(pat: &Coo, u: &[f32]) -> Vec<f32> {
    let mut alpha = vec![0f32; u.len()];
    for &(s, t) in &row_segments(pat) {
        let max = u[s..t].iter().map(|&x| leaky(x)).fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for e in s..t {
            let v = (leaky(u[e]) - max).exp();
            alpha[e] = v;
            sum += v;
        }
        let inv = 1.0 / sum.max(1e-30);
        for a in &mut alpha[s..t] {
            *a *= inv;
        }
    }
    alpha
}

/// One GAT layer's parameters + caches.
struct GatLayer {
    w: Matrix,
    al: Vec<f32>,
    ar: Vec<f32>,
    bias: Vec<f32>,
    // caches
    z: Option<Matrix>,
    u: Option<Vec<f32>>,
    alpha: Option<Vec<f32>>,
    pre: Option<Matrix>,
}

impl GatLayer {
    fn new(d_in: usize, d_out: usize, rng: &mut Rng) -> GatLayer {
        GatLayer {
            w: Matrix::glorot(d_in, d_out, rng),
            al: (0..d_out).map(|_| (rng.normal() * 0.1) as f32).collect(),
            ar: (0..d_out).map(|_| (rng.normal() * 0.1) as f32).collect(),
            bias: vec![0.0; d_out],
            z: None,
            u: None,
            alpha: None,
            pre: None,
        }
    }
}

/// One GAT layer's parameter gradients.
pub struct GatLayerGrads {
    pub dw: Matrix,
    pub dal: Vec<f32>,
    pub dar: Vec<f32>,
    pub dbias: Vec<f32>,
}

/// One backward pass's parameter gradients — the mini-batch accumulation
/// unit (see `gnn::minibatch`).
pub struct GatGrads {
    pub l1: GatLayerGrads,
    pub l2: GatLayerGrads,
}

impl GatGrads {
    /// `self += w · other` (shard-weighted gradient accumulation).
    pub fn add_scaled(&mut self, o: &GatGrads, w: f32) {
        for (a, b) in [(&mut self.l1, &o.l1), (&mut self.l2, &o.l2)] {
            ops::axpy_slice(&mut a.dw.data, &b.dw.data, w);
            ops::axpy_slice(&mut a.dal, &b.dal, w);
            ops::axpy_slice(&mut a.dar, &b.dar, w);
            ops::axpy_slice(&mut a.dbias, &b.dbias, w);
        }
    }

    /// `self *= w`.
    pub fn scale(&mut self, w: f32) {
        for l in [&mut self.l1, &mut self.l2] {
            ops::scale_slice(&mut l.dw.data, w);
            ops::scale_slice(&mut l.dal, w);
            ops::scale_slice(&mut l.dar, w);
            ops::scale_slice(&mut l.dbias, w);
        }
    }
}

/// Engine slot ids for one graph binding (train shards or the dedicated
/// full-graph eval binding — §Shared-Ownership double-buffering).
#[derive(Clone, Copy)]
struct GatSlots {
    x: usize,
    att1: usize,
    att2: usize,
    h1: usize,
}

/// Two-layer single-head GAT.
pub struct Gat {
    l1: GatLayer,
    l2: GatLayer,
    adam: Adam,
    /// Attention pattern of the train/shard binding (shared handle — the
    /// mini-batch driver hands the same `Arc` it keeps as master).
    train_pattern: Arc<Coo>,
    /// Epoch-invariant full-graph pattern for the eval binding.
    eval_pattern: Option<Arc<Coo>>,
    slots: GatSlots,
    train_slots: GatSlots,
    eval_slots: Option<GatSlots>,
    h1_cache: Option<Matrix>, // pre-activation of layer 1
}

impl Gat {
    pub fn new(
        ds: &GraphDataset,
        hidden: usize,
        lr: f32,
        rng: &mut Rng,
        eng: &mut AdjEngine,
    ) -> Gat {
        let n = ds.adj.rows;
        // Attention pattern: adjacency + self loops (values irrelevant).
        let pattern = Gat::attention_pattern(&ds.adj);
        let l1 = GatLayer::new(ds.features.cols, hidden, rng);
        let l2 = GatLayer::new(hidden, ds.n_classes, rng);
        let adam = Adam::new(
            &[
                l1.w.data.len(), l1.al.len(), l1.ar.len(), l1.bias.len(),
                l2.w.data.len(), l2.al.len(), l2.ar.len(), l2.bias.len(),
            ],
            lr,
        );
        let empty_h1 = Coo::from_triples(n, hidden, vec![]);
        let train_slots = GatSlots {
            x: eng.add_slot("gat.X", ds.features.clone()),
            att1: eng.add_slot("gat.Att.l1", pattern.clone()),
            att2: eng.add_slot("gat.Att.l2", pattern.clone()),
            h1: eng.add_slot("gat.H1", empty_h1),
        };
        Gat {
            slots: train_slots,
            train_slots,
            eval_slots: None,
            train_pattern: Arc::new(pattern),
            eval_pattern: None,
            l1,
            l2,
            adam,
            h1_cache: None,
        }
    }

    /// Shared per-layer forward: projection slot → attention → aggregation.
    fn layer_forward(
        pattern: &Coo,
        layer: &mut GatLayer,
        eng: &mut AdjEngine,
        s_in: usize,
        s_att: usize,
    ) -> Matrix {
        let z = eng.spmm(s_in, &layer.w);
        let u = edge_logits(pattern, &z, &layer.al, &layer.ar);
        let alpha = edge_softmax(pattern, &u);
        // Attention matrix: fixed pattern, fresh α values — value-copy
        // refresh, no per-epoch re-conversion (§Perf). The backward pass
        // reads A_αᵀ from this same slot via `spmm_t`.
        eng.update_slot_values(s_att, pattern, &alpha);
        let agg = eng.spmm(s_att, &z);
        let pre = ops::add_row(&agg, &layer.bias);
        eng.recycle(s_att, agg);
        layer.z = Some(z);
        layer.u = Some(u);
        layer.alpha = Some(alpha);
        layer.pre = Some(pre.clone());
        pre
    }

    /// Shared per-layer backward. Returns `dz · Wᵀ` (gradient wrt the layer
    /// input) and the parameter gradients (dw, dal, dar, dbias).
    #[allow(clippy::type_complexity)]
    fn layer_backward(
        pattern: &Coo,
        layer: &GatLayer,
        eng: &mut AdjEngine,
        s_in: usize,
        s_att: usize,
        dpre: &Matrix,
    ) -> (Matrix, Matrix, Vec<f32>, Vec<f32>, Vec<f32>) {
        let z = layer.z.as_ref().unwrap();
        let u = layer.u.as_ref().unwrap();
        let alpha = layer.alpha.as_ref().unwrap();
        let h = z.cols;

        let dbias = ops::col_sums(dpre);
        // Aggregation path: dz += A_αᵀ · dpre — transpose-free on the
        // attention slot.
        let mut dz = eng.spmm_t(s_att, dpre);
        // Attention path.
        // dα_e = dpre_i · z_j.
        let dalpha: Vec<f32> = crate::util::parallel::parallel_map(pattern.nnz(), |e| {
            let i = pattern.row[e] as usize;
            let j = pattern.col[e] as usize;
            dpre.row(i).iter().zip(z.row(j).iter()).map(|(&a, &b)| a * b).sum()
        });
        // Softmax backward per row + LeakyReLU gate.
        let mut du = vec![0f32; pattern.nnz()];
        for &(s, t) in &row_segments(pattern) {
            let dot: f32 = (s..t).map(|e| alpha[e] * dalpha[e]).sum();
            for e in s..t {
                du[e] = alpha[e] * (dalpha[e] - dot) * leaky_grad(u[e]);
            }
        }
        // Scatter du into dal/dar and dz.
        let mut dal = vec![0f32; h];
        let mut dar = vec![0f32; h];
        for e in 0..pattern.nnz() {
            let i = pattern.row[e] as usize;
            let j = pattern.col[e] as usize;
            let g = du[e];
            if g == 0.0 {
                continue;
            }
            for k in 0..h {
                dal[k] += g * z.at(i, k);
                dar[k] += g * z.at(j, k);
                *dz.at_mut(i, k) += g * layer.al[k];
                *dz.at_mut(j, k) += g * layer.ar[k];
            }
        }
        // dW = inputᵀ · dz — transpose-free on the input slot.
        let dw = eng.spmm_t(s_in, &dz);
        let dinput = dz.matmul_t(&layer.w);
        (dinput, dw, dal, dar, dbias)
    }

    pub fn forward(&mut self, eng: &mut AdjEngine) -> Matrix {
        let sl = self.slots;
        // Active pattern derived from which slot set is active (so engine
        // operands and model-side pattern can never desync); written as a
        // field-disjoint borrow that stays clear of `l1`/`l2`.
        let on_eval = self.eval_slots.is_some_and(|e| e.x == sl.x);
        let pattern: &Coo = if on_eval {
            self.eval_pattern.as_deref().expect("bind_eval_graph before eval forward")
        } else {
            &self.train_pattern
        };
        let pre1 = Self::layer_forward(pattern, &mut self.l1, eng, sl.x, sl.att1);
        let h1_dense = ops::relu(&pre1);
        eng.update_slot_dense(sl.h1, &h1_dense);
        self.h1_cache = Some(pre1);
        Self::layer_forward(pattern, &mut self.l2, eng, sl.h1, sl.att2)
    }

    /// Backward pass returning parameter gradients without applying them
    /// (the mini-batch accumulation path).
    pub fn backward_grads(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) -> GatGrads {
        let pre1 = self.h1_cache.take().expect("forward before backward");
        let sl = self.slots;
        let on_eval = self.eval_slots.is_some_and(|e| e.x == sl.x);
        let pattern: &Coo = if on_eval {
            self.eval_pattern.as_deref().expect("bind_eval_graph before eval forward")
        } else {
            &self.train_pattern
        };
        let (dh1, dw2, dal2, dar2, db2) =
            Self::layer_backward(pattern, &self.l2, eng, sl.h1, sl.att2, dlogits);
        let dpre1 = ops::relu_grad(&pre1, &dh1);
        let (_dx, dw1, dal1, dar1, db1) =
            Self::layer_backward(pattern, &self.l1, eng, sl.x, sl.att1, &dpre1);
        GatGrads {
            l1: GatLayerGrads { dw: dw1, dal: dal1, dar: dar1, dbias: db1 },
            l2: GatLayerGrads { dw: dw2, dal: dal2, dar: dar2, dbias: db2 },
        }
    }

    /// One Adam step from (possibly accumulated) gradients.
    pub fn apply_grads(&mut self, g: &GatGrads) {
        self.adam.tick();
        self.adam.update_matrix(0, &mut self.l1.w, &g.l1.dw);
        self.adam.update(1, &mut self.l1.al, &g.l1.dal);
        self.adam.update(2, &mut self.l1.ar, &g.l1.dar);
        self.adam.update(3, &mut self.l1.bias, &g.l1.dbias);
        self.adam.update_matrix(4, &mut self.l2.w, &g.l2.dw);
        self.adam.update(5, &mut self.l2.al, &g.l2.dal);
        self.adam.update(6, &mut self.l2.ar, &g.l2.dar);
        self.adam.update(7, &mut self.l2.bias, &g.l2.dbias);
    }

    /// Backward + Adam step (full-batch path).
    pub fn backward(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) {
        let g = self.backward_grads(eng, dlogits);
        self.apply_grads(&g);
    }

    /// Point the model's train slots at a new (sub)graph: induced feature
    /// rows `x` and the induced **attention pattern** (raw adjacency + self
    /// loops, unit values). The attention slots are re-seeded with the
    /// pattern so the per-forward value refresh (`update_slot_values`)
    /// finds a matching edge count; their format decision is re-made
    /// through the decision cache.
    pub fn set_graph(
        &mut self,
        eng: &mut AdjEngine,
        x: impl Into<SharedMatrix>,
        pattern: impl Into<Arc<Coo>>,
    ) {
        self.slots = self.train_slots;
        let pattern = pattern.into();
        eng.set_slot_matrix(self.train_slots.x, x);
        eng.set_slot_matrix(self.train_slots.att1, SparseMatrix::Coo((*pattern).clone()));
        eng.set_slot_matrix(self.train_slots.att2, SparseMatrix::Coo((*pattern).clone()));
        self.train_pattern = pattern;
    }

    /// Create + bind the dedicated full-graph eval slots once. The feature
    /// master binds by handle (zero copies); the two attention slots are
    /// seeded from the epoch-invariant full pattern **once** — every later
    /// eval forward refreshes their α values in place, and the per-epoch
    /// flip itself ([`Gat::use_eval_graph`]) touches no matrix data.
    pub fn bind_eval_graph(&mut self, eng: &mut AdjEngine, x: SharedMatrix, pattern: Arc<Coo>) {
        assert!(self.eval_slots.is_none(), "eval slots are bound once at startup");
        let n = pattern.rows;
        let hidden = self.l1.bias.len();
        self.eval_slots = Some(GatSlots {
            x: eng.add_slot_shared("gat.X.eval", x),
            att1: eng.add_slot("gat.Att.l1.eval", (*pattern).clone()),
            att2: eng.add_slot("gat.Att.l2.eval", (*pattern).clone()),
            h1: eng.add_slot("gat.H1.eval", Coo::from_triples(n, hidden, vec![])),
        });
        self.eval_pattern = Some(pattern);
    }

    /// Flip onto the full-graph eval slots — O(1), no engine traffic.
    pub fn use_eval_graph(&mut self) {
        self.slots = self.eval_slots.expect("bind_eval_graph before use_eval_graph");
    }

    /// Flip back onto the train/shard slots (`set_graph` also does this).
    pub fn use_train_graph(&mut self) {
        self.slots = self.train_slots;
    }

    /// Attention pattern for an arbitrary raw adjacency: adjacency + self
    /// loops, unit values (what [`Gat::new`] builds for the full graph).
    pub fn attention_pattern(adj: &Coo) -> Coo {
        let n = adj.rows;
        let mut triples: Vec<(u32, u32, f32)> = Vec::with_capacity(adj.nnz() + n);
        for i in 0..adj.nnz() {
            triples.push((adj.row[i], adj.col[i], 1.0));
        }
        for i in 0..n as u32 {
            triples.push((i, i, 1.0));
        }
        Coo::from_triples(n, n, triples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::engine::StaticPolicy;
    use crate::graph::DatasetSpec;
    use crate::sparse::Format;

    fn tiny_dataset(rng: &mut Rng) -> GraphDataset {
        let spec = DatasetSpec {
            name: "Tiny",
            n: 90,
            feat_dim: 20,
            adj_density: 0.06,
            feat_density: 0.2,
            n_classes: 3,
        };
        GraphDataset::generate(&spec, rng)
    }

    #[test]
    fn attention_rows_sum_to_one() {
        let mut rng = Rng::new(1);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Gat::new(&ds, 8, 0.01, &mut rng, &mut eng);
        let _ = model.forward(&mut eng);
        let alpha = model.l1.alpha.as_ref().unwrap();
        for &(s, t) in &row_segments(&model.train_pattern) {
            let sum: f32 = alpha[s..t].iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "row softmax sum {sum}");
        }
    }

    #[test]
    fn loss_decreases() {
        let mut rng = Rng::new(2);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Gat::new(&ds, 8, 0.02, &mut rng, &mut eng);
        let mut losses = Vec::new();
        for _ in 0..40 {
            let logits = model.forward(&mut eng);
            let (loss, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
            model.backward(&mut eng, &dlogits);
            losses.push(loss);
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.8),
            "GAT loss should drop: {:?} -> {:?}",
            losses.first(),
            losses.last()
        );
    }

    #[test]
    fn attention_params_receive_gradient() {
        let mut rng = Rng::new(3);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Coo);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Gat::new(&ds, 8, 0.05, &mut rng, &mut eng);
        let al_before = model.l1.al.clone();
        for _ in 0..3 {
            let logits = model.forward(&mut eng);
            let (_, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
            model.backward(&mut eng, &dlogits);
        }
        let moved = model
            .l1
            .al
            .iter()
            .zip(al_before.iter())
            .any(|(&a, &b)| (a - b).abs() > 1e-7);
        assert!(moved, "attention vector al should be updated");
    }
}
