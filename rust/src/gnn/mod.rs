//! The five GNN architectures of the paper's evaluation (§5.1) — GCN [18],
//! GAT [30], RGCN [26], GNN-FiLM [3] and EGC [28] — implemented with
//! explicit forward/backward passes over the sparse substrate.
//!
//! Every sparse multiply goes through [`engine::AdjEngine`], the integration
//! point where the paper's contribution happens: before a layer touches a
//! sparse matrix, the engine consults a [`engine::FormatPolicy`] (static
//! format / learned predictor / oracle), converts if needed, and charges
//! feature-extraction + prediction + conversion overhead to the measured
//! time — matching the paper's accounting.
//!
//! Beyond full-batch scale, [`minibatch`] trains all five models over node
//! shards (degree-aware partition → seeded neighbor sampling → direct
//! submatrix extraction → cached per-shard format decisions → gradient
//! accumulation; DESIGN.md §Minibatch). RGCN extracts one induced
//! submatrix **per relation**, multiplying the decision surface the format
//! predictor optimizes over (R relations × shards).

pub mod engine;
pub mod adam;
pub mod gcn;
pub mod gat;
pub mod rgcn;
pub mod film;
pub mod egc;
pub mod train;
pub mod minibatch;

pub use engine::{AdjEngine, FormatPolicy, StaticPolicy};
pub use minibatch::{
    train_minibatch, train_minibatch_warm, FullGraphOps, MinibatchConfig, MinibatchReport,
};
pub use train::{train, ModelKind, TrainConfig, TrainReport, ALL_MODELS};
