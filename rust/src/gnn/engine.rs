//! The per-layer sparse-format switching engine.
//!
//! Each sparse operand of a GNN (the normalized adjacency per layer, the
//! sparse feature matrix, sparsified intermediate activations, attention
//! matrices, relation adjacencies…) is registered as a **slot**. Before the
//! first SpMM on a slot — and again whenever the slot's density drifts —
//! the engine asks the [`FormatPolicy`] which storage format to use,
//! converts, and executes the format-dispatched kernel. All overheads
//! (feature extraction, model inference, conversion) are charged to the
//! engine's [`Stopwatch`], reproducing the paper's end-to-end accounting.
//!
//! §Perf (see DESIGN.md §SparseOps): steady-state **output** buffers are
//! allocation-free. Each slot owns a small pool of recycled output buffers —
//! [`AdjEngine::spmm`]/[`AdjEngine::spmm_t`] pop one, run the
//! `spmm_into`/`spmm_t_into` kernel, and hand the matrix to the caller, who
//! returns it with [`AdjEngine::recycle`] once consumed. Backward passes go
//! through [`AdjEngine::spmm_t`], which executes `Aᵀ·X` on the slot's
//! existing arrays (CSR↔CSC duality): no duplicate transposed slots, no
//! per-epoch dense transposes. Scatter-style kernels (CSC forward,
//! CSR/COO/BSR/LIL transpose) accumulate into the persistent worker pool's
//! grow-only scratch buffers (`util::pool`), and every kernel dispatches on
//! that pool's parked workers — so the steady-state multiply path performs
//! no thread spawns and no heap allocation at all. The decision path reads
//! a cached COO view that is invalidated only when the slot's *content*
//! changes — format conversions keep it.

use crate::predictor::cache::DecisionCache;
use crate::sparse::shared::WeakMatrix;
use crate::sparse::{Coo, Format, Schedule, SharedMatrix, SparseMatrix};
use crate::tensor::Matrix;
use crate::util::timer::Stopwatch;
use std::sync::Arc;

/// Slot-bind operand gate (DESIGN.md §Fault-Tolerance): the always-on tier
/// is [`SparseMatrix::validate_quick`] — O(outer-dim) shape/length
/// coherence, cheap enough for every bind — and debug builds additionally
/// run the full O(nnz) [`SparseMatrix::validate`] sweep. Binding is a
/// programmer-controlled boundary (unlike snapshot publication or request
/// operands, which get typed errors in `serve`), so a malformed operand
/// here is a caller bug and panics with the format diagnosis.
fn check_operand(op: &str, slot: &str, m: &SparseMatrix) {
    if let Err(e) = m.validate_quick() {
        panic!("{op}({slot}): {e}");
    }
    #[cfg(debug_assertions)]
    if let Err(e) = m.validate() {
        panic!("{op}({slot}): {e}");
    }
}

/// Strategy for choosing a slot's storage format.
pub trait FormatPolicy {
    /// Choose a format for a matrix about to be multiplied with a dense
    /// operand of width `d`. Implementations charge their own overhead
    /// (feature extraction, inference, profiling) to `sw`.
    fn decide(&mut self, coo: &Coo, d: usize, sw: &mut Stopwatch) -> Format;

    /// Slot-aware decision (default: ignore the slot name). Lets
    /// experiments target specific operands — e.g. Fig. 3 varies only the
    /// layer-1 output's format.
    fn decide_for_slot(
        &mut self,
        _slot: &str,
        coo: &Coo,
        d: usize,
        sw: &mut Stopwatch,
    ) -> Format {
        self.decide(coo, d, sw)
    }

    /// Slot-aware decision plus a **calibrated confidence margin** in
    /// [0, 1]. Deterministic policies are fully confident (1.0); learned
    /// policies report the top-1 − top-2 class-probability gap, and the
    /// decision cache declines to pin low-margin answers behind its
    /// hysteresis dead-band (see `predictor::cache`).
    fn decide_for_slot_with_confidence(
        &mut self,
        slot: &str,
        coo: &Coo,
        d: usize,
        sw: &mut Stopwatch,
    ) -> (Format, f64) {
        (self.decide_for_slot(slot, coo, d, sw), 1.0)
    }

    /// Full execution-plan decision: storage format **plus** kernel
    /// schedule (tile width / split rule / thread cap — see
    /// `sparse::schedule`), with the calibrated confidence margin of the
    /// combined plan. The default keeps format-only policies working
    /// unchanged: they run under [`Schedule::effective`], i.e. the tuned
    /// default kernels (or the `GNN_SPMM_SCHEDULE` process override).
    /// Schedule-aware policies — the measured autotuner, the multi-output
    /// GBDT predictor — override this.
    fn decide_plan_for_slot(
        &mut self,
        slot: &str,
        coo: &Coo,
        d: usize,
        sw: &mut Stopwatch,
    ) -> (Format, Schedule, f64) {
        let (fmt, margin) = self.decide_for_slot_with_confidence(slot, coo, d, sw);
        (fmt, Schedule::effective(), margin)
    }

    /// Human-readable name for reports.
    fn policy_name(&self) -> String;
}

/// Uses `special` for slots whose name contains `needle`, `default`
/// elsewhere (the Fig-3 experiment: vary only the H1 storage format).
pub struct SlotTargetedPolicy {
    pub needle: &'static str,
    pub special: Format,
    pub default: Format,
}

impl FormatPolicy for SlotTargetedPolicy {
    fn decide(&mut self, _coo: &Coo, _d: usize, _sw: &mut Stopwatch) -> Format {
        self.default
    }

    fn decide_for_slot(
        &mut self,
        slot: &str,
        _coo: &Coo,
        _d: usize,
        _sw: &mut Stopwatch,
    ) -> Format {
        if slot.contains(self.needle) {
            self.special
        } else {
            self.default
        }
    }

    fn policy_name(&self) -> String {
        format!("slot[{}]={} else {}", self.needle, self.special, self.default)
    }
}

/// Always use one fixed format (the paper's baseline: COO, and the per-
/// format bars of Figs. 1/3).
pub struct StaticPolicy(pub Format);

impl FormatPolicy for StaticPolicy {
    fn decide(&mut self, _coo: &Coo, _d: usize, _sw: &mut Stopwatch) -> Format {
        self.0
    }

    fn policy_name(&self) -> String {
        format!("static-{}", self.0)
    }
}

/// Max recycled output buffers retained per slot. Forward + backward of a
/// two-layer model keep at most a handful of distinct output shapes alive
/// per slot; beyond that we let buffers drop rather than hoard memory.
const SLOT_POOL_CAP: usize = 4;

/// One sparse operand with its cached format decision, recycled output
/// workspaces and cached decision-path COO view.
pub struct Slot {
    pub name: String,
    /// The operand in its working representation (possibly converted to the
    /// decided format). An Arc-backed handle: binding a master here is a
    /// refcount bump, and conversion installs a *fresh* handle — the bound
    /// source is never written through (§Shared-Ownership).
    pub matrix: SharedMatrix,
    /// Identity of the operand as last bound (`add_slot`/`set_slot_matrix`),
    /// kept even after `matrix` is replaced by a converted representation —
    /// so rebinding the *same* handle is a no-op that preserves the
    /// decision, the conversion and the COO view. A **non-owning** weak
    /// token: after a conversion replaces the working copy, the original
    /// operand is freed, not pinned by provenance (a dead token simply
    /// never matches). `None` once the slot's content has been mutated
    /// away from any bound handle (`update_slot*` refresh paths): a later
    /// rebind of the old handle is then a real content change and must go
    /// through the decision path again.
    source: Option<WeakMatrix>,
    pub decided: Option<Format>,
    /// Kernel schedule of the current decision — what `spmm`/`spmm_t` hand
    /// the scheduled kernels. Meaningful only while `decided` is `Some`;
    /// re-decisions overwrite it together with the format.
    pub schedule: Schedule,
    pub density_at_decision: f64,
    /// Shape observed when the current decision was made. A refresh that
    /// changes the operand's shape (mini-batch H1 slots resize per shard)
    /// is a structure change the density dead-band alone can mask —
    /// density is nnz-per-cell, so a differently-sized matrix can sit
    /// within the drift band while its signature differs. `ensure`
    /// re-decides whenever the shape moved, regardless of drift.
    shape_at_decision: (usize, usize),
    /// Recycled output buffers (raw storage; resized on reuse). Populated
    /// by [`AdjEngine::recycle`], drained by `spmm`/`spmm_t`.
    pool: Vec<Vec<f32>>,
    /// COO view for the policy's decision path, built lazily and kept until
    /// the slot's *content* changes (conversions don't invalidate it).
    coo_view: Option<Coo>,
}

/// A recorded decision event (slot, chosen plan, density at decision).
#[derive(Clone, Debug)]
pub struct Decision {
    pub slot: String,
    pub format: Format,
    /// Kernel schedule chosen alongside the format.
    pub schedule: Schedule,
    pub density: f64,
    /// Answered by the decision cache (no COO view, no policy call).
    pub cached: bool,
}

/// How an engine holds its decision cache.
///
/// `Owned` is the training-side default: this engine is the only user, so
/// fresh decisions are stored back (the cache warms as the run proceeds).
/// `Shared` is the serving-side mode: many worker engines read **one**
/// warm cache through an `Arc` — lookups are lock-free (`&self` + atomic
/// counters), and fresh decisions are *used but not stored*, exactly like
/// the low-margin bypass: a read-only snapshot cache must never need a
/// writer lock on the hot path (DESIGN.md §Serving cache-sharing rule).
enum CacheRef {
    Owned(DecisionCache),
    Shared(Arc<DecisionCache>),
}

impl CacheRef {
    fn get(&self) -> &DecisionCache {
        match self {
            CacheRef::Owned(c) => c,
            CacheRef::Shared(c) => c,
        }
    }
}

/// The format-switching SpMM engine.
pub struct AdjEngine<'p> {
    pub slots: Vec<Slot>,
    pub policy: &'p mut dyn FormatPolicy,
    pub sw: Stopwatch,
    /// Relative density drift that triggers a re-decision (paper §4:
    /// "monitor the input matrix sparsity and dynamically adjust").
    pub redecide_rel_drift: f64,
    pub decisions: Vec<Decision>,
    /// Optional signature-keyed decision cache (mini-batch shard streams;
    /// see `predictor::cache`). Off by default: full-batch runs decide a
    /// handful of times and the paper's overhead accounting stays
    /// untouched.
    decision_cache: Option<CacheRef>,
}

impl<'p> AdjEngine<'p> {
    pub fn new(policy: &'p mut dyn FormatPolicy) -> AdjEngine<'p> {
        AdjEngine {
            slots: Vec::new(),
            policy,
            sw: Stopwatch::new(),
            redecide_rel_drift: 0.5,
            decisions: Vec::new(),
            decision_cache: None,
        }
    }

    /// Turn on the signature-keyed decision cache. The cache's hysteresis
    /// dead-band inherits [`AdjEngine::redecide_rel_drift`] (set the field
    /// first if a non-default band is wanted).
    pub fn enable_decision_cache(&mut self) {
        self.decision_cache = Some(CacheRef::Owned(DecisionCache::new(self.redecide_rel_drift)));
    }

    /// Install a pre-populated decision cache (warm start: a service loads
    /// the previous run's persisted cache and skips the cold first epoch).
    pub fn set_decision_cache(&mut self, cache: DecisionCache) {
        self.decision_cache = Some(CacheRef::Owned(cache));
    }

    /// Share a decision cache with other engines (the serving mode: many
    /// worker engines read one warm cache lock-free). A shared cache is
    /// **read-only** from this engine's perspective — fresh decisions are
    /// used but not stored, so no writer lock is ever needed on the hot
    /// path. Warm-start the cache (via [`DecisionCache::load`]) before
    /// sharing it if hits are expected.
    pub fn share_decision_cache(&mut self, cache: Arc<DecisionCache>) {
        self.decision_cache = Some(CacheRef::Shared(cache));
    }

    /// The decision cache, if enabled (hit/miss accounting for reports).
    pub fn decision_cache(&self) -> Option<&DecisionCache> {
        self.decision_cache.as_ref().map(|c| c.get())
    }

    /// Take ownership of the decision cache (to persist it after a run).
    /// Returns `None` for a shared cache — the `Arc` holders own it.
    pub fn take_decision_cache(&mut self) -> Option<DecisionCache> {
        match self.decision_cache.take() {
            Some(CacheRef::Owned(c)) => Some(c),
            other => {
                self.decision_cache = other;
                None
            }
        }
    }

    /// Register a sparse operand; returns its slot id.
    pub fn add_slot(&mut self, name: &str, coo: Coo) -> usize {
        self.add_slot_shared(name, SharedMatrix::from(coo))
    }

    /// Register a sparse operand by shared handle — the master stays
    /// co-owned by the caller, nothing is copied.
    pub fn add_slot_shared(&mut self, name: &str, m: SharedMatrix) -> usize {
        check_operand("add_slot_shared", name, &m);
        self.slots.push(Slot {
            name: name.to_string(),
            source: Some(m.downgrade()),
            matrix: m,
            decided: None,
            schedule: Schedule::effective(),
            density_at_decision: 0.0,
            shape_at_decision: (0, 0),
            pool: Vec::new(),
            coo_view: None,
        });
        self.slots.len() - 1
    }

    /// Replace a slot's contents (same conceptual operand, new values /
    /// pattern — e.g. a sparsified activation that changes every epoch).
    /// The format decision is kept unless density drifts.
    pub fn update_slot(&mut self, slot: usize, coo: Coo) {
        let m = SharedMatrix::from(coo);
        let s = &mut self.slots[slot];
        check_operand("update_slot", &s.name, &m);
        s.matrix = m;
        s.source = None;
        s.coo_view = None;
    }

    /// Rebind a slot to a **different operand** in whatever format it
    /// already carries — the mini-batch shard stream, where each batch's
    /// extracted submatrix (CSR from the direct extraction path) replaces
    /// the previous one. Binding is an O(1) handle install: no matrix data
    /// moves. The format decision is cleared — a new matrix deserves a
    /// fresh decision, which the decision cache answers in O(1) for
    /// structurally similar shards — **unless** the incoming handle is the
    /// very operand already bound (identity match on the slot's weak
    /// source token): then the slot's decision, conversion and COO view
    /// are all still literally about this matrix, and the rebind is a
    /// complete no-op (the per-epoch full-graph eval path).
    pub fn set_slot_matrix(&mut self, slot: usize, m: impl Into<SharedMatrix>) {
        let m = m.into();
        let s = &mut self.slots[slot];
        if s.source.as_ref().is_some_and(|src| src.is_handle_of(&m)) {
            return;
        }
        // After the identity short-circuit on purpose: the per-epoch
        // eval-flip rebind of an already-validated master must stay O(1)
        // and allocation-free (the bench_engine gate).
        check_operand("set_slot_matrix", &s.name, &m);
        s.source = Some(m.downgrade());
        s.matrix = m;
        s.coo_view = None;
        s.decided = None;
    }

    /// Refresh a slot whose **pattern is unchanged** with new values in
    /// pattern (row-major COO) order — the GAT attention path, where the
    /// softmax produces fresh coefficients on a fixed edge pattern every
    /// forward. COO/CSR/LIL store values in exactly this order, so the
    /// update is a value copy with no re-conversion (§Perf); other formats
    /// fall back to a rebuild.
    pub fn update_slot_values(&mut self, slot: usize, pattern: &Coo, vals: &[f32]) {
        debug_assert_eq!(pattern.nnz(), vals.len());
        self.slots[slot].coo_view = None;
        // Content diverges from whatever handle was bound: drop the source
        // identity so a later rebind of the old handle re-decides. (The
        // token is weak, so this has no bearing on the CoW below — only a
        // slot still sharing its payload with an external master pays one
        // copy, and is uniquely owned from then on.)
        self.slots[slot].source = None;
        // Check writability on a shared view before touching `to_mut`: a
        // variant mismatch falls through to a rebuild, and cloning the
        // payload just to discover that would be a wasted deep copy.
        let can_in_place = match &*self.slots[slot].matrix {
            SparseMatrix::Coo(c) => c.val.len() == vals.len(),
            SparseMatrix::Csr(c) => c.vals.len() == vals.len(),
            SparseMatrix::Lil(l) => l.nnz() == vals.len(),
            _ => false,
        };
        let replaced = can_in_place
            && self.sw.phase("sparsify", || {
                match self.slots[slot].matrix.to_mut() {
                    SparseMatrix::Coo(c) if c.val.len() == vals.len() => {
                        c.val.copy_from_slice(vals);
                        true
                    }
                    SparseMatrix::Csr(c) if c.vals.len() == vals.len() => {
                        c.vals.copy_from_slice(vals);
                        true
                    }
                    SparseMatrix::Lil(l) if l.nnz() == vals.len() => {
                        let mut i = 0;
                        for row in &mut l.rows_data {
                            for entry in row.iter_mut() {
                                entry.1 = vals[i];
                                i += 1;
                            }
                        }
                        true
                    }
                    _ => false,
                }
            });
        if !replaced {
            let coo = Coo {
                rows: pattern.rows,
                cols: pattern.cols,
                row: pattern.row.clone(),
                col: pattern.col.clone(),
                val: vals.to_vec(),
            };
            self.update_slot(slot, coo);
        }
    }

    /// Refresh a slot from a dense activation, sparsifying **directly into
    /// the decided format** (single pass, no COO hop + re-conversion).
    ///
    /// This is the §Perf optimization for per-epoch refreshed operands
    /// (GCN/GAT/… layer-1 outputs): the static-COO baseline and the
    /// predicted policy now pay the same one-pass construction cost, so the
    /// measured difference is the SpMM kernels — matching the paper's
    /// accounting, where a layer output materializes straight into its
    /// chosen format. Cost is charged to the `sparsify` phase.
    pub fn update_slot_dense(&mut self, slot: usize, dense: &Matrix) {
        let target = self.slots[slot].decided;
        let built = self.sw.phase("sparsify", || match target {
            Some(fmt) => SparseMatrix::from_dense(dense, fmt)
                .unwrap_or_else(|_| SparseMatrix::Csr(crate::sparse::Csr::from_dense(dense))),
            None => SparseMatrix::Coo(Coo::from_dense(dense)),
        });
        self.slots[slot].matrix = SharedMatrix::from(built);
        self.slots[slot].source = None;
        self.slots[slot].coo_view = None;
    }

    /// Current density of a slot.
    pub fn density(&self, slot: usize) -> f64 {
        self.slots[slot].matrix.density()
    }

    /// Make sure the slot is stored in the policy-chosen format, deciding /
    /// re-deciding and converting as needed.
    fn ensure(&mut self, slot: usize, d: usize) {
        let density = self.slots[slot].matrix.density();
        let shape = self.slots[slot].matrix.ops().shape();
        let need_decision = match self.slots[slot].decided {
            None => true,
            Some(_) => {
                // Structure change first: a refresh that resized the
                // operand invalidates the decision outright — the density
                // dead-band below must never mask a signature change
                // (shape is part of the decision-cache signature).
                if shape != self.slots[slot].shape_at_decision {
                    true
                } else {
                    let base = self.slots[slot].density_at_decision.max(1e-12);
                    (density - base).abs() / base > self.redecide_rel_drift
                }
            }
        };
        if need_decision {
            let name = self.slots[slot].name.clone();
            // Cache first: the signature reads O(1) header fields, so a hit
            // skips both the COO view and the policy (feature extraction /
            // inference) entirely — the mini-batch amortization.
            let (rows, cols) = shape;
            let nnz = self.slots[slot].matrix.nnz();
            let cached_plan = self
                .decision_cache
                .as_ref()
                .and_then(|c| c.get().lookup_plan(&name, rows, cols, nnz, density, d));
            let (fmt, sched, cached) = match cached_plan {
                Some((fmt, sched)) => (fmt, sched, true),
                None => {
                    // The policy inspects a COO view (cost charged by the
                    // policy); the view is cached across re-decisions until
                    // content changes.
                    if self.slots[slot].coo_view.is_none() {
                        let coo =
                            self.sw.phase("to_coo_view", || self.slots[slot].matrix.to_coo());
                        self.slots[slot].coo_view = Some(coo);
                    }
                    let coo = self.slots[slot].coo_view.take().unwrap();
                    let (fmt, sched, margin) =
                        self.policy.decide_plan_for_slot(&name, &coo, d, &mut self.sw);
                    self.slots[slot].coo_view = Some(coo);
                    if let Some(CacheRef::Owned(c)) = self.decision_cache.as_mut() {
                        // Low-margin predictions are *used* but not pinned:
                        // the cache declines them (see `store_plan`) so the
                        // hysteresis dead-band can't freeze a coin flip into
                        // a standing answer. A `Shared` cache is read-only
                        // by construction — skip the store.
                        c.store_plan(&name, rows, cols, nnz, density, d, fmt, sched, margin);
                    }
                    (fmt, sched, false)
                }
            };
            self.slots[slot].decided = Some(fmt);
            self.slots[slot].schedule = sched;
            self.slots[slot].density_at_decision = density;
            self.slots[slot].shape_at_decision = shape;
            self.decisions.push(Decision {
                slot: name,
                format: fmt,
                schedule: sched,
                density,
                cached,
            });
        }
        let fmt = self.slots[slot].decided.unwrap();
        if self.slots[slot].matrix.format() != fmt {
            let converted = self
                .sw
                .phase("convert", || self.slots[slot].matrix.convert(fmt))
                // A format that cannot hold this matrix (DIA budget): fall
                // back to CSR, like a library would.
                .unwrap_or_else(|_| {
                    self.slots[slot]
                        .matrix
                        .convert(Format::Csr)
                        .expect("CSR conversion cannot fail")
                });
            // Conversion preserves content: the cached COO view stays
            // valid, and so does the bound-source identity — a later rebind
            // of the same master handle is still a no-op. The converted
            // representation gets a fresh handle; the source (possibly a
            // co-owned master) is released untouched.
            self.slots[slot].matrix = SharedMatrix::from(converted);
        }
    }

    /// Pop a recycled buffer (or allocate) sized for `len` elements.
    fn take_buf(&mut self, slot: usize, len: usize) -> Vec<f32> {
        let mut buf = self.slots[slot].pool.pop().unwrap_or_default();
        buf.resize(len, 0.0);
        buf
    }

    /// Return an output matrix obtained from [`AdjEngine::spmm`] /
    /// [`AdjEngine::spmm_t`] on `slot` so its buffer backs a later call.
    /// Purely an optimization — unreturned matrices are simply freed.
    pub fn recycle(&mut self, slot: usize, m: Matrix) {
        let pool = &mut self.slots[slot].pool;
        if pool.len() < SLOT_POOL_CAP {
            pool.push(m.into_buffer());
        }
    }

    /// Format-dispatched SpMM on a slot: `slots[slot] · x`. The output is
    /// backed by the slot's workspace pool when a recycled buffer exists.
    pub fn spmm(&mut self, slot: usize, x: &Matrix) -> Matrix {
        self.ensure(slot, x.cols);
        let rows = self.slots[slot].matrix.rows();
        let mut out = Matrix::from_buffer(rows, x.cols, self.take_buf(slot, rows * x.cols));
        let sched = self.slots[slot].schedule;
        let m = &self.slots[slot].matrix;
        self.sw.phase("spmm", || m.spmm_into_with(x, &mut out, sched));
        out
    }

    /// Transpose-SpMM on a slot: `slots[slot]ᵀ · x`, executed transpose-free
    /// on the slot's existing arrays (no transposed copy is ever stored).
    /// This is the backward-pass entry point for every GNN model.
    pub fn spmm_t(&mut self, slot: usize, x: &Matrix) -> Matrix {
        self.ensure(slot, x.cols);
        let cols = self.slots[slot].matrix.cols();
        let mut out = Matrix::from_buffer(cols, x.cols, self.take_buf(slot, cols * x.cols));
        let sched = self.slots[slot].schedule;
        let m = &self.slots[slot].matrix;
        self.sw.phase("spmm_t", || m.spmm_t_into_with(x, &mut out, sched));
        out
    }

    /// The format a slot currently uses (after any decision).
    pub fn slot_format(&self, slot: usize) -> Option<Format> {
        self.slots[slot].decided
    }

    /// The kernel schedule a slot's multiplies run under (after any
    /// decision; the process default before one is made).
    pub fn slot_schedule(&self, slot: usize) -> Schedule {
        self.slots[slot].schedule
    }

    /// Total engine-attributed time (spmm + conversions + policy overhead).
    pub fn total_time(&self) -> f64 {
        self.sw.grand_total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, n: usize, density: f64) -> Coo {
        let mut triples = Vec::new();
        for r in 0..n {
            for c in 0..n {
                if rng.bernoulli(density) {
                    triples.push((r as u32, c as u32, rng.uniform(-1.0, 1.0) as f32));
                }
            }
        }
        Coo::from_triples(n, n, triples)
    }

    #[test]
    fn static_policy_converts_once_and_reuses() {
        let mut rng = Rng::new(1);
        let coo = random_coo(&mut rng, 32, 0.1);
        let x = Matrix::rand(32, 4, &mut rng);
        let want = coo.to_dense().matmul(&x);

        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        let slot = engine.add_slot("A", coo);
        let y1 = engine.spmm(slot, &x);
        let y2 = engine.spmm(slot, &x);
        assert!(y1.max_abs_diff(&want) < 1e-4);
        assert!(y2.max_abs_diff(&want) < 1e-4);
        assert_eq!(engine.slot_format(slot), Some(Format::Csr));
        // Only one decision + one conversion happened.
        assert_eq!(engine.decisions.len(), 1);
    }

    #[test]
    fn spmm_t_matches_explicit_transpose() {
        let mut rng = Rng::new(6);
        let coo = random_coo(&mut rng, 48, 0.1);
        let x = Matrix::rand(48, 5, &mut rng);
        let want = coo.to_dense().transpose().matmul(&x);
        for fmt in [Format::Coo, Format::Csr, Format::Csc, Format::Bsr, Format::Dok, Format::Lil]
        {
            let mut policy = StaticPolicy(fmt);
            let mut engine = AdjEngine::new(&mut policy);
            let slot = engine.add_slot("A", coo.clone());
            let got = engine.spmm_t(slot, &x);
            assert!(got.max_abs_diff(&want) < 1e-4, "{fmt}");
            assert!(engine.sw.total("spmm_t") > 0.0);
        }
    }

    #[test]
    fn recycled_buffers_are_reused() {
        let mut rng = Rng::new(7);
        let coo = random_coo(&mut rng, 40, 0.1);
        let x = Matrix::rand(40, 4, &mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        let slot = engine.add_slot("A", coo);
        let y1 = engine.spmm(slot, &x);
        let want = y1.clone();
        let ptr = y1.data.as_ptr() as usize;
        engine.recycle(slot, y1);
        // Same shape → the recycled allocation backs the next output.
        let y2 = engine.spmm(slot, &x);
        assert_eq!(y2.data.as_ptr() as usize, ptr);
        assert!(y2.max_abs_diff(&want) < 1e-6);
        // A different width reuses the storage too (resized).
        let x2 = Matrix::rand(40, 2, &mut rng);
        engine.recycle(slot, y2);
        let y3 = engine.spmm(slot, &x2);
        assert_eq!(y3.shape(), (40, 2));
    }

    #[test]
    fn coo_view_cached_across_redecisions() {
        let mut rng = Rng::new(8);
        let a = random_coo(&mut rng, 64, 0.1);
        let x = Matrix::rand(64, 3, &mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        let slot = engine.add_slot("A", a.clone());
        let _ = engine.spmm(slot, &x);
        let views_after_first = engine.sw.report();
        let first = views_after_first
            .iter()
            .find(|r| r.0 == "to_coo_view")
            .map(|r| r.2)
            .unwrap_or(0);
        assert_eq!(first, 1);
        // Force a re-decision without changing content: the cached view is
        // reused, so no second to_coo materialization happens.
        engine.slots[slot].decided = None;
        let _ = engine.spmm(slot, &x);
        let second = engine
            .sw
            .report()
            .iter()
            .find(|r| r.0 == "to_coo_view")
            .map(|r| r.2)
            .unwrap_or(0);
        assert_eq!(second, 1, "cached COO view should be reused");
        // Content update invalidates the cache.
        engine.update_slot(slot, a);
        engine.slots[slot].decided = None;
        let _ = engine.spmm(slot, &x);
        let third = engine
            .sw
            .report()
            .iter()
            .find(|r| r.0 == "to_coo_view")
            .map(|r| r.2)
            .unwrap_or(0);
        assert_eq!(third, 2, "content update must rebuild the COO view");
    }

    #[test]
    fn set_slot_matrix_clears_decision_and_keeps_format() {
        let mut rng = Rng::new(21);
        let a = random_coo(&mut rng, 32, 0.1);
        let b = random_coo(&mut rng, 32, 0.1);
        let x = Matrix::rand(32, 4, &mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        let slot = engine.add_slot("A", a);
        let _ = engine.spmm(slot, &x);
        assert_eq!(engine.decisions.len(), 1);
        // Rebinding with an already-CSR matrix: decision re-made, no
        // conversion needed afterwards (the matrix is already in the
        // decided format).
        let csr = SparseMatrix::Csr(crate::sparse::Csr::from_coo(&b));
        engine.set_slot_matrix(slot, csr);
        assert_eq!(engine.slot_format(slot), None);
        let want = b.to_dense().matmul(&x);
        let y = engine.spmm(slot, &x);
        assert!(y.max_abs_diff(&want) < 1e-4);
        assert_eq!(engine.decisions.len(), 2);
        let converts = engine.sw.report().iter().find(|r| r.0 == "convert").map(|r| r.2).unwrap_or(0);
        assert_eq!(converts, 1, "only the first decision should convert");
    }

    /// Regression (ISSUE-4): a refresh that changes the operand's shape
    /// must re-decide even when the density sits inside the drift
    /// dead-band. `update_slot` keeps the decision across same-structure
    /// refreshes; before the shape anchor, a same-density matrix of a
    /// different size silently kept the stale decision (the mini-batch H1
    /// slot resizes every shard).
    #[test]
    fn shape_change_redecides_despite_density_dead_band() {
        let mut rng = Rng::new(24);
        let small = random_coo(&mut rng, 64, 0.1);
        let x64 = Matrix::rand(64, 4, &mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        let slot = engine.add_slot("H1", small);
        let _ = engine.spmm(slot, &x64);
        assert_eq!(engine.decisions.len(), 1);
        // Same-shape, near-identical density: the dead-band holds.
        engine.update_slot(slot, random_coo(&mut rng, 64, 0.1));
        let _ = engine.spmm(slot, &x64);
        assert_eq!(engine.decisions.len(), 1, "dead-band should hold decision");
        // 2× the rows at the same density: structure signature changed —
        // the decision must be re-made even though drift is ~0.
        let big = {
            let mut triples = Vec::new();
            for r in 0..128u32 {
                for c in 0..128u32 {
                    if rng.bernoulli(0.1) {
                        triples.push((r, c, 1.0f32));
                    }
                }
            }
            Coo::from_triples(128, 128, triples)
        };
        let x128 = Matrix::rand(128, 4, &mut rng);
        engine.update_slot(slot, big);
        let _ = engine.spmm(slot, &x128);
        assert_eq!(engine.decisions.len(), 2, "shape change must re-decide");
    }

    /// Regression (ISSUE-4): rebinding a slot to a structurally different
    /// matrix goes back through the decision cache with the **new**
    /// signature — the stale entry (anchored on the old structure) must
    /// not answer, dead-band or not.
    #[test]
    fn set_slot_matrix_structural_change_misses_cache() {
        let mut rng = Rng::new(25);
        let x = Matrix::rand(64, 4, &mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        engine.enable_decision_cache();
        let slot = engine.add_slot("A", random_coo(&mut rng, 64, 0.15));
        let _ = engine.spmm(slot, &x);
        assert_eq!(engine.decision_cache().unwrap().misses(), 1);
        // 4× the rows at the same density: different rows bucket ⇒ the
        // cached entry must not be served.
        let big = {
            let mut triples = Vec::new();
            for r in 0..256u32 {
                for c in 0..256u32 {
                    if rng.bernoulli(0.15) {
                        triples.push((r, c, 1.0f32));
                    }
                }
            }
            Coo::from_triples(256, 256, triples)
        };
        let x256 = Matrix::rand(256, 4, &mut rng);
        engine.set_slot_matrix(slot, SparseMatrix::Coo(big));
        let _ = engine.spmm(slot, &x256);
        let cache = engine.decision_cache().unwrap();
        assert_eq!(cache.misses(), 2, "structural rebind must miss the cache");
        assert_eq!(cache.hits(), 0);
        assert!(engine.decisions.iter().all(|d| !d.cached));
    }

    #[test]
    fn decision_cache_answers_similar_slot_streams() {
        let mut rng = Rng::new(22);
        let x = Matrix::rand(64, 4, &mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        engine.enable_decision_cache();
        // Density 0.15 keeps realized draws clear of the cache's
        // half-decade bucket boundaries (0.1 and 0.316).
        let slot = engine.add_slot("A", random_coo(&mut rng, 64, 0.15));
        let _ = engine.spmm(slot, &x);
        // First decision: miss (policy consulted, COO view built).
        assert_eq!(engine.decision_cache().unwrap().misses(), 1);
        assert_eq!(engine.decision_cache().unwrap().hits(), 0);
        let views_first = engine
            .sw
            .report()
            .iter()
            .find(|r| r.0 == "to_coo_view")
            .map(|r| r.2)
            .unwrap_or(0);
        assert_eq!(views_first, 1);
        // A stream of structurally similar matrices: every further decision
        // is a cache hit and never materializes a COO view.
        for _ in 0..5 {
            engine.set_slot_matrix(
                slot,
                SparseMatrix::Coo(random_coo(&mut rng, 64, 0.15)),
            );
            let _ = engine.spmm(slot, &x);
        }
        let cache = engine.decision_cache().unwrap();
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 5);
        assert!(cache.hit_rate() > 0.8);
        let views_after = engine
            .sw
            .report()
            .iter()
            .find(|r| r.0 == "to_coo_view")
            .map(|r| r.2)
            .unwrap_or(0);
        assert_eq!(views_after, 1, "cache hits must not build COO views");
        // Decisions record their provenance.
        assert!(!engine.decisions[0].cached);
        assert!(engine.decisions[1..].iter().all(|d| d.cached));
    }

    #[test]
    fn decision_cache_misses_on_structural_change() {
        let mut rng = Rng::new(23);
        let x = Matrix::rand(64, 4, &mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        engine.enable_decision_cache();
        let slot = engine.add_slot("A", random_coo(&mut rng, 64, 0.05));
        let _ = engine.spmm(slot, &x);
        // 6× denser: different density bucket (and beyond the dead-band).
        engine.set_slot_matrix(slot, SparseMatrix::Coo(random_coo(&mut rng, 64, 0.3)));
        let _ = engine.spmm(slot, &x);
        let cache = engine.decision_cache().unwrap();
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 0);
    }

    #[test]
    fn density_drift_triggers_redecision() {
        let mut rng = Rng::new(2);
        let sparse = random_coo(&mut rng, 64, 0.02);
        let dense = random_coo(&mut rng, 64, 0.4);
        let x = Matrix::rand(64, 3, &mut rng);

        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        let slot = engine.add_slot("H1", sparse);
        let _ = engine.spmm(slot, &x);
        assert_eq!(engine.decisions.len(), 1);
        // Update with 20× denser content → drift > 50% → re-decide.
        engine.update_slot(slot, dense);
        let _ = engine.spmm(slot, &x);
        assert_eq!(engine.decisions.len(), 2);
    }

    #[test]
    fn small_update_keeps_decision() {
        let mut rng = Rng::new(3);
        let a = random_coo(&mut rng, 64, 0.1);
        let b = random_coo(&mut rng, 64, 0.11); // ~10% drift < 50%
        let x = Matrix::rand(64, 3, &mut rng);
        let mut policy = StaticPolicy(Format::Lil);
        let mut engine = AdjEngine::new(&mut policy);
        let slot = engine.add_slot("H1", a);
        let _ = engine.spmm(slot, &x);
        engine.update_slot(slot, b);
        let _ = engine.spmm(slot, &x);
        assert_eq!(engine.decisions.len(), 1);
    }

    #[test]
    fn dia_budget_falls_back_to_csr() {
        // Anti-diagonal: every element on a distinct diagonal → n_diags = n,
        // footprint n² > DIA_BUDGET → conversion fails, engine must fall back.
        let n = 9000;
        let mut rng = Rng::new(4);
        let triples: Vec<_> = (0..n)
            .map(|i| (i as u32, (n - 1 - i) as u32, 1.0f32))
            .collect();
        let coo = Coo::from_triples(n, n, triples);
        let x = Matrix::rand(n, 2, &mut rng);
        let want = {
            let csr = crate::sparse::Csr::from_coo(&coo);
            csr.spmm(&x)
        };
        let mut policy = StaticPolicy(Format::Dia);
        let mut engine = AdjEngine::new(&mut policy);
        let slot = engine.add_slot("A", coo);
        let y = engine.spmm(slot, &x);
        assert!(y.max_abs_diff(&want) < 1e-4);
        assert_eq!(engine.slots[slot].matrix.format(), Format::Csr);
    }

    /// §Shared-Ownership: rebinding the **same handle** (the per-epoch
    /// eval path before dedicated eval slots existed) is a complete no-op —
    /// decision, conversion and COO view all survive, even after the slot
    /// converted its working representation away from the bound source.
    #[test]
    fn rebinding_same_handle_is_a_noop() {
        let mut rng = Rng::new(26);
        let master = SharedMatrix::from(random_coo(&mut rng, 48, 0.1));
        let x = Matrix::rand(48, 4, &mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        let slot = engine.add_slot_shared("A", master.clone());
        let want = master.to_dense().matmul(&x);
        let y1 = engine.spmm(slot, &x);
        assert!(y1.max_abs_diff(&want) < 1e-4);
        // COO master + CSR policy: the slot converted (fresh handle), the
        // master itself is untouched and still COO.
        assert_eq!(engine.slots[slot].matrix.format(), Format::Csr);
        assert_eq!(master.format(), Format::Coo);
        assert_eq!(engine.decisions.len(), 1);
        let converts =
            engine.sw.report().iter().find(|r| r.0 == "convert").map(|r| r.2).unwrap_or(0);
        assert_eq!(converts, 1);
        // Rebind the same handle: no new decision, no new conversion, the
        // converted working copy is kept.
        engine.set_slot_matrix(slot, master.clone());
        let y2 = engine.spmm(slot, &x);
        assert!(y2.max_abs_diff(&want) < 1e-4);
        assert_eq!(engine.decisions.len(), 1, "same-handle rebind must not re-decide");
        let converts_after =
            engine.sw.report().iter().find(|r| r.0 == "convert").map(|r| r.2).unwrap_or(0);
        assert_eq!(converts_after, 1, "same-handle rebind must not re-convert");
        assert_eq!(engine.slots[slot].matrix.format(), Format::Csr);
        // A *different* handle with identical content is still a rebind
        // (identity, not equality, is the key).
        let other = SharedMatrix::from(master.to_coo());
        engine.set_slot_matrix(slot, other);
        let _ = engine.spmm(slot, &x);
        assert_eq!(engine.decisions.len(), 2, "new handle must re-decide");
    }

    /// §Shared-Ownership: binding a master never deep-copies it, and the
    /// slot's handle count returns to baseline after rebinds.
    #[test]
    fn slot_binding_shares_instead_of_copying() {
        let mut rng = Rng::new(27);
        let master = SharedMatrix::from(crate::sparse::Csr::from_coo(&random_coo(
            &mut rng, 40, 0.1,
        )));
        assert_eq!(master.strong_count(), 1);
        let x = Matrix::rand(40, 3, &mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        let slot = engine.add_slot_shared("A", master.clone());
        // Slot holds one working handle (the source identity is a weak
        // token), no copies.
        assert_eq!(master.strong_count(), 2);
        // Already CSR + CSR policy: no conversion, the master's own arrays
        // execute the kernel.
        let _ = engine.spmm(slot, &x);
        assert_eq!(master.strong_count(), 2, "no conversion, no copies");
        // Rebinds of the same handle don't accumulate references…
        for _ in 0..10 {
            engine.set_slot_matrix(slot, master.clone());
        }
        assert_eq!(master.strong_count(), 2);
        // …and binding something else releases the master entirely.
        engine.set_slot_matrix(slot, random_coo(&mut rng, 40, 0.1));
        assert_eq!(master.strong_count(), 1);
    }

    /// A policy with tunable confidence for the margin-bypass test.
    struct FixedConfidencePolicy {
        format: Format,
        margin: f64,
    }

    impl FormatPolicy for FixedConfidencePolicy {
        fn decide(&mut self, _coo: &Coo, _d: usize, _sw: &mut Stopwatch) -> Format {
            self.format
        }

        fn decide_for_slot_with_confidence(
            &mut self,
            _slot: &str,
            coo: &Coo,
            d: usize,
            sw: &mut Stopwatch,
        ) -> (Format, f64) {
            (self.decide(coo, d, sw), self.margin)
        }

        fn policy_name(&self) -> String {
            "fixed-confidence".to_string()
        }
    }

    /// Low-margin decisions are used once but never pinned: every
    /// structurally similar rebind consults the policy again instead of
    /// being answered by a cache entry the dead-band would freeze.
    #[test]
    fn low_margin_decisions_bypass_the_cache() {
        let mut rng = Rng::new(28);
        let x = Matrix::rand(64, 4, &mut rng);
        let mut policy = FixedConfidencePolicy { format: Format::Csr, margin: 0.01 };
        let mut engine = AdjEngine::new(&mut policy);
        engine.enable_decision_cache();
        let slot = engine.add_slot("A", random_coo(&mut rng, 64, 0.15));
        let _ = engine.spmm(slot, &x);
        for _ in 0..3 {
            engine.set_slot_matrix(slot, random_coo(&mut rng, 64, 0.15));
            let _ = engine.spmm(slot, &x);
        }
        let cache = engine.decision_cache().unwrap();
        assert_eq!(cache.hits(), 0, "low-margin answers must never be served");
        assert_eq!(cache.misses(), 4);
        assert_eq!(cache.len(), 0, "low-margin answers must not be stored");
        assert_eq!(cache.low_margin_bypasses(), 4);
        // Confident answers for the same stream do get pinned.
        let mut policy = FixedConfidencePolicy { format: Format::Csr, margin: 0.9 };
        let mut engine = AdjEngine::new(&mut policy);
        engine.enable_decision_cache();
        let mut rng = Rng::new(28);
        let _ = Matrix::rand(64, 4, &mut rng); // consume like above
        let slot = engine.add_slot("A", random_coo(&mut rng, 64, 0.15));
        let _ = engine.spmm(slot, &x);
        for _ in 0..3 {
            engine.set_slot_matrix(slot, random_coo(&mut rng, 64, 0.15));
            let _ = engine.spmm(slot, &x);
        }
        let cache = engine.decision_cache().unwrap();
        assert_eq!(cache.hits(), 3);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.low_margin_bypasses(), 0);
    }

    /// A schedule-aware policy for the plan-propagation test: always CSR,
    /// but under a non-default kernel schedule.
    struct FixedPlanPolicy(Schedule);

    impl FormatPolicy for FixedPlanPolicy {
        fn decide(&mut self, _coo: &Coo, _d: usize, _sw: &mut Stopwatch) -> Format {
            Format::Csr
        }

        fn decide_plan_for_slot(
            &mut self,
            _slot: &str,
            _coo: &Coo,
            _d: usize,
            _sw: &mut Stopwatch,
        ) -> (Format, Schedule, f64) {
            (Format::Csr, self.0, 1.0)
        }

        fn policy_name(&self) -> String {
            format!("fixed-plan[{}]", self.0.label())
        }
    }

    /// The policy's schedule propagates end to end: into the slot (so the
    /// kernels run under it), into the decision log, into the cache — and a
    /// cache hit on a structurally similar rebind hands back the **complete
    /// plan**, not just the format.
    #[test]
    fn schedule_flows_through_decisions_and_cache() {
        use crate::sparse::{Split, ThreadCap, Tile};
        let plan = Schedule {
            tile: Tile::T4,
            split: Split::EvenUnits,
            threads: ThreadCap::Cap(1),
        };
        let mut rng = Rng::new(29);
        let x = Matrix::rand(64, 4, &mut rng);
        let coo = random_coo(&mut rng, 64, 0.15);
        let want = coo.to_dense().matmul(&x);
        let mut policy = FixedPlanPolicy(plan);
        let mut engine = AdjEngine::new(&mut policy);
        engine.enable_decision_cache();
        let slot = engine.add_slot("A", coo);
        let y = engine.spmm(slot, &x);
        assert!(y.max_abs_diff(&want) < 1e-3, "scheduled kernel must stay correct");
        assert_eq!(engine.slot_schedule(slot), plan);
        assert_eq!(engine.decisions[0].schedule, plan);
        assert!(!engine.decisions[0].cached);
        // Structurally similar rebind: the cache answers with the full plan.
        engine.set_slot_matrix(slot, SparseMatrix::Coo(random_coo(&mut rng, 64, 0.15)));
        let _ = engine.spmm(slot, &x);
        assert_eq!(engine.decision_cache().unwrap().hits(), 1);
        assert!(engine.decisions[1].cached);
        assert_eq!(engine.decisions[1].schedule, plan);
        assert_eq!(engine.slot_schedule(slot), plan);
        // Format-only policies keep the process-default schedule.
        let mut plain = StaticPolicy(Format::Csr);
        let mut engine2 = AdjEngine::new(&mut plain);
        let mut rng2 = Rng::new(30);
        let slot2 = engine2.add_slot("B", random_coo(&mut rng2, 32, 0.1));
        let _ = engine2.spmm(slot2, &Matrix::rand(32, 4, &mut rng2));
        assert_eq!(engine2.slot_schedule(slot2), Schedule::effective());
    }

    #[test]
    #[should_panic(expected = "set_slot_matrix(A)")]
    fn binding_a_malformed_operand_panics() {
        let mut rng = Rng::new(17);
        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        let slot = engine.add_slot("A", random_coo(&mut rng, 32, 0.1));
        // Torn CSR: indptr no longer ends at nnz — the always-on
        // validate_quick tier must refuse the bind.
        let mut csr = crate::sparse::Csr::from_coo(&random_coo(&mut rng, 32, 0.1));
        csr.indptr.pop();
        engine.set_slot_matrix(slot, SparseMatrix::Csr(csr));
    }

    #[test]
    fn rebinding_the_same_handle_skips_the_operand_gate() {
        // The identity short-circuit must stay ahead of validation: the
        // eval-flip rebind of an already-bound master is a no-op.
        let mut rng = Rng::new(18);
        let mut policy = StaticPolicy(Format::Csr);
        let mut engine = AdjEngine::new(&mut policy);
        let master = SharedMatrix::from(random_coo(&mut rng, 32, 0.1));
        let slot = engine.add_slot_shared("A", master.clone());
        let x = Matrix::rand(32, 4, &mut rng);
        let _ = engine.spmm(slot, &x);
        engine.set_slot_matrix(slot, master.clone());
        assert!(engine.slot_format(slot).is_some(), "no-op rebind keeps the decision");
    }

    #[test]
    fn overhead_is_charged() {
        let mut rng = Rng::new(5);
        let coo = random_coo(&mut rng, 32, 0.1);
        let x = Matrix::rand(32, 4, &mut rng);
        let mut policy = StaticPolicy(Format::Bsr);
        let mut engine = AdjEngine::new(&mut policy);
        let slot = engine.add_slot("A", coo);
        let _ = engine.spmm(slot, &x);
        assert!(engine.sw.total("spmm") > 0.0);
        assert!(engine.sw.total("convert") > 0.0);
        assert!(engine.total_time() > 0.0);
    }
}
