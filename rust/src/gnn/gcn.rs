//! Graph Convolutional Network (Kipf & Welling [18]) — two layers:
//!
//! ```text
//! H1     = ReLU( Â · (X · W0) + b0 )         X sparse (bag-of-words)
//! logits = Â · (H1 · W1) + b1                H1 sparsified per epoch
//! ```
//!
//! Every sparse product is a format-managed engine slot: `X`, `Â` per layer
//! (the paper decides per GNN layer), and the sparsified intermediate `H1`
//! whose density drifts over training — the effect driving the paper's
//! Fig. 2/3. Weight gradients (`Xᵀ·dZ`, `H1ᵀ·dZ`) run through
//! [`AdjEngine::spmm_t`] on the *same* slots — no duplicate transposed
//! slots, no per-epoch dense transposes (§Perf).

use super::adam::Adam;
use super::engine::AdjEngine;
use crate::graph::GraphDataset;
use crate::sparse::{Coo, SharedMatrix};
use crate::tensor::{ops, Matrix};
use crate::util::rng::Rng;

/// Engine slot ids for one graph binding — the train/shard binding every
/// model starts with, or the dedicated full-graph eval binding created by
/// `bind_eval_graph` (§Shared-Ownership double-buffering).
#[derive(Clone, Copy)]
struct GcnSlots {
    x: usize,
    a1: usize,
    a2: usize,
    h1: usize,
}

/// Two-layer GCN with sparse intermediate storage.
pub struct Gcn {
    pub w0: Matrix,
    pub b0: Vec<f32>,
    pub w1: Matrix,
    pub b1: Vec<f32>,
    adam: Adam,
    /// Slots the forward/backward passes currently run on.
    slots: GcnSlots,
    train_slots: GcnSlots,
    /// Double-buffered full-graph eval slots, bound once (`bind_eval_graph`).
    eval_slots: Option<GcnSlots>,
    cache: Option<Cache>,
}

struct Cache {
    s0_pre: Matrix,
    h1_density: f64,
}

/// One backward pass's parameter gradients — the mini-batch accumulation
/// unit (grads are summed shard-weighted across batches, then applied in a
/// single optimizer step; see `gnn::minibatch`).
pub struct GcnGrads {
    pub dw0: Matrix,
    pub db0: Vec<f32>,
    pub dw1: Matrix,
    pub db1: Vec<f32>,
}

impl GcnGrads {
    /// `self += w · other` (shard-weighted gradient accumulation).
    pub fn add_scaled(&mut self, o: &GcnGrads, w: f32) {
        ops::axpy_slice(&mut self.dw0.data, &o.dw0.data, w);
        ops::axpy_slice(&mut self.db0, &o.db0, w);
        ops::axpy_slice(&mut self.dw1.data, &o.dw1.data, w);
        ops::axpy_slice(&mut self.db1, &o.db1, w);
    }

    /// `self *= w`.
    pub fn scale(&mut self, w: f32) {
        ops::scale_slice(&mut self.dw0.data, w);
        ops::scale_slice(&mut self.db0, w);
        ops::scale_slice(&mut self.dw1.data, w);
        ops::scale_slice(&mut self.db1, w);
    }
}

impl Gcn {
    /// Build the model and register its sparse operands as engine slots.
    pub fn new(
        ds: &GraphDataset,
        hidden: usize,
        lr: f32,
        rng: &mut Rng,
        eng: &mut AdjEngine,
    ) -> Gcn {
        let d = ds.features.cols;
        let c = ds.n_classes;
        let w0 = Matrix::glorot(d, hidden, rng);
        let w1 = Matrix::glorot(hidden, c, rng);
        let adam = Adam::new(&[w0.data.len(), hidden, w1.data.len(), c], lr);
        let empty_h1 = Coo::from_triples(ds.adj.rows, hidden, vec![]);
        let train_slots = GcnSlots {
            x: eng.add_slot("gcn.X", ds.features.clone()),
            a1: eng.add_slot("gcn.A.l1", ds.adj_norm.clone()),
            a2: eng.add_slot("gcn.A.l2", ds.adj_norm.clone()),
            h1: eng.add_slot("gcn.H1", empty_h1),
        };
        Gcn {
            slots: train_slots,
            train_slots,
            eval_slots: None,
            w0,
            b0: vec![0.0; hidden],
            w1,
            b1: vec![0.0; c],
            adam,
            cache: None,
        }
    }

    /// Forward pass; returns logits (n × classes).
    pub fn forward(&mut self, eng: &mut AdjEngine) -> Matrix {
        let s = self.slots;
        let z0 = eng.spmm(s.x, &self.w0);
        let a1z0 = eng.spmm(s.a1, &z0);
        eng.recycle(s.x, z0);
        let s0_pre = ops::add_row(&a1z0, &self.b0);
        eng.recycle(s.a1, a1z0);
        let h1_dense = ops::relu(&s0_pre);
        // Store layer-1 output sparse — the paper's Fig-3 decision point.
        // Sparsified directly into the slot's decided format (§Perf); the
        // backward pass reads the same slot transpose-free via `spmm_t`.
        eng.update_slot_dense(s.h1, &h1_dense);
        let h1_density = eng.density(s.h1);
        let z1 = eng.spmm(s.h1, &self.w1);
        let a2z1 = eng.spmm(s.a2, &z1);
        eng.recycle(s.h1, z1);
        let logits = ops::add_row(&a2z1, &self.b1);
        eng.recycle(s.a2, a2z1);
        self.cache = Some(Cache { s0_pre, h1_density });
        logits
    }

    /// Backward pass from the loss gradient wrt logits, returning the
    /// parameter gradients **without** applying them — the mini-batch loop
    /// accumulates these across shards before a single optimizer step.
    pub fn backward_grads(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) -> GcnGrads {
        let cache = self.cache.take().expect("forward before backward");
        let s = self.slots;
        let db1 = ops::col_sums(dlogits);
        // dZ1 = Âᵀ·dlogits (Â symmetric).
        let dz1 = eng.spmm(s.a2, dlogits);
        // dW1 = H1ᵀ·dZ1 — transpose-free on the H1 slot.
        let dw1 = eng.spmm_t(s.h1, &dz1);
        // dH1 = dZ1·W1ᵀ, gated by ReLU.
        let dh1 = dz1.matmul_t(&self.w1);
        eng.recycle(s.a2, dz1);
        let ds0 = ops::relu_grad(&cache.s0_pre, &dh1);
        let db0 = ops::col_sums(&ds0);
        let dz0 = eng.spmm(s.a1, &ds0);
        // dW0 = Xᵀ·dZ0 — transpose-free on the X slot.
        let dw0 = eng.spmm_t(s.x, &dz0);
        eng.recycle(s.a1, dz0);
        GcnGrads { dw0, db0, dw1, db1 }
    }

    /// One Adam step from (possibly accumulated) gradients.
    pub fn apply_grads(&mut self, g: &GcnGrads) {
        self.adam.tick();
        self.adam.update_matrix(0, &mut self.w0, &g.dw0);
        self.adam.update(1, &mut self.b0, &g.db0);
        self.adam.update_matrix(2, &mut self.w1, &g.dw1);
        self.adam.update(3, &mut self.b1, &g.db1);
    }

    /// Backward + Adam step from the loss gradient wrt logits (the
    /// full-batch path: gradients applied immediately).
    pub fn backward(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) {
        let g = self.backward_grads(eng, dlogits);
        self.apply_grads(&g);
    }

    /// Point the model's **train slots** at a new (sub)graph: induced
    /// feature rows `x` and induced normalized adjacency `a` (both layers
    /// share it — one handle, not two copies). Shapes may differ per shard;
    /// the weights don't. H1 re-derives itself on the next forward. Also
    /// flips the model back onto the train slots if it was evaluating.
    pub fn set_graph(
        &mut self,
        eng: &mut AdjEngine,
        x: impl Into<SharedMatrix>,
        a: impl Into<SharedMatrix>,
    ) {
        self.slots = self.train_slots;
        let a = a.into();
        eng.set_slot_matrix(self.train_slots.x, x);
        eng.set_slot_matrix(self.train_slots.a1, a.clone());
        eng.set_slot_matrix(self.train_slots.a2, a);
    }

    /// Create the dedicated full-graph eval slots (once) and bind them to
    /// the shared masters — a refcount bump each, zero matrix-data copies.
    /// Per-epoch eval then flips onto them via [`Gcn::use_eval_graph`]:
    /// an O(1) id swap with no engine traffic at all, so format decisions,
    /// conversions and workspace pools persist across epochs.
    pub fn bind_eval_graph(&mut self, eng: &mut AdjEngine, x: SharedMatrix, a: SharedMatrix) {
        assert!(self.eval_slots.is_none(), "eval slots are bound once at startup");
        let n = a.rows();
        let hidden = self.b0.len();
        self.eval_slots = Some(GcnSlots {
            x: eng.add_slot_shared("gcn.X.eval", x),
            a1: eng.add_slot_shared("gcn.A.l1.eval", a.clone()),
            a2: eng.add_slot_shared("gcn.A.l2.eval", a),
            h1: eng.add_slot("gcn.H1.eval", Coo::from_triples(n, hidden, vec![])),
        });
    }

    /// Flip onto the full-graph eval slots ([`Gcn::bind_eval_graph`] first).
    pub fn use_eval_graph(&mut self) {
        self.slots = self.eval_slots.expect("bind_eval_graph before use_eval_graph");
    }

    /// Flip back onto the train/shard slots (`set_graph` also does this).
    pub fn use_train_graph(&mut self) {
        self.slots = self.train_slots;
    }

    /// Density of the sparsified layer-1 activation after the last forward
    /// (the paper's Fig-2 quantity).
    pub fn h1_density(&self) -> Option<f64> {
        self.cache.as_ref().map(|c| c.h1_density)
    }

    /// Copy trained parameters from a template model (serving replication:
    /// each worker builds its own model against its own engine, then takes
    /// the trained weights — optimizer state stays per-replica and unused,
    /// since serving is forward-only). Panics on shape mismatch.
    pub fn copy_weights_from(&mut self, other: &Gcn) {
        assert_eq!(self.w0.data.len(), other.w0.data.len(), "w0 shape mismatch");
        assert_eq!(self.w1.data.len(), other.w1.data.len(), "w1 shape mismatch");
        self.w0.data.copy_from_slice(&other.w0.data);
        self.b0.copy_from_slice(&other.b0);
        self.w1.data.copy_from_slice(&other.w1.data);
        self.b1.copy_from_slice(&other.b1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::engine::StaticPolicy;
    use crate::graph::DatasetSpec;
    use crate::sparse::Format;

    fn tiny_dataset(rng: &mut Rng) -> GraphDataset {
        let spec = DatasetSpec {
            name: "Tiny",
            n: 120,
            feat_dim: 24,
            adj_density: 0.05,
            feat_density: 0.15,
            n_classes: 3,
        };
        GraphDataset::generate(&spec, rng)
    }

    #[test]
    fn loss_decreases_over_training() {
        let mut rng = Rng::new(1);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Gcn::new(&ds, 16, 0.02, &mut rng, &mut eng);
        let mut losses = Vec::new();
        for _ in 0..30 {
            let logits = model.forward(&mut eng);
            let (loss, dlogits) =
                ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
            model.backward(&mut eng, &dlogits);
            losses.push(loss);
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.7),
            "loss should drop: {:?} -> {:?}",
            losses.first(),
            losses.last()
        );
    }

    #[test]
    fn learns_homophilous_labels() {
        let mut rng = Rng::new(2);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Gcn::new(&ds, 16, 0.02, &mut rng, &mut eng);
        for _ in 0..60 {
            let logits = model.forward(&mut eng);
            let (_, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
            model.backward(&mut eng, &dlogits);
        }
        let logits = model.forward(&mut eng);
        let acc = ops::masked_accuracy(&logits, &ds.labels, &ds.test_mask);
        assert!(acc > 0.6, "test accuracy {acc}");
    }

    #[test]
    fn same_result_under_every_format() {
        // The format choice must not change numerics, only speed.
        let mut rng = Rng::new(3);
        let ds = tiny_dataset(&mut rng);
        let mut logits_per_format = Vec::new();
        for fmt in [Format::Coo, Format::Csr, Format::Csc, Format::Bsr, Format::Lil, Format::Dok] {
            let mut rng2 = Rng::new(99);
            let mut policy = StaticPolicy(fmt);
            let mut eng = AdjEngine::new(&mut policy);
            let mut model = Gcn::new(&ds, 8, 0.02, &mut rng2, &mut eng);
            for _ in 0..3 {
                let logits = model.forward(&mut eng);
                let (_, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
                model.backward(&mut eng, &dlogits);
            }
            logits_per_format.push(model.forward(&mut eng));
        }
        for other in &logits_per_format[1..] {
            let diff = logits_per_format[0].max_abs_diff(other);
            assert!(diff < 2e-2, "formats diverged: {diff}");
        }
    }

    #[test]
    fn h1_density_reported() {
        let mut rng = Rng::new(4);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Gcn::new(&ds, 16, 0.02, &mut rng, &mut eng);
        let _ = model.forward(&mut eng);
        let d = model.h1_density().unwrap();
        assert!(d > 0.0 && d <= 1.0);
    }
}
