//! Efficient Graph Convolution (Tailor et al. [28], EGC-S) — per-node
//! learned combination of B basis aggregations:
//!
//! ```text
//! S      = softmax_rows(H · Ws)              (n × B combination weights)
//! P_b    = Â · (H · W_b)                     (B basis aggregations)
//! H'     = ReLU( Σ_b diag(S_b) · P_b + bias )
//! ```
//!
//! B = 2 bases; aggregations remain plain SpMMs, so format selection hits
//! the same hot path as GCN with twice the SpMM traffic.

use super::adam::Adam;
use super::engine::AdjEngine;
use crate::graph::GraphDataset;
use crate::sparse::Coo;
use crate::tensor::{ops, Matrix};
use crate::util::rng::Rng;

/// Number of basis aggregators.
pub const N_BASES: usize = 2;

struct EgcLayer {
    w: Vec<Matrix>,
    ws: Matrix,
    bias: Vec<f32>,
}

impl EgcLayer {
    fn new(d_in: usize, d_out: usize, rng: &mut Rng) -> EgcLayer {
        EgcLayer {
            w: (0..N_BASES).map(|_| Matrix::glorot(d_in, d_out, rng)).collect(),
            ws: Matrix::glorot(d_in, N_BASES, rng),
            bias: vec![0.0; d_out],
        }
    }
}

/// Two-layer EGC-S.
pub struct Egc {
    l1: EgcLayer,
    l2: EgcLayer,
    adam: Adam,
    s_x: usize,
    s_a1: usize,
    s_a2: usize,
    s_h1: usize,
    cache: Option<Cache>,
}

struct Cache {
    s1: Matrix,
    p1: Vec<Matrix>,
    pre1: Matrix,
    s2: Matrix,
    p2: Vec<Matrix>,
}

/// `out[r] = Σ_c a[r,c]·b[r,c]` — rowwise dot products.
fn row_dots(a: &Matrix, b: &Matrix) -> Vec<f32> {
    assert_eq!(a.shape(), b.shape());
    (0..a.rows)
        .map(|r| a.row(r).iter().zip(b.row(r).iter()).map(|(&x, &y)| x * y).sum())
        .collect()
}

fn scale_rows_by(m: &Matrix, s: &[f32]) -> Matrix {
    let mut out = m.clone();
    for r in 0..out.rows {
        let f = s[r];
        for v in out.row_mut(r) {
            *v *= f;
        }
    }
    out
}

impl Egc {
    pub fn new(
        ds: &GraphDataset,
        hidden: usize,
        lr: f32,
        rng: &mut Rng,
        eng: &mut AdjEngine,
    ) -> Egc {
        let l1 = EgcLayer::new(ds.features.cols, hidden, rng);
        let l2 = EgcLayer::new(hidden, ds.n_classes, rng);
        let mut sizes = Vec::new();
        for l in [&l1, &l2] {
            for w in &l.w {
                sizes.push(w.data.len());
            }
            sizes.push(l.ws.data.len());
            sizes.push(l.bias.len());
        }
        let adam = Adam::new(&sizes, lr);
        let n = ds.adj.rows;
        Egc {
            s_x: eng.add_slot("egc.X", ds.features.clone()),
            s_a1: eng.add_slot("egc.A.l1", ds.adj_norm.clone()),
            s_a2: eng.add_slot("egc.A.l2", ds.adj_norm.clone()),
            s_h1: eng.add_slot("egc.H1", Coo::from_triples(n, hidden, vec![])),
            l1,
            l2,
            adam,
            cache: None,
        }
    }

    fn layer_forward(
        layer: &EgcLayer,
        eng: &mut AdjEngine,
        s_in: usize,
        s_a: usize,
    ) -> (Matrix, Vec<Matrix>, Matrix) {
        let s_logits = eng.spmm(s_in, &layer.ws);
        let s = ops::softmax_rows(&s_logits);
        let mut pre: Option<Matrix> = None;
        let mut ps = Vec::with_capacity(N_BASES);
        for b in 0..N_BASES {
            let zw = eng.spmm(s_in, &layer.w[b]);
            let p = eng.spmm(s_a, &zw);
            let sb: Vec<f32> = (0..s.rows).map(|r| s.at(r, b)).collect();
            let contrib = scale_rows_by(&p, &sb);
            pre = Some(match pre {
                None => contrib,
                Some(acc) => ops::add(&acc, &contrib),
            });
            ps.push(p);
        }
        let pre = ops::add_row(&pre.unwrap(), &layer.bias);
        (s, ps, pre)
    }

    /// Returns (dinput, dws, dw[b], dbias). All `inputᵀ·…` products run
    /// transpose-free through `spmm_t` on the forward input slot.
    fn layer_backward(
        layer: &EgcLayer,
        eng: &mut AdjEngine,
        s_in: usize,
        s_a: usize,
        s: &Matrix,
        ps: &[Matrix],
        dpre: &Matrix,
    ) -> (Matrix, Matrix, Vec<Matrix>, Vec<f32>) {
        let dbias = ops::col_sums(dpre);
        // dS_b = rowdot(P_b, dpre); softmax backward.
        let mut ds = Matrix::zeros(s.rows, N_BASES);
        for (b, p) in ps.iter().enumerate() {
            for (r, v) in row_dots(p, dpre).into_iter().enumerate() {
                *ds.at_mut(r, b) = v;
            }
        }
        let mut dslogits = Matrix::zeros(s.rows, N_BASES);
        for r in 0..s.rows {
            let dot: f32 = (0..N_BASES).map(|b| s.at(r, b) * ds.at(r, b)).sum();
            for b in 0..N_BASES {
                *dslogits.at_mut(r, b) = s.at(r, b) * (ds.at(r, b) - dot);
            }
        }
        let dws = eng.spmm_t(s_in, &dslogits);
        let mut dinput = dslogits.matmul_t(&layer.ws);
        let mut dw = Vec::with_capacity(N_BASES);
        for b in 0..N_BASES {
            let sb: Vec<f32> = (0..s.rows).map(|r| s.at(r, b)).collect();
            let dp = scale_rows_by(dpre, &sb);
            let dzw = eng.spmm(s_a, &dp); // Âᵀ = Â
            dw.push(eng.spmm_t(s_in, &dzw));
            dinput = ops::add(&dinput, &dzw.matmul_t(&layer.w[b]));
            eng.recycle(s_a, dzw);
        }
        (dinput, dws, dw, dbias)
    }

    pub fn forward(&mut self, eng: &mut AdjEngine) -> Matrix {
        let (s1, p1, pre1) = Self::layer_forward(&self.l1, eng, self.s_x, self.s_a1);
        let h1_dense = ops::relu(&pre1);
        eng.update_slot_dense(self.s_h1, &h1_dense);
        let (s2, p2, logits) = Self::layer_forward(&self.l2, eng, self.s_h1, self.s_a2);
        self.cache = Some(Cache { s1, p1, pre1, s2, p2 });
        logits
    }

    pub fn backward(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) {
        let cache = self.cache.take().expect("forward before backward");
        let (dh1, dws2, dw2, db2) = Self::layer_backward(
            &self.l2, eng, self.s_h1, self.s_a2, &cache.s2, &cache.p2, dlogits,
        );
        let dpre1 = ops::relu_grad(&cache.pre1, &dh1);
        let (_dx, dws1, dw1, db1) = Self::layer_backward(
            &self.l1, eng, self.s_x, self.s_a1, &cache.s1, &cache.p1, &dpre1,
        );
        self.adam.tick();
        let mut idx = 0;
        for b in 0..N_BASES {
            self.adam.update_matrix(idx, &mut self.l1.w[b], &dw1[b]);
            idx += 1;
        }
        self.adam.update_matrix(idx, &mut self.l1.ws, &dws1);
        idx += 1;
        self.adam.update(idx, &mut self.l1.bias, &db1);
        idx += 1;
        for b in 0..N_BASES {
            self.adam.update_matrix(idx, &mut self.l2.w[b], &dw2[b]);
            idx += 1;
        }
        self.adam.update_matrix(idx, &mut self.l2.ws, &dws2);
        idx += 1;
        self.adam.update(idx, &mut self.l2.bias, &db2);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::engine::StaticPolicy;
    use crate::graph::DatasetSpec;
    use crate::sparse::Format;

    fn tiny_dataset(rng: &mut Rng) -> GraphDataset {
        let spec = DatasetSpec {
            name: "Tiny",
            n: 100,
            feat_dim: 20,
            adj_density: 0.06,
            feat_density: 0.2,
            n_classes: 3,
        };
        GraphDataset::generate(&spec, rng)
    }

    #[test]
    fn loss_decreases() {
        let mut rng = Rng::new(1);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Egc::new(&ds, 12, 0.02, &mut rng, &mut eng);
        let mut losses = Vec::new();
        for _ in 0..40 {
            let logits = model.forward(&mut eng);
            let (loss, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
            model.backward(&mut eng, &dlogits);
            losses.push(loss);
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.7),
            "EGC loss should drop: {:?} -> {:?}",
            losses.first(),
            losses.last()
        );
    }

    #[test]
    fn combination_weights_are_distributions() {
        let mut rng = Rng::new(2);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Egc::new(&ds, 8, 0.02, &mut rng, &mut eng);
        let _ = model.forward(&mut eng);
        let s = &model.cache.as_ref().unwrap().s1;
        for r in 0..s.rows {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }
}
