//! Efficient Graph Convolution (Tailor et al. [28], EGC-S) — per-node
//! learned combination of B basis aggregations:
//!
//! ```text
//! S      = softmax_rows(H · Ws)              (n × B combination weights)
//! P_b    = Â · (H · W_b)                     (B basis aggregations)
//! H'     = ReLU( Σ_b diag(S_b) · P_b + bias )
//! ```
//!
//! B = 2 bases; aggregations remain plain SpMMs, so format selection hits
//! the same hot path as GCN with twice the SpMM traffic.

use super::adam::Adam;
use super::engine::AdjEngine;
use crate::graph::GraphDataset;
use crate::sparse::{Coo, SharedMatrix};
use crate::tensor::{ops, Matrix};
use crate::util::rng::Rng;

/// Number of basis aggregators.
pub const N_BASES: usize = 2;

struct EgcLayer {
    w: Vec<Matrix>,
    ws: Matrix,
    bias: Vec<f32>,
}

impl EgcLayer {
    fn new(d_in: usize, d_out: usize, rng: &mut Rng) -> EgcLayer {
        EgcLayer {
            w: (0..N_BASES).map(|_| Matrix::glorot(d_in, d_out, rng)).collect(),
            ws: Matrix::glorot(d_in, N_BASES, rng),
            bias: vec![0.0; d_out],
        }
    }
}

/// Engine slot ids for one graph binding (train shards or the dedicated
/// full-graph eval binding — §Shared-Ownership double-buffering).
#[derive(Clone, Copy)]
struct EgcSlots {
    x: usize,
    a1: usize,
    a2: usize,
    h1: usize,
}

/// Two-layer EGC-S.
pub struct Egc {
    l1: EgcLayer,
    l2: EgcLayer,
    adam: Adam,
    slots: EgcSlots,
    train_slots: EgcSlots,
    eval_slots: Option<EgcSlots>,
    cache: Option<Cache>,
}

struct Cache {
    s1: Matrix,
    p1: Vec<Matrix>,
    pre1: Matrix,
    s2: Matrix,
    p2: Vec<Matrix>,
}

/// One EGC layer's parameter gradients.
pub struct EgcLayerGrads {
    pub dw: Vec<Matrix>,
    pub dws: Matrix,
    pub dbias: Vec<f32>,
}

/// One backward pass's parameter gradients — the mini-batch accumulation
/// unit (see `gnn::minibatch`).
pub struct EgcGrads {
    pub l1: EgcLayerGrads,
    pub l2: EgcLayerGrads,
}

impl EgcGrads {
    /// `self += w · other` (shard-weighted gradient accumulation).
    pub fn add_scaled(&mut self, o: &EgcGrads, w: f32) {
        for (a, b) in [(&mut self.l1, &o.l1), (&mut self.l2, &o.l2)] {
            for (da, db) in a.dw.iter_mut().zip(b.dw.iter()) {
                ops::axpy_slice(&mut da.data, &db.data, w);
            }
            ops::axpy_slice(&mut a.dws.data, &b.dws.data, w);
            ops::axpy_slice(&mut a.dbias, &b.dbias, w);
        }
    }

    /// `self *= w`.
    pub fn scale(&mut self, w: f32) {
        for l in [&mut self.l1, &mut self.l2] {
            for dw in &mut l.dw {
                ops::scale_slice(&mut dw.data, w);
            }
            ops::scale_slice(&mut l.dws.data, w);
            ops::scale_slice(&mut l.dbias, w);
        }
    }
}

/// `out[r] = Σ_c a[r,c]·b[r,c]` — rowwise dot products.
fn row_dots(a: &Matrix, b: &Matrix) -> Vec<f32> {
    assert_eq!(a.shape(), b.shape());
    (0..a.rows)
        .map(|r| a.row(r).iter().zip(b.row(r).iter()).map(|(&x, &y)| x * y).sum())
        .collect()
}

fn scale_rows_by(m: &Matrix, s: &[f32]) -> Matrix {
    let mut out = m.clone();
    for r in 0..out.rows {
        let f = s[r];
        for v in out.row_mut(r) {
            *v *= f;
        }
    }
    out
}

impl Egc {
    pub fn new(
        ds: &GraphDataset,
        hidden: usize,
        lr: f32,
        rng: &mut Rng,
        eng: &mut AdjEngine,
    ) -> Egc {
        let l1 = EgcLayer::new(ds.features.cols, hidden, rng);
        let l2 = EgcLayer::new(hidden, ds.n_classes, rng);
        let mut sizes = Vec::new();
        for l in [&l1, &l2] {
            for w in &l.w {
                sizes.push(w.data.len());
            }
            sizes.push(l.ws.data.len());
            sizes.push(l.bias.len());
        }
        let adam = Adam::new(&sizes, lr);
        let n = ds.adj.rows;
        let train_slots = EgcSlots {
            x: eng.add_slot("egc.X", ds.features.clone()),
            a1: eng.add_slot("egc.A.l1", ds.adj_norm.clone()),
            a2: eng.add_slot("egc.A.l2", ds.adj_norm.clone()),
            h1: eng.add_slot("egc.H1", Coo::from_triples(n, hidden, vec![])),
        };
        Egc {
            slots: train_slots,
            train_slots,
            eval_slots: None,
            l1,
            l2,
            adam,
            cache: None,
        }
    }

    fn layer_forward(
        layer: &EgcLayer,
        eng: &mut AdjEngine,
        s_in: usize,
        s_a: usize,
    ) -> (Matrix, Vec<Matrix>, Matrix) {
        let s_logits = eng.spmm(s_in, &layer.ws);
        let s = ops::softmax_rows(&s_logits);
        let mut pre: Option<Matrix> = None;
        let mut ps = Vec::with_capacity(N_BASES);
        for b in 0..N_BASES {
            let zw = eng.spmm(s_in, &layer.w[b]);
            let p = eng.spmm(s_a, &zw);
            let sb: Vec<f32> = (0..s.rows).map(|r| s.at(r, b)).collect();
            let contrib = scale_rows_by(&p, &sb);
            pre = Some(match pre {
                None => contrib,
                Some(acc) => ops::add(&acc, &contrib),
            });
            ps.push(p);
        }
        let pre = ops::add_row(&pre.unwrap(), &layer.bias);
        (s, ps, pre)
    }

    /// Returns (dinput, dws, dw[b], dbias). All `inputᵀ·…` products run
    /// transpose-free through `spmm_t` on the forward input slot.
    fn layer_backward(
        layer: &EgcLayer,
        eng: &mut AdjEngine,
        s_in: usize,
        s_a: usize,
        s: &Matrix,
        ps: &[Matrix],
        dpre: &Matrix,
    ) -> (Matrix, Matrix, Vec<Matrix>, Vec<f32>) {
        let dbias = ops::col_sums(dpre);
        // dS_b = rowdot(P_b, dpre); softmax backward.
        let mut ds = Matrix::zeros(s.rows, N_BASES);
        for (b, p) in ps.iter().enumerate() {
            for (r, v) in row_dots(p, dpre).into_iter().enumerate() {
                *ds.at_mut(r, b) = v;
            }
        }
        let mut dslogits = Matrix::zeros(s.rows, N_BASES);
        for r in 0..s.rows {
            let dot: f32 = (0..N_BASES).map(|b| s.at(r, b) * ds.at(r, b)).sum();
            for b in 0..N_BASES {
                *dslogits.at_mut(r, b) = s.at(r, b) * (ds.at(r, b) - dot);
            }
        }
        let dws = eng.spmm_t(s_in, &dslogits);
        let mut dinput = dslogits.matmul_t(&layer.ws);
        let mut dw = Vec::with_capacity(N_BASES);
        for b in 0..N_BASES {
            let sb: Vec<f32> = (0..s.rows).map(|r| s.at(r, b)).collect();
            let dp = scale_rows_by(dpre, &sb);
            let dzw = eng.spmm(s_a, &dp); // Âᵀ = Â
            dw.push(eng.spmm_t(s_in, &dzw));
            dinput = ops::add(&dinput, &dzw.matmul_t(&layer.w[b]));
            eng.recycle(s_a, dzw);
        }
        (dinput, dws, dw, dbias)
    }

    pub fn forward(&mut self, eng: &mut AdjEngine) -> Matrix {
        let sl = self.slots;
        let (s1, p1, pre1) = Self::layer_forward(&self.l1, eng, sl.x, sl.a1);
        let h1_dense = ops::relu(&pre1);
        eng.update_slot_dense(sl.h1, &h1_dense);
        let (s2, p2, logits) = Self::layer_forward(&self.l2, eng, sl.h1, sl.a2);
        self.cache = Some(Cache { s1, p1, pre1, s2, p2 });
        logits
    }

    /// Backward pass returning parameter gradients without applying them
    /// (the mini-batch accumulation path).
    pub fn backward_grads(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) -> EgcGrads {
        let cache = self.cache.take().expect("forward before backward");
        let sl = self.slots;
        let (dh1, dws2, dw2, db2) = Self::layer_backward(
            &self.l2, eng, sl.h1, sl.a2, &cache.s2, &cache.p2, dlogits,
        );
        let dpre1 = ops::relu_grad(&cache.pre1, &dh1);
        let (_dx, dws1, dw1, db1) = Self::layer_backward(
            &self.l1, eng, sl.x, sl.a1, &cache.s1, &cache.p1, &dpre1,
        );
        EgcGrads {
            l1: EgcLayerGrads { dw: dw1, dws: dws1, dbias: db1 },
            l2: EgcLayerGrads { dw: dw2, dws: dws2, dbias: db2 },
        }
    }

    /// One Adam step from (possibly accumulated) gradients. Parameter
    /// order matches `new`.
    pub fn apply_grads(&mut self, g: &EgcGrads) {
        self.adam.tick();
        let mut idx = 0;
        for b in 0..N_BASES {
            self.adam.update_matrix(idx, &mut self.l1.w[b], &g.l1.dw[b]);
            idx += 1;
        }
        self.adam.update_matrix(idx, &mut self.l1.ws, &g.l1.dws);
        idx += 1;
        self.adam.update(idx, &mut self.l1.bias, &g.l1.dbias);
        idx += 1;
        for b in 0..N_BASES {
            self.adam.update_matrix(idx, &mut self.l2.w[b], &g.l2.dw[b]);
            idx += 1;
        }
        self.adam.update_matrix(idx, &mut self.l2.ws, &g.l2.dws);
        idx += 1;
        self.adam.update(idx, &mut self.l2.bias, &g.l2.dbias);
    }

    /// Backward + Adam step (full-batch path).
    pub fn backward(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) {
        let g = self.backward_grads(eng, dlogits);
        self.apply_grads(&g);
    }

    /// Point the model's train slots at a new (sub)graph: induced feature
    /// rows `x` and induced normalized adjacency `a` (both layers share
    /// one handle) — same rebinding contract as GCN. H1 re-derives on the
    /// next forward.
    pub fn set_graph(
        &mut self,
        eng: &mut AdjEngine,
        x: impl Into<SharedMatrix>,
        a: impl Into<SharedMatrix>,
    ) {
        self.slots = self.train_slots;
        let a = a.into();
        eng.set_slot_matrix(self.train_slots.x, x);
        eng.set_slot_matrix(self.train_slots.a1, a.clone());
        eng.set_slot_matrix(self.train_slots.a2, a);
    }

    /// Create + bind the dedicated full-graph eval slots once (handle
    /// bumps, zero matrix-data copies); see [`super::gcn::Gcn::bind_eval_graph`].
    pub fn bind_eval_graph(&mut self, eng: &mut AdjEngine, x: SharedMatrix, a: SharedMatrix) {
        assert!(self.eval_slots.is_none(), "eval slots are bound once at startup");
        let n = a.rows();
        let hidden = self.l1.bias.len();
        self.eval_slots = Some(EgcSlots {
            x: eng.add_slot_shared("egc.X.eval", x),
            a1: eng.add_slot_shared("egc.A.l1.eval", a.clone()),
            a2: eng.add_slot_shared("egc.A.l2.eval", a),
            h1: eng.add_slot("egc.H1.eval", Coo::from_triples(n, hidden, vec![])),
        });
    }

    /// Flip onto the full-graph eval slots — O(1), no engine traffic.
    pub fn use_eval_graph(&mut self) {
        self.slots = self.eval_slots.expect("bind_eval_graph before use_eval_graph");
    }

    /// Flip back onto the train/shard slots (`set_graph` also does this).
    pub fn use_train_graph(&mut self) {
        self.slots = self.train_slots;
    }

    /// Copy trained parameters from a template model (serving replication;
    /// see [`super::gcn::Gcn::copy_weights_from`]).
    pub fn copy_weights_from(&mut self, other: &Egc) {
        for (dst, src) in [(&mut self.l1, &other.l1), (&mut self.l2, &other.l2)] {
            assert_eq!(dst.ws.data.len(), src.ws.data.len(), "layer shape mismatch");
            for (dw, sw) in dst.w.iter_mut().zip(src.w.iter()) {
                dw.data.copy_from_slice(&sw.data);
            }
            dst.ws.data.copy_from_slice(&src.ws.data);
            dst.bias.copy_from_slice(&src.bias);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::engine::StaticPolicy;
    use crate::graph::DatasetSpec;
    use crate::sparse::Format;

    fn tiny_dataset(rng: &mut Rng) -> GraphDataset {
        let spec = DatasetSpec {
            name: "Tiny",
            n: 100,
            feat_dim: 20,
            adj_density: 0.06,
            feat_density: 0.2,
            n_classes: 3,
        };
        GraphDataset::generate(&spec, rng)
    }

    #[test]
    fn loss_decreases() {
        let mut rng = Rng::new(1);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Egc::new(&ds, 12, 0.02, &mut rng, &mut eng);
        let mut losses = Vec::new();
        for _ in 0..40 {
            let logits = model.forward(&mut eng);
            let (loss, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
            model.backward(&mut eng, &dlogits);
            losses.push(loss);
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.7),
            "EGC loss should drop: {:?} -> {:?}",
            losses.first(),
            losses.last()
        );
    }

    /// The grads-split refactor must leave full-batch EGC identical:
    /// `backward` ≡ `backward_grads` + `apply_grads`.
    #[test]
    fn split_backward_matches_fused_backward() {
        let run = |split: bool| -> Matrix {
            let mut rng = Rng::new(55);
            let ds = tiny_dataset(&mut rng);
            let mut policy = StaticPolicy(Format::Csr);
            let mut eng = AdjEngine::new(&mut policy);
            let mut model = Egc::new(&ds, 8, 0.02, &mut rng, &mut eng);
            for _ in 0..4 {
                let logits = model.forward(&mut eng);
                let (_, dlogits) =
                    ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
                if split {
                    let g = model.backward_grads(&mut eng, &dlogits);
                    model.apply_grads(&g);
                } else {
                    model.backward(&mut eng, &dlogits);
                }
            }
            model.forward(&mut eng)
        };
        let a = run(false);
        let b = run(true);
        assert!(a.max_abs_diff(&b) < 1e-6, "split/fused EGC backward diverged");
    }

    #[test]
    fn combination_weights_are_distributions() {
        let mut rng = Rng::new(2);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Egc::new(&ds, 8, 0.02, &mut rng, &mut eng);
        let _ = model.forward(&mut eng);
        let s = &model.cache.as_ref().unwrap().s1;
        for r in 0..s.rows {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }
}
