//! Sharded mini-batch training — the subsystem that takes the format
//! machinery past full-batch scale (ROADMAP north star).
//!
//! Pipeline per epoch:
//!
//! ```text
//! partition (degree-aware LPT)         graph::partition
//!   → per-shard neighbor sampling      graph::sampler   (seeded, per epoch)
//!   → induced-submatrix extraction     sparse  (direct CSR, no COO hop)
//!   → per-shard format decision        engine + predictor::cache
//!   → forward / backward on the shard  (same models, same AdjEngine)
//!   → shard-weighted gradient accumulation → one optimizer step
//! epoch end → full-graph eval (train/test accuracy)
//! ```
//!
//! Three design rules keep the shard stream cheap:
//!
//! * **Extraction is format-direct.** The full-graph operands are held in
//!   CSR; `extract_rows_cols` slices shard rows/cols on the CSR arrays and
//!   hands the engine a CSR submatrix — no COO round-trip
//!   (`sparse::coo_fallback_extractions` stays flat; `bench_minibatch`
//!   asserts it).
//! * **Decisions are cached by structure.** Every shard rebind clears the
//!   slot's decision (it *is* a different matrix), but the engine's
//!   signature-keyed [`DecisionCache`](crate::predictor::cache::DecisionCache)
//!   answers structurally similar shards in O(1) — feature extraction is
//!   paid once per signature, not per batch (GE-SpMM/ParamSpMM's
//!   amortization argument, applied to the paper's predictor).
//! * **One engine for the whole run.** Slots, workspace pools, the worker
//!   pool and the decision cache persist across shards and epochs — the
//!   steady-state multiply path stays allocation-free.
//!
//! All five models train sharded. GCN/FiLM/EGC slice the shared normalized
//! adjacency; GAT slices the raw adjacency and re-derives its attention
//! pattern; **RGCN slices one induced submatrix per relation** off R
//! per-relation normalized CSR masters — each relation keeps its own
//! engine slot, so the decision cache holds one entry per relation per
//! shard signature (R × shards decision surface: the regime where the
//! paper's per-matrix decisions pay off most).
//!
//! Gradient semantics: each shard computes the masked-mean loss over its
//! *seed* train nodes; shard gradients are accumulated weighted by
//! `seed-train-count / total-train-count`, so the applied step equals the
//! full-batch train-set mean gradient up to neighbor-sampling truncation.

use super::egc::{Egc, EgcGrads};
use super::engine::{AdjEngine, Decision, FormatPolicy};
use super::film::{Film, FilmGrads};
use super::gat::{Gat, GatGrads};
use super::gcn::{Gcn, GcnGrads};
use super::rgcn::{relation_operands, Rgcn, RgcnGrads};
use super::train::ModelKind;
use crate::graph::{GraphDataset, NeighborSampler, Partitioning};
use crate::predictor::cache::DecisionCache;
use crate::sparse::{Coo, Csr, SharedMatrix, SparseMatrix};
use crate::tensor::{ops, Matrix};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Mini-batch training hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct MinibatchConfig {
    pub epochs: usize,
    pub hidden: usize,
    pub lr: f32,
    pub seed: u64,
    /// Node shards per epoch (degree-aware partition).
    pub n_shards: usize,
    /// Sampled neighbors per seed node (GraphSAGE-style fan-out).
    pub fanout: usize,
}

impl Default for MinibatchConfig {
    fn default() -> Self {
        MinibatchConfig {
            epochs: 5,
            hidden: 16,
            lr: 0.02,
            seed: 0x6E11,
            n_shards: 8,
            fanout: 8,
        }
    }
}

/// Everything a bench/report needs from one sharded training run.
#[derive(Clone, Debug)]
pub struct MinibatchReport {
    pub model: &'static str,
    pub dataset: String,
    pub policy: String,
    pub n_shards: usize,
    pub fanout: usize,
    /// Shard-weighted mean train loss per epoch.
    pub epoch_losses: Vec<f32>,
    /// Wall-clock seconds per epoch (shard loop + optimizer step; eval
    /// excluded so the series is comparable across eval cadences).
    pub epoch_times: Vec<f64>,
    /// Full-graph train/test accuracy after each epoch.
    pub train_accs: Vec<f64>,
    pub test_accs: Vec<f64>,
    pub final_train_acc: f64,
    pub final_test_acc: f64,
    /// End-to-end wall-clock time (includes extraction, decisions,
    /// conversions, eval — the paper's all-overheads accounting).
    pub total_time: f64,
    /// Engine phase breakdown: (phase, seconds, invocations).
    pub phases: Vec<(&'static str, f64, u64)>,
    pub decisions: Vec<Decision>,
    /// Decision-cache accounting over the whole run.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Cache hit rate over decisions made **after the first epoch** (the
    /// steady-state figure the acceptance gate checks: > 0.8).
    pub warm_cache_hit_rate: f64,
    /// Seconds spent deciding (COO views + feature extraction + model
    /// inference) across the run.
    pub decision_overhead_s: f64,
    /// `sparse::coo_fallback_extractions()` delta across the run — 0 when
    /// every shard extraction took a direct format path.
    pub coo_fallback_extractions: u64,
    /// The decision cache as it stood at the end of the run (taken from
    /// the engine, not copied). Persist it with [`DecisionCache::save`] to
    /// warm-start the next process ([`train_minibatch_warm`]).
    pub final_cache: DecisionCache,
}

enum MbModel {
    Gcn(Gcn),
    Gat(Gat),
    Film(Film),
    Rgcn(Rgcn),
    Egc(Egc),
}

enum MbGrads {
    Gcn(GcnGrads),
    Gat(GatGrads),
    Film(FilmGrads),
    Rgcn(RgcnGrads),
    Egc(EgcGrads),
}

impl MbGrads {
    fn scale(&mut self, w: f32) {
        match self {
            MbGrads::Gcn(g) => g.scale(w),
            MbGrads::Gat(g) => g.scale(w),
            MbGrads::Film(g) => g.scale(w),
            MbGrads::Rgcn(g) => g.scale(w),
            MbGrads::Egc(g) => g.scale(w),
        }
    }

    fn add_scaled(&mut self, o: &MbGrads, w: f32) {
        match (self, o) {
            (MbGrads::Gcn(a), MbGrads::Gcn(b)) => a.add_scaled(b, w),
            (MbGrads::Gat(a), MbGrads::Gat(b)) => a.add_scaled(b, w),
            (MbGrads::Film(a), MbGrads::Film(b)) => a.add_scaled(b, w),
            (MbGrads::Rgcn(a), MbGrads::Rgcn(b)) => a.add_scaled(b, w),
            (MbGrads::Egc(a), MbGrads::Egc(b)) => a.add_scaled(b, w),
            _ => unreachable!("gradient kind mismatch"),
        }
    }
}

/// Full-graph operand masters the shard loop slices from. Everything sits
/// in a format with a direct extraction path (CSR masters; GAT's raw
/// adjacency is native COO), so the shard stream never pays the counted
/// COO fallback. The masters are **shared handles** (§Shared-Ownership):
/// the model's dedicated eval slots co-own them for the whole run — no
/// rebind ever copies matrix data out of this struct.
pub struct FullGraphOps<'d> {
    /// Sparse features, CSR (row slice via the identity-column fast path).
    pub feats: SharedMatrix,
    /// Normalized adjacency, CSR (GCN/FiLM/EGC propagation operand).
    pub adjn: SharedMatrix,
    /// Raw adjacency (GAT derives its attention pattern from it).
    pub adj: &'d Coo,
    /// RGCN: one normalized adjacency per relation, CSR (empty otherwise).
    /// Each relation is sliced and rebound independently — per-relation
    /// slots mean per-relation decision-cache entries.
    pub rels: Vec<SharedMatrix>,
    /// GAT: epoch-invariant full-graph attention pattern.
    pub pattern: Option<Arc<Coo>>,
}

impl<'d> FullGraphOps<'d> {
    /// Build the shared masters for `kind` from a dataset: CSR features and
    /// normalized adjacency (direct extraction paths), per-relation CSRs
    /// for RGCN (`rel_ops` from [`relation_operands`], empty otherwise),
    /// and GAT's epoch-invariant attention pattern. Shared by the
    /// mini-batch trainer and the serving layer's snapshot builder — both
    /// need the same "slice-friendly masters" invariant.
    pub fn new(ds: &'d GraphDataset, kind: ModelKind, rel_ops: &[Coo]) -> FullGraphOps<'d> {
        FullGraphOps {
            feats: SharedMatrix::from(Csr::from_coo(&ds.features)),
            adjn: SharedMatrix::from(Csr::from_coo(&ds.adj_norm)),
            adj: &ds.adj,
            rels: rel_ops.iter().map(|r| SharedMatrix::from(Csr::from_coo(r))).collect(),
            // GAT's full-graph attention pattern is epoch-invariant: build
            // it once here instead of re-deriving it per epoch.
            pattern: match kind {
                ModelKind::Gat => Some(Arc::new(Gat::attention_pattern(&ds.adj))),
                _ => None,
            },
        }
    }
}

impl MbModel {
    fn forward(&mut self, eng: &mut AdjEngine) -> Matrix {
        match self {
            MbModel::Gcn(m) => m.forward(eng),
            MbModel::Gat(m) => m.forward(eng),
            MbModel::Film(m) => m.forward(eng),
            MbModel::Rgcn(m) => m.forward(eng),
            MbModel::Egc(m) => m.forward(eng),
        }
    }

    fn backward_grads(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) -> MbGrads {
        match self {
            MbModel::Gcn(m) => MbGrads::Gcn(m.backward_grads(eng, dlogits)),
            MbModel::Gat(m) => MbGrads::Gat(m.backward_grads(eng, dlogits)),
            MbModel::Film(m) => MbGrads::Film(m.backward_grads(eng, dlogits)),
            MbModel::Rgcn(m) => MbGrads::Rgcn(m.backward_grads(eng, dlogits)),
            MbModel::Egc(m) => MbGrads::Egc(m.backward_grads(eng, dlogits)),
        }
    }

    fn apply_grads(&mut self, g: &MbGrads) {
        match (self, g) {
            (MbModel::Gcn(m), MbGrads::Gcn(g)) => m.apply_grads(g),
            (MbModel::Gat(m), MbGrads::Gat(g)) => m.apply_grads(g),
            (MbModel::Film(m), MbGrads::Film(g)) => m.apply_grads(g),
            (MbModel::Rgcn(m), MbGrads::Rgcn(g)) => m.apply_grads(g),
            (MbModel::Egc(m), MbGrads::Egc(g)) => m.apply_grads(g),
            _ => unreachable!("gradient kind mismatch"),
        }
    }

    /// Extract the induced graph operands this model actually propagates
    /// over and rebind its slots. GCN/FiLM/EGC slice the normalized
    /// adjacency (direct CSR path); GAT slices the raw adjacency (native
    /// COO path) and derives its attention pattern from it; RGCN slices
    /// each relation's normalized CSR master independently. Every
    /// extraction is charged to the `extract` phase.
    fn bind_subgraph(
        &mut self,
        eng: &mut AdjEngine,
        x: SparseMatrix,
        nodes: &[u32],
        full: &FullGraphOps,
    ) {
        if let MbModel::Gat(m) = self {
            let pat = eng.sw.phase("extract", || {
                Gat::attention_pattern(&full.adj.extract_rows_cols(nodes, nodes))
            });
            m.set_graph(eng, x, pat);
            return;
        }
        if let MbModel::Rgcn(m) = self {
            // One induced submatrix per relation: a symmetric principal
            // submatrix of a symmetric relation stays symmetric, so the
            // model's Â_rᵀ = Â_r backward identity holds per shard. Each
            // submatrix becomes one shared handle bound to both layers.
            let subs: Vec<SharedMatrix> = eng.sw.phase("extract", || {
                full.rels
                    .iter()
                    .map(|rm| SharedMatrix::from(rm.extract_rows_cols(nodes, nodes)))
                    .collect()
            });
            m.set_graph(eng, x, subs);
            return;
        }
        let a = eng.sw.phase("extract", || full.adjn.extract_rows_cols(nodes, nodes));
        match self {
            MbModel::Gcn(m) => m.set_graph(eng, x, a),
            MbModel::Film(m) => m.set_graph(eng, x, a),
            MbModel::Egc(m) => m.set_graph(eng, x, a),
            MbModel::Gat(_) | MbModel::Rgcn(_) => unreachable!("handled above"),
        }
    }

    /// Create + bind the dedicated double-buffered eval slots, once at
    /// startup, straight onto the shared masters (refcount bumps only —
    /// the masters are never copied; for RGCN that deletes the old ~2R CSR
    /// copies per epoch).
    fn bind_eval_graph(&mut self, eng: &mut AdjEngine, full: &FullGraphOps) {
        let x = full.feats.clone();
        match self {
            MbModel::Gcn(m) => m.bind_eval_graph(eng, x, full.adjn.clone()),
            MbModel::Film(m) => m.bind_eval_graph(eng, x, full.adjn.clone()),
            MbModel::Egc(m) => m.bind_eval_graph(eng, x, full.adjn.clone()),
            MbModel::Rgcn(m) => m.bind_eval_graph(eng, x, full.rels.clone()),
            MbModel::Gat(m) => m.bind_eval_graph(
                eng,
                x,
                full.pattern.clone().expect("pattern precomputed for GAT"),
            ),
        }
    }

    /// Flip onto the eval slots for the per-epoch full-graph eval: an O(1)
    /// id swap — zero engine traffic, zero matrix-data allocations
    /// (asserted by `bench_minibatch`'s alloc-counter gate). The next
    /// `bind_subgraph` flips back implicitly via `set_graph`.
    fn use_eval_graph(&mut self) {
        match self {
            MbModel::Gcn(m) => m.use_eval_graph(),
            MbModel::Gat(m) => m.use_eval_graph(),
            MbModel::Film(m) => m.use_eval_graph(),
            MbModel::Rgcn(m) => m.use_eval_graph(),
            MbModel::Egc(m) => m.use_eval_graph(),
        }
    }
}

/// Train `kind` on `ds` with sharded mini-batches under `policy`.
///
/// Every [`ModelKind`] has a mini-batch path (the assert guards future
/// models added without one; see [`ModelKind::supports_minibatch`]).
pub fn train_minibatch(
    kind: ModelKind,
    ds: &GraphDataset,
    policy: &mut dyn FormatPolicy,
    cfg: &MinibatchConfig,
) -> MinibatchReport {
    train_minibatch_warm(kind, ds, policy, cfg, None)
}

/// [`train_minibatch`] with an optional **warm-started decision cache** —
/// a cache persisted by a previous process ([`DecisionCache::save`] on
/// [`MinibatchReport::final_cache`], [`DecisionCache::load`] here) answers
/// decisions from the first shard onward, skipping the cold first epoch a
/// fresh service would otherwise pay.
pub fn train_minibatch_warm(
    kind: ModelKind,
    ds: &GraphDataset,
    policy: &mut dyn FormatPolicy,
    cfg: &MinibatchConfig,
    warm_cache: Option<DecisionCache>,
) -> MinibatchReport {
    assert!(
        kind.supports_minibatch(),
        "{} has no mini-batch training path",
        kind.name()
    );
    let policy_name = policy.policy_name();
    let fallbacks_before = crate::sparse::coo_fallback_extractions();
    let start = std::time::Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let mut eng = AdjEngine::new(policy);
    match warm_cache {
        Some(cache) => eng.set_decision_cache(cache),
        None => eng.enable_decision_cache(),
    }

    // Full-graph operand masters in CSR: row/col slicing runs directly on
    // the CSR arrays. RGCN additionally materializes one normalized CSR
    // per relation — split + normalized once, shared with the model's
    // slots below, so the single-shard degenerate run reproduces the
    // full-batch step exactly.
    let rel_ops = if kind == ModelKind::Rgcn {
        relation_operands(&ds.adj)
    } else {
        Vec::new()
    };
    let full = FullGraphOps::new(ds, kind, &rel_ops);
    let adj_csr = Csr::from_coo(&ds.adj); // sampler neighbor lists
    let all_feat_cols: Vec<u32> = (0..ds.features.cols as u32).collect();

    let part = Partitioning::by_degree(&ds.adj, cfg.n_shards);
    let sampler = NeighborSampler::new(&adj_csr, cfg.fanout, cfg.seed);

    let mut model = match kind {
        ModelKind::Gcn => MbModel::Gcn(Gcn::new(ds, cfg.hidden, cfg.lr, &mut rng, &mut eng)),
        ModelKind::Gat => MbModel::Gat(Gat::new(ds, cfg.hidden, cfg.lr, &mut rng, &mut eng)),
        ModelKind::Film => MbModel::Film(Film::new(ds, cfg.hidden, cfg.lr, &mut rng, &mut eng)),
        ModelKind::Rgcn => MbModel::Rgcn(Rgcn::with_relations(
            ds, &rel_ops, cfg.hidden, cfg.lr, &mut rng, &mut eng,
        )),
        ModelKind::Egc => MbModel::Egc(Egc::new(ds, cfg.hidden, cfg.lr, &mut rng, &mut eng)),
    };
    // Dedicated double-buffered eval slots, bound once onto the shared
    // masters: every per-epoch full-graph eval is then a pure slot-id flip.
    model.bind_eval_graph(&mut eng, &full);

    let total_train = ds.train_mask.iter().filter(|&&m| m).count().max(1);

    let mut epoch_losses = Vec::with_capacity(cfg.epochs);
    let mut epoch_times = Vec::with_capacity(cfg.epochs);
    let mut train_accs = Vec::with_capacity(cfg.epochs);
    let mut test_accs = Vec::with_capacity(cfg.epochs);
    let mut decisions_after_first_epoch = 0usize;

    for epoch in 0..cfg.epochs {
        let t0 = std::time::Instant::now();
        let mut acc: Option<MbGrads> = None;
        let mut epoch_loss = 0.0f32;
        for (sid, shard) in part.shards.iter().enumerate() {
            if shard.is_empty() {
                continue;
            }
            let batch = sampler.sample(shard, epoch, sid);
            let nodes = &batch.nodes;
            // Per-batch loss mask: seed nodes that are train nodes.
            let labels_sub: Vec<usize> =
                nodes.iter().map(|&v| ds.labels[v as usize]).collect();
            let mask_sub: Vec<bool> = nodes
                .iter()
                .zip(&batch.is_seed)
                .map(|(&v, &s)| s && ds.train_mask[v as usize])
                .collect();
            let m_train = mask_sub.iter().filter(|&&m| m).count();
            if m_train == 0 {
                continue; // context-only shard: no loss signal
            }
            // Induced operands — direct format paths, charged like every
            // other engine overhead.
            let x_sub = eng
                .sw
                .phase("extract", || full.feats.extract_rows_cols(nodes, &all_feat_cols));
            model.bind_subgraph(&mut eng, x_sub, nodes, &full);
            let logits = model.forward(&mut eng);
            let (loss, dlogits) =
                ops::masked_xent_with_grad(&logits, &labels_sub, &mask_sub);
            let g = model.backward_grads(&mut eng, &dlogits);
            let w = m_train as f32 / total_train as f32;
            epoch_loss += loss * w;
            match &mut acc {
                None => {
                    let mut g = g;
                    g.scale(w);
                    acc = Some(g);
                }
                Some(a) => a.add_scaled(&g, w),
            }
        }
        if let Some(g) = &acc {
            model.apply_grads(g);
        }
        epoch_times.push(t0.elapsed().as_secs_f64());
        epoch_losses.push(epoch_loss);

        // Full-graph eval on the updated weights: flip onto the eval slots
        // (O(1), allocation-free) — decisions, conversions and workspaces
        // made there in epoch 0 persist for the whole run.
        model.use_eval_graph();
        let logits = model.forward(&mut eng);
        train_accs.push(ops::masked_accuracy(&logits, &ds.labels, &ds.train_mask));
        test_accs.push(ops::masked_accuracy(&logits, &ds.labels, &ds.test_mask));

        if epoch == 0 {
            decisions_after_first_epoch = eng.decisions.len();
        }
    }

    let total_time = start.elapsed().as_secs_f64() - eng.sw.total("oracle_search");
    let warm = &eng.decisions[decisions_after_first_epoch.min(eng.decisions.len())..];
    let warm_cache_hit_rate = if warm.is_empty() {
        0.0
    } else {
        warm.iter().filter(|d| d.cached).count() as f64 / warm.len() as f64
    };
    let decision_overhead_s = eng.sw.total("to_coo_view")
        + eng.sw.total("feature_extract")
        + eng.sw.total("predict");
    // The engine is dropped with this function: take the decision log and
    // the cache instead of copying them (the old per-report
    // `decisions.clone()` duplicated the full history every run).
    let cache = eng.take_decision_cache().expect("enabled above");
    let decisions = std::mem::take(&mut eng.decisions);

    MinibatchReport {
        model: kind.name(),
        dataset: ds.name.clone(),
        policy: policy_name,
        n_shards: part.shards.len(),
        fanout: cfg.fanout,
        epoch_losses,
        epoch_times,
        final_train_acc: train_accs.last().copied().unwrap_or(0.0),
        final_test_acc: test_accs.last().copied().unwrap_or(0.0),
        train_accs,
        test_accs,
        total_time,
        phases: eng.sw.report(),
        cache_hits: cache.hits(),
        cache_misses: cache.misses(),
        warm_cache_hit_rate,
        decision_overhead_s,
        coo_fallback_extractions: crate::sparse::coo_fallback_extractions()
            - fallbacks_before,
        decisions,
        final_cache: cache,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::engine::StaticPolicy;
    use crate::graph::DatasetSpec;
    use crate::sparse::Format;

    fn small() -> GraphDataset {
        let mut rng = Rng::new(31);
        GraphDataset::generate(
            &DatasetSpec {
                name: "MbSmall",
                n: 400,
                feat_dim: 24,
                adj_density: 0.03,
                feat_density: 0.15,
                n_classes: 4,
            },
            &mut rng,
        )
    }

    #[test]
    fn gcn_minibatch_loss_decreases() {
        let ds = small();
        let mut policy = StaticPolicy(Format::Csr);
        let report = train_minibatch(
            ModelKind::Gcn,
            &ds,
            &mut policy,
            &MinibatchConfig { epochs: 10, hidden: 12, n_shards: 4, fanout: 6, ..Default::default() },
        );
        assert_eq!(report.epoch_losses.len(), 10);
        assert_eq!(report.train_accs.len(), 10);
        let first = report.epoch_losses[0];
        let last = *report.epoch_losses.last().unwrap();
        assert!(last < first, "loss should drop: {first} -> {last}");
        // One accumulated optimizer step per epoch = 10 Adam steps total:
        // expect clearly-better-than-chance (4 classes), not convergence.
        assert!(report.final_train_acc > 0.35, "train acc {}", report.final_train_acc);
        assert!(report.total_time > 0.0);
        assert_eq!(report.epoch_times.len(), 10);
    }

    #[test]
    fn gat_and_film_minibatch_run() {
        let ds = small();
        for kind in [ModelKind::Gat, ModelKind::Film] {
            let mut policy = StaticPolicy(Format::Csr);
            let report = train_minibatch(
                kind,
                &ds,
                &mut policy,
                &MinibatchConfig { epochs: 3, hidden: 8, n_shards: 4, fanout: 4, ..Default::default() },
            );
            assert_eq!(report.epoch_losses.len(), 3, "{}", kind.name());
            assert!(
                report.epoch_losses.iter().all(|l| l.is_finite()),
                "{}: losses {:?}",
                kind.name(),
                report.epoch_losses
            );
            assert!(report.final_train_acc > 0.0, "{}", kind.name());
        }
    }

    #[test]
    fn shard_extraction_takes_direct_paths_only() {
        let ds = small();
        let mut policy = StaticPolicy(Format::Csr);
        let report = train_minibatch(
            ModelKind::Gcn,
            &ds,
            &mut policy,
            &MinibatchConfig { epochs: 2, hidden: 8, n_shards: 4, fanout: 4, ..Default::default() },
        );
        assert_eq!(
            report.coo_fallback_extractions, 0,
            "CSR/COO shard extraction must never round-trip through the COO fallback"
        );
        // Extraction happened and was charged to the engine stopwatch.
        let extract = report.phases.iter().find(|p| p.0 == "extract");
        assert!(extract.is_some_and(|p| p.2 > 0), "extract phase recorded");
    }

    #[test]
    fn rgcn_minibatch_trains_with_per_relation_decisions() {
        let ds = small();
        let mut policy = StaticPolicy(Format::Csr);
        let report = train_minibatch(
            ModelKind::Rgcn,
            &ds,
            &mut policy,
            &MinibatchConfig { epochs: 8, hidden: 12, n_shards: 4, fanout: 6, ..Default::default() },
        );
        assert_eq!(report.epoch_losses.len(), 8);
        assert!(report.epoch_losses.iter().all(|l| l.is_finite()));
        let first = report.epoch_losses[0];
        let last = *report.epoch_losses.last().unwrap();
        assert!(last < first, "RGCN minibatch loss should drop: {first} -> {last}");
        // Per-relation extraction stays on the direct CSR path.
        assert_eq!(report.coo_fallback_extractions, 0);
        // Every relation slot decided independently, on both layers.
        for r in 0..crate::gnn::rgcn::N_RELATIONS {
            for layer in 1..=2 {
                let slot = format!("rgcn.A{r}.l{layer}");
                assert!(
                    report.decisions.iter().any(|d| d.slot == slot),
                    "missing decisions for relation slot {slot}"
                );
            }
        }
    }

    #[test]
    fn egc_minibatch_runs() {
        let ds = small();
        let mut policy = StaticPolicy(Format::Csr);
        let report = train_minibatch(
            ModelKind::Egc,
            &ds,
            &mut policy,
            &MinibatchConfig { epochs: 3, hidden: 8, n_shards: 4, fanout: 4, ..Default::default() },
        );
        assert_eq!(report.epoch_losses.len(), 3);
        assert!(report.epoch_losses.iter().all(|l| l.is_finite()));
        assert!(report.final_train_acc > 0.0);
        assert_eq!(report.coo_fallback_extractions, 0);
    }
}
