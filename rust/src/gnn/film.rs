//! GNN-FiLM (Brockschmidt [3]) — feature-wise linear modulation of
//! messages by the *target* node:
//!
//! ```text
//! γ_j = (H G)_j      β_j = (H B)_j
//! H'_j = ReLU( γ_j ⊙ (Â · H W)_j + ρ_j · β_j + b )
//! ```
//!
//! Because the modulation depends only on the target, it factors out of the
//! neighbour sum — the aggregation stays a single SpMM (`Â · HW`), keeping
//! the layer exactly as SpMM-bound as the paper's other models (see
//! DESIGN.md §Substitutions for this standard single-relation reduction;
//! ρ_j = Σ_i Â_ji is the normalized degree).

use super::adam::Adam;
use super::engine::AdjEngine;
use crate::graph::GraphDataset;
use crate::sparse::{Coo, SharedMatrix, SparseOps};
use crate::tensor::{ops, Matrix};
use crate::util::rng::Rng;

struct FilmLayer {
    w: Matrix,
    g: Matrix,
    bm: Matrix,
    bias: Vec<f32>,
}

impl FilmLayer {
    fn new(d_in: usize, d_out: usize, rng: &mut Rng) -> FilmLayer {
        FilmLayer {
            w: Matrix::glorot(d_in, d_out, rng),
            g: Matrix::glorot(d_in, d_out, rng),
            bm: Matrix::glorot(d_in, d_out, rng),
            bias: vec![0.0; d_out],
        }
    }
}

/// Engine slot ids for one graph binding (train shards or the dedicated
/// full-graph eval binding — §Shared-Ownership double-buffering).
#[derive(Clone, Copy)]
struct FilmSlots {
    x: usize,
    a1: usize,
    a2: usize,
    h1: usize,
}

/// Two-layer GNN-FiLM.
pub struct Film {
    l1: FilmLayer,
    l2: FilmLayer,
    adam: Adam,
    slots: FilmSlots,
    train_slots: FilmSlots,
    eval_slots: Option<FilmSlots>,
    /// ρ: row sums of Â for the train/shard binding (recomputed per
    /// `set_graph`).
    train_rho: Vec<f32>,
    /// ρ for the full-graph eval binding, computed once at bind time.
    eval_rho: Vec<f32>,
    cache: Option<Cache>,
}

struct Cache {
    // layer 1
    gamma1: Matrix,
    p1: Matrix,
    pre1: Matrix,
    // layer 2
    gamma2: Matrix,
    p2: Matrix,
}

/// One FiLM layer's parameter gradients.
pub struct FilmLayerGrads {
    pub dw: Matrix,
    pub dg: Matrix,
    pub dbm: Matrix,
    pub dbias: Vec<f32>,
}

/// One backward pass's parameter gradients — the mini-batch accumulation
/// unit (see `gnn::minibatch`).
pub struct FilmGrads {
    pub l1: FilmLayerGrads,
    pub l2: FilmLayerGrads,
}

impl FilmGrads {
    /// `self += w · other` (shard-weighted gradient accumulation).
    pub fn add_scaled(&mut self, o: &FilmGrads, w: f32) {
        for (a, b) in [(&mut self.l1, &o.l1), (&mut self.l2, &o.l2)] {
            ops::axpy_slice(&mut a.dw.data, &b.dw.data, w);
            ops::axpy_slice(&mut a.dg.data, &b.dg.data, w);
            ops::axpy_slice(&mut a.dbm.data, &b.dbm.data, w);
            ops::axpy_slice(&mut a.dbias, &b.dbias, w);
        }
    }

    /// `self *= w`.
    pub fn scale(&mut self, w: f32) {
        for l in [&mut self.l1, &mut self.l2] {
            ops::scale_slice(&mut l.dw.data, w);
            ops::scale_slice(&mut l.dg.data, w);
            ops::scale_slice(&mut l.dbm.data, w);
            ops::scale_slice(&mut l.dbias, w);
        }
    }
}

fn scale_rows(m: &Matrix, rho: &[f32]) -> Matrix {
    let mut out = m.clone();
    for r in 0..out.rows {
        let s = rho[r];
        for v in out.row_mut(r) {
            *v *= s;
        }
    }
    out
}

impl Film {
    pub fn new(
        ds: &GraphDataset,
        hidden: usize,
        lr: f32,
        rng: &mut Rng,
        eng: &mut AdjEngine,
    ) -> Film {
        let l1 = FilmLayer::new(ds.features.cols, hidden, rng);
        let l2 = FilmLayer::new(hidden, ds.n_classes, rng);
        let adam = Adam::new(
            &[
                l1.w.data.len(), l1.g.data.len(), l1.bm.data.len(), l1.bias.len(),
                l2.w.data.len(), l2.g.data.len(), l2.bm.data.len(), l2.bias.len(),
            ],
            lr,
        );
        let n = ds.adj.rows;
        let rho = SparseOps::row_sums(&ds.adj_norm);
        let train_slots = FilmSlots {
            x: eng.add_slot("film.X", ds.features.clone()),
            a1: eng.add_slot("film.A.l1", ds.adj_norm.clone()),
            a2: eng.add_slot("film.A.l2", ds.adj_norm.clone()),
            h1: eng.add_slot("film.H1", Coo::from_triples(n, hidden, vec![])),
        };
        Film {
            slots: train_slots,
            train_slots,
            eval_slots: None,
            l1,
            l2,
            adam,
            train_rho: rho,
            eval_rho: Vec::new(),
            cache: None,
        }
    }

    /// ρ for the active binding — derived from which slot set is active,
    /// so the engine operands and the model-side ρ can never desync.
    fn rho(&self) -> &[f32] {
        if self.eval_slots.is_some_and(|e| e.x == self.slots.x) {
            &self.eval_rho
        } else {
            &self.train_rho
        }
    }

    pub fn forward(&mut self, eng: &mut AdjEngine) -> Matrix {
        let sl = self.slots;
        // Layer 1 (input = sparse X).
        let gamma1 = eng.spmm(sl.x, &self.l1.g);
        let beta1 = eng.spmm(sl.x, &self.l1.bm);
        let zw1 = eng.spmm(sl.x, &self.l1.w);
        let p1 = eng.spmm(sl.a1, &zw1);
        let pre1 = ops::add_row(
            &ops::add(&ops::mul(&gamma1, &p1), &scale_rows(&beta1, self.rho())),
            &self.l1.bias,
        );
        let h1_dense = ops::relu(&pre1);
        eng.update_slot_dense(sl.h1, &h1_dense);

        // Layer 2 (input = sparsified H1).
        let gamma2 = eng.spmm(sl.h1, &self.l2.g);
        let beta2 = eng.spmm(sl.h1, &self.l2.bm);
        let zw2 = eng.spmm(sl.h1, &self.l2.w);
        let p2 = eng.spmm(sl.a2, &zw2);
        let logits = ops::add_row(
            &ops::add(&ops::mul(&gamma2, &p2), &scale_rows(&beta2, self.rho())),
            &self.l2.bias,
        );
        self.cache = Some(Cache { gamma1, p1, pre1, gamma2, p2 });
        logits
    }

    /// Backward pass returning parameter gradients without applying them
    /// (the mini-batch accumulation path).
    pub fn backward_grads(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) -> FilmGrads {
        let cache = self.cache.take().expect("forward before backward");
        let sl = self.slots;
        let db2 = ops::col_sums(dlogits);
        // Layer 2.
        let dgamma2 = ops::mul(&cache.p2, dlogits);
        let dp2 = ops::mul(&cache.gamma2, dlogits);
        let dbeta2 = scale_rows(dlogits, self.rho());
        let dzw2 = eng.spmm(sl.a2, &dp2); // Âᵀ = Â
        // H1ᵀ·… — transpose-free on the H1 slot.
        let dw2 = eng.spmm_t(sl.h1, &dzw2);
        let dg2 = eng.spmm_t(sl.h1, &dgamma2);
        let dbm2 = eng.spmm_t(sl.h1, &dbeta2);
        let dh1 = {
            let a = dzw2.matmul_t(&self.l2.w);
            let b = dgamma2.matmul_t(&self.l2.g);
            let c = dbeta2.matmul_t(&self.l2.bm);
            ops::add(&ops::add(&a, &b), &c)
        };

        // Layer 1 through ReLU.
        let dpre1 = ops::relu_grad(&cache.pre1, &dh1);
        let db1 = ops::col_sums(&dpre1);
        let dgamma1 = ops::mul(&cache.p1, &dpre1);
        let dp1 = ops::mul(&cache.gamma1, &dpre1);
        let dbeta1 = scale_rows(&dpre1, self.rho());
        let dzw1 = eng.spmm(sl.a1, &dp1);
        // Xᵀ·… — transpose-free on the X slot.
        let dw1 = eng.spmm_t(sl.x, &dzw1);
        let dg1 = eng.spmm_t(sl.x, &dgamma1);
        let dbm1 = eng.spmm_t(sl.x, &dbeta1);

        FilmGrads {
            l1: FilmLayerGrads { dw: dw1, dg: dg1, dbm: dbm1, dbias: db1 },
            l2: FilmLayerGrads { dw: dw2, dg: dg2, dbm: dbm2, dbias: db2 },
        }
    }

    /// One Adam step from (possibly accumulated) gradients.
    pub fn apply_grads(&mut self, g: &FilmGrads) {
        self.adam.tick();
        self.adam.update_matrix(0, &mut self.l1.w, &g.l1.dw);
        self.adam.update_matrix(1, &mut self.l1.g, &g.l1.dg);
        self.adam.update_matrix(2, &mut self.l1.bm, &g.l1.dbm);
        self.adam.update(3, &mut self.l1.bias, &g.l1.dbias);
        self.adam.update_matrix(4, &mut self.l2.w, &g.l2.dw);
        self.adam.update_matrix(5, &mut self.l2.g, &g.l2.dg);
        self.adam.update_matrix(6, &mut self.l2.bm, &g.l2.dbm);
        self.adam.update(7, &mut self.l2.bias, &g.l2.dbias);
    }

    /// Backward + Adam step (full-batch path).
    pub fn backward(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) {
        let g = self.backward_grads(eng, dlogits);
        self.apply_grads(&g);
    }

    /// Point the model's train slots at a new (sub)graph: induced feature
    /// rows `x` and induced normalized adjacency `a`. ρ (the per-node
    /// normalized degree the modulation scales by) is recomputed from `a`'s
    /// row sums via the format-dispatched `row_sums` — no COO round-trip
    /// for CSR shards.
    pub fn set_graph(
        &mut self,
        eng: &mut AdjEngine,
        x: impl Into<SharedMatrix>,
        a: impl Into<SharedMatrix>,
    ) {
        self.slots = self.train_slots;
        let a = a.into();
        self.train_rho = a.row_sums();
        eng.set_slot_matrix(self.train_slots.x, x);
        eng.set_slot_matrix(self.train_slots.a1, a.clone());
        eng.set_slot_matrix(self.train_slots.a2, a);
    }

    /// Create + bind the dedicated full-graph eval slots once (handle
    /// bumps, zero matrix-data copies); ρ for the full graph is computed
    /// here exactly once. See [`super::gcn::Gcn::bind_eval_graph`].
    pub fn bind_eval_graph(&mut self, eng: &mut AdjEngine, x: SharedMatrix, a: SharedMatrix) {
        assert!(self.eval_slots.is_none(), "eval slots are bound once at startup");
        let n = a.rows();
        let hidden = self.l1.bias.len();
        self.eval_rho = a.row_sums();
        self.eval_slots = Some(FilmSlots {
            x: eng.add_slot_shared("film.X.eval", x),
            a1: eng.add_slot_shared("film.A.l1.eval", a.clone()),
            a2: eng.add_slot_shared("film.A.l2.eval", a),
            h1: eng.add_slot("film.H1.eval", Coo::from_triples(n, hidden, vec![])),
        });
    }

    /// Flip onto the full-graph eval slots (and eval ρ) — O(1), no engine
    /// traffic, no allocations.
    pub fn use_eval_graph(&mut self) {
        self.slots = self.eval_slots.expect("bind_eval_graph before use_eval_graph");
    }

    /// Flip back onto the train/shard slots (`set_graph` also does this).
    pub fn use_train_graph(&mut self) {
        self.slots = self.train_slots;
    }

    /// Copy trained parameters from a template model (serving replication;
    /// see [`super::gcn::Gcn::copy_weights_from`]). ρ is a graph property,
    /// not a weight — it stays per-replica and follows `set_graph`.
    pub fn copy_weights_from(&mut self, other: &Film) {
        for (dst, src) in [(&mut self.l1, &other.l1), (&mut self.l2, &other.l2)] {
            assert_eq!(dst.w.data.len(), src.w.data.len(), "layer shape mismatch");
            dst.w.data.copy_from_slice(&src.w.data);
            dst.g.data.copy_from_slice(&src.g.data);
            dst.bm.data.copy_from_slice(&src.bm.data);
            dst.bias.copy_from_slice(&src.bias);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::engine::StaticPolicy;
    use crate::graph::DatasetSpec;
    use crate::sparse::Format;

    fn tiny_dataset(rng: &mut Rng) -> GraphDataset {
        let spec = DatasetSpec {
            name: "Tiny",
            n: 100,
            feat_dim: 20,
            adj_density: 0.06,
            feat_density: 0.2,
            n_classes: 3,
        };
        GraphDataset::generate(&spec, rng)
    }

    #[test]
    fn loss_decreases() {
        let mut rng = Rng::new(1);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Film::new(&ds, 12, 0.02, &mut rng, &mut eng);
        let mut losses = Vec::new();
        for _ in 0..40 {
            let logits = model.forward(&mut eng);
            let (loss, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
            model.backward(&mut eng, &dlogits);
            losses.push(loss);
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.7),
            "FiLM loss should drop: {:?} -> {:?}",
            losses.first(),
            losses.last()
        );
    }

    #[test]
    fn modulation_params_learn() {
        let mut rng = Rng::new(2);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Coo);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Film::new(&ds, 8, 0.05, &mut rng, &mut eng);
        let g_before = model.l1.g.clone();
        for _ in 0..3 {
            let logits = model.forward(&mut eng);
            let (_, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
            model.backward(&mut eng, &dlogits);
        }
        assert!(model.l1.g.max_abs_diff(&g_before) > 1e-7);
    }
}
