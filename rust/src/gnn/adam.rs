//! Adam optimizer state for a named set of dense parameters.

use crate::tensor::Matrix;

/// Adam moments for one parameter tensor.
#[derive(Clone, Debug)]
pub struct AdamParam {
    m: Vec<f32>,
    v: Vec<f32>,
}

/// Adam optimizer over a model's parameter list.
#[derive(Clone, Debug)]
pub struct Adam {
    states: Vec<AdamParam>,
    t: usize,
    pub lr: f32,
    pub weight_decay: f32,
}

const B1: f32 = 0.9;
const B2: f32 = 0.999;
const EPS: f32 = 1e-8;

impl Adam {
    /// `sizes[i]` is the flat length of parameter `i`.
    pub fn new(sizes: &[usize], lr: f32) -> Adam {
        Adam {
            states: sizes
                .iter()
                .map(|&n| AdamParam { m: vec![0.0; n], v: vec![0.0; n] })
                .collect(),
            t: 0,
            lr,
            weight_decay: 0.0,
        }
    }

    /// Begin an optimization step (advances the shared timestep).
    pub fn tick(&mut self) {
        self.t += 1;
    }

    /// Update parameter `idx` in place with gradient `grad`.
    pub fn update(&mut self, idx: usize, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        let st = &mut self.states[idx];
        assert_eq!(st.m.len(), params.len());
        let bc1 = 1.0 - B1.powi(self.t as i32);
        let bc2 = 1.0 - B2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grad[i] + self.weight_decay * params[i];
            st.m[i] = B1 * st.m[i] + (1.0 - B1) * g;
            st.v[i] = B2 * st.v[i] + (1.0 - B2) * g * g;
            params[i] -= self.lr * (st.m[i] / bc1) / ((st.v[i] / bc2).sqrt() + EPS);
        }
    }

    /// Convenience for matrix parameters.
    pub fn update_matrix(&mut self, idx: usize, param: &mut Matrix, grad: &Matrix) {
        assert_eq!(param.shape(), grad.shape());
        // Split borrow: Matrix exposes data directly.
        let data = std::mem::take(&mut param.data);
        let mut data = data;
        self.update(idx, &mut data, &grad.data);
        param.data = data;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        // minimize (w - 3)^2 → w → 3
        let mut w = vec![0.0f32];
        let mut opt = Adam::new(&[1], 0.1);
        for _ in 0..500 {
            opt.tick();
            let grad = vec![2.0 * (w[0] - 3.0)];
            opt.update(0, &mut w, &grad);
        }
        assert!((w[0] - 3.0).abs() < 0.05, "w={}", w[0]);
    }

    #[test]
    fn multiple_params_independent() {
        let mut a = vec![0.0f32];
        let mut b = vec![0.0f32];
        let mut opt = Adam::new(&[1, 1], 0.05);
        for _ in 0..800 {
            opt.tick();
            let ga = [2.0 * (a[0] - 1.0)];
            opt.update(0, &mut a, &ga);
            let gb = [2.0 * (b[0] + 2.0)];
            opt.update(1, &mut b, &gb);
        }
        assert!((a[0] - 1.0).abs() < 0.05);
        assert!((b[0] + 2.0).abs() < 0.05);
    }
}
