//! Relational GCN (Schlichtkrull et al. [26]) — two layers over R typed
//! adjacencies:
//!
//! ```text
//! H' = ReLU( Σ_r Â_r · (H · W_r)  +  H · W_self + b )
//! ```
//!
//! Each relation's adjacency is an independent engine slot per layer (the
//! paper's per-layer decisions apply per relation matrix). Edge types are
//! derived by partitioning the dataset's edges into `R` relations. Weight
//! gradients (`Xᵀ·…`, `H1ᵀ·…`) run transpose-free through
//! [`AdjEngine::spmm_t`] on the forward slots (§Perf).

use super::adam::Adam;
use super::engine::AdjEngine;
use crate::graph::{normalize_adj, GraphDataset};
use crate::sparse::Coo;
use crate::tensor::{ops, Matrix};
use crate::util::rng::Rng;

/// Number of relation types carved from the edge set.
pub const N_RELATIONS: usize = 3;

struct RgcnLayer {
    w_rel: Vec<Matrix>,
    w_self: Matrix,
    bias: Vec<f32>,
}

impl RgcnLayer {
    fn new(d_in: usize, d_out: usize, rng: &mut Rng) -> RgcnLayer {
        RgcnLayer {
            w_rel: (0..N_RELATIONS).map(|_| Matrix::glorot(d_in, d_out, rng)).collect(),
            w_self: Matrix::glorot(d_in, d_out, rng),
            bias: vec![0.0; d_out],
        }
    }
}

/// Two-layer RGCN.
pub struct Rgcn {
    l1: RgcnLayer,
    l2: RgcnLayer,
    adam: Adam,
    s_x: usize,
    /// `s_rel[layer][relation]`.
    s_rel: [[usize; N_RELATIONS]; 2],
    s_h1: usize,
    cache: Option<Cache>,
}

struct Cache {
    pre1: Matrix,
}

/// Partition edges into relation buckets by a deterministic hash.
pub fn split_relations(adj: &Coo, n_rels: usize) -> Vec<Coo> {
    let mut buckets: Vec<Vec<(u32, u32, f32)>> = vec![Vec::new(); n_rels];
    for i in 0..adj.nnz() {
        let (r, c) = (adj.row[i], adj.col[i]);
        // Undirected edge key so both directions land in one relation.
        let (a, b) = if r < c { (r, c) } else { (c, r) };
        let h = (a as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(b as u64)
            .wrapping_mul(0xBF58_476D_1CE4_E5B9);
        let k = (h >> 32) as usize % n_rels;
        buckets[k].push((r, c, adj.val[i]));
    }
    buckets
        .into_iter()
        .map(|t| Coo::from_triples(adj.rows, adj.cols, t))
        .collect()
}

impl Rgcn {
    pub fn new(
        ds: &GraphDataset,
        hidden: usize,
        lr: f32,
        rng: &mut Rng,
        eng: &mut AdjEngine,
    ) -> Rgcn {
        let rels: Vec<Coo> = split_relations(&ds.adj, N_RELATIONS)
            .iter()
            .map(normalize_adj)
            .collect();
        let l1 = RgcnLayer::new(ds.features.cols, hidden, rng);
        let l2 = RgcnLayer::new(hidden, ds.n_classes, rng);
        let mut sizes = Vec::new();
        for l in [&l1, &l2] {
            for w in &l.w_rel {
                sizes.push(w.data.len());
            }
            sizes.push(l.w_self.data.len());
            sizes.push(l.bias.len());
        }
        let adam = Adam::new(&sizes, lr);
        let mut s_rel = [[0usize; N_RELATIONS]; 2];
        for (layer, slots) in s_rel.iter_mut().enumerate() {
            for (r, slot) in slots.iter_mut().enumerate() {
                *slot = eng.add_slot(&format!("rgcn.A{r}.l{}", layer + 1), rels[r].clone());
            }
        }
        let n = ds.adj.rows;
        Rgcn {
            s_x: eng.add_slot("rgcn.X", ds.features.clone()),
            s_h1: eng.add_slot("rgcn.H1", Coo::from_triples(n, hidden, vec![])),
            l1,
            l2,
            adam,
            s_rel,
            cache: None,
        }
    }

    pub fn forward(&mut self, eng: &mut AdjEngine) -> Matrix {
        // Layer 1: input X (sparse slot).
        let mut pre1: Option<Matrix> = None;
        for r in 0..N_RELATIONS {
            let zw = eng.spmm(self.s_x, &self.l1.w_rel[r]); // X·W_r
            let p = eng.spmm(self.s_rel[0][r], &zw); // Â_r·(X·W_r)
            pre1 = Some(match pre1 {
                None => p,
                Some(acc) => ops::add(&acc, &p),
            });
        }
        let self1 = eng.spmm(self.s_x, &self.l1.w_self);
        let pre1 = ops::add_row(&ops::add(&pre1.unwrap(), &self1), &self.l1.bias);
        eng.recycle(self.s_x, self1);
        let h1_dense = ops::relu(&pre1);
        eng.update_slot_dense(self.s_h1, &h1_dense);

        // Layer 2: input H1 (sparse slot).
        let mut pre2: Option<Matrix> = None;
        for r in 0..N_RELATIONS {
            let zw = eng.spmm(self.s_h1, &self.l2.w_rel[r]);
            let p = eng.spmm(self.s_rel[1][r], &zw);
            pre2 = Some(match pre2 {
                None => p,
                Some(acc) => ops::add(&acc, &p),
            });
        }
        let self2 = eng.spmm(self.s_h1, &self.l2.w_self);
        let logits = ops::add_row(&ops::add(&pre2.unwrap(), &self2), &self.l2.bias);
        eng.recycle(self.s_h1, self2);
        self.cache = Some(Cache { pre1 });
        logits
    }

    pub fn backward(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) {
        let cache = self.cache.take().expect("forward before backward");
        let db2 = ops::col_sums(dlogits);
        // Layer 2 gradients.
        let mut dh1 = dlogits.matmul_t(&self.l2.w_self); // self path
        let mut dw2_rel = Vec::with_capacity(N_RELATIONS);
        for r in 0..N_RELATIONS {
            let da = eng.spmm(self.s_rel[1][r], dlogits); // Â_rᵀ·dlogits (sym)
            let dw = eng.spmm_t(self.s_h1, &da); // H1ᵀ·(Â_r dlogits)
            dh1 = ops::add(&dh1, &da.matmul_t(&self.l2.w_rel[r]));
            eng.recycle(self.s_rel[1][r], da);
            dw2_rel.push(dw);
        }
        let dw2_self = eng.spmm_t(self.s_h1, dlogits);

        // Through ReLU.
        let dpre1 = ops::relu_grad(&cache.pre1, &dh1);
        let db1 = ops::col_sums(&dpre1);
        let mut dw1_rel = Vec::with_capacity(N_RELATIONS);
        for r in 0..N_RELATIONS {
            let da = eng.spmm(self.s_rel[0][r], &dpre1);
            dw1_rel.push(eng.spmm_t(self.s_x, &da));
            eng.recycle(self.s_rel[0][r], da);
        }
        let dw1_self = eng.spmm_t(self.s_x, &dpre1);

        // Adam updates (parameter order matches `new`).
        self.adam.tick();
        let mut idx = 0;
        for r in 0..N_RELATIONS {
            self.adam.update_matrix(idx, &mut self.l1.w_rel[r], &dw1_rel[r]);
            idx += 1;
        }
        self.adam.update_matrix(idx, &mut self.l1.w_self, &dw1_self);
        idx += 1;
        self.adam.update(idx, &mut self.l1.bias, &db1);
        idx += 1;
        for r in 0..N_RELATIONS {
            self.adam.update_matrix(idx, &mut self.l2.w_rel[r], &dw2_rel[r]);
            idx += 1;
        }
        self.adam.update_matrix(idx, &mut self.l2.w_self, &dw2_self);
        idx += 1;
        self.adam.update(idx, &mut self.l2.bias, &db2);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::engine::StaticPolicy;
    use crate::graph::DatasetSpec;
    use crate::sparse::Format;

    fn tiny_dataset(rng: &mut Rng) -> GraphDataset {
        let spec = DatasetSpec {
            name: "Tiny",
            n: 100,
            feat_dim: 20,
            adj_density: 0.06,
            feat_density: 0.2,
            n_classes: 3,
        };
        GraphDataset::generate(&spec, rng)
    }

    #[test]
    fn relations_partition_edges() {
        let mut rng = Rng::new(1);
        let ds = tiny_dataset(&mut rng);
        let rels = split_relations(&ds.adj, N_RELATIONS);
        let total: usize = rels.iter().map(|r| r.nnz()).sum();
        assert_eq!(total, ds.adj.nnz());
        // Both directions of an undirected edge share a relation →
        // each relation matrix stays symmetric.
        for r in &rels {
            assert_eq!(r.transpose(), *r);
        }
    }

    #[test]
    fn loss_decreases() {
        let mut rng = Rng::new(2);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Rgcn::new(&ds, 12, 0.02, &mut rng, &mut eng);
        let mut losses = Vec::new();
        for _ in 0..40 {
            let logits = model.forward(&mut eng);
            let (loss, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
            model.backward(&mut eng, &dlogits);
            losses.push(loss);
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.7),
            "RGCN loss should drop: {:?} -> {:?}",
            losses.first(),
            losses.last()
        );
    }
}
