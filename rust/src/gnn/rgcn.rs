//! Relational GCN (Schlichtkrull et al. [26]) — two layers over R typed
//! adjacencies:
//!
//! ```text
//! H' = ReLU( Σ_r Â_r · (H · W_r)  +  H · W_self + b )
//! ```
//!
//! Each relation's adjacency is an independent engine slot per layer (the
//! paper's per-layer decisions apply per relation matrix). Edge types are
//! derived by partitioning the dataset's edges into `R` relations. Weight
//! gradients (`Xᵀ·…`, `H1ᵀ·…`) run transpose-free through
//! [`AdjEngine::spmm_t`] on the forward slots (§Perf).

use super::adam::Adam;
use super::engine::AdjEngine;
use crate::graph::{normalize_adj, GraphDataset};
use crate::sparse::{Coo, SharedMatrix};
use crate::tensor::{ops, Matrix};
use crate::util::rng::Rng;

/// Number of relation types carved from the edge set.
pub const N_RELATIONS: usize = 3;

struct RgcnLayer {
    w_rel: Vec<Matrix>,
    w_self: Matrix,
    bias: Vec<f32>,
}

impl RgcnLayer {
    fn new(d_in: usize, d_out: usize, rng: &mut Rng) -> RgcnLayer {
        RgcnLayer {
            w_rel: (0..N_RELATIONS).map(|_| Matrix::glorot(d_in, d_out, rng)).collect(),
            w_self: Matrix::glorot(d_in, d_out, rng),
            bias: vec![0.0; d_out],
        }
    }
}

/// Engine slot ids for one graph binding (train shards or the dedicated
/// full-graph eval binding — §Shared-Ownership double-buffering).
#[derive(Clone, Copy)]
struct RgcnSlots {
    x: usize,
    /// `rel[layer][relation]`.
    rel: [[usize; N_RELATIONS]; 2],
    h1: usize,
}

/// Two-layer RGCN.
pub struct Rgcn {
    l1: RgcnLayer,
    l2: RgcnLayer,
    adam: Adam,
    slots: RgcnSlots,
    train_slots: RgcnSlots,
    eval_slots: Option<RgcnSlots>,
    cache: Option<Cache>,
}

struct Cache {
    pre1: Matrix,
}

/// Partition edges into relation buckets by a deterministic hash.
pub fn split_relations(adj: &Coo, n_rels: usize) -> Vec<Coo> {
    let mut buckets: Vec<Vec<(u32, u32, f32)>> = vec![Vec::new(); n_rels];
    for i in 0..adj.nnz() {
        let (r, c) = (adj.row[i], adj.col[i]);
        // Undirected edge key so both directions land in one relation.
        let (a, b) = if r < c { (r, c) } else { (c, r) };
        let h = (a as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(b as u64)
            .wrapping_mul(0xBF58_476D_1CE4_E5B9);
        let k = (h >> 32) as usize % n_rels;
        buckets[k].push((r, c, adj.val[i]));
    }
    buckets
        .into_iter()
        .map(|t| Coo::from_triples(adj.rows, adj.cols, t))
        .collect()
}

/// Full-graph per-relation **normalized** adjacencies — the operands
/// [`Rgcn::new`] registers per layer, and the masters the mini-batch
/// driver slices shard submatrices off (`gnn::minibatch`).
pub fn relation_operands(adj: &Coo) -> Vec<Coo> {
    split_relations(adj, N_RELATIONS).iter().map(normalize_adj).collect()
}

/// One RGCN layer's parameter gradients.
pub struct RgcnLayerGrads {
    pub dw_rel: Vec<Matrix>,
    pub dw_self: Matrix,
    pub dbias: Vec<f32>,
}

/// One backward pass's parameter gradients — the mini-batch accumulation
/// unit (see `gnn::minibatch`).
pub struct RgcnGrads {
    pub l1: RgcnLayerGrads,
    pub l2: RgcnLayerGrads,
}

impl RgcnGrads {
    /// `self += w · other` (shard-weighted gradient accumulation).
    pub fn add_scaled(&mut self, o: &RgcnGrads, w: f32) {
        for (a, b) in [(&mut self.l1, &o.l1), (&mut self.l2, &o.l2)] {
            for (da, db) in a.dw_rel.iter_mut().zip(b.dw_rel.iter()) {
                ops::axpy_slice(&mut da.data, &db.data, w);
            }
            ops::axpy_slice(&mut a.dw_self.data, &b.dw_self.data, w);
            ops::axpy_slice(&mut a.dbias, &b.dbias, w);
        }
    }

    /// `self *= w`.
    pub fn scale(&mut self, w: f32) {
        for l in [&mut self.l1, &mut self.l2] {
            for dw in &mut l.dw_rel {
                ops::scale_slice(&mut dw.data, w);
            }
            ops::scale_slice(&mut l.dw_self.data, w);
            ops::scale_slice(&mut l.dbias, w);
        }
    }
}

impl Rgcn {
    pub fn new(
        ds: &GraphDataset,
        hidden: usize,
        lr: f32,
        rng: &mut Rng,
        eng: &mut AdjEngine,
    ) -> Rgcn {
        Rgcn::with_relations(ds, &relation_operands(&ds.adj), hidden, lr, rng, eng)
    }

    /// Build from **precomputed** normalized relation operands
    /// ([`relation_operands`]). The mini-batch driver computes them once
    /// and shares them between the model's slots and its extraction
    /// masters instead of splitting + normalizing the edge set twice.
    /// Consumes `rng` exactly like [`Rgcn::new`].
    pub fn with_relations(
        ds: &GraphDataset,
        rels: &[Coo],
        hidden: usize,
        lr: f32,
        rng: &mut Rng,
        eng: &mut AdjEngine,
    ) -> Rgcn {
        assert_eq!(rels.len(), N_RELATIONS, "one operand per relation");
        let l1 = RgcnLayer::new(ds.features.cols, hidden, rng);
        let l2 = RgcnLayer::new(hidden, ds.n_classes, rng);
        let mut sizes = Vec::new();
        for l in [&l1, &l2] {
            for w in &l.w_rel {
                sizes.push(w.data.len());
            }
            sizes.push(l.w_self.data.len());
            sizes.push(l.bias.len());
        }
        let adam = Adam::new(&sizes, lr);
        let mut s_rel = [[0usize; N_RELATIONS]; 2];
        for (layer, slots) in s_rel.iter_mut().enumerate() {
            for (r, slot) in slots.iter_mut().enumerate() {
                *slot = eng.add_slot(&format!("rgcn.A{r}.l{}", layer + 1), rels[r].clone());
            }
        }
        let n = ds.adj.rows;
        let train_slots = RgcnSlots {
            x: eng.add_slot("rgcn.X", ds.features.clone()),
            rel: s_rel,
            h1: eng.add_slot("rgcn.H1", Coo::from_triples(n, hidden, vec![])),
        };
        Rgcn {
            slots: train_slots,
            train_slots,
            eval_slots: None,
            l1,
            l2,
            adam,
            cache: None,
        }
    }

    pub fn forward(&mut self, eng: &mut AdjEngine) -> Matrix {
        let sl = self.slots;
        // Layer 1: input X (sparse slot).
        let mut pre1: Option<Matrix> = None;
        for r in 0..N_RELATIONS {
            let zw = eng.spmm(sl.x, &self.l1.w_rel[r]); // X·W_r
            let p = eng.spmm(sl.rel[0][r], &zw); // Â_r·(X·W_r)
            pre1 = Some(match pre1 {
                None => p,
                Some(acc) => ops::add(&acc, &p),
            });
        }
        let self1 = eng.spmm(sl.x, &self.l1.w_self);
        let pre1 = ops::add_row(&ops::add(&pre1.unwrap(), &self1), &self.l1.bias);
        eng.recycle(sl.x, self1);
        let h1_dense = ops::relu(&pre1);
        eng.update_slot_dense(sl.h1, &h1_dense);

        // Layer 2: input H1 (sparse slot).
        let mut pre2: Option<Matrix> = None;
        for r in 0..N_RELATIONS {
            let zw = eng.spmm(sl.h1, &self.l2.w_rel[r]);
            let p = eng.spmm(sl.rel[1][r], &zw);
            pre2 = Some(match pre2 {
                None => p,
                Some(acc) => ops::add(&acc, &p),
            });
        }
        let self2 = eng.spmm(sl.h1, &self.l2.w_self);
        let logits = ops::add_row(&ops::add(&pre2.unwrap(), &self2), &self.l2.bias);
        eng.recycle(sl.h1, self2);
        self.cache = Some(Cache { pre1 });
        logits
    }

    /// Backward pass returning parameter gradients without applying them
    /// (the mini-batch accumulation path).
    pub fn backward_grads(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) -> RgcnGrads {
        let cache = self.cache.take().expect("forward before backward");
        let sl = self.slots;
        let db2 = ops::col_sums(dlogits);
        // Layer 2 gradients.
        let mut dh1 = dlogits.matmul_t(&self.l2.w_self); // self path
        let mut dw2_rel = Vec::with_capacity(N_RELATIONS);
        for r in 0..N_RELATIONS {
            let da = eng.spmm(sl.rel[1][r], dlogits); // Â_rᵀ·dlogits (sym)
            let dw = eng.spmm_t(sl.h1, &da); // H1ᵀ·(Â_r dlogits)
            dh1 = ops::add(&dh1, &da.matmul_t(&self.l2.w_rel[r]));
            eng.recycle(sl.rel[1][r], da);
            dw2_rel.push(dw);
        }
        let dw2_self = eng.spmm_t(sl.h1, dlogits);

        // Through ReLU.
        let dpre1 = ops::relu_grad(&cache.pre1, &dh1);
        let db1 = ops::col_sums(&dpre1);
        let mut dw1_rel = Vec::with_capacity(N_RELATIONS);
        for r in 0..N_RELATIONS {
            let da = eng.spmm(sl.rel[0][r], &dpre1);
            dw1_rel.push(eng.spmm_t(sl.x, &da));
            eng.recycle(sl.rel[0][r], da);
        }
        let dw1_self = eng.spmm_t(sl.x, &dpre1);

        RgcnGrads {
            l1: RgcnLayerGrads { dw_rel: dw1_rel, dw_self: dw1_self, dbias: db1 },
            l2: RgcnLayerGrads { dw_rel: dw2_rel, dw_self: dw2_self, dbias: db2 },
        }
    }

    /// One Adam step from (possibly accumulated) gradients. Parameter
    /// order matches `new`.
    pub fn apply_grads(&mut self, g: &RgcnGrads) {
        self.adam.tick();
        let mut idx = 0;
        for r in 0..N_RELATIONS {
            self.adam.update_matrix(idx, &mut self.l1.w_rel[r], &g.l1.dw_rel[r]);
            idx += 1;
        }
        self.adam.update_matrix(idx, &mut self.l1.w_self, &g.l1.dw_self);
        idx += 1;
        self.adam.update(idx, &mut self.l1.bias, &g.l1.dbias);
        idx += 1;
        for r in 0..N_RELATIONS {
            self.adam.update_matrix(idx, &mut self.l2.w_rel[r], &g.l2.dw_rel[r]);
            idx += 1;
        }
        self.adam.update_matrix(idx, &mut self.l2.w_self, &g.l2.dw_self);
        idx += 1;
        self.adam.update(idx, &mut self.l2.bias, &g.l2.dbias);
    }

    /// Backward + Adam step (full-batch path).
    pub fn backward(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) {
        let g = self.backward_grads(eng, dlogits);
        self.apply_grads(&g);
    }

    /// Point the model's train slots at a new (sub)graph: induced feature
    /// rows `x` and one induced **normalized relation adjacency per
    /// relation** (both layers share each relation's *handle* — no
    /// per-layer copy). This is the per-relation rebinding the mini-batch
    /// driver uses — every relation keeps its own slot, so the decision
    /// cache holds one entry per relation per shard signature. H1
    /// re-derives itself on the next forward.
    pub fn set_graph(
        &mut self,
        eng: &mut AdjEngine,
        x: impl Into<SharedMatrix>,
        rels: Vec<SharedMatrix>,
    ) {
        assert_eq!(rels.len(), N_RELATIONS, "one submatrix per relation");
        self.slots = self.train_slots;
        eng.set_slot_matrix(self.train_slots.x, x);
        for (r, sub) in rels.into_iter().enumerate() {
            eng.set_slot_matrix(self.train_slots.rel[0][r], sub.clone());
            eng.set_slot_matrix(self.train_slots.rel[1][r], sub);
        }
    }

    /// Create + bind the dedicated full-graph eval slots once: the feature
    /// master and all R relation masters bind by handle (for RGCN the old
    /// deep-clone eval rebind was the worst offender — ~2R CSR copies per
    /// epoch, now zero). See [`super::gcn::Gcn::bind_eval_graph`].
    pub fn bind_eval_graph(
        &mut self,
        eng: &mut AdjEngine,
        x: SharedMatrix,
        rels: Vec<SharedMatrix>,
    ) {
        assert!(self.eval_slots.is_none(), "eval slots are bound once at startup");
        assert_eq!(rels.len(), N_RELATIONS, "one master per relation");
        let n = x.rows();
        let hidden = self.l1.bias.len();
        let mut rel = [[0usize; N_RELATIONS]; 2];
        for (layer, slots) in rel.iter_mut().enumerate() {
            for (r, slot) in slots.iter_mut().enumerate() {
                *slot = eng.add_slot_shared(
                    &format!("rgcn.A{r}.l{}.eval", layer + 1),
                    rels[r].clone(),
                );
            }
        }
        self.eval_slots = Some(RgcnSlots {
            x: eng.add_slot_shared("rgcn.X.eval", x),
            rel,
            h1: eng.add_slot("rgcn.H1.eval", Coo::from_triples(n, hidden, vec![])),
        });
    }

    /// Flip onto the full-graph eval slots — O(1), no engine traffic.
    pub fn use_eval_graph(&mut self) {
        self.slots = self.eval_slots.expect("bind_eval_graph before use_eval_graph");
    }

    /// Flip back onto the train/shard slots (`set_graph` also does this).
    pub fn use_train_graph(&mut self) {
        self.slots = self.train_slots;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::engine::StaticPolicy;
    use crate::graph::DatasetSpec;
    use crate::sparse::Format;

    fn tiny_dataset(rng: &mut Rng) -> GraphDataset {
        let spec = DatasetSpec {
            name: "Tiny",
            n: 100,
            feat_dim: 20,
            adj_density: 0.06,
            feat_density: 0.2,
            n_classes: 3,
        };
        GraphDataset::generate(&spec, rng)
    }

    #[test]
    fn relations_partition_edges() {
        let mut rng = Rng::new(1);
        let ds = tiny_dataset(&mut rng);
        let rels = split_relations(&ds.adj, N_RELATIONS);
        let total: usize = rels.iter().map(|r| r.nnz()).sum();
        assert_eq!(total, ds.adj.nnz());
        // Both directions of an undirected edge share a relation →
        // each relation matrix stays symmetric.
        for r in &rels {
            assert_eq!(r.transpose(), *r);
        }
    }

    /// Property suite (ISSUE-4): for random symmetric graphs and relation
    /// counts, the relation split is an exact disjoint cover of the edge
    /// set (values preserved), every relation matrix stays symmetric, and
    /// the partition is deterministic.
    #[test]
    fn prop_split_relations_cover_disjoint_symmetric() {
        use crate::testing::{check, prop_assert, PropResult};
        use std::collections::HashMap;
        check(
            25,
            |rng| {
                let n = 20 + rng.gen_range(80);
                let mut triples = Vec::new();
                for r in 0..n as u32 {
                    for c in (r + 1)..n as u32 {
                        if rng.bernoulli(0.08) {
                            let v = rng.uniform(0.1, 1.0) as f32;
                            triples.push((r, c, v));
                            triples.push((c, r, v));
                        }
                    }
                }
                let n_rels = 1 + rng.gen_range(5);
                (Coo::from_triples(n, n, triples), n_rels)
            },
            |(adj, n_rels)| -> PropResult {
                let rels = split_relations(adj, *n_rels);
                prop_assert(rels.len() == *n_rels, "one bucket per relation")?;
                // Disjoint cover with values preserved: the multiset of
                // entries across relations equals the original edge set.
                let mut seen: HashMap<(u32, u32), f32> = HashMap::new();
                for rel in &rels {
                    prop_assert(
                        (rel.rows, rel.cols) == (adj.rows, adj.cols),
                        "relation keeps the graph shape",
                    )?;
                    for i in 0..rel.nnz() {
                        prop_assert(
                            seen.insert((rel.row[i], rel.col[i]), rel.val[i]).is_none(),
                            "edge assigned to exactly one relation",
                        )?;
                    }
                    // Symmetry: both directions of an undirected edge hash
                    // to the same relation.
                    prop_assert(rel.transpose() == *rel, "relation symmetric")?;
                }
                prop_assert(seen.len() == adj.nnz(), "edges covered exactly")?;
                for i in 0..adj.nnz() {
                    prop_assert(
                        seen.get(&(adj.row[i], adj.col[i])) == Some(&adj.val[i]),
                        "edge value preserved",
                    )?;
                }
                // Deterministic for the same input.
                prop_assert(split_relations(adj, *n_rels) == rels, "deterministic")
            },
        );
    }

    /// Self-loops hash on the degenerate key (v, v): each lands in exactly
    /// one relation with its weight intact, and symmetry is unaffected.
    #[test]
    fn split_relations_handles_self_loops() {
        let adj = Coo::from_triples(
            6,
            6,
            vec![
                (0, 0, 2.0),
                (1, 1, 3.0),
                (5, 5, 1.0),
                (0, 1, 1.0),
                (1, 0, 1.0),
                (2, 4, 1.0),
                (4, 2, 1.0),
            ],
        );
        let rels = split_relations(&adj, N_RELATIONS);
        let total: usize = rels.iter().map(|r| r.nnz()).sum();
        assert_eq!(total, adj.nnz());
        let mut loop_count = 0;
        for rel in &rels {
            assert_eq!(rel.transpose(), *rel);
            for i in 0..rel.nnz() {
                if rel.row[i] == rel.col[i] {
                    loop_count += 1;
                    let v = rel.val[i];
                    assert!(v == 2.0 || v == 3.0 || v == 1.0);
                }
            }
        }
        assert_eq!(loop_count, 3, "every self-loop lands in exactly one relation");
    }

    /// The grads-split refactor must leave full-batch RGCN identical:
    /// `backward` ≡ `backward_grads` + `apply_grads`.
    #[test]
    fn split_backward_matches_fused_backward() {
        let run = |split: bool| -> Matrix {
            let mut rng = Rng::new(77);
            let ds = tiny_dataset(&mut rng);
            let mut policy = StaticPolicy(Format::Csr);
            let mut eng = AdjEngine::new(&mut policy);
            let mut model = Rgcn::new(&ds, 8, 0.02, &mut rng, &mut eng);
            for _ in 0..4 {
                let logits = model.forward(&mut eng);
                let (_, dlogits) =
                    ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
                if split {
                    let g = model.backward_grads(&mut eng, &dlogits);
                    model.apply_grads(&g);
                } else {
                    model.backward(&mut eng, &dlogits);
                }
            }
            model.forward(&mut eng)
        };
        let a = run(false);
        let b = run(true);
        assert!(a.max_abs_diff(&b) < 1e-6, "split/fused RGCN backward diverged");
    }

    #[test]
    fn loss_decreases() {
        let mut rng = Rng::new(2);
        let ds = tiny_dataset(&mut rng);
        let mut policy = StaticPolicy(Format::Csr);
        let mut eng = AdjEngine::new(&mut policy);
        let mut model = Rgcn::new(&ds, 12, 0.02, &mut rng, &mut eng);
        let mut losses = Vec::new();
        for _ in 0..40 {
            let logits = model.forward(&mut eng);
            let (loss, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
            model.backward(&mut eng, &dlogits);
            losses.push(loss);
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.7),
            "RGCN loss should drop: {:?} -> {:?}",
            losses.first(),
            losses.last()
        );
    }
}
