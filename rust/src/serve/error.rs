//! The serving error taxonomy (DESIGN.md §Fault-Tolerance).
//!
//! Every failure a request can meet maps to one typed variant, and every
//! submitted request gets **exactly one** response carrying either logits
//! or one of these — a panic costs the request, never the server. The
//! variants split by where the failure was decided: at admission
//! (`QueueFull`, `Closed`, `Degraded`), at dequeue (`DeadlineExceeded`),
//! or during inference (`WorkerPanic`, `CorruptOperand`);
//! `InvalidSnapshot` is the publish-side rejection that never reaches a
//! request at all.

use crate::sparse::FormatError;

/// Why a request (or a snapshot publication) failed.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The worker's inference panicked; the worker is respawned by the
    /// supervisor (within the restart budget) and only this request pays.
    WorkerPanic { worker: usize, detail: String },
    /// `try_submit` shed the request: the queue is at capacity.
    QueueFull,
    /// The server is shutting down; the queue no longer admits work.
    Closed,
    /// The request's deadline had already passed when a worker dequeued
    /// it — dropped without inference (the work would be wasted anyway).
    DeadlineExceeded,
    /// A per-request sparse operand failed structural validation.
    CorruptOperand(FormatError),
    /// A published snapshot failed structural validation; the previous
    /// snapshot stays current.
    InvalidSnapshot(FormatError),
    /// The restart budget is exhausted and the server stopped admitting
    /// (or, with no workers left, serving) requests.
    Degraded,
}

impl ServeError {
    /// Stable short tag for logs/metrics.
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::WorkerPanic { .. } => "worker_panic",
            ServeError::QueueFull => "queue_full",
            ServeError::Closed => "closed",
            ServeError::DeadlineExceeded => "deadline_exceeded",
            ServeError::CorruptOperand(_) => "corrupt_operand",
            ServeError::InvalidSnapshot(_) => "invalid_snapshot",
            ServeError::Degraded => "degraded",
        }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::WorkerPanic { worker, detail } => {
                write!(f, "worker {worker} panicked during inference: {detail}")
            }
            ServeError::QueueFull => write!(f, "request shed: queue at capacity"),
            ServeError::Closed => write!(f, "server is shutting down"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before inference started"),
            ServeError::CorruptOperand(e) => write!(f, "corrupt request operand: {e}"),
            ServeError::InvalidSnapshot(e) => write!(f, "rejected snapshot: {e}"),
            ServeError::Degraded => write!(f, "server degraded: worker restart budget exhausted"),
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::CorruptOperand(e) | ServeError::InvalidSnapshot(e) => Some(e),
            _ => None,
        }
    }
}

/// Best-effort human-readable panic payload (for `WorkerPanic::detail`).
pub(crate) fn panic_detail(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Format;

    #[test]
    fn kinds_and_display_are_stable() {
        let e = ServeError::WorkerPanic { worker: 3, detail: "boom".into() };
        assert_eq!(e.kind(), "worker_panic");
        assert!(e.to_string().contains("worker 3"));
        assert_eq!(ServeError::QueueFull.kind(), "queue_full");
        assert_eq!(ServeError::DeadlineExceeded.kind(), "deadline_exceeded");
        let fe = FormatError { format: Format::Csr, what: "test".into() };
        assert_eq!(ServeError::CorruptOperand(fe.clone()).kind(), "corrupt_operand");
        use std::error::Error;
        assert!(ServeError::InvalidSnapshot(fe).source().is_some());
    }

    #[test]
    fn panic_detail_extracts_strings() {
        let s: Box<dyn std::any::Any + Send> = Box::new("static str");
        assert_eq!(panic_detail(s.as_ref()), "static str");
        let s: Box<dyn std::any::Any + Send> = Box::new(String::from("owned"));
        assert_eq!(panic_detail(s.as_ref()), "owned");
        let s: Box<dyn std::any::Any + Send> = Box::new(42u8);
        assert_eq!(panic_detail(s.as_ref()), "non-string panic payload");
    }
}
