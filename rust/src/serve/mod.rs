//! Concurrent inference serving with epoch-swap snapshot isolation
//! (DESIGN.md §Serving).
//!
//! The training side of this repo amortizes format decisions over shard
//! streams; this module amortizes them over *request* streams — the
//! ROADMAP's "heavy traffic" regime, and ParamSpMM's point that adaptive
//! SpMM only pays off across many invocations. One process serves many
//! concurrent node-batch requests:
//!
//! ```text
//! submit(nodes) → bounded MPMC queue → worker pool (N threads)
//!   each worker: long-lived AdjEngine + model replica (trained weights)
//!     request → snapshot.load()  (lock held only for the Arc clone)
//!             → extract_rows_cols (induced subgraph, direct CSR paths)
//!             → forward-only inference → logits + latency record
//! writer: publish(EngineSnapshot)  — never blocks readers
//! ```
//!
//! Three rules make the hot path scale:
//!
//! * **Reads are lock-free during SpMM.** A request clones the snapshot
//!   `Arc` under a momentary read lock ([`EpochCell`]), then computes on
//!   an immutable graph no writer can touch; displaced snapshots free
//!   themselves when their last in-flight reader drops.
//! * **One warm [`DecisionCache`], shared read-only.** Workers consult it
//!   through relaxed atomics ([`AdjEngine::share_decision_cache`]); fresh
//!   decisions fall back to the worker's policy and are *not* stored —
//!   no writer lock exists to contend on.
//! * **Metrics are wait-free.** Per-request latency lands in a lock-free
//!   log-bucketed histogram ([`LatencyHistogram`]); p50/p95/p99 and
//!   ops/sec are emitted as JSON-lines ([`ServeReport`], `BENCH_serve.json`).

pub mod metrics;
pub mod queue;
pub mod snapshot;
mod worker;

pub use metrics::LatencyHistogram;
pub use queue::RequestQueue;
pub use snapshot::EngineSnapshot;

use crate::gnn::egc::Egc;
use crate::gnn::engine::StaticPolicy;
use crate::gnn::film::Film;
use crate::gnn::gcn::Gcn;
use crate::gnn::{AdjEngine, ModelKind};
use crate::graph::GraphDataset;
use crate::predictor::cache::{CacheStats, DecisionCache};
use crate::sparse::shared::EpochCell;
use crate::sparse::{Format, SharedMatrix};
use crate::tensor::{ops, Matrix};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A trained model the server replicates into each worker. Only the
/// shared-adjacency architectures serve for now (GCN / FiLM / EGC — one
/// induced adjacency per request); GAT needs a per-request attention
/// pattern and RGCN per-relation extraction, both deferred.
pub enum ServedModel {
    Gcn(Gcn),
    Film(Film),
    Egc(Egc),
}

impl ServedModel {
    pub fn kind(&self) -> ModelKind {
        match self {
            ServedModel::Gcn(_) => ModelKind::Gcn,
            ServedModel::Film(_) => ModelKind::Film,
            ServedModel::Egc(_) => ModelKind::Egc,
        }
    }

    pub fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Build an untrained model of `kind` on `eng`. Panics for kinds
    /// without a serving path (GAT, RGCN).
    pub fn build(
        kind: ModelKind,
        ds: &GraphDataset,
        hidden: usize,
        lr: f32,
        rng: &mut Rng,
        eng: &mut AdjEngine,
    ) -> ServedModel {
        match kind {
            ModelKind::Gcn => ServedModel::Gcn(Gcn::new(ds, hidden, lr, rng, eng)),
            ModelKind::Film => ServedModel::Film(Film::new(ds, hidden, lr, rng, eng)),
            ModelKind::Egc => ServedModel::Egc(Egc::new(ds, hidden, lr, rng, eng)),
            other => panic!("{} has no serving path", other.name()),
        }
    }

    /// Build a fresh replica on `eng` carrying this template's trained
    /// weights (`hidden` must match the template's).
    pub fn replicate(
        &self,
        ds: &GraphDataset,
        hidden: usize,
        lr: f32,
        rng: &mut Rng,
        eng: &mut AdjEngine,
    ) -> ServedModel {
        let mut replica = ServedModel::build(self.kind(), ds, hidden, lr, rng, eng);
        replica.copy_weights_from(self);
        replica
    }

    pub fn copy_weights_from(&mut self, other: &ServedModel) {
        match (self, other) {
            (ServedModel::Gcn(a), ServedModel::Gcn(b)) => a.copy_weights_from(b),
            (ServedModel::Film(a), ServedModel::Film(b)) => a.copy_weights_from(b),
            (ServedModel::Egc(a), ServedModel::Egc(b)) => a.copy_weights_from(b),
            _ => panic!("model kind mismatch in copy_weights_from"),
        }
    }

    pub fn set_graph(
        &mut self,
        eng: &mut AdjEngine,
        x: impl Into<SharedMatrix>,
        a: impl Into<SharedMatrix>,
    ) {
        match self {
            ServedModel::Gcn(m) => m.set_graph(eng, x, a),
            ServedModel::Film(m) => m.set_graph(eng, x, a),
            ServedModel::Egc(m) => m.set_graph(eng, x, a),
        }
    }

    pub fn forward(&mut self, eng: &mut AdjEngine) -> Matrix {
        match self {
            ServedModel::Gcn(m) => m.forward(eng),
            ServedModel::Film(m) => m.forward(eng),
            ServedModel::Egc(m) => m.forward(eng),
        }
    }

    pub fn backward(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) {
        match self {
            ServedModel::Gcn(m) => m.backward(eng, dlogits),
            ServedModel::Film(m) => m.backward(eng, dlogits),
            ServedModel::Egc(m) => m.backward(eng, dlogits),
        }
    }
}

/// Full-batch train a serving template: the short offline phase that
/// produces the weights every worker replica copies.
pub fn train_template(
    kind: ModelKind,
    ds: &GraphDataset,
    hidden: usize,
    lr: f32,
    epochs: usize,
    seed: u64,
) -> ServedModel {
    let mut rng = Rng::new(seed);
    let mut policy = StaticPolicy(Format::Csr);
    let mut eng = AdjEngine::new(&mut policy);
    let mut model = ServedModel::build(kind, ds, hidden, lr, &mut rng, &mut eng);
    for _ in 0..epochs {
        let logits = model.forward(&mut eng);
        let (_, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
        model.backward(&mut eng, &dlogits);
    }
    model
}

/// Server construction parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads (each with its own engine + model replica).
    pub workers: usize,
    /// Bounded request-queue capacity (back-pressure threshold).
    pub queue_capacity: usize,
    /// Hidden width — must match the template's.
    pub hidden: usize,
    /// Replica-construction learning rate (optimizer state is unused;
    /// serving is forward-only).
    pub lr: f32,
    pub seed: u64,
    /// Per-worker fallback policy when the shared cache has no answer.
    pub fallback_format: Format,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: 4,
            queue_capacity: 64,
            hidden: 16,
            lr: 0.02,
            seed: 0x5E21,
            fallback_format: Format::Csr,
        }
    }
}

/// One enqueued node-batch inference request.
pub struct InferenceRequest {
    pub id: u64,
    /// Sorted, duplicate-free node ids (the `extract_rows_cols` contract;
    /// [`InferenceServer::submit`] normalizes).
    pub nodes: Vec<u32>,
}

/// A completed request: logits for `nodes` (row i ↔ nodes\[i\]) computed
/// against snapshot `snapshot_version`.
pub struct InferenceResponse {
    pub id: u64,
    pub nodes: Vec<u32>,
    pub logits: Matrix,
    pub snapshot_version: u64,
    pub worker: usize,
    pub latency_ns: u64,
}

/// State shared between the server handle and its workers.
pub(crate) struct ServerShared {
    pub(crate) queue: RequestQueue<InferenceRequest>,
    pub(crate) snapshot: EpochCell<EngineSnapshot>,
    pub(crate) cache: Arc<DecisionCache>,
    pub(crate) hist: LatencyHistogram,
    pub(crate) ds: Arc<GraphDataset>,
    pub(crate) template: Arc<ServedModel>,
    pub(crate) cfg: ServeConfig,
    results: Mutex<Vec<InferenceResponse>>,
    pending: Mutex<usize>,
    drained: Condvar,
}

impl ServerShared {
    pub(crate) fn complete(&self, resp: InferenceResponse) {
        self.results.lock().unwrap().push(resp);
        let mut p = self.pending.lock().unwrap();
        *p -= 1;
        if *p == 0 {
            self.drained.notify_all();
        }
    }
}

/// Handle to a running inference service. Dropping without
/// [`InferenceServer::shutdown`] detaches the workers; prefer an explicit
/// shutdown so the queue closes and threads join.
pub struct InferenceServer {
    shared: Arc<ServerShared>,
    handles: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
    started: Instant,
}

impl InferenceServer {
    /// Spawn the worker pool. `warm_cache` (e.g. [`DecisionCache::load`]
    /// of a training run's persisted cache) is shared read-only by every
    /// worker; `None` serves with an empty cache (all decisions fall back
    /// to the worker policy).
    pub fn start(
        cfg: ServeConfig,
        ds: Arc<GraphDataset>,
        template: Arc<ServedModel>,
        initial: EngineSnapshot,
        warm_cache: Option<DecisionCache>,
    ) -> InferenceServer {
        assert!(cfg.workers > 0, "at least one worker");
        let cache = Arc::new(
            warm_cache.unwrap_or_else(|| DecisionCache::new(0.5)),
        );
        let shared = Arc::new(ServerShared {
            queue: RequestQueue::bounded(cfg.queue_capacity),
            snapshot: EpochCell::new(initial),
            cache,
            hist: LatencyHistogram::new(),
            ds,
            template,
            cfg: cfg.clone(),
            results: Mutex::new(Vec::new()),
            pending: Mutex::new(0),
            drained: Condvar::new(),
        });
        let handles = (0..cfg.workers)
            .map(|wid| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker::worker_loop(shared, wid))
            })
            .collect();
        InferenceServer { shared, handles, next_id: AtomicU64::new(0), started: Instant::now() }
    }

    /// Enqueue a node-batch request (ids are sorted + deduplicated here —
    /// the extraction contract). Blocks while the queue is full; returns
    /// the request id, or `None` if the server is shutting down.
    pub fn submit(&self, mut nodes: Vec<u32>) -> Option<u64> {
        assert!(!nodes.is_empty(), "empty request");
        nodes.sort_unstable();
        nodes.dedup();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        *self.shared.pending.lock().unwrap() += 1;
        if self.shared.queue.push(InferenceRequest { id, nodes }) {
            Some(id)
        } else {
            let mut p = self.shared.pending.lock().unwrap();
            *p -= 1;
            if *p == 0 {
                self.shared.drained.notify_all();
            }
            None
        }
    }

    /// Publish a new snapshot; returns the cell epoch it became current
    /// at. Never blocks readers beyond their momentary pointer clone.
    pub fn publish(&self, snap: EngineSnapshot) -> u64 {
        self.shared.snapshot.publish(snap)
    }

    /// Publish a pre-built `Arc` — the zero-allocation swap path.
    pub fn publish_arc(&self, snap: Arc<EngineSnapshot>) -> u64 {
        self.shared.snapshot.publish_arc(snap)
    }

    /// The currently served snapshot (a co-owning handle).
    pub fn current_snapshot(&self) -> Arc<EngineSnapshot> {
        self.shared.snapshot.load()
    }

    pub fn snapshot_epoch(&self) -> u64 {
        self.shared.snapshot.epoch()
    }

    /// Wait until every submitted request has completed, then take the
    /// accumulated responses (ordering across workers is arbitrary).
    pub fn drain(&self) -> Vec<InferenceResponse> {
        let mut p = self.shared.pending.lock().unwrap();
        while *p > 0 {
            p = self.shared.drained.wait(p).unwrap();
        }
        drop(p);
        std::mem::take(&mut *self.shared.results.lock().unwrap())
    }

    pub fn histogram(&self) -> &LatencyHistogram {
        &self.shared.hist
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.shared.cache.snapshot()
    }

    /// Latency/throughput summary over everything served so far.
    pub fn report(&self, dataset: &str) -> ServeReport {
        let h = &self.shared.hist;
        let elapsed = self.started.elapsed().as_secs_f64().max(1e-9);
        ServeReport {
            model: self.shared.template.name().to_string(),
            dataset: dataset.to_string(),
            workers: self.shared.cfg.workers,
            requests: h.count(),
            p50_ns: h.p50_ns(),
            p95_ns: h.p95_ns(),
            p99_ns: h.p99_ns(),
            mean_ns: h.mean_ns(),
            max_ns: h.max_ns(),
            ops_per_sec: h.count() as f64 / elapsed,
            cache: self.cache_stats(),
            snapshot_epoch: self.snapshot_epoch(),
        }
    }

    /// Close the queue, join every worker, and return any undrained
    /// responses.
    pub fn shutdown(self) -> Vec<InferenceResponse> {
        self.shared.queue.close();
        for h in self.handles {
            let _ = h.join();
        }
        std::mem::take(&mut *self.shared.results.lock().unwrap())
    }
}

/// One JSON-lines record of serving metrics (`BENCH_serve.json`,
/// DecentDB-style: one object per line, keyed by a run name).
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub model: String,
    pub dataset: String,
    pub workers: usize,
    pub requests: u64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub mean_ns: f64,
    pub max_ns: u64,
    pub ops_per_sec: f64,
    pub cache: CacheStats,
    pub snapshot_epoch: u64,
}

impl ServeReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(format!("serve/{}/{}/w{}", self.dataset, self.model, self.workers))),
            ("model", Json::Str(self.model.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("workers", Json::Num(self.workers as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("p50_ns", Json::Num(self.p50_ns as f64)),
            ("p95_ns", Json::Num(self.p95_ns as f64)),
            ("p99_ns", Json::Num(self.p99_ns as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("max_ns", Json::Num(self.max_ns as f64)),
            ("ops_per_sec", Json::Num(self.ops_per_sec)),
            ("cache_hits", Json::Num(self.cache.hits as f64)),
            ("cache_misses", Json::Num(self.cache.misses as f64)),
            ("cache_hit_rate", Json::Num(self.cache.hit_rate())),
            ("snapshot_epoch", Json::Num(self.snapshot_epoch as f64)),
        ])
    }

    /// One line of `BENCH_serve.json`.
    pub fn to_json_line(&self) -> String {
        self.to_json().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;

    fn tiny() -> GraphDataset {
        let spec = DatasetSpec {
            name: "Tiny",
            n: 80,
            feat_dim: 16,
            adj_density: 0.06,
            feat_density: 0.2,
            n_classes: 3,
        };
        GraphDataset::generate(&spec, &mut Rng::new(11))
    }

    fn boot(kind: ModelKind, workers: usize) -> (Arc<GraphDataset>, InferenceServer) {
        let ds = Arc::new(tiny());
        let template = Arc::new(train_template(kind, &ds, 16, 0.02, 5, 7));
        let cfg = ServeConfig { workers, ..ServeConfig::default() };
        let snap = EngineSnapshot::from_dataset(&ds, 0);
        let srv = InferenceServer::start(cfg, Arc::clone(&ds), template, snap, None);
        (ds, srv)
    }

    #[test]
    fn serves_logits_for_every_request() {
        let (ds, srv) = boot(ModelKind::Gcn, 2);
        for start in 0..10u32 {
            srv.submit((start..start + 8).collect()).unwrap();
        }
        let responses = srv.drain();
        assert_eq!(responses.len(), 10);
        for r in &responses {
            assert_eq!(r.logits.rows, r.nodes.len());
            assert_eq!(r.logits.cols, ds.n_classes);
            assert!(r.logits.data.iter().all(|v| v.is_finite()));
            assert_eq!(r.snapshot_version, 0);
        }
        assert_eq!(srv.histogram().count(), 10);
        assert!(srv.shutdown().is_empty(), "drain already took the results");
    }

    #[test]
    fn submit_normalizes_node_ids() {
        let (_ds, srv) = boot(ModelKind::Gcn, 1);
        srv.submit(vec![5, 3, 5, 1]).unwrap();
        let r = srv.drain();
        assert_eq!(r[0].nodes, vec![1, 3, 5], "sorted + deduplicated");
        srv.shutdown();
    }

    #[test]
    fn replicas_match_template_inference() {
        // A worker replica must produce the template's own full-graph
        // logits: copy_weights_from is exact, inference is deterministic.
        let ds = tiny();
        let template = train_template(ModelKind::Egc, &ds, 16, 0.02, 4, 9);
        let infer = |seed: u64| -> Matrix {
            let mut policy = StaticPolicy(Format::Csr);
            let mut eng = AdjEngine::new(&mut policy);
            let mut rng = Rng::new(seed);
            let mut replica = template.replicate(&ds, 16, 0.02, &mut rng, &mut eng);
            replica.forward(&mut eng)
        };
        // Different init seeds: the template copy must erase every trace
        // of the replica's own initialization.
        let a = infer(1234);
        let b = infer(77);
        assert_eq!(a.data, b.data, "replica logits must be bit-identical");
    }

    #[test]
    fn epoch_swap_is_visible_to_later_requests() {
        let (ds, srv) = boot(ModelKind::Film, 2);
        srv.submit(vec![0, 1, 2, 3]).unwrap();
        let first = srv.drain();
        assert_eq!(first[0].snapshot_version, 0);
        let epoch = srv.publish(EngineSnapshot::from_dataset(&ds, 42));
        assert_eq!(epoch, 1);
        srv.submit(vec![0, 1, 2, 3]).unwrap();
        let second = srv.drain();
        assert_eq!(second[0].snapshot_version, 42);
        srv.shutdown();
    }

    #[test]
    fn report_emits_all_latency_fields() {
        let (_ds, srv) = boot(ModelKind::Gcn, 2);
        for _ in 0..20 {
            srv.submit(vec![0, 1, 2, 3, 4]).unwrap();
        }
        srv.drain();
        let rep = srv.report("Tiny");
        assert_eq!(rep.requests, 20);
        assert!(rep.p50_ns > 0 && rep.p95_ns >= rep.p50_ns && rep.p99_ns >= rep.p95_ns);
        assert!(rep.ops_per_sec > 0.0);
        let line = rep.to_json_line();
        for key in ["p50_ns", "p95_ns", "p99_ns", "ops_per_sec", "workers"] {
            assert!(line.contains(key), "JSON line missing {key}: {line}");
        }
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("requests").and_then(Json::as_usize), Some(20));
        srv.shutdown();
    }
}
