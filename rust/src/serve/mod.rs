//! Concurrent inference serving with epoch-swap snapshot isolation and
//! worker supervision (DESIGN.md §Serving, §Fault-Tolerance).
//!
//! The training side of this repo amortizes format decisions over shard
//! streams; this module amortizes them over *request* streams — the
//! ROADMAP's "heavy traffic" regime, and ParamSpMM's point that adaptive
//! SpMM only pays off across many invocations. One process serves many
//! concurrent node-batch requests:
//!
//! ```text
//! submit(nodes) → bounded MPMC queue → worker pool (N threads)
//!   each worker: long-lived AdjEngine + model replica (trained weights)
//!     request → snapshot.load()  (lock held only for the Arc clone)
//!             → extract_rows_cols (induced subgraph, direct CSR paths)
//!             → validate operands → forward-only inference → logits
//! writer: publish(EngineSnapshot)  — validated, never blocks readers
//! supervisor: respawns panicked workers within a restart budget
//! ```
//!
//! Three rules make the hot path scale:
//!
//! * **Reads are lock-free during SpMM.** A request clones the snapshot
//!   `Arc` under a momentary read lock ([`EpochCell`]), then computes on
//!   an immutable graph no writer can touch; displaced snapshots free
//!   themselves when their last in-flight reader drops.
//! * **One warm [`DecisionCache`], shared read-only.** Workers consult it
//!   through relaxed atomics ([`AdjEngine::share_decision_cache`]); fresh
//!   decisions fall back to the worker's policy and are *not* stored —
//!   no writer lock exists to contend on.
//! * **Metrics are wait-free.** Per-request latency lands in a lock-free
//!   log-bucketed histogram ([`LatencyHistogram`]); p50/p95/p99 and
//!   ops/sec are emitted as JSON-lines ([`ServeReport`], `BENCH_serve.json`).
//!
//! And three rules keep it alive under failure (the §Fault-Tolerance
//! contract):
//!
//! * **Every submitted request gets exactly one response** — logits or a
//!   typed [`ServeError`]. A worker panic is caught per request, answered
//!   as [`ServeError::WorkerPanic`], and the worker is respawned by a
//!   supervisor thread until `restart_budget` is spent; past the budget
//!   the server degrades to typed rejection instead of hanging.
//! * **No lock ever wedges.** Every mutex/condvar in this module recovers
//!   from poisoning (`util::sync`), so one panic cannot take down
//!   `submit`, `drain`, or `report` for everyone else.
//! * **Operands are validated at trust boundaries.** Published snapshots
//!   and per-request extractions pass [`SparseMatrix::validate`]
//!   (`sparse::validate`) before any kernel indexes off them.
//!
//! [`SparseMatrix::validate`]: crate::sparse::SparseMatrix::validate

pub mod error;
pub mod metrics;
pub mod queue;
pub mod snapshot;
mod supervisor;
mod worker;

pub use error::ServeError;
pub use metrics::LatencyHistogram;
pub use queue::{RequestQueue, TryPushError};
pub use snapshot::EngineSnapshot;

use crate::gnn::egc::Egc;
use crate::gnn::engine::StaticPolicy;
use crate::gnn::film::Film;
use crate::gnn::gcn::Gcn;
use crate::gnn::{AdjEngine, ModelKind};
use crate::graph::GraphDataset;
use crate::predictor::cache::{CacheStats, DecisionCache};
use crate::sparse::shared::EpochCell;
use crate::sparse::{Format, SharedMatrix};
use crate::tensor::{ops, Matrix};
use crate::testing::FaultPlan;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::sync::{lock_recover, wait_timeout_recover};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// A trained model the server replicates into each worker. Only the
/// shared-adjacency architectures serve for now (GCN / FiLM / EGC — one
/// induced adjacency per request); GAT needs a per-request attention
/// pattern and RGCN per-relation extraction, both deferred.
pub enum ServedModel {
    Gcn(Gcn),
    Film(Film),
    Egc(Egc),
}

impl ServedModel {
    pub fn kind(&self) -> ModelKind {
        match self {
            ServedModel::Gcn(_) => ModelKind::Gcn,
            ServedModel::Film(_) => ModelKind::Film,
            ServedModel::Egc(_) => ModelKind::Egc,
        }
    }

    pub fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Build an untrained model of `kind` on `eng`. Panics for kinds
    /// without a serving path (GAT, RGCN).
    pub fn build(
        kind: ModelKind,
        ds: &GraphDataset,
        hidden: usize,
        lr: f32,
        rng: &mut Rng,
        eng: &mut AdjEngine,
    ) -> ServedModel {
        match kind {
            ModelKind::Gcn => ServedModel::Gcn(Gcn::new(ds, hidden, lr, rng, eng)),
            ModelKind::Film => ServedModel::Film(Film::new(ds, hidden, lr, rng, eng)),
            ModelKind::Egc => ServedModel::Egc(Egc::new(ds, hidden, lr, rng, eng)),
            other => panic!("{} has no serving path", other.name()),
        }
    }

    /// Build a fresh replica on `eng` carrying this template's trained
    /// weights (`hidden` must match the template's).
    pub fn replicate(
        &self,
        ds: &GraphDataset,
        hidden: usize,
        lr: f32,
        rng: &mut Rng,
        eng: &mut AdjEngine,
    ) -> ServedModel {
        let mut replica = ServedModel::build(self.kind(), ds, hidden, lr, rng, eng);
        replica.copy_weights_from(self);
        replica
    }

    pub fn copy_weights_from(&mut self, other: &ServedModel) {
        match (self, other) {
            (ServedModel::Gcn(a), ServedModel::Gcn(b)) => a.copy_weights_from(b),
            (ServedModel::Film(a), ServedModel::Film(b)) => a.copy_weights_from(b),
            (ServedModel::Egc(a), ServedModel::Egc(b)) => a.copy_weights_from(b),
            _ => panic!("model kind mismatch in copy_weights_from"),
        }
    }

    pub fn set_graph(
        &mut self,
        eng: &mut AdjEngine,
        x: impl Into<SharedMatrix>,
        a: impl Into<SharedMatrix>,
    ) {
        match self {
            ServedModel::Gcn(m) => m.set_graph(eng, x, a),
            ServedModel::Film(m) => m.set_graph(eng, x, a),
            ServedModel::Egc(m) => m.set_graph(eng, x, a),
        }
    }

    pub fn forward(&mut self, eng: &mut AdjEngine) -> Matrix {
        match self {
            ServedModel::Gcn(m) => m.forward(eng),
            ServedModel::Film(m) => m.forward(eng),
            ServedModel::Egc(m) => m.forward(eng),
        }
    }

    pub fn backward(&mut self, eng: &mut AdjEngine, dlogits: &Matrix) {
        match self {
            ServedModel::Gcn(m) => m.backward(eng, dlogits),
            ServedModel::Film(m) => m.backward(eng, dlogits),
            ServedModel::Egc(m) => m.backward(eng, dlogits),
        }
    }
}

/// Full-batch train a serving template: the short offline phase that
/// produces the weights every worker replica copies.
pub fn train_template(
    kind: ModelKind,
    ds: &GraphDataset,
    hidden: usize,
    lr: f32,
    epochs: usize,
    seed: u64,
) -> ServedModel {
    let mut rng = Rng::new(seed);
    let mut policy = StaticPolicy(Format::Csr);
    let mut eng = AdjEngine::new(&mut policy);
    let mut model = ServedModel::build(kind, ds, hidden, lr, &mut rng, &mut eng);
    for _ in 0..epochs {
        let logits = model.forward(&mut eng);
        let (_, dlogits) = ops::masked_xent_with_grad(&logits, &ds.labels, &ds.train_mask);
        model.backward(&mut eng, &dlogits);
    }
    model
}

/// Server construction parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads (each with its own engine + model replica).
    pub workers: usize,
    /// Bounded request-queue capacity (back-pressure threshold).
    pub queue_capacity: usize,
    /// Hidden width — must match the template's.
    pub hidden: usize,
    /// Replica-construction learning rate (optimizer state is unused;
    /// serving is forward-only).
    pub lr: f32,
    pub seed: u64,
    /// Per-worker fallback policy when the shared cache has no answer.
    pub fallback_format: Format,
    /// Cumulative worker-respawn allowance before the server degrades to
    /// typed rejection (see `serve::supervisor`).
    pub restart_budget: usize,
    /// Fault-injection schedule — inert by default; tests and the ci.sh
    /// smoke arm it ([`FaultPlan`]).
    pub faults: Arc<FaultPlan>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: 4,
            queue_capacity: 64,
            hidden: 16,
            lr: 0.02,
            seed: 0x5E21,
            fallback_format: Format::Csr,
            restart_budget: 8,
            faults: Arc::new(FaultPlan::inert()),
        }
    }
}

/// One enqueued node-batch inference request.
pub struct InferenceRequest {
    pub id: u64,
    /// Sorted, duplicate-free node ids (the `extract_rows_cols` contract;
    /// [`InferenceServer::submit`] normalizes).
    pub nodes: Vec<u32>,
    /// Admission-control deadline: a worker dequeuing this request after
    /// the instant has passed drops it as [`ServeError::DeadlineExceeded`]
    /// without doing the inference.
    pub deadline: Option<Instant>,
}

/// The success payload of a request: logits for its nodes (row i ↔
/// nodes\[i\]) computed against snapshot `snapshot_version`.
pub struct Inference {
    pub logits: Matrix,
    pub snapshot_version: u64,
}

/// A completed request — exactly one per submission, success or typed
/// failure (the §Fault-Tolerance liveness contract).
pub struct InferenceResponse {
    pub id: u64,
    pub nodes: Vec<u32>,
    pub result: Result<Inference, ServeError>,
    /// Worker that produced the response; `None` for responses synthesized
    /// off-worker (degraded-mode queue failure).
    pub worker: Option<usize>,
    pub latency_ns: u64,
}

impl InferenceResponse {
    pub fn is_ok(&self) -> bool {
        self.result.is_ok()
    }

    /// The inference, if the request succeeded.
    pub fn ok(&self) -> Option<&Inference> {
        self.result.as_ref().ok()
    }

    /// The typed error, if the request failed.
    pub fn err(&self) -> Option<&ServeError> {
        self.result.as_ref().err()
    }
}

/// State shared between the server handle, its workers, and the
/// supervisor.
pub(crate) struct ServerShared {
    pub(crate) queue: RequestQueue<InferenceRequest>,
    pub(crate) snapshot: EpochCell<EngineSnapshot>,
    pub(crate) cache: Arc<DecisionCache>,
    pub(crate) hist: LatencyHistogram,
    pub(crate) ds: Arc<GraphDataset>,
    pub(crate) template: Arc<ServedModel>,
    pub(crate) cfg: ServeConfig,
    results: Mutex<Vec<InferenceResponse>>,
    pending: Mutex<usize>,
    drained: Condvar,
    // §Fault-Tolerance accounting (all surfaced in [`ServeReport`]).
    pub(crate) shed: AtomicU64,
    pub(crate) expired: AtomicU64,
    pub(crate) panics: AtomicU64,
    pub(crate) restarts: AtomicU64,
    pub(crate) live_workers: AtomicUsize,
    pub(crate) degraded: AtomicBool,
    pub(crate) supervisor: Mutex<supervisor::SupervisorInbox>,
    pub(crate) supervisor_cv: Condvar,
    /// Handles of supervisor-respawned workers, joined at shutdown.
    pub(crate) respawned: Mutex<Vec<JoinHandle<()>>>,
}

impl ServerShared {
    /// Deliver a response and retire its pending slot — the single point
    /// every request (ok, error, or synthesized failure) exits through,
    /// which is what makes "exactly one response per submission" and
    /// `drain` termination local invariants instead of distributed hope.
    pub(crate) fn complete(&self, resp: InferenceResponse) {
        lock_recover(&self.results).push(resp);
        let mut p = lock_recover(&self.pending);
        *p = p.saturating_sub(1);
        if *p == 0 {
            self.drained.notify_all();
        }
    }

    /// Fail every currently queued request with a typed error (degraded
    /// mode with no live worker left to pop them).
    pub(crate) fn fail_queued(&self, err: impl Fn() -> ServeError) {
        while let Some(req) = self.queue.try_pop() {
            self.complete(InferenceResponse {
                id: req.id,
                nodes: req.nodes,
                result: Err(err()),
                worker: None,
                latency_ns: 0,
            });
        }
    }

    /// Report an abnormal worker exit to the supervisor.
    pub(crate) fn notify_worker_death(&self, worker_id: usize) {
        lock_recover(&self.supervisor).dead.push(worker_id);
        self.supervisor_cv.notify_all();
    }
}

/// Handle to a running inference service. Dropping without
/// [`InferenceServer::shutdown`] detaches the workers; prefer an explicit
/// shutdown so the queue closes and threads join.
pub struct InferenceServer {
    shared: Arc<ServerShared>,
    handles: Vec<JoinHandle<()>>,
    supervisor: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    started: Instant,
}

impl InferenceServer {
    /// Spawn the worker pool and its supervisor. `warm_cache` (e.g.
    /// [`DecisionCache::load`] of a training run's persisted cache) is
    /// shared read-only by every worker; `None` serves with an empty cache
    /// (all decisions fall back to the worker policy). The initial
    /// snapshot passes the same validation gate as `publish` — a server
    /// must not boot onto operands it would refuse at runtime.
    pub fn start(
        cfg: ServeConfig,
        ds: Arc<GraphDataset>,
        template: Arc<ServedModel>,
        initial: EngineSnapshot,
        warm_cache: Option<DecisionCache>,
    ) -> InferenceServer {
        assert!(cfg.workers > 0, "at least one worker");
        if let Err(e) = initial.validate() {
            panic!("initial snapshot rejected: {e}");
        }
        let cache = Arc::new(
            warm_cache.unwrap_or_else(|| DecisionCache::new(0.5)),
        );
        let shared = Arc::new(ServerShared {
            queue: RequestQueue::bounded(cfg.queue_capacity),
            snapshot: EpochCell::new(initial),
            cache,
            hist: LatencyHistogram::new(),
            ds,
            template,
            cfg: cfg.clone(),
            results: Mutex::new(Vec::new()),
            pending: Mutex::new(0),
            drained: Condvar::new(),
            shed: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            live_workers: AtomicUsize::new(cfg.workers),
            degraded: AtomicBool::new(false),
            supervisor: Mutex::new(supervisor::SupervisorInbox::default()),
            supervisor_cv: Condvar::new(),
            respawned: Mutex::new(Vec::new()),
        });
        let handles = (0..cfg.workers)
            .map(|wid| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker::worker_loop(shared, wid))
            })
            .collect();
        let supervisor = {
            let shared = Arc::clone(&shared);
            Some(std::thread::spawn(move || supervisor::supervisor_loop(shared)))
        };
        InferenceServer {
            shared,
            handles,
            supervisor,
            next_id: AtomicU64::new(0),
            started: Instant::now(),
        }
    }

    fn admit(&self, mut nodes: Vec<u32>) -> Result<(u64, Vec<u32>), ServeError> {
        assert!(!nodes.is_empty(), "empty request");
        // ord: degraded is a cross-thread mode flag set by the supervisor;
        // SeqCst keeps the set/observe order consistent with live_workers
        // so admission can never race past a final degraded flip.
        if self.shared.degraded.load(Ordering::SeqCst) {
            return Err(ServeError::Degraded);
        }
        nodes.sort_unstable();
        nodes.dedup();
        // ord: id allocator only needs uniqueness, not ordering.
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        *lock_recover(&self.shared.pending) += 1;
        Ok((id, nodes))
    }

    fn retire_pending(&self) {
        let mut p = lock_recover(&self.shared.pending);
        *p = p.saturating_sub(1);
        if *p == 0 {
            self.shared.drained.notify_all();
        }
    }

    /// Enqueue a node-batch request (ids are sorted + deduplicated here —
    /// the extraction contract). Blocks while the queue is full; returns
    /// the request id, or a typed error when shutting down or degraded.
    pub fn submit(&self, nodes: Vec<u32>) -> Result<u64, ServeError> {
        self.submit_with_deadline(nodes, None)
    }

    /// [`InferenceServer::submit`] with an admission-control deadline:
    /// workers drop the request unserved if they dequeue it after
    /// `deadline` ([`ServeError::DeadlineExceeded`] in its response).
    pub fn submit_with_deadline(
        &self,
        nodes: Vec<u32>,
        deadline: Option<Instant>,
    ) -> Result<u64, ServeError> {
        let (id, nodes) = self.admit(nodes)?;
        if self.shared.queue.push(InferenceRequest { id, nodes, deadline }) {
            Ok(id)
        } else {
            self.retire_pending();
            Err(ServeError::Closed)
        }
    }

    /// Non-blocking admission: sheds the request with
    /// [`ServeError::QueueFull`] when the queue is saturated instead of
    /// parking the caller — load-shedding back-pressure for callers with
    /// their own latency budget (counted in [`ServeReport::shed`]).
    pub fn try_submit(
        &self,
        nodes: Vec<u32>,
        deadline: Option<Instant>,
    ) -> Result<u64, ServeError> {
        let (id, nodes) = self.admit(nodes)?;
        match self.shared.queue.try_push(InferenceRequest { id, nodes, deadline }) {
            Ok(()) => Ok(id),
            Err(TryPushError::Full(_)) => {
                self.retire_pending();
                // ord: fault stat counter, read only in report().
                self.shared.shed.fetch_add(1, Ordering::Relaxed);
                Err(ServeError::QueueFull)
            }
            Err(TryPushError::Closed(_)) => {
                self.retire_pending();
                Err(ServeError::Closed)
            }
        }
    }

    /// Publish a new snapshot after validating it; returns the cell epoch
    /// it became current at. Never blocks readers beyond their momentary
    /// pointer clone. A malformed snapshot is refused
    /// ([`ServeError::InvalidSnapshot`]) and the previous one stays
    /// current — the snapshot-publish trust boundary.
    pub fn publish(&self, snap: EngineSnapshot) -> Result<u64, ServeError> {
        snap.validate().map_err(ServeError::InvalidSnapshot)?;
        Ok(self.shared.snapshot.publish(snap))
    }

    /// Publish a pre-built `Arc` — the zero-allocation swap path (the
    /// validation sweep reads, never allocates).
    pub fn publish_arc(&self, snap: Arc<EngineSnapshot>) -> Result<u64, ServeError> {
        snap.validate().map_err(ServeError::InvalidSnapshot)?;
        Ok(self.shared.snapshot.publish_arc(snap))
    }

    /// Publish the streaming store's latest compacted epoch as this
    /// server's serving snapshot: the store's row-normalized adjacency
    /// (`D⁻¹A`, already validated by compaction) joins the caller's
    /// feature matrix under the stream's epoch version. The handles are
    /// `Arc` clones — no matrix copies — and the usual snapshot-publish
    /// trust boundary still applies. A degraded store (compactor past its
    /// restart budget) keeps serving its last published epoch, so this
    /// remains safe to call while ingest is backpressuring.
    pub fn publish_from_stream(
        &self,
        store: &crate::graph::stream::StreamStore,
        feats: SharedMatrix,
    ) -> Result<u64, ServeError> {
        let snap = store.published();
        self.publish(EngineSnapshot::new(feats, snap.norm.clone(), snap.version))
    }

    /// The currently served snapshot (a co-owning handle).
    pub fn current_snapshot(&self) -> Arc<EngineSnapshot> {
        self.shared.snapshot.load()
    }

    pub fn snapshot_epoch(&self) -> u64 {
        self.shared.snapshot.epoch()
    }

    /// Has the restart budget been exhausted (new work is rejected)?
    pub fn is_degraded(&self) -> bool {
        self.shared.degraded.load(Ordering::SeqCst) // ord: mode flag, see admit()
    }

    /// Wait until every submitted request has completed, then take the
    /// accumulated responses (ordering across workers is arbitrary).
    ///
    /// Liveness: every admitted request is completed by a worker (ok or
    /// typed error — panics included, see `serve::worker`), so `pending`
    /// always reaches zero. The timed re-check is the belt-and-braces
    /// backstop for the degraded edge where the last worker dies with
    /// requests still queued: those are failed here with typed errors
    /// rather than waited on forever.
    pub fn drain(&self) -> Vec<InferenceResponse> {
        let mut p = lock_recover(&self.shared.pending);
        while *p > 0 {
            let (guard, timed_out) =
                wait_timeout_recover(&self.shared.drained, p, Duration::from_millis(50));
            p = guard;
            // SeqCst on both flags gives a single total order between the
            // supervisor's (degraded=true, live_workers=0) writes and this
            // read pair, so the backstop can't fire on a half-updated
            // state nor miss a settled one.
            if timed_out
                && self.shared.degraded.load(Ordering::SeqCst) // ord: see block comment above the `if`
                && self.shared.live_workers.load(Ordering::SeqCst) == 0 // ord: see block comment above the `if`
            {
                drop(p);
                self.shared.fail_queued(|| ServeError::Degraded);
                p = lock_recover(&self.shared.pending);
            }
        }
        drop(p);
        std::mem::take(&mut *lock_recover(&self.shared.results))
    }

    pub fn histogram(&self) -> &LatencyHistogram {
        &self.shared.hist
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.shared.cache.snapshot()
    }

    /// Latency/throughput summary over everything served so far.
    /// `requests` counts successful inferences (the histogram population);
    /// shed/expired/panicked requests are tallied separately.
    pub fn report(&self, dataset: &str) -> ServeReport {
        let h = &self.shared.hist;
        let elapsed = self.started.elapsed().as_secs_f64().max(1e-9);
        ServeReport {
            model: self.shared.template.name().to_string(),
            dataset: dataset.to_string(),
            workers: self.shared.cfg.workers,
            requests: h.count(),
            p50_ns: h.p50_ns(),
            p95_ns: h.p95_ns(),
            p99_ns: h.p99_ns(),
            mean_ns: h.mean_ns(),
            max_ns: h.max_ns(),
            ops_per_sec: h.count() as f64 / elapsed,
            cache: self.cache_stats(),
            snapshot_epoch: self.snapshot_epoch(),
            // ord: fault stat counters; report() is a statistical readout
            // and tolerates tearing across the four loads.
            shed: self.shared.shed.load(Ordering::Relaxed),
            expired: self.shared.expired.load(Ordering::Relaxed), // ord: see shed above
            panics: self.shared.panics.load(Ordering::Relaxed), // ord: see shed above
            restarts: self.shared.restarts.load(Ordering::Relaxed), // ord: see shed above
            degraded: self.is_degraded(),
        }
    }

    /// Close the queue, retire the supervisor, join every worker
    /// (original and respawned), and return any undrained responses.
    pub fn shutdown(mut self) -> Vec<InferenceResponse> {
        self.shared.queue.close();
        lock_recover(&self.shared.supervisor).closed = true;
        self.shared.supervisor_cv.notify_all();
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Respawned workers were pushed by the (now joined) supervisor;
        // one sweep after its join sees the complete set.
        for h in std::mem::take(&mut *lock_recover(&self.shared.respawned)) {
            let _ = h.join();
        }
        std::mem::take(&mut *lock_recover(&self.shared.results))
    }
}

/// One JSON-lines record of serving metrics (`BENCH_serve.json`,
/// DecentDB-style: one object per line, keyed by a run name).
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub model: String,
    pub dataset: String,
    pub workers: usize,
    pub requests: u64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub mean_ns: f64,
    pub max_ns: u64,
    pub ops_per_sec: f64,
    pub cache: CacheStats,
    pub snapshot_epoch: u64,
    /// Requests shed by `try_submit` on a saturated queue.
    pub shed: u64,
    /// Requests dropped at dequeue with an expired deadline.
    pub expired: u64,
    /// Worker panics caught (each cost exactly one request).
    pub panics: u64,
    /// Supervisor respawns performed.
    pub restarts: u64,
    pub degraded: bool,
}

impl ServeReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(format!("serve/{}/{}/w{}", self.dataset, self.model, self.workers))),
            ("model", Json::Str(self.model.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("workers", Json::Num(self.workers as f64)),
            ("requests", Json::Num(self.requests as f64)),
            ("p50_ns", Json::Num(self.p50_ns as f64)),
            ("p95_ns", Json::Num(self.p95_ns as f64)),
            ("p99_ns", Json::Num(self.p99_ns as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("max_ns", Json::Num(self.max_ns as f64)),
            ("ops_per_sec", Json::Num(self.ops_per_sec)),
            ("cache_hits", Json::Num(self.cache.hits as f64)),
            ("cache_misses", Json::Num(self.cache.misses as f64)),
            ("cache_hit_rate", Json::Num(self.cache.hit_rate())),
            ("snapshot_epoch", Json::Num(self.snapshot_epoch as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("expired", Json::Num(self.expired as f64)),
            ("panics", Json::Num(self.panics as f64)),
            ("restarts", Json::Num(self.restarts as f64)),
            ("degraded", Json::Bool(self.degraded)),
        ])
    }

    /// One line of `BENCH_serve.json`.
    pub fn to_json_line(&self) -> String {
        self.to_json().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::DatasetSpec;
    use crate::testing::FaultKind;

    fn tiny() -> GraphDataset {
        let spec = DatasetSpec {
            name: "Tiny",
            n: 80,
            feat_dim: 16,
            adj_density: 0.06,
            feat_density: 0.2,
            n_classes: 3,
        };
        GraphDataset::generate(&spec, &mut Rng::new(11))
    }

    fn boot_cfg(kind: ModelKind, cfg: ServeConfig) -> (Arc<GraphDataset>, InferenceServer) {
        let ds = Arc::new(tiny());
        let template = Arc::new(train_template(kind, &ds, 16, 0.02, 5, 7));
        let snap = EngineSnapshot::from_dataset(&ds, 0);
        let srv = InferenceServer::start(cfg, Arc::clone(&ds), template, snap, None);
        (ds, srv)
    }

    fn boot(kind: ModelKind, workers: usize) -> (Arc<GraphDataset>, InferenceServer) {
        boot_cfg(kind, ServeConfig { workers, ..ServeConfig::default() })
    }

    #[test]
    fn serves_logits_for_every_request() {
        let (ds, srv) = boot(ModelKind::Gcn, 2);
        for start in 0..10u32 {
            srv.submit((start..start + 8).collect()).unwrap();
        }
        let responses = srv.drain();
        assert_eq!(responses.len(), 10);
        for r in &responses {
            let inf = r.ok().expect("all requests succeed");
            assert_eq!(inf.logits.rows, r.nodes.len());
            assert_eq!(inf.logits.cols, ds.n_classes);
            assert!(inf.logits.data.iter().all(|v| v.is_finite()));
            assert_eq!(inf.snapshot_version, 0);
        }
        assert_eq!(srv.histogram().count(), 10);
        assert!(srv.shutdown().is_empty(), "drain already took the results");
    }

    #[test]
    fn submit_normalizes_node_ids() {
        let (_ds, srv) = boot(ModelKind::Gcn, 1);
        srv.submit(vec![5, 3, 5, 1]).unwrap();
        let r = srv.drain();
        assert_eq!(r[0].nodes, vec![1, 3, 5], "sorted + deduplicated");
        srv.shutdown();
    }

    #[test]
    fn replicas_match_template_inference() {
        // A worker replica must produce the template's own full-graph
        // logits: copy_weights_from is exact, inference is deterministic.
        let ds = tiny();
        let template = train_template(ModelKind::Egc, &ds, 16, 0.02, 4, 9);
        let infer = |seed: u64| -> Matrix {
            let mut policy = StaticPolicy(Format::Csr);
            let mut eng = AdjEngine::new(&mut policy);
            let mut rng = Rng::new(seed);
            let mut replica = template.replicate(&ds, 16, 0.02, &mut rng, &mut eng);
            replica.forward(&mut eng)
        };
        // Different init seeds: the template copy must erase every trace
        // of the replica's own initialization.
        let a = infer(1234);
        let b = infer(77);
        assert_eq!(a.data, b.data, "replica logits must be bit-identical");
    }

    #[test]
    fn epoch_swap_is_visible_to_later_requests() {
        let (ds, srv) = boot(ModelKind::Film, 2);
        srv.submit(vec![0, 1, 2, 3]).unwrap();
        let first = srv.drain();
        assert_eq!(first[0].ok().unwrap().snapshot_version, 0);
        let epoch = srv.publish(EngineSnapshot::from_dataset(&ds, 42)).unwrap();
        assert_eq!(epoch, 1);
        srv.submit(vec![0, 1, 2, 3]).unwrap();
        let second = srv.drain();
        assert_eq!(second[0].ok().unwrap().snapshot_version, 42);
        srv.shutdown();
    }

    #[test]
    fn report_emits_all_latency_and_fault_fields() {
        let (_ds, srv) = boot(ModelKind::Gcn, 2);
        for _ in 0..20 {
            srv.submit(vec![0, 1, 2, 3, 4]).unwrap();
        }
        srv.drain();
        let rep = srv.report("Tiny");
        assert_eq!(rep.requests, 20);
        assert!(rep.p50_ns > 0 && rep.p95_ns >= rep.p50_ns && rep.p99_ns >= rep.p95_ns);
        assert!(rep.ops_per_sec > 0.0);
        assert_eq!((rep.shed, rep.expired, rep.panics, rep.restarts), (0, 0, 0, 0));
        assert!(!rep.degraded);
        let line = rep.to_json_line();
        for key in ["p50_ns", "p95_ns", "p99_ns", "ops_per_sec", "workers", "shed", "expired", "restarts"] {
            assert!(line.contains(key), "JSON line missing {key}: {line}");
        }
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("requests").and_then(Json::as_usize), Some(20));
        assert_eq!(parsed.get("panics").and_then(Json::as_usize), Some(0));
        assert_eq!(parsed.get("degraded").and_then(Json::as_bool), Some(false));
        srv.shutdown();
    }

    #[test]
    fn expired_deadline_is_dropped_at_dequeue() {
        let (_ds, srv) = boot(ModelKind::Gcn, 1);
        // Deadline = now: by the time a worker dequeues, it has passed.
        srv.submit_with_deadline(vec![0, 1, 2], Some(Instant::now())).unwrap();
        let r = srv.drain();
        assert_eq!(r.len(), 1, "expired requests still get their one response");
        assert_eq!(r[0].err(), Some(&ServeError::DeadlineExceeded));
        let rep = srv.report("Tiny");
        assert_eq!(rep.expired, 1);
        assert_eq!(rep.requests, 0, "expired requests never enter the latency histogram");
        srv.shutdown();
    }

    #[test]
    fn worker_panic_yields_typed_response_and_respawn() {
        let cfg = ServeConfig {
            workers: 1,
            restart_budget: 4,
            faults: Arc::new(FaultPlan::inert().script(FaultKind::Panic, &[0])),
            ..ServeConfig::default()
        };
        let (_ds, srv) = boot_cfg(ModelKind::Gcn, cfg);
        for _ in 0..3 {
            srv.submit(vec![0, 1, 2, 3]).unwrap();
        }
        let mut responses = srv.drain();
        responses.sort_by_key(|r| r.id);
        assert_eq!(responses.len(), 3, "exactly one response per submission");
        // One worker, FIFO: the scripted ordinal-0 panic hits request 0.
        match responses[0].err() {
            Some(ServeError::WorkerPanic { worker: 0, detail }) => {
                assert!(detail.contains("fault injection"), "detail: {detail}");
            }
            other => panic!("expected WorkerPanic, got {other:?}"),
        }
        assert!(responses[1].is_ok() && responses[2].is_ok(), "respawned worker serves the rest");
        let rep = srv.report("Tiny");
        assert_eq!(rep.panics, 1);
        assert_eq!(rep.restarts, 1);
        assert!(!rep.degraded);
        assert!(srv.submit(vec![0, 1]).is_ok(), "server still admits after respawn");
        srv.drain();
        srv.shutdown();
    }

    #[test]
    fn exhausted_restart_budget_degrades_but_drain_terminates() {
        let cfg = ServeConfig {
            workers: 1,
            restart_budget: 1,
            // Panic on every request: burns worker, respawn, then budget.
            faults: Arc::new(FaultPlan::inert().with_rate(FaultKind::Panic, 1.0)),
            ..ServeConfig::default()
        };
        let (_ds, srv) = boot_cfg(ModelKind::Gcn, cfg);
        for _ in 0..6 {
            if srv.submit(vec![0, 1, 2]).is_err() {
                break; // degraded admission rejection is legal mid-stream
            }
        }
        let responses = srv.drain(); // must terminate (the liveness criterion)
        assert!(!responses.is_empty());
        for r in &responses {
            assert!(
                matches!(r.err(), Some(ServeError::WorkerPanic { .. } | ServeError::Degraded)),
                "every response is a typed error, got ok={}",
                r.is_ok()
            );
        }
        assert!(srv.is_degraded());
        assert_eq!(srv.report("Tiny").restarts, 1, "budget capped the respawns");
        assert!(
            matches!(srv.submit(vec![0, 1]), Err(ServeError::Degraded)),
            "degraded server rejects new work at admission"
        );
        srv.shutdown();
    }

    #[test]
    fn publish_rejects_malformed_snapshots() {
        let (ds, srv) = boot(ModelKind::Gcn, 1);
        let mut bad = EngineSnapshot::from_dataset(&ds, 9);
        if let crate::sparse::SparseMatrix::Csr(c) = bad.adjn.to_mut() {
            c.indices[0] = c.cols as u32 + 5;
        }
        let before = srv.snapshot_epoch();
        match srv.publish_arc(Arc::new(bad)) {
            Err(ServeError::InvalidSnapshot(e)) => assert!(e.what.contains("out of bounds"), "{e}"),
            other => panic!("expected InvalidSnapshot, got {other:?}"),
        }
        assert_eq!(srv.snapshot_epoch(), before, "previous snapshot stays current");
        srv.submit(vec![0, 1, 2]).unwrap();
        assert!(srv.drain()[0].is_ok(), "serving continues on the old snapshot");
        srv.shutdown();
    }
}
