//! Lock-free per-request latency histogram.
//!
//! Workers record nanosecond latencies concurrently with four relaxed
//! atomic RMWs (bucket, count, sum, max); there is no lock anywhere, so recording
//! never perturbs the tail latencies it measures. Buckets are log-linear
//! (HdrHistogram-style): exact below 8 ns, then 4 linear sub-buckets per
//! power of two — ≤ 25 % relative width everywhere, 256 counters total.
//!
//! Percentile queries use the **nearest-rank** convention (the bucket
//! holding the ⌈p/100·n⌉-th observation, reported as the bucket's lower
//! bound), matching `util::stats::percentile_nearest_rank` up to bucket
//! resolution. Queries racing with recorders read a slightly stale but
//! internally consistent-enough view — metrics, not ledgers.

use std::sync::atomic::{AtomicU64, Ordering};

const N_BUCKETS: usize = 256;

fn bucket_index(ns: u64) -> usize {
    if ns < 8 {
        return ns as usize;
    }
    let major = 63 - ns.leading_zeros() as usize; // ≥ 3
    let sub = ((ns >> (major - 2)) & 0b11) as usize;
    8 + (major - 3) * 4 + sub
}

/// Lower bound of a bucket — the value a percentile query reports.
fn bucket_floor(idx: usize) -> u64 {
    if idx < 8 {
        return idx as u64;
    }
    let major = (idx - 8) / 4 + 3;
    let sub = ((idx - 8) % 4) as u64;
    (1u64 << major) + (sub << (major - 2))
}

/// Lock-free latency histogram (nanoseconds).
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    /// Record one observation. Wait-free: four relaxed RMWs, no CAS loop
    /// (`fetch_max` is a single RMW on every 64-bit platform we target).
    pub fn record(&self, ns: u64) {
        // ord: wait-free histogram by design — each counter is independent
        // and readers tolerate torn cross-counter views (percentiles are
        // statistical, not transactional), so Relaxed everywhere.
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed); // ord: see record() head comment
        self.sum_ns.fetch_add(ns, Ordering::Relaxed); // ord: see record() head comment
        self.max_ns.fetch_max(ns, Ordering::Relaxed); // ord: see record() head comment
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed) // ord: statistical readout, tearing tolerated
    }

    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64 // ord: statistical readout, tearing tolerated
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed) // ord: statistical readout, tearing tolerated
    }

    /// Nearest-rank percentile, reported as the owning bucket's lower
    /// bound. 0 for an empty histogram.
    pub fn percentile_ns(&self, p: f64) -> u64 {
        // ord: per-bucket snapshot may tear across buckets; percentiles on
        // a live histogram are approximate by contract.
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (idx, &c) in counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_floor(idx);
            }
        }
        bucket_floor(N_BUCKETS - 1)
    }

    pub fn p50_ns(&self) -> u64 {
        self.percentile_ns(50.0)
    }

    pub fn p95_ns(&self) -> u64 {
        self.percentile_ns(95.0)
    }

    pub fn p99_ns(&self) -> u64 {
        self.percentile_ns(99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn buckets_are_monotone_and_invertible() {
        // Every bucket's floor maps back into that bucket, and indices are
        // monotone in the value.
        let mut prev = 0usize;
        for ns in [0u64, 1, 5, 7, 8, 9, 15, 16, 100, 1_000, 1 << 20, u64::MAX / 2] {
            let idx = bucket_index(ns);
            assert!(idx >= prev, "bucket index must be monotone at {ns}");
            assert!(bucket_floor(idx) <= ns, "floor exceeds value at {ns}");
            assert_eq!(bucket_index(bucket_floor(idx)), idx, "floor left its bucket at {ns}");
            prev = idx;
        }
    }

    #[test]
    fn percentiles_on_known_distribution() {
        let h = LatencyHistogram::new();
        // 0..7 land in exact buckets: percentiles are exact here.
        for ns in 0..8u64 {
            h.record(ns);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.p50_ns(), 3);
        assert_eq!(h.percentile_ns(100.0), 7);
        assert_eq!(h.max_ns(), 7);
        assert!((h.mean_ns() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_is_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50_ns(), 0);
        assert_eq!(h.p99_ns(), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn tail_percentile_within_bucket_resolution() {
        let h = LatencyHistogram::new();
        for _ in 0..99 {
            h.record(1_000);
        }
        h.record(1_000_000);
        let p99 = h.p99_ns();
        // Nearest rank of 100 obs at p99 is the 99th: the 1 µs cohort.
        assert!(p99 <= 1_000 && p99 >= 768, "p99 {p99} outside 1µs bucket");
        // The outlier surfaces at p100 with ≤25% relative error.
        let top = h.percentile_ns(100.0) as f64;
        assert!(top >= 750_000.0 && top <= 1_000_000.0, "p100 {top}");
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let h = Arc::new(LatencyHistogram::new());
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..1_000u64 {
                        h.record(t * 1_000 + i);
                    }
                })
            })
            .collect();
        for j in handles {
            j.join().unwrap();
        }
        assert_eq!(h.count(), 4_000);
    }
}
