//! Bounded MPMC request queue (std `Mutex` + two `Condvar`s).
//!
//! The serving hot path holds the queue lock only to move one item in or
//! out of a `VecDeque` — producers block while full (back-pressure toward
//! the client instead of unbounded memory growth), consumers block while
//! empty. `close` wakes everyone: producers see a rejected push, consumers
//! drain the remaining items and then observe `None`, which is the worker
//! shutdown signal.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer FIFO.
pub struct RequestQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> RequestQueue<T> {
    pub fn bounded(capacity: usize) -> RequestQueue<T> {
        assert!(capacity > 0, "queue capacity must be positive");
        RequestQueue {
            state: Mutex::new(QueueState { items: VecDeque::with_capacity(capacity), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Enqueue, blocking while the queue is at capacity. Returns `false`
    /// (item dropped) iff the queue has been closed.
    pub fn push(&self, item: T) -> bool {
        let mut s = self.state.lock().unwrap();
        while s.items.len() >= self.capacity && !s.closed {
            s = self.not_full.wait(s).unwrap();
        }
        if s.closed {
            return false;
        }
        s.items.push_back(item);
        drop(s);
        self.not_empty.notify_one();
        true
    }

    /// Dequeue, blocking while empty. `None` means closed **and** drained —
    /// the consumer's signal to exit; items enqueued before `close` are
    /// always delivered.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.items.pop_front() {
                drop(s);
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.not_empty.wait(s).unwrap();
        }
    }

    /// Close the queue: further pushes are rejected, consumers drain what
    /// remains and then see `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let q = RequestQueue::bounded(4);
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert!(q.is_empty());
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = RequestQueue::bounded(4);
        q.push(7);
        q.close();
        assert!(!q.push(8), "push after close must be rejected");
        assert_eq!(q.pop(), Some(7), "pre-close items are delivered");
        assert_eq!(q.pop(), None, "then consumers see the exit signal");
    }

    #[test]
    fn full_queue_blocks_producer_until_consumed() {
        let q = Arc::new(RequestQueue::bounded(1));
        q.push(0);
        let prod = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.push(1))
        };
        // The producer is blocked on capacity; popping frees its slot.
        assert_eq!(q.pop(), Some(0));
        assert!(prod.join().unwrap());
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn mpmc_delivers_every_item_exactly_once() {
        let q = Arc::new(RequestQueue::bounded(8));
        let n_prod = 4;
        let per_prod = 100u64;
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        let producers: Vec<_> = (0..n_prod)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..per_prod {
                        assert!(q.push(p * per_prod + i));
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let expect: Vec<u64> = (0..n_prod * per_prod).collect();
        assert_eq!(all, expect);
    }
}
