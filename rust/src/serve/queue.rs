//! Bounded MPMC request queue (std `Mutex` + two `Condvar`s).
//!
//! The serving hot path holds the queue lock only to move one item in or
//! out of a `VecDeque` — producers block while full (back-pressure toward
//! the client instead of unbounded memory growth), consumers block while
//! empty. `close` wakes everyone: producers see a rejected push, consumers
//! drain the remaining items and then observe `None`, which is the worker
//! shutdown signal.
//!
//! Fault-tolerance (DESIGN.md §Fault-Tolerance): every lock acquisition
//! recovers from poison — a panicking worker must never wedge the queue
//! for its peers — and [`RequestQueue::try_push`] gives admission control
//! a non-blocking shed path (`Full`) instead of parking the producer. A
//! producer parked in `not_full` re-checks `closed` on every wakeup and
//! `close` notifies **all** waiters on both condvars, so a full queue
//! closed mid-push releases its producers promptly (regression-tested
//! below).

use crate::util::sync::{lock_recover, wait_recover};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Why a [`RequestQueue::try_push`] was refused; carries the item back.
#[derive(Debug, PartialEq, Eq)]
pub enum TryPushError<T> {
    /// At capacity — admission control's shed signal.
    Full(T),
    /// Closed — the server is shutting down.
    Closed(T),
}

/// Bounded multi-producer multi-consumer FIFO.
pub struct RequestQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl<T> RequestQueue<T> {
    pub fn bounded(capacity: usize) -> RequestQueue<T> {
        assert!(capacity > 0, "queue capacity must be positive");
        RequestQueue {
            state: Mutex::new(QueueState { items: VecDeque::with_capacity(capacity), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    /// Enqueue, blocking while the queue is at capacity. Returns `false`
    /// (item dropped) iff the queue has been closed — including a close
    /// that lands while this producer is parked waiting for a slot.
    pub fn push(&self, item: T) -> bool {
        let mut s = lock_recover(&self.state);
        while s.items.len() >= self.capacity && !s.closed {
            s = wait_recover(&self.not_full, s);
        }
        if s.closed {
            return false;
        }
        s.items.push_back(item);
        drop(s);
        self.not_empty.notify_one();
        true
    }

    /// Non-blocking enqueue: `Full` when at capacity (the caller sheds the
    /// load), `Closed` when shut down. Never parks.
    pub fn try_push(&self, item: T) -> Result<(), TryPushError<T>> {
        let mut s = lock_recover(&self.state);
        if s.closed {
            return Err(TryPushError::Closed(item));
        }
        if s.items.len() >= self.capacity {
            return Err(TryPushError::Full(item));
        }
        s.items.push_back(item);
        drop(s);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Dequeue, blocking while empty. `None` means closed **and** drained —
    /// the consumer's signal to exit; items enqueued before `close` are
    /// always delivered.
    pub fn pop(&self) -> Option<T> {
        let mut s = lock_recover(&self.state);
        loop {
            if let Some(item) = s.items.pop_front() {
                drop(s);
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = wait_recover(&self.not_empty, s);
        }
    }

    /// Non-blocking dequeue: `None` when currently empty (closed or not).
    /// The degraded-mode failure path uses this to hand queued requests a
    /// typed error without parking on a queue no worker will ever feed.
    pub fn try_pop(&self) -> Option<T> {
        let mut s = lock_recover(&self.state);
        let item = s.items.pop_front();
        drop(s);
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Close the queue: further pushes are rejected, consumers drain what
    /// remains and then see `None`.
    pub fn close(&self) {
        lock_recover(&self.state).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        lock_recover(&self.state).closed
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.state).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_order_single_thread() {
        let q = RequestQueue::bounded(4);
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert!(q.is_empty());
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = RequestQueue::bounded(4);
        q.push(7);
        q.close();
        assert!(!q.push(8), "push after close must be rejected");
        assert_eq!(q.pop(), Some(7), "pre-close items are delivered");
        assert_eq!(q.pop(), None, "then consumers see the exit signal");
    }

    #[test]
    fn full_queue_blocks_producer_until_consumed() {
        let q = Arc::new(RequestQueue::bounded(1));
        q.push(0);
        let prod = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.push(1))
        };
        // The producer is blocked on capacity; popping frees its slot.
        assert_eq!(q.pop(), Some(0));
        assert!(prod.join().unwrap());
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn try_push_sheds_when_full_and_reports_closed() {
        let q = RequestQueue::bounded(2);
        assert_eq!(q.try_push(1), Ok(()));
        assert_eq!(q.try_push(2), Ok(()));
        assert_eq!(q.try_push(3), Err(TryPushError::Full(3)), "at capacity: shed, don't park");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.try_push(3), Ok(()), "slot freed");
        q.close();
        assert_eq!(q.try_push(4), Err(TryPushError::Closed(4)));
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn try_pop_never_blocks_on_empty() {
        let q: RequestQueue<u32> = RequestQueue::bounded(2);
        assert_eq!(q.try_pop(), None, "empty + open: immediate None");
        q.close();
        assert_eq!(q.try_pop(), None);
    }

    /// The close-mid-push race: producers parked in `not_full.wait` on a
    /// full queue must observe `close` and return `false` — not re-sleep
    /// forever on a condvar nobody will signal again.
    #[test]
    fn close_releases_producers_parked_on_a_full_queue() {
        let q = Arc::new(RequestQueue::bounded(1));
        assert!(q.push(0)); // fill to capacity
        let producers: Vec<_> = (0..3)
            .map(|i| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || q.push(100 + i))
            })
            .collect();
        // Let the producers reach the capacity wait, then close without
        // ever popping: their slot never frees, only `close` can wake them.
        while q.len() < 1 {
            std::thread::yield_now();
        }
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        for p in producers {
            assert!(!p.join().unwrap(), "parked producer must observe close and reject");
        }
        assert_eq!(q.pop(), Some(0), "the pre-close item still drains");
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn mpmc_delivers_every_item_exactly_once() {
        let q = Arc::new(RequestQueue::bounded(8));
        let n_prod = 4;
        let per_prod = 100u64;
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        let producers: Vec<_> = (0..n_prod)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..per_prod {
                        assert!(q.push(p * per_prod + i));
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let expect: Vec<u64> = (0..n_prod * per_prod).collect();
        assert_eq!(all, expect);
    }
}
