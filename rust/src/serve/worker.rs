//! The per-worker serving loop.
//!
//! Each worker owns a full engine stack on its own thread: a fallback
//! `StaticPolicy`, an [`AdjEngine`] whose slot workspaces persist across
//! requests (the long-lived-workspace amortization the engine was built
//! for), and a private model replica carrying the template's trained
//! weights. The only shared state a request touches is read-only or
//! lock-free: the snapshot `Arc` (one brief read-lock for the pointer
//! clone), the shared [`DecisionCache`] (relaxed atomics), and the latency
//! histogram — so workers scale without a serialization point.
//!
//! The engine's policy borrow (`&mut dyn FormatPolicy`) pins both policy
//! and engine to this thread's stack frame; that is why replicas are built
//! here rather than handed in from the spawner.

use super::{InferenceResponse, ServerShared};
use crate::gnn::engine::StaticPolicy;
use crate::gnn::AdjEngine;
use crate::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

pub(crate) fn worker_loop(shared: Arc<ServerShared>, worker_id: usize) {
    let mut policy = StaticPolicy(shared.cfg.fallback_format);
    let mut eng = AdjEngine::new(&mut policy);
    eng.share_decision_cache(Arc::clone(&shared.cache));
    // Replica init weights are throwaway (overwritten by the template
    // copy), but distinct seeds keep any future shared-rng misuse loud.
    let mut rng = Rng::new(shared.cfg.seed ^ (worker_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut model = shared.template.replicate(
        &shared.ds,
        shared.cfg.hidden,
        shared.cfg.lr,
        &mut rng,
        &mut eng,
    );
    let feat_cols: Vec<u32> = (0..shared.ds.features.cols as u32).collect();

    while let Some(req) = shared.queue.pop() {
        let t0 = Instant::now();
        // Lock held only for the Arc clone; the whole request below runs
        // against an immutable snapshot no writer can touch.
        let snap = shared.snapshot.load();
        let x = snap.feats.extract_rows_cols(&req.nodes, &feat_cols);
        let a = snap.adjn.extract_rows_cols(&req.nodes, &req.nodes);
        model.set_graph(&mut eng, x, a);
        let logits = model.forward(&mut eng);
        let latency_ns = t0.elapsed().as_nanos() as u64;
        shared.hist.record(latency_ns);
        shared.complete(InferenceResponse {
            id: req.id,
            nodes: req.nodes,
            logits,
            snapshot_version: snap.version,
            worker: worker_id,
            latency_ns,
        });
    }
}
