//! The per-worker serving loop, supervised (DESIGN.md §Fault-Tolerance).
//!
//! Each worker owns a full engine stack on its own thread: a fallback
//! `StaticPolicy`, an [`AdjEngine`] whose slot workspaces persist across
//! requests (the long-lived-workspace amortization the engine was built
//! for), and a private model replica carrying the template's trained
//! weights. The only shared state a request touches is read-only or
//! lock-free: the snapshot `Arc` (one brief read-lock for the pointer
//! clone), the shared [`DecisionCache`] (relaxed atomics), and the latency
//! histogram — so workers scale without a serialization point.
//!
//! The engine's policy borrow (`&mut dyn FormatPolicy`) pins both policy
//! and engine to this thread's stack frame; that is why replicas are built
//! here rather than handed in from the spawner.
//!
//! Supervision protocol: each request's inference runs under
//! `catch_unwind`. A panic costs exactly that request — it completes with
//! a typed [`ServeError::WorkerPanic`] (so `pending` is decremented and
//! `drain` stays live) — and then the worker **exits**, because its engine
//! and replica may hold arbitrarily torn state after an unwind. The
//! supervisor respawns a replacement with a freshly built engine +
//! replica. Expired deadlines are dropped at dequeue before any work;
//! corrupt extracted operands fail validation and cost one request as a
//! typed [`ServeError::CorruptOperand`].

use super::error::panic_detail;
use super::{Inference, InferenceRequest, InferenceResponse, ServeError, ServerShared};
use crate::gnn::engine::StaticPolicy;
use crate::gnn::AdjEngine;
use crate::util::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// Why `serve_requests` returned.
enum WorkerExit {
    /// Queue closed and drained — normal shutdown.
    QueueClosed,
    /// A request's inference panicked; engine state is suspect.
    Panicked,
}

pub(crate) fn worker_loop(shared: Arc<ServerShared>, worker_id: usize) {
    // The outer catch guards replica construction too: a template/snapshot
    // bad enough to panic the build must not strand `live_workers`.
    let exit = catch_unwind(AssertUnwindSafe(|| serve_requests(&shared, worker_id)));
    if exit.is_err() {
        // ord: fault stat counter, read only in report().
        shared.panics.fetch_add(1, Ordering::Relaxed);
    }
    // ord: SeqCst so the decrement is in the same total order as the
    // supervisor/drain zero-checks (serve/mod.rs drain()).
    shared.live_workers.fetch_sub(1, Ordering::SeqCst);
    if !matches!(exit, Ok(WorkerExit::QueueClosed)) {
        shared.notify_worker_death(worker_id);
    }
}

fn serve_requests(shared: &Arc<ServerShared>, worker_id: usize) -> WorkerExit {
    let mut policy = StaticPolicy(shared.cfg.fallback_format);
    let mut eng = AdjEngine::new(&mut policy);
    eng.share_decision_cache(Arc::clone(&shared.cache));
    // Replica init weights are throwaway (overwritten by the template
    // copy), but distinct seeds keep any future shared-rng misuse loud.
    let mut rng = Rng::new(shared.cfg.seed ^ (worker_id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut model = shared.template.replicate(
        &shared.ds,
        shared.cfg.hidden,
        shared.cfg.lr,
        &mut rng,
        &mut eng,
    );
    let feat_cols: Vec<u32> = (0..shared.ds.features.cols as u32).collect();

    // lint: begin(request-path)
    while let Some(req) = shared.queue.pop() {
        let t0 = Instant::now();
        // Admission control, dequeue side: an already-expired request is
        // dropped before any extraction or SpMM — the latency budget its
        // client gave us is spent, so the work would be pure waste.
        if req.deadline.is_some_and(|d| Instant::now() >= d) {
            // ord: fault stat counter, read only in report().
            shared.expired.fetch_add(1, Ordering::Relaxed);
            shared.complete(InferenceResponse {
                id: req.id,
                nodes: req.nodes,
                result: Err(ServeError::DeadlineExceeded),
                worker: Some(worker_id),
                latency_ns: 0,
            });
            continue;
        }
        shared.cfg.faults.maybe_delay();
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            infer_one(shared, &mut model, &mut eng, &req, &feat_cols)
        }));
        let latency_ns = t0.elapsed().as_nanos() as u64;
        match outcome {
            Ok(result) => {
                if result.is_ok() {
                    shared.hist.record(latency_ns);
                }
                shared.complete(InferenceResponse {
                    id: req.id,
                    nodes: req.nodes,
                    result,
                    worker: Some(worker_id),
                    latency_ns,
                });
            }
            Err(payload) => {
                // ord: fault stat counter, read only in report().
                shared.panics.fetch_add(1, Ordering::Relaxed);
                shared.complete(InferenceResponse {
                    id: req.id,
                    nodes: req.nodes,
                    result: Err(ServeError::WorkerPanic {
                        worker: worker_id,
                        detail: panic_detail(payload.as_ref()),
                    }),
                    worker: Some(worker_id),
                    latency_ns,
                });
                return WorkerExit::Panicked;
            }
        }
    }
    WorkerExit::QueueClosed
}

fn infer_one(
    shared: &ServerShared,
    model: &mut super::ServedModel,
    eng: &mut AdjEngine,
    req: &InferenceRequest,
    feat_cols: &[u32],
) -> Result<Inference, ServeError> {
    shared.cfg.faults.maybe_panic();
    // Lock held only for the Arc clone; the whole request below runs
    // against an immutable snapshot no writer can touch.
    let snap = shared.snapshot.load();
    let x = snap.feats.extract_rows_cols(&req.nodes, feat_cols);
    let mut a = snap.adjn.extract_rows_cols(&req.nodes, &req.nodes);
    shared.cfg.faults.maybe_corrupt(&mut a);
    // Per-request operand gate: O(nnz) against the O(nnz·d) forward —
    // cheap insurance that a torn extraction (or an injected corruption)
    // costs one typed error, not an out-of-bounds read inside a kernel.
    x.validate().map_err(ServeError::CorruptOperand)?;
    a.validate().map_err(ServeError::CorruptOperand)?;
    model.set_graph(eng, x, a);
    let logits = model.forward(eng);
    Ok(Inference { logits, snapshot_version: snap.version })
}
// lint: end(request-path)
