//! Engine snapshots — the unit of epoch-swap publication.
//!
//! A snapshot is the pair of full-graph masters a request's induced
//! subgraph is sliced from (CSR features + CSR normalized adjacency, the
//! same "direct extraction path" invariant as `gnn::minibatch`'s
//! [`FullGraphOps`]), tagged with a caller-assigned version. Snapshots are
//! immutable once built: publication swaps an `Arc<EngineSnapshot>` in an
//! [`EpochCell`], in-flight requests keep the `Arc` they loaded, and the
//! displaced snapshot frees itself when its last reader drops — see
//! `sparse::shared::EpochCell` for the lock discipline.
//!
//! Building a snapshot (CSR conversion, allocation) happens entirely
//! *before* publication, on the writer's time; the swap itself is a
//! pointer store (the `bench_serve` alloc gate pins this at zero
//! allocations).

use crate::gnn::FullGraphOps;
use crate::graph::GraphDataset;
use crate::sparse::{Csr, FormatError, SharedMatrix};

/// Immutable full-graph operand set served to inference requests.
#[derive(Clone, Debug)]
pub struct EngineSnapshot {
    /// Sparse features, CSR (row slice via the identity-column fast path).
    pub feats: SharedMatrix,
    /// Normalized adjacency, CSR (direct row/col extraction).
    pub adjn: SharedMatrix,
    /// Caller-assigned version, echoed into every response served from
    /// this snapshot — the stress test replays logits against it.
    pub version: u64,
}

impl EngineSnapshot {
    pub fn new(feats: SharedMatrix, adjn: SharedMatrix, version: u64) -> EngineSnapshot {
        EngineSnapshot { feats, adjn, version }
    }

    /// Build from a dataset (CSR conversion happens here, pre-publication).
    pub fn from_dataset(ds: &GraphDataset, version: u64) -> EngineSnapshot {
        EngineSnapshot {
            feats: SharedMatrix::from(Csr::from_coo(&ds.features)),
            adjn: SharedMatrix::from(Csr::from_coo(&ds.adj_norm)),
            version,
        }
    }

    /// Share the mini-batch trainer's masters (refcount bumps, zero matrix
    /// data copies): train and serve can co-own one set of CSR masters.
    pub fn from_ops(ops: &FullGraphOps, version: u64) -> EngineSnapshot {
        EngineSnapshot { feats: ops.feats.clone(), adjn: ops.adjn.clone(), version }
    }

    /// Number of graph nodes this snapshot serves.
    pub fn n_nodes(&self) -> usize {
        self.adjn.rows()
    }

    /// Structural validation at the publish trust boundary (DESIGN.md
    /// §Fault-Tolerance): both masters pass the full per-format sweep, the
    /// adjacency is square, and the masters agree on the node count. A
    /// snapshot that fails here is refused by `InferenceServer::publish`
    /// before any worker can slice from it.
    pub fn validate(&self) -> Result<(), FormatError> {
        self.feats.validate()?;
        self.adjn.validate()?;
        if self.adjn.rows() != self.adjn.cols() {
            return Err(FormatError {
                format: self.adjn.format(),
                what: format!("adjacency is {}×{}, not square", self.adjn.rows(), self.adjn.cols()),
            });
        }
        if self.feats.rows() != self.adjn.rows() {
            return Err(FormatError {
                format: self.feats.format(),
                what: format!(
                    "features cover {} nodes but adjacency covers {}",
                    self.feats.rows(),
                    self.adjn.rows()
                ),
            });
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::ModelKind;
    use crate::graph::DatasetSpec;
    use crate::util::rng::Rng;

    fn tiny() -> GraphDataset {
        let spec = DatasetSpec {
            name: "Tiny",
            n: 60,
            feat_dim: 12,
            adj_density: 0.08,
            feat_density: 0.2,
            n_classes: 3,
        };
        GraphDataset::generate(&spec, &mut Rng::new(5))
    }

    #[test]
    fn from_ops_shares_masters() {
        let ds = tiny();
        let ops = FullGraphOps::new(&ds, ModelKind::Gcn, &[]);
        let before = ops.feats.strong_count();
        let snap = EngineSnapshot::from_ops(&ops, 3);
        assert!(snap.feats.ptr_eq(&ops.feats), "snapshot must co-own, not copy");
        assert_eq!(ops.feats.strong_count(), before + 1);
        assert_eq!(snap.version, 3);
        assert_eq!(snap.n_nodes(), 60);
    }

    #[test]
    fn from_dataset_builds_csr_masters() {
        let ds = tiny();
        let snap = EngineSnapshot::from_dataset(&ds, 0);
        assert_eq!(snap.feats.format(), crate::sparse::Format::Csr);
        assert_eq!(snap.adjn.format(), crate::sparse::Format::Csr);
        assert_eq!(snap.feats.nnz(), ds.features.nnz());
    }
}
