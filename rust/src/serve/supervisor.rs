//! The worker supervisor (DESIGN.md §Fault-Tolerance).
//!
//! One supervisor thread per server owns the respawn decision. Workers
//! that exit abnormally (a caught inference panic, or an unwind during
//! replica construction) report their id to the supervisor's inbox; the
//! supervisor replaces each with a fresh thread — fresh engine, fresh
//! replica, same worker id — while the cumulative restart count stays
//! within `ServeConfig::restart_budget`. Past the budget the server goes
//! **degraded**: new submissions are rejected with `ServeError::Degraded`,
//! surviving workers keep draining what was admitted, and once no worker
//! is left the supervisor fails the remaining queued requests with typed
//! errors so `drain` always terminates.
//!
//! The budget is cumulative, not per-worker: a crash loop (e.g. a
//! poisoned model update panicking every request) burns the budget in
//! `budget` requests and then stops consuming the stream, rather than
//! respawn-thrashing forever.

use super::{worker, ServeError, ServerShared};
use crate::util::sync::{lock_recover, wait_recover};
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Dead-worker reports plus the shutdown latch, guarded by
/// `ServerShared::supervisor`.
#[derive(Default)]
pub(crate) struct SupervisorInbox {
    pub(crate) dead: Vec<usize>,
    pub(crate) closed: bool,
}

pub(crate) fn supervisor_loop(shared: Arc<ServerShared>) {
    loop {
        let dead_worker = {
            let mut inbox = lock_recover(&shared.supervisor);
            loop {
                if let Some(wid) = inbox.dead.pop() {
                    break wid;
                }
                if inbox.closed {
                    return;
                }
                inbox = wait_recover(&shared.supervisor_cv, inbox);
            }
        };
        // ord: restarts is written by this supervisor thread only (workers
        // never touch it), so its own program order makes Relaxed exact here.
        if shared.restarts.load(Ordering::Relaxed) >= shared.cfg.restart_budget as u64 {
            // ord: degraded + live_workers share one SeqCst total order with
            // admit()/drain() readers — see serve/mod.rs admit().
            shared.degraded.store(true, Ordering::SeqCst);
            // No replacement is coming. If that death left zero live
            // workers, queued requests would wait forever — fail them
            // with typed errors so `drain` terminates.
            // ord: same SeqCst total order as the degraded store above.
            if shared.live_workers.load(Ordering::SeqCst) == 0 {
                shared.fail_queued(|| ServeError::Degraded);
            }
            continue;
        }
        shared.restarts.fetch_add(1, Ordering::Relaxed); // ord: supervisor-private counter, see budget check above
        shared.live_workers.fetch_add(1, Ordering::SeqCst); // ord: paired with drain()'s SeqCst zero-check
        let worker_shared = Arc::clone(&shared);
        let handle = std::thread::spawn(move || worker::worker_loop(worker_shared, dead_worker));
        lock_recover(&shared.respawned).push(handle);
    }
}
