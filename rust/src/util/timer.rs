//! Wall-clock timing utilities for kernels, phases and benches.

use std::collections::BTreeMap;
use std::time::Instant;

/// Time a closure, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}

/// Run `f` repeatedly: `warmup` unmeasured iterations then `iters` measured,
/// returning per-iteration seconds.
pub fn time_n<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let start = Instant::now();
        std::hint::black_box(f());
        out.push(start.elapsed().as_secs_f64());
    }
    out
}

/// Accumulates named phase durations across a run — used by the coordinator
/// to attribute time to SpMM vs. dense compute vs. feature extraction vs.
/// format conversion (the paper includes all overheads in reported time).
#[derive(Default, Debug, Clone)]
pub struct Stopwatch {
    totals: BTreeMap<&'static str, f64>,
    counts: BTreeMap<&'static str, u64>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f` and charge it to `phase`.
    pub fn phase<T>(&mut self, phase: &'static str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        *self.totals.entry(phase).or_insert(0.0) += start.elapsed().as_secs_f64();
        *self.counts.entry(phase).or_insert(0) += 1;
        out
    }

    /// Add an externally measured duration.
    pub fn add(&mut self, phase: &'static str, secs: f64) {
        *self.totals.entry(phase).or_insert(0.0) += secs;
        *self.counts.entry(phase).or_insert(0) += 1;
    }

    pub fn total(&self, phase: &str) -> f64 {
        self.totals.get(phase).copied().unwrap_or(0.0)
    }

    pub fn grand_total(&self) -> f64 {
        self.totals.values().sum()
    }

    pub fn merge(&mut self, other: &Stopwatch) {
        for (k, v) in &other.totals {
            *self.totals.entry(k).or_insert(0.0) += v;
        }
        for (k, c) in &other.counts {
            *self.counts.entry(k).or_insert(0) += c;
        }
    }

    /// Phases sorted by descending total time.
    pub fn report(&self) -> Vec<(&'static str, f64, u64)> {
        let mut rows: Vec<_> = self
            .totals
            .iter()
            .map(|(&k, &v)| (k, v, self.counts.get(k).copied().unwrap_or(0)))
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn time_n_lengths() {
        let samples = time_n(2, 5, || std::hint::black_box(1 + 1));
        assert_eq!(samples.len(), 5);
        assert!(samples.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.phase("a", || std::thread::sleep(std::time::Duration::from_millis(1)));
        sw.phase("a", || {});
        sw.add("b", 0.5);
        assert!(sw.total("a") > 0.0);
        assert_eq!(sw.total("b"), 0.5);
        let report = sw.report();
        assert_eq!(report[0].0, "b");
        assert_eq!(report.iter().find(|r| r.0 == "a").unwrap().2, 2);
        assert!(sw.grand_total() > 0.5);
    }

    #[test]
    fn stopwatch_merge() {
        let mut a = Stopwatch::new();
        a.add("x", 1.0);
        let mut b = Stopwatch::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b);
        assert_eq!(a.total("x"), 3.0);
        assert_eq!(a.total("y"), 3.0);
    }
}
