//! Data-parallel helpers over the persistent worker pool (`util::pool`).
//!
//! The SpMM kernels, feature extraction and the training-data labeler all
//! parallelize across row ranges or independent work items through these
//! primitives. None of them spawns threads: everything dispatches onto the
//! pool's long-lived workers (nested/contended calls run inline).
//!
//! Scheduling is **work-weighted** where it matters: [`indptr_span`] and
//! [`split_ranges_by_weight`] partition units by cumulative non-zero count
//! rather than unit count, so on power-law graphs (a few hub rows carrying
//! most of the nnz) every worker still gets an equal share of multiply-adds.

use crate::util::pool;
use std::ops::Range;

/// Number of worker threads to use. Owned by the pool, which resolves
/// `GNN_SPMM_THREADS` / `available_parallelism` exactly once (`OnceLock`).
pub fn num_threads() -> usize {
    pool::global().n_threads()
}

/// The `i`-th of `parts` near-equal contiguous ranges of `[0, n)`
/// (closed-form; empty when `parts > n` leaves nothing for slot `i`).
#[inline]
pub fn even_range(n: usize, parts: usize, i: usize) -> Range<usize> {
    let parts = parts.max(1);
    debug_assert!(i < parts);
    let base = n / parts;
    let extra = n % parts;
    let start = i * base + i.min(extra);
    let len = base + usize::from(i < extra);
    start..start + len
}

/// Split `[0, n)` into at most `parts` contiguous non-empty ranges of
/// near-equal size.
pub fn split_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return vec![];
    }
    let parts = parts.clamp(1, n);
    (0..parts).map(|i| even_range(n, parts, i)).collect()
}

/// Split `[0, n)` into exactly `max(parts, 1)` contiguous ranges (possibly
/// empty) with near-equal **total weight**: range boundaries chase the
/// cumulative-weight quantiles `total·(i+1)/parts`. Degenerate inputs
/// (all-zero weights) fall back to an even count split; a single huge unit
/// ("hub") simply occupies one range on its own while the remaining weight
/// spreads over the others. The concatenation always covers `[0, n)`
/// exactly.
pub fn split_ranges_by_weight<W>(n: usize, parts: usize, weight: W) -> Vec<Range<usize>>
where
    W: Fn(usize) -> usize,
{
    let parts = parts.max(1);
    let total: usize = (0..n).map(&weight).sum();
    if total == 0 {
        return (0..parts).map(|i| even_range(n, parts, i)).collect();
    }
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut acc = 0usize;
    for i in 0..parts {
        if i + 1 == parts {
            out.push(start..n);
            start = n;
        } else {
            let target = total * (i + 1) / parts;
            let mut end = start;
            while end < n && acc < target {
                acc += weight(end);
                end += 1;
            }
            out.push(start..end);
            start = end;
        }
    }
    out
}

/// The `i`-th of `parts` spans of `[0, indptr.len() - 1)` with near-equal
/// cumulative `indptr` weight — the nnz-balanced scheduling rule for
/// compressed formats, where `indptr[u+1] - indptr[u]` is unit `u`'s
/// non-zero count. Boundaries are found by binary search on the (already
/// prefix-summed) `indptr`, so computing a span is `O(log n)` and allocates
/// nothing: kernels call this per task instead of materializing a range
/// list. Consecutive `i` produce abutting spans that exactly cover the unit
/// range.
pub fn indptr_span(indptr: &[usize], parts: usize, i: usize) -> Range<usize> {
    let n = indptr.len().saturating_sub(1);
    if n == 0 {
        return 0..0;
    }
    let parts = parts.max(1);
    debug_assert!(i < parts);
    let base = indptr[0];
    let total = indptr[n] - base;
    if total == 0 {
        return even_range(n, parts, i);
    }
    // Boundary for cumulative-weight quantile `t`: the first unit whose
    // prefix weight reaches `t`. A hub unit straddling the quantile lands
    // wholly in the left span, which matches the greedy sweep of
    // [`split_ranges_by_weight`].
    let boundary = |t: usize| -> usize { indptr.partition_point(|&p| p - base < t) };
    let start = if i == 0 { 0 } else { boundary(total * i / parts) };
    let end = if i + 1 == parts { n } else { boundary(total * (i + 1) / parts) };
    start..end.max(start)
}

/// Run `f(range)` over an even partition of `[0, n)` on the worker pool.
///
/// `f` must be safe to run concurrently on disjoint ranges; use it to fill
/// disjoint slices of a shared output obtained via `split_at_mut` or raw
/// pointer arithmetic encapsulated by the caller.
pub fn parallel_ranges<F>(n: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    pool::global().run_ranges(n, f);
}

/// Parallel map: apply `f` to every index in `[0, n)` collecting results in
/// order. Work is chunked contiguously per executor.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let addr = out.as_mut_ptr() as usize;
    let k = num_threads().min(n.max(1));
    pool::global().run_weighted_ranges(k, |i| even_range(n, k, i), |r| {
        for i in r {
            // SAFETY: ranges are disjoint, so each slot is written by
            // exactly one task.
            let slot = unsafe { &mut *(addr as *mut Option<T>).add(i) };
            *slot = Some(f(i));
        }
    });
    out.into_iter().map(|o| o.expect("parallel_map slot unfilled")).collect()
}

/// Parallel fill of a mutable f32 slice by disjoint row blocks:
/// `fill(row_range, out_chunk)` where `out_chunk` is rows `row_range` of a
/// row-major `[n_rows, row_len]` buffer. Rows are split evenly; use
/// [`parallel_fill_rows_spans`] when per-row work is skewed.
pub fn parallel_fill_rows<F>(out: &mut [f32], n_rows: usize, row_len: usize, fill: F)
where
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    let k = num_threads().min(n_rows.max(1));
    parallel_fill_rows_spans(out, n_rows, row_len, k, |i| even_range(n_rows, k, i), fill);
}

/// Weighted variant of [`parallel_fill_rows`]: task `i` fills the rows of
/// `span_of(i)`. Spans must be disjoint and together cover `[0, n_rows)`
/// exactly (empty spans allowed) — e.g. produced by [`indptr_span`] so each
/// task owns an equal share of non-zeros instead of an equal share of rows.
pub fn parallel_fill_rows_spans<S, F>(
    out: &mut [f32],
    n_rows: usize,
    row_len: usize,
    n_tasks: usize,
    span_of: S,
    fill: F,
) where
    S: Fn(usize) -> Range<usize> + Sync,
    F: Fn(Range<usize>, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), n_rows * row_len);
    let addr = out.as_mut_ptr() as usize;
    pool::global().run_weighted_ranges(n_tasks, span_of, |r| {
        // SAFETY: spans are disjoint (caller contract), so the row chunks
        // never alias across tasks.
        let chunk = unsafe {
            std::slice::from_raw_parts_mut(
                (addr as *mut f32).add(r.start * row_len),
                r.len() * row_len,
            )
        };
        fill(r, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers_exactly() {
        for n in [0usize, 1, 7, 100, 101] {
            for p in [1usize, 3, 8, 200] {
                let rs = split_ranges(n, p);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                let mut next = 0;
                for r in &rs {
                    assert_eq!(r.start, next);
                    assert!(!r.is_empty());
                    next = r.end;
                }
            }
        }
    }

    #[test]
    fn even_range_matches_split_ranges() {
        for n in [1usize, 7, 100, 101] {
            for p in [1usize, 3, 8] {
                let p = p.min(n);
                let rs = split_ranges(n, p);
                for (i, r) in rs.iter().enumerate() {
                    assert_eq!(*r, even_range(n, p, i));
                }
            }
        }
    }

    #[test]
    fn weight_split_covers_under_skew() {
        // Hub-dominated: unit 3 carries ~all weight.
        let w = |i: usize| if i == 3 { 10_000 } else { 1 };
        for parts in [1usize, 2, 4, 9] {
            let spans = split_ranges_by_weight(20, parts, w);
            assert_eq!(spans.len(), parts);
            let mut next = 0;
            for s in &spans {
                assert_eq!(s.start, next);
                next = s.end;
            }
            assert_eq!(next, 20);
        }
        // All-zero weights degrade to an even split.
        let spans = split_ranges_by_weight(10, 4, |_| 0);
        assert_eq!(spans.iter().map(|r| r.len()).sum::<usize>(), 10);
        assert_eq!(spans.len(), 4);
    }

    #[test]
    fn indptr_span_covers_and_balances() {
        // indptr with empty rows and a hub row.
        let indptr = [0usize, 0, 5, 5, 105, 110, 110, 120];
        let n = indptr.len() - 1;
        for parts in [1usize, 2, 3, 7, 12] {
            let mut next = 0;
            for i in 0..parts {
                let s = indptr_span(&indptr, parts, i);
                assert_eq!(s.start, next, "parts={parts} i={i}");
                assert!(s.end >= s.start);
                next = s.end;
            }
            assert_eq!(next, n, "parts={parts}");
        }
        // With 2 parts the hub row (100 nnz) must sit alone-ish: the split
        // lands at the row holding the 60th nnz, which is the hub row.
        let a = indptr_span(&indptr, 2, 0);
        let b = indptr_span(&indptr, 2, 1);
        assert_eq!(a.end, b.start);
        assert!(a.contains(&3) || b.contains(&3));
    }

    #[test]
    fn parallel_map_matches_serial() {
        let out = parallel_map(1000, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_ranges_visits_all() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let sum = AtomicU64::new(0);
        parallel_ranges(10_000, |r| {
            let mut local = 0u64;
            for i in r {
                local += i as u64;
            }
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10_000u64 * 9_999 / 2);
    }

    #[test]
    fn parallel_fill_rows_disjoint() {
        let n_rows = 97;
        let row_len = 13;
        let mut out = vec![0f32; n_rows * row_len];
        parallel_fill_rows(&mut out, n_rows, row_len, |rows, chunk| {
            for (j, row) in rows.clone().enumerate() {
                for c in 0..row_len {
                    chunk[j * row_len + c] = (row * row_len + c) as f32;
                }
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn parallel_fill_rows_spans_weighted() {
        // Weighted spans from an indptr: every row still written once.
        let indptr = [0usize, 50, 50, 51, 52, 100];
        let n_rows = indptr.len() - 1;
        let row_len = 4;
        let k = 3;
        let mut out = vec![-1.0f32; n_rows * row_len];
        parallel_fill_rows_spans(&mut out, n_rows, row_len, k, |i| {
            indptr_span(&indptr, k, i)
        }, |rows, chunk| {
            for (j, row) in rows.clone().enumerate() {
                for c in 0..row_len {
                    chunk[j * row_len + c] = row as f32;
                }
            }
        });
        for r in 0..n_rows {
            for c in 0..row_len {
                assert_eq!(out[r * row_len + c], r as f32);
            }
        }
    }
}
