//! Data-parallel helpers over `std::thread::scope` (rayon is unavailable).
//!
//! The SpMM kernels, feature extraction and training-data labeler all
//! parallelize across row ranges or independent work items through these
//! primitives.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (cached).
pub fn num_threads() -> usize {
    static N: AtomicUsize = AtomicUsize::new(0);
    let cached = N.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let n = std::env::var("GNN_SPMM_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        })
        .max(1);
    N.store(n, Ordering::Relaxed);
    n
}

/// Split `[0, n)` into at most `parts` contiguous ranges of near-equal size.
pub fn split_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return vec![];
    }
    let parts = parts.clamp(1, n);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f(range)` over a partition of `[0, n)` on the worker pool.
///
/// `f` must be safe to run concurrently on disjoint ranges; use it to fill
/// disjoint slices of a shared output obtained via `split_at_mut` or raw
/// pointer arithmetic encapsulated by the caller.
pub fn parallel_ranges<F>(n: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let ranges = split_ranges(n, num_threads());
    if ranges.len() <= 1 {
        for r in ranges {
            f(r);
        }
        return;
    }
    std::thread::scope(|s| {
        for r in ranges {
            s.spawn(|| f(r));
        }
    });
}

/// Parallel map: apply `f` to every index in `[0, n)` collecting results in
/// order. Work is chunked contiguously per thread.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = &mut out[..];
        let ranges = split_ranges(n, num_threads());
        if ranges.len() <= 1 {
            for r in ranges {
                for i in r {
                    slots[i] = Some(f(i));
                }
            }
        } else {
            std::thread::scope(|s| {
                let mut rest = slots;
                let mut offset = 0;
                for r in ranges {
                    let (head, tail) = rest.split_at_mut(r.len());
                    rest = tail;
                    let base = offset;
                    offset += r.len();
                    let f = &f;
                    s.spawn(move || {
                        for (j, slot) in head.iter_mut().enumerate() {
                            *slot = Some(f(base + j));
                        }
                    });
                }
            });
        }
    }
    out.into_iter().map(|o| o.expect("parallel_map slot unfilled")).collect()
}

/// Parallel fill of a mutable f32 slice by disjoint row blocks:
/// `fill(row_range, out_chunk)` where `out_chunk` is rows `row_range` of a
/// row-major `[n_rows, row_len]` buffer.
pub fn parallel_fill_rows<F>(out: &mut [f32], n_rows: usize, row_len: usize, fill: F)
where
    F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), n_rows * row_len);
    let ranges = split_ranges(n_rows, num_threads());
    if ranges.len() <= 1 {
        for r in ranges {
            let s = r.start * row_len;
            let e = r.end * row_len;
            fill(r, &mut out[s..e]);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut rest = out;
        for r in ranges {
            let take = (r.end - r.start) * row_len;
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let fill = &fill;
            s.spawn(move || fill(r, head));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers_exactly() {
        for n in [0usize, 1, 7, 100, 101] {
            for p in [1usize, 3, 8, 200] {
                let rs = split_ranges(n, p);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                let mut next = 0;
                for r in &rs {
                    assert_eq!(r.start, next);
                    assert!(!r.is_empty());
                    next = r.end;
                }
            }
        }
    }

    #[test]
    fn parallel_map_matches_serial() {
        let out = parallel_map(1000, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_ranges_visits_all() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let sum = AtomicU64::new(0);
        parallel_ranges(10_000, |r| {
            let mut local = 0u64;
            for i in r {
                local += i as u64;
            }
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10_000u64 * 9_999 / 2);
    }

    #[test]
    fn parallel_fill_rows_disjoint() {
        let n_rows = 97;
        let row_len = 13;
        let mut out = vec![0f32; n_rows * row_len];
        parallel_fill_rows(&mut out, n_rows, row_len, |rows, chunk| {
            for (j, row) in rows.clone().enumerate() {
                for c in 0..row_len {
                    chunk[j * row_len + c] = (row * row_len + c) as f32;
                }
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }
}
