//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Grammar: `prog <subcommand> [--key value]... [--flag]... [positional]...`
//! Flags may use `--key=value` or `--key value`. Unknown keys are kept and
//! can be validated by the caller.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        // First non-flag token is the subcommand.
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.options.insert(body.to_string(), it.next().unwrap());
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the real process arguments.
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse_from(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        // Convention: positionals precede flags (a bare `--flag value` pair
        // is indistinguishable from `--option value`).
        let a = parse(&["train", "data.csv", "--epochs", "10", "--lr=0.01", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_usize("epochs", 0), 10);
        assert_eq!(a.get_f64("lr", 0.0), 0.01);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["data.csv"]);
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--x", "1"]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_usize("x", 0), 1);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["run", "--fast"]);
        assert!(a.has_flag("fast"));
        assert!(a.get("fast").is_none());
    }

    #[test]
    fn defaults() {
        let a = parse(&["run"]);
        assert_eq!(a.get_or("mode", "auto"), "auto");
        assert_eq!(a.get_usize("n", 7), 7);
    }
}
