//! Persistent worker-pool execution layer.
//!
//! Every data-parallel primitive in the crate (`util::parallel`, the SpMM
//! kernels, feature extraction, the predictor's training labeler) executes
//! on this pool. Before it existed, each `spmm_into` call spawned fresh OS
//! threads through `std::thread::scope`; on small-to-mid graphs the spawn +
//! join cost dwarfed the format differences the paper measures. The pool
//! replaces that with:
//!
//! * **Long-lived parked workers** — `n_threads - 1` threads spawned once
//!   (the caller is the n-th executor), parked on a condvar between jobs.
//!   Dispatch is a mutex/condvar handshake: no allocation, no syscall-heavy
//!   thread creation on the hot path.
//! * **A single job slot** — jobs are serialized by a lease (`try_lock`):
//!   whoever holds the lease owns all workers. Contending callers and
//!   *nested* parallel calls (a task that itself calls a parallel helper)
//!   degrade to inline serial execution instead of deadlocking, so the pool
//!   is safe to use from anywhere, including inside its own tasks.
//! * **Per-task reusable scratch buffers** — [`Pool::scatter_reduce`] hands
//!   each task a grow-only `Vec<f32>` drawn from a pool-owned registry.
//!   After warmup the buffers (and the registry spine) are at capacity, so
//!   a scatter-style SpMM performs **zero heap allocations** per multiply —
//!   finishing the zero-allocation story the engine's slot workspace pools
//!   started (DESIGN.md §Execution-Pool).
//!
//! The thread count is resolved exactly once at pool construction (a
//! `OnceLock` — fixing the old double-read race in `num_threads()`):
//! `GNN_SPMM_THREADS` if set, else `available_parallelism`. The pool owns
//! that number; `util::parallel::num_threads()` just reads it.

use std::ops::Range;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, TryLockError};

use crate::util::parallel::even_range;
use crate::util::sync::{lock_recover, wait_recover};

/// An erased borrowed task closure. Only valid while the publishing
/// [`Lease::run_tasks`] call is on the stack: it blocks until `pending == 0`,
/// i.e. until every claimed task has returned, before the borrow ends.
type TaskFn = &'static (dyn Fn(usize) + Sync);

/// The shared job slot. All fields are guarded by one mutex; workers claim
/// task indices under the lock (jobs are coarse — one task per worker-sized
/// chunk — so the lock is uncontended in practice).
struct SlotState {
    task: Option<TaskFn>,
    n_tasks: usize,
    /// Next unclaimed task index.
    next: usize,
    /// Claimed-but-unfinished plus unclaimed task count. The publisher waits
    /// for this to hit zero before returning (and before the closure borrow
    /// expires).
    pending: usize,
    /// Set when a worker's task panicked (caught so `pending` still drains
    /// and the publisher can't deadlock); the publisher re-raises.
    poisoned: bool,
}

struct Shared {
    slot: Mutex<SlotState>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The publisher parks here while tasks drain.
    done_cv: Condvar,
}

/// The persistent worker pool. One per process (see [`global`]).
pub struct Pool {
    n_threads: usize,
    shared: Arc<Shared>,
    /// Exclusive right to dispatch on the workers. `try_lock` only — a
    /// contended or nested caller runs inline instead of blocking.
    lease_lock: Mutex<()>,
    /// Grow-only scratch buffers for [`Pool::scatter_reduce`]. Taken as a
    /// whole set under the lease, returned after the reduction; steady-state
    /// reuse is allocation-free.
    scratch: Mutex<Vec<Vec<f32>>>,
}

thread_local! {
    static IN_POOL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// True on pool worker threads. Nested data-parallel calls check this and
/// run inline (serially) rather than re-entering the pool.
pub fn in_pool_worker() -> bool {
    IN_POOL.with(|c| c.get())
}

static POOL: OnceLock<Pool> = OnceLock::new();

/// The process-wide pool, created on first use. Thread-count resolution and
/// worker spawning happen exactly once, behind the `OnceLock`.
pub fn global() -> &'static Pool {
    POOL.get_or_init(Pool::new)
}

fn worker_loop(shared: Arc<Shared>) {
    IN_POOL.with(|c| c.set(true));
    let mut slot = lock_recover(&shared.slot);
    loop {
        // `task` is Copy (a shared reference), so claim it into locals
        // before touching the guard again.
        let task_opt = slot.task;
        let claim = match task_opt {
            Some(task) if slot.next < slot.n_tasks => {
                let i = slot.next;
                slot.next += 1;
                Some((task, i))
            }
            _ => None,
        };
        match claim {
            Some((task, i)) => {
                drop(slot);
                // Catch panics so `pending` always drains — otherwise the
                // publisher would wait forever on a buggy task.
                let result =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(i)));
                slot = lock_recover(&shared.slot);
                slot.pending -= 1;
                if result.is_err() {
                    slot.poisoned = true;
                }
                if slot.pending == 0 {
                    shared.done_cv.notify_all();
                }
            }
            None => {
                slot = wait_recover(&shared.work_cv, slot);
            }
        }
    }
}

/// Exclusive dispatch right on the pool's workers, released on drop.
struct Lease<'a> {
    shared: &'a Shared,
    _guard: MutexGuard<'a, ()>,
}

impl Lease<'_> {
    /// Execute `f(0..n_tasks)` across the workers and the calling thread,
    /// returning once every task has finished.
    fn run_tasks<F>(&self, n_tasks: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n_tasks == 0 {
            return;
        }
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: the erased reference is only reachable through the job
        // slot, and this function does not return until `pending == 0`,
        // which requires every claimed task to have finished executing the
        // closure. The slot's `task` is cleared before returning, so no
        // worker can observe the reference after the borrow of `f` ends.
        let erased: TaskFn = unsafe { std::mem::transmute(f_ref) };
        {
            let mut s = lock_recover(&self.shared.slot);
            s.task = Some(erased);
            s.n_tasks = n_tasks;
            s.next = 0;
            s.pending = n_tasks;
            s.poisoned = false;
        }
        self.shared.work_cv.notify_all();
        // The caller participates as the n-th executor. Its own task panics
        // are caught and re-raised only after every outstanding task has
        // drained — unwinding earlier would end the closure borrow while
        // workers still hold the erased reference.
        let mut caller_panic: Option<Box<dyn std::any::Any + Send>> = None;
        loop {
            let mut s = lock_recover(&self.shared.slot);
            if caller_panic.is_none() && s.next < n_tasks {
                let i = s.next;
                s.next += 1;
                drop(s);
                let result =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i)));
                let mut s = lock_recover(&self.shared.slot);
                s.pending -= 1;
                let done = s.pending == 0;
                if let Err(payload) = result {
                    caller_panic = Some(payload);
                }
                drop(s);
                if done {
                    self.shared.done_cv.notify_all();
                }
            } else {
                while s.pending > 0 {
                    s = wait_recover(&self.shared.done_cv, s);
                }
                s.task = None;
                let worker_panicked = s.poisoned;
                s.poisoned = false;
                drop(s);
                if let Some(payload) = caller_panic {
                    std::panic::resume_unwind(payload);
                }
                if worker_panicked {
                    panic!("pool worker task panicked");
                }
                return;
            }
        }
    }
}

impl Pool {
    fn new() -> Pool {
        let n_threads = std::env::var("GNN_SPMM_THREADS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            })
            .max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(SlotState {
                task: None,
                n_tasks: 0,
                next: 0,
                pending: 0,
                poisoned: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        // The caller of a parallel region is always one executor, so spawn
        // n_threads - 1 long-lived workers. They park between jobs and die
        // with the process.
        for idx in 1..n_threads {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("gnn-pool-{idx}"))
                .spawn(move || worker_loop(shared))
                .expect("failed to spawn pool worker");
        }
        Pool {
            n_threads,
            shared,
            lease_lock: Mutex::new(()),
            scratch: Mutex::new(Vec::new()),
        }
    }

    /// The worker-thread budget (env-resolved once at construction).
    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Try to acquire exclusive dispatch. `None` ⇒ run inline: the pool is
    /// single-threaded, the caller is itself a pool worker (nested call), or
    /// another thread currently holds the lease.
    fn lease(&self) -> Option<Lease<'_>> {
        if self.n_threads <= 1 || in_pool_worker() {
            return None;
        }
        match self.lease_lock.try_lock() {
            Ok(guard) => Some(Lease { shared: &self.shared, _guard: guard }),
            // A caller that panicked inside run_tasks (re-raised task panic)
            // unwound while holding the lease and poisoned this mutex. The
            // lease guards no data — treating Poisoned as WouldBlock would
            // silently degrade every later job to serial forever.
            Err(TryLockError::Poisoned(p)) => {
                Some(Lease { shared: &self.shared, _guard: p.into_inner() })
            }
            Err(TryLockError::WouldBlock) => None,
        }
    }

    /// Run `f` over an even partition of `[0, n)` — one contiguous range per
    /// executor. `f` must be safe to run concurrently on disjoint ranges.
    pub fn run_ranges<F>(&self, n: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let k = self.n_threads.min(n);
        self.run_weighted_ranges(k, |i| even_range(n, k, i), f);
    }

    /// Run `f(span_of(i))` for every task `i < n_tasks`, skipping empty
    /// spans. This is the weighted-scheduling entry point: callers compute
    /// spans with equal *work* (non-zeros), not equal length — e.g. via
    /// [`crate::util::parallel::indptr_span`] — so no worker is stuck with
    /// all the hub rows of a power-law graph. Spans must be disjoint when
    /// `f` writes to shared output.
    pub fn run_weighted_ranges<S, F>(&self, n_tasks: usize, span_of: S, f: F)
    where
        S: Fn(usize) -> Range<usize> + Sync,
        F: Fn(Range<usize>) + Sync,
    {
        if n_tasks == 0 {
            return;
        }
        let lease = if n_tasks > 1 { self.lease() } else { None };
        match lease {
            Some(lease) => lease.run_tasks(n_tasks, |i| {
                let span = span_of(i);
                if !span.is_empty() {
                    f(span);
                }
            }),
            None => {
                for i in 0..n_tasks {
                    let span = span_of(i);
                    if !span.is_empty() {
                        f(span);
                    }
                }
            }
        }
    }

    /// Scatter-reduce: `out = Σ_i contribution(span_of(i))` over an
    /// `n_rows × row_len` row-major buffer, overwriting `out` completely.
    ///
    /// Each task scatters into a zeroed per-task scratch buffer from the
    /// pool registry (grow-only: steady state performs no heap allocation),
    /// then the scratches are summed into `out` in parallel over row blocks.
    /// Single-threaded, nested and lease-contended calls scatter straight
    /// into `out` serially — same result, no scratch.
    pub fn scatter_reduce<S, F>(
        &self,
        out: &mut [f32],
        n_rows: usize,
        row_len: usize,
        n_tasks: usize,
        span_of: S,
        scatter: F,
    ) where
        S: Fn(usize) -> Range<usize> + Sync,
        F: Fn(Range<usize>, &mut [f32]) + Sync,
    {
        let nd = n_rows * row_len;
        debug_assert_eq!(out.len(), nd);
        let lease = if n_tasks > 1 { self.lease() } else { None };
        let Some(lease) = lease else {
            out.fill(0.0);
            for i in 0..n_tasks {
                let span = span_of(i);
                if !span.is_empty() {
                    scatter(span, out);
                }
            }
            return;
        };

        let mut bufs = std::mem::take(&mut *lock_recover(&self.scratch));
        while bufs.len() < n_tasks {
            bufs.push(Vec::new());
        }
        let bufs_addr = bufs.as_mut_ptr() as usize;
        lease.run_tasks(n_tasks, |i| {
            // SAFETY: task indices are distinct, so each task gets exclusive
            // access to its own scratch Vec.
            let buf = unsafe { &mut *(bufs_addr as *mut Vec<f32>).add(i) };
            let span = span_of(i);
            if span.is_empty() {
                // Mark unused so the reduction skips it.
                buf.clear();
            } else {
                buf.clear();
                buf.resize(nd, 0.0);
                scatter(span, buf.as_mut_slice());
            }
        });

        let used: &[Vec<f32>] = &bufs[..n_tasks];
        let k_red = self.n_threads.min(n_rows.max(1));
        let out_addr = out.as_mut_ptr() as usize;
        lease.run_tasks(k_red, |j| {
            let rows = even_range(n_rows, k_red, j);
            let lo = rows.start * row_len;
            let len = rows.len() * row_len;
            // SAFETY: row ranges are disjoint across tasks, so the chunks
            // never alias.
            let chunk = unsafe {
                std::slice::from_raw_parts_mut((out_addr as *mut f32).add(lo), len)
            };
            chunk.fill(0.0);
            for buf in used {
                if buf.len() == nd {
                    for (o, &v) in chunk.iter_mut().zip(buf[lo..lo + len].iter()) {
                        *o += v;
                    }
                }
            }
        });
        // Return the scratch set while still holding the lease: a concurrent
        // caller that wins the lease next must find the registry populated,
        // or it would allocate (and later leak) a whole fresh buffer set.
        *lock_recover(&self.scratch) = bufs;
        drop(lease);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parallel::{num_threads, parallel_map};
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn run_ranges_covers_and_pool_is_reused() {
        // Many sequential jobs on the same pool: workers must wake, drain
        // and park correctly every time.
        for round in 0..50 {
            let sum = AtomicU64::new(0);
            global().run_ranges(1000, |r| {
                let mut local = 0u64;
                for i in r {
                    local += i as u64;
                }
                sum.fetch_add(local, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2, "round {round}");
        }
    }

    #[test]
    fn weighted_ranges_skip_empty_spans() {
        let visited = AtomicU64::new(0);
        let spans = [0..0, 0..5, 5..5, 5..9, 9..9];
        global().run_weighted_ranges(spans.len(), |i| spans[i].clone(), |r| {
            visited.fetch_add(r.len() as u64, Ordering::Relaxed);
        });
        assert_eq!(visited.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn nested_parallel_calls_run_inline() {
        // Outer parallel_map tasks each start an inner parallel region; the
        // inner ones must degrade to serial (no deadlock, correct results).
        let out = parallel_map(8, |i| {
            let sum = AtomicU64::new(0);
            global().run_ranges(200, |r| {
                let mut local = 0u64;
                for j in r {
                    local += j as u64;
                }
                sum.fetch_add(local, Ordering::Relaxed);
            });
            sum.into_inner() + i as u64
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, 199 * 200 / 2 + i as u64);
        }
    }

    #[test]
    fn concurrent_callers_stay_correct() {
        // Lease contention: losers run inline; everyone computes the right
        // answer. (Test-only scope spawn — kernels never spawn.)
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..20 {
                        let sum = AtomicU64::new(0);
                        global().run_ranges(512, |r| {
                            let mut local = 0u64;
                            for i in r {
                                local += i as u64;
                            }
                            sum.fetch_add(local, Ordering::Relaxed);
                        });
                        assert_eq!(sum.load(Ordering::Relaxed), 511 * 512 / 2);
                    }
                });
            }
        });
    }

    #[test]
    fn scatter_reduce_overwrites_and_sums() {
        let (n, d) = (64, 3);
        let mut out = vec![99.0f32; n * d];
        let k = num_threads().min(8).max(2);
        // 32 units; unit u bumps column 0 of row u.
        global().scatter_reduce(&mut out, n, d, k, |i| even_range(32, k, i), |span, buf| {
            for u in span {
                buf[u * d] += 1.0;
            }
        });
        for r in 0..n {
            let want = if r < 32 { 1.0 } else { 0.0 };
            assert_eq!(out[r * d], want, "row {r}");
            assert_eq!(out[r * d + 1], 0.0);
            assert_eq!(out[r * d + 2], 0.0);
        }
    }

    #[test]
    fn scatter_reduce_empty_tasks() {
        let mut out = vec![7.0f32; 12];
        global().scatter_reduce(&mut out, 4, 3, 1, |_| 0..0, |_, _| unreachable!());
        assert_eq!(out, vec![0.0; 12]);
    }
}
