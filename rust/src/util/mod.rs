//! Support substrates built in-tree because the build environment is offline
//! (no rayon / rand / serde / clap / criterion available).

pub mod rng;
pub mod json;
pub mod cli;
pub mod parallel;
pub mod pool;
pub mod stats;
pub mod csv;
pub mod fsio;
pub mod sync;
pub mod timer;

pub use rng::Rng;
pub use timer::Stopwatch;
