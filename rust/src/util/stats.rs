//! Summary statistics used by the bench harness and the experiment reports
//! (the paper reports geometric means with min–max bars).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (paper's headline aggregation); requires positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|&x| x.max(1e-300).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Median via sort-copy.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Min-max scaling of a value into [0,1] given observed bounds (paper §4.4:
/// feature normalization with clipping at deployment).
pub fn minmax_scale(x: f64, lo: f64, hi: f64) -> f64 {
    if hi <= lo {
        return 0.0;
    }
    ((x - lo) / (hi - lo)).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn minmax_scale_clips() {
        assert_eq!(minmax_scale(5.0, 0.0, 10.0), 0.5);
        assert_eq!(minmax_scale(-1.0, 0.0, 10.0), 0.0);
        assert_eq!(minmax_scale(11.0, 0.0, 10.0), 1.0);
        assert_eq!(minmax_scale(1.0, 2.0, 2.0), 0.0);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }
}
