//! Summary statistics used by the bench harness and the experiment reports
//! (the paper reports geometric means with min–max bars).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (paper's headline aggregation); requires positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|&x| x.max(1e-300).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Median via sort-copy.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Nearest-rank percentile, p in (0, 100]: the smallest element with at
/// least ⌈p/100 · n⌉ elements ≤ it. Unlike [`percentile`], this always
/// returns an **observed** value — the convention latency SLOs use (a
/// reported p99 is a latency some request actually paid, never an
/// interpolation between two). Empty input returns 0.
pub fn percentile_nearest_rank(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

/// Median latency (nearest-rank) — the serving layer's p50.
pub fn p50(xs: &[f64]) -> f64 {
    percentile_nearest_rank(xs, 50.0)
}

/// Nearest-rank 95th percentile.
pub fn p95(xs: &[f64]) -> f64 {
    percentile_nearest_rank(xs, 95.0)
}

/// Nearest-rank 99th percentile.
pub fn p99(xs: &[f64]) -> f64 {
    percentile_nearest_rank(xs, 99.0)
}

/// Min-max scaling of a value into [0,1] given observed bounds (paper §4.4:
/// feature normalization with clipping at deployment).
pub fn minmax_scale(x: f64, lo: f64, hi: f64) -> f64 {
    if hi <= lo {
        return 0.0;
    }
    ((x - lo) / (hi - lo)).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn minmax_scale_clips() {
        assert_eq!(minmax_scale(5.0, 0.0, 10.0), 0.5);
        assert_eq!(minmax_scale(-1.0, 0.0, 10.0), 0.0);
        assert_eq!(minmax_scale(11.0, 0.0, 10.0), 1.0);
        assert_eq!(minmax_scale(1.0, 2.0, 2.0), 0.0);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    /// Nearest-rank on a known distribution: 1..=100 puts pXX exactly at
    /// the value XX (rank ⌈p⌉ of 100 elements).
    #[test]
    fn nearest_rank_on_known_distribution() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(p50(&xs), 50.0);
        assert_eq!(p95(&xs), 95.0);
        assert_eq!(p99(&xs), 99.0);
        assert_eq!(percentile_nearest_rank(&xs, 100.0), 100.0);
        // Sub-1% ranks clamp to the smallest observation.
        assert_eq!(percentile_nearest_rank(&xs, 0.1), 1.0);
    }

    /// Nearest-rank must return an observed value even where the
    /// interpolated percentile would not: 4 elements, p50 → rank 2.
    #[test]
    fn nearest_rank_returns_observed_values() {
        let xs = [4.0, 1.0, 3.0, 2.0]; // unsorted on purpose
        assert_eq!(p50(&xs), 2.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12, "interpolated median differs");
        assert_eq!(p95(&xs), 4.0);
        assert_eq!(p99(&xs), 4.0);
    }

    #[test]
    fn nearest_rank_edge_cases() {
        // Empty slice: all percentiles degrade to 0.
        assert_eq!(p50(&[]), 0.0);
        assert_eq!(p95(&[]), 0.0);
        assert_eq!(p99(&[]), 0.0);
        // Single element: every percentile is that element.
        let one = [42.0];
        assert_eq!(p50(&one), 42.0);
        assert_eq!(p95(&one), 42.0);
        assert_eq!(p99(&one), 42.0);
        // Two elements: p50 is the lower, the tails are the upper.
        let two = [10.0, 20.0];
        assert_eq!(p50(&two), 10.0);
        assert_eq!(p99(&two), 20.0);
    }
}
