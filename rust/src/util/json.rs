//! Minimal JSON parser/emitter (serde is unavailable offline).
//!
//! Used for: trained-model serialization, the AOT artifact manifest, and
//! experiment result dumps. Supports the full JSON value model with f64
//! numbers; good enough for our interchange needs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps key order deterministic for reproducible dumps.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num_arr<'a, I: IntoIterator<Item = &'a f64>>(items: I) -> Json {
        Json::Arr(items.into_iter().map(|&v| Json::Num(v)).collect())
    }

    pub fn f32_arr<'a, I: IntoIterator<Item = &'a f32>>(items: I) -> Json {
        Json::Arr(items.into_iter().map(|&v| Json::Num(v as f64)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Required-field accessors that produce useful errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("JSON key '{key}' is not a number"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("JSON key '{key}' is not a string"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("JSON key '{key}' is not an array"))
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(input: &str) -> anyhow::Result<Json> {
        let bytes = input.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            anyhow::bail!("trailing characters at byte {pos}");
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        anyhow::bail!("unexpected end of input");
    }
    match b[*pos] {
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => anyhow::bail!("expected ',' or ']' at byte {pos}"),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    anyhow::bail!("expected ':' at byte {pos}");
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => anyhow::bail!("expected ',' or '}}' at byte {pos}"),
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> anyhow::Result<Json> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(v)
    } else {
        anyhow::bail!("invalid literal at byte {pos}")
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> anyhow::Result<String> {
    if b.get(*pos) != Some(&b'"') {
        anyhow::bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => anyhow::bail!("bad escape at byte {pos}"),
                }
                *pos += 1;
            }
            c => {
                // Copy raw UTF-8 bytes through.
                let start = *pos;
                let len = utf8_len(c);
                *pos += len;
                out.push_str(std::str::from_utf8(&b[start..*pos])?);
            }
        }
    }
    anyhow::bail!("unterminated string")
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> anyhow::Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    let n: f64 = s
        .parse()
        .map_err(|_| anyhow::anyhow!("invalid number '{s}' at byte {start}"))?;
    Ok(Json::Num(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -3.25e2}"#;
        let v = Json::parse(src).unwrap();
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, round);
        assert_eq!(v.get("d").unwrap().as_f64().unwrap(), -325.0);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x\ny"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nope").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn escapes_control_chars() {
        let v = Json::Str("a\u{1}b".to_string());
        let s = v.to_string();
        assert!(s.contains("\\u0001"));
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn req_accessors_error_cleanly() {
        let v = Json::parse(r#"{"x": 1}"#).unwrap();
        assert_eq!(v.req_f64("x").unwrap(), 1.0);
        assert!(v.req_f64("y").is_err());
        assert!(v.req_str("x").is_err());
    }
}
