//! Crash-safe file I/O primitives (DESIGN.md §Streaming-Durability).
//!
//! Every persistence path in this crate — WAL segments, compaction
//! checkpoints, the decision-cache warm-start file, the trained-predictor
//! dump — routes its writes through this module; the `durability-io` lint
//! rule forbids raw `File::create`/`write_all` in those files so a new
//! call site cannot silently reintroduce torn-on-crash writes.
//!
//! Two idioms cover all of them:
//!
//! * **Replace-whole-file** ([`atomic_write`] / [`PreparedWrite`]): write
//!   a temp file *in the destination directory* (rename across
//!   filesystems is not atomic), `fsync` it, then `rename` over the
//!   destination and `fsync` the directory. A crash at any point leaves
//!   either the complete old file or the complete new file — never a
//!   prefix. `PreparedWrite` splits the two halves so fault injection can
//!   crash exactly between data-durable and name-durable.
//! * **Append-only** ([`AppendFile`]): length-tracked appends with
//!   explicit `sync` batching and `truncate_to` healing — the WAL's
//!   substrate. Torn tails are the *expected* crash artifact here; the
//!   WAL's per-record CRC (via [`crc32`]) finds the last good byte on
//!   replay.

use std::fs::{File, OpenOptions};
use std::io::{self, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Atomically replace the file at `path` with `bytes`: temp file in the
/// same directory + fsync + rename + directory fsync. Creates parent
/// directories as needed.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    PreparedWrite::prepare(path, bytes)?.commit()
}

/// The two-phase half of [`atomic_write`]: after [`PreparedWrite::prepare`]
/// the data is durable under a temp name; [`PreparedWrite::commit`] makes
/// it *the* file. Dropping without committing removes the temp file — the
/// crash-abandonment path fault tests exercise on purpose.
#[derive(Debug)]
pub struct PreparedWrite {
    tmp: PathBuf,
    dst: PathBuf,
    committed: bool,
}

impl PreparedWrite {
    /// Write `bytes` to a temp file next to `dst` and fsync it. The
    /// destination is untouched until [`PreparedWrite::commit`].
    pub fn prepare(dst: &Path, bytes: &[u8]) -> io::Result<PreparedWrite> {
        if let Some(parent) = dst.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut name = dst.file_name().map(|n| n.to_os_string()).unwrap_or_default();
        name.push(format!(".tmp.{}", std::process::id()));
        let tmp = dst.with_file_name(name);
        let mut f = File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        Ok(PreparedWrite { tmp, dst: dst.to_path_buf(), committed: false })
    }

    /// Publish the prepared bytes under the destination name (atomic
    /// rename) and fsync the directory so the rename itself is durable.
    pub fn commit(mut self) -> io::Result<()> {
        std::fs::rename(&self.tmp, &self.dst)?;
        self.committed = true;
        sync_parent_dir(&self.dst)
    }

    /// Discard without publishing (explicit spelling of the `Drop` path).
    pub fn abandon(self) {}
}

impl Drop for PreparedWrite {
    fn drop(&mut self) {
        if !self.committed {
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

/// Fsync the directory containing `path` so a just-committed rename (or a
/// just-created file) survives power loss. Directory handles are openable
/// read-only on every unix; elsewhere this degrades to a no-op.
fn sync_parent_dir(path: &Path) -> io::Result<()> {
    if !cfg!(unix) {
        return Ok(());
    }
    match path.parent() {
        Some(dir) if !dir.as_os_str().is_empty() => File::open(dir)?.sync_all(),
        _ => Ok(()),
    }
}

/// Length-tracked append-only file: the WAL substrate. All writes go
/// through [`AppendFile::append`], durability through
/// [`AppendFile::sync`], and failed/torn appends are healed by
/// [`AppendFile::truncate_to`] back to the last known-good length.
#[derive(Debug)]
pub struct AppendFile {
    file: File,
    path: PathBuf,
    len: u64,
}

impl AppendFile {
    /// Open (creating if absent) for appending. The cursor starts at the
    /// current end; `len()` reports it. On first creation the parent
    /// directory is fsynced: without it the file's directory entry is not
    /// durable, and a crash could drop the whole log even after its
    /// records were individually fsynced.
    pub fn open_append(path: &Path) -> io::Result<AppendFile> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let existed = path.exists();
        let mut file = OpenOptions::new().read(true).create(true).append(true).open(path)?;
        if !existed {
            sync_parent_dir(path)?;
        }
        let len = file.seek(SeekFrom::End(0))?;
        Ok(AppendFile { file, path: path.to_path_buf(), len })
    }

    /// Current byte length (as tracked through this handle).
    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append `bytes` at the end. Buffered in the OS page cache until
    /// [`AppendFile::sync`]; on error the on-disk tail is unspecified and
    /// the caller must heal with [`AppendFile::truncate_to`].
    pub fn append(&mut self, bytes: &[u8]) -> io::Result<()> {
        self.file.write_all(bytes)?;
        self.len += bytes.len() as u64;
        Ok(())
    }

    /// Make everything appended so far durable.
    pub fn sync(&mut self) -> io::Result<()> {
        self.file.sync_all()
    }

    /// Heal the tail back to `len` bytes (after a failed append, or on
    /// open after a torn-tail scan).
    pub fn truncate_to(&mut self, len: u64) -> io::Result<()> {
        self.file.set_len(len)?;
        self.file.seek(SeekFrom::End(0))?;
        self.len = len;
        Ok(())
    }

    /// Read the whole file (for replay scans).
    pub fn read_all(&mut self) -> io::Result<Vec<u8>> {
        let mut buf = Vec::with_capacity(self.len as usize);
        self.file.seek(SeekFrom::Start(0))?;
        self.file.read_to_end(&mut buf)?;
        self.file.seek(SeekFrom::End(0))?;
        Ok(buf)
    }
}

/// CRC-32 (IEEE 802.3, the zlib polynomial), bitwise — no table, and the
/// WAL/checkpoint records it guards are small enough that the ~8
/// shifts/byte never show up in a profile.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("gnn_spmm_fsio").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn crc32_matches_the_ieee_check_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn atomic_write_replaces_whole_file() {
        let path = tmp_dir("aw").join("out.bin");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second, longer").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second, longer");
        // No temp droppings.
        let siblings: Vec<_> = std::fs::read_dir(path.parent().unwrap()).unwrap().collect();
        assert_eq!(siblings.len(), 1);
    }

    #[test]
    fn abandoned_prepare_leaves_destination_intact() {
        let path = tmp_dir("abandon").join("out.bin");
        atomic_write(&path, b"stable").unwrap();
        let staged = PreparedWrite::prepare(&path, b"never lands").unwrap();
        staged.abandon();
        assert_eq!(std::fs::read(&path).unwrap(), b"stable");
        let siblings: Vec<_> = std::fs::read_dir(path.parent().unwrap()).unwrap().collect();
        assert_eq!(siblings.len(), 1, "temp file must be cleaned up");
    }

    #[test]
    fn append_file_tracks_length_and_heals() {
        let path = tmp_dir("append").join("log.bin");
        let _ = std::fs::remove_file(&path);
        let mut f = AppendFile::open_append(&path).unwrap();
        assert!(f.is_empty());
        f.append(b"abcd").unwrap();
        f.append(b"efgh").unwrap();
        f.sync().unwrap();
        assert_eq!(f.len(), 8);
        // Torn append healed back to the good prefix.
        f.append(b"torn").unwrap();
        f.truncate_to(8).unwrap();
        f.append(b"ijkl").unwrap();
        assert_eq!(f.read_all().unwrap(), b"abcdefghijkl");
        // Reopen sees the same length.
        drop(f);
        let f2 = AppendFile::open_append(&path).unwrap();
        assert_eq!(f2.len(), 12);
    }
}
