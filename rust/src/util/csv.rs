//! Minimal CSV writer for experiment outputs (one file per figure/table so
//! plots can be regenerated outside this repo).

use std::io::Write;
use std::path::Path;

/// In-memory CSV table with a header row.
#[derive(Debug, Clone)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Self {
        CsvTable {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Push a row; panics if the width differs from the header.
    pub fn push<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, row: I) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "CSV row width mismatch");
        self.rows.push(row);
    }

    fn escape(field: &str) -> String {
        if field.contains(',') || field.contains('"') || field.contains('\n') {
            format!("\"{}\"", field.replace('"', "\"\""))
        } else {
            field.to_string()
        }
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let fmt_row = |row: &[String]| {
            row.iter().map(|f| Self::escape(f)).collect::<Vec<_>>().join(",")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Write to `path`, creating parent directories.
    pub fn write_file<P: AsRef<Path>>(&self, path: P) -> anyhow::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())?;
        Ok(())
    }
}

/// Format a float with fixed precision for tables.
pub fn fmt(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_escapes() {
        let mut t = CsvTable::new(["name", "value"]);
        t.push(["plain", "1"]);
        t.push(["has,comma", "quote\"inside"]);
        let s = t.to_string();
        assert!(s.starts_with("name,value\n"));
        assert!(s.contains("\"has,comma\""));
        assert!(s.contains("\"quote\"\"inside\""));
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = CsvTable::new(["a", "b"]);
        t.push(["only-one"]);
    }

    #[test]
    fn writes_file() {
        let mut t = CsvTable::new(["x"]);
        t.push(["1"]);
        let path = std::env::temp_dir().join("gnn_spmm_csv_test/out.csv");
        t.write_file(&path).unwrap();
        let read = std::fs::read_to_string(&path).unwrap();
        assert_eq!(read, "x\n1\n");
        let _ = std::fs::remove_file(path);
    }
}
