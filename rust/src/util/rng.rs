//! Deterministic pseudo-random number generation.
//!
//! A small xoshiro256** generator seeded through SplitMix64, sufficient for
//! synthetic-matrix generation, weight init, shuffling and property tests.
//! Deterministic across platforms so every experiment is reproducible from a
//! seed recorded in EXPERIMENTS.md.

/// xoshiro256** PRNG (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-thread generators).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough method; bias is
        // negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Rejection sampling for sparse draws.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.gen_range(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }

    /// Zipf-like power-law integer in `[0, n)` with exponent `alpha` —
    /// used for citation-graph-style skewed degree distributions.
    pub fn powerlaw(&mut self, n: usize, alpha: f64) -> usize {
        // Inverse-CDF of a truncated Pareto, mapped onto [0, n).
        let u = self.next_f64();
        let x = (1.0 - u * (1.0 - (n as f64).powf(1.0 - alpha))).powf(1.0 / (1.0 - alpha));
        ((x - 1.0).max(0.0) as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        for &(n, k) in &[(100usize, 5usize), (100, 90), (10, 10), (1000, 1)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn powerlaw_skews_small() {
        let mut r = Rng::new(13);
        let n = 10_000;
        let draws: Vec<usize> = (0..n).map(|_| r.powerlaw(1000, 2.2)).collect();
        let small = draws.iter().filter(|&&d| d < 10).count();
        assert!(small > n / 3, "power law should concentrate mass at small values: {small}");
        assert!(draws.iter().all(|&d| d < 1000));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(77);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
