//! Poison-recovering lock helpers (DESIGN.md §Fault-Tolerance).
//!
//! `std` mutexes poison when a holder panics, and every later
//! `.lock().unwrap()` then panics too — one worker crash cascades into a
//! wedged queue, a hanging `drain`, and an unreportable server. Poisoning
//! is only a *heuristic* ("a critical section may have been cut short");
//! for the serving structures in this crate the protected state is always
//! consistent at every await point (counter increments, `VecDeque`
//! push/pop, `Vec` push are each atomic with respect to panics), so the
//! right policy is to **recover**: take the guard out of the
//! `PoisonError` and carry on. These helpers centralize that policy so
//! call sites read as intent (`lock_recover`) rather than as a sprinkle
//! of `unwrap_or_else(PoisonError::into_inner)`.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait` that survives poisoning (the wait itself cannot corrupt
/// state; poison here only means some *other* holder panicked earlier).
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait_timeout` with poison recovery. Returns the reacquired
/// guard and whether the wait timed out.
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(guard, dur) {
        Ok((g, t)) => (g, t.timed_out()),
        Err(p) => {
            let (g, t) = p.into_inner();
            (g, t.timed_out())
        }
    }
}

/// Read-lock an `RwLock`, recovering from poison.
pub fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// Write-lock an `RwLock`, recovering from poison.
pub fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::{Arc, Condvar, Mutex, RwLock};

    /// Poison `m` by panicking while holding its guard.
    fn poison<T: Send + 'static>(m: &Arc<Mutex<T>>) {
        let m = Arc::clone(m);
        let _ = std::thread::spawn(move || {
            let _g = m.lock().unwrap(); // lint: allow(lock-discipline) -- test helper must hold a raw guard to poison the mutex on purpose
            panic!("poison on purpose");
        })
        .join();
    }

    #[test]
    fn lock_recover_survives_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(41));
        poison(&m);
        assert!(m.lock().is_err(), "precondition: mutex is poisoned");
        let mut g = lock_recover(&m);
        *g += 1;
        assert_eq!(*g, 42);
    }

    #[test]
    fn wait_recover_wakes_on_poisoned_mutex() {
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        poison(&Arc::new(Mutex::new(())));
        let waiter = {
            let pair = Arc::clone(&pair);
            std::thread::spawn(move || {
                let (m, cv) = &*pair;
                let mut g = lock_recover(m);
                while !*g {
                    g = wait_recover(cv, g);
                }
            })
        };
        // Poison the waited-on mutex from a third thread, then signal.
        {
            let pair = Arc::clone(&pair);
            let _ = std::thread::spawn(move || {
                let (m, _cv) = &*pair;
                let mut g = lock_recover(m);
                *g = true;
                panic!("poison while signalling");
            })
            .join();
        }
        pair.1.notify_all();
        waiter.join().unwrap();
    }

    #[test]
    fn wait_timeout_recover_reports_timeout() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_recover(&m);
        let (_g, timed_out) = wait_timeout_recover(&cv, g, Duration::from_millis(5));
        assert!(timed_out);
    }

    #[test]
    fn rwlock_recover_survives_poisoned_writer() {
        let l = Arc::new(RwLock::new(7));
        {
            let l = Arc::clone(&l);
            let _ = std::thread::spawn(move || {
                let _g = l.write().unwrap(); // lint: allow(lock-discipline) -- test must poison the rwlock through a raw writer guard
                panic!("poison the rwlock");
            })
            .join();
        }
        assert!(l.read().is_err(), "precondition: rwlock is poisoned");
        assert_eq!(*read_recover(&l), 7);
        *write_recover(&l) = 8;
        assert_eq!(*read_recover(&l), 8);
    }

    #[test]
    fn recovery_composes_with_catch_unwind() {
        // The serving pattern: a panic inside a critical section is caught,
        // and the next lock_recover proceeds as if nothing happened.
        let m = Mutex::new(vec![1, 2, 3]);
        let r = catch_unwind(AssertUnwindSafe(|| {
            let _g = lock_recover(&m);
            panic!("mid-section");
        }));
        assert!(r.is_err());
        assert_eq!(lock_recover(&m).len(), 3);
    }
}
