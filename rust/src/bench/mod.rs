//! Criterion-like micro-bench harness (criterion is unavailable offline).
//! Used by every binary under `rust/benches/` (built with `harness = false`).

pub mod alloc_counter;

pub use alloc_counter::{count_allocs, CountingAlloc};

use crate::util::stats;
use crate::util::timer::time_n;

/// Statistics for one benchmarked configuration.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} median {:>11} mean {:>11} min {:>11} max {:>11} (n={})",
            self.name,
            fmt_time(self.median_s),
            fmt_time(self.mean_s),
            fmt_time(self.min_s),
            fmt_time(self.max_s),
            self.iters
        )
    }
}

/// Human-readable seconds.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Run one benchmark: `warmup` unmeasured + `iters` measured invocations.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    let samples = time_n(warmup, iters, &mut f);
    let result = BenchResult {
        name: name.to_string(),
        iters,
        median_s: stats::median(&samples),
        mean_s: stats::mean(&samples),
        min_s: stats::min(&samples),
        max_s: stats::max(&samples),
        stddev_s: stats::stddev(&samples),
    };
    println!("{}", result.line());
    result
}

/// Print a bench-section header.
pub fn section(title: &str) {
    println!("\n―― {title} ――");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_stats() {
        let r = bench("noop", 1, 5, || std::hint::black_box(1 + 1));
        assert_eq!(r.iters, 5);
        assert!(r.min_s <= r.median_s && r.median_s <= r.max_s);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-5).ends_with("µs"));
        assert!(fmt_time(2e-2).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }
}
