//! Counting global allocator shared by the bench binaries (DESIGN.md
//! §Perf accounting rules — one implementation, one rule set).
//!
//! Each bench binary that wants allocation counts installs it:
//!
//! ```ignore
//! #[global_allocator]
//! static GLOBAL: gnn_spmm::bench::CountingAlloc = gnn_spmm::bench::CountingAlloc;
//! ```
//!
//! Counting is **gated**: the atomic counters only tick inside
//! [`count_allocs`], so the timing sections of a bench run under the same
//! conditions as an uninstrumented binary (two relaxed atomic RMWs per
//! allocation would otherwise skew every recorded ns/op, conflating a code
//! change with the instrumentation in cross-PR comparisons). The gate is a
//! single relaxed load on the alloc path when disabled.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);
static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Counting allocator: tracks calls and bytes (while enabled) so benches
/// can report the per-op allocation cost of a code path.
pub struct CountingAlloc;

// SAFETY: pure pass-through to `System`; the impl upholds `GlobalAlloc`'s
// contract because every method delegates layout handling verbatim and the
// counter updates have no effect on the returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller contract identical to `System.alloc`; we only add
    // relaxed counter ticks before delegating.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
            ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    // SAFETY: `ptr`/`layout` are forwarded untouched to the allocator that
    // produced them (`System`, via our `alloc`).
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: same delegation argument as `alloc`/`dealloc`; `new_size`
    // validity is the caller's obligation, unchanged by the counting.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
            ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

/// Allocation calls + bytes across one invocation of `f`. Counts every
/// thread's allocations while `f` runs (pool workers included), exactly
/// like the always-on counter it replaces did during its window.
pub fn count_allocs<T>(mut f: impl FnMut() -> T) -> (u64, u64) {
    let c0 = ALLOC_CALLS.load(Ordering::Relaxed);
    let b0 = ALLOC_BYTES.load(Ordering::Relaxed);
    ENABLED.store(true, Ordering::SeqCst);
    std::hint::black_box(f());
    ENABLED.store(false, Ordering::SeqCst);
    (
        ALLOC_CALLS.load(Ordering::Relaxed) - c0,
        ALLOC_BYTES.load(Ordering::Relaxed) - b0,
    )
}
