//! Deterministic fault-injection harness (DESIGN.md §Fault-Tolerance).
//!
//! A [`FaultPlan`] is a seeded, instance-scoped schedule of injected
//! failures: worker panics, slow-request delays, corrupt sparse operands,
//! and cache-file truncation. The serving layer threads one through
//! `ServeConfig` and consults it at fixed injection points; the default
//! plan is **inert** — every `maybe_*` call is a branch on a zeroed rate
//! table, so production paths carry the hooks at no behavioral cost and
//! tests arm exactly the failures they mean to exercise.
//!
//! Determinism is the point: whether observation ordinal `n` of kind `k`
//! fires is a pure function of `(seed, k, n)` (a splitmix64 draw against
//! the kind's rate) plus an explicit scripted-ordinal list — so a failing
//! fault schedule replays exactly from its seed, the same property
//! `testing::check` gives random matrices. There is no global state:
//! plans are `Arc`-shared per server, and two servers with the same seed
//! see the same schedule.

use crate::sparse::SparseMatrix;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// The injectable failure classes, one counter lane each.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic inside a worker's per-request inference.
    Panic,
    /// Sleep before serving a request (widens race windows, expires
    /// deadlines).
    Delay,
    /// Structurally corrupt a sparse operand in place.
    CorruptOperand,
    /// Truncate a file (cache persistence hardening).
    TruncateFile,
    /// A write that lands only a prefix of its bytes (torn WAL record /
    /// torn checkpoint temp file). Consulted at durable-write seams.
    ShortWrite,
    /// An I/O operation that fails outright (full disk, yanked volume).
    IoError,
    /// Simulated process death at a named durability seam (WAL append,
    /// checkpoint rename, compaction publish): the seam returns a typed
    /// crash error, the harness drops every in-memory structure and
    /// re-opens from disk — the single-crash recovery model.
    CrashPoint,
}

const N_KINDS: usize = 7;

impl FaultKind {
    fn lane(self) -> usize {
        match self {
            FaultKind::Panic => 0,
            FaultKind::Delay => 1,
            FaultKind::CorruptOperand => 2,
            FaultKind::TruncateFile => 3,
            FaultKind::ShortWrite => 4,
            FaultKind::IoError => 5,
            FaultKind::CrashPoint => 6,
        }
    }

    fn salt(self) -> u64 {
        // Distinct odd salts decorrelate the per-kind draw streams.
        [
            0x9E37_79B9_7F4A_7C15,
            0xBF58_476D_1CE4_E5B9,
            0x94D0_49BB_1331_11EB,
            0xD6E8_FEB8_6659_FD93,
            0xA5A3_1CC1_2F6A_B0D5,
            0xC2B2_AE3D_27D4_EB4F,
            0x1656_67B1_9E37_79F9,
        ][self.lane()]
    }
}

/// Seeded, instance-scoped fault schedule. Inert unless armed.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    /// Per-kind firing probability per observation, in \[0, 1\].
    rates: [f64; N_KINDS],
    /// Per-kind explicit observation ordinals (0-based) that always fire,
    /// regardless of rate — the "panic on the 5th request" scripting tests
    /// use for exact schedules.
    scripted: [Vec<u64>; N_KINDS],
    /// Observations per kind (every `maybe_*` call counts one).
    observed: [AtomicU64; N_KINDS],
    /// Fires per kind.
    fired: [AtomicU64; N_KINDS],
    delay: Duration,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::inert()
    }
}

impl FaultPlan {
    /// The do-nothing plan every production config starts from.
    pub fn inert() -> FaultPlan {
        FaultPlan {
            seed: 0,
            rates: [0.0; N_KINDS],
            scripted: Default::default(),
            observed: Default::default(),
            fired: Default::default(),
            delay: Duration::from_millis(2),
        }
    }

    /// A seeded plan with modest default rates on every kind — the CI
    /// smoke's "a few of everything" schedule. Tune with
    /// [`FaultPlan::with_rate`] / [`FaultPlan::script`].
    /// `CrashPoint` stays **script-only** here: a rate-driven crash would
    /// make any env-armed run die at a nondeterministic seam mid-stream;
    /// crash schedules are always explicit ordinals.
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::inert() }
            .with_rate(FaultKind::Panic, 0.03)
            .with_rate(FaultKind::Delay, 0.05)
            .with_rate(FaultKind::CorruptOperand, 0.02)
            .with_rate(FaultKind::TruncateFile, 1.0)
            .with_rate(FaultKind::ShortWrite, 0.02)
            .with_rate(FaultKind::IoError, 0.02)
    }

    /// Arm from `GNN_FAULT_SEED` (the ci.sh hook): `None` when the
    /// variable is unset or unparsable — the inert default.
    pub fn from_env() -> Option<FaultPlan> {
        let seed: u64 = std::env::var("GNN_FAULT_SEED").ok()?.trim().parse().ok()?;
        Some(FaultPlan::seeded(seed))
    }

    pub fn with_rate(mut self, kind: FaultKind, rate: f64) -> FaultPlan {
        self.rates[kind.lane()] = rate.clamp(0.0, 1.0);
        self
    }

    /// Fire `kind` at exactly these 0-based observation ordinals (in
    /// addition to any rate-driven fires).
    pub fn script(mut self, kind: FaultKind, ordinals: &[u64]) -> FaultPlan {
        self.scripted[kind.lane()].extend_from_slice(ordinals);
        self
    }

    pub fn with_delay(mut self, delay: Duration) -> FaultPlan {
        self.delay = delay;
        self
    }

    /// Is any failure class armed?
    pub fn armed(&self) -> bool {
        self.rates.iter().any(|&r| r > 0.0) || self.scripted.iter().any(|s| !s.is_empty())
    }

    /// Observations of `kind` so far.
    pub fn observed(&self, kind: FaultKind) -> u64 {
        self.observed[kind.lane()].load(Ordering::Relaxed)
    }

    /// Fires of `kind` so far.
    pub fn fired(&self, kind: FaultKind) -> u64 {
        self.fired[kind.lane()].load(Ordering::Relaxed)
    }

    /// Count one observation and decide — deterministic in
    /// `(seed, kind, ordinal)`.
    fn decide(&self, kind: FaultKind) -> bool {
        let lane = kind.lane();
        let n = self.observed[lane].fetch_add(1, Ordering::Relaxed);
        let fire = self.scripted[lane].contains(&n)
            || (self.rates[lane] > 0.0 && {
                let draw = splitmix64(self.seed ^ kind.salt() ^ n.wrapping_mul(0x2545_F491_4F6C_DD1D));
                (draw >> 11) as f64 / (1u64 << 53) as f64 > 1.0 - self.rates[lane]
            });
        if fire {
            self.fired[lane].fetch_add(1, Ordering::Relaxed);
        }
        fire
    }

    /// Injection point: panic (the supervised-worker failure mode).
    pub fn maybe_panic(&self) {
        if self.decide(FaultKind::Panic) {
            panic!("fault injection: scheduled worker panic (seed {:#x})", self.seed);
        }
    }

    /// Injection point: slow request.
    pub fn maybe_delay(&self) {
        if self.decide(FaultKind::Delay) {
            std::thread::sleep(self.delay);
        }
    }

    /// Injection point: corrupt `m` in place so [`SparseMatrix::validate`]
    /// must reject it. Returns whether it fired.
    pub fn maybe_corrupt(&self, m: &mut SparseMatrix) -> bool {
        if !self.decide(FaultKind::CorruptOperand) {
            return false;
        }
        corrupt(m);
        true
    }

    /// Injection point: truncate the file at `path` to half its length
    /// (torn-write simulation for persistence hardening). Returns whether
    /// it fired; propagates real I/O errors.
    pub fn maybe_truncate_file(&self, path: &Path) -> std::io::Result<bool> {
        if !self.decide(FaultKind::TruncateFile) {
            return Ok(false);
        }
        let bytes = std::fs::read(path)?;
        std::fs::write(path, &bytes[..bytes.len() / 2])?;
        Ok(true)
    }

    /// Injection point: torn durable write. Returns `Some(prefix_len)` —
    /// how many of `len` bytes actually land — when it fires; the caller
    /// writes only that prefix and reports the write failed (the bytes
    /// are on disk as a torn tail for recovery to find and truncate).
    pub fn maybe_short_write(&self, len: usize) -> Option<usize> {
        if self.decide(FaultKind::ShortWrite) {
            Some(len / 2)
        } else {
            None
        }
    }

    /// Injection point: outright I/O failure at a durable-write seam.
    /// `what` names the seam for the error text.
    pub fn maybe_io_error(&self, what: &str) -> std::io::Result<()> {
        if self.decide(FaultKind::IoError) {
            Err(std::io::Error::other(format!(
                "fault injection: scheduled I/O error at {what} (seed {:#x})",
                self.seed
            )))
        } else {
            Ok(())
        }
    }

    /// Injection point: simulated process death at durability seam
    /// `seam`. Returns whether the caller must now act crashed: stop
    /// touching its files, surface a typed crash error, and let the
    /// harness drop everything and re-open from disk. One lane counts all
    /// seams, so a scripted ordinal `k` kills the `k`-th seam the run
    /// reaches — the property test sweeps `k` across the whole schedule.
    #[must_use = "a fired crash point must abort the caller's durability protocol"]
    pub fn maybe_crash(&self, seam: &str) -> bool {
        let fired = self.decide(FaultKind::CrashPoint);
        if fired {
            eprintln!("fault injection: crash point at {seam} (seed {:#x})", self.seed);
        }
        fired
    }
}

/// One targeted structural corruption per format — each chosen so the
/// matrix fails validation (several already at the `validate_quick` tier).
fn corrupt(m: &mut SparseMatrix) {
    match m {
        SparseMatrix::Coo(c) => {
            if let Some(v) = c.val.first_mut() {
                *v = f32::NAN;
            } else {
                c.row.push(0); // torn triples: row without col/val
            }
        }
        SparseMatrix::Csr(c) => {
            if let Some(i) = c.indices.first_mut() {
                *i = c.cols as u32 + 7;
            } else if let Some(p) = c.indptr.first_mut() {
                *p = 1;
            }
        }
        SparseMatrix::Csc(c) => {
            if let Some(i) = c.indices.first_mut() {
                *i = c.rows as u32 + 7;
            } else if let Some(p) = c.indptr.first_mut() {
                *p = 1;
            }
        }
        SparseMatrix::Dia(d) => {
            d.offsets.push(d.cols as i64 + 1); // offsets/data length mismatch
        }
        SparseMatrix::Bsr(b) => {
            if b.blocks.pop().is_none() {
                if let Some(p) = b.indptr.first_mut() {
                    *p = 1;
                }
            }
        }
        SparseMatrix::Dok(d) => {
            d.map.insert((u32::MAX, u32::MAX), f32::NAN);
        }
        SparseMatrix::Lil(l) => {
            l.rows_data.push(Vec::new()); // row-list count vs rows mismatch
        }
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, SparseMatrix, ALL_FORMATS};

    #[test]
    fn inert_plan_never_fires() {
        let p = FaultPlan::inert();
        assert!(!p.armed());
        for _ in 0..500 {
            p.maybe_panic();
            p.maybe_delay();
        }
        assert_eq!(p.fired(FaultKind::Panic), 0);
        assert_eq!(p.fired(FaultKind::Delay), 0);
        assert_eq!(p.observed(FaultKind::Panic), 500);
    }

    #[test]
    fn scripted_ordinals_fire_exactly() {
        let p = FaultPlan::inert().script(FaultKind::Panic, &[3, 7]);
        assert!(p.armed());
        let mut fired_at = Vec::new();
        for i in 0..10u64 {
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| p.maybe_panic())).is_err() {
                fired_at.push(i);
            }
        }
        assert_eq!(fired_at, vec![3, 7]);
        assert_eq!(p.fired(FaultKind::Panic), 2);
    }

    #[test]
    fn rate_schedule_is_deterministic_in_the_seed() {
        let run = |seed: u64| -> Vec<bool> {
            let p = FaultPlan::inert().with_rate(FaultKind::Delay, 0.3).with_delay(Duration::ZERO);
            let p = FaultPlan { seed, ..p };
            (0..200).map(|_| p.decide(FaultKind::Delay)).collect()
        };
        let a = run(42);
        assert_eq!(a, run(42), "same seed → same schedule");
        assert_ne!(a, run(43), "different seed → different schedule");
        let hits = a.iter().filter(|&&b| b).count();
        assert!(hits > 20 && hits < 120, "rate 0.3 over 200 draws fired {hits} times");
    }

    #[test]
    fn corruption_defeats_validation_in_every_format() {
        let coo = Coo::from_triples(
            6,
            6,
            vec![(0, 1, 1.0), (1, 3, 2.0), (2, 0, 0.5), (4, 5, -1.0)],
        );
        let p = FaultPlan::inert().with_rate(FaultKind::CorruptOperand, 1.0);
        for &fmt in ALL_FORMATS {
            let mut m = SparseMatrix::from_coo(coo.clone()).convert(fmt).unwrap();
            m.validate().unwrap_or_else(|e| panic!("{fmt:?} valid before: {e}"));
            assert!(p.maybe_corrupt(&mut m), "armed plan must fire");
            assert!(m.validate().is_err(), "{fmt:?} must fail validation after corruption");
        }
        // Empty matrices corrupt detectably too.
        for &fmt in ALL_FORMATS {
            let mut m =
                SparseMatrix::from_coo(Coo::from_triples(3, 3, vec![])).convert(fmt).unwrap();
            assert!(p.maybe_corrupt(&mut m));
            assert!(m.validate().is_err(), "empty {fmt:?} must fail validation after corruption");
        }
    }

    #[test]
    fn truncation_halves_the_file() {
        let dir = std::env::temp_dir().join("gnn_spmm_fault_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trunc.json");
        std::fs::write(&path, b"0123456789").unwrap();
        let p = FaultPlan::inert().with_rate(FaultKind::TruncateFile, 1.0);
        assert!(p.maybe_truncate_file(&path).unwrap());
        assert_eq!(std::fs::read(&path).unwrap(), b"01234");
        let inert = FaultPlan::inert();
        assert!(!inert.maybe_truncate_file(&path).unwrap(), "inert plan leaves files alone");
        assert_eq!(std::fs::read(&path).unwrap().len(), 5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn short_write_yields_half_the_bytes() {
        let p = FaultPlan::inert().with_rate(FaultKind::ShortWrite, 1.0);
        assert_eq!(p.maybe_short_write(10), Some(5));
        assert_eq!(p.maybe_short_write(1), Some(0));
        let inert = FaultPlan::inert();
        assert_eq!(inert.maybe_short_write(10), None);
    }

    #[test]
    fn io_error_fires_on_schedule_and_names_the_seam() {
        let p = FaultPlan::inert().script(FaultKind::IoError, &[1]);
        assert!(p.maybe_io_error("wal-append").is_ok());
        let err = p.maybe_io_error("wal-append").unwrap_err();
        assert!(err.to_string().contains("wal-append"), "{err}");
        assert!(p.maybe_io_error("wal-append").is_ok());
        assert_eq!(p.fired(FaultKind::IoError), 1);
    }

    #[test]
    fn crash_points_count_one_lane_across_seams() {
        // Ordinal 2 on a shared lane kills the third seam the run reaches,
        // whichever seam that is — the sweep the property test relies on.
        let p = FaultPlan::inert().script(FaultKind::CrashPoint, &[2]);
        assert!(!p.maybe_crash("wal-append"));
        assert!(!p.maybe_crash("checkpoint-rename"));
        assert!(p.maybe_crash("compact-publish"));
        assert!(!p.maybe_crash("wal-append"));
        assert_eq!(p.observed(FaultKind::CrashPoint), 4);
        assert_eq!(p.fired(FaultKind::CrashPoint), 1);
    }

    #[test]
    fn seeded_plans_keep_crash_points_script_only() {
        let p = FaultPlan::seeded(7);
        for _ in 0..500 {
            assert!(!p.maybe_crash("seam"));
        }
    }

    #[test]
    fn from_env_requires_the_variable() {
        // Never set in the test environment unless ci.sh armed it; both
        // outcomes are legal, but parsing must not panic.
        let _ = FaultPlan::from_env();
    }
}
