//! Miniature property-based testing framework (proptest is unavailable
//! offline).
//!
//! Usage inside `#[cfg(test)]`:
//! ```ignore
//! check(200, |rng| gen_matrix(rng), |m| {
//!     prop_assert(roundtrip(m) == *m, "conversion round-trip")
//! });
//! ```
//! Each case is generated from a deterministic per-case seed; on failure the
//! framework reports the seed so the case can be replayed with
//! [`replay`]. No shrinking — generators are kept small instead.

pub mod fault;

pub use fault::{FaultKind, FaultPlan};

use crate::util::rng::Rng;

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Assert helper for property bodies.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert two f32 slices are elementwise close.
pub fn prop_close(a: &[f32], b: &[f32], tol: f32, what: &str) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("{what}: index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Base seed; override with GNN_SPMM_PROP_SEED to reproduce CI failures.
fn base_seed() -> u64 {
    std::env::var("GNN_SPMM_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `cases` random property checks. Panics on first failure, printing the
/// per-case seed for replay.
pub fn check<T, G, P>(cases: usize, mut generate: G, property: P)
where
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
    T: std::fmt::Debug,
{
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let input = generate(&mut rng);
        if let Err(msg) = property(&input) {
            panic!(
                "property failed on case {case} (replay: GNN_SPMM_PROP_SEED={base}, case seed {seed})\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

/// Re-run a single failing case by its reported seed.
pub fn replay<T, G, P>(seed: u64, mut generate: G, property: P)
where
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> PropResult,
    T: std::fmt::Debug,
{
    let mut rng = Rng::new(seed);
    let input = generate(&mut rng);
    property(&input).expect("replayed property failed");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0usize;
        check(
            50,
            |rng| rng.gen_range(100),
            |&x| {
                let _ = x;
                Ok(())
            },
        );
        n += 50;
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(
            50,
            |rng| rng.gen_range(100),
            |&x| prop_assert(x < 90, "x should be < 90 (expected to fail sometimes)"),
        );
    }

    #[test]
    fn prop_close_detects_mismatch() {
        assert!(prop_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, "same").is_ok());
        assert!(prop_close(&[1.0], &[1.1], 1e-3, "diff").is_err());
        assert!(prop_close(&[1.0], &[1.0, 2.0], 1e-3, "len").is_err());
    }

    #[test]
    fn relative_tolerance_scales() {
        // 1e6 vs 1e6+1 is within 1e-5 relative.
        assert!(prop_close(&[1e6], &[1e6 + 1.0], 1e-5, "rel").is_ok());
    }
}
