//! Elementwise / rowwise operations on [`Matrix`] used by the GNN layers and
//! the from-scratch ML models.

use super::Matrix;

/// `a += w · b` elementwise over raw f32 storage (gradient accumulation —
/// the mini-batch shard-weighted sum). Slice twin of [`axpy`], for the
/// bias vectors and `Matrix::data` buffers the grads structs carry.
pub fn axpy_slice(a: &mut [f32], b: &[f32], w: f32) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b.iter()) {
        *x += w * y;
    }
}

/// `a *= w` elementwise over raw f32 storage (in-place twin of [`scale`]).
pub fn scale_slice(a: &mut [f32], w: f32) {
    for x in a.iter_mut() {
        *x *= w;
    }
}

/// ReLU forward.
pub fn relu(x: &Matrix) -> Matrix {
    Matrix {
        rows: x.rows,
        cols: x.cols,
        data: x.data.iter().map(|&v| v.max(0.0)).collect(),
    }
}

/// ReLU backward: grad * (x > 0).
pub fn relu_grad(x: &Matrix, grad: &Matrix) -> Matrix {
    assert_eq!(x.shape(), grad.shape());
    Matrix {
        rows: x.rows,
        cols: x.cols,
        data: x
            .data
            .iter()
            .zip(grad.data.iter())
            .map(|(&v, &g)| if v > 0.0 { g } else { 0.0 })
            .collect(),
    }
}

/// LeakyReLU forward (GAT uses slope 0.2 on attention logits).
pub fn leaky_relu(x: &Matrix, slope: f32) -> Matrix {
    Matrix {
        rows: x.rows,
        cols: x.cols,
        data: x.data.iter().map(|&v| if v > 0.0 { v } else { slope * v }).collect(),
    }
}

/// Elementwise sigmoid.
pub fn sigmoid(x: &Matrix) -> Matrix {
    Matrix {
        rows: x.rows,
        cols: x.cols,
        data: x.data.iter().map(|&v| 1.0 / (1.0 + (-v).exp())).collect(),
    }
}

/// Elementwise tanh.
pub fn tanh(x: &Matrix) -> Matrix {
    Matrix { rows: x.rows, cols: x.cols, data: x.data.iter().map(|&v| v.tanh()).collect() }
}

/// a + b.
pub fn add(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.shape(), b.shape());
    Matrix {
        rows: a.rows,
        cols: a.cols,
        data: a.data.iter().zip(b.data.iter()).map(|(&x, &y)| x + y).collect(),
    }
}

/// a - b.
pub fn sub(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.shape(), b.shape());
    Matrix {
        rows: a.rows,
        cols: a.cols,
        data: a.data.iter().zip(b.data.iter()).map(|(&x, &y)| x - y).collect(),
    }
}

/// Hadamard product.
pub fn mul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.shape(), b.shape());
    Matrix {
        rows: a.rows,
        cols: a.cols,
        data: a.data.iter().zip(b.data.iter()).map(|(&x, &y)| x * y).collect(),
    }
}

/// Scalar multiply.
pub fn scale(a: &Matrix, s: f32) -> Matrix {
    Matrix { rows: a.rows, cols: a.cols, data: a.data.iter().map(|&x| x * s).collect() }
}

/// In-place `a += s * b` (used by optimizers to avoid allocation).
pub fn axpy(a: &mut Matrix, s: f32, b: &Matrix) {
    assert_eq!(a.shape(), b.shape());
    for (x, &y) in a.data.iter_mut().zip(b.data.iter()) {
        *x += s * y;
    }
}

/// Broadcast-add a row vector (bias) to every row.
pub fn add_row(a: &Matrix, bias: &[f32]) -> Matrix {
    assert_eq!(a.cols, bias.len());
    let mut out = a.clone();
    for r in 0..out.rows {
        for (v, &b) in out.row_mut(r).iter_mut().zip(bias.iter()) {
            *v += b;
        }
    }
    out
}

/// Column sums (bias gradients).
pub fn col_sums(m: &Matrix) -> Vec<f32> {
    let mut out = vec![0f32; m.cols];
    for r in 0..m.rows {
        for (o, &v) in out.iter_mut().zip(m.row(r).iter()) {
            *o += v;
        }
    }
    out
}

/// Row-wise softmax.
pub fn softmax_rows(x: &Matrix) -> Matrix {
    let mut out = x.clone();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum.max(1e-30);
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
    out
}

/// Row-wise log-softmax (numerically stable).
pub fn log_softmax_rows(x: &Matrix) -> Matrix {
    let mut out = x.clone();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let logsum = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
        for v in row.iter_mut() {
            *v -= logsum;
        }
    }
    out
}

/// Mean negative log-likelihood of `labels` under row log-probabilities,
/// restricted to `mask` rows (graph datasets train on a node subset).
/// Returns (loss, gradient wrt logits) where gradient already includes the
/// softmax backward: `(softmax - onehot) / n_masked`.
pub fn masked_xent_with_grad(
    logits: &Matrix,
    labels: &[usize],
    mask: &[bool],
) -> (f32, Matrix) {
    assert_eq!(logits.rows, labels.len());
    assert_eq!(logits.rows, mask.len());
    let logp = log_softmax_rows(logits);
    let n_masked = mask.iter().filter(|&&m| m).count().max(1);
    let scale = 1.0 / n_masked as f32;
    let mut loss = 0.0f32;
    let mut grad = Matrix::zeros(logits.rows, logits.cols);
    for r in 0..logits.rows {
        if !mask[r] {
            continue;
        }
        let lp = logp.row(r);
        loss -= lp[labels[r]];
        let g = grad.row_mut(r);
        for c in 0..lp.len() {
            g[c] = (lp[c].exp() - f32::from(c == labels[r])) * scale;
        }
    }
    (loss * scale, grad)
}

/// Classification accuracy of argmax rows vs labels over `mask`.
pub fn masked_accuracy(logits: &Matrix, labels: &[usize], mask: &[bool]) -> f64 {
    let mut correct = 0usize;
    let mut total = 0usize;
    for r in 0..logits.rows {
        if !mask[r] {
            continue;
        }
        total += 1;
        let row = logits.row(r);
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        if pred == labels[r] {
            correct += 1;
        }
    }
    if total == 0 {
        0.0
    } else {
        correct as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn relu_and_grad() {
        let x = Matrix::from_vec(1, 4, vec![-1.0, 0.0, 2.0, -3.0]);
        assert_eq!(relu(&x).data, vec![0.0, 0.0, 2.0, 0.0]);
        let g = Matrix::full(1, 4, 1.0);
        assert_eq!(relu_grad(&x, &g).data, vec![0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(1);
        let x = Matrix::rand(5, 7, &mut rng);
        let s = softmax_rows(&x);
        for r in 0..5 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(s.row(r).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn log_softmax_consistent_with_softmax() {
        let mut rng = Rng::new(2);
        let x = Matrix::rand(4, 6, &mut rng);
        let s = softmax_rows(&x);
        let ls = log_softmax_rows(&x);
        for i in 0..x.data.len() {
            assert!((ls.data[i].exp() - s.data[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let x = Matrix::from_vec(1, 3, vec![1000.0, 1001.0, 999.0]);
        let s = softmax_rows(&x);
        assert!(s.data.iter().all(|v| v.is_finite()));
        assert!((s.data.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn xent_grad_is_softmax_minus_onehot() {
        let logits = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 0.5, 0.5, 0.5]);
        let labels = vec![2usize, 0usize];
        let mask = vec![true, true];
        let (loss, grad) = masked_xent_with_grad(&logits, &labels, &mask);
        assert!(loss > 0.0);
        let s = softmax_rows(&logits);
        // row 0, class 2: (p - 1) / 2
        assert!((grad.at(0, 2) - (s.at(0, 2) - 1.0) / 2.0).abs() < 1e-5);
        assert!((grad.at(0, 0) - s.at(0, 0) / 2.0).abs() < 1e-5);
    }

    #[test]
    fn xent_respects_mask() {
        let logits = Matrix::from_vec(2, 2, vec![5.0, -5.0, -5.0, 5.0]);
        let labels = vec![0usize, 0usize]; // row 1 is wrong but masked out
        let mask = vec![true, false];
        let (loss, grad) = masked_xent_with_grad(&logits, &labels, &mask);
        assert!(loss < 0.01, "masked loss should be tiny: {loss}");
        assert_eq!(grad.row(1), &[0.0, 0.0]);
    }

    #[test]
    fn accuracy_counts() {
        let logits = Matrix::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0]);
        let labels = vec![0, 1, 1];
        assert!((masked_accuracy(&logits, &labels, &[true, true, true]) - 2.0 / 3.0).abs() < 1e-9);
        assert!((masked_accuracy(&logits, &labels, &[true, true, false]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn axpy_accumulates() {
        let mut a = Matrix::full(2, 2, 1.0);
        let b = Matrix::full(2, 2, 2.0);
        axpy(&mut a, 0.5, &b);
        assert_eq!(a.data, vec![2.0; 4]);
    }
}
