//! Dense f32 matrix substrate.
//!
//! Row-major `Matrix` with the operations the GNN layers and the ML stack
//! need: threaded blocked GEMM, transpose, row softmax / log-softmax,
//! activations and elementwise arithmetic. This is the "dense side" of every
//! SpMM (`sparse × dense → dense`) in the system.

pub mod matrix;
pub mod ops;

pub use matrix::Matrix;
